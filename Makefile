PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: ci test test-sharded smoke examples-smoke bench tune tune-smoke \
	bench-batched-smoke bench-sharded-smoke bench-epilogue-smoke \
	bench-obs-smoke trace-smoke serve-smoke lint analyze \
	traffic-baseline

# examples-smoke subsumes the quickstart smoke (runs it in full), so ci
# doesn't run it twice.
ci: test examples-smoke

# Style lint: ruff (E/F/W/I/UP per pyproject.toml) when installed, plus
# the repo-specific AST rules (RL001-RL006).  ruff is a dev dependency
# (requirements-dev.txt); a container without it still runs the RL leg.
lint:
	@if $(PY) -m ruff --version >/dev/null 2>&1; then \
	    $(PY) -m ruff check .; \
	else \
	    echo "ruff not installed; skipping style leg (pip install ruff)"; \
	fi
	$(PY) -m repro.analysis lint

# Static verification gate (CI-required): repo lint + plan-invariant
# linter over the mini suite + registry-driven kernel audit + the
# bytes-moved/coalescing traffic gate diffed against the committed
# baseline (artifacts/traffic_baseline.json).  Reports land in
# artifacts/ and are uploaded by CI.
analyze: lint
	mkdir -p artifacts
	$(PY) -m repro.analysis planlint --suite mini
	$(PY) -m repro.analysis audit --out artifacts/kernel_audit.txt
	$(PY) -m repro.analysis traffic --check \
	    --json artifacts/traffic_report.json

# Regenerate the static bytes-moved baseline after an *intentional*
# traffic change (new kernel, tiling change); commit the diff with the
# change that caused it.
traffic-baseline:
	$(PY) -m repro.analysis traffic --update

# Tier-1 verify (ROADMAP.md).  DeprecationWarnings are errors: first-party
# code and tests must use the v1 policy=/exec= spellings (the shim tests
# in tests/test_api.py exercise the legacy forms under pytest.warns).
test:
	$(PY) -m pytest -x -q -W error::DeprecationWarning

# Sharded SpMM tests on a forced 8-device CPU substrate (tests/conftest.py
# turns REPRO_FORCE_DEVICES into XLA_FLAGS before jax initializes).  The
# plain `make test` run covers the same tests via a subprocess wrapper;
# this target runs them directly, with the mesh visible to every test.
test-sharded:
	REPRO_FORCE_DEVICES=8 $(PY) -m pytest -x -q \
	    -W error::DeprecationWarning \
	    tests/test_distributed_spmm.py tests/test_shard_property.py

# Fast interpret-mode smoke of the public SpMM API
smoke:
	$(PY) examples/quickstart.py

# Every example end-to-end on CPU (Pallas interpret mode): quickstart in
# full, the rest via their CI-sized --smoke paths.  Wired into CI so the
# examples can never silently rot against the API.
examples-smoke:
	$(PY) examples/quickstart.py
	$(PY) examples/moe_spmm_demo.py --smoke
	$(PY) examples/serve_pruned.py --smoke
	$(PY) examples/train_tiny_lm.py --smoke

bench:
	$(PY) -m benchmarks.run

# Full empirical autotune over the paper corpus (see EXPERIMENTS.md)
tune:
	$(PY) -m repro.tune --suite paper --out tune.json

# CI smoke: autotune the 3-matrix mini suite + corpus bench, artifacts
# land in artifacts/ (TuneDB JSON + bench CSV)
tune-smoke:
	mkdir -p artifacts
	$(PY) -m repro.tune --suite mini --out artifacts/tune.json \
	    --warmup 1 --repeat 2
	REPRO_CORPUS_SUITE=mini $(PY) -m benchmarks.run corpus \
	    > artifacts/bench_corpus.csv
	cat artifacts/bench_corpus.csv

# CI smoke: tiny batch x k sweep through the Pallas kernels in interpret
# mode (real batched/K-tiled grid dataflow), CSV lands in artifacts/
bench-batched-smoke:
	mkdir -p artifacts
	REPRO_BENCH_BATCHED=smoke $(PY) -m benchmarks.run batched \
	    > artifacts/bench_batched.csv
	cat artifacts/bench_batched.csv

# CI smoke: fused epilogue vs separate elementwise tail through the Pallas
# kernels in interpret mode (real in-kernel epilogue flush), CSV lands in
# artifacts/
bench-epilogue-smoke:
	mkdir -p artifacts
	REPRO_BENCH_EPILOGUE=smoke $(PY) -m benchmarks.run epilogue \
	    > artifacts/bench_epilogue.csv
	cat artifacts/bench_epilogue.csv

# CI smoke: obs-enabled corpus bench — traced engine execution with the
# live roofline accountant; prints obs.report() (achieved bandwidth vs
# the measured streaming roof per method) and lands the CSV in artifacts/
bench-obs-smoke:
	mkdir -p artifacts
	REPRO_BENCH_OBS=smoke $(PY) -m benchmarks.run obs \
	    > artifacts/bench_obs.csv
	cat artifacts/bench_obs.csv

# CI smoke: traced interpret-mode serve + train — Chrome trace-event JSON
# and metrics dumps land in artifacts/ and are schema-validated
# (repro.obs.validate); a malformed trace or an empty span set fails here
# instead of uploading a useless artifact.
trace-smoke:
	mkdir -p artifacts
	$(PY) -m repro.launch.serve --smoke --batch 2 --prompt-len 16 \
	    --prune-ffn 0.25 \
	    --trace-out artifacts/serve_trace.json \
	    --metrics-out artifacts/serve_metrics.json
	$(PY) -m repro.launch.train --smoke --steps 2 --global-batch 2 \
	    --seq-len 16 \
	    --trace-out artifacts/train_trace.json \
	    --metrics-out artifacts/train_metrics.json
	$(PY) -m repro.obs.validate \
	    --trace artifacts/serve_trace.json \
	    --require-cats plan,cache,dispatch,serve \
	    --metrics artifacts/serve_metrics.json \
	    --require-metrics plan_resolve_total,plan_cache_events_total,serve_latency_us
	$(PY) -m repro.obs.validate \
	    --trace artifacts/train_trace.json \
	    --metrics artifacts/train_metrics.json \
	    --require-metrics train_step_latency_us

# CI smoke: online serving under Poisson load — continuous batching vs
# one-at-a-time (the >= 1.5x smoke throughput gate lives inside the
# bench), plus a Pallas interpret-mode leg and the shed-accounting leg.
# Trace + metrics artifacts are schema-validated: the serve.* spans and
# the serving metric families must actually exist.
serve-smoke:
	mkdir -p artifacts
	REPRO_BENCH_SERVING=smoke \
	    REPRO_SERVING_TRACE_OUT=artifacts/serving_trace.json \
	    REPRO_SERVING_METRICS_OUT=artifacts/serving_metrics.json \
	    $(PY) -m benchmarks.run serving > artifacts/bench_serving.csv
	cat artifacts/bench_serving.csv
	$(PY) -m repro.obs.validate \
	    --trace artifacts/serving_trace.json \
	    --require-cats serve \
	    --metrics artifacts/serving_metrics.json \
	    --require-metrics serve_requests_total,serve_request_latency_us,serve_batch_occupancy,program_cache_events_total

# CI smoke: shard-count sweep + nnz-vs-row balance on a forced 8-device
# CPU mesh (bench_sharded forces the device count itself when run as a
# module), CSV lands in artifacts/
bench-sharded-smoke:
	mkdir -p artifacts
	$(PY) -m benchmarks.bench_sharded > artifacts/bench_sharded.csv
	cat artifacts/bench_sharded.csv
