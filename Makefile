PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: ci test smoke bench

ci: test smoke

# Tier-1 verify (ROADMAP.md)
test:
	$(PY) -m pytest -x -q

# Fast interpret-mode smoke of the public SpMM API
smoke:
	$(PY) examples/quickstart.py

bench:
	$(PY) -m benchmarks.run
