PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: ci test smoke bench tune tune-smoke bench-batched-smoke

ci: test smoke

# Tier-1 verify (ROADMAP.md)
test:
	$(PY) -m pytest -x -q

# Fast interpret-mode smoke of the public SpMM API
smoke:
	$(PY) examples/quickstart.py

bench:
	$(PY) -m benchmarks.run

# Full empirical autotune over the paper corpus (see EXPERIMENTS.md)
tune:
	$(PY) -m repro.tune --suite paper --out tune.json

# CI smoke: autotune the 3-matrix mini suite + corpus bench, artifacts
# land in artifacts/ (TuneDB JSON + bench CSV)
tune-smoke:
	mkdir -p artifacts
	$(PY) -m repro.tune --suite mini --out artifacts/tune.json \
	    --warmup 1 --repeat 2
	REPRO_CORPUS_SUITE=mini $(PY) -m benchmarks.run corpus \
	    > artifacts/bench_corpus.csv
	cat artifacts/bench_corpus.csv

# CI smoke: tiny batch x k sweep through the Pallas kernels in interpret
# mode (real batched/K-tiled grid dataflow), CSV lands in artifacts/
bench-batched-smoke:
	mkdir -p artifacts
	REPRO_BENCH_BATCHED=smoke $(PY) -m benchmarks.run batched \
	    > artifacts/bench_batched.csv
	cat artifacts/bench_batched.csv
