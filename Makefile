PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: ci test smoke examples-smoke bench tune tune-smoke \
	bench-batched-smoke

# examples-smoke subsumes the quickstart smoke (runs it in full), so ci
# doesn't run it twice.
ci: test examples-smoke

# Tier-1 verify (ROADMAP.md)
test:
	$(PY) -m pytest -x -q

# Fast interpret-mode smoke of the public SpMM API
smoke:
	$(PY) examples/quickstart.py

# Every example end-to-end on CPU (Pallas interpret mode): quickstart in
# full, the rest via their CI-sized --smoke paths.  Wired into CI so the
# examples can never silently rot against the API.
examples-smoke:
	$(PY) examples/quickstart.py
	$(PY) examples/moe_spmm_demo.py --smoke
	$(PY) examples/serve_pruned.py --smoke
	$(PY) examples/train_tiny_lm.py --smoke

bench:
	$(PY) -m benchmarks.run

# Full empirical autotune over the paper corpus (see EXPERIMENTS.md)
tune:
	$(PY) -m repro.tune --suite paper --out tune.json

# CI smoke: autotune the 3-matrix mini suite + corpus bench, artifacts
# land in artifacts/ (TuneDB JSON + bench CSV)
tune-smoke:
	mkdir -p artifacts
	$(PY) -m repro.tune --suite mini --out artifacts/tune.json \
	    --warmup 1 --repeat 2
	REPRO_CORPUS_SUITE=mini $(PY) -m benchmarks.run corpus \
	    > artifacts/bench_corpus.csv
	cat artifacts/bench_corpus.csv

# CI smoke: tiny batch x k sweep through the Pallas kernels in interpret
# mode (real batched/K-tiled grid dataflow), CSV lands in artifacts/
bench-batched-smoke:
	mkdir -p artifacts
	REPRO_BENCH_BATCHED=smoke $(PY) -m benchmarks.run batched \
	    > artifacts/bench_batched.csv
	cat artifacts/bench_batched.csv
