"""Corpus sweep: per-matrix kernel timings + heuristic-vs-oracle accuracy.

The §5.4 claim generalized from Fig. 6's synthetic sweep to the matrix
corpus (``repro.matrices.suites``; ``REPRO_CORPUS_SUITE`` env overrides
the default ``paper`` suite — CI smoke uses ``mini``).  Per matrix:
row-length stats (d, cv, Gini — the Fig. 1 axes), a vendor-stand-in
timing, and *every registered SpMM method* (``repro.kernels.registry``)
timed through the inline plan-per-call path — a newly registered method
shows up here with zero edits.  Then three selection policies are scored
against the merge/rowsplit oracle:

* the paper's fixed K40c threshold (9.35),
* a threshold calibrated on *this* sweep's timings,
* the TuneDB ladder as ``engine.get_plan`` would resolve it — exact hits
  replayed from the sweep's own records (100% by construction; reported
  as a consistency check) and, more interestingly, **class-signature
  leave-one-out**: each matrix resolved only from the *other* matrices'
  records, the generalization the binned ``(m, k, d, cv)`` classes claim.
"""
from __future__ import annotations

import functools
import os

import jax
import numpy as np

from repro.core import ExecutionConfig, Heuristic, PlanPolicy, calibrate, \
    spmm
from repro.core.plan import pattern_fingerprint
from repro.kernels import ref, registry
from repro.matrices import compute_stats, get_suite
from repro.tune.db import TuneDB, TuneRecord

from .common import geomean, make_b, timeit

N = 64
_XLA = ExecutionConfig(impl="xla")


def run(csv=print):
    suite = os.environ.get("REPRO_CORPUS_SUITE", "paper")
    specs = get_suite(suite)
    csv("name,us_per_call,derived")

    recs, fps, mats = [], [], []
    for spec in specs:
        a = spec()
        s = compute_stats(a)
        b = make_b(7, a.k, N)
        t_vendor = timeit(jax.jit(ref.spmm_gather_ref), a, b)
        csv(f"corpus_{spec.name}_vendor,{t_vendor:.1f},"
            f"d={s.d:.1f};cv={s.cv:.2f};gini={s.gini:.2f}")
        # Every registered method, dispatched through the registry — the
        # per-method l_pad/t defaults come from PlanPolicy.resolve, so a
        # new method needs no plumbing here.  Resolving once per matrix
        # outside the timed callable pins the explicit statics, keeping
        # the auto ladder (TuneDB/heuristic) out of the timed loop; the
        # per-call parameter validation and structure build that remain
        # inside are the plan-per-call cost this bench times on purpose.
        timings = {}
        for mname in registry.method_names():
            r = PlanPolicy(method=mname).resolve(a)
            pol = PlanPolicy(method=r.method, t=r.t, tl=r.tl,
                             l_pad=r.l_pad)
            timings[mname] = timeit(functools.partial(
                spmm, policy=pol, exec=_XLA, plan="inline"), a, b)
        winner = min(timings, key=timings.get)
        for mname, t_us in timings.items():
            # tcv is timing noise (std/mean over repeats, from the
            # TimingResult samples) — a WIN whose margin over the
            # runner-up is inside the noise band is not a real win.
            csv(f"corpus_{spec.name}_{mname},{t_us:.1f},"
                f"tcv={t_us.cv:.3f}"
                f"{';WIN' if mname == winner else ''}")
        t_mg, t_rs = timings["merge"], timings["rowsplit"]
        pair_winner = "merge" if t_mg < t_rs else "rowsplit"
        pred = Heuristic().choose(a)
        csv(f"corpus_{spec.name}_heuristic,0,pred={pred};"
            f"oracle={pair_winner};"
            f"{'HIT' if pred == pair_winner else 'MISS'}")
        recs.append(TuneRecord(
            method=pair_winner, merge_us=t_mg, rowsplit_us=t_rs, m=s.m,
            k=s.k, d=s.d, cv=s.cv, n=N, name=spec.name, timings=timings))
        fps.append(pattern_fingerprint(a))
        mats.append(a)

    ds = np.array([r.d for r in recs])
    t_mg = np.array([r.merge_us for r in recs])
    t_rs = np.array([r.rowsplit_us for r in recs])
    oracle_merge = t_mg < t_rs
    t_best = np.minimum(t_mg, t_rs)

    paper_pred = ds < Heuristic().threshold
    csv(f"corpus_paper_threshold_accuracy,0,"
        f"{np.mean(paper_pred == oracle_merge) * 100:.1f}%")
    thr, acc = calibrate(ds, t_rs, t_mg)
    csv(f"corpus_calibrated_threshold,0,{thr:.2f}")
    csv(f"corpus_calibrated_accuracy,0,{acc * 100:.1f}%")

    # TuneDB ladder accuracy: exact (consistency) and class leave-one-out.
    db = TuneDB(backend="bench")
    for fp, r in zip(fps, recs):
        db.record(fp, r)
    exact_ok = sum(db.choose(a) == r.oracle
                   for a, r in zip(mats, recs))
    csv(f"corpus_tunedb_exact_accuracy,0,"
        f"{exact_ok / len(recs) * 100:.1f}%")
    loo_ok = 0
    for i, r in enumerate(recs):
        loo = TuneDB(backend="bench")
        for j, (fp, rj) in enumerate(zip(fps, recs)):
            if j != i:
                loo.record(fp, rj)
        loo.calibrate_threshold()
        loo_ok += loo.choose(mats[i]) == r.oracle
    csv(f"corpus_tunedb_loo_accuracy,0,"
        f"{loo_ok / len(recs) * 100:.1f}%")
    csv(f"corpus_oracle_vs_merge_only_geomean,0,"
        f"{geomean(t_mg / t_best):.3f}x")
    csv(f"corpus_oracle_vs_rowsplit_only_geomean,0,"
        f"{geomean(t_rs / t_best):.3f}x")


if __name__ == "__main__":
    run()
