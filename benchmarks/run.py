"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  See benchmarks/common.py for the
timing methodology note (XLA impls timed on CPU; Pallas bodies validated in
interpret mode by tests/).
"""
from __future__ import annotations

import sys


def main() -> None:
    from . import (bench_batched, bench_corpus, bench_epilogue,
                   bench_fig1_imbalance, bench_fig4_aspect,
                   bench_fig5_rows, bench_fig6_heuristic,
                   bench_fig7_density, bench_plan_reuse, bench_sharded,
                   bench_table1_analysis, bench_train_step,
                   bench_moe_balance)
    mods = [
        ("fig1", bench_fig1_imbalance),
        ("fig4", bench_fig4_aspect),
        ("fig5", bench_fig5_rows),
        ("fig6", bench_fig6_heuristic),
        ("fig7", bench_fig7_density),
        ("table1", bench_table1_analysis),
        ("moe", bench_moe_balance),
        ("plan", bench_plan_reuse),
        ("batched", bench_batched),
        ("epilogue", bench_epilogue),
        ("sharded", bench_sharded),
        ("train", bench_train_step),
        ("corpus", bench_corpus),
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    printed_header = False
    for name, mod in mods:
        if only and only != name:
            continue
        print(f"# --- {name}: {mod.__doc__.splitlines()[0]}", flush=True)

        def csv(line):
            nonlocal printed_header
            if line.startswith("name,") and printed_header:
                return
            if line.startswith("name,"):
                printed_header = True
            print(line, flush=True)

        mod.run(csv=csv)


if __name__ == "__main__":
    main()
