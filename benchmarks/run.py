"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  See benchmarks/common.py for the
timing methodology note (XLA impls timed on CPU; Pallas bodies validated in
interpret mode by tests/).

Every ``bench_*.py`` module in this directory must appear in ``MODS`` —
``check_registration()`` asserts it at startup (and in tests), so a new
bench can't be silently left out of CI.
"""
from __future__ import annotations

import os
import sys


def _mods():
    from . import (bench_batched, bench_corpus, bench_epilogue,
                   bench_fig1_imbalance, bench_fig4_aspect,
                   bench_fig5_rows, bench_fig6_heuristic,
                   bench_fig7_density, bench_obs, bench_plan_reuse,
                   bench_serving, bench_sharded, bench_table1_analysis,
                   bench_train_step, bench_moe_balance)
    return [
        ("fig1", bench_fig1_imbalance),
        ("fig4", bench_fig4_aspect),
        ("fig5", bench_fig5_rows),
        ("fig6", bench_fig6_heuristic),
        ("fig7", bench_fig7_density),
        ("table1", bench_table1_analysis),
        ("moe", bench_moe_balance),
        ("plan", bench_plan_reuse),
        ("batched", bench_batched),
        ("epilogue", bench_epilogue),
        ("sharded", bench_sharded),
        ("train", bench_train_step),
        ("corpus", bench_corpus),
        ("obs", bench_obs),
        ("serving", bench_serving),
    ]


def check_registration(mods=None) -> list:
    """Every bench_*.py present on disk must be registered. Returns the
    sorted list of unregistered module stems (empty = in sync); ``main``
    refuses to run when it's non-empty."""
    mods = _mods() if mods is None else mods
    here = os.path.dirname(os.path.abspath(__file__))
    on_disk = {f[:-3] for f in os.listdir(here)
               if f.startswith("bench_") and f.endswith(".py")}
    registered = {mod.__name__.rsplit(".", 1)[-1] for _, mod in mods}
    return sorted(on_disk - registered)


def main() -> None:
    mods = _mods()
    missing = check_registration(mods)
    if missing:
        raise SystemExit(
            f"benchmarks/run.py: unregistered bench modules {missing} — "
            "add them to _mods() so they run in CI")
    only = sys.argv[1] if len(sys.argv) > 1 else None
    printed_header = False
    for name, mod in mods:
        if only and only != name:
            continue
        print(f"# --- {name}: {mod.__doc__.splitlines()[0]}", flush=True)

        def csv(line):
            nonlocal printed_header
            if line.startswith("name,") and printed_header:
                return
            if line.startswith("name,"):
                printed_header = True
            print(line, flush=True)

        mod.run(csv=csv)


if __name__ == "__main__":
    main()
