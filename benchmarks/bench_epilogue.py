"""Fused epilogue vs. the unfused serving tail (MLP block, mini corpus).

The pruned-FFN serving hot path is ``h = gelu(W1_csr @ x + b)``: an SpMM
followed by an elementwise tail.  The serving loop (``examples/
serve_pruned.py``) is a Python loop over layers — plans are built and
layers dispatched eagerly, so before the fused epilogue the tail ran
primitive-by-primitive against the SpMM's jitted program, with C crossing
a program boundary per primitive.  Three timings per (matrix × dtype):

* ``unfused`` — that pre-epilogue serving regime: ``execute_plan`` (one
  jitted program) then an *eager* ``gelu(C + bias)`` — C is written, then
  re-read by each tail primitive's dispatch,
* ``fused``   — one ``execute_plan`` with
  ``Epilogue(bias=True, activation="gelu")``: the tail is applied at the
  accumulator flush inside the same program and the activated output is
  written once.  ``derived`` reports unfused/fused next to the
  bytes-moved ceiling from ``repro.obs.roofline.fused_epilogue_ceiling``
  (a bandwidth-bound bound: CPU caches soften the round-trip it counts,
  dispatch savings add back),
* ``block``   — both steps inside *one* jit, unfused at the source level:
  what whole-block jitting recovers when the serving loop can afford it
  (static shapes, plans hoisted).  Reported for honesty: against this
  baseline the epilogue's win is having *made* the block one program,
  not extra bytes — XLA already fuses a jitted elementwise tail.

Dtype configs: f32 end-to-end, and bf16 inputs with f32 accumulation
(``acc_dtype="float32"``) writing bf16 — the mixed-precision serving
setup, which also halves the bytes of every C crossing it removes.

Matrices: the ``mini`` corpus suite (``repro.matrices.suites``) at the
paper's n=64 — the sparse-d regime (d ≈ 3–24) where the tail is a real
fraction of the call.  Smoke mode (``REPRO_BENCH_EPILOGUE=smoke``, used
by ``make bench-epilogue-smoke``): one tiny synthetic matrix through the
*Pallas kernels in interpret mode* — exercising the real in-kernel
epilogue flush, not the XLA twin — with the CSV landing in artifacts/
from CI.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp

from repro.core import Epilogue, ExecutionConfig, build_plan, execute_plan
from repro.matrices import get_suite
from repro.obs.roofline import fused_epilogue_ceiling
from .common import make_matrix, timeit

N = 64
EP = Epilogue(bias=True, activation="gelu")


def _smoke() -> bool:
    return os.environ.get("REPRO_BENCH_EPILOGUE", "") == "smoke"


def _cases():
    if _smoke():
        return [("tiny", lambda: make_matrix(0, 64, 64, nnz_per_row=(0, 8)))]
    return [(spec.name, spec) for spec in get_suite("mini")]


def run(csv=print):
    smoke = _smoke()
    kw = dict(impl="pallas", interpret=True, tk=64) if smoke \
        else dict(impl="xla")
    warmup, repeat = (1, 2) if smoke else (2, 9)
    dtypes = ("f32",) if smoke else ("f32", "bf16")
    csv("name,us_per_call,derived")
    for mat_name, build in _cases():
        a = build()
        plan = build_plan(a, method="merge", with_transpose=False)
        nnz = int(a.col_ind.shape[0])
        for dt in dtypes:
            in_dtype = jnp.bfloat16 if dt == "bf16" else jnp.float32
            nb = 2 if dt == "bf16" else 4
            vals = a.vals.astype(in_dtype)
            b = jax.random.normal(jax.random.PRNGKey(1),
                                  (a.k, N)).astype(in_dtype)
            bias = jax.random.normal(jax.random.PRNGKey(2), (a.m,),
                                     jnp.float32).astype(in_dtype)
            base = ExecutionConfig(acc_dtype="float32", **kw)
            fused_ex = ExecutionConfig(acc_dtype="float32", epilogue=EP,
                                       **kw)

            # Pre-epilogue serving regime: execute_plan's program, then
            # the tail dispatched eagerly (NOT jitted here on purpose).
            def unfused(v, b2, bb):
                return jax.nn.gelu(
                    execute_plan(plan, v, b2, base) + bb[:, None])

            def fused(v, b2, bb):
                return execute_plan(plan, v, b2, fused_ex, bias=bb)

            block = jax.jit(lambda v, b2, bb: jax.nn.gelu(
                execute_plan(plan, v, b2, base) + bb[:, None]))

            t0 = time.perf_counter()
            jax.block_until_ready(fused(vals, b, bias))
            cold = (time.perf_counter() - t0) * 1e6
            t_un = timeit(unfused, vals, b, bias, warmup=warmup,
                          repeat=repeat)
            t_f = timeit(fused, vals, b, bias, warmup=warmup,
                         repeat=repeat)
            t_blk = timeit(block, vals, b, bias, warmup=warmup,
                           repeat=repeat)
            ceil = fused_epilogue_ceiling(a.m, a.k, N, nnz, val_bytes=nb,
                                          out_bytes=nb)
            name = f"epilogue_{mat_name}_{dt}"
            # tcv: per-timing noise band (std/mean over repeats) — a
            # speedup inside the combined noise is not a speedup.
            csv(f"{name}_unfused,{t_un:.1f},"
                f"1_program+eager_tail;tcv={t_un.cv:.3f}")
            csv(f"{name}_fused,{t_f:.1f},"
                f"{t_un / t_f:.2f}x_vs_unfused_ceiling_{ceil:.2f}x;"
                f"tcv={t_f.cv:.3f}")
            csv(f"{name}_block,{t_blk:.1f},"
                f"whole_block_jit_{t_blk / t_f:.2f}x_of_fused;"
                f"tcv={t_blk.cv:.3f}")
            csv(f"{name}_fused_cold,{cold:.1f},compile+run")


if __name__ == "__main__":
    run()
