"""Fig. 4 analogue: row-split SpMM vs. the vendor baseline as a function of
aspect ratio (fixed nnz budget, row length grows left→right).

The paper: row-split loses on short rows (L ≪ 32 wastes lanes — here: ELL
padding to the TL tile) and wins on long rows (ILP amortizes).  The
derived column is speedup-vs-vendor; > 1 on the right, < 1 on the far
left reproduces the paper's crossover shape.
"""
from __future__ import annotations

import functools

import jax

from repro.core import ExecutionConfig, PlanPolicy, spmm
from repro.kernels import ref
from .common import make_b, make_matrix, timeit

TOTAL_NNZ = 1 << 18
N = 64


def run(csv=print):
    csv("name,us_per_call,derived")
    for log_m in range(6, 15, 2):
        m = 1 << log_m
        npr = max(1, TOTAL_NNZ // m)
        k = max(m, 2 * npr)
        a = make_matrix(0, m, k, nnz_per_row=npr)
        b = make_b(1, k, N)
        t_vendor = timeit(jax.jit(ref.spmm_gather_ref), a, b)
        t_rs = timeit(functools.partial(
            spmm, policy=PlanPolicy(method="rowsplit", l_pad=npr),
            exec=ExecutionConfig(impl="xla"), plan="inline"), a, b)
        csv(f"fig4_rowsplit_len{npr},{t_rs:.1f},{t_vendor / t_rs:.2f}x")


if __name__ == "__main__":
    run()
