"""Observed corpus: engine execution with the live roofline accountant on.

The obs-enabled twin of the corpus sweep: every matrix in the suite
(``REPRO_CORPUS_SUITE``; smoke via ``REPRO_BENCH_OBS=smoke`` uses one tiny
synthetic) is planned through the engine and executed warm per registered
method, with

* tracing enabled (``obs.tracing()``) so plan/cache/dispatch spans land in
  the ring buffer,
* each warm timing fed to the global :data:`repro.obs.accountant` with the
  plan's modeled minimum bytes,
* the streaming roof measured once (cached in ``artifacts/``),

and the run ends by printing ``obs.report()`` — achieved bandwidth as a
fraction of the roof per (method, impl), ladder-rung hit rates, and the
cache counters — the "kernel X ran at Y% of roof" verdict the GPU/TPU
port will be judged with.  CSV rows carry the roof fraction per matrix ×
method so CI archives the numbers.
"""
from __future__ import annotations

import functools
import os

from repro import obs
from repro.core import ExecutionConfig, PlanPolicy, execute_plan
from repro.engine import PlanCache
from repro.kernels import registry
from repro.matrices import get_suite

from .common import make_b, make_matrix, timeit

N = 64
_XLA = ExecutionConfig(impl="xla")


def _smoke() -> bool:
    return os.environ.get("REPRO_BENCH_OBS", "") == "smoke"


def _cases():
    if _smoke():
        return [("tiny", lambda: make_matrix(0, 256, 256,
                                             nnz_per_row=(0, 8)))]
    suite = os.environ.get("REPRO_CORPUS_SUITE", "mini")
    return [(spec.name, spec) for spec in get_suite(suite)]


def run(csv=print):
    warmup, repeat = (1, 2) if _smoke() else (2, 7)
    roof = obs.measure_roof(elements=1 << 20 if _smoke() else 1 << 24,
                            repeat=3 if _smoke() else 5)
    cache = PlanCache(name="bench_obs")
    csv("name,us_per_call,derived")
    with obs.tracing() as tracer:
        for mat_name, build in _cases():
            a = build()
            for mname in registry.method_names():
                plan = cache.get(a, PlanPolicy(method=mname))
                fn = functools.partial(execute_plan, plan, exec=_XLA)
                b = make_b(7, a.k, N)
                t = timeit(fn, a.vals, b, warmup=warmup, repeat=repeat)
                obs.accountant.account_plan(
                    plan.meta, N, wall_us=t.mean * len(t.samples),
                    impl=_XLA.impl, val_dtype=str(a.vals.dtype),
                    calls=len(t.samples))
                frac = (obs.plan_min_bytes(plan.meta, N) / (t * 1e-6)
                        / roof.bytes_per_s)
                csv(f"obs_{mat_name}_{mname},{t:.1f},"
                    f"roof_frac={frac:.3f};tcv={t.cv:.3f}")
        spans = {c: len(tracer.events(cat=c))
                 for c in ("plan", "cache", "dispatch")}
        csv(f"obs_trace_events,0,"
            + ";".join(f"{c}={n}" for c, n in spans.items()))
    print(obs.report(roof=roof))


if __name__ == "__main__":
    run()
