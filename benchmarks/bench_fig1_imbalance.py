"""Fig. 1 analogue: baseline SpMM throughput vs. matrix aspect ratio.

The paper's microbenchmark: fixed ~16.7M nnz, matrices from (2 rows ×
8.3M nnz/row) to (8.3M rows × 2 nnz/row), multiplied by a 64-column dense
B with the *vendor* SpMM.  Our vendor stand-in is the unblocked XLA
gather/segment-sum SpMM (``ref.spmm_gather_ref``), and we scale nnz to CPU
budgets.  Type 1 imbalance appears on the right (few long rows), Type 2 on
the left (many short rows) — for the vendor baseline; the merge kernel's
flat profile across the sweep is the paper's headline effect.
"""
from __future__ import annotations

import functools

import jax

from repro.core import ExecutionConfig, PlanPolicy, spmm
from repro.kernels import ref
from .common import geomean, make_b, make_matrix, timeit

TOTAL_NNZ = 1 << 18
N = 64


def run(csv=print):
    csv("name,us_per_call,derived")
    rows = []
    for log_m in range(4, 15, 2):
        m = 1 << log_m
        npr = max(1, TOTAL_NNZ // m)
        k = max(m, 2 * npr)
        a = make_matrix(0, m, k, nnz_per_row=npr)
        b = make_b(1, k, N)
        t_vendor = timeit(jax.jit(ref.spmm_gather_ref), a, b)
        t_merge = timeit(functools.partial(
            spmm, policy=PlanPolicy(method="merge"),
            exec=ExecutionConfig(impl="xla"), plan="inline"), a, b)
        gflops = 2 * TOTAL_NNZ * N / t_vendor / 1e3
        csv(f"fig1_vendor_m{m},{t_vendor:.1f},{gflops:.2f}GF")
        gflops_m = 2 * TOTAL_NNZ * N / t_merge / 1e3
        csv(f"fig1_merge_m{m},{t_merge:.1f},{gflops_m:.2f}GF")
        rows.append(t_vendor / t_merge)
    csv(f"fig1_merge_vs_vendor_geomean,0,{geomean(rows):.2f}x")


if __name__ == "__main__":
    run()
