"""Benchmark utilities: timing + synthetic matrix builders.

Timing methodology: everything timed is jit-compiled XLA (``impl="xla"`` —
the same dataflow the Pallas kernels implement, emulated on this CPU-only
container; the Pallas bodies themselves are validated in interpret mode in
tests/).  Relative behaviour — ELL padding waste for row-split, equal-work
chunks + fix-up overhead for merge — is preserved, so crossovers and the
heuristic calibration are meaningful on this backend.  Absolute numbers are
CPU numbers; see EXPERIMENTS.md for the TPU roofline story.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CSR, random_csr
# Single timing implementation, shared with the empirical autotuner
# (repro.tune) so bench rows and TuneDB records are directly comparable.
from repro.tune.timing import TimingResult, timeit

__all__ = ["TimingResult", "timeit", "make_matrix", "make_b", "geomean",
           "CSR", "random_csr"]


def make_matrix(seed: int, m: int, k: int, *, nnz_per_row=None,
                density=None, irregular=False):
    key = jax.random.PRNGKey(seed)
    if irregular and nnz_per_row is not None and not isinstance(
            nnz_per_row, tuple):
        nnz_per_row = (0, 2 * nnz_per_row)
    return random_csr(key, m, k, nnz_per_row=nnz_per_row, density=density)


def make_b(seed: int, k: int, n: int, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(seed), (k, n), dtype)


def geomean(x) -> float:
    x = np.asarray(x, dtype=np.float64)
    return float(np.exp(np.mean(np.log(x))))
