"""Table 1 analogue: static work/traffic analysis per kernel.

The paper's Table 1 counts independent instructions, register usage, and
memory-access overhead per thread for row-split vs. merge-based.  The TPU
analogue: per-grid-step work items, VMEM working set (the register-file
analogue), and HBM traffic overhead vs. the nnz lower bound — derived from
the kernels' BlockSpecs, not timed.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import random_csr
from repro.kernels import merge_spmm as MS
from repro.kernels import rowsplit_spmm as RS


def analyze(m=4096, k=4096, mean_len=16, irregular=True, n=128, dtype_b=4):
    npr = (0, 2 * mean_len) if irregular else mean_len
    a = random_csr(jax.random.PRNGKey(0), m, k, nnz_per_row=npr)
    nnz = int(a.nnz())
    lengths = np.diff(np.asarray(a.row_ptr))
    rows = []

    # row-split: ELL pad to max row length rounded to TL
    tl = RS.DEFAULT_TL
    l_pad = int(tl * (-(-max(int(lengths.max()), 1) // tl)))
    work_rs = m * l_pad                      # padded work items
    vmem_rs = (k * RS.TN + RS.TM * RS.TN) * dtype_b  # B panel + C tile
    a_traffic_rs = work_rs * 8 * (n // RS.TN)  # (col,val) per n-tile
    rows.append(("rowsplit", RS.TM * tl, vmem_rs / 2**20,
                 work_rs / nnz, a_traffic_rs / (nnz * 8)))

    # merge: chunks of T nonzeroes, broken at TM-row tiles
    t = MS.DEFAULT_T
    plan = MS.plan_merge(a, t=t)
    n_chunks = int(plan["cols"].shape[0])
    work_mg = n_chunks * t
    vmem_mg = (k * MS.TN + MS.TM * MS.TN) * dtype_b
    a_traffic_mg = work_mg * 12 * (n // MS.TN)  # (col,val,lrow)
    rows.append(("merge", t, vmem_mg / 2**20,
                 work_mg / nnz, a_traffic_mg / (nnz * 8)))
    return rows, nnz


def run(csv=print):
    csv("name,us_per_call,derived")
    for irregular in (False, True):
        rows, nnz = analyze(irregular=irregular)
        tag = "irregular" if irregular else "regular"
        for name, items, vmem_mb, work_ratio, traffic_ratio in rows:
            csv(f"table1_{tag}_{name}_items_per_step,0,{items}")
            csv(f"table1_{tag}_{name}_vmem_mb,0,{vmem_mb:.2f}")
            csv(f"table1_{tag}_{name}_padded_work_ratio,0,{work_ratio:.2f}")
            csv(f"table1_{tag}_{name}_A_traffic_ratio,0,{traffic_ratio:.2f}")


if __name__ == "__main__":
    run()
