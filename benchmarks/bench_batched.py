"""Batched plan execution: one plan, many dense operands (batch × k sweep).

The serving regime behind the engine: a frozen pruned pattern is planned
once and then multiplies a *stream* of dense right-hand sides.  Three
timings per (k, batch):

* ``loop``    — the pre-batch regime: one ``execute_plan`` dispatch per
  matrix (a Python loop over the stack), paying per-call dispatch +
  framework overhead ``batch`` times,
* ``batched`` — ``execute_plan(plan, vals, B)`` with ``B (batch, k, n)``:
  the batch folds into the kernel grid, one dispatch for the whole stack;
  ``derived`` reports loop/batched, the amortization factor,
* ``cold``    — the batched path's first call (trace + compile + run),
  to show what one-time cost the warm numbers amortize.

The k sweep exercises the K-tiled B stream: panels of at most
``DEFAULT_TK_MAX`` rows hold VMEM bounded as ``d_in`` grows (the
whole-``k`` panel this replaced scaled linearly with ``d_in`` and could
not run configs like Qwen2-72B's d_in=29568 at all).

Smoke mode (``REPRO_BENCH_BATCHED=smoke``, used by ``make
bench-batched-smoke``): a tiny sweep through the *Pallas kernels in
interpret mode* — exercising the real batched/K-tiled grid dataflow, not
the XLA twin — with the CSV landing in artifacts/ from CI.
"""
from __future__ import annotations

import functools
import os
import time

import jax
import jax.numpy as jnp

from repro.core import ExecutionConfig, build_plan, execute_plan
from .common import make_matrix, timeit


def _config():
    if os.environ.get("REPRO_BENCH_BATCHED", "") == "smoke":
        return dict(m=32, n=32, ks=(32, 128), batches=(1, 4), npr=(0, 8),
                    impl="pallas", interpret=True, tk=64,
                    warmup=1, repeat=2)
    return dict(m=1024, n=64, ks=(256, 1024, 4096), batches=(1, 4, 16),
                npr=(0, 16), impl="xla", interpret=None, tk=None,
                warmup=2, repeat=5)


def run(csv=print):
    cfg = _config()
    csv("name,us_per_call,derived")
    for k in cfg["ks"]:
        a = make_matrix(0, cfg["m"], k, nnz_per_row=cfg["npr"])
        plan = build_plan(a, method="merge", with_transpose=False)
        ex = functools.partial(execute_plan, exec=ExecutionConfig(
            impl=cfg["impl"], interpret=cfg["interpret"], tk=cfg["tk"]))
        for batch in cfg["batches"]:
            bs = jax.random.normal(jax.random.PRNGKey(1),
                                   (batch, k, cfg["n"]), jnp.float32)
            # Fresh closures per point so "cold" really compiles.
            one = jax.jit(lambda v, b2: ex(plan, v, b2))
            many = jax.jit(lambda v, b3: ex(plan, v, b3))

            t0 = time.perf_counter()
            jax.block_until_ready(many(a.vals, bs))
            cold = (time.perf_counter() - t0) * 1e6
            warm = timeit(many, a.vals, bs, warmup=cfg["warmup"],
                          repeat=cfg["repeat"])

            def loop(v, b3):
                return [one(v, b3[i]) for i in range(b3.shape[0])]

            t_loop = timeit(loop, a.vals, bs, warmup=cfg["warmup"],
                            repeat=cfg["repeat"])
            name = f"batched_k{k}_b{batch}"
            csv(f"{name}_cold,{cold:.1f},compile+run")
            csv(f"{name}_batched,{warm:.1f},"
                f"{t_loop / warm:.2f}x_vs_loop")
            csv(f"{name}_loop,{t_loop:.1f},{batch}_dispatches")


if __name__ == "__main__":
    run()
