"""Fig. 6 analogue + §5.4: the multi-algorithm heuristic.

Sweep ~40 synthetic matrices across the irregularity spectrum (the paper
uses 157/195 SuiteSparse datasets), time row-split and merge-based,
calibrate the ``d = nnz/m`` threshold for THIS backend, and report:

* per-algorithm geomean speedup vs. the vendor stand-in (paper: +13.2% and
  −21.5% individually),
* combined-with-heuristic geomean + peak speedup (paper: +31.7%, 4.1×),
* heuristic accuracy vs. the oracle (paper: 99.3%).
"""
from __future__ import annotations

import functools

import jax
import numpy as np

from repro.core import (ExecutionConfig, Heuristic, PlanPolicy,
                        calibrate, spmm)
from repro.kernels import ref
from .common import geomean, make_b, make_matrix, timeit

N = 64


def dataset_sweep():
    cases = []
    seeds = iter(range(1000))
    for m, k in [(2048, 4096), (4096, 4096), (8192, 2048)]:
        for mean_len in (2, 4, 8, 12, 16, 24, 32, 48, 64):
            for irregular in (False, True):
                npr = ((0, 2 * mean_len) if irregular else mean_len)
                cases.append(make_matrix(next(seeds), m, k, nnz_per_row=npr))
    return cases


def run(csv=print):
    csv("name,us_per_call,derived")
    ds, t_rs, t_mg, t_vendor = [], [], [], []
    for a in dataset_sweep():
        b = make_b(7, a.k, N)
        l_pad = int(np.max(np.diff(np.asarray(a.row_ptr))))
        t_vendor.append(timeit(jax.jit(ref.spmm_gather_ref), a, b))
        t_rs.append(timeit(functools.partial(
            spmm,
            policy=PlanPolicy(method="rowsplit", l_pad=max(l_pad, 1)),
            exec=ExecutionConfig(impl="xla"), plan="inline"), a, b))
        t_mg.append(timeit(functools.partial(
            spmm, policy=PlanPolicy(method="merge"),
            exec=ExecutionConfig(impl="xla"), plan="inline"), a, b))
        ds.append(float(a.mean_row_length()))
    ds, t_rs, t_mg, t_vendor = map(np.asarray, (ds, t_rs, t_mg, t_vendor))

    csv(f"fig6_rowsplit_geomean,0,{geomean(t_vendor / t_rs):.3f}x")
    csv(f"fig6_merge_geomean,0,{geomean(t_vendor / t_mg):.3f}x")

    thr, acc = calibrate(ds, t_rs, t_mg)
    csv(f"fig6_calibrated_threshold,0,{thr:.2f}")
    csv(f"fig6_heuristic_accuracy,0,{acc * 100:.1f}%")

    t_heur = np.where(ds < thr, t_mg, t_rs)
    combined = t_vendor / t_heur
    csv(f"fig6_combined_geomean,0,{geomean(combined):.3f}x")
    csv(f"fig6_combined_peak,0,{combined.max():.2f}x")

    # the paper's fixed threshold (9.35, K40c) scored on this backend:
    paper = Heuristic()
    pred = ds < paper.threshold
    oracle = t_mg < t_rs
    csv(f"fig6_paper_threshold_accuracy,0,"
        f"{float(np.mean(pred == oracle)) * 100:.1f}%")


if __name__ == "__main__":
    run()
