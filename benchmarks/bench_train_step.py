"""Train step through the differentiable SpMM engine.

Times a jitted sparse fine-tuning step over a pruned two-layer MLP
(forward SpMM → loss → backward), exercising the new backward kernels:
``dB = Aᵀ @ dC`` through the cached transpose merge plan and ``dvals``
through the SDDMM gather-dot — against the forward-only cost, for both
kernel methods.  Plans are prebuilt by the engine; the timed region never
replans (the cache-miss counter is asserted flat).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine
from repro.core import ExecutionConfig
from repro.models import sparse as S
from repro.runtime import steps as R
from .common import timeit

BATCH = 64
D = 512
FF = 1024
_XLA = ExecutionConfig(impl="xla")


def _sparse_mlp(seed: int, keep: float):
    rng = np.random.default_rng(seed)
    p = {"w1": jnp.asarray(rng.standard_normal((D, FF)), jnp.float32),
         "w2": jnp.asarray(rng.standard_normal((FF, D)), jnp.float32)}
    return S.prune_mlp(p, keep)


def run(csv=print):
    csv("name,us_per_call,derived")
    rng = np.random.default_rng(42)
    x = jnp.asarray(rng.standard_normal((BATCH, D)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((BATCH, D)), jnp.float32)

    # keep=1% → ~5 nnz/row < 9.35 → merge; keep=25% → long rows → rowsplit
    for name, keep in [("merge_keep1%", 0.01), ("rowsplit_keep25%", 0.25)]:
        sp = _sparse_mlp(0, keep)
        method = sp["w1"].method
        step, vals0 = R.make_sparse_train_step(sp, impl="xla")
        jstep = jax.jit(step)

        def fwd_only(vals, xx):
            layers = S.mlp_with_vals(sp, vals)
            return S.sparse_mlp_apply(
                {k: functools.partial(sl, exec=_XLA)
                 for k, sl in layers.items()}, xx, None)

        jfwd = jax.jit(fwd_only)
        misses0 = engine.cache_stats().misses
        t_fwd = timeit(jfwd, vals0, x)
        t_step = timeit(jstep, vals0, x, y)
        assert engine.cache_stats().misses == misses0, \
            "timed region replanned!"
        csv(f"train_{name}_fwd,{t_fwd:.1f},method={method}")
        csv(f"train_{name}_step,{t_step:.1f},"
            f"{t_step / t_fwd:.2f}x_fwd_bwd_update")


if __name__ == "__main__":
    run()
