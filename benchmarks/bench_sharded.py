"""Device-sharded SpMM: shard-count sweep + nnz- vs row-balanced cuts.

Two questions about ``repro.distributed.spmm``:

* **Scaling**: warm sharded execution across shard counts vs. the
  single-device engine baseline.  With one real device (the default CPU
  container) shards execute as the per-shard loop — the row reports the
  sharding *overhead* floor; with forced devices (run this module
  directly: it forces 8 CPU devices before importing jax, like ``make
  test-sharded``) the uniform path is one ``shard_map`` program and the
  row reports actual multi-device scaling.  ``derived`` is
  speedup-vs-baseline.
* **Balance**: the paper's §4 argument at device granularity — cutting an
  imbalanced matrix into equal-*row* shards leaves one device holding a
  multiple of the ideal nonzero load, while the equal-*nnz* cuts of
  ``shard_csr_by_nnz`` stay within one max-row-length of ideal.
  ``derived`` is the max-shard-nnz / ideal imbalance factor (1.0 =
  perfect).
"""
from __future__ import annotations

import os
import sys

if __name__ == "__main__":          # standalone: force a multi-device CPU
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=8").strip()

import jax
import numpy as np

from repro.core import ExecutionConfig, PlanPolicy, ShardSpec
from repro.engine import PlanCache

from .common import make_b, make_matrix, timeit

N = 64
M = 2048
SHARD_COUNTS = (1, 2, 4, 8)


def _cases():
    yield "uniform_d32", make_matrix(0, M, M, nnz_per_row=32)
    yield "irregular_d16", make_matrix(1, M, M, nnz_per_row=(0, 32))
    yield "skewed_head", _skewed(2)


def _skewed(seed):
    """A few dense head rows over a sparse tail — the row-balance killer."""

    from repro.core.csr import from_dense
    rng = np.random.default_rng(seed)
    dense = np.zeros((M, M), np.float32)
    for r in range(8):                        # 8 rows with ~M/4 nnz each
        cols = rng.choice(M, M // 4, replace=False)
        dense[r, cols] = rng.standard_normal(M // 4)
    tail = rng.random((M, M)) < (4.0 / M)     # d≈4 tail
    dense[8:][tail[8:]] = 1.0
    return from_dense(dense)


def _mesh(n):
    if n > jax.device_count():
        return None
    return jax.sharding.Mesh(np.array(jax.devices()[:n]), ("data",))


def _row_balanced_max_nnz(a, n):
    """max shard nnz when cutting into equal-row shards (the strawman)."""
    rp = np.asarray(a.row_ptr)
    cuts = np.linspace(0, a.m, n + 1).astype(np.int64)
    return max(int(rp[cuts[i + 1]] - rp[cuts[i]]) for i in range(n))


def run(csv=print):
    from repro.core import execute_plan
    from repro.distributed.spmm import shard_csr_by_nnz

    csv("name,us_per_call,derived")
    exec_cfg = ExecutionConfig(impl="xla")
    for name, a in _cases():
        b = make_b(7, a.k, N)
        cache = PlanCache()
        base_plan = cache.get(a, PlanPolicy())
        t_base = timeit(jax.jit(lambda v, bb: execute_plan(
            base_plan, v, bb, exec_cfg)), a.vals, b)
        csv(f"{name}_base,{t_base:.1f},1.00")
        for n in SHARD_COUNTS:
            mesh = _mesh(n)
            spec = (ShardSpec(mesh=mesh) if mesh is not None
                    else ShardSpec(n=n))
            plan = cache.get(a, PlanPolicy(shards=spec))
            mode = ("spmd" if plan.meta.spmd_mesh() is not None else "loop")
            t = timeit(jax.jit(lambda v, bb, p=plan: p.execute(v, bb,
                                                               exec_cfg)),
                       a.vals, b)
            csv(f"{name}_shards{n}_{mode},{t:.1f},{t_base / t:.2f}")
        # balance: equal-nnz cuts vs equal-row cuts, as max/ideal factors
        nnz = int(np.asarray(a.row_ptr)[-1])
        for n in SHARD_COUNTS[1:]:
            ideal = nnz / n
            nnz_bal = max(shard_csr_by_nnz(a, n).nnz_per_shard()) / ideal
            row_bal = _row_balanced_max_nnz(a, n) / ideal
            csv(f"{name}_balance{n}_nnz,0.0,{nnz_bal:.2f}")
            csv(f"{name}_balance{n}_rows,0.0,{row_bal:.2f}")


if __name__ == "__main__":
    run()
    sys.exit(0)
