"""Roofline analysis (deliverable g): three terms per (arch × shape × mesh)
from the dry-run's compiled artifacts.

    compute    = HLO_FLOPs_per_device            / peak_FLOPs      [197e12]
    memory     = HLO_HBM_bytes_per_device        / HBM_bw          [819e9]
    collective = collective_wire_bytes_per_device / link_bw        [50e9]

FLOPs/bytes come from the trip-count-scaled HLO parse
(``repro.analysis.hlo`` — ``cost_analysis`` counts while bodies once and
is useless for scanned graphs; the parse is validated against unrolled
modules in tests/test_hlo_stats.py).  The dominant term is the bottleneck; the
"useful" ratio MODEL_FLOPS / (HLO_FLOPs × chips) catches remat/padding/
overcompute waste.

    python -m benchmarks.roofline [--dir results/dryrun] [--md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12      # bf16 / chip (TPU v5e)
HBM_BW = 819e9           # B/s / chip
LINK_BW = 50e9           # B/s / link (ICI)

TOKENS = {"train_4k": 4096 * 256, "prefill_32k": 32768 * 32,
          "decode_32k": 128, "long_500k": 1}


def model_flops(rec) -> float:
    """MODEL_FLOPS = 6·N·D (training) / 2·N·D (inference), N_active for
    MoE — *global*, all chips."""
    n = rec["model_params_active"]
    d = TOKENS[rec["shape"]]
    mult = 6 if rec["shape"].startswith("train") else 2
    return mult * n * d


def analyze(rec) -> dict:
    chips = 512 if rec["mesh"] == "2x16x16" else 256
    p = rec["hlo_parsed"]
    terms = {
        "compute_s": p["flops"] / PEAK_FLOPS,
        "memory_s": p["hbm_bytes"] / HBM_BW,
        "collective_s": p["collective_wire_bytes"] / LINK_BW,
    }
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    mf = model_flops(rec)
    useful = mf / (p["flops"] * chips) if p["flops"] else 0.0
    # roofline fraction: useful model compute per step over what the
    # bottleneck term allows at peak
    frac = (mf / chips / PEAK_FLOPS) / bound if bound else 0.0
    return dict(rec=rec, terms=terms, dominant=dom.replace("_s", ""),
                useful=useful, roofline_fraction=frac, chips=chips,
                model_flops=mf)


def suggestion(a) -> str:
    dom = a["dominant"]
    rec = a["rec"]
    if dom == "collective":
        if rec["shape"].startswith("train"):
            return ("cut FSDP re-gathers: ZeRO-1 (replicate bf16 params "
                    "over data, shard master/optimizer) or fewer "
                    "microbatches")
        return "shard params over fewer axes; batch decode requests"
    if dom == "memory":
        return ("fuse/remat less, larger microbatch, chunked-scan "
                "recurrences to cut log-depth traffic")
    return "already compute-bound: raise useful ratio (less remat/padding)"


def load(dirname):
    recs = []
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        r = json.load(open(f))
        if r.get("ok") and "hlo_parsed" in r:
            recs.append(r)
    return recs


def table(recs, md=False):
    rows = []
    for rec in recs:
        a = analyze(rec)
        rows.append((rec["arch"], rec["shape"], rec["mesh"],
                     a["terms"]["compute_s"], a["terms"]["memory_s"],
                     a["terms"]["collective_s"], a["dominant"],
                     a["useful"], a["roofline_fraction"], suggestion(a)))
    hdr = ("arch", "shape", "mesh", "compute_s", "memory_s", "collective_s",
           "bound", "useful", "roofline", "next-move")
    if md:
        print("| " + " | ".join(hdr) + " |")
        print("|" + "---|" * len(hdr))
        for r in rows:
            print(f"| {r[0]} | {r[1]} | {r[2]} | {r[3]:.3g} | {r[4]:.3g} "
                  f"| {r[5]:.3g} | {r[6]} | {r[7]:.2f} | {r[8]:.3f} "
                  f"| {r[9]} |")
    else:
        print(f"{'arch':18s} {'shape':12s} {'mesh':8s} {'comp_s':>8s} "
              f"{'mem_s':>8s} {'coll_s':>8s} {'bound':>10s} {'useful':>7s} "
              f"{'roofline':>8s}")
        for r in rows:
            print(f"{r[0]:18s} {r[1]:12s} {r[2]:8s} {r[3]:8.3g} {r[4]:8.3g} "
                  f"{r[5]:8.3g} {r[6]:>10s} {r[7]:7.2f} {r[8]:8.3f}")
    return rows


# --- SpMM traffic model (moved to repro.obs.roofline in the obs PR) -------
#
# DEPRECATED re-export, kept only so third-party scripts keep running:
# the compulsory-bytes model lives in ``repro.obs.roofline`` (with the
# live roofline accountant).  First-party code must import from there —
# repo lint rule RL005 rejects new imports of this shim, and the
# re-export will be dropped once external callers have migrated.

from repro.obs.roofline import (epilogue_tail_bytes, fused_epilogue_ceiling,
                                spmm_min_bytes)  # noqa: F401,E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    recs = load(args.dir)
    if not recs:
        print("no dry-run records found; run python -m repro.launch.dryrun")
        return 1
    table(recs, md=args.md)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
