"""Fig. 5 analogue: row-split and merge-based on *long-row* (62.5 nnz/row
average — paper Fig. 5a) and *short-row* (7.92 nnz/row — Fig. 5b)
dataset suites, vs. the vendor stand-in.

The paper's datasets are 10 SuiteSparse graphs per suite; we synthesize 10
matrices per suite with matching mean row lengths and varying irregularity
(regular → uniform-irregular → heavy-tail), which spans the same Type 1/2
spectrum.
"""
from __future__ import annotations

import functools

import jax
import numpy as np

from repro.core import ExecutionConfig, PlanPolicy, spmm
from repro.kernels import ref
from .common import geomean, make_b, make_matrix, timeit

N = 64
M = 4096


def _suite(mean_len: int):
    suites = []
    for i in range(10):
        if i < 3:
            npr = mean_len                       # regular
        elif i < 7:
            npr = (max(0, mean_len // 4), 2 * mean_len - mean_len // 4)
        else:
            npr = (0, 2 * mean_len)              # maximally irregular
        suites.append(make_matrix(i, M, 2 * M, nnz_per_row=npr))
    return suites


def _bench_suite(name, mean_len, csv):
    rs_speed, mg_speed = [], []
    b = make_b(99, 2 * M, N)
    for i, a in enumerate(_suite(mean_len)):
        t_vendor = timeit(jax.jit(ref.spmm_gather_ref), a, b)
        l_pad = int(np.max(np.diff(np.asarray(a.row_ptr))))
        t_rs = timeit(functools.partial(
            spmm,
            policy=PlanPolicy(method="rowsplit", l_pad=max(l_pad, 1)),
            exec=ExecutionConfig(impl="xla"), plan="inline"), a, b)
        t_mg = timeit(functools.partial(
            spmm, policy=PlanPolicy(method="merge"),
            exec=ExecutionConfig(impl="xla"), plan="inline"), a, b)
        rs_speed.append(t_vendor / t_rs)
        mg_speed.append(t_vendor / t_mg)
        csv(f"{name}_ds{i}_rowsplit,{t_rs:.1f},{t_vendor / t_rs:.2f}x")
        csv(f"{name}_ds{i}_merge,{t_mg:.1f},{t_vendor / t_mg:.2f}x")
    csv(f"{name}_rowsplit_geomean,0,{geomean(rs_speed):.2f}x")
    csv(f"{name}_merge_geomean,0,{geomean(mg_speed):.2f}x")


def run(csv=print):
    csv("name,us_per_call,derived")
    _bench_suite("fig5a_long62.5", 62, csv)   # paper: 62.5 nnz/row
    _bench_suite("fig5b_short7.9", 8, csv)    # paper: 7.92 nnz/row


if __name__ == "__main__":
    run()
