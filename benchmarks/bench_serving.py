"""Online serving: continuous batching vs one-request-at-a-time.

The serving acceptance gate for ``repro.serving``: a shared Poisson
arrival tape (seeded, replayable) drives two servers over the same
pruned-FFN scorer — ``naive`` with a batch ladder of ``(1,)`` (every
request served solo, the pre-batching regime) and ``batched`` with the
full power-of-two ladder — at an offered load of ~8x the measured solo
call capacity.  Under that saturation, throughput is service capacity
and queue-wait dominates latency, so the batcher must win on *both*
axes: ``serving_speedup`` asserts >= 2x throughput (>= 1.5x in smoke,
where the model is tiny and dispatch overhead compresses the gap) at
p99 no worse than naive.  Both runs also assert zero program-cache
recompiles after warmup — the bucket ladder covered every served shape
— and zero sheds/errors, so the speedup is on identical completed work.

Two more legs exercise paths the timed comparison cannot:

* ``serving_pallas_interpret`` — a few ragged requests through the real
  Pallas kernel bodies (interpret mode; the XLA twin is what the timed
  legs use, per benchmarks/common.py), asserting correctness plumbing,
  not speed: interpret-mode cost scales with padded batch size, which
  would invert the throughput comparison.
* ``serving_shed`` — overload a ``queue_depth=4`` server with 12
  already-expired requests: 8 shed at admission (queue full), 4 at
  dequeue (deadline), 0 served — admission control accounted exactly.

Smoke mode (``REPRO_BENCH_SERVING=smoke``, used by ``make
serve-smoke``): smaller scorer and fewer requests.  When
``REPRO_SERVING_TRACE_OUT`` / ``REPRO_SERVING_METRICS_OUT`` are set the
run enables tracing and exports the artifacts CI validates with
``repro.obs.validate`` (spans ``serve.*``, the serve metric families).
"""
from __future__ import annotations

import os

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import ExecutionConfig
from repro.models import sparse as S
from repro.serving import BucketLadder, RequestShed, Server, loadgen

SEED = 0


def _smoke() -> bool:
    return os.environ.get("REPRO_BENCH_SERVING", "") == "smoke"


def make_scorer(*, vocab: int, d_model: int, d_ff: int, n_layers: int,
                keep: float, exec_cfg: ExecutionConfig, seed: int = SEED):
    """SpMM-heavy request scorer: embed -> residual pruned-MLP blocks ->
    tied-embedding logits.  Rows (requests) are independent, so a packed
    forward is bit-identical to a solo forward at the same bucket shape.
    Returns ``(forward, state)`` for :class:`repro.serving.Server`.
    """
    rng = np.random.default_rng(seed)

    def w(*shape):
        return jnp.asarray(
            rng.normal(0, 0.02, size=shape).astype(np.float32))

    blocks = [S.prune_mlp({"w1": w(d_model, d_ff),
                           "w2": w(d_ff, d_model)}, keep)
              for _ in range(n_layers)]
    state = {"embed": w(vocab, d_model), "blocks": blocks}

    def forward(state, tokens):
        h = state["embed"][tokens]                    # (b, s, d)
        for blk in state["blocks"]:
            h = h + S.sparse_mlp_apply(blk, h, None, exec=exec_cfg)
        return h @ state["embed"].T                   # (b, s, vocab)

    return forward, state


def _drive(forward, state, ladder, schedule, *, vocab: int,
           window_s: float, name: str):
    """One warmed server through the shared arrival tape; returns the
    LoadReport with zero-recompile/shed/error asserted."""
    server = Server(forward, state, ladder, batch_window_s=window_s,
                    name=name).start()
    report = loadgen.run_load(server, schedule, vocab=vocab, seed=SEED)
    server.stop()
    if server.recompiles():
        raise RuntimeError(
            f"{name}: {server.recompiles()} recompiles after warmup — "
            "the bucket ladder must cover every served shape")
    if report.shed or report.error:
        raise RuntimeError(
            f"{name}: {report.shed} shed / {report.error} errors — the "
            "throughput comparison needs identical completed work")
    return report


def _interpret_leg(csv, *, vocab: int) -> None:
    fwd, state = make_scorer(
        vocab=vocab, d_model=32, d_ff=128, n_layers=1, keep=0.25,
        exec_cfg=ExecutionConfig(impl="pallas", interpret=True, tk=32))
    srv = Server(fwd, state, BucketLadder(lengths=(8, 16),
                                          batches=(1, 2)),
                 name="bench.serving.interp")
    futs = [srv.submit(loadgen.make_tokens(n, vocab, seed=n))
            for n in (3, 8, 11, 16)]
    srv.start()
    outs = [f.result(timeout=600) for f in futs]
    srv.stop()
    for n, o in zip((3, 8, 11, 16), outs):
        if o.shape != (n, vocab):
            raise RuntimeError(
                f"interpret leg: request of length {n} returned "
                f"{o.shape}, wanted ({n}, {vocab})")
    if srv.recompiles():
        raise RuntimeError("interpret leg recompiled after warmup")
    csv(f"serving_pallas_interpret,0.0,"
        f"{len(outs)}_ragged_ok_recompiles_0")


def _shed_leg(csv, *, vocab: int) -> None:
    fwd, state = make_scorer(
        vocab=vocab, d_model=16, d_ff=32, n_layers=1, keep=0.5,
        exec_cfg=ExecutionConfig(impl="xla"))
    srv = Server(fwd, state, BucketLadder(lengths=(8,), batches=(1, 4)),
                 queue_depth=4, name="bench.serving.shed")
    futs = [srv.submit(loadgen.make_tokens(8, vocab, seed=i),
                       deadline_s=1e-6) for i in range(12)]
    srv.start()
    outcomes = []
    for f in futs:
        try:
            f.result(timeout=60)
            outcomes.append("ok")
        except RequestShed:
            outcomes.append("shed")
    srv.stop()
    shed = outcomes.count("shed")
    if shed != 12 or outcomes.count("ok") != 0:
        raise RuntimeError(
            f"shed leg: wanted all 12 requests shed (8 admission + 4 "
            f"deadline), got {outcomes}")
    csv(f"serving_shed,0.0,12_offered_4_queue_depth_{shed}_shed")


def run(csv=print):
    smoke = _smoke()
    trace_out = os.environ.get("REPRO_SERVING_TRACE_OUT", "")
    metrics_out = os.environ.get("REPRO_SERVING_METRICS_OUT", "")
    if trace_out:
        obs.enable()

    # The scorer stays small enough that per-call fixed cost (dispatch,
    # pytree flatten, host<->device hops) is a real fraction of a solo
    # call — the dispatch-bound regime continuous batching exists for.
    # A CPU-compute-saturating model would hide the effect: unlike a
    # GPU's idle lanes, host matmul time grows with the batch axis.
    vocab = 101
    if smoke:
        scorer_kw = dict(vocab=vocab, d_model=32, d_ff=128, n_layers=2,
                         keep=0.25)
        n_req, max_len, max_batch, need = 24, 16, 8, 1.5
    else:
        scorer_kw = dict(vocab=vocab, d_model=64, d_ff=256, n_layers=2,
                         keep=0.25)
        n_req, max_len, max_batch, need = 64, 32, 8, 2.0

    # Timed legs run the XLA impl (benchmarks/common.py methodology);
    # interpret-mode Pallas cost scales with the padded batch, which
    # would charge the batcher for exactly the padding it amortizes.
    forward, state = make_scorer(exec_cfg=ExecutionConfig(impl="xla"),
                                 **scorer_kw)
    ladder = BucketLadder.from_max(max_len, max_batch)
    naive_ladder = BucketLadder(lengths=ladder.lengths, batches=(1,))

    # Rate calibration: offer ~8x one server's solo-call capacity so
    # both servers saturate — throughput below is service capacity.
    probe = Server(forward, state, naive_ladder,
                   name="bench.serving.probe")
    solo_s = min(probe.probe(1, max_len) for _ in range(3))
    probe.stop()
    rate = 8.0 / solo_s
    sched = loadgen.poisson_schedule(n_req, rate,
                                     (max(1, max_len // 4), max_len),
                                     seed=SEED)
    window = min(0.01, 2 * solo_s)

    csv("name,us_per_call,derived")
    naive = _drive(forward, state, naive_ladder, sched, vocab=vocab,
                   window_s=window, name="bench.serving.naive")
    batched = _drive(forward, state, ladder, sched, vocab=vocab,
                     window_s=window, name="bench.serving.batched")

    csv(f"serving_naive,{naive.p99_us:.0f},"
        f"{naive.throughput_rps:.1f}rps_p50_{naive.p50_us:.0f}us")
    csv(f"serving_batched,{batched.p99_us:.0f},"
        f"{batched.throughput_rps:.1f}rps_p50_{batched.p50_us:.0f}us")
    speedup = batched.throughput_rps / naive.throughput_rps
    csv(f"serving_speedup,0.0,{speedup:.2f}x_throughput_at_"
        f"{rate:.0f}rps_offered_need_{need:.1f}x")
    if speedup < need:
        raise RuntimeError(
            f"continuous batching {speedup:.2f}x naive throughput — "
            f"the serving gate needs >= {need}x under saturation")
    if batched.p99_us > naive.p99_us:
        raise RuntimeError(
            f"batched p99 {batched.p99_us:.0f}us worse than naive "
            f"{naive.p99_us:.0f}us — batching must not buy throughput "
            "with tail latency under overload")

    _interpret_leg(csv, vocab=vocab)
    _shed_leg(csv, vocab=vocab)

    if trace_out:
        tr = obs.get_tracer()
        if tr is not None:
            tr.export(trace_out)
    if metrics_out:
        obs.dump_metrics(metrics_out)


if __name__ == "__main__":
    run()
