"""Fig. 7 analogue: SpMM vs. dense GEMM as a function of density.

The paper: on a 100k×100k random matrix × (100k×64) dense B, merge-based
SpMM beats cuBLAS sgemm below ~9% density.  We sweep density on a
CPU-budget matrix and report the crossover for this backend.
"""
from __future__ import annotations

import functools

import jax

from repro.core import ExecutionConfig, PlanPolicy, spmm
from .common import make_b, make_matrix, timeit

M = K = 2048
N = 64


def run(csv=print):
    csv("name,us_per_call,derived")
    b = make_b(3, K, N)
    dense_a = jax.random.normal(jax.random.PRNGKey(4), (M, K))
    t_gemm = timeit(jax.jit(lambda a, bb: a @ bb), dense_a, b)
    csv(f"fig7_dense_gemm,{t_gemm:.1f},1.00x")
    crossover = None
    for pct in (0.5, 1, 2, 4, 6, 9, 12, 16, 25):
        a = make_matrix(5, M, K, density=pct / 100)
        t_sp = timeit(functools.partial(
            spmm, policy=PlanPolicy(method="merge"),
            exec=ExecutionConfig(impl="xla"), plan="inline"), a, b)
        csv(f"fig7_spmm_d{pct}%,{t_sp:.1f},{t_gemm / t_sp:.2f}x")
        if crossover is None and t_sp > t_gemm:
            crossover = pct
    csv(f"fig7_crossover_density,0,{crossover if crossover else '>25'}%")


if __name__ == "__main__":
    run()
