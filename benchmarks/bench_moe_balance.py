"""MoE load balancing under routing skew — the paper's Type 1/2 imbalance
story applied to experts (DESIGN.md §3.3).

Hot experts are "long rows", cold experts "short rows".  The merge-based
sort dispatch assigns an equal number of tokens per block regardless of
skew; the dense (GShard-einsum) baseline pays for every expert.  We time
both under uniform and pathological (zipf) routing.
"""
from __future__ import annotations

import dataclasses
import functools

import jax

from repro.configs import get_smoke_config
from repro.models import moe as MOE
from .common import timeit


def run(csv=print):
    csv("name,us_per_call,derived")
    cfg = dataclasses.replace(get_smoke_config("olmoe-1b-7b"),
                              d_model=256, d_ff=512, num_experts=16,
                              top_k=2)
    p = MOE.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 128, cfg.d_model))

    # skew the router so most mass lands on a few experts (Type 1)
    router_skew = p["router"] * 1.0
    router_skew = router_skew.at[:, 0].add(4.0).at[:, 1].add(3.0)
    p_skew = dict(p, router=router_skew)

    sort_fn = jax.jit(functools.partial(MOE.moe_apply, cfg=cfg,
                                        use_kernel=False))
    cfg_d = dataclasses.replace(cfg, moe_impl="dense")
    dense_fn = jax.jit(functools.partial(MOE.moe_apply, cfg=cfg_d,
                                         use_kernel=False))

    for tag, params in (("uniform", p), ("skewed", p_skew)):
        t_sort = timeit(lambda xx, pp=params: sort_fn(pp, xx), x)
        t_dense = timeit(lambda xx, pp=params: dense_fn(pp, xx), x)
        csv(f"moe_sort_{tag},{t_sort:.1f},{t_dense / t_sort:.2f}x")
        csv(f"moe_dense_{tag},{t_dense:.1f},1.00x")


if __name__ == "__main__":
    run()
