"""Plan reuse: cold plan-build+execute vs. warm execute-only (the engine).

The amortization claim behind the plan-once/execute-many refactor (and
Shi et al., arXiv:2005.14469): per-pattern preprocessing is paid once,
so steady-state SpMM cost is the execute phase alone.  Three numbers per
(matrix, method):

* ``plan_build``  — host-side ``build_plan`` incl. the transpose plan
  (paid once per sparsity pattern, amortized by the engine cache),
* ``cold``        — build + execute, the first-call cost,
* ``warm``        — execute through the prebuilt plan, the steady state;
  ``derived`` reports cold/warm, the per-pattern amortization factor.

Also timed: ``inline`` — the pre-engine regime with planning traced into
every call (what the figure benchmarks reproduce), as the honest baseline
warm execution must beat.
"""
from __future__ import annotations

import functools
import time

from repro.core import (ExecutionConfig, PlanPolicy, build_plan,
                        execute_plan, spmm)
from .common import make_b, make_matrix, timeit

N = 64
M = 2048


def _cases():
    yield "merge_short4", make_matrix(0, M, M, nnz_per_row=(0, 8)), "merge"
    yield "merge_mid16", make_matrix(1, M, M, nnz_per_row=(0, 32)), "merge"
    yield "rowsplit_long64", make_matrix(2, M, M, nnz_per_row=64), "rowsplit"


def run(csv=print):
    csv("name,us_per_call,derived")
    for name, a, method in _cases():
        b = make_b(7, a.k, N)
        # Warm the planning ops' trace/compile (build_plan itself never
        # caches), so t_plan is the steady per-pattern cost, not XLA setup.
        build_plan(a, method=method)
        t0 = time.perf_counter()
        plan = build_plan(a, method=method)
        t_plan = (time.perf_counter() - t0) * 1e6

        warm_fn = functools.partial(execute_plan,
                                    exec=ExecutionConfig(impl="xla"))
        t_warm = timeit(warm_fn, plan, a.vals, b)
        t_inline = timeit(functools.partial(
            spmm, policy=PlanPolicy(method=method),
            exec=ExecutionConfig(impl="xla"), plan="inline"), a, b)
        t_cold = t_plan + t_warm

        csv(f"plan_{name}_build,{t_plan:.1f},once_per_pattern")
        csv(f"plan_{name}_cold,{t_cold:.1f},build+execute")
        csv(f"plan_{name}_warm,{t_warm:.1f},{t_cold / t_warm:.1f}x_amortized")
        csv(f"plan_{name}_inline,{t_inline:.1f},"
            f"{t_inline / t_warm:.2f}x_vs_warm")


if __name__ == "__main__":
    run()
