"""Quickstart: the paper's SpMM in five minutes, through the v1 API.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

import repro
from repro import ExecutionConfig, PlanPolicy, SparseMatrix
from repro.core import Heuristic, random_csr
from repro.kernels import ref, registry

# 1. Build a sparse matrix (CSR underneath — the paper's input format, no
#    conversion step) with the SparseMatrix frontend.
rng = np.random.default_rng(0)
dense = rng.standard_normal((64, 96)) * (rng.random((64, 96)) < 0.1)
A = SparseMatrix.from_dense(dense.astype(np.float32))
print(f"A: {A.shape}, nnz={int(A.nnz())}, "
      f"mean row length d={float(A.data.mean_row_length()):.2f}")

# 2. A tall-skinny dense B (n ≪ m — the paper's SpMM regime).
b = jax.random.normal(jax.random.PRNGKey(1), (96, 64))

# 3. Multiply. `A @ B` plans once through the engine cache ('auto': the
#    TuneDB ladder, then the §5.4 heuristic d < 9.35 → merge); an explicit
#    PlanPolicy forces any registered method — including the row-grouped
#    variant that registered itself without touching a single dispatch
#    site (repro/kernels/registry.py).
c_auto = A @ b
print("registered methods:", ", ".join(registry.method_names()))
print("heuristic picked:", Heuristic().choose(A.data))
want = np.asarray(ref.spmm_dense_ref(A.data, b))
np.testing.assert_allclose(np.asarray(c_auto), want, rtol=2e-5, atol=2e-5)
print("auto      matches dense oracle ✓")

for method in registry.method_names():
    c = repro.spmm(A.data, b, PlanPolicy(method=method),
                   ExecutionConfig(impl="xla"))
    np.testing.assert_allclose(np.asarray(c), want, rtol=2e-5, atol=2e-5)
    print(f"{method:9s} matches dense oracle ✓")

# 4. Plan once, execute many: attach the plan, jit, swap values freely —
#    the pattern (and therefore the plan) is frozen.
A = A.plan(PlanPolicy(method="merge"))
fast = jax.jit(lambda mtx, bb: mtx @ bb)
np.testing.assert_allclose(np.asarray(fast(A, b)), want,
                           rtol=2e-5, atol=2e-5)
A2 = A.with_vals(2.0 * A.vals)
np.testing.assert_allclose(np.asarray(fast(A2, b)), 2 * want,
                           rtol=2e-5, atol=2e-5)
print(f"plan-once/execute-many under jit ✓ (method={A.method})")

# 5. Irregular matrices are where the merge kernel shines (Type 1/2 load
#    imbalance, Fig. 1): every chunk gets exactly T nonzeroes.
irregular = random_csr(jax.random.PRNGKey(2), 256, 128, nnz_per_row=(0, 24))
b2 = jax.random.normal(jax.random.PRNGKey(3), (128, 32))
c2 = repro.spmm(irregular, b2, PlanPolicy(method="merge"))
np.testing.assert_allclose(np.asarray(c2),
                           np.asarray(ref.spmm_dense_ref(irregular, b2)),
                           rtol=2e-5, atol=2e-5)
print("irregular merge-based SpMM ✓")
