"""Quickstart: the paper's SpMM in five minutes.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CSR, Heuristic, from_dense, random_csr, spmm
from repro.kernels import ref

# 1. Build a sparse matrix in CSR (the paper's input format — no
#    conversion step, Algorithm 1 consumes row_ptr/col_ind/vals directly).
rng = np.random.default_rng(0)
dense = rng.standard_normal((64, 96)) * (rng.random((64, 96)) < 0.1)
a = from_dense(dense.astype(np.float32))
print(f"A: {a.shape}, nnz={int(a.nnz())}, "
      f"mean row length d={float(a.mean_row_length()):.2f}")

# 2. A tall-skinny dense B (n ≪ m — the paper's SpMM regime).
b = jax.random.normal(jax.random.PRNGKey(1), (96, 64))

# 3. Multiply three ways — row-split (§4.1), merge-based (§4.2), and
#    'auto' (the §5.4 heuristic: d < 9.35 → merge).
c_rowsplit = spmm(a, b, method="rowsplit")
c_merge = spmm(a, b, method="merge")
c_auto = spmm(a, b)  # picks merge here (d ≈ 9.6? check below)
print("heuristic picked:", Heuristic().choose(a))

# 4. All agree with the dense oracle.
want = np.asarray(ref.spmm_dense_ref(a, b))
for name, got in [("rowsplit", c_rowsplit), ("merge", c_merge),
                  ("auto", c_auto)]:
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5, atol=2e-5)
    print(f"{name:9s} matches dense oracle ✓")

# 5. Irregular matrices are where the merge kernel shines (Type 1/2 load
#    imbalance, Fig. 1): every chunk gets exactly T nonzeroes.
irregular = random_csr(jax.random.PRNGKey(2), 256, 128, nnz_per_row=(0, 24))
b2 = jax.random.normal(jax.random.PRNGKey(3), (128, 32))
c2 = spmm(irregular, b2, method="merge")
np.testing.assert_allclose(np.asarray(c2),
                           np.asarray(ref.spmm_dense_ref(irregular, b2)),
                           rtol=2e-5, atol=2e-5)
print("irregular merge-based SpMM ✓")
