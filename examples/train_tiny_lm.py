"""End-to-end training driver (deliverable b): train a small LM with the
full stack — deterministic data pipeline, AdamW, checkpointing, resume.

Default is CPU-sized; ``--preset 100m`` selects a ~100M-param llama-family
config for a few hundred steps on real hardware.

    PYTHONPATH=src python examples/train_tiny_lm.py --steps 60
"""
import argparse
import dataclasses
import sys

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--preset", choices=["tiny", "100m"], default="tiny")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_tiny_lm")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: a few steps, tiny batch/sequence")
    args = ap.parse_args()

    if args.smoke:
        return train_mod.main([
            "--arch", "llama3.2-1b", "--smoke", "--steps", "3",
            "--global-batch", "2", "--seq-len", "32",
            "--log-every", "1"])

    if args.preset == "100m":
        # ~100M params: 12L × 768d llama-family
        import repro.configs.llama3_2_1b as l3
        cfg = dataclasses.replace(
            l3.CONFIG, num_layers=12, d_model=768, num_heads=12,
            num_kv_heads=4, head_dim=0, d_ff=2048, vocab_size=32768,
            segments=())
        cfg = dataclasses.replace(cfg)  # __post_init__ rebuilds segments
        n = cfg.param_count()
        print(f"preset 100m: {n / 1e6:.0f}M params")
        argv = ["--arch", "llama3.2-1b", "--steps", str(args.steps),
                "--global-batch", "8", "--seq-len", "512",
                "--ckpt-dir", args.ckpt_dir]
        # train.py reads configs by name; patch the registry entry
        import repro.configs as C
        C._MODULES = dict(C._MODULES)
        mod = type(sys)("preset100m")
        mod.CONFIG = cfg
        mod.smoke_config = lambda: cfg
        sys.modules["repro.configs.preset100m"] = mod
        C._MODULES["llama3.2-1b"] = "preset100m"
        return train_mod.main(argv)

    return train_mod.main([
        "--arch", "llama3.2-1b", "--smoke", "--steps", str(args.steps),
        "--global-batch", "4", "--seq-len", "64",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "25",
        "--log-every", "10"])


if __name__ == "__main__":
    raise SystemExit(main())
