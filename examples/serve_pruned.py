"""Pruned-FFN serving via the paper's SpMM (use case §1 [1]), v1 API.

Magnitude-prunes a small LM's MLP weights into ``SparseLinear`` layers
(each carrying a ``SparseMatrix`` + engine-cached plan) and serves the
forward pass through the plan-once/execute-many engine — the activation
matrix is the paper's tall-skinny dense B.  Compares pruned vs. dense
outputs and reports agreement + the kernel each layer's policy picked.

    PYTHONPATH=src python examples/serve_pruned.py --keep 0.25
    PYTHONPATH=src python examples/serve_pruned.py --smoke   # CI-sized
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import PlanPolicy
from repro.configs import get_smoke_config
from repro.models import layers as L
from repro.models import model as M
from repro.models.sparse import prune_mlp, sparse_mlp_apply


def forward_with_pruned_mlps(params, cfg, tokens, keep, policy=None):
    """Python-loop forward (layers unstacked) with SparseLinear MLPs."""
    h = M.embed_inputs(params, cfg, {"tokens": tokens})
    kinds = []
    for si, (pattern, count) in enumerate(cfg.segments):
        for ci in range(count):
            for pi, btype in enumerate(pattern):
                lp = jax.tree.map(lambda x: x[ci],
                                  params["segments"][si][pi])
                hn = L.norm_apply(lp["ln1"], h, cfg.norm)
                attn, _ = L.attention_apply(lp["attn"], hn, cfg)
                h = h + attn
                hn2 = L.norm_apply(lp["ln2"], h, cfg.norm)
                sparse_p = prune_mlp(lp["mlp"], keep, policy=policy)
                kinds.append({k: v.method for k, v in sparse_p.items()})
                h = h + sparse_mlp_apply(sparse_p, hn2, cfg)
    h = L.norm_apply(params["final_norm"], h, cfg.norm)
    logits = h.astype(jnp.float32) @ M.unembed_matrix(
        params, cfg).T.astype(jnp.float32)
    return logits, kinds


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--keep", type=float, default=0.25)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=16)
    ap.add_argument("--method", default="auto",
                    help="SpMM method policy for every pruned layer "
                    "(any registered method; default: auto)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: tiny batch/sequence")
    args = ap.parse_args()
    if args.smoke:
        args.batch, args.seq = 1, 8

    cfg = get_smoke_config("llama3.2-1b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.seq), 0, cfg.vocab_size)

    # dense reference
    h = M.embed_inputs(params, cfg, {"tokens": tokens})
    h, _, _ = M.forward(params, cfg, h)
    h = L.norm_apply(params["final_norm"], h, cfg.norm)
    dense_logits = h.astype(jnp.float32) @ M.unembed_matrix(
        params, cfg).T.astype(jnp.float32)

    policy = PlanPolicy(method=args.method)
    pruned_logits, kinds = forward_with_pruned_mlps(
        params, cfg, tokens, args.keep, policy=policy)
    d_top = np.asarray(jnp.argmax(dense_logits[:, -1], -1))
    p_top = np.asarray(jnp.argmax(pruned_logits[:, -1], -1))
    agree = float((d_top == p_top).mean())
    print(f"keep={args.keep:.0%}: SpMM methods per layer: {kinds[0]}")
    print(f"top-1 agreement dense vs pruned @ last position: {agree:.0%}")
    cos = float(jnp.sum(dense_logits * pruned_logits) /
                (jnp.linalg.norm(dense_logits) *
                 jnp.linalg.norm(pruned_logits)))
    print(f"logit cosine similarity: {cos:.4f}")


if __name__ == "__main__":
    main()
