"""MoE dispatch as merge-based SpMM (DESIGN.md §3.3).

Routes a batch through a 16-expert MoE with deliberately skewed routing and
shows that the sort-based (merge) dispatch produces the same result as the
dense einsum baseline while doing equal-tokens-per-block work — the
paper's equal-nonzeros-per-chunk principle applied to experts.

    PYTHONPATH=src python examples/moe_spmm_demo.py [--smoke]
"""
import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import moe as MOE

ap = argparse.ArgumentParser()
ap.add_argument("--smoke", action="store_true",
                help="CI-sized run: fewer tokens per batch")
args = ap.parse_args()
seq = 16 if args.smoke else 64

cfg = dataclasses.replace(get_smoke_config("olmoe-1b-7b"),
                          d_model=128, d_ff=256, num_experts=16, top_k=2,
                          compute_dtype="float32")
p = MOE.init_moe(jax.random.PRNGKey(0), cfg)
# skew the router: experts 0/1 are "hot" (the paper's long rows)
p["router"] = p["router"].at[:, 0].add(3.0).at[:, 1].add(2.0)

x = jax.random.normal(jax.random.PRNGKey(1), (4, seq, cfg.d_model))
xt = x.reshape(-1, cfg.d_model)
gates, experts, probs = MOE.route(p, xt, cfg)
counts = np.bincount(np.asarray(experts).ravel(), minlength=cfg.num_experts)
print("tokens per expert (skewed routing):", counts)
print(f"hottest/coldest = {counts.max()}/{max(counts.min(), 1)} — "
      f"Type 1 imbalance for an expert-parallel baseline")

buf, meta = MOE._sorted_dispatch(xt, experts, cfg, MOE.TT,
                                 capacity_factor=float(cfg.num_experts))
print(f"merge dispatch: sorted buffer {buf.shape}, every block of "
      f"{MOE.TT} tokens does identical work regardless of skew")

y_sort, aux = MOE.moe_apply(p, x, cfg, use_kernel=False,
                            capacity_factor=float(cfg.num_experts))
cfg_d = dataclasses.replace(cfg, moe_impl="dense")
y_dense, _ = MOE.moe_apply(p, x, cfg_d)
np.testing.assert_allclose(np.asarray(y_sort), np.asarray(y_dense),
                           rtol=2e-4, atol=2e-4)
print("sort (merge-based) dispatch == dense baseline ✓  "
      f"(aux load-balance loss {float(aux):.3f})")
