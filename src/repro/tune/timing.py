"""Wall-clock timing of jitted callables — the measurement primitive.

This is the single implementation behind both the paper-figure benchmarks
(``benchmarks/common.timeit`` re-exports it) and the empirical autotuner:
warm up past compilation, then report the median of ``repeat`` synchronous
calls in microseconds.  Median (not mean) so a stray GC pause or
first-touch page fault cannot flip a merge/rowsplit verdict recorded into
the TuneDB.

The result is a :class:`TimingResult` — a ``float`` subclass whose value
*is* the median, so every existing arithmetic/format call site keeps
working — that additionally retains the per-repeat samples and exposes
``p50``/``p95``/``min``/``mean``/``std``/``cv``.  The benchmarks print
``cv`` as a variance column: a winner whose margin is inside the noise
band is not a winner.
"""
from __future__ import annotations

import time

import jax
import numpy as np


class TimingResult(float):
    """Median µs as a float, with the raw per-repeat samples attached."""

    __slots__ = ("samples",)

    def __new__(cls, samples):
        xs = [float(s) for s in samples]
        self = super().__new__(cls, float(np.median(xs)) if xs
                               else float("nan"))
        self.samples = tuple(xs)
        return self

    @property
    def median(self) -> float:
        return float(self)

    @property
    def p50(self) -> float:
        return float(np.percentile(self.samples, 50))

    @property
    def p95(self) -> float:
        return float(np.percentile(self.samples, 95))

    @property
    def min(self) -> float:
        return float(np.min(self.samples))

    @property
    def max(self) -> float:
        return float(np.max(self.samples))

    @property
    def mean(self) -> float:
        return float(np.mean(self.samples))

    @property
    def std(self) -> float:
        return float(np.std(self.samples))

    @property
    def cv(self) -> float:
        """Coefficient of variation (std/mean) — the noise band."""
        m = self.mean
        return self.std / m if m > 0 else float("nan")

    def __repr__(self) -> str:
        return (f"TimingResult({float(self):.1f}us, n={len(self.samples)}, "
                f"cv={self.cv:.3f})")


def timeit(fn, *args, warmup: int = 2, repeat: int = 5) -> TimingResult:
    """Median wall-time in µs of a jitted callable (a TimingResult)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return TimingResult(ts)
