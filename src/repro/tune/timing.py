"""Wall-clock timing of jitted callables — the measurement primitive.

This is the single implementation behind both the paper-figure benchmarks
(``benchmarks/common.timeit`` re-exports it) and the empirical autotuner:
warm up past compilation, then report the median of ``repeat`` synchronous
calls in microseconds.  Median (not mean) so a stray GC pause or
first-touch page fault cannot flip a merge/rowsplit verdict recorded into
the TuneDB.
"""
from __future__ import annotations

import time

import jax
import numpy as np


def timeit(fn, *args, warmup: int = 2, repeat: int = 5) -> float:
    """Median wall-time in µs of a jitted callable."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))
