"""Empirical autotuning: measured kernel selection with a persistent DB.

    # build the database once per backend
    python -m repro.tune --suite paper --out tune.json

    # plan building then resolves methods from measurements
    from repro import engine
    engine.load_tunedb("tune.json")
    plan = engine.get_plan(a)       # exact -> class -> calibrated threshold

See ``repro.tune.db`` for the resolution ladder and the on-disk schema,
``repro.tune.autotune`` for what exactly gets timed.
"""
from .autotune import tune_pattern, tune_suite
from .db import (SCHEMA_VERSION, TuneDB, TuneRecord, backend_key,
                 class_signature)
from .timing import TimingResult, timeit

__all__ = [
    "tune_pattern", "tune_suite",
    "SCHEMA_VERSION", "TuneDB", "TuneRecord", "backend_key",
    "class_signature",
    "TimingResult", "timeit",
]
