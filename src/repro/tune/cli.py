"""``python -m repro.tune``: build/refresh a TuneDB over a corpus suite.

    # tune the paper suite and write the database
    python -m repro.tune --suite paper --out tune.json

    # CI smoke: 3 matrices, fast timing budget
    python -m repro.tune --suite mini --out artifacts/tune.json \
        --warmup 1 --repeat 2

    # fold a directory of .mtx files into an existing DB
    python -m repro.tune --mtx-dir ./suitesparse --out tune.json

The resulting JSON is consumed by ``repro.engine`` (``--tunedb`` on the
serve/train launchers, or ``engine.load_tunedb(path)``): plan building
then resolves the kernel method from measurements instead of the paper's
K40c threshold.
"""
from __future__ import annotations

import argparse

from repro.matrices.suites import get_suite, specs_from_mtx_dir, suite_names

from .autotune import tune_suite
from .db import TuneDB, backend_key


def _report(db: TuneDB) -> None:
    print(f"# TuneDB backend={db.backend} entries={len(db)}")
    print("name,m,k,d,cv,method,merge_us,rowsplit_us,speedup,timings")
    for rec in sorted(db.entries.values(), key=lambda r: r.name):
        lo, hi = sorted((rec.merge_us, rec.rowsplit_us))
        extras = ";".join(f"{m}={us:.0f}" for m, us in
                          sorted((rec.timings or {}).items()))
        print(f"{rec.name or '?'},{rec.m},{rec.k},{rec.d:.2f},"
              f"{rec.cv:.2f},{rec.method},{rec.merge_us:.0f},"
              f"{rec.rowsplit_us:.0f},{hi / max(lo, 1e-9):.2f}x,{extras}")
    if db.threshold is not None:
        print(f"# calibrated_threshold={db.threshold:.3f} "
              f"accuracy={db.threshold_accuracy * 100:.1f}%")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.tune",
        description="empirically autotune merge vs rowsplit over a "
                    "matrix corpus and persist winners in a TuneDB")
    ap.add_argument("--suite", choices=suite_names(), default=None,
                    help="named corpus suite (repro.matrices.suites)")
    ap.add_argument("--mtx-dir", default=None,
                    help="directory of .mtx files to tune as well")
    ap.add_argument("--out", required=True, help="TuneDB JSON path "
                    "(loaded and extended if it exists)")
    ap.add_argument("--n", type=int, default=64,
                    help="dense B columns for timing (paper: n in 32-128)")
    ap.add_argument("--impl", default="xla", choices=["xla", "pallas"],
                    help="kernel implementation to time")
    ap.add_argument("--warmup", type=int, default=2)
    ap.add_argument("--repeat", type=int, default=5)
    ap.add_argument("--wide", action="store_true",
                    help="also sweep l_pad/t candidates per method")
    ap.add_argument("--refresh", action="store_true",
                    help="re-time patterns already in the DB")
    args = ap.parse_args(argv)

    if args.suite is None and args.mtx_dir is None:
        ap.error("nothing to tune: pass --suite and/or --mtx-dir")

    specs = list(get_suite(args.suite)) if args.suite else []
    if args.mtx_dir:
        specs += specs_from_mtx_dir(args.mtx_dir)

    try:
        # strict: a corrupt or backend/schema-mismatched existing DB must
        # error out, not silently degrade to empty and then be overwritten
        # by db.save() — launchers degrade gracefully, the builder doesn't.
        db = TuneDB.load(args.out, strict=True)
        print(f"# extending {args.out} ({len(db)} entries)")
    except FileNotFoundError:
        db = TuneDB()
        print(f"# new TuneDB for backend {backend_key()}")
    except ValueError as e:
        ap.error(f"refusing to overwrite {args.out}: {e} "
                 "(move the file aside, or point --out elsewhere)")

    tune_suite(specs, db, n=args.n, impl=args.impl, warmup=args.warmup,
               repeat=args.repeat, wide=args.wide, refresh=args.refresh,
               log=lambda s: print(f"# {s}"))
    db.save(args.out)
    _report(db)
    print(f"# wrote {args.out}")
    return 0
