"""TuneDB: a versioned, backend-keyed, on-disk record of measured winners.

The paper ships one number — threshold 9.35, calibrated once on a K40c —
and the seed repo hard-coded it.  The crossover is a property of the
backend (memory system, kernel implementations), so this module replaces
the constant with *measurements*: every tuned pattern gets a record of its
merge/rowsplit timings, the winning method, and the winning static
parameters (row-split ``l_pad``, merge chunk ``t``).

Resolution at plan-build time (``repro.engine.get_plan``), all host-side:

1. **exact** — the pattern's content fingerprint has a record → use its
   method (and tuned ``l_pad``/``t``),
2. **class** — the pattern's binned ``(m, k, d, cv)`` signature matches
   tuned patterns → majority winner among them,
3. **threshold** — the §5.4 analytic rule with a threshold *calibrated
   from this DB's own timings* (falling back to the paper's 9.35 only
   when the DB is empty).

A DB is bound to one backend key (platform + device kind).  ``load`` is
forgiving by design: a corrupt file, a schema-version mismatch, or a
backend mismatch degrades to an *empty* DB — plan building then falls
back to the analytic heuristic instead of crashing a serving job over a
stale artifact.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
import warnings

import numpy as np

from repro.core.csr import CSR
from repro.core.heuristic import Heuristic, calibrate
from repro.core.plan import pattern_fingerprint

SCHEMA_VERSION = 1


def backend_key() -> str:
    """Identity of the backend the timings belong to."""
    import jax

    dev = jax.devices()[0]
    return f"{jax.default_backend()}:{dev.device_kind}"


def _log2_bin(x: float) -> int:
    return int(round(math.log2(x))) if x > 0 else -1


_CV_EDGES = (0.1, 0.5, 1.0)     # regular | mild | irregular | heavy-tail


def class_signature(m: int, k: int, d: float, cv: float) -> str:
    """Binned pattern-class signature over (m, k, d, cv).

    Octave (log2) bins for the sizes and the mean row length, coarse
    imbalance bins for cv — wide enough that one tuned matrix covers its
    neighbours, narrow enough that the merge/rowsplit crossover (an
    octave-scale effect in ``d``) stays resolvable.
    """
    cv_bin = sum(cv >= e for e in _CV_EDGES)
    return (f"m{_log2_bin(m)}k{_log2_bin(k)}"
            f"d{_log2_bin(d)}cv{cv_bin}")


@dataclasses.dataclass
class TuneRecord:
    """Measured outcome for one sparsity pattern on one backend.

    ``method`` is the overall winner across every registered method (it
    may name a registered non-core method, e.g. ``"rowgroup"``; exact
    TuneDB hits replay it).  ``merge_us``/``rowsplit_us`` always hold the
    core pair's timings — they anchor the class aggregates and the
    threshold calibration, which are inherently two-way.  ``timings``
    carries the full per-method best timings (absent in pre-v1 files).
    """

    method: str                  # overall winner (a registered method name)
    merge_us: float
    rowsplit_us: float
    m: int
    k: int
    d: float                     # mean row length
    cv: float                    # row-length coefficient of variation
    n: int                       # dense B columns used for timing
    l_pad: int | None = None  # winning rowsplit pad (None: pattern max)
    t: int | None = None      # winning merge chunk size (None: default)
    name: str = ""               # corpus spec name, for reports
    timings: dict[str, float] | None = None  # per-method best, in us

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    @property
    def oracle(self) -> str:
        """Winner of the core merge/rowsplit pair (calibration target)."""
        return "merge" if self.merge_us < self.rowsplit_us else "rowsplit"

    @property
    def signature(self) -> str:
        return class_signature(self.m, self.k, self.d, self.cv)


class TuneDB:
    """In-memory view of the tuning database (see module docstring)."""

    def __init__(self, backend: str | None = None):
        self.backend = backend or backend_key()
        self.entries: dict[str, TuneRecord] = {}
        self.threshold: float | None = None
        self.threshold_accuracy: float | None = None
        self._classes: dict[str, dict[str, float]] = {}
        self._digest: str | None = None

    # ------------------------------------------------------- mutation ---

    def record(self, fingerprint: str, rec: TuneRecord) -> None:
        old = self.entries.get(fingerprint)
        if old is not None:
            self._class_add(old, remove=True)
        self.entries[fingerprint] = rec
        self._class_add(rec)
        self._digest = None

    def _class_add(self, rec: TuneRecord, remove: bool = False) -> None:
        sgn = -1.0 if remove else 1.0
        agg = self._classes.setdefault(
            rec.signature, {"merge_wins": 0.0, "rowsplit_wins": 0.0,
                            "merge_us": 0.0, "rowsplit_us": 0.0})
        agg[f"{rec.oracle}_wins"] += sgn
        agg["merge_us"] += sgn * rec.merge_us
        agg["rowsplit_us"] += sgn * rec.rowsplit_us

    def calibrate_threshold(self) -> tuple[float, float]:
        """Fit the analytic-fallback threshold from this DB's timings."""
        if not self.entries:
            raise ValueError("cannot calibrate an empty TuneDB")
        recs = list(self.entries.values())
        ds = np.array([r.d for r in recs])
        thr, acc = calibrate(ds, np.array([r.rowsplit_us for r in recs]),
                             np.array([r.merge_us for r in recs]))
        self.threshold, self.threshold_accuracy = thr, acc
        self._digest = None
        return thr, acc

    # -------------------------------------------------------- queries ---

    def __len__(self) -> int:
        return len(self.entries)

    def lookup_exact(self, fingerprint: str) -> TuneRecord | None:
        return self.entries.get(fingerprint)

    def lookup_class(self, signature: str) -> str | None:
        agg = self._classes.get(signature)
        if agg is None or (agg["merge_wins"] + agg["rowsplit_wins"]) <= 0:
            return None
        if agg["merge_wins"] != agg["rowsplit_wins"]:
            return "merge" if agg["merge_wins"] > agg["rowsplit_wins"] \
                else "rowsplit"
        return "merge" if agg["merge_us"] <= agg["rowsplit_us"] \
            else "rowsplit"

    def heuristic(self) -> Heuristic:
        """Analytic fallback, calibrated from this DB when possible."""
        if self.threshold is not None:
            return Heuristic(threshold=self.threshold)
        return Heuristic()

    def lookup_class_for(self, a: CSR) -> str | None:
        """Class-rung lookup for a concrete pattern (no exact check)."""
        from repro.matrices.stats import compute_stats

        s = compute_stats(a)
        return self.lookup_class(class_signature(s.m, s.k, s.d, s.cv))

    def resolve(self, a: CSR) -> tuple[str | None, str]:
        """Method for a concrete pattern: ``(method, source)``.

        ``source`` is ``"exact"``, ``"class"``, or ``"miss"`` (method
        None — the caller falls back to :meth:`heuristic`).  Host-side
        only: fingerprints and stats need a concrete pattern.
        """
        rec = self.lookup_exact(pattern_fingerprint(a))
        if rec is not None:
            return rec.method, "exact"
        cls = self.lookup_class_for(a)
        if cls is not None:
            return cls, "class"
        return None, "miss"

    def choose(self, a: CSR) -> str:
        """Fully resolved method (resolve, then heuristic fallback)."""
        method, _ = self.resolve(a)
        return method if method is not None else self.heuristic().choose(a)

    def digest(self) -> str:
        """Content hash — cache-key token so plan caches never serve a
        plan resolved against a different DB state."""
        if self._digest is None:
            blob = json.dumps(self.as_dict(), sort_keys=True)
            self._digest = hashlib.sha1(blob.encode()).hexdigest()[:16]
        return self._digest

    # ---------------------------------------------------- persistence ---

    def as_dict(self) -> dict:
        return {
            "schema_version": SCHEMA_VERSION,
            "backend": self.backend,
            "threshold": self.threshold,
            "threshold_accuracy": self.threshold_accuracy,
            "entries": {fp: r.as_dict()
                        for fp, r in sorted(self.entries.items())},
        }

    def save(self, path: str | os.PathLike) -> None:
        tmp = f"{path}.tmp"
        with open(tmp, "w") as f:
            json.dump(self.as_dict(), f, indent=1, sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)

    @classmethod
    def load(cls, path: str | os.PathLike, *,
             backend: str | None = None, strict: bool = False) -> "TuneDB":
        """Load a DB for ``backend`` (default: the current one).

        Any defect — unreadable/corrupt JSON, schema-version mismatch,
        backend mismatch — returns an **empty** DB (with a warning), so
        callers degrade to the analytic heuristic.  ``strict=True`` turns
        those defects into exceptions (the CLI uses it).
        """
        expect = backend or backend_key()

        def _reject(msg: str) -> "TuneDB":
            if strict:
                raise ValueError(f"TuneDB {path}: {msg}")
            warnings.warn(f"TuneDB {path}: {msg}; falling back to the "
                          "analytic heuristic", stacklevel=2)
            return cls(backend=expect)

        try:
            with open(path) as f:
                raw = json.load(f)
        except FileNotFoundError:
            raise
        except (OSError, json.JSONDecodeError, UnicodeDecodeError) as e:
            return _reject(f"unreadable or corrupt ({e})")
        if not isinstance(raw, dict):
            return _reject("not a JSON object")
        if raw.get("schema_version") != SCHEMA_VERSION:
            return _reject(f"schema version {raw.get('schema_version')!r} "
                           f"!= supported {SCHEMA_VERSION}")
        if raw.get("backend") != expect:
            return _reject(f"built for backend {raw.get('backend')!r}, "
                           f"this process runs {expect!r}")
        db = cls(backend=expect)
        try:
            for fp, rd in raw.get("entries", {}).items():
                db.record(fp, TuneRecord(**rd))
        except TypeError as e:
            return _reject(f"malformed entry ({e})")
        db.threshold = raw.get("threshold")
        db.threshold_accuracy = raw.get("threshold_accuracy")
        db._digest = None
        return db
