"""Empirical autotuner: measure every registered method, record the winner.

What gets timed is the *steady state the engine actually runs*: a plan is
built once per (method, candidate) outside the timed region, then
``execute_plan`` is jitted and timed — the plan-once/execute-many regime,
not the paper's per-call planning (benchmarks time that separately).

The method list and each method's static-parameter candidates (row-split
``l_pad`` pads, merge chunk sizes ``t``) come from the method registry
(``repro.kernels.registry``) — a newly registered method is tuned with
zero edits here.  The winner's method and parameters are recorded so
exact-pattern TuneDB hits replay them at plan build; per-method best
timings land in ``TuneRecord.timings``.
"""
from __future__ import annotations

import math
from collections.abc import Callable, Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import ExecutionConfig
from repro.core.csr import CSR
from repro.core.plan import build_plan, pattern_fingerprint
from repro.core.spmm import execute_plan
from repro.matrices.stats import compute_stats
from repro.matrices.suites import MatrixSpec

from .db import TuneDB, TuneRecord
from .timing import timeit


def _time_plan(a: CSR, b, *, method: str, impl: str, warmup: int,
               repeat: int, **cand) -> float:
    plan = build_plan(a, method=method, with_transpose=False, **cand)
    run = ExecutionConfig(impl=impl)
    fn = jax.jit(lambda vals, bb: execute_plan(plan, vals, bb, run))
    return timeit(fn, a.vals, b, warmup=warmup, repeat=repeat)


def tune_pattern(a: CSR, *, n: int = 64, impl: str = "xla",
                 warmup: int = 2, repeat: int = 5, wide: bool = False,
                 name: str = "", seed: int = 0) -> TuneRecord:
    """Time every registered method (over its candidates) on a pattern."""
    from repro.kernels import registry

    rng = np.random.default_rng(seed)
    b = jnp.asarray(rng.standard_normal((a.k, n)), a.dtype)

    timings: dict[str, float] = {}
    best_kw: dict[str, dict] = {}
    for mname in registry.method_names():
        spec = registry.get_method(mname)
        best, bkw = math.inf, {}
        for cand in spec.tune_candidates(a, wide):
            us = _time_plan(a, b, method=mname, impl=impl, warmup=warmup,
                            repeat=repeat, **cand)
            if us < best:
                best, bkw = us, dict(cand)
        timings[mname] = float(best)
        best_kw[mname] = bkw

    s = compute_stats(a)
    method = min(timings, key=timings.get)
    return TuneRecord(method=method, merge_us=timings["merge"],
                      rowsplit_us=timings["rowsplit"], m=s.m, k=s.k,
                      d=s.d, cv=s.cv, n=n,
                      l_pad=best_kw[method].get("l_pad"),
                      t=best_kw[method].get("t"), name=name,
                      timings=timings)


def tune_suite(specs: Iterable[MatrixSpec], db: TuneDB, *, n: int = 64,
               impl: str = "xla", warmup: int = 2, repeat: int = 5,
               wide: bool = False, refresh: bool = False,
               log: Callable[[str], None] = lambda s: None) -> TuneDB:
    """Tune every spec into ``db`` (skipping fresh hits unless refresh),
    then recalibrate the DB's fallback threshold from all its timings."""
    for spec in specs:
        a = spec()
        fp = pattern_fingerprint(a)
        if not refresh and db.lookup_exact(fp) is not None:
            log(f"{spec.name}: cached")
            continue
        rec = tune_pattern(a, n=n, impl=impl, warmup=warmup,
                           repeat=repeat, wide=wide, name=spec.name)
        db.record(fp, rec)
        others = "; ".join(f"{m} {us:.0f}us"
                           for m, us in sorted((rec.timings or {}).items()))
        log(f"{spec.name}: d={rec.d:.1f} cv={rec.cv:.2f} -> {rec.method} "
            f"({others})")
    if len(db):
        thr, acc = db.calibrate_threshold()
        log(f"calibrated threshold={thr:.2f} "
            f"(oracle agreement {acc * 100:.1f}%)")
    return db
