"""Empirical autotuner: measure merge vs. row-split, record the winner.

What gets timed is the *steady state the engine actually runs*: a plan is
built once per (method, candidate) outside the timed region, then
``execute_plan`` is jitted and timed — the plan-once/execute-many regime,
not the paper's per-call planning (benchmarks time that separately).
Beyond the method, static-parameter candidates ride along: row-split
``l_pad`` pads (pattern max, padded-up tiles) and merge chunk sizes ``t``
— the winner's parameters are recorded so exact-pattern TuneDB hits replay
them at plan build.
"""
from __future__ import annotations

from typing import Callable, Iterable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.csr import CSR
from repro.core.plan import build_plan, pattern_fingerprint
from repro.core.spmm import execute_plan
from repro.matrices.stats import compute_stats
from repro.matrices.suites import MatrixSpec

from .db import TuneDB, TuneRecord
from .timing import timeit


def _time_plan(a: CSR, b, *, method: str, impl: str, warmup: int,
               repeat: int, t: int | None = None,
               l_pad: int | None = None) -> float:
    plan = build_plan(a, method=method, t=t, l_pad=l_pad,
                      with_transpose=False)
    fn = jax.jit(lambda vals, bb: execute_plan(plan, vals, bb, impl=impl))
    return timeit(fn, a.vals, b, warmup=warmup, repeat=repeat)


def _l_pad_candidates(a: CSR, wide: bool) -> Sequence[Optional[int]]:
    lengths = np.diff(np.asarray(a.row_ptr))
    lmax = max(int(lengths.max()) if lengths.size else 1, 1)
    cands = [lmax]
    if wide:
        up8 = -(-lmax // 8) * 8
        if up8 != lmax:
            cands.append(up8)      # tile-aligned ELL rows
    return cands


def _t_candidates(wide: bool) -> Sequence[Optional[int]]:
    from repro.kernels import merge_spmm

    cands = [merge_spmm.DEFAULT_T]
    if wide:
        cands += [c for c in (8, 32) if c != merge_spmm.DEFAULT_T]
    return cands


def tune_pattern(a: CSR, *, n: int = 64, impl: str = "xla",
                 warmup: int = 2, repeat: int = 5, wide: bool = False,
                 name: str = "", seed: int = 0) -> TuneRecord:
    """Time both methods (over candidates) on a concrete pattern."""
    rng = np.random.default_rng(seed)
    b = jnp.asarray(rng.standard_normal((a.k, n)), a.dtype)

    merge_us, best_t = np.inf, None
    for t in _t_candidates(wide):
        us = _time_plan(a, b, method="merge", impl=impl, warmup=warmup,
                        repeat=repeat, t=t)
        if us < merge_us:
            merge_us, best_t = us, t

    rowsplit_us, best_l_pad = np.inf, None
    for l_pad in _l_pad_candidates(a, wide):
        us = _time_plan(a, b, method="rowsplit", impl=impl, warmup=warmup,
                        repeat=repeat, l_pad=l_pad)
        if us < rowsplit_us:
            rowsplit_us, best_l_pad = us, l_pad

    s = compute_stats(a)
    method = "merge" if merge_us < rowsplit_us else "rowsplit"
    return TuneRecord(method=method, merge_us=float(merge_us),
                      rowsplit_us=float(rowsplit_us), m=s.m, k=s.k,
                      d=s.d, cv=s.cv, n=n,
                      l_pad=best_l_pad if method == "rowsplit" else None,
                      t=best_t if method == "merge" else None, name=name)


def tune_suite(specs: Iterable[MatrixSpec], db: TuneDB, *, n: int = 64,
               impl: str = "xla", warmup: int = 2, repeat: int = 5,
               wide: bool = False, refresh: bool = False,
               log: Callable[[str], None] = lambda s: None) -> TuneDB:
    """Tune every spec into ``db`` (skipping fresh hits unless refresh),
    then recalibrate the DB's fallback threshold from all its timings."""
    for spec in specs:
        a = spec()
        fp = pattern_fingerprint(a)
        if not refresh and db.lookup_exact(fp) is not None:
            log(f"{spec.name}: cached")
            continue
        rec = tune_pattern(a, n=n, impl=impl, warmup=warmup,
                           repeat=repeat, wide=wide, name=spec.name)
        db.record(fp, rec)
        log(f"{spec.name}: d={rec.d:.1f} cv={rec.cv:.2f} -> {rec.method} "
            f"(merge {rec.merge_us:.0f}us vs rowsplit "
            f"{rec.rowsplit_us:.0f}us)")
    if len(db):
        thr, acc = db.calibrate_threshold()
        log(f"calibrated threshold={thr:.2f} "
            f"(oracle agreement {acc * 100:.1f}%)")
    return db
