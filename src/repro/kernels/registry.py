"""Pluggable SpMM method registry — adding a method is a registration.

The paper frames SpMM as a *dispatch decision* over a shared CSR input
(merge vs. row-split, §5.4), and the row-grouped-CSR line of work shows
more methods are coming.  Pre-v1 that dispatch was hardwired into if/elif
chains across ``core/spmm.py``, ``core/plan.py``, the engine cache, and
the autotuner; here each method registers one :class:`MethodSpec` bundling
everything those call sites need:

* ``build_structure`` — the pattern-only plan-structure builder,
* ``execute`` — the plan-execute op (Pallas body + XLA ref behind
  ``impl=``), wrapped on demand in a ``custom_vmap`` rule by
  :func:`execute_op`,
* ``inline`` — the plan-per-call form (``spmm(..., plan="inline")``),
* ``resolve_params`` — per-method static-parameter resolution and
  validation (defaults, ``l_pad`` derivation, silent-truncation guards),
* ``tune_candidates`` — the autotuner's static-parameter sweep,
* ``heuristic_rank`` — the analytic cost hook behind ``method="auto"``
  (``None``: opt-in only, never auto-selected).

``core.spmm._forward``, ``core.config.PlanPolicy.resolve``,
``core.plan.build_plan``, ``engine.PlanCache``, ``tune.tune_pattern`` and
``benchmarks/bench_corpus.py`` all dispatch through this table, so a new
method (see ``rowgroup_spmm.py``) touches only its own module plus a
``register_method`` call.
"""
from __future__ import annotations

import dataclasses
import functools
from collections.abc import Callable, Sequence

import jax
import numpy as np

from . import merge_spmm as _merge
from . import ops as _ops
from . import rowsplit_spmm as _rowsplit


@dataclasses.dataclass(frozen=True)
class MethodSpec:
    """Everything the engine needs to plan, execute, tune one method.

    Callable contracts (``meta`` is a ``core.plan.PlanMeta``; ``fwd`` the
    method's pattern structure dict; ``a`` a concrete ``CSR``):

    * ``build_structure(a, meta) -> dict`` of static-shaped device arrays
      (pattern-only; values re-applied per call through ``slot_nz``).
    * ``execute(meta, fwd, vals, b, *, tk, interpret, impl, epilogue=None,
      bias=None, residual=None, acc_dtype=None, out_dtype=None) -> C``
      with ``b (..., k, n) -> (..., m, n)`` (leading batch dims native).
      ``epilogue`` is a ``core.Epilogue`` fused into the output write
      (``bias (m,)``; ``residual (..., m, n)``, broadcast over the batch);
      ``acc_dtype``/``out_dtype`` set accumulation and output precision.
    * ``inline(a, b, *, t, tl, l_pad, extra, tk, interpret, impl) -> C``
      — the plan-per-call regime (``t``/``tl``/``l_pad`` may be None:
      kernel defaults; ``extra`` is the already-resolved
      ``PlanMeta.extra`` when the caller ran ``resolve_params``, else
      None — a hint methods may use to skip derivable work); ``None`` if
      the method has no inline form.
    * ``resolve_params(a, *, t, tl, l_pad) -> (t, tl, l_pad, extra)``:
      fill defaults, validate, and compute ``extra`` (a hashable tuple of
      method-specific statics stored in ``PlanMeta.extra``).
    * ``tune_candidates(a, wide) -> [ {t=...} | {l_pad=...} | {} , ...]``
      — kwargs for ``build_plan`` sweeps in ``repro.tune``.
    * ``heuristic_rank(a, heuristic) -> float`` — analytic cost; the
      lowest-ranked method wins ``method="auto"`` (ties go to the
      later-registered spec, preserving the paper rule's ``d >=
      threshold -> rowsplit``).
    * ``traffic(plan, n, batch, var, tk) -> [KernelLaunch]`` — the
      static launch model(s) of the method's ``impl="pallas"`` lowering
      (``repro.kernels.introspect``), consumed by the kernel audit, the
      coalescing checker and the bytes-moved analyzer
      (``repro.analysis``).  ``None`` strands the method outside the
      static-analysis gate and is itself a diagnostic (K001/T101) —
      coverage is bidirectionally loud, never silently skipped.
    """

    name: str
    description: str
    build_structure: Callable
    execute: Callable
    inline: Callable | None
    resolve_params: Callable
    tune_candidates: Callable
    heuristic_rank: Callable | None
    traffic: Callable | None = None


_REGISTRY: dict[str, MethodSpec] = {}


def register_method(spec: MethodSpec, *, override: bool = False) -> None:
    """Register an SpMM method. Raises on duplicate names unless
    ``override`` (tests may swap in instrumented specs)."""
    if spec.name in _REGISTRY and not override:
        raise ValueError(f"SpMM method {spec.name!r} is already registered "
                         "(pass override=True to replace it)")
    _REGISTRY[spec.name] = spec


def method_names() -> tuple[str, ...]:
    """Registered method names, in registration order."""
    return tuple(_REGISTRY)


def get_method(name: str) -> MethodSpec:
    spec = _REGISTRY.get(name)
    if spec is None:
        raise ValueError(
            f"unknown SpMM method: {name!r}; registered methods: "
            + ", ".join(sorted(_REGISTRY)))
    return spec


def choose_auto(a, heuristic) -> str:
    """Resolve ``method="auto"`` through the registered cost hooks.

    Ties go to the later-registered spec, so with only the built-in pair
    this reproduces ``Heuristic.choose`` exactly (``d < threshold ->
    merge``, else rowsplit).
    """
    best = None
    for name, spec in _REGISTRY.items():
        if spec.heuristic_rank is None:
            continue
        rank = spec.heuristic_rank(a, heuristic)
        if best is None or rank <= best[0]:
            best = (rank, name)
    if best is None:
        raise ValueError("no registered SpMM method is heuristic-eligible")
    return best[1]


# Bounded like the per-method op caches it replaced: keys embed the full
# static PlanMeta, so a long-lived server cycling patterns cannot grow it
# without bound; entries are pure functions of the key.
@functools.lru_cache(maxsize=512)
def execute_op(meta, tk: int | None, interpret: bool | None, impl: str,
               epilogue=None, acc_dtype: str | None = None,
               out_dtype: str | None = None):
    """A method's ``execute`` wrapped with the explicit vmap rule.

    The ``custom_vmap`` wrapper rewrites a vmapped dense-operand axis onto
    the method's native leading-batch path (a flagged ``residual`` batches
    with it; ``bias`` stays unbatched — JAX sums its cotangent across the
    vmap axis); anything else falls back to a sequential ``lax.map``.
    Only for use where JAX vmaps but never differentiates (the custom-VJP
    fwd/bwd bodies in ``core.spmm``).  ``bias``/``residual`` are always
    positional operands of the wrapped op (pass None when the epilogue
    doesn't flag them) so one call shape serves every epilogue.
    """
    spec = get_method(meta.method)

    def fn(fwd, vals, b, bias, residual):
        return spec.execute(meta, fwd, vals, b, tk=tk, interpret=interpret,
                            impl=impl, epilogue=epilogue, bias=bias,
                            residual=residual, acc_dtype=acc_dtype,
                            out_dtype=out_dtype)

    def native(in_batched):
        fwd_b, vals_b, b_b, bias_b, res_b = in_batched
        res_leaves = jax.tree.leaves(res_b)
        return (b_b and not vals_b and not any(jax.tree.leaves(fwd_b))
                and not any(jax.tree.leaves(bias_b))
                and (not res_leaves or all(res_leaves)))

    return _ops._vmappable(fn, native)


# ------------------------------------------------------ built-in methods ---


def _max_row_len(a) -> int:
    lengths = np.diff(np.asarray(a.row_ptr))
    return int(lengths.max()) if lengths.size else 0


def _merge_resolve(a, *, t, tl, l_pad):
    t = _merge.DEFAULT_T if t is None else t
    tl = _rowsplit.DEFAULT_TL if tl is None else tl
    return t, tl, None, ()          # merge has no row pad


def _merge_execute(meta, fwd, vals, b, *, tk, interpret, impl,
                   epilogue=None, bias=None, residual=None,
                   acc_dtype=None, out_dtype=None):
    return _ops.merge_execute(fwd, vals, b, m=meta.m, tk=tk,
                              interpret=interpret, impl=impl,
                              epilogue=epilogue, bias=bias,
                              residual=residual, acc_dtype=acc_dtype,
                              out_dtype=out_dtype)


def _merge_candidates(a, wide: bool) -> Sequence[dict]:
    cands = [dict(t=_merge.DEFAULT_T)]
    if wide:
        cands += [dict(t=c) for c in (8, 32) if c != _merge.DEFAULT_T]
    return cands


def _merge_inline(a, b, *, t, tl, l_pad, extra, tk, interpret, impl):
    return _ops.merge_spmm(a, b, t=t, tk=tk, interpret=interpret, impl=impl)


def _rowsplit_resolve(a, *, t, tl, l_pad):
    t = _merge.DEFAULT_T if t is None else t
    tl = _rowsplit.DEFAULT_TL if tl is None else tl
    max_len = _max_row_len(a)
    if l_pad is None:
        l_pad = max(max_len, 1)
    elif l_pad < max_len:
        # An undersized pad would make plan_rowsplit_structure's ELL mask
        # silently truncate long rows — wrong C, no error.  The pattern is
        # concrete here, so validate at the single choke point every plan
        # request (user kwargs, TuneDB replays, the engine cache) funnels
        # through.
        raise ValueError(
            f"l_pad={l_pad} is smaller than the pattern's longest row "
            f"({max_len} nonzeroes): the row-split ELL layout would "
            "silently drop nonzeroes and return a wrong C. Pass "
            f"l_pad >= {max_len}, or omit l_pad to derive it from the "
            "pattern.")
    return t, tl, l_pad, ()


def _rowsplit_structure(a, meta):
    return dict(_rowsplit.plan_rowsplit_structure(a, l_pad=meta.l_pad,
                                                  tl=meta.tl))


def _rowsplit_execute(meta, fwd, vals, b, *, tk, interpret, impl,
                      epilogue=None, bias=None, residual=None,
                      acc_dtype=None, out_dtype=None):
    return _ops.rowsplit_execute(fwd, vals, b, m=meta.m, tl=meta.tl, tk=tk,
                                 interpret=interpret, impl=impl,
                                 epilogue=epilogue, bias=bias,
                                 residual=residual, acc_dtype=acc_dtype,
                                 out_dtype=out_dtype)


def _rowsplit_candidates(a, wide: bool) -> Sequence[dict]:
    lmax = max(_max_row_len(a), 1)
    cands = [dict(l_pad=lmax)]
    if wide:
        up8 = -(-lmax // 8) * 8
        if up8 != lmax:
            cands.append(dict(l_pad=up8))    # tile-aligned ELL rows
    return cands


def _rowsplit_inline(a, b, *, t, tl, l_pad, extra, tk, interpret, impl):
    tl = _rowsplit.DEFAULT_TL if tl is None else tl
    return _ops.rowsplit_spmm(a, b, l_pad=l_pad, tl=tl, tk=tk,
                              interpret=interpret, impl=impl)


register_method(MethodSpec(
    name="merge",
    description="merge-based nonzero splitting (paper §4.2): equal "
                "nonzeroes per chunk, broken at output row tiles",
    build_structure=lambda a, meta: dict(
        _merge.plan_merge_structure(a, t=meta.t)),
    execute=_merge_execute,
    inline=_merge_inline,
    resolve_params=_merge_resolve,
    tune_candidates=_merge_candidates,
    # The paper's §5.4 rule as a cost: d below the threshold prefers merge.
    heuristic_rank=lambda a, h: h.mean_row_length(a) - h.threshold,
    traffic=_merge.launch_models,
))

register_method(MethodSpec(
    name="rowsplit",
    description="row splitting (paper §4.1): one ELL-padded row tile per "
                "grid step",
    build_structure=_rowsplit_structure,
    execute=_rowsplit_execute,
    inline=_rowsplit_inline,
    resolve_params=_rowsplit_resolve,
    tune_candidates=_rowsplit_candidates,
    heuristic_rank=lambda a, h: h.threshold - h.mean_row_length(a),
    traffic=_rowsplit.launch_models,
))
