"""Row-grouped SpMM: per-group ELL pads à la row-grouped CSR.

The row-grouped-CSR line of work (Oberhuber et al., arXiv:1012.2270;
Heller & Oberhuber, arXiv:1203.5737) attacks row-split's Type 2 waste —
every row padded to the *global* max row length — by grouping rows of
similar length and padding each group only to its own max.  Here rows are
bucketed by the power-of-two octave of their length, each bucket becomes
one ELL structure padded to that bucket's (tile-rounded) max, and the
existing row-split kernel executes each group; a final static row gather
undoes the grouping permutation.  Padding FLOPs drop from
``m * max_len`` to ``sum_g m_g * max_len_g``.

This module is also the registry's extensibility proof: it is wired into
``spmm(method="rowgroup")``, plans, the engine cache, ``python -m
repro.tune`` and ``bench_corpus`` purely through the ``MethodSpec``
registration at the bottom — zero edits to any dispatch site.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from . import ops as _ops
from . import registry as _registry
from .merge_spmm import DEFAULT_T
from .rowsplit_spmm import DEFAULT_TL, TM, ell_slots

# Bucketing memo keyed on the live row_ptr object (the pattern_fingerprint
# idiom): one plan request touches group_rows from resolve_params, the
# structure build, and the inline path — the O(m log m) host argsort runs
# once per live pattern per tl instead of once per touch.
_bucket_memo: dict = {}


def group_rows(row_ptr, tl: int):
    """Bucket rows by the octave of their length (host-side, memoized).

    Returns ``(order, groups)``: ``order`` (m,) — row ids sorted by
    bucket, original order preserved within a bucket — and ``groups``, a
    tuple of ``(m_g, l_g)`` pairs (group row count, tile-rounded group
    pad) covering ``order`` contiguously, shortest rows first.
    """
    import weakref

    key = (id(row_ptr), int(tl))
    memo = _bucket_memo.get(key)
    if memo is not None and memo[0]() is row_ptr:
        return memo[1], memo[2]
    lengths = np.diff(np.asarray(row_ptr))
    m = lengths.shape[0]
    if m == 0:
        order, groups = np.zeros(0, np.int64), ()
    else:
        bucket = np.zeros(m, np.int64)
        nz = lengths > 1
        bucket[nz] = np.ceil(np.log2(lengths[nz])).astype(np.int64)
        order = np.argsort(bucket, kind="stable")
        out = []
        start = 0
        for b in np.unique(bucket):
            rows = order[start:start + int((bucket == b).sum())]
            m_g = rows.shape[0]
            max_len = int(lengths[rows].max()) if m_g else 0
            l_g = max(tl, tl * (-(-max(max_len, 1) // tl)))
            out.append((int(m_g), int(l_g)))
            start += m_g
        groups = tuple(out)
    try:
        ref = weakref.ref(row_ptr,
                          lambda _, k=key: _bucket_memo.pop(k, None))
    except TypeError:           # object not weakref-able: skip the memo
        return order, groups
    _bucket_memo[key] = (ref, order, groups)
    return order, groups


def plan_rowgroup_structure(a, *, tl: int = DEFAULT_TL, tm: int = TM,
                            precomputed=None):
    """Pattern-only structure: one ELL block per length bucket.

    Returns a dict with ``groups`` (a tuple of per-group
    ``{cols, slot_nz}`` dicts, each ``(m_g_pad, l_g)`` like the row-split
    structure) and ``inv_pos`` (m,) — the static gather that maps the
    concatenated per-group outputs back to original row order.  Values
    are re-applied per call via ``slot_nz`` (``merge_spmm.apply_vals``).
    ``precomputed``: an ``(order, groups)`` pair from :func:`group_rows`
    the caller already computed for this ``(pattern, tl)``.
    """
    order, groups = precomputed if precomputed is not None \
        else group_rows(a.row_ptr, tl)
    m = a.m
    out_groups = []
    start = 0
    for m_g, l_g in groups:
        rows = jnp.asarray(order[start:start + m_g], jnp.int32)
        start += m_g
        out_groups.append(ell_slots(a, rows, l_g, tm=tm))
    inv = np.zeros(m, np.int64)
    inv[order] = np.arange(m)
    return dict(groups=tuple(out_groups),
                inv_pos=jnp.asarray(inv, jnp.int32))


def rowgroup_execute_parts(groups_meta: tuple, tl: int, fwd: dict,
                           vals: jax.Array, b: jax.Array, *,
                           tk=None, interpret=None, impl="pallas",
                           epilogue=None, bias=None, residual=None,
                           acc_dtype=None, out_dtype=None):
    """Run the row-split kernel once per group, then un-permute rows.

    ``groups_meta`` is the static ``((m_g, l_g), ...)`` tuple (from
    ``PlanMeta.extra``); ``b (..., k, n) -> (..., m, n)`` with leading
    batch dims handled natively by the per-group executes.

    The ``epilogue``'s bias/activation/scale fuse into the per-group
    kernels (the bias rides permuted into group row order and sliced per
    group); a flagged ``residual`` is indexed in *original* row order, so
    it lands after the un-permuting gather — correct because it is the
    last epilogue term, and the groups then flush in ``acc_dtype`` with
    the single ``out_dtype`` cast deferred past the add.
    """
    ep = epilogue
    adt = jnp.float32 if acc_dtype is None else jnp.dtype(acc_dtype)
    odt = jnp.promote_types(vals.dtype, b.dtype) if out_dtype is None \
        else jnp.dtype(out_dtype)
    group_ep, group_out, bias_perm = None, out_dtype, None
    if ep is not None:
        group_ep = dataclasses.replace(ep, residual=False)
        if group_ep.is_identity():
            group_ep = None
        if ep.residual:
            group_out = adt
        if ep.bias:
            m = fwd["inv_pos"].shape[0]
            bias_perm = jnp.zeros((m,), bias.dtype) \
                .at[fwd["inv_pos"]].set(bias)
    outs = []
    start = 0
    for (m_g, _), gs in zip(groups_meta, fwd["groups"]):
        gb = None if bias_perm is None else bias_perm[start:start + m_g]
        start += m_g
        outs.append(_ops.rowsplit_execute(
            gs, vals, b, m=m_g, tl=tl, tk=tk, interpret=interpret,
            impl=impl, epilogue=group_ep, bias=gb, acc_dtype=acc_dtype,
            out_dtype=group_out))
    if not outs:
        return jnp.zeros(b.shape[:-2] + (0, b.shape[-1]), odt)
    out = jnp.concatenate(outs, axis=-2) if len(outs) > 1 else outs[0]
    out = jnp.take(out, fwd["inv_pos"], axis=-2)
    if ep is not None and ep.residual:
        out = (out + residual.astype(out.dtype)).astype(odt)
    return out


# ----------------------------------------------------- static launch model ---


def launch_models(plan, n, batch, var, tk):
    """Static model of the per-group row-split launches.

    One row-split launch per length bucket.  The residual never fuses
    into the groups (it applies after the un-grouping gather) and a
    flagged residual forces the groups to flush in acc precision
    (``rowgroup_execute_parts`` defers the single out cast past the
    add).
    """
    from .rowsplit_spmm import ell_launch
    ep = var.epilogue
    residual = ep is not None and ep.residual
    odt = var.acc_dtype if residual else (var.out_dtype or var.b_dtype)
    models = []
    for g, gs in enumerate(plan.fwd["groups"]):
        models.append(ell_launch(
            f"rowgroup[g{g}]", plan.meta, tuple(gs["slot_nz"].shape),
            plan.meta.tl, n, batch, var, tk,
            with_bias=ep is not None and ep.bias,
            with_residual=False, out_dtype=odt))
    return models


# --------------------------------------------------- MethodSpec adapters ---


def _reject_l_pad(l_pad) -> None:
    if l_pad is not None:
        raise ValueError(
            "method='rowgroup' derives a pad per row group from the "
            "pattern; a global l_pad override is not supported (use "
            "method='rowsplit' for a single explicit pad).")


def _resolve(a, *, t, tl, l_pad):
    t = DEFAULT_T if t is None else t
    tl = DEFAULT_TL if tl is None else tl
    _reject_l_pad(l_pad)
    _, groups = group_rows(a.row_ptr, tl)
    return t, tl, None, groups


def _build_structure(a, meta):
    return plan_rowgroup_structure(a, tl=meta.tl)


def _execute(meta, fwd, vals, b, *, tk, interpret, impl, epilogue=None,
             bias=None, residual=None, acc_dtype=None, out_dtype=None):
    return rowgroup_execute_parts(meta.extra, meta.tl, fwd, vals, b, tk=tk,
                                  interpret=interpret, impl=impl,
                                  epilogue=epilogue, bias=bias,
                                  residual=residual, acc_dtype=acc_dtype,
                                  out_dtype=out_dtype)


def _inline(a, b, *, t, tl, l_pad, extra, tk, interpret, impl):
    if isinstance(a.row_ptr, jax.core.Tracer) or \
            isinstance(a.col_ind, jax.core.Tracer):
        raise ValueError(
            "rowgroup's length bucketing is a host-side decision and "
            "cannot run on a traced CSR. Build an SpmmPlan outside jit "
            "(repro.engine.get_plan) and pass it through the jitted "
            "function.")
    _reject_l_pad(l_pad)
    tl = DEFAULT_TL if tl is None else tl
    # `extra` (group sizes only — it must stay small and hashable for
    # PlanMeta) cannot carry the row `order` the structure needs, but
    # group_rows is memoized per live pattern, so this re-derivation is
    # an O(1) lookup whenever the caller already resolved the policy.
    order, groups = group_rows(a.row_ptr, tl)
    fwd = plan_rowgroup_structure(a, tl=tl, precomputed=(order, groups))
    return rowgroup_execute_parts(groups, tl, fwd, a.vals, b, tk=tk,
                                  interpret=interpret, impl=impl)


_registry.register_method(_registry.MethodSpec(
    name="rowgroup",
    description="row-grouped ELL (arXiv:1012.2270): rows bucketed by "
                "length octave, each group padded to its own max",
    build_structure=_build_structure,
    execute=_execute,
    inline=_inline,
    resolve_params=_resolve,
    tune_candidates=lambda a, wide: [dict()],
    heuristic_rank=None,          # opt-in: explicit method= or TuneDB hits
    traffic=launch_models,
))
