"""Row-splitting SpMM — Pallas TPU kernel.  Paper §4.1.

TPU adaptation of the paper's warp-per-row kernel:

* The GPU warp's 32 lanes reading 32 consecutive floats of a row-major B row
  become a ``TN=128``-lane slice of B fetched from a VMEM-resident
  ``(TK, TN)`` panel — the dense operand streams through VMEM in K tiles
  with the accumulator carried across them (grid axis ``k_tiles``,
  innermost), so VMEM stays bounded at any ``k``; a leading ``batch`` grid
  axis executes a whole stack of dense operands per dispatch (see
  ``merge_spmm`` for the shared rationale).
* "Equal rows per processor" becomes a grid over ``TM``-row tiles of C; each
  row is processed in batches of ``TL`` nonzeroes, ELL-padded to the tile's
  static bound ``L`` — the TPU manifestation of the paper's Type 2 load
  imbalance: rows shorter than the pad waste *lanes as padding FLOPs*
  instead of diverged threads, and the waste grows with row irregularity
  exactly as in Fig. 4.
* The warp ``__shfl`` broadcast of ``(col_ind, val)`` becomes a VPU
  broadcast of the (TM, TL) index/value tiles across lanes.

Phase 0 (``plan_rowsplit``, plain XLA): scatter CSR into ELL-padded
``(m, L)`` index/value arrays.  This is *runtime scratch within the same
jit*, not a stored format conversion — the input stays CSR (the paper's
headline constraint).
"""
from __future__ import annotations

import functools

import jax
import jax.experimental.pallas.tpu as pltpu
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.csr import CSR

TN = 128
TM = 8
DEFAULT_TL = 16


def ell_slots(a: CSR, rows: jax.Array, l: int, *, tm: int = TM) -> dict:
    """ELL slot block ``{cols, slot_nz}`` for a row subset, padded to tm.

    ``rows`` (r,) int32 selects the rows; each is laid out over ``l``
    slots.  Invalid slots carry ``slot_nz == nnz_pad`` — the sentinel
    that reads the appended zero in ``merge_spmm.apply_vals`` — and the
    column gather is sentinel-extended so a 0-nnz pattern (empty
    ``col_ind``) stays constructible.  Shared by the whole-matrix
    row-split structure and the per-bucket row-grouped structure
    (``rowgroup_spmm``) so the subtle slot/sentinel contract lives once.
    """
    lengths = jnp.diff(a.row_ptr)
    idx = jnp.arange(l, dtype=jnp.int32)
    take = a.row_ptr[rows][:, None] + idx[None, :]         # (r, l)
    valid = idx[None, :] < lengths[rows][:, None]
    safe = jnp.where(valid, take, 0)
    col_ext = jnp.concatenate(
        [a.col_ind, jnp.zeros((1,), a.col_ind.dtype)])
    cols = jnp.where(valid, col_ext[safe], 0)
    slot_nz = jnp.where(valid, take, a.nnz_pad).astype(jnp.int32)
    r = rows.shape[0]
    pad_rows = tm * (-(-r // tm)) - r
    cols = jnp.pad(cols, ((0, pad_rows), (0, 0)))
    slot_nz = jnp.pad(slot_nz, ((0, pad_rows), (0, 0)),
                      constant_values=a.nnz_pad)
    return dict(cols=cols, slot_nz=slot_nz)


def plan_rowsplit_structure(a: CSR, *, l_pad: int, tl: int = DEFAULT_TL,
                            tm: int = TM):
    """Phase 0, pattern-only: ELL slot structure (m_pad, L), L = l_pad↑tl.

    ``l_pad`` must be a static upper bound on the longest row.  Depends only
    on the sparsity pattern; per-call values are re-applied through
    ``slot_nz`` (see ``merge_spmm.apply_vals``) — the plan-once/execute-many
    split of ``repro.core.plan``.
    """
    l = max(tl, tl * (-(-l_pad // tl)))
    rows = jnp.arange(a.m, dtype=jnp.int32)
    return ell_slots(a, rows, l, tm=tm)


def plan_rowsplit(a: CSR, *, l_pad: int, tl: int = DEFAULT_TL,
                  tm: int = TM):
    """Phase 0 with values applied: the single-call (plan-per-call) form."""
    from .merge_spmm import apply_vals
    structure = plan_rowsplit_structure(a, l_pad=l_pad, tl=tl, tm=tm)
    plan = dict(structure)
    plan["vals"] = apply_vals(structure, a.vals)
    return plan


def _rowsplit_kernel(cols_ref, slot_ref, vals_ref, b_ref, *rest,
                     acc_dtype, n_l: int, tk: int, n_k: int, ep):
    from repro.core.epilogue import apply_epilogue
    i = 0
    bias_ref = res_ref = None
    if ep is not None and ep.bias:
        bias_ref, i = rest[i], i + 1
    if ep is not None and ep.residual:
        res_ref, i = rest[i], i + 1
    o_ref, acc_ref = rest[i], rest[i + 1]
    ll = pl.program_id(3)
    kk = pl.program_id(4)

    @pl.when((ll == 0) & (kk == 0))
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    tm, tl = cols_ref.shape
    cols = cols_ref[...].reshape(-1)                       # (tm*tl,)
    # Mask to the columns whose B row is in the resident (TK, TN) panel;
    # the rest accumulate when their panel streams in (see merge_spmm).
    local = cols - kk * tk
    in_panel = (local >= 0) & (local < tk)
    # In-kernel values gather through the ELL slot ids (sentinel nnz_pad
    # reads the operand's zero padding) — no per-call HBM materialization.
    vals = jnp.take(vals_ref[0], slot_ref[...].reshape(-1), axis=0)
    vals = jnp.where(in_panel, vals, 0).astype(acc_dtype)
    bgat = jnp.take(b_ref[0], jnp.where(in_panel, local, 0),
                    axis=0).astype(acc_dtype)              # (tm*tl, TN)
    prod = vals[:, None] * bgat
    acc_ref[...] += prod.reshape(tm, tl, -1).sum(axis=1)

    @pl.when((ll == n_l - 1) & (kk == n_k - 1))
    def _flush():
        r = apply_epilogue(
            acc_ref[...], ep,
            bias_ref[0][:, None] if bias_ref is not None else None,
            res_ref[0] if res_ref is not None else None)
        o_ref[0] = r.astype(o_ref.dtype)


def rowsplit_spmm_pallas(plan: dict, vals: jax.Array, b: jax.Array, *,
                         tm: int = TM, tn: int = TN, tl: int = DEFAULT_TL,
                         tk: int | None = None, interpret: bool = False,
                         acc_dtype=jnp.float32, out_dtype=None,
                         epilogue=None, bias=None,
                         residual=None) -> jax.Array:
    """``b`` is (batch, k, n) with n % tn == 0; plan arrays (m_pad, L).

    ``plan`` is the pattern structure (``plan_rowsplit_structure``);
    ``vals`` the raw (nnz_pad,) values, gathered in-kernel through
    ``slot_nz``.  ``epilogue``/``bias (m_pad,)``/``residual
    (batch, m_pad, n)`` fuse the C tail into the accumulator flush;
    ``acc_dtype``/``out_dtype`` control accumulation and output precision
    (see ``merge_spmm_pallas``).

    Returns (batch, m_pad, n): batch on the leading grid axis, B streamed
    through VMEM in (TK, TN) panels (``k_tiles`` innermost, accumulator
    carried).
    """
    from .merge_spmm import pack_vals, resolve_tk
    batch, k, n = b.shape
    m_pad, l = plan["cols"].shape
    tk, n_k = resolve_tk(k, tk)
    kpad = n_k * tk - k
    if kpad:
        b = jnp.pad(b, ((0, 0), (0, kpad), (0, 0)))
    vals2 = pack_vals(vals, vals.shape[0], tn=tn)
    nv = vals2.shape[1]
    ep = epilogue
    out_dtype = b.dtype if out_dtype is None else out_dtype
    grid = (batch, m_pad // tm, n // tn, l // tl, n_k)
    in_specs = [
        pl.BlockSpec((tm, tl), lambda bb, i, j, ll, kk: (i, ll)),
        pl.BlockSpec((tm, tl), lambda bb, i, j, ll, kk: (i, ll)),
        pl.BlockSpec((1, nv), lambda bb, i, j, ll, kk: (0, 0)),
        pl.BlockSpec((1, tk, tn), lambda bb, i, j, ll, kk: (bb, kk, j)),
    ]
    operands = [plan["cols"], plan["slot_nz"], vals2, b]
    if ep is not None and ep.bias:
        in_specs.append(pl.BlockSpec((1, tm),
                                     lambda bb, i, j, ll, kk: (i, 0)))
        operands.append(bias.reshape(m_pad // tm, tm))
    if ep is not None and ep.residual:
        in_specs.append(pl.BlockSpec((1, tm, tn),
                                     lambda bb, i, j, ll, kk: (bb, i, j)))
        operands.append(residual)
    kernel = functools.partial(_rowsplit_kernel, acc_dtype=acc_dtype,
                               n_l=l // tl, tk=tk, n_k=n_k, ep=ep)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, tm, tn),
                               lambda bb, i, j, ll, kk: (bb, i, j)),
        out_shape=jax.ShapeDtypeStruct((batch, m_pad, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((tm, tn), acc_dtype)],
        interpret=interpret,
    )(*operands)


# ----------------------------------------------------- static launch model ---


def ell_launch(label, meta, slot_shape, tl, n, batch, var, tk, *,
               with_bias, with_residual, out_dtype):
    """One row-split-kernel launch over an (m_pad, L) ELL block — shared
    by the rowsplit method and rowgroup's per-group launches.  Mirrors
    ``rowsplit_spmm_pallas``'s grid/BlockSpec construction block-for-
    block (see ``repro.kernels.introspect``)."""
    from .introspect import KernelBlock, KernelLaunch
    from .merge_spmm import resolve_tk, vals_launch_block
    m_pad, length = slot_shape
    n_l = length // tl
    tk, n_k = resolve_tk(meta.k, tk)
    blocks = [
        KernelBlock("cols", (TM, tl), "int32",
                    lambda bb, i, j, ll, kk: (i, ll), (m_pad, length),
                    "in"),
        KernelBlock("slot_nz", (TM, tl), "int32",
                    lambda bb, i, j, ll, kk: (i, ll), (m_pad, length),
                    "in"),
        vals_launch_block(meta.nnz_pad, var.vals_dtype),
        KernelBlock("b", (1, tk, TN), var.b_dtype,
                    lambda bb, i, j, ll, kk: (bb, kk, j),
                    (batch, n_k * tk, n), "in"),
    ]
    if with_bias:
        blocks.append(KernelBlock(
            "bias", (1, TM), var.b_dtype,
            lambda bb, i, j, ll, kk: (i, 0), (m_pad // TM, TM), "in"))
    if with_residual:
        blocks.append(KernelBlock(
            "residual", (1, TM, TN), var.b_dtype,
            lambda bb, i, j, ll, kk: (bb, i, j),
            (batch, m_pad, n), "in"))
    out = KernelBlock("out", (1, TM, TN), out_dtype,
                      lambda bb, i, j, ll, kk: (bb, i, j),
                      (batch, m_pad, n), "out")
    blocks += [out, KernelBlock("acc", (TM, TN), var.acc_dtype, None,
                                (TM, TN), "scratch")]
    return KernelLaunch(
        label=label,
        grid=(batch, m_pad // TM, n // TN, n_l, n_k),
        blocks=tuple(blocks),
        flush=lambda bb, i, j, ll, kk: ll == n_l - 1 and kk == n_k - 1,
        out=out)


def launch_models(plan, n, batch, var, tk):
    """Static model of ``rowsplit_spmm_pallas``'s one launch."""
    ep = var.epilogue
    return [ell_launch(
        "rowsplit", plan.meta, tuple(plan.fwd["slot_nz"].shape),
        plan.meta.tl, n, batch, var, tk,
        with_bias=ep is not None and ep.bias,
        with_residual=ep is not None and ep.residual,
        out_dtype=var.out_dtype or var.b_dtype)]
