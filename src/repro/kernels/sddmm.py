"""Sampled dense-dense matmul (SDDMM) — Pallas TPU kernel.

The backward pass of ``C = A @ B`` with respect to the CSR values is a
dense-dense product *sampled at the sparsity pattern*:

    dvals[p] = dC[row[p], :] · B[col[p], :]       for each nonzero p.

This is the gather-dot dual of the forward SpMM: instead of gathering B
rows by column index and scattering into C, we gather a dC row and a B row
per nonzero and reduce across the lane axis.  The nonzero stream is chunked
``TQ`` at a time (the same equal-nonzero balancing as the merge kernel —
cost is O(nnz), independent of row distribution, so the backward pass
inherits the paper's load-balance guarantees), and the reduction over the
dense axis n runs as an inner grid dimension with a VMEM accumulator.

Batched execution adds a leading ``batch`` grid axis: ``dc (batch, m, n)``
and ``b (batch, k, n)`` yield per-element dots ``(batch, P, TQ)`` in one
dispatch.  The caller reduces over the batch when the values are shared
across it (``repro.core.spmm``'s batched VJP) — keeping the axis here is
what makes the same kernel serve ``jax.vmap``'s per-element semantics.

Padded nonzeroes must arrive with in-bounds (row, col) = (0, 0); the caller
masks their outputs (``repro.kernels.ops.sddmm``).
"""
from __future__ import annotations

import functools

import jax
import jax.experimental.pallas.tpu as pltpu
import jax.numpy as jnp
from jax.experimental import pallas as pl

TN = 128   # lanes of the dense axis per grid step
TQ = 128   # nonzeroes per chunk


def _sddmm_kernel(rows_ref, cols_ref, dc_ref, b_ref, o_ref, acc_ref, *,
                  n_j: int, acc_dtype):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    rows = rows_ref[0]                                    # (TQ,)
    cols = cols_ref[0]                                    # (TQ,)
    # Row-major coalesced gathers of dC and B rows (lane-contiguous slices).
    dcg = jnp.take(dc_ref[0], rows, axis=0).astype(acc_dtype)     # (TQ, TN)
    bg = jnp.take(b_ref[0], cols, axis=0).astype(acc_dtype)       # (TQ, TN)
    acc_ref[...] += jnp.sum(dcg * bg, axis=1)[None, :]

    @pl.when(j == n_j - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def sddmm_pallas(rows: jax.Array, cols: jax.Array, dc: jax.Array,
                 b: jax.Array, *, tn: int = TN,
                 interpret: bool = False) -> jax.Array:
    """``rows``/``cols`` are (P, TQ) chunked nonzero coordinates; ``dc`` is
    (batch, m, n), ``b`` is (batch, k, n), n % tn == 0.  Returns
    (batch, P, TQ) float32 dots — per batch element; callers with values
    shared across the batch reduce over axis 0 themselves."""
    p, tq = rows.shape
    batch, m, n = dc.shape
    _, k, _ = b.shape
    acc_dtype = jnp.float32
    grid = (batch, p, n // tn)
    kernel = functools.partial(_sddmm_kernel, n_j=n // tn,
                               acc_dtype=acc_dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tq), lambda bb, i, j: (i, 0)),
            pl.BlockSpec((1, tq), lambda bb, i, j: (i, 0)),
            pl.BlockSpec((1, m, tn), lambda bb, i, j: (bb, 0, j)),
            pl.BlockSpec((1, k, tn), lambda bb, i, j: (bb, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, tq), lambda bb, i, j: (bb, i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, p, tq), acc_dtype),
        scratch_shapes=[pltpu.VMEM((1, tq), acc_dtype)],
        interpret=interpret,
    )(rows, cols, dc, b)


# ----------------------------------------------------- static launch model ---


def launch_models(*, nnz_pad, m, k, n, batch, dc_dtype="float32",
                  b_dtype="float32"):
    """Static model of ``sddmm_pallas``'s one launch, as dispatched by
    ``ops.sddmm``: the nonzero stream chunked ``(P, TQ)``, ``dc``/``b``
    lane-padded to ``TN`` multiples, output ``(batch, P, TQ)`` f32.

    This is the backward (values-cotangent) kernel the forward audits
    never stage; ``repro.analysis.access``/``traffic`` pull it in
    explicitly so the ``custom_vjp`` path gets the same coalescing and
    bytes coverage as the forward launches.
    """
    from .introspect import KernelBlock, KernelLaunch
    p = -(-nnz_pad // TQ)
    n_pad = TN * (-(-n // TN))
    n_j = n_pad // TN
    blocks = [
        KernelBlock("rows", (1, TQ), "int32",
                    lambda bb, i, j: (i, 0), (p, TQ), "in"),
        KernelBlock("cols", (1, TQ), "int32",
                    lambda bb, i, j: (i, 0), (p, TQ), "in"),
        KernelBlock("dc", (1, m, TN), dc_dtype,
                    lambda bb, i, j: (bb, 0, j), (batch, m, n_pad), "in"),
        KernelBlock("b", (1, k, TN), b_dtype,
                    lambda bb, i, j: (bb, 0, j), (batch, k, n_pad), "in"),
    ]
    out = KernelBlock("out", (1, 1, TQ), "float32",
                      lambda bb, i, j: (bb, i, 0), (batch, p, TQ), "out")
    blocks += [out, KernelBlock("acc", (1, TQ), "float32", None, (1, TQ),
                                "scratch")]
    return [KernelLaunch(
        label="sddmm", grid=(batch, p, n_j), blocks=tuple(blocks),
        flush=lambda bb, i, j: j == n_j - 1, out=out)]
