"""Causal flash attention — Pallas TPU kernel (beyond-paper hot spot).

The §Roofline tables show every training cell pays a large memory term in
the attention inner loops (online-softmax carries + score blocks).  This
kernel applies the same design principles the paper uses for SpMM:

* the 128-lane dimension is the coalescing unit (head_dim on the lanes),
* the grid streams KV blocks through VMEM while the (q-block × head) C
  tile stays resident — one flush per output tile, like the merge kernel's
  revisit-accumulation,
* the causal band is *skipped structurally*: the KV grid dimension is
  clamped per q-block (no masked-out compute), the banded analogue of
  row-split's "only touch the nonzeroes you own".

Layout: q (b, h, sq, dh), k/v (b, h, skv, dh) — heads pre-broadcast for
GQA by the wrapper (ops-level; the model path keeps using the XLA flash
implementation, this kernel is the TPU serving/training drop-in).
"""
from __future__ import annotations

import functools

import jax
import jax.experimental.pallas.tpu as pltpu
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  bq: int, bk: int, scale: float, n_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # only blocks with kpos_min <= qpos_max survive the grid clamp; the
    # diagonal block still needs the elementwise causal mask
    q = q_ref[0]                                   # (bq, dh)
    k = k_ref[0]                                   # (bk, dh)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    s = jnp.where(qpos >= kpos, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(-1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def launch_models(*, bh: int, s: int, dh: int, bq: int = DEFAULT_BQ,
                  bk: int = DEFAULT_BK, dtype: str = "float32"):
    """Static model of :func:`flash_attention_pallas` (introspect.py) —
    mirrors the BlockSpecs below for the access/traffic analyses."""
    from .introspect import KernelBlock, KernelLaunch
    n_q = s // bq
    n_k = s // bk
    blocks = [
        KernelBlock("q", (1, bq, dh), dtype,
                    lambda b, z, i, j: (b, i, 0), (bh, s, dh), "in"),
        KernelBlock("k", (1, bk, dh), dtype,
                    lambda b, z, i, j: (b, j, 0), (bh, s, dh), "in"),
        KernelBlock("v", (1, bk, dh), dtype,
                    lambda b, z, i, j: (b, j, 0), (bh, s, dh), "in"),
    ]
    out = KernelBlock("o", (1, bq, dh), dtype,
                      lambda b, z, i, j: (b, i, 0), (bh, s, dh), "out")
    blocks += [
        out,
        KernelBlock("m", (bq, 1), "float32", None, (bq, 1), "scratch"),
        KernelBlock("l", (bq, 1), "float32", None, (bq, 1), "scratch"),
        KernelBlock("acc", (bq, dh), "float32", None, (bq, dh),
                    "scratch"),
    ]
    return [KernelLaunch(
        label="flash_attention", grid=(bh, 1, n_q, n_k),
        blocks=tuple(blocks),
        flush=lambda b, z, i, j: j == n_k - 1, out=out)]


def flash_attention_pallas(q, k, v, *, bq: int = DEFAULT_BQ,
                           bk: int = DEFAULT_BK,
                           interpret: bool = False) -> jax.Array:
    """q/k/v (bh, s, dh) with identical head counts (GQA pre-broadcast).

    Causal; s % bq == 0 == s % bk (ops.py pads).  The kv grid dim is NOT
    clamped per-q (Pallas grids are rectangular) but out-of-band blocks
    exit via the mask producing zero updates; structural skipping is done
    by the wrapper slicing the band for long sequences.
    """
    bh, s, dh = q.shape
    scale = dh ** -0.5
    n_q = s // bq
    n_k = s // bk
    grid = (bh, 1, n_q, n_k)
    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, scale=scale,
                               n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, _, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, _, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, _, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda b, _, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running denom
            pltpu.VMEM((bq, dh), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
