"""Grouped (expert) GEMM with merge-based load balancing — Pallas TPU.

The paper's nonzero-split principle applied to MoE: the token→expert routing
matrix is sparse, hot experts are "long rows" (Type 1 imbalance), cold
experts "short rows" (Type 2).  Sorting tokens by expert puts the problem in
CSR order; padding each expert's token count to the token-tile ``TT`` plays
the role of the paper's chunk breaks at CTA boundaries (the group-boundary
analogue of the carry-out fix-up); the grid then assigns an *equal number of
tokens per step*, with the expert's weight block fetched through a
scalar-prefetched dynamic ``index_map`` — load balance is perfect by
construction regardless of the routing distribution.
"""
from __future__ import annotations

import functools

import jax
import jax.experimental.pallas.tpu as pltpu
import jax.numpy as jnp
from jax.experimental import pallas as pl

TT = 64    # tokens per grid step (the merge chunk)
TDN = 128  # output-feature lanes
TDK = 512  # reduction tile


def plan_groups(group_sizes: jax.Array, tokens_pad: int, tt: int = TT):
    """Map each token-block of ``tt`` sorted tokens to its expert.

    ``group_sizes`` (E,) are *padded* group sizes, each a multiple of ``tt``
    and summing to ``tokens_pad`` (callers pad with dummy tokens; see
    models/moe.py).  Returns ``block_expert`` (tokens_pad//tt,) int32.
    """
    n_blocks = tokens_pad // tt
    ends = jnp.cumsum(group_sizes)
    starts = jnp.arange(n_blocks, dtype=group_sizes.dtype) * tt
    return jnp.searchsorted(ends, starts, side="right").astype(jnp.int32)


def _moe_kernel(be_ref, x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(kk == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def launch_models(block_expert, *, tokens: int, d_in: int, d_out: int,
                  n_experts: int, tt: int = TT, tdn: int = TDN,
                  tdk: int = TDK, dtype: str = "float32"):
    """Static model of :func:`moe_group_gemm_pallas` (introspect.py) —
    mirrors the BlockSpecs below for the access/traffic analyses.
    ``block_expert`` is the concrete (host) block→expert stream."""
    import numpy as np

    from .introspect import KernelBlock, KernelLaunch
    be = np.asarray(block_expert)
    n_k = d_in // tdk
    n_b = tokens // tt
    blocks = [
        KernelBlock("block_expert", (n_b,), "int32", None, (n_b,),
                    "scalar"),
        KernelBlock("x", (tt, tdk), dtype,
                    lambda bi, j, kk: (bi, kk), (tokens, d_in), "in"),
        KernelBlock("w", (1, tdk, tdn), dtype,
                    lambda bi, j, kk: (be[bi], kk, j),
                    (n_experts, d_in, d_out), "in"),
    ]
    out = KernelBlock("o", (tt, tdn), dtype,
                      lambda bi, j, kk: (bi, j), (tokens, d_out), "out")
    blocks += [out, KernelBlock("acc", (tt, tdn), "float32", None,
                                (tt, tdn), "scratch")]
    return [KernelLaunch(
        label="moe_gemm", grid=(n_b, d_out // tdn, n_k),
        blocks=tuple(blocks),
        flush=lambda bi, j, kk: kk == n_k - 1, out=out)]


def moe_group_gemm_pallas(x: jax.Array, w: jax.Array,
                          block_expert: jax.Array, *, tt: int = TT,
                          tdn: int = TDN, tdk: int = TDK,
                          interpret: bool = False) -> jax.Array:
    """y[i] = x[i] @ w[expert_of_block(i // tt)].

    x (tokens_pad, d_in), w (E, d_in, d_out); tokens_pad % tt == 0,
    d_in % tdk == 0, d_out % tdn == 0 (ops.py pads).
    """
    tokens, d_in = x.shape
    _, _, d_out = w.shape
    n_k = d_in // tdk
    grid = (tokens // tt, d_out // tdn, n_k)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tt, tdk), lambda bi, j, kk, be: (bi, kk)),
            pl.BlockSpec((1, tdk, tdn), lambda bi, j, kk, be: (be[bi], kk, j)),
        ],
        out_specs=pl.BlockSpec((tt, tdn), lambda bi, j, kk, be: (bi, j)),
        scratch_shapes=[pltpu.VMEM((tt, tdn), jnp.float32)],
    )
    kernel = functools.partial(_moe_kernel, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((tokens, d_out), x.dtype),
        interpret=interpret,
    )(block_expert, x, w)
