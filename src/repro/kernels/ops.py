"""Jitted public wrappers for the Pallas kernels.

Every op handles padding to tile multiples, backend selection (interpret
mode on CPU — the kernel body runs in Python for bit-level validation
against ref.py; compiled Mosaic on real TPUs), and exposes an XLA fallback
(``impl="xla"``) built from the same dataflow for A/B benchmarking.

The plan-execute ops (``merge_execute``/``rowsplit_execute``/``sddmm``)
accept dense operands with arbitrary leading batch dims — ``b (..., k, n)``
folds into the kernels' leading batch grid axis, one dispatch for the whole
stack.  The forward's vmap wrapping is generic now — the method registry's
``registry.execute_op`` wraps any registered method's execute in an
explicit ``jax.custom_batching.custom_vmap`` rule (vmapped batch axis →
native stacked axis instead of tracing into ``pallas_call``); this module
keeps only the wrapped ops the custom-VJP *backward* body needs
(``merge_execute_op`` for the transpose dB plan, ``sddmm_op`` for the
values cotangent).  The raw ops stay plain so forward-only XLA callers
keep ordinary autodiff.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.csr import CSR
from repro.core.epilogue import apply_epilogue
from . import flash_attention as _flash
from . import merge_spmm as _merge
from . import moe_gemm as _moe
from . import ref as _ref
from . import rowsplit_spmm as _rowsplit
from . import sddmm as _sddmm


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pad_axis(x, mult, axis):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _lead_fold(x):
    """Fold leading batch dims of (..., r, n) into one axis: (nb, r, n)."""
    return x.reshape((-1,) + x.shape[-2:])


@functools.partial(jax.jit, static_argnames=("t", "tk", "interpret", "impl"))
def merge_spmm(a: CSR, b: jax.Array, *, t: int | None = None,
               tk: int | None = None, interpret: bool | None = None,
               impl: str = "pallas"):
    """Merge-based SpMM: C = A @ B with equal-nonzero load balancing."""
    t = _merge.DEFAULT_T if t is None else t
    if impl == "xla":
        return _ref.spmm_merge_ref(a, b, t=t)
    if interpret is None:
        interpret = _interpret_default()
    m = a.m
    b2 = _pad_axis(b, _merge.TN, 1)
    structure = _merge.plan_merge_structure(a, t=t)
    m_pad = _merge.TM * (-(-m // _merge.TM))
    out = _merge.merge_spmm_pallas(structure, a.vals, b2[None], m_pad,
                                   tk=tk, interpret=interpret)
    return out[0, :m, : b.shape[1]]


def rowsplit_spmm(a: CSR, b: jax.Array, *, l_pad: int | None = None,
                  tl: int = _rowsplit.DEFAULT_TL, tk: int | None = None,
                  interpret: bool | None = None, impl: str = "pallas"):
    """Row-split SpMM: C = A @ B, one row tile per grid step (ELL-padded).

    ``l_pad``: static max row length.  Outside jit it is derived from the
    concrete row_ptr; under tracing it must be supplied.  A supplied
    ``l_pad`` smaller than the true max row length would silently truncate
    rows, so it is validated whenever the pattern is concrete.
    """
    traced = isinstance(a.row_ptr, jax.core.Tracer)
    max_len = None
    if not traced:
        lengths = np.diff(np.asarray(a.row_ptr))
        max_len = int(lengths.max()) if lengths.size else 0
    if l_pad is None:
        if traced:
            raise ValueError(
                "rowsplit_spmm under trace requires a static l_pad (the max "
                "row length is data-dependent and cannot be derived from a "
                "traced row_ptr). Either pass l_pad= explicitly, or build an "
                "SpmmPlan outside jit — repro.engine.get_plan(a) / "
                "repro.core.plan.build_plan(a) — which captures the static "
                "l_pad once per sparsity pattern and can be passed through "
                "jitted code freely.")
        l_pad = max(max_len, 1)
    elif max_len is not None and l_pad < max_len:
        raise ValueError(
            f"l_pad={l_pad} is smaller than the pattern's longest row "
            f"({max_len} nonzeroes): the ELL layout would silently drop "
            f"nonzeroes and return a wrong C. Pass l_pad >= {max_len}, or "
            "omit l_pad to derive it from the pattern.")
    return _rowsplit_spmm_jit(a, b, l_pad=l_pad, tl=tl, tk=tk,
                              interpret=interpret, impl=impl)


@functools.partial(jax.jit,
                   static_argnames=("l_pad", "tl", "tk", "interpret", "impl"))
def _rowsplit_spmm_jit(a: CSR, b: jax.Array, *, l_pad: int,
                       tl: int = _rowsplit.DEFAULT_TL, tk: int | None = None,
                       interpret: bool | None = None, impl: str = "pallas"):
    if impl == "xla":
        return _ref.spmm_rowsplit_ref(a, b, tl=tl, l_pad=l_pad)
    if interpret is None:
        interpret = _interpret_default()
    b2 = _pad_axis(b, _rowsplit.TN, 1)
    structure = _rowsplit.plan_rowsplit_structure(a, l_pad=l_pad, tl=tl)
    out = _rowsplit.rowsplit_spmm_pallas(structure, a.vals, b2[None], tl=tl,
                                         tk=tk, interpret=interpret)
    return out[0, : a.m, : b.shape[1]]


def _resolve_dtypes(vals, b, acc_dtype, out_dtype):
    """(acc, out) dtypes: f32 accumulation and operand promotion defaults."""
    adt = jnp.float32 if acc_dtype is None else jnp.dtype(acc_dtype)
    odt = jnp.promote_types(vals.dtype, b.dtype) if out_dtype is None \
        else jnp.dtype(out_dtype)
    return adt, odt


def _apply_tail(c, ep, bias, residual):
    """Post-hoc epilogue for the degenerate (kernel-free) early-outs: even
    with no contributing nonzero, ``act(0 + bias) * scale + residual`` is
    generally nonzero and must still be produced."""
    if ep is None:
        return c
    bias_col = bias.astype(c.dtype)[:, None] if ep.bias else None
    return apply_epilogue(c, ep, bias_col, residual if ep.residual else None)


def _pad_epilogue_operands(ep, bias, residual, lead, m, n, m_pad, tn):
    """Kernel-shaped epilogue operands: bias (m,) → (m_pad,); residual
    broadcast over ``lead`` then folded/padded like the dense operand."""
    extra = {}
    if ep is None:
        return extra
    extra["epilogue"] = ep
    if ep.bias:
        extra["bias"] = jnp.pad(bias, (0, m_pad - m))
    if ep.residual:
        res3 = _lead_fold(jnp.broadcast_to(residual, lead + (m, n)))
        res3 = jnp.pad(res3, ((0, 0), (0, m_pad - m), (0, 0)))
        extra["residual"] = _pad_axis(res3, tn, 2)
    return extra


@functools.partial(jax.jit,
                   static_argnames=("m", "tk", "interpret", "impl",
                                    "epilogue", "acc_dtype", "out_dtype"))
def merge_execute(structure: dict, vals: jax.Array, b: jax.Array, *, m: int,
                  tk: int | None = None, interpret: bool | None = None,
                  impl: str = "pallas", epilogue=None, bias=None,
                  residual=None, acc_dtype=None, out_dtype=None):
    """Execute a prebuilt merge structure: C = A @ B with per-call values.

    ``structure`` is the pattern-only plan from
    ``merge_spmm.plan_merge_structure`` (built once per sparsity pattern by
    ``repro.core.plan`` / cached by ``repro.engine``); ``vals`` is the
    (nnz_pad,) value vector of the call, gathered in-kernel through
    ``slot_nz`` — no per-call padded-layout materialization in HBM.  ``b``
    may carry leading batch dims: (..., k, n) → (..., m, n), one kernel
    dispatch overall.

    ``epilogue`` (``repro.core.Epilogue``) fuses ``act(C + bias) * scale +
    residual`` into the accumulator flush; ``bias (m,)`` and ``residual
    (..., m, n)`` (broadcast over the batch) ride per its flags.
    ``acc_dtype`` (default f32) is the accumulation precision, ``out_dtype``
    (default: operand promotion) the single C write.
    """
    lead, n = b.shape[:-2], b.shape[-1]
    adt, odt = _resolve_dtypes(vals, b, acc_dtype, out_dtype)
    ep = epilogue
    if m == 0 or b.shape[-2] == 0:
        # Degenerate 0-row / 0-col pattern: no nonzero contributes — skip
        # the kernel, but the epilogue tail still applies to C = 0.
        c = jnp.zeros(lead + (m, n), adt)
        return _apply_tail(c, ep, bias, residual).astype(odt)
    if impl == "xla":
        res = None if ep is None or not ep.residual else \
            jnp.broadcast_to(residual, lead + (m, n))
        return _ref.merge_execute_ref(
            structure, vals, b, m, _merge.TM, epilogue=ep, bias=bias,
            residual=res, acc_dtype=adt, out_dtype=odt)
    if interpret is None:
        interpret = _interpret_default()
    b3 = _pad_axis(_lead_fold(b), _merge.TN, 2)
    m_pad = _merge.TM * (-(-m // _merge.TM))
    extra = _pad_epilogue_operands(ep, bias, residual, lead, m, n, m_pad,
                                   _merge.TN)
    out = _merge.merge_spmm_pallas(structure, vals, b3, m_pad, tk=tk,
                                   interpret=interpret, acc_dtype=adt,
                                   out_dtype=odt, **extra)
    return out[:, :m, :n].reshape(lead + (m, n))


@functools.partial(jax.jit,
                   static_argnames=("m", "tl", "tk", "interpret", "impl",
                                    "epilogue", "acc_dtype", "out_dtype"))
def rowsplit_execute(structure: dict, vals: jax.Array, b: jax.Array, *,
                     m: int, tl: int = _rowsplit.DEFAULT_TL,
                     tk: int | None = None, interpret: bool | None = None,
                     impl: str = "pallas", epilogue=None, bias=None,
                     residual=None, acc_dtype=None, out_dtype=None):
    """Execute a prebuilt ELL structure: row-split SpMM with per-call values.

    The static ``l_pad`` is baked into the structure's (m_pad, L) shape, so
    this is trace-safe with no l_pad argument.  ``b`` may carry leading
    batch dims: (..., k, n) → (..., m, n).  ``epilogue``/``bias``/
    ``residual`` and ``acc_dtype``/``out_dtype`` as in ``merge_execute``.
    """
    lead, n = b.shape[:-2], b.shape[-1]
    adt, odt = _resolve_dtypes(vals, b, acc_dtype, out_dtype)
    ep = epilogue
    if m == 0 or b.shape[-2] == 0:
        c = jnp.zeros(lead + (m, n), adt)
        return _apply_tail(c, ep, bias, residual).astype(odt)
    if impl == "xla":
        res = None if ep is None or not ep.residual else \
            jnp.broadcast_to(residual, lead + (m, n))
        return _ref.rowsplit_execute_ref(
            structure, vals, b, m, epilogue=ep, bias=bias, residual=res,
            acc_dtype=adt, out_dtype=odt)
    if interpret is None:
        interpret = _interpret_default()
    b3 = _pad_axis(_lead_fold(b), _rowsplit.TN, 2)
    m_pad = structure["cols"].shape[0]
    extra = _pad_epilogue_operands(ep, bias, residual, lead, m, n, m_pad,
                                   _rowsplit.TN)
    out = _rowsplit.rowsplit_spmm_pallas(structure, vals, b3, tl=tl, tk=tk,
                                         interpret=interpret, acc_dtype=adt,
                                         out_dtype=odt, **extra)
    return out[:, :m, :n].reshape(lead + (m, n))


@functools.partial(jax.jit, static_argnames=("interpret", "impl"))
def sddmm(rows: jax.Array, cols: jax.Array, valid: jax.Array, dc: jax.Array,
          b: jax.Array, *, interpret: bool | None = None,
          impl: str = "pallas"):
    """Sampled dense-dense matmul over a pattern: dvals[p] = dC[r_p]·B[c_p].

    ``rows``/``cols`` are per-nonzero coordinates (in-bounds everywhere;
    padded entries masked off by ``valid``).  This is the values-cotangent
    kernel of the differentiable SpMM.  ``dc``/``b`` may carry matching
    leading batch dims, kept per element: (..., m, n) × (..., k, n) →
    (..., nnz_pad); shared-values callers reduce the leading dims.
    """
    lead = dc.shape[:-2]
    nnz_pad = rows.shape[0]
    if nnz_pad == 0 or dc.shape[-2] == 0 or b.shape[-2] == 0:
        # 0-nnz / 0-row / 0-col patterns: every slot is padding — the
        # cotangent is identically zero (and the kernel's (p, tq) chunking
        # has nothing to chunk).
        return jnp.zeros(lead + (nnz_pad,), dc.dtype)
    if impl == "xla":
        return _ref.sddmm_ref(rows, cols, valid, dc, b)
    if interpret is None:
        interpret = _interpret_default()
    tq = _sddmm.TQ
    p = -(-nnz_pad // tq)
    rows2 = _pad_axis(rows, tq, 0).reshape(p, tq)
    cols2 = _pad_axis(cols, tq, 0).reshape(p, tq)
    dc3 = _pad_axis(_lead_fold(dc), _sddmm.TN, 2)
    b3 = _pad_axis(_lead_fold(b), _sddmm.TN, 2)
    out = _sddmm.sddmm_pallas(rows2, cols2, dc3, b3, interpret=interpret)
    dvals = out.reshape(out.shape[0], -1)[:, :nnz_pad]
    return jnp.where(valid, dvals.reshape(lead + (nnz_pad,)),
                     0).astype(dc.dtype)


# ---------------------------------------------------- explicit vmap rules ---
#
# ``jax.custom_batching.custom_vmap`` wrappers over the plan-execute ops.
# A vmapped batch axis on the dense operand(s) is rewritten onto the ops'
# native leading-batch path — i.e. into the kernels' batch grid axis — and
# any other batching (per-element values, batched structures) falls back to
# a sequential ``lax.map``, which is always correct.  custom_vmap does not
# compose with reverse-mode autodiff, so these wrapped forms must only be
# used where autodiff never differentiates through them: the forward and
# backward *bodies* of ``repro.core.spmm``'s custom VJP (which JAX vmaps,
# but never differentiates).


def _vmappable(fn, native_when):
    op = jax.custom_batching.custom_vmap(fn)

    @op.def_vmap
    def _rule(axis_size, in_batched, *args):
        if native_when(in_batched):
            # The batch axis becomes a native leading dim; recursing
            # through ``op`` keeps any remaining outer vmap axes handled.
            return op(*args), True

        def one(i):
            sliced = tuple(
                jax.tree.map(lambda bt, x: x[i] if bt else x, tb, arg)
                for tb, arg in zip(in_batched, args))
            return op(*sliced)

        return jax.lax.map(one, jnp.arange(axis_size)), True

    return op


def _structure_free(tree_batched) -> bool:
    return not any(jax.tree.leaves(tree_batched))


# Bounded: keys embed per-pattern statics (m, k), so an unbounded cache
# would grow with every distinct pattern shape a long-lived server sees.
# Entries are pure functions of the key — eviction just rebuilds the thin
# wrapper; the jitted ops underneath keep their stable identity.
_OP_CACHE_SIZE = 512


@functools.lru_cache(maxsize=_OP_CACHE_SIZE)
def merge_execute_op(m: int, tk: int | None, interpret: bool | None,
                     impl: str):
    """``merge_execute`` with an explicit vmap rule (statics closed over)."""
    fn = lambda structure, vals, b: merge_execute(
        structure, vals, b, m=m, tk=tk, interpret=interpret, impl=impl)

    def native(in_batched):
        st, va, bb = in_batched
        return bb and not va and _structure_free(st)

    return _vmappable(fn, native)


@functools.lru_cache(maxsize=_OP_CACHE_SIZE)
def sddmm_op(interpret: bool | None, impl: str):
    """``sddmm`` with an explicit vmap rule.

    Native when both dense operands batch together (the kernel keeps the
    axis per element, exactly vmap's semantics); coordinate batching falls
    back to the sequential map.
    """
    fn = lambda rows, cols, valid, dc, b: sddmm(
        rows, cols, valid, dc, b, interpret=interpret, impl=impl)

    def native(in_batched):
        rr, cc, vv, dcb, bb = in_batched
        return dcb and bb and not (rr or cc or vv)

    return _vmappable(fn, native)


def moe_group_gemm(x: jax.Array, w: jax.Array, group_sizes: jax.Array, *,
                   tt: int = _moe.TT, interpret: bool | None = None,
                   impl: str = "pallas"):
    """Grouped GEMM over expert-sorted tokens (merge-based balancing).

    x (tokens_pad, d_in) sorted by expert; w (E, d_in, d_out);
    group_sizes (E,) padded sizes, multiples of ``tt``, summing to
    tokens_pad.
    """
    if interpret is None:
        interpret = _interpret_default()
    tokens, d_in = x.shape
    e, _, d_out = w.shape
    if impl == "xla":
        block_expert = _moe.plan_groups(group_sizes, tokens, tt)
        token_expert = jnp.repeat(block_expert, tt, total_repeat_length=tokens)
        return _ref.moe_group_gemm_ref(x, w, token_expert)
    assert tokens % tt == 0
    x2 = _pad_axis(x, _moe.TDK, 1)
    w2 = _pad_axis(_pad_axis(w, _moe.TDK, 1), _moe.TDN, 2)
    block_expert = _moe.plan_groups(group_sizes, tokens, tt)
    out = _moe.moe_group_gemm_pallas(x2, w2, block_expert, tt=tt,
                                     interpret=interpret)
    return out[:, :d_out]


@functools.partial(jax.jit, static_argnames=("bq", "bk", "interpret"))
def flash_attention(q, k, v, *, bq: int = _flash.DEFAULT_BQ,
                    bk: int = _flash.DEFAULT_BK,
                    interpret: bool | None = None):
    """Causal flash attention via the Pallas kernel.

    q (b, s, h, dh); k/v (b, s, kv, dh) with h % kv == 0 — KV heads are
    broadcast to the query heads (GQA), then (b, h) folds into the grid's
    batch dimension.  Sequence is padded to the block size (padded queries
    are discarded; padded keys sit in the causal future and are masked).
    """
    if interpret is None:
        interpret = _interpret_default()
    b, s, h, dh = q.shape
    kvh = k.shape[2]
    g = h // kvh
    kb = jnp.repeat(k, g, axis=2) if g > 1 else k
    vb = jnp.repeat(v, g, axis=2) if g > 1 else v
    fold = lambda x: x.transpose(0, 2, 1, 3).reshape(b * h, s, dh)
    qf, kf, vf = fold(q), fold(kb), fold(vb)
    pad = (-s) % max(bq, bk)
    if pad:
        qf = jnp.pad(qf, ((0, 0), (0, pad), (0, 0)))
        kf = jnp.pad(kf, ((0, 0), (0, pad), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad), (0, 0)))
    out = _flash.flash_attention_pallas(qf, kf, vf, bq=bq, bk=bk,
                                        interpret=interpret)
    out = out[:, :s]
    return out.reshape(b, h, s, dh).transpose(0, 2, 1, 3)
