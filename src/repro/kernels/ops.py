"""Jitted public wrappers for the Pallas kernels.

Every op handles padding to tile multiples, backend selection (interpret
mode on CPU — the kernel body runs in Python for bit-level validation
against ref.py; compiled Mosaic on real TPUs), and exposes an XLA fallback
(``impl="xla"``) built from the same dataflow for A/B benchmarking.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.csr import CSR
from . import flash_attention as _flash
from . import merge_spmm as _merge
from . import moe_gemm as _moe
from . import ref as _ref
from . import rowsplit_spmm as _rowsplit
from . import sddmm as _sddmm


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pad_axis(x, mult, axis):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("t", "interpret", "impl"))
def merge_spmm(a: CSR, b: jax.Array, *, t: int = _merge.DEFAULT_T,
               interpret: bool | None = None, impl: str = "pallas"):
    """Merge-based SpMM: C = A @ B with equal-nonzero load balancing."""
    if impl == "xla":
        return _ref.spmm_merge_ref(a, b, t=t)
    if interpret is None:
        interpret = _interpret_default()
    m = a.m
    b2 = _pad_axis(b, _merge.TN, 1)
    plan = _merge.plan_merge(a, t=t)
    m_pad = _merge.TM * (-(-m // _merge.TM))
    out = _merge.merge_spmm_pallas(plan, b2, m_pad, interpret=interpret)
    return out[:m, : b.shape[1]]


def rowsplit_spmm(a: CSR, b: jax.Array, *, l_pad: int | None = None,
                  tl: int = _rowsplit.DEFAULT_TL,
                  interpret: bool | None = None, impl: str = "pallas"):
    """Row-split SpMM: C = A @ B, one row tile per grid step (ELL-padded).

    ``l_pad``: static max row length.  Outside jit it is derived from the
    concrete row_ptr; under tracing it must be supplied.
    """
    if l_pad is None:
        if isinstance(a.row_ptr, jax.core.Tracer):
            raise ValueError(
                "rowsplit_spmm under trace requires a static l_pad (the max "
                "row length is data-dependent and cannot be derived from a "
                "traced row_ptr). Either pass l_pad= explicitly, or build an "
                "SpmmPlan outside jit — repro.engine.get_plan(a) / "
                "repro.core.plan.build_plan(a) — which captures the static "
                "l_pad once per sparsity pattern and can be passed through "
                "jitted code freely.")
        l_pad = int(np.max(np.diff(np.asarray(a.row_ptr)))) if a.m else 1
        l_pad = max(l_pad, 1)
    return _rowsplit_spmm_jit(a, b, l_pad=l_pad, tl=tl, interpret=interpret,
                              impl=impl)


@functools.partial(jax.jit,
                   static_argnames=("l_pad", "tl", "interpret", "impl"))
def _rowsplit_spmm_jit(a: CSR, b: jax.Array, *, l_pad: int,
                       tl: int = _rowsplit.DEFAULT_TL,
                       interpret: bool | None = None, impl: str = "pallas"):
    if impl == "xla":
        return _ref.spmm_rowsplit_ref(a, b, tl=tl, l_pad=l_pad)
    if interpret is None:
        interpret = _interpret_default()
    b2 = _pad_axis(b, _rowsplit.TN, 1)
    plan = _rowsplit.plan_rowsplit(a, l_pad=l_pad, tl=tl)
    out = _rowsplit.rowsplit_spmm_pallas(plan, b2, tl=tl, interpret=interpret)
    return out[: a.m, : b.shape[1]]


@functools.partial(jax.jit, static_argnames=("m", "interpret", "impl"))
def merge_execute(structure: dict, vals: jax.Array, b: jax.Array, *, m: int,
                  interpret: bool | None = None, impl: str = "pallas"):
    """Execute a prebuilt merge structure: C = A @ B with per-call values.

    ``structure`` is the pattern-only plan from
    ``merge_spmm.plan_merge_structure`` (built once per sparsity pattern by
    ``repro.core.plan`` / cached by ``repro.engine``); ``vals`` is the
    (nnz_pad,) value vector of the call.  No planning happens here — only a
    single slot gather plus the phase-2 kernel.
    """
    chunk_vals = _merge.apply_vals(structure, vals)
    if impl == "xla":
        return _ref.merge_execute_ref(structure, chunk_vals, b, m, _merge.TM)
    if interpret is None:
        interpret = _interpret_default()
    b2 = _pad_axis(b, _merge.TN, 1)
    m_pad = _merge.TM * (-(-m // _merge.TM))
    plan = dict(structure)
    plan["vals"] = chunk_vals
    out = _merge.merge_spmm_pallas(plan, b2, m_pad, interpret=interpret)
    return out[:m, : b.shape[1]]


@functools.partial(jax.jit, static_argnames=("m", "tl", "interpret", "impl"))
def rowsplit_execute(structure: dict, vals: jax.Array, b: jax.Array, *,
                     m: int, tl: int = _rowsplit.DEFAULT_TL,
                     interpret: bool | None = None, impl: str = "pallas"):
    """Execute a prebuilt ELL structure: row-split SpMM with per-call values.

    The static ``l_pad`` is baked into the structure's (m_pad, L) shape, so
    this is trace-safe with no l_pad argument.
    """
    ell_vals = _merge.apply_vals(structure, vals)
    if impl == "xla":
        return _ref.rowsplit_execute_ref(structure, ell_vals, b, m)
    if interpret is None:
        interpret = _interpret_default()
    b2 = _pad_axis(b, _rowsplit.TN, 1)
    plan = dict(structure)
    plan["vals"] = ell_vals
    out = _rowsplit.rowsplit_spmm_pallas(plan, b2, tl=tl, interpret=interpret)
    return out[:m, : b.shape[1]]


@functools.partial(jax.jit, static_argnames=("interpret", "impl"))
def sddmm(rows: jax.Array, cols: jax.Array, valid: jax.Array, dc: jax.Array,
          b: jax.Array, *, interpret: bool | None = None,
          impl: str = "pallas"):
    """Sampled dense-dense matmul over a pattern: dvals[p] = dC[r_p]·B[c_p].

    ``rows``/``cols`` are per-nonzero coordinates (in-bounds everywhere;
    padded entries masked off by ``valid``).  This is the values-cotangent
    kernel of the differentiable SpMM.
    """
    if impl == "xla":
        return _ref.sddmm_ref(rows, cols, valid, dc, b)
    if interpret is None:
        interpret = _interpret_default()
    nnz_pad = rows.shape[0]
    tq = _sddmm.TQ
    p = max(1, -(-nnz_pad // tq))
    rows2 = _pad_axis(rows, tq, 0).reshape(p, tq)
    cols2 = _pad_axis(cols, tq, 0).reshape(p, tq)
    dc2 = _pad_axis(dc, _sddmm.TN, 1)
    b2 = _pad_axis(b, _sddmm.TN, 1)
    out = _sddmm.sddmm_pallas(rows2, cols2, dc2, b2, interpret=interpret)
    dvals = out.reshape(-1)[:nnz_pad]
    return jnp.where(valid, dvals, 0).astype(dc.dtype)


def moe_group_gemm(x: jax.Array, w: jax.Array, group_sizes: jax.Array, *,
                   tt: int = _moe.TT, interpret: bool | None = None,
                   impl: str = "pallas"):
    """Grouped GEMM over expert-sorted tokens (merge-based balancing).

    x (tokens_pad, d_in) sorted by expert; w (E, d_in, d_out);
    group_sizes (E,) padded sizes, multiples of ``tt``, summing to
    tokens_pad.
    """
    if interpret is None:
        interpret = _interpret_default()
    tokens, d_in = x.shape
    e, _, d_out = w.shape
    if impl == "xla":
        block_expert = _moe.plan_groups(group_sizes, tokens, tt)
        token_expert = jnp.repeat(block_expert, tt, total_repeat_length=tokens)
        return _ref.moe_group_gemm_ref(x, w, token_expert)
    assert tokens % tt == 0
    x2 = _pad_axis(x, _moe.TDK, 1)
    w2 = _pad_axis(_pad_axis(w, _moe.TDK, 1), _moe.TDN, 2)
    block_expert = _moe.plan_groups(group_sizes, tokens, tt)
    out = _moe.moe_group_gemm_pallas(x2, w2, block_expert, tt=tt,
                                     interpret=interpret)
    return out[:, :d_out]


@functools.partial(jax.jit, static_argnames=("bq", "bk", "interpret"))
def flash_attention(q, k, v, *, bq: int = _flash.DEFAULT_BQ,
                    bk: int = _flash.DEFAULT_BK,
                    interpret: bool | None = None):
    """Causal flash attention via the Pallas kernel.

    q (b, s, h, dh); k/v (b, s, kv, dh) with h % kv == 0 — KV heads are
    broadcast to the query heads (GQA), then (b, h) folds into the grid's
    batch dimension.  Sequence is padded to the block size (padded queries
    are discarded; padded keys sit in the causal future and are masked).
    """
    if interpret is None:
        interpret = _interpret_default()
    b, s, h, dh = q.shape
    kvh = k.shape[2]
    g = h // kvh
    kb = jnp.repeat(k, g, axis=2) if g > 1 else k
    vb = jnp.repeat(v, g, axis=2) if g > 1 else v
    fold = lambda x: x.transpose(0, 2, 1, 3).reshape(b * h, s, dh)
    qf, kf, vf = fold(q), fold(kb), fold(vb)
    pad = (-s) % max(bq, bk)
    if pad:
        qf = jnp.pad(qf, ((0, 0), (0, pad), (0, 0)))
        kf = jnp.pad(kf, ((0, 0), (0, pad), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad), (0, 0)))
    out = _flash.flash_attention_pallas(qf, kf, vf, bq=bq, bk=bk,
                                        interpret=interpret)
    out = out[:, :s]
    return out.reshape(b, h, s, dh).transpose(0, 2, 1, 3)
