"""Static launch models: each kernel describes its own ``pallas_call``.

A :class:`KernelLaunch` is a host-side, numerically enumerable model of
one ``pallas_call`` — the grid, every BlockSpec (shape, dtype, index
map over grid points, full operand shape, in/out/scratch/scalar kind)
and the accumulator-flush predicate.  Each kernel module exports a
``launch_models(plan, n, batch, var, tk)`` hook built from these (wired
into the registry through ``MethodSpec.traffic``), so the static
analyses — the kernel audit's VMEM/bounds/single-writer checks
(``repro.analysis.kernel_audit``), the coalescing checker
(``repro.analysis.access``) and the bytes-moved analyzer
(``repro.analysis.traffic``) — all read one model that lives next to
the ``pl.BlockSpec`` lines it mirrors.

``var`` is any object with ``vals_dtype``/``b_dtype``/``acc_dtype``/
``out_dtype``/``epilogue`` attributes (e.g. ``kernel_audit.Variant``).
"""
from __future__ import annotations

import dataclasses
from collections.abc import Callable

import numpy as np


@dataclasses.dataclass(frozen=True)
class KernelBlock:
    """One BlockSpec of a modeled launch (or a scratch/scalar operand)."""

    name: str
    shape: tuple                 # block shape
    dtype: str
    index_map: Callable | None   # grid point -> block index, or None
    array_shape: tuple           # full operand shape
    kind: str                    # "in" | "out" | "scratch" | "scalar"

    def nbytes(self) -> int:
        import jax.numpy as jnp
        n = int(np.prod(self.shape)) if self.shape else 1
        return n * jnp.dtype(self.dtype).itemsize

    def array_nbytes(self) -> int:
        import jax.numpy as jnp
        n = int(np.prod(self.array_shape)) if self.array_shape else 1
        return n * jnp.dtype(self.dtype).itemsize


@dataclasses.dataclass(frozen=True)
class KernelLaunch:
    """A statically checkable model of one ``pallas_call``."""

    label: str
    grid: tuple
    blocks: tuple                # KernelBlock, ... (includes the out block)
    flush: Callable              # grid point -> bool (writes out block?)
    out: KernelBlock

    def vmem_bytes(self) -> int:
        """Modeled VMEM residency: in/out blocks double-buffered (the
        Mosaic DMA pipeline), scratch and scalar-prefetch counted once."""
        total = 0
        for b in self.blocks:
            total += b.nbytes() * (2 if b.kind in ("in", "out") else 1)
        return total

    def hbm_bytes(self) -> int:
        """Transition-counted DMA traffic of the launch.

        Walks the grid in lexicographic order (last axis fastest — the
        Pallas TPU iteration order) and counts an input-block fetch only
        when its block index differs from the previous step's (Mosaic
        elides the copy when the index is unchanged).  Output tiles are
        written at each flush point; scalar-prefetch operands are read
        once, whole; scratch never touches HBM.
        """
        total = 0
        for blk in self.blocks:
            if blk.kind == "scalar":
                total += blk.array_nbytes()
            elif blk.kind == "in":
                total += self._fetches(blk) * blk.nbytes()
        writes = sum(1 for p in np.ndindex(*self.grid) if self.flush(*p))
        return total + writes * self.out.nbytes()

    def _fetches(self, blk: KernelBlock) -> int:
        if blk.index_map is None:
            return 1
        fetches, prev = 0, None
        for point in np.ndindex(*self.grid):
            idx = tuple(int(i) for i in blk.index_map(*point))
            if idx != prev:
                fetches += 1
                prev = idx
        return fetches
