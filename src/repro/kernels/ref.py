"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth for the allclose sweeps in tests/ and the
"paper-faithful dataflow in plain XLA" baselines for the benchmarks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.csr import CSR
from repro.core.epilogue import apply_epilogue
from repro.core.partition import chunk_segments, partition_spmm


def spmm_dense_ref(a: CSR, b: jax.Array) -> jax.Array:
    """Densify-and-matmul oracle (small matrices only)."""
    return a.to_dense() @ b


def spmm_gather_ref(a: CSR, b: jax.Array) -> jax.Array:
    """Gather/segment-sum oracle: the CSR dataflow with no blocking at all."""
    _, nnz_rows = partition_spmm(a, t=max(a.nnz_pad, 1))
    prods = a.vals[:, None] * b[a.col_ind]          # (nnz_pad, n)
    return jax.ops.segment_sum(prods, nnz_rows, num_segments=a.m)


def spmm_rowsplit_ref(a: CSR, b: jax.Array, tl: int = 8,
                      l_pad: int | None = None) -> jax.Array:
    """Row-split dataflow reference (paper §4.1), ELL-style padded rows.

    Every row is processed in batches of ``tl`` nonzeroes — the paper's
    "effective number of independent instructions is sensitive to row
    lengths that do not divide 32" (here: that do not divide ``tl``).
    ``l_pad`` is a static upper bound on the row length (defaults to the
    worst case, the whole nnz capacity — callers with host knowledge of the
    max row length should pass it).
    """
    lengths = a.row_lengths()
    if l_pad is None:
        l_pad = int(a.nnz_pad)
    l_pad = max(tl, tl * (-(-l_pad // tl)))
    idx = jnp.arange(l_pad)
    take = a.row_ptr[:-1, None] + idx[None, :]                # (m, l_pad)
    valid = idx[None, :] < lengths[:, None]
    take = jnp.where(valid, take, 0)
    cols = jnp.where(valid, a.col_ind[take], 0)
    vals = jnp.where(valid, a.vals[take], 0)
    return jnp.einsum("ml,mln->mn", vals, b[cols])


def spmm_merge_ref(a: CSR, b: jax.Array, t: int = 8) -> jax.Array:
    """Merge-based (nonzero-split) dataflow reference (paper §4.2).

    Phase 1: equal-nonzero partition.  Phase 2: per-chunk gather + multiply +
    intra-chunk segmented sum.  Epilogue: scatter-add partials into C (the
    carry-out fix-up).
    """
    _, nnz_rows = partition_spmm(a, t)
    rows, local, seg_rows = chunk_segments(nnz_rows, t, a.m)
    n_chunks = rows.shape[0]
    pad = n_chunks * t - a.nnz_pad
    cols = jnp.pad(a.col_ind, (0, pad)).reshape(n_chunks, t)
    vals = jnp.pad(a.vals, (0, pad)).reshape(n_chunks, t)
    prods = vals[..., None] * b[cols]                        # (chunks, t, n)
    # Intra-chunk segmented reduction over the local segment axis.
    onehot = (local[..., None] == jnp.arange(t)[None, None, :])
    partials = jnp.einsum("cts,ctn->csn", onehot.astype(prods.dtype), prods)
    return jax.ops.segment_sum(
        partials.reshape(n_chunks * t, -1), seg_rows.reshape(-1),
        num_segments=a.m)


def _map_leading(one, *stacked):
    """Apply a 2-D-operand reference over folded leading batch dims.

    ``lax.map`` (scan) rather than vmap/moveaxis: the Pallas kernels
    serialize the batch grid axis on a core, so the faithful XLA twin
    iterates batch elements inside one computation too — per-element
    working set, one dispatch — instead of materializing a batch-wide
    gathered intermediate.
    """
    lead = stacked[0].shape[:-2]
    flat = [x.reshape((-1,) + x.shape[-2:]) for x in stacked]
    out = jax.lax.map(one, tuple(flat)) if len(flat) > 1 else \
        jax.lax.map(one, flat[0])
    return out.reshape(lead + out.shape[1:])


def _slot_gather(structure: dict, vals: jax.Array) -> jax.Array:
    """Per-slot values through ``slot_nz`` (sentinel → appended zero) —
    the XLA twin of the kernels' in-kernel gather."""
    vals_ext = jnp.concatenate([vals, jnp.zeros((1,), vals.dtype)])
    return vals_ext[structure["slot_nz"]]


def _finish(out, ep, bias_col, res2, out_dtype):
    return apply_epilogue(out, ep, bias_col, res2).astype(out_dtype)


def merge_execute_ref(structure: dict, vals: jax.Array, b: jax.Array,
                      m: int, tm: int, *, epilogue=None, bias=None,
                      residual=None, acc_dtype=jnp.float32,
                      out_dtype=None) -> jax.Array:
    """Plan-execute reference for the merge structure (differentiable XLA).

    Same dataflow as ``merge_spmm_pallas`` on a prebuilt pattern structure:
    gather the raw ``vals`` into chunk slots (``slot_nz``), gather B rows
    per slot, multiply, scatter into C by (tile, lrow) — all in
    ``acc_dtype`` — then apply the fused ``epilogue`` identically to the
    kernel's accumulator flush and cast once to ``out_dtype``.  Unused
    slots carry value 0 and scatter 0.  ``b`` may carry leading batch dims
    — (..., k, n) → (..., m, n), matching the batched kernel grid
    (K-tiling is a VMEM-residency concern with no XLA analogue: the
    compiler owns the streaming here); a flagged ``residual`` batches with
    it.
    """
    acc = jnp.dtype(acc_dtype)
    odt = jnp.promote_types(vals.dtype, b.dtype) if out_dtype is None \
        else jnp.dtype(out_dtype)
    ep = epilogue
    chunk_vals = _slot_gather(structure, vals).astype(acc)
    bias_col = bias.astype(acc)[:, None] \
        if ep is not None and ep.bias else None

    def one(b2, res2=None):
        prods = chunk_vals[..., None] * b2.astype(acc)[structure["cols"]]
        rows = structure["tile"][:, None] * tm + structure["lrow"]
        m_pad = tm * (-(-m // tm))
        out = jax.ops.segment_sum(prods.reshape(-1, b2.shape[-1]),
                                  rows.reshape(-1), num_segments=m_pad)
        return _finish(out[:m], ep, bias_col, res2, odt)

    if b.ndim == 2:
        return one(b, residual)
    if ep is not None and ep.residual:
        return _map_leading(lambda args: one(*args), b, residual)
    return _map_leading(one, b)


def rowsplit_execute_ref(structure: dict, vals: jax.Array,
                         b: jax.Array, m: int, *, epilogue=None, bias=None,
                         residual=None, acc_dtype=jnp.float32,
                         out_dtype=None) -> jax.Array:
    """Plan-execute reference for the ELL structure (differentiable XLA).

    Raw ``vals`` gathered through ``slot_nz`` like the kernel; batched
    like it too: ``b (..., k, n) → (..., m, n)``; fused ``epilogue`` and
    ``acc_dtype``/``out_dtype`` as in ``merge_execute_ref``.
    """
    acc = jnp.dtype(acc_dtype)
    odt = jnp.promote_types(vals.dtype, b.dtype) if out_dtype is None \
        else jnp.dtype(out_dtype)
    ep = epilogue
    ell_vals = _slot_gather(structure, vals).astype(acc)
    bias_col = bias.astype(acc)[:, None] \
        if ep is not None and ep.bias else None

    def one(b2, res2=None):
        out = jnp.einsum("ml,mln->mn", ell_vals,
                         b2.astype(acc)[structure["cols"]])[:m]
        return _finish(out, ep, bias_col, res2, odt)

    if b.ndim == 2:
        return one(b, residual)
    if ep is not None and ep.residual:
        return _map_leading(lambda args: one(*args), b, residual)
    return _map_leading(one, b)


def sddmm_ref(rows: jax.Array, cols: jax.Array, valid: jax.Array,
              dc: jax.Array, b: jax.Array) -> jax.Array:
    """Gather-dot oracle for the sampled dense-dense product.

    ``dvals[..., p] = dC[..., rows[p], :] · B[..., cols[p], :]`` masked by
    ``valid`` — the cotangent of the CSR values under C = A @ B.  Leading
    batch dims are kept per element (shared-values callers reduce them).
    """
    def one(args):
        dc2, b2 = args
        dots = jnp.sum(dc2[rows] * b2[cols], axis=-1)
        return jnp.where(valid, dots, 0).astype(dc.dtype)

    if dc.ndim == 2:
        return one((dc, b))
    return _map_leading(one, dc, b)


def moe_group_gemm_ref(x_sorted: jax.Array, w: jax.Array,
                       group_ids: jax.Array) -> jax.Array:
    """Grouped GEMM oracle: y[i] = x_sorted[i] @ w[group_ids[i]].

    ``x_sorted`` (tokens, d_in) is sorted by expert, ``group_ids`` (tokens,)
    gives each token's expert, ``w`` (experts, d_in, d_out).
    """
    return jnp.einsum("td,tdo->to", x_sorted, w[group_ids])
