# Submodules only — the jit'd wrappers live in ops (kernels.ops.merge_spmm
# etc.); re-exporting them here would shadow the kernel modules themselves.
from . import merge_spmm, moe_gemm, ops, ref, rowsplit_spmm, sddmm

__all__ = ["merge_spmm", "moe_gemm", "ops", "ref", "rowsplit_spmm", "sddmm"]
