# Submodules only — the jit'd wrappers live in ops (kernels.ops.merge_spmm
# etc.); re-exporting them here would shadow the kernel modules themselves.
# Importing registry/rowgroup_spmm here is what registers the built-in and
# row-grouped methods: `from repro.kernels import registry` always sees a
# fully populated method table.
from . import (merge_spmm, moe_gemm, ops, ref, registry, rowgroup_spmm,
               rowsplit_spmm, sddmm)

__all__ = ["merge_spmm", "moe_gemm", "ops", "ref", "registry",
           "rowgroup_spmm", "rowsplit_spmm", "sddmm"]
