"""Merge-based (nonzero-split) SpMM — Pallas TPU kernel.  Paper §4.2.

TPU adaptation of the paper's two-phase decomposition:

* **Phase 1** (``plan_merge``, plain XLA): assign an equal number ``T`` of
  nonzeroes per chunk, *breaking chunks at output row-tile boundaries* so
  every chunk's rows live in exactly one ``TM``-row tile of C.  This is the
  paper's ``PartitionSpmm`` binary search; the tile-boundary break replaces
  the GPU carry-out machinery (CTAs that cannot synchronize must ship
  boundary rows through global memory — Pallas grid steps execute in order
  on a core, so a revisited output block simply stays resident in VMEM and
  accumulates, and the fix-up kernel disappears).

* **Phase 2** (``_merge_kernel``): grid ``(batch, n_tiles, chunks,
  k_tiles)``.  Each step gathers the ``T`` B rows named by the chunk's
  column indices from a VMEM-resident ``(TK, TN)`` panel of B — the TPU
  analogue of the paper's row-major coalesced loads (lane-contiguous row
  slices) — multiplies by the chunk's values, and scatter-adds into the
  ``(TM, TN)`` C tile through a one-hot ``(T, TM)`` matmul on the MXU.  The
  chunk stream is ordered by row tile, so C tiles are revisited
  consecutively and flushed exactly once.

Two grid axes beyond the paper's decomposition:

* **batch** (leading): one plan executes a whole stack of dense operands
  ``B (batch, k, n)`` in a single dispatch — the plan-once/execute-many
  serving regime with the batch folded into the grid instead of a Python
  loop of launches.
* **k_tiles** (innermost): the dense operand streams through VMEM in
  ``(TK, TN)`` panels with the accumulator carried across tiles, so VMEM
  stays bounded at any ``k`` (``d_in``) instead of pinning the whole
  ``(k, TN)`` panel.  Column indices outside the resident panel are masked
  per tile; when ``k <= DEFAULT_TK_MAX`` a single tile covers all of ``k``
  and the dataflow (and bit pattern) is exactly the unsplit kernel's.

Latency hiding: the paper's ILP (32 independent loads per thread) becomes
Mosaic's double-buffered DMA pipeline across grid steps plus ``T``
independent VMEM gathers inside a step.  Occupancy (TLP) becomes grid size.
"""
from __future__ import annotations

import functools

import jax
import jax.experimental.pallas.tpu as pltpu
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.csr import CSR, rows_from_row_ptr
from repro.core.epilogue import apply_epilogue

# Default tile sizes: TN = 128 lanes (the "warp width" / coalescing unit),
# TM = 8 sublanes, T = nonzeroes per chunk (the paper's blockDim.x work unit).
TN = 128
TM = 8
DEFAULT_T = 16
# K-tile cap: the B panel streams through VMEM in (TK, TN) blocks.  At the
# default, a float32 panel is 1024*128*4 = 512 KiB per buffer (~1 MiB double
# buffered) — bounded regardless of d_in, where the old whole-(k, TN) panel
# hit 4 MiB at k=8k and overflowed VMEM entirely at Qwen2-72B's d_in=29568.
DEFAULT_TK_MAX = 1024


def resolve_tk(k: int, tk: int | None, *, sub: int = 8) -> tuple[int, int]:
    """Resolve the K-tile size: returns ``(tk, n_k)``.

    ``tk`` is clamped to a sublane multiple and to the (padded) ``k``;
    ``None`` picks the whole of ``k`` up to ``DEFAULT_TK_MAX``, so small
    operands keep the single-panel dataflow bit-for-bit while large ``k``
    streams in bounded panels.
    """
    k_pad = max(sub, sub * (-(-k // sub)))
    if tk is None:
        tk = min(k_pad, DEFAULT_TK_MAX)
    else:
        tk = min(max(sub, sub * (-(-tk // sub))), k_pad)
    return tk, -(-k_pad // tk)


def plan_merge_structure(a: CSR, *, t: int = DEFAULT_T, tm: int = TM):
    """Phase 1, pattern-only: equal-nonzero chunks broken at TM-row tiles.

    Depends only on the sparsity pattern (``row_ptr``/``col_ind``), never on
    ``vals`` — the plan-once/execute-many split: values are re-applied per
    call through ``slot_nz`` while the chunk structure is built once per
    pattern (``repro.core.plan``).

    Returns a dict of device arrays (all static-shaped):
      cols    (C, t) int32  column index of each nonzero in each chunk
      lrow    (C, t) int32  row offset within the TM-row tile, in [0, tm)
      slot_nz (C, t) int32  flat nonzero id feeding each slot, or ``nnz_pad``
                            (a sentinel gathering an appended zero) for
                            unused slots
      tile    (C,)   int32  output row-tile of the chunk (non-decreasing)
      first  (C,)   int32   1 iff chunk is the first of its row tile
    where C = nnz_pad//t + ceil(m/tm) (static worst case).
    """
    m = a.m
    nnz_pad = a.nnz_pad
    if m == 0:
        # Degenerate 0-row pattern: no output tiles, no valid nonzeroes.
        # Execution early-outs before touching these (ops.merge_execute),
        # but the structure must still be constructible with static shapes.
        n_chunks = max(1, -(-nnz_pad // t))
        zeros = jnp.zeros((n_chunks, t), jnp.int32)
        edge = jnp.zeros((n_chunks,), jnp.int32)
        return dict(cols=zeros, lrow=zeros,
                    slot_nz=jnp.full((n_chunks, t), nnz_pad, jnp.int32),
                    tile=edge, first=edge.at[0].set(1),
                    last=edge.at[-1].set(1))
    n_tiles_m = -(-m // tm)
    n_chunks = -(-nnz_pad // t) + n_tiles_m

    rows = rows_from_row_ptr(a.row_ptr, nnz_pad)   # (nnz,) row ids, pad→m
    tile_of_nz = jnp.minimum(rows // tm, n_tiles_m - 1)    # pad entries clamp
    # nonzero count per row tile, and each nonzero's rank within its tile
    # (tile_of_nz is non-decreasing: CSR order, pads at the end).
    tile_starts = jnp.searchsorted(
        tile_of_nz, jnp.arange(n_tiles_m, dtype=jnp.int32), side="left"
    ).astype(jnp.int32)
    tile_counts = jnp.diff(jnp.append(tile_starts, nnz_pad))
    pos_in_tile = (jnp.arange(nnz_pad, dtype=jnp.int32)
                   - tile_starts[tile_of_nz])
    # chunks allocated per tile: ceil(count/t), min 1 so that every C row
    # tile is visited (and zeroed) at least once; exclusive prefix sum.
    chunks_per_tile = jnp.maximum(1, -(-tile_counts // t))
    chunks_before = jnp.cumsum(chunks_per_tile) - chunks_per_tile
    dest_chunk = chunks_before[tile_of_nz] + pos_in_tile // t
    dest_slot = pos_in_tile % t

    # Padded nonzeroes keep their formula slots (reserved via tile_counts of
    # the last tile) but contribute value 0 / column 0.
    valid = jnp.arange(nnz_pad) < a.nnz()
    zeros_i = jnp.zeros((n_chunks, t), jnp.int32)
    cols = zeros_i.at[dest_chunk, dest_slot].set(
        jnp.where(valid, a.col_ind, 0), mode="drop")
    slot_nz = jnp.full((n_chunks, t), nnz_pad, jnp.int32)
    slot_nz = slot_nz.at[dest_chunk, dest_slot].set(
        jnp.where(valid, jnp.arange(nnz_pad, dtype=jnp.int32), nnz_pad),
        mode="drop")
    lrow = zeros_i.at[dest_chunk, dest_slot].set(
        jnp.where(valid, rows % tm, 0), mode="drop")

    # chunk -> row tile (non-decreasing); unused tail chunks point at the
    # last used tile so the revisit stream stays monotone.
    chunk_ids = jnp.arange(n_chunks, dtype=jnp.int32)
    cum = chunks_before + chunks_per_tile  # inclusive prefix
    tile_of_chunk = jnp.searchsorted(cum, chunk_ids, side="right")
    used = chunk_ids < cum[-1]
    tile_of_chunk = jnp.minimum(tile_of_chunk, n_tiles_m - 1)
    tile = jnp.where(used, tile_of_chunk, n_tiles_m - 1).astype(jnp.int32)
    first = jnp.concatenate(
        [jnp.ones((1,), jnp.int32),
         (tile[1:] != tile[:-1]).astype(jnp.int32)])
    last = jnp.concatenate(
        [(tile[1:] != tile[:-1]).astype(jnp.int32),
         jnp.ones((1,), jnp.int32)])
    return dict(cols=cols, lrow=lrow, slot_nz=slot_nz, tile=tile, first=first,
                last=last)


def apply_vals(structure: dict, vals: jax.Array) -> jax.Array:
    """Gather per-call values into a structure's slots (chunk or ELL layout).

    ``slot_nz == nnz_pad`` slots read the appended zero, so padded/unused
    slots contribute nothing regardless of what ``vals`` holds.
    """
    vals_ext = jnp.concatenate([vals, jnp.zeros((1,), vals.dtype)])
    return vals_ext[structure["slot_nz"]]


def plan_merge(a: CSR, *, t: int = DEFAULT_T, tm: int = TM):
    """Phase 1 with values applied: the single-call (plan-per-call) form."""
    structure = plan_merge_structure(a, t=t, tm=tm)
    plan = dict(structure)
    plan["vals"] = apply_vals(structure, a.vals)
    return plan


def pack_vals(vals: jax.Array, nnz_pad: int, *, tn: int = TN) -> jax.Array:
    """Lay the raw values out as one whole-block (1, NV) kernel operand.

    Zero-padded past the sentinel index ``nnz_pad`` (and up to a lane
    multiple), so the in-kernel ``slot_nz`` gather keeps ``apply_vals``'s
    contract — unused slots read a zero — without ever materializing the
    padded per-slot layout in HBM.
    """
    nv = tn * (-(-(nnz_pad + 1) // tn))
    return jnp.pad(vals, (0, nv - nnz_pad)).reshape(1, nv)


def _merge_kernel(tile_ref, first_ref, last_ref, cols_ref, slot_ref,
                  lrow_ref, vals_ref, b_ref, *rest, tm: int, tk: int,
                  n_k: int, acc_dtype, ep):
    i = 0
    bias_ref = res_ref = None
    if ep is not None and ep.bias:
        bias_ref, i = rest[i], i + 1
    if ep is not None and ep.residual:
        res_ref, i = rest[i], i + 1
    o_ref, acc_ref = rest[i], rest[i + 1]
    c = pl.program_id(2)
    kk = pl.program_id(3)

    @pl.when((first_ref[c] == 1) & (kk == 0))
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    cols = cols_ref[0]                                   # (t,)
    lrow = lrow_ref[0]                                   # (t,)
    # Only the columns whose B row lives in the resident (TK, TN) panel
    # contribute on this k step; the rest are masked and picked up by the
    # accumulator carry when their panel streams in.
    local = cols - kk * tk
    in_panel = (local >= 0) & (local < tk)
    # In-kernel values gather: each slot names its flat nonzero id
    # (sentinel nnz_pad lands in the operand's zero padding), replacing
    # the per-call HBM materialization of the chunked values.
    vals = jnp.take(vals_ref[0], slot_ref[0], axis=0)     # (t,)
    vals = jnp.where(in_panel, vals, 0).astype(acc_dtype)
    # Row-major coalesced gather of B rows (lane-contiguous slices).
    bgat = jnp.take(b_ref[0], jnp.where(in_panel, local, 0),
                    axis=0).astype(acc_dtype)             # (t, TN)
    prod = vals[:, None] * bgat                           # (t, TN)
    # Scatter-add into the TM-row tile via a one-hot matmul (MXU).
    t = lrow.shape[0]
    onehot = (lrow[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (t, tm), 1))
    acc_ref[...] += jnp.dot(onehot.astype(acc_dtype).T, prod,
                            preferred_element_type=acc_dtype)

    @pl.when((last_ref[c] == 1) & (kk == n_k - 1))
    def _flush():
        # Fused epilogue on the accumulator: one pass over C instead of a
        # write + re-read for bias/activation/residual.
        r = apply_epilogue(
            acc_ref[...], ep,
            bias_ref[0][:, None] if bias_ref is not None else None,
            res_ref[0] if res_ref is not None else None)
        o_ref[0] = r.astype(o_ref.dtype)


def merge_spmm_pallas(plan: dict, vals: jax.Array, b: jax.Array,
                      m_pad: int, *, tm: int = TM, tn: int = TN,
                      tk: int | None = None, interpret: bool = False,
                      acc_dtype=jnp.float32, out_dtype=None,
                      epilogue=None, bias=None,
                      residual=None) -> jax.Array:
    """Phase 2. ``b`` is (batch, k, n), n % tn == 0, m_pad % tm == 0.

    ``plan`` is the pattern structure (``plan_merge_structure``); ``vals``
    the raw (nnz_pad,) value vector, gathered in-kernel through
    ``slot_nz``.  ``epilogue`` (a ``repro.core.Epilogue``) fuses
    ``act(C + bias) * scale + residual`` into the accumulator flush —
    ``bias (m_pad,)`` and ``residual (batch, m_pad, n)`` must be present
    exactly per its flags.  Accumulation runs in ``acc_dtype`` (f32 by
    default, also under bf16 inputs); C is written once in ``out_dtype``
    (default: b's dtype).

    Returns (batch, m_pad, n): the batch rides the leading grid axis (one
    dispatch for the whole stack) and B streams in (TK, TN) VMEM panels.
    The raw values sit whole in VMEM as one (1, NV) block — fine on the
    interpret/CPU substrate and at pruned-FFN sizes; a real-TPU port at
    very large nnz would window this per chunk range.
    """
    batch, k, n = b.shape
    n_chunks, t = plan["cols"].shape
    tk, n_k = resolve_tk(k, tk)
    kpad = n_k * tk - k
    if kpad:
        b = jnp.pad(b, ((0, 0), (0, kpad), (0, 0)))
    nnz_pad = vals.shape[0]
    vals2 = pack_vals(vals, nnz_pad, tn=tn)
    nv = vals2.shape[1]
    ep = epilogue
    out_dtype = b.dtype if out_dtype is None else out_dtype
    grid = (batch, n // tn, n_chunks, n_k)
    in_specs = [
        pl.BlockSpec((1, t), lambda bb, j, c, kk, tile, first, last:
                     (c, 0)),
        pl.BlockSpec((1, t), lambda bb, j, c, kk, tile, first, last:
                     (c, 0)),
        pl.BlockSpec((1, t), lambda bb, j, c, kk, tile, first, last:
                     (c, 0)),
        pl.BlockSpec((1, nv), lambda bb, j, c, kk, tile, first, last:
                     (0, 0)),
        pl.BlockSpec((1, tk, tn), lambda bb, j, c, kk, tile, first, last:
                     (bb, kk, j)),
    ]
    operands = [plan["cols"], plan["slot_nz"], plan["lrow"], vals2, b]
    if ep is not None and ep.bias:
        in_specs.append(pl.BlockSpec(
            (1, tm), lambda bb, j, c, kk, tile, first, last: (tile[c], 0)))
        operands.append(bias.reshape(m_pad // tm, tm))
    if ep is not None and ep.residual:
        in_specs.append(pl.BlockSpec(
            (1, tm, tn), lambda bb, j, c, kk, tile, first, last:
            (bb, tile[c], j)))
        operands.append(residual)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, tm, tn), lambda bb, j, c, kk, tile, first, last:
            (bb, tile[c], j)),
        scratch_shapes=[pltpu.VMEM((tm, tn), acc_dtype)],
    )
    kernel = functools.partial(_merge_kernel, tm=tm, tk=tk, n_k=n_k,
                               acc_dtype=acc_dtype, ep=ep)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((batch, m_pad, n), out_dtype),
        interpret=interpret,
    )(plan["tile"], plan["first"], plan["last"], *operands)


# ----------------------------------------------------- static launch model ---


def vals_launch_block(nnz_pad: int, dtype: str):
    """The whole-block ``(1, NV)`` values operand (see ``pack_vals``)."""
    from .introspect import KernelBlock
    nv = TN * (-(-(nnz_pad + 1) // TN))
    return KernelBlock("vals", (1, nv), dtype, lambda *_: (0, 0), (1, nv),
                       "in")


def launch_models(plan, n, batch, var, tk):
    """Static model of ``merge_spmm_pallas``'s one launch.

    Mirrors the grid/BlockSpec construction above block-for-block (a
    drifted model fails the kernel audit's in-bounds/single-writer
    enumeration, which evaluates these maps against the real scalar
    streams).  ``plan`` carries ``.meta``/``.fwd``; ``var`` the dtype/
    epilogue corner (see ``repro.kernels.introspect``).
    """
    from .introspect import KernelBlock, KernelLaunch
    meta, fwd = plan.meta, plan.fwd
    c_n, t = fwd["cols"].shape
    tile = np.asarray(fwd["tile"])
    last = np.asarray(fwd["last"])
    tk, n_k = resolve_tk(meta.k, tk)
    m_pad = TM * (-(-meta.m // TM))
    ep = var.epilogue
    odt = var.out_dtype or var.b_dtype
    blocks = [
        KernelBlock("tile", (c_n,), "int32", None, (c_n,), "scalar"),
        KernelBlock("first", (c_n,), "int32", None, (c_n,), "scalar"),
        KernelBlock("last", (c_n,), "int32", None, (c_n,), "scalar"),
        KernelBlock("cols", (1, t), "int32",
                    lambda bb, j, c, kk: (c, 0), (c_n, t), "in"),
        KernelBlock("slot_nz", (1, t), "int32",
                    lambda bb, j, c, kk: (c, 0), (c_n, t), "in"),
        KernelBlock("lrow", (1, t), "int32",
                    lambda bb, j, c, kk: (c, 0), (c_n, t), "in"),
        vals_launch_block(meta.nnz_pad, var.vals_dtype),
        KernelBlock("b", (1, tk, TN), var.b_dtype,
                    lambda bb, j, c, kk: (bb, kk, j),
                    (batch, n_k * tk, n), "in"),
    ]
    if ep is not None and ep.bias:
        blocks.append(KernelBlock(
            "bias", (1, TM), var.b_dtype,
            lambda bb, j, c, kk: (tile[c], 0), (m_pad // TM, TM), "in"))
    if ep is not None and ep.residual:
        blocks.append(KernelBlock(
            "residual", (1, TM, TN), var.b_dtype,
            lambda bb, j, c, kk: (bb, tile[c], j),
            (batch, m_pad, n), "in"))
    out = KernelBlock("out", (1, TM, TN), odt,
                      lambda bb, j, c, kk: (bb, tile[c], j),
                      (batch, m_pad, n), "out")
    blocks += [out, KernelBlock("acc", (TM, TN), var.acc_dtype, None,
                                (TM, TN), "scratch")]
    return [KernelLaunch(
        label="merge", grid=(batch, n // TN, c_n, n_k),
        blocks=tuple(blocks),
        flush=lambda bb, j, c, kk: bool(last[c] == 1) and kk == n_k - 1,
        out=out)]
