"""Mixture-of-Experts with merge-based (paper §4.2) load balancing.

The token→expert routing matrix is sparse and irregular — hot experts are
the paper's long rows (Type 1 imbalance), cold experts its short rows
(Type 2).  The ``sort`` implementation is the nonzero-split idea applied to
experts:

  1. top-k routing,
  2. sort token-replicas by expert (CSR ordering),
  3. pad each expert group to the token-tile ``TT`` (chunk breaks at group
     boundaries — the carry-out analogue),
  4. grouped GEMM over equal-token blocks (``kernels/moe_gemm.py`` on TPU;
     a block-gather einsum with identical dataflow under XLA/dry-run),
  5. weighted scatter back to token order (the fix-up epilogue).

Load balance is perfect by construction regardless of routing skew.
``dense`` is the GShard-style einsum baseline (the paper-comparison
baseline; see benchmarks/bench_moe_balance.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.kernels import ops as _ops

TT = 64  # tokens per block (the merge chunk size for experts)


def init_moe(key, cfg) -> dict:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    return {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * s,
        "w1": jax.random.normal(ks[1], (e, d, ff), cfg.pdtype) * s,
        "w3": jax.random.normal(ks[2], (e, d, ff), cfg.pdtype) * s,
        "w2": jax.random.normal(ks[3], (e, ff, d), cfg.pdtype) * ff ** -0.5,
    }


def route(p, x, cfg):
    """Top-k routing.  x (t, d) → gates (t, k) f32, experts (t, k) i32."""
    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.sum(gates, -1, keepdims=True)
    return gates, experts.astype(jnp.int32), probs


def aux_load_balance_loss(probs, experts, cfg):
    """Switch-style auxiliary loss: E · Σ_e f_e · P_e.

    probs (t, E) router probabilities; experts (t, k) selected ids."""
    e = cfg.num_experts
    onehot = jax.nn.one_hot(experts, e, dtype=jnp.float32)   # (t, k, E)
    counts = onehot.sum((0, 1))                              # (E,)
    f = counts / jnp.maximum(counts.sum(), 1.0)
    p_mean = probs.mean(0)                                   # (E,)
    return e * jnp.sum(f * p_mean)


def _sorted_dispatch(x, experts, cfg, tt, capacity_factor: float = 1.25):
    """Sort token-replicas by expert into a fixed-capacity buffer.

    Expert ``e`` owns rows ``[e·cap, (e+1)·cap)`` of ``buf`` (cap static =
    ⌈t·k/E · capacity_factor⌉ rounded to ``tt``).  The sort is the CSR
    ordering; the per-expert capacity is the static bound that keeps every
    grid/einsum block equal-sized (the group-boundary analogue of the
    paper's chunk breaks).  Token-replicas beyond an expert's capacity are
    dropped (standard capacity-based MoE; the aux loss keeps routing
    balanced so drops are rare at cf = 1.25).
    """
    t, d = x.shape
    k, e = cfg.top_k, cfg.num_experts
    cap = tt * max(1, -(-int(t * k * capacity_factor) // (e * tt)))
    flat_e = experts.reshape(-1)                     # (t*k,)
    order = jnp.argsort(flat_e, stable=True)         # CSR ordering
    sorted_e = flat_e[order]
    sizes = jnp.bincount(flat_e, length=e)           # true group sizes
    group_start = jnp.cumsum(sizes) - sizes
    rank = jnp.arange(t * k) - group_start[sorted_e]
    keep = rank < cap
    slot = jnp.where(keep, sorted_e * cap + rank, e * cap)
    buf = jnp.zeros((e * cap, d), x.dtype).at[slot].set(
        x[order // k], mode="drop")
    return buf, dict(order=order, slot=slot, keep=keep, cap=cap)


def _group_mlp(buf, p, cfg, tt, use_kernel):
    """SwiGLU through grouped GEMMs (equal tokens per block)."""
    dt = cfg.cdtype
    e = cfg.num_experts
    cap = buf.shape[0] // e
    if use_kernel:
        sizes = jnp.full((e,), cap, jnp.int32)
        gg = functools.partial(_ops.moe_group_gemm, tt=tt)
        h = jax.nn.silu(gg(buf, p["w1"].astype(dt), sizes)) * \
            gg(buf, p["w3"].astype(dt), sizes)
        return gg(h, p["w2"].astype(dt), sizes)
    # XLA path: one batched matmul over the (E, cap, d) layout — exact
    # capacity FLOPs, each expert's weights touched once.  (Constraining
    # the buf layout over *capacity* was A/B-tested and REFUTED — §Perf
    # iteration 1; constraining the *expert* dim to match expert-parallel
    # weights is iteration 10.)
    xb = buf.reshape(e, cap, -1)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xb, p["w1"].astype(dt))) * \
        jnp.einsum("ecd,edf->ecf", xb, p["w3"].astype(dt))
    out = jnp.einsum("ecf,efd->ecd", h, p["w2"].astype(dt))
    return out.reshape(e * cap, -1)


def _sort_moe(p, xt, gates, experts, cfg, tt, use_kernel, capacity_factor):
    buf, meta = _sorted_dispatch(xt, experts, cfg, tt, capacity_factor)
    out = _group_mlp(buf, p, cfg, tt, use_kernel)
    # fix-up epilogue: weighted scatter back to token order
    safe_slot = jnp.minimum(meta["slot"], out.shape[0] - 1)
    contrib = jnp.where(meta["keep"][:, None], out[safe_slot], 0.0)
    tok = meta["order"] // cfg.top_k
    w = gates.reshape(-1)[meta["order"]].astype(contrib.dtype)
    return jax.ops.segment_sum(contrib * w[:, None], tok,
                               num_segments=xt.shape[0])


def moe_apply(p, x, cfg, *, tt: int = TT, use_kernel: bool | None = None,
              capacity_factor: float = 1.25):
    """x (b, s, d) → (y, aux_loss)."""
    if use_kernel is None:
        use_kernel = cfg.moe_impl == "sort" and jax.default_backend() == "tpu"
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    gates, experts, probs = route(p, xt, cfg)
    aux = aux_load_balance_loss(probs, experts, cfg)
    if cfg.moe_impl == "dense":
        y = _dense_moe(p, xt, gates, experts, cfg)
    elif cfg.moe_groups > 1 and (b * s) % cfg.moe_groups == 0:
        # hierarchical dispatch: per-group local sort/scatter (groups track
        # the data shards, so the merge ordering never crosses devices —
        # §Perf iteration 8).  Per-group capacity keeps total work equal.
        g = cfg.moe_groups
        xg = constrain(xt.reshape(g, (b * s) // g, d), "dp", None, None)
        gg = gates.reshape(g, -1, cfg.top_k)
        eg = experts.reshape(g, -1, cfg.top_k)
        y = jax.vmap(lambda x_, g_, e_: _sort_moe(
            p, x_, g_, e_, cfg, tt, use_kernel, capacity_factor))(xg, gg, eg)
        y = y.reshape(b * s, d)
    else:
        y = _sort_moe(p, xt, gates, experts, cfg, tt, use_kernel,
                      capacity_factor)
    return y.reshape(b, s, d).astype(x.dtype), aux


def _dense_moe(p, xt, gates, experts, cfg):
    """GShard-style einsum baseline: every token × every expert mask."""
    e = cfg.num_experts
    dt = cfg.cdtype
    comb = jnp.zeros((xt.shape[0], e), jnp.float32)
    comb = comb.at[jnp.arange(xt.shape[0])[:, None], experts].add(gates)
    h = jnp.einsum("td,edf->tef", xt, p["w1"].astype(dt))
    h3 = jnp.einsum("td,edf->tef", xt, p["w3"].astype(dt))
    o = jnp.einsum("tef,efd->ted", jax.nn.silu(h) * h3, p["w2"].astype(dt))
    return jnp.einsum("ted,te->td", o, comb.astype(dt))
