"""Transformer building blocks: norms, RoPE, blockwise (flash) attention
with GQA / sliding-window / local variants, SwiGLU & GELU MLPs.

Pure-functional: ``init_*`` builds param pytrees (plain dicts), ``*_apply``
consumes them.  All sequence-mixing ops are written blockwise (``lax.scan``
over query/key chunks with online softmax) so activation memory stays
bounded at 32k prefill and the HLO stays compact for the dry-run.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain

# ---------------------------------------------------------------- norms ----


def init_norm(d: int, kind: str, dtype) -> dict:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def norm_apply(p: dict, x: jax.Array, kind: str = "rmsnorm",
               eps: float = 1e-6) -> jax.Array:
    """Statistics in f32, elementwise math in the input dtype.

    Keeping the *tensor* in bf16 matters for distribution, not just speed:
    upcasting x before the normalize lets the SPMD partitioner hoist the
    convert between the reduce-scatter/all-gather halves of the TP
    all-reduce, doubling collective bytes (§Perf iteration 3).  The f32
    reduction below fuses into the reduce — no f32 copy of x exists.
    """
    if kind == "rmsnorm":
        ms = jnp.mean(jnp.square(x.astype(jnp.float32)), -1, keepdims=True)
        y = x * jax.lax.rsqrt(ms + eps).astype(x.dtype)
    else:
        xf = x.astype(jnp.float32)
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
        y = (x - mu.astype(x.dtype)) * jax.lax.rsqrt(var + eps).astype(x.dtype)
    y = y * p["scale"].astype(x.dtype)
    if "bias" in p:
        y = y + p["bias"].astype(x.dtype)
    return y


# ----------------------------------------------------------------- RoPE ----


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x (..., s, h, dh), positions (..., s) broadcastable."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq   # (..., s, half)
    ang = ang[..., None, :]                            # (..., s, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal(positions: jax.Array, d: int) -> jax.Array:
    half = d // 2
    freq = 10_000.0 ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ----------------------------------------------- blockwise attention -------


def _attend_block(q, k, v, qpos, kpos, carry, *, scale, window, softcap):
    """Online-softmax update for one (q-chunk, kv-chunk) pair.

    q (b, cq, kv, g, dh); k/v (b, ck, kv, dh); positions (cq,), (ck,).
    carry = (m, l, acc) with shapes (b, kv, g, cq[, dh]).
    """
    m, l, acc = carry
    # bf16 MACs with f32 accumulation — the MXU-native regime, and
    # numerically consistent with decode_attention.
    s = jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                   preferred_element_type=jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    mask = qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= kpos[None, :] > (qpos[:, None] - window)
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    m_new = jnp.maximum(m, s.max(-1))
    p = jnp.exp(s - m_new[..., None])
    p = jnp.where(jnp.isfinite(m_new)[..., None], p, 0.0)
    alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_new), 0.0)
    l = l * alpha + p.sum(-1)
    acc = acc * alpha[..., None] + jnp.einsum(
        "bkgqs,bskd->bkgqd", p.astype(v.dtype), v,
        preferred_element_type=jnp.float32)
    return m_new, l, acc


def flash_attention(q, k, v, *, q_offset=0, window: int | None = None,
                    q_chunk: int = 512, kv_chunk: int = 512,
                    softcap: float = 0.0) -> jax.Array:
    """Causal blockwise attention.  q (b,sq,h,dh), k/v (b,skv,kv,dh).

    ``q_offset``: absolute position of q[0] (prefill continuation).
    ``window``: sliding-window size (SWA/local); None = full attention.
    Windowed variants only *fetch* the KV chunks a query chunk can see
    (dynamic_slice of size window+q_chunk) — sub-quadratic compute, the
    banded analogue of the paper's "only touch the nonzeroes you own".
    """
    b, sq, h, dh = q.shape
    _, skv, kvh, _ = k.shape
    g = h // kvh
    scale = dh ** -0.5
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    n_q = -(-sq // q_chunk)
    assert sq % q_chunk == 0, (sq, q_chunk)
    qg = q.reshape(b, sq, kvh, g, dh)

    if window is not None:
        span = kv_chunk * (-(-(window + q_chunk) // kv_chunk))
        span = min(span, skv)
    else:
        span = skv

    def q_step(_, qi):
        q_blk = jax.lax.dynamic_slice_in_dim(qg, qi * q_chunk, q_chunk, 1)
        qpos = q_offset + qi * q_chunk + jnp.arange(q_chunk)
        if window is not None:
            start = jnp.clip(q_offset + (qi + 1) * q_chunk - span, 0,
                             skv - span)
        else:
            start = 0
        k_win = jax.lax.dynamic_slice_in_dim(k, start, span, 1)
        v_win = jax.lax.dynamic_slice_in_dim(v, start, span, 1)
        m0 = jnp.full((b, kvh, g, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, q_chunk, dh), jnp.float32)

        # checkpoint: backward re-materializes one (q,kv)-chunk of scores
        # at a time instead of saving every p matrix (O(s²) otherwise).
        @jax.checkpoint
        def kv_step(carry, ki):
            k_blk = jax.lax.dynamic_slice_in_dim(k_win, ki * kv_chunk,
                                                 kv_chunk, 1)
            v_blk = jax.lax.dynamic_slice_in_dim(v_win, ki * kv_chunk,
                                                 kv_chunk, 1)
            kpos = start + ki * kv_chunk + jnp.arange(kv_chunk)
            return _attend_block(q_blk, k_blk, v_blk, qpos, kpos, carry,
                                 scale=scale, window=window,
                                 softcap=softcap), None

        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      jnp.arange(span // kv_chunk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        # (b, kv, g, cq, dh) -> (b, cq, kv*g, dh)
        out = out.transpose(0, 3, 1, 2, 4).reshape(b, q_chunk, h, dh)
        return None, out.astype(q.dtype)

    _, chunks = jax.lax.scan(jax.checkpoint(q_step), None, jnp.arange(n_q))
    # chunks (n_q, b, q_chunk, h, dh)
    return chunks.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, dh)


def decode_attention(q, k_cache, v_cache, pos, *,
                     window: int | None = None,
                     softcap: float = 0.0) -> jax.Array:
    """One-token attention against a cache.  q (b,1,h,dh); caches
    (b,S,kv,dh); pos (b,) current position (number of tokens already in
    cache).  Windowed archs slice only the window from the cache."""
    b, _, h, dh = q.shape
    _, s_cache, kvh, _ = k_cache.shape
    g = h // kvh
    scale = dh ** -0.5
    full_span = window is None or window >= s_cache
    if full_span:
        # attend over the whole cache in place — no slicing, no gather
        span = s_cache
        k_win, v_win = k_cache, v_cache
        kpos = jnp.broadcast_to(jnp.arange(span)[None], (b, span))
        # flash-decoding: keep the cache sequence-sharded; softmax
        # reductions over seq become partial-reduce + tiny all-reduce
        # instead of an all-gather of the (huge) cache.
        k_win = constrain(k_win, "dp", "model", None, None)
        v_win = constrain(v_win, "dp", "model", None, None)
    else:
        span = window
        start = jnp.clip(pos + 1 - span, 0, s_cache - span)
        k_win = jax.vmap(
            lambda kc, st: jax.lax.dynamic_slice_in_dim(kc, st, span, 0))(
                k_cache, start)
        v_win = jax.vmap(
            lambda vc, st: jax.lax.dynamic_slice_in_dim(vc, st, span, 0))(
                v_cache, start)
        kpos = start[:, None] + jnp.arange(span)[None]       # (b, span)
    qg = q.reshape(b, kvh, g, dh)
    # bf16 inputs, f32 accumulation — never materialize an f32 cache copy
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_win,
                   preferred_element_type=jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    mask = kpos <= pos[:, None]                              # causal
    s = jnp.where(mask[:, None, None], s, -jnp.inf)
    if full_span:
        s = constrain(s, "dp", None, None, "model")
    # numerically-safe softmax over the (possibly sharded) seq axis
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - jax.lax.stop_gradient(m))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_win.dtype), v_win,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, dh).astype(q.dtype)


# ------------------------------------------------------------ attention ----


def init_attention(key, cfg) -> dict:
    d, h, kvh, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    p = {
        "wq": jax.random.normal(ks[0], (d, h * dh), cfg.pdtype) * s,
        "wk": jax.random.normal(ks[1], (d, kvh * dh), cfg.pdtype) * s,
        "wv": jax.random.normal(ks[2], (d, kvh * dh), cfg.pdtype) * s,
        "wo": jax.random.normal(ks[3], (h * dh, d), cfg.pdtype) * s,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * dh,), cfg.pdtype)
        p["bk"] = jnp.zeros((kvh * dh,), cfg.pdtype)
        p["bv"] = jnp.zeros((kvh * dh,), cfg.pdtype)
    return p


def _qkv(p, x, cfg):
    b, s, _ = x.shape
    h, kvh, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = cfg.cdtype
    q = x @ p["wq"].astype(dt)
    k = x @ p["wk"].astype(dt)
    v = x @ p["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    # pin head-sharded layouts (TP over heads; batch over dp); in pure-FSDP
    # mode heads stay local and the batch spans every device
    bt = "dp" if cfg.tp else "dpm"
    ht = "model" if cfg.tp else None
    q = constrain(q.reshape(b, s, h, dh), bt, None, ht, None)
    k = constrain(k.reshape(b, s, kvh, dh), bt, None, ht, None)
    v = constrain(v.reshape(b, s, kvh, dh), bt, None, ht, None)
    return q, k, v


def attention_apply(p, x, cfg, *, window=None, positions=None,
                    cache=None, pos=None):
    """x (b, s, d).  Training/prefill when cache is None or being filled;
    decode when s == 1 and cache holds prior KV.

    Returns (out, new_cache) where cache = {"k","v"} (b, S, kv, dh)."""
    b, s, _ = x.shape
    q, k, v = _qkv(p, x, cfg)
    if positions is None:
        positions = jnp.arange(s)[None, :]
    if cfg.rope_theta:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    softcap = cfg.attn_logit_softcap
    # larger flash tiles cut online-softmax carry traffic ~10% (§Perf
    # iteration 7); capped at 512 for long sequences to bound the f32
    # score block (b·kv·g·cq·ck) on 16 GiB chips
    chunk = 1024 if s <= 8192 else 512
    if cache is None:
        out = flash_attention(q, k, v, window=window, softcap=softcap,
                              q_chunk=chunk, kv_chunk=chunk)
        new_cache = {"k": k, "v": v}
    elif s == 1:
        kc = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice_in_dim(
            c, u, i, 0))(cache["k"], k, pos)
        vc = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice_in_dim(
            c, u, i, 0))(cache["v"], v, pos)
        out = decode_attention(q, kc, vc, pos, window=window, softcap=softcap)
        new_cache = {"k": kc, "v": vc}
    else:  # prefill into an allocated cache
        out = flash_attention(q, k, v, window=window, softcap=softcap,
                              q_chunk=chunk, kv_chunk=chunk)
        s_cache = cache["k"].shape[1]
        pad = [(0, 0), (0, s_cache - s), (0, 0), (0, 0)]
        new_cache = {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)}
    out = out.reshape(b, s, -1) @ p["wo"].astype(cfg.cdtype)
    return constrain(out, *cfg.residual_spec), new_cache


# ----------------------------------------------------------------- MLPs ----


def init_mlp(key, cfg, d_ff=None) -> dict:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    s = d ** -0.5
    if getattr(cfg, "mlp", "swiglu") == "gelu":
        return {"w1": jax.random.normal(ks[0], (d, ff), cfg.pdtype) * s,
                "w2": (jax.random.normal(ks[1], (ff, d), cfg.pdtype)
                       * ff ** -0.5)}
    return {"w1": jax.random.normal(ks[0], (d, ff), cfg.pdtype) * s,
            "w3": jax.random.normal(ks[1], (d, ff), cfg.pdtype) * s,
            "w2": jax.random.normal(ks[2], (ff, d), cfg.pdtype) * ff ** -0.5}


def mlp_apply(p, x, cfg):
    if callable(p.get("w1")):
        # SparseLinear (pruned-FFN serving/fine-tuning): the layer carries
        # its own SpmmPlan and kernel choice — see repro/models/sparse.py.
        from repro.models.sparse import sparse_mlp_apply
        return sparse_mlp_apply(p, x, cfg)
    dt = cfg.cdtype
    if "w3" in p:
        h = jax.nn.silu(x @ p["w1"].astype(dt)) * (x @ p["w3"].astype(dt))
    else:
        h = jax.nn.gelu(x @ p["w1"].astype(dt))
    if cfg.tp:
        h = constrain(h, "dp", None, "model")   # ff dim TP-sharded
    else:
        h = constrain(h, "dpm", None, None)
    return constrain(h @ p["w2"].astype(dt), *cfg.residual_spec)
