from . import layers, losses, model, moe, rglru, sparse, ssm
from .model import (decode_step, forward, init_caches, init_params,
                    loss_and_aux, prefill)

__all__ = ["layers", "losses", "model", "moe", "rglru", "sparse", "ssm",
           "decode_step", "forward", "init_caches", "init_params",
           "loss_and_aux", "prefill"]
