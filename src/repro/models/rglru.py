"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

    r_t = σ(w_a ⊙ x_t)                 recurrence gate
    i_t = σ(w_x ⊙ x_t)                 input gate
    a_t = exp(-c · softplus(Λ) · r_t)   c = 8
    h_t = a_t ⊙ h_{t-1} + √(1 − a_t²) ⊙ (i_t ⊙ x_t)

Training/prefill uses an associative scan (parallel in O(log s) depth);
decode is the O(1) recurrence step.  The block follows Griffin: two input
branches (recurrent path with causal conv width 4, gating path with GELU),
multiplied and projected out.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_C = 8.0


def init_rglru(key, cfg) -> dict:
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    # Λ init so a ∈ (0.9, 0.999) at r = 1 (Griffin §2.4)
    lam = jnp.log(jnp.expm1(-jnp.log(
        jnp.linspace(0.9, 0.999, w)) / _C)).astype(jnp.float32)
    return {
        "wx_in": jax.random.normal(ks[0], (d, w), cfg.pdtype) * s,
        "wg_in": jax.random.normal(ks[1], (d, w), cfg.pdtype) * s,
        "conv": (jax.random.normal(ks[2], (cfg.conv_width, w), cfg.pdtype)
                 * 0.1),
        "gate_a": jnp.zeros((w,), jnp.float32),
        "gate_x": jnp.zeros((w,), jnp.float32),
        "lam": lam,
        "out": jax.random.normal(ks[3], (w, d), cfg.pdtype) * w ** -0.5,
    }


def _gates(p, x):
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf * p["gate_a"])
    i = jax.nn.sigmoid(xf * p["gate_x"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r        # (..., w), ≤ 0
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * xf)
    return a, b


def _conv(x, conv, state=None):
    w = conv.shape[0]
    if state is None:
        pad = jnp.pad(x, ((0, 0), (w - 1, 0), (0, 0)))
        new_state = pad[:, -(w - 1):] if w > 1 else None
    else:
        pad = jnp.concatenate([state.astype(x.dtype), x], axis=1)
        new_state = pad[:, -(w - 1):] if w > 1 else None
    out = sum(pad[:, i:i + x.shape[1]] * conv[i] for i in range(w))
    return out, new_state


def rglru_apply(p, x, cfg, *, state=None):
    """x (b, s, d) → (out, new_state); state = {"conv", "h"}."""
    dt = x.dtype
    xr = x @ p["wx_in"].astype(dt)                     # recurrent branch
    xg = jax.nn.gelu(x @ p["wg_in"].astype(dt))        # gating branch
    if state is None:
        xr, conv_state = _conv(xr, p["conv"].astype(dt))
        a, b = _gates(p, xr)

        def combine(l, r):
            return (r[0] * l[0], r[0] * l[1] + r[1])

        _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
        h_last = h[:, -1]
    else:
        xr, conv_state = _conv(xr, p["conv"].astype(dt), state["conv"])
        a, b = _gates(p, xr)
        h = a * state["h"][:, None] + b                 # (b, 1, w)
        h_last = h[:, -1]
    y = (h.astype(dt) * xg) @ p["out"].astype(dt)
    return y, {"conv": conv_state, "h": h_last}


def init_rglru_state(cfg, batch, dtype=jnp.float32):
    w = cfg.lru_width or cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
        "h": jnp.zeros((batch, w), jnp.float32),
    }
