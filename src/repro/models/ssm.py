"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) block.

Chunked SSD algorithm: within a chunk the recurrence is computed in its
"attention" (quadratic) dual form; states are passed between chunks with a
linear recurrence — O(s·q) compute, O(1)-state decode.

Recurrence (per head, diagonal A):
    h_t = exp(Δ_t A) · h_{t-1} + Δ_t · B_t ⊗ x_t
    y_t = C_t · h_t + D · x_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_ssd(key, cfg) -> dict:
    d = cfg.d_model
    din = cfg.ssm_expand * d
    heads = din // cfg.ssm_head_dim
    n = cfg.ssm_state
    ks = jax.random.split(key, 5)
    s = d ** -0.5
    return {
        # fused input projection: [z (din), x (din), B (n), C (n), dt (heads)]
        "in_proj": jax.random.normal(
            ks[0], (d, 2 * din + 2 * n + heads), cfg.pdtype) * s,
        "conv": jax.random.normal(
            ks[1], (cfg.conv_width, din + 2 * n), cfg.pdtype) * 0.1,
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, heads)).astype(jnp.float32),
        "d_skip": jnp.ones((heads,), jnp.float32),
        "dt_bias": jnp.zeros((heads,), jnp.float32),
        "out_proj": (jax.random.normal(ks[2], (din, d), cfg.pdtype)
                     * din ** -0.5),
    }


def _split_proj(p, u, cfg):
    d = cfg.d_model
    din = cfg.ssm_expand * d
    n = cfg.ssm_state
    heads = din // cfg.ssm_head_dim
    zxbcdt = u @ p["in_proj"].astype(u.dtype)
    z, xbc, dt = jnp.split(zxbcdt, [din, 2 * din + 2 * n], axis=-1)
    return z, xbc, dt, din, n, heads


def _causal_conv(xbc, conv, state=None):
    """Depthwise causal conv along seq.  xbc (b,s,c), conv (w,c).

    state (b, w-1, c) holds the trailing inputs for decode; returns
    (out, new_state)."""
    w = conv.shape[0]
    if state is None:
        pad = jnp.pad(xbc, ((0, 0), (w - 1, 0), (0, 0)))
        new_state = pad[:, -(w - 1):] if w > 1 else None
    else:
        pad = jnp.concatenate([state.astype(xbc.dtype), xbc], axis=1)
        new_state = pad[:, -(w - 1):] if w > 1 else None
    out = sum(pad[:, i:i + xbc.shape[1]] * conv[i] for i in range(w))
    return jax.nn.silu(out), new_state


def ssd_scan_chunked(x, dt, a, b, c, *, chunk: int,
                     mac_dtype=None):
    """Chunked SSD.  x (B,S,H,P), dt (B,S,H) (post-softplus), a (H,) < 0,
    b/c (B,S,N).  Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    bs, s, h, p = x.shape
    n = b.shape[-1]
    nc = s // chunk
    mac = mac_dtype or x.dtype
    xc = x.reshape(bs, nc, chunk, h, p)
    dtc = dt.reshape(bs, nc, chunk, h)
    bc = b.reshape(bs, nc, chunk, n)
    cc = c.reshape(bs, nc, chunk, n)

    da = dtc * a[None, None, None]                    # (B,nc,L,H) log-decay
    cum = jnp.cumsum(da, axis=2)                       # within-chunk cumsum
    # intra-chunk (dual / attention form):
    #   y_t = Σ_{u<=t} C_t·B_u exp(cum_t - cum_u) Δ_u x_u
    # mask in LOG space before exp — masking after (exp(+big)·0) NaNs the
    # backward pass.  The O(L²·H) decay tensor and the gathered operands
    # are kept bf16 (decay ∈ [0,1]; f32 accumulation in the einsums) —
    # halves the dominant HBM term (§Perf iteration 13).
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    diff = jnp.where(mask[None, None, :, :, None], diff, -jnp.inf)
    decay = jnp.exp(diff).astype(mac)
    xb16 = xc.astype(mac)
    scores = jnp.einsum("bcln,bcmn->bclm", cc.astype(mac),
                        bc.astype(mac),
                        preferred_element_type=jnp.float32)  # (B,nc,L,L)
    y_intra = jnp.einsum("bclm,bclmh,bcmh,bcmhp->bclhp",
                         scores.astype(mac), decay,
                         dtc.astype(mac), xb16,
                         preferred_element_type=jnp.float32)

    # chunk-level states: S_c = Σ_u exp(cum_L - cum_u) Δ_u B_u ⊗ x_u
    chunk_decay = jnp.exp(cum[:, :, -1:, :] - cum)     # (B,nc,L,H)
    states = jnp.einsum("bclh,bcln,bclhp->bchpn",
                        (dtc * chunk_decay).astype(mac),
                        bc.astype(mac), xb16,
                        preferred_element_type=jnp.float32)  # (B,nc,H,P,N)
    total = jnp.exp(cum[:, :, -1])                     # (B,nc,H) chunk decay

    def step(carry, inp):
        st_prev = carry                                # (B,H,P,N)
        st_c, tot_c = inp
        st = st_prev * tot_c[:, :, None, None] + st_c
        return st, st_prev

    init = jnp.zeros((bs, h, p, n), x.dtype)
    final, prev_states = jax.lax.scan(
        step, init, (states.transpose(1, 0, 2, 3, 4),
                     total.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (B,nc,H,P,N)

    # inter-chunk: y_t += C_t · exp(cum_t) · S_{c-1}
    y_inter = jnp.einsum("bcln,bclh,bchpn->bclhp",
                         cc, jnp.exp(cum), prev_states)
    y = (y_intra + y_inter).reshape(bs, s, h, p)
    return y, final


def ssd_apply(p, u, cfg, *, state=None):
    """u (b, s, d).  Training/prefill: state=None.  Decode: s == 1 with
    state = {"conv": (b,w-1,c), "ssm": (b,H,P,N)}."""
    z, xbc, dt, din, n, heads = _split_proj(p, u, cfg)
    hd = cfg.ssm_head_dim
    a = -jnp.exp(p["a_log"])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])

    if state is None:
        xbc, conv_state = _causal_conv(xbc, p["conv"].astype(xbc.dtype))
        x, b, c = jnp.split(xbc, [din, din + n], axis=-1)
        bs, s, _ = x.shape
        xh = x.reshape(bs, s, heads, hd)
        # pad seq to a chunk multiple with identity steps (dt = 0 →
        # decay 1, zero state update) so the final state is exact
        chunk = min(cfg.ssm_chunk, s)
        pad = (-s) % chunk
        sp = ((0, 0), (0, pad))
        y, ssm_state = ssd_scan_chunked(
            jnp.pad(xh.astype(jnp.float32), sp + ((0, 0), (0, 0))),
            jnp.pad(dt, sp + ((0, 0),)), a,
            jnp.pad(b.astype(jnp.float32), sp + ((0, 0),)),
            jnp.pad(c.astype(jnp.float32), sp + ((0, 0),)), chunk=chunk,
            mac_dtype=cfg.cdtype)
        y = y[:, :s]
        y = y + p["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
        y = y.reshape(bs, s, din).astype(u.dtype)
    else:
        xbc, conv_state = _causal_conv(
            xbc, p["conv"].astype(xbc.dtype), state["conv"])
        x, b, c = jnp.split(xbc, [din, din + n], axis=-1)
        bs = x.shape[0]
        xh = x.reshape(bs, heads, hd).astype(jnp.float32)
        dt1 = dt[:, 0]                                  # (b, H)
        decay = jnp.exp(dt1 * a[None])                  # (b, H)
        db_x = jnp.einsum("bh,bn,bhp->bhpn", dt1, b[:, 0].astype(jnp.float32),
                          xh)
        ssm_state = state["ssm"] * decay[..., None, None] + db_x
        y = jnp.einsum("bn,bhpn->bhp", c[:, 0].astype(jnp.float32), ssm_state)
        y = y + p["d_skip"][None, :, None] * xh
        y = y.reshape(bs, 1, din).astype(u.dtype)

    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(u.dtype)
    out = y @ p["out_proj"].astype(u.dtype)
    return out, {"conv": conv_state, "ssm": ssm_state}


def init_ssd_state(cfg, batch, dtype=jnp.float32):
    d = cfg.d_model
    din = cfg.ssm_expand * d
    heads = din // cfg.ssm_head_dim
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, din + 2 * cfg.ssm_state),
                          dtype),
        "ssm": jnp.zeros((batch, heads, cfg.ssm_head_dim, cfg.ssm_state),
                         jnp.float32),
    }
