"""Pruned-FFN layers via SpMM — the paper's motivating use case (§1, [1]).

``SparseLinear`` stores a magnitude-pruned weight matrix in CSR and runs
the matmul through the plan-once/execute-many engine: the forward is
``y = (W_csr @ x.T).T`` where the activation matrix ``x.T (d_in, tokens)``
is the tall-skinny dense B — during decode ``tokens`` is the batch size
(1–128), exactly the paper's n ∈ [32, 128] regime.

Every pattern-derived static decision — kernel choice (§5.4 heuristic),
row-split ``l_pad``, chunk layout, and the transpose plan for the backward
pass — lives in the layer's ``SpmmPlan``, built once per sparsity pattern
through ``repro.engine``'s cache.  The layer is a pytree, so it passes
through ``jax.jit`` / ``jax.grad`` boundaries with its plan attached and
*never replans inside a jitted step*.  It is differentiable: gradients
flow to the CSR values (sparse fine-tuning of a pruned weight) and to the
activations.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CSR, ExecutionConfig, Heuristic, PlanPolicy,
                        SparseMatrix, SpmmPlan)
from repro.core.config import _UNSET, _warn_deprecated

# Below this many tokens per call, flattening the leading axes packs the
# tokens densely into the kernels' TN=128-lane tiles; from here up each
# batch element already fills its lane tiles, so the batched grid path —
# B (..., d_in, tokens) folded into the kernel's leading batch axis — wins
# by skipping the (batch*tokens) reshape/transpose and running the whole
# stack in one dispatch.
BATCHED_MIN_TOKENS = 128


def _legacy_heuristic(context: str, heuristic, policy):
    """Fold the pre-v1 ``heuristic=`` kwarg into a policy (warn once)."""
    if heuristic is _UNSET:
        return policy
    if policy is not None:
        raise ValueError(f"{context}: pass either policy= or the legacy "
                         "heuristic=, not both")
    _warn_deprecated(
        f"{context}(heuristic=...)",
        "pass policy=PlanPolicy(heuristic=...) "
        "(see README.md: Migrating to API v1)", stacklevel=4)
    return PlanPolicy(heuristic=heuristic)


@dataclasses.dataclass(frozen=True)
class SparseLinear:
    weight: CSR                    # (d_out, d_in)
    plan: SpmmPlan | None       # pattern plan (None = plan on first use)

    @classmethod
    def from_dense(cls, w: jax.Array, keep_fraction: float,
                   heuristic: Heuristic | None = _UNSET, *,
                   policy: PlanPolicy | None = None) -> "SparseLinear":
        """Prune w (d_in, d_out) — stored transposed as (d_out, d_in).

        ``policy`` pins the plan request (method, static params, TuneDB);
        the default lets the engine resolve the kernel method through the
        full ladder — TuneDB exact/class hits, then a DB-calibrated
        threshold — instead of pinning the analytic default.
        (``heuristic`` is the pre-v1 spelling of
        ``policy=PlanPolicy(heuristic=...)``; it warns once.)
        """
        policy = _legacy_heuristic("SparseLinear.from_dense", heuristic,
                                   policy)
        if policy is None:
            policy = PlanPolicy()
        mtx = SparseMatrix.prune(np.asarray(w).T, keep_fraction, policy)
        return cls(mtx.data, mtx.spmm_plan)

    @property
    def matrix(self) -> SparseMatrix:
        """This layer's weight as the v1 ``SparseMatrix`` frontend."""
        return SparseMatrix(self.weight, self.plan)

    def with_plan(self, heuristic: Heuristic | None = _UNSET, *,
                  policy: PlanPolicy | None = None) -> "SparseLinear":
        """(Re)attach the engine-cached plan for this weight's pattern.

        Identity-cheap when the plan is already cached — use after
        checkpoint restore or pattern surgery, outside jit.
        """
        policy = _legacy_heuristic("SparseLinear.with_plan", heuristic,
                                   policy)
        if policy is None and self.plan is not None:
            # Replay the existing plan's full statics (method and tuned
            # t/tl/l_pad), not just its method — a TuneDB-tuned l_pad
            # must survive a re-plan after checkpoint restore.  After
            # pattern surgery that outgrows a pattern-derived parameter,
            # plan_like falls back to the method alone and re-derives.
            mtx = SparseMatrix(self.weight).plan_like(self.plan.meta)
        else:
            mtx = SparseMatrix(self.weight).plan(policy or PlanPolicy())
        return dataclasses.replace(self, plan=mtx.spmm_plan)

    def shard(self, mesh=None, *, n: int | None = None,
              dim: str = "rows", axis: str | None = None,
              policy: PlanPolicy | None = None) -> "SparseLinear":
        """Re-plan this layer's weight with a device-sharded plan.

        nnz-balanced shards, one local plan per shard, executed under
        ``shard_map`` when ``mesh`` is given and the shards are uniform —
        see ``SparseMatrix.shard`` / ``repro.distributed.spmm``.
        """
        mtx = SparseMatrix(self.weight).shard(mesh, n=n, dim=dim, axis=axis,
                                              policy=policy)
        return dataclasses.replace(self, plan=mtx.spmm_plan)

    @property
    def method(self) -> str:
        return self.plan.meta.method if self.plan is not None else "auto"

    @property
    def l_pad(self) -> int | None:
        return self.plan.meta.l_pad if self.plan is not None else None

    def __call__(self, x: jax.Array,
                 exec: ExecutionConfig | None = None, *,
                 bias: jax.Array | None = None,
                 residual: jax.Array | None = None, **kw) -> jax.Array:
        """x (..., d_in) → (..., d_out).  Differentiable in x and vals.

        ``exec`` is the per-call :class:`ExecutionConfig` (bare
        ``impl``/``interpret``/``tk`` kwargs fold into one through the
        ``execute_plan`` shims).  With 3-D+ activations carrying enough
        tokens per call (``BATCHED_MIN_TOKENS``), the leading axes ride
        the engine's batched execution — B (..., d_in, tokens) folds into
        the kernel grid — instead of being flattened into one wide token
        axis.

        ``bias (d_out,)`` / ``residual (..., d_out)`` (layer coordinates,
        like ``x``) and any ``exec.epilogue`` activation fuse into the
        SpMM's output write: the layer runs as ``y = (W @ xᵀ)ᵀ``, so the
        per-``d_out`` bias is exactly the kernel's per-C-row bias and the
        residual rides transposed into kernel coordinates.
        """
        layer = self if self.plan is not None else self.with_plan()
        mtx = layer.matrix
        w = layer.weight
        out_dtype = x.dtype if exec is None or exec.out_dtype is None \
            else jnp.dtype(exec.out_dtype)
        if x.ndim >= 3 and x.shape[-2] >= BATCHED_MIN_TOKENS:
            xt = jnp.swapaxes(x, -1, -2).astype(w.dtype)  # (..., d_in, tok)
            res = None if residual is None else \
                jnp.swapaxes(residual, -1, -2)
            y = mtx.matmul(xt, exec, bias=bias, residual=res, **kw)
            return jnp.swapaxes(y, -1, -2).astype(out_dtype)
        lead = x.shape[:-1]
        xt = x.reshape(-1, x.shape[-1]).T          # (d_in, tokens) = B
        res = None if residual is None else \
            residual.reshape(-1, w.m).T            # (d_out, tokens) = C
        y = mtx.matmul(xt.astype(w.dtype), exec, bias=bias, residual=res,
                       **kw)
        return y.T.reshape(*lead, w.m).astype(out_dtype)


jax.tree_util.register_pytree_node(
    SparseLinear,
    lambda sl: ((sl.weight, sl.plan), ()),
    lambda aux, ch: SparseLinear(*ch),
)


def prune_mlp(mlp_params: dict, keep_fraction: float,
              policy: PlanPolicy | None = None) -> dict:
    """Convert a dense MLP param dict (w1/w2[/w3]) to SparseLinear layers.

    ``policy`` pins every layer's plan request (e.g.
    ``PlanPolicy(method="rowgroup")`` from ``serve --spmm-method``).
    Plans come from the engine cache, so repeated pruning with the same
    masks (e.g. rebuilding layers each serving epoch) replans nothing.
    """
    return {name: SparseLinear.from_dense(w, keep_fraction, policy=policy)
            for name, w in mlp_params.items()}


def sparse_mlp_apply(sparse_p: dict, x: jax.Array, cfg,
                     exec: ExecutionConfig | None = None) -> jax.Array:
    """Apply a pruned MLP block (gelu or swiglu, by the param dict's keys).

    The gelu variant fuses the activation into w1's SpMM epilogue — C is
    written once, activated, instead of written and re-read by a separate
    elementwise program.  swiglu stays unfused: silu and the w3 gate are
    not epilogue shapes.  ``exec`` carries the per-call backend knobs for
    every layer; its ``epilogue`` field is overridden on w1 by the fused
    activation.
    """
    from repro.core.epilogue import Epilogue
    base = exec if exec is not None else ExecutionConfig()
    if "w3" in sparse_p:
        h = jax.nn.silu(sparse_p["w1"](x, base)) * sparse_p["w3"](x, base)
    else:
        fused = dataclasses.replace(base,
                                    epilogue=Epilogue(activation="gelu"))
        h = sparse_p["w1"](x, fused)
    return sparse_p["w2"](h, base)


def mlp_vals(sparse_p: dict) -> dict:
    """Extract the trainable CSR values of a SparseLinear dict."""
    return {name: sl.weight.vals for name, sl in sparse_p.items()}


def mlp_with_vals(sparse_p: dict, vals: dict) -> dict:
    """Rebind CSR values onto the (frozen-pattern) layers — the sparse
    fine-tuning parameterization: patterns and plans stay put, values are
    the optimizer's degrees of freedom."""
    return {name: dataclasses.replace(
        sl, weight=dataclasses.replace(sl.weight, vals=vals[name]))
        for name, sl in sparse_p.items()}
