"""Pruned-FFN serving via SpMM — the paper's motivating use case (§1, [1]).

``SparseLinear`` stores a magnitude-pruned weight matrix in CSR and runs the
forward matmul through the paper's SpMM: ``y = (W_csr @ x.T).T`` where the
activation matrix ``x.T (d_in, tokens)`` is the tall-skinny dense B — during
decode ``tokens`` is the batch size (1–128), exactly the paper's
n ∈ [32, 128] regime.  Kernel selection uses the paper's §5.4 heuristic.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CSR, Heuristic, prune_to_csr, spmm


@dataclasses.dataclass(frozen=True)
class SparseLinear:
    weight: CSR            # (d_out, d_in)
    l_pad: int             # static max row nnz (for row-split)
    method: str            # rowsplit | merge (resolved once at build)

    @classmethod
    def from_dense(cls, w: jax.Array, keep_fraction: float,
                   heuristic: Heuristic = Heuristic()) -> "SparseLinear":
        """Prune w (d_in, d_out) — stored transposed as (d_out, d_in)."""
        csr = prune_to_csr(np.asarray(w).T, keep_fraction)
        l_pad = int(np.max(np.diff(np.asarray(csr.row_ptr))))
        return cls(csr, max(l_pad, 1), heuristic.choose(csr))

    def __call__(self, x: jax.Array, **kw) -> jax.Array:
        """x (..., d_in) → (..., d_out)."""
        lead = x.shape[:-1]
        xt = x.reshape(-1, x.shape[-1]).T          # (d_in, tokens) = B
        y = spmm(self.weight, xt.astype(self.weight.dtype),
                 method=self.method, l_pad=self.l_pad, **kw)
        return y.T.reshape(*lead, self.weight.m).astype(x.dtype)


jax.tree_util.register_pytree_node(
    SparseLinear,
    lambda sl: ((sl.weight,), (sl.l_pad, sl.method)),
    lambda aux, ch: SparseLinear(ch[0], *aux),
)


def prune_mlp(mlp_params: dict, keep_fraction: float) -> dict:
    """Convert a dense MLP param dict (w1/w2[/w3]) to SparseLinear layers."""
    return {name: SparseLinear.from_dense(w, keep_fraction)
            for name, w in mlp_params.items()}


def sparse_mlp_apply(sparse_p: dict, x: jax.Array, cfg) -> jax.Array:
    if "w3" in sparse_p:
        h = jax.nn.silu(sparse_p["w1"](x)) * sparse_p["w3"](x)
    else:
        h = jax.nn.gelu(sparse_p["w1"](x))
    return sparse_p["w2"](h)
