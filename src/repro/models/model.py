"""Unified decoder model over heterogeneous block stacks.

A model is a sequence of *segments* ``(pattern, repeat)`` where pattern is a
tuple of block types:

  attn   — pre-norm GQA attention (full/SWA/local) + MLP
  moe    — attention + mixture-of-experts FFN (merge-based dispatch)
  ssd    — Mamba2 state-space block
  rglru  — RG-LRU recurrent block + MLP (RecurrentGemma)

Each segment is applied with ``lax.scan`` over its ``repeat`` axis (compact
HLO for 80-layer models) with optional remat.  Three entry points mirror
the dry-run shapes: ``loss_and_aux`` (train), ``prefill``, ``decode_step``.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from . import layers as L
from . import moe as M
from . import rglru as R
from . import ssm as S
from .losses import chunked_cross_entropy


# ------------------------------------------------------------- blocks ------


def init_block(key, btype: str, cfg) -> dict:
    ks = jax.random.split(key, 4)
    nk = cfg.norm
    d = cfg.d_model
    if btype in ("attn", "moe"):
        p = {"ln1": L.init_norm(d, nk, jnp.float32),
             "attn": L.init_attention(ks[0], cfg)}
        if btype == "attn":
            p["ln2"] = L.init_norm(d, nk, jnp.float32)
            p["mlp"] = L.init_mlp(ks[1], cfg)
        else:
            p["ln2"] = L.init_norm(d, nk, jnp.float32)
            p["moe"] = M.init_moe(ks[1], cfg)
        return p
    if btype == "ssd":
        return {"ln1": L.init_norm(d, nk, jnp.float32),
                "ssd": S.init_ssd(ks[0], cfg)}
    if btype == "rglru":
        return {"ln1": L.init_norm(d, nk, jnp.float32),
                "rec": R.init_rglru(ks[0], cfg),
                "ln2": L.init_norm(d, nk, jnp.float32),
                "mlp": L.init_mlp(ks[1], cfg)}
    raise ValueError(btype)


def init_block_cache(btype: str, cfg, batch: int, cache_len: int):
    if btype in ("attn", "moe"):
        kvh, dh = cfg.num_kv_heads, cfg.head_dim
        return {"k": jnp.zeros((batch, cache_len, kvh, dh), cfg.cdtype),
                "v": jnp.zeros((batch, cache_len, kvh, dh), cfg.cdtype)}
    if btype == "ssd":
        return S.init_ssd_state(cfg, batch)
    if btype == "rglru":
        return R.init_rglru_state(cfg, batch)
    raise ValueError(btype)


def _window(cfg) -> int | None:
    return None if cfg.attention == "full" else cfg.window


def block_apply(p, btype, x, cfg, *, positions=None, cache=None, pos=None):
    """Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    # Norm outputs are constrained so the *cotangent* resharding in the
    # backward (dx = dh @ W^T is partial over the model axis) is pinned
    # here, where primal & cotangent are bf16 — otherwise the partitioner
    # reshards the f32 carrier downstream at 2× wire (§Perf iteration 3).
    rs = cfg.residual_spec
    if btype in ("attn", "moe"):
        h = constrain(L.norm_apply(p["ln1"], x, cfg.norm), *rs)
        attn_out, new_cache = L.attention_apply(
            p["attn"], h, cfg, window=_window(cfg), positions=positions,
            cache=cache, pos=pos)
        if getattr(cfg, "parallel_block", False):
            if btype == "moe":
                ffn_out, aux = M.moe_apply(p["moe"], h, cfg)
            else:
                ffn_out = L.mlp_apply(p["mlp"], h, cfg)
            x = x + attn_out + ffn_out
        else:
            x = x + attn_out
            h2 = constrain(L.norm_apply(p["ln2"], x, cfg.norm), *rs)
            if btype == "moe":
                ffn_out, aux = M.moe_apply(p["moe"], h2, cfg)
            else:
                ffn_out = L.mlp_apply(p["mlp"], h2, cfg)
            x = x + ffn_out
        return x, new_cache, aux
    # Recurrent blocks: a multi-token input is a prefill — the state is
    # computed from scratch (the passed cache is only a shape donor).
    if btype == "ssd":
        h = L.norm_apply(p["ln1"], x, cfg.norm)
        state = cache if (cache is not None and x.shape[1] == 1) else None
        out, new_cache = S.ssd_apply(p["ssd"], h, cfg, state=state)
        return x + out, new_cache, aux
    if btype == "rglru":
        h = L.norm_apply(p["ln1"], x, cfg.norm)
        state = cache if (cache is not None and x.shape[1] == 1) else None
        out, new_cache = R.rglru_apply(p["rec"], h, cfg, state=state)
        x = x + out
        h2 = L.norm_apply(p["ln2"], x, cfg.norm)
        x = x + L.mlp_apply(p["mlp"], h2, cfg)
        return x, new_cache, aux
    raise ValueError(btype)


# ------------------------------------------------------------- params ------


def init_params(cfg, key) -> dict:
    keys = jax.random.split(key, 8)
    d, v = cfg.d_model, cfg.vocab_size
    params: dict[str, Any] = {}
    if cfg.input_mode == "tokens":
        params["embed"] = (jax.random.normal(keys[0], (v, d), cfg.pdtype)
                           * d ** -0.5)
        if not cfg.tie_embeddings:
            params["unembed"] = (jax.random.normal(keys[1], (v, d),
                                                   cfg.pdtype) * d ** -0.5)
    else:  # stub modality frontend provides embeddings; head is untied
        params["unembed"] = (jax.random.normal(keys[1], (v, d), cfg.pdtype)
                             * d ** -0.5)
    params["final_norm"] = L.init_norm(d, cfg.norm, jnp.float32)

    segments = []
    kseg = keys[2]
    for pattern, count in cfg.segments:
        seg = []
        for pos_i, btype in enumerate(pattern):
            kseg, sub = jax.random.split(kseg)
            bkeys = jax.random.split(sub, count)
            seg.append(jax.vmap(
                lambda k, bt=btype: init_block(k, bt, cfg))(bkeys))
        segments.append(seg)
    params["segments"] = segments
    return params


def init_caches(cfg, batch: int, cache_len: int) -> list:
    caches = []
    for pattern, count in cfg.segments:
        seg = []
        for btype in pattern:
            one = init_block_cache(btype, cfg, batch, cache_len)
            seg.append(jax.tree.map(
                lambda x: jnp.broadcast_to(x, (count,) + x.shape), one))
        caches.append(seg)
    return caches


# ------------------------------------------------------------ forward ------


def forward(params, cfg, h, *, positions=None, caches=None, pos=None,
            remat: bool = False):
    """h (b, s, d) embedded inputs → (h, new_caches, aux_total)."""
    h = constrain(h, *cfg.residual_spec)
    aux_total = jnp.zeros((), jnp.float32)
    new_caches = []
    for si, (pattern, count) in enumerate(cfg.segments):
        seg_params = tuple(params["segments"][si])
        seg_caches = tuple(caches[si]) if caches is not None else None

        def step(carry, xs, pattern=pattern):
            hh, aux = carry
            if seg_caches is None:
                lp = xs
                lc = (None,) * len(pattern)
            else:
                lp, lc = xs
            outs = []
            for pi, btype in enumerate(pattern):
                hh, nc, a = block_apply(lp[pi], btype, hh, cfg,
                                        positions=positions, cache=lc[pi],
                                        pos=pos)
                hh = constrain(hh, *cfg.residual_spec)  # residual layout
                outs.append(nc)
                aux = aux + a
            return (hh, aux), tuple(outs)

        fn = jax.checkpoint(step) if remat else step
        xs = seg_params if seg_caches is None else (seg_params, seg_caches)
        (h, aux_total), seg_new = jax.lax.scan(fn, (h, aux_total), xs)
        new_caches.append(list(seg_new))
    return h, new_caches, aux_total


# ------------------------------------------------------------ embed/head ---


def embed_inputs(params, cfg, batch: dict, *, positions=None):
    if cfg.input_mode == "tokens":
        h = jnp.take(params["embed"], batch["tokens"], axis=0)
        h = h.astype(cfg.cdtype)
        if getattr(cfg, "embed_scale", False):
            h = h * jnp.asarray(cfg.d_model ** 0.5, cfg.cdtype)
    else:
        h = batch["embeds"].astype(cfg.cdtype)
    if cfg.rope_theta == 0.0:  # sinusoidal absolute positions (musicgen)
        if positions is None:
            positions = jnp.arange(h.shape[1])[None, :]
        h = h + L.sinusoidal(positions, cfg.d_model).astype(h.dtype)
    return h


def unembed_matrix(params, cfg):
    return params.get("unembed", params.get("embed"))


# ------------------------------------------------------- entry points ------


def loss_and_aux(params, cfg, batch, *, remat: bool = True,
                 loss_chunk: int = 512, aux_weight: float = 0.01):
    """Causal-LM loss.  batch: tokens/embeds (b,s[,d]), labels (b,s)."""
    h = embed_inputs(params, cfg, batch)
    h, _, aux = forward(params, cfg, h, remat=remat)
    h = L.norm_apply(params["final_norm"], h, cfg.norm)
    nll, cnt = chunked_cross_entropy(
        h, unembed_matrix(params, cfg), batch["labels"],
        chunk=loss_chunk, logit_softcap=cfg.logit_softcap,
        mask=batch.get("mask"))
    return nll + aux_weight * aux, {"nll": nll, "aux": aux, "tokens": cnt}


def prefill(params, cfg, batch, *, cache_len: int | None = None):
    """Forward pass that fills caches.  Returns (caches, last_logits, pos)."""
    h = embed_inputs(params, cfg, batch)
    b, s, _ = h.shape
    cache_len = cache_len or s
    caches = init_caches(cfg, b, cache_len)
    h, caches, _ = forward(params, cfg, h, caches=caches)
    h_last = L.norm_apply(params["final_norm"], h[:, -1:], cfg.norm)
    logits = jnp.einsum("bsd,vd->bsv", h_last,
                        unembed_matrix(params, cfg).astype(h_last.dtype),
                        preferred_element_type=jnp.float32)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return caches, logits, jnp.full((b,), s, jnp.int32)


def decode_step(params, cfg, caches, batch, pos):
    """One-token step.  batch: tokens (b,1) or embeds (b,1,d); pos (b,).

    Returns (logits (b,1,v), new_caches)."""
    positions = pos[:, None]
    h = embed_inputs(params, cfg, batch, positions=positions)
    h, caches, _ = forward(params, cfg, h, positions=positions,
                           caches=caches, pos=pos)
    h = L.norm_apply(params["final_norm"], h, cfg.norm)
    logits = jnp.einsum("bsd,vd->bsv", h,
                        unembed_matrix(params, cfg).astype(h.dtype),
                        preferred_element_type=jnp.float32)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits, caches
