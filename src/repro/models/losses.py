"""Vocab-safe losses: sequence-chunked cross-entropy.

Full logits for (batch·seq, 256k-vocab) would dominate activation memory
(e.g. command-r train_4k: 256·4096·256000·2B ≈ 537 GB global).  We scan
over sequence chunks, computing each chunk's logits + log-sum-exp and
discarding them — peak logits memory = chunk × vocab, sharded over the
model axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def chunked_cross_entropy(h, unembed, labels, *, chunk: int = 512,
                          logit_softcap: float = 0.0,
                          mask=None):
    """h (b,s,d) final hidden states; unembed (v,d); labels (b,s) int32.

    Returns (mean_nll, token_count)."""
    b, s, d = h.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    n_chunks = s // chunk
    if mask is None:
        mask = jnp.ones((b, s), jnp.float32)

    # checkpoint: the backward re-materializes one chunk's logits at a time
    # (otherwise every chunk's (b, chunk, vocab) logits are saved).
    @jax.checkpoint
    def step(carry, i):
        tot, cnt = carry
        hc = jax.lax.dynamic_slice_in_dim(h, i * chunk, chunk, 1)
        yc = jax.lax.dynamic_slice_in_dim(labels, i * chunk, chunk, 1)
        mc = jax.lax.dynamic_slice_in_dim(mask, i * chunk, chunk, 1)
        # bf16 operands, f32 accumulation: the unembed FSDP gather stays
        # bf16 (an f32 upcast here doubles its bytes — §Perf iteration 5)
        logits = jnp.einsum("bsd,vd->bsv", hc, unembed.astype(hc.dtype),
                            preferred_element_type=jnp.float32)
        if logit_softcap:
            logits = logit_softcap * jnp.tanh(logits / logit_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mc
        return (tot + nll.sum(), cnt + mc.sum()), None

    (tot, cnt), _ = jax.lax.scan(step, (jnp.zeros(()), jnp.zeros(())),
                                 jnp.arange(n_chunks))
    return tot / jnp.maximum(cnt, 1.0), cnt
