"""Continuous-batching server over pre-compiled shape buckets.

The paper's core principle — split *total work*, not rows, into equal
pieces so no execution unit idles — lifted to the request level: instead
of one compiled program per caller-shaped batch (recompile on every
ragged tail) or one request at a time (the dispatch amortizer idle), an
open stream of ragged requests feeds a bounded queue, a batcher thread
drains it continuously, and every drained group is packed into the
smallest ``(batch, length)`` bucket of a pre-compiled ladder
(:mod:`repro.serving.buckets`).  All bucket programs and every SpMM plan
are warmed at startup (``warmup``: ``ensure_spmm_plans`` + one AOT
compile per bucket through :class:`repro.engine.ProgramCache`), so the
steady state replans nothing and recompiles nothing — both asserted
against counters, not hoped for.

Admission control keeps the system stable under overload: the queue is
bounded (``submit`` sheds immediately when full), each request may carry
a deadline (shed at dequeue when already expired — serving a dead
request would only delay live ones), and transient execution failures
retry with exponential backoff through ``repro.distributed.fault.retry``.

Observability: ``serve_requests_total{outcome=ok|shed|error}``,
``serve_request_latency_us{phase=queue_wait|assemble|execute|total}``,
``serve_batch_occupancy`` (true requests / bucket batch), and
``serve_retries_total`` on the global registry, plus trace spans
``serve.enqueue`` / ``serve.batch`` / ``serve.execute`` when tracing is
enabled.
"""
from __future__ import annotations

import dataclasses
import itertools
import queue as _queue
import threading
import time
from collections.abc import Callable
from concurrent.futures import Future

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.distributed import fault
from repro.engine.programs import ProgramCache
from repro.obs import trace as _trace
from repro.runtime.steps import ensure_spmm_plans

from .buckets import BucketLadder, pack

_requests_total = obs.registry.counter(
    "serve_requests_total", "served requests by outcome",
    labels=("outcome",))
_latency = obs.registry.histogram(
    "serve_request_latency_us", "per-request serving latency by phase",
    labels=("phase",))
_batch_occupancy = obs.registry.histogram(
    "serve_batch_occupancy",
    "true requests / bucket batch per executed batch")
_retries_total = obs.registry.counter(
    "serve_retries_total", "transient execution failures retried")

_server_ids = itertools.count()


class RequestShed(RuntimeError):
    """Request dropped by admission control (queue full or deadline)."""


class ServerClosed(RuntimeError):
    """submit() after stop()."""


@dataclasses.dataclass
class _Pending:
    tokens: np.ndarray
    length: int
    deadline: float | None          # absolute perf_counter time
    future: Future
    t_submit: float
    t_dequeue: float = 0.0


class Server:
    """Async request queue + continuous batcher over bucket programs.

    ``forward(state, tokens)`` is the jit-able request scorer: ``tokens``
    is ``(batch, length) int32`` (right-padded with ``pad_id``), the
    output's leading axes are ``(batch, length, ...)`` and each row must
    depend only on its own tokens (true for causal models and for
    row-independent SpMM scoring) — that independence is what makes a
    packed request bit-identical to a solo forward at the same bucket
    shape.  ``state`` is the parameter pytree; ``warmup`` re-attaches
    engine-cached SpMM plans to every sparse leaf before compiling, so
    plans are built once, outside every program.

    ``submit`` is thread-safe and non-blocking: it returns a
    ``concurrent.futures.Future`` resolving to the request's output rows
    (trimmed to its true length) or raising :class:`RequestShed` /
    the execution error.
    """

    def __init__(self, forward: Callable, state, ladder: BucketLadder, *,
                 queue_depth: int = 256, batch_window_s: float = 0.002,
                 default_deadline_s: float | None = None,
                 retry_attempts: int = 3, retry_backoff_s: float = 0.05,
                 transient: tuple = (OSError,), pad_id: int = 0,
                 trim: bool = True, poll_s: float = 0.05,
                 name: str | None = None):
        self.ladder = ladder
        self.state = state
        self.queue_depth = queue_depth
        self.batch_window_s = batch_window_s
        self.default_deadline_s = default_deadline_s
        self.retry_attempts = retry_attempts
        self.retry_backoff_s = retry_backoff_s
        self.transient = transient
        self.pad_id = pad_id
        self.trim = trim
        self.name = name if name is not None else \
            f"server{next(_server_ids)}"
        self.programs = ProgramCache(name=f"{self.name}.programs")
        self._jitted = jax.jit(forward)
        self._q: _queue.Queue = _queue.Queue(maxsize=queue_depth)
        self._poll_s = poll_s
        self._stop = threading.Event()
        self._closed = False
        self._thread: threading.Thread | None = None
        self._warm_misses: int | None = None

    # ------------------------------------------------------------ warmup ---

    def _program(self, batch: int, length: int):
        def build():
            tok = jax.ShapeDtypeStruct((batch, length), jnp.int32)
            return self._jitted.lower(self.state, tok).compile()

        return self.programs.get((batch, length), build)

    def warmup(self) -> "Server":
        """Build every SpMM plan and compile every bucket program.

        Idempotent; records the post-warmup miss count so
        :meth:`recompiles` can assert the steady state compiled nothing.
        """
        shapes = self.ladder.shapes()
        with _trace.span("serve.warmup", cat="serve",
                         buckets=len(shapes)):
            self.state = ensure_spmm_plans(self.state)
            for b, s in shapes:
                self._program(b, s)
        self._warm_misses = self.programs.stats().misses
        return self

    def recompiles(self) -> int:
        """Program-cache misses since :meth:`warmup` (0 = the bucket
        ladder covered every served shape)."""
        warm = self._warm_misses if self._warm_misses is not None else 0
        return self.programs.stats().misses - warm

    def probe(self, batch: int, length: int) -> float:
        """One warm call at a bucket shape; returns seconds (rate
        calibration for load generators)."""
        prog = self._program(batch, length)
        tok = jnp.full((batch, length), self.pad_id, jnp.int32)
        t0 = time.perf_counter()
        jax.block_until_ready(self._call_program(prog, tok))
        return time.perf_counter() - t0

    # ------------------------------------------------------- client side ---

    def submit(self, tokens, *, deadline_s: float | None = None) -> Future:
        """Enqueue one request (a 1-D int token array) for batching.

        Sheds immediately (future raises :class:`RequestShed`) when the
        queue is at depth; ``deadline_s`` (default: the server's
        ``default_deadline_s``) sheds at dequeue when already expired.
        """
        if self._closed:
            raise ServerClosed(f"server {self.name} is stopped")
        tokens = np.asarray(tokens)
        if tokens.ndim != 1:
            raise ValueError(
                f"submit takes one request — a 1-D token array — got "
                f"shape {tokens.shape}")
        length = int(tokens.shape[0])
        self.ladder.length_bucket(length)       # admission: length cap
        now = time.perf_counter()
        limit = deadline_s if deadline_s is not None \
            else self.default_deadline_s
        p = _Pending(tokens=tokens.astype(np.int32), length=length,
                     deadline=None if limit is None else now + limit,
                     future=Future(), t_submit=now)
        try:
            self._q.put_nowait(p)
        except _queue.Full:
            self._shed(p, f"queue full (depth {self.queue_depth})")
            return p.future
        if _trace._enabled:
            _trace.event("serve.enqueue", cat="serve", length=length,
                         depth=self._q.qsize())
        return p.future

    # ---------------------------------------------------------- batcher ---

    def start(self) -> "Server":
        """Warm up (if not yet) and launch the batcher thread."""
        if self._thread is not None:
            raise RuntimeError(f"server {self.name} already started")
        if self._warm_misses is None:
            self.warmup()
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._loop, name=f"{self.name}.batcher", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        """Stop accepting requests, drain the queue, join the batcher."""
        self._closed = True
        self._stop.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _loop(self) -> None:
        while True:
            try:
                first = self._q.get(timeout=self._poll_s)
            except _queue.Empty:
                if self._stop.is_set():
                    return
                continue
            first.t_dequeue = time.perf_counter()
            batch = [first]
            # Continuous assembly: after the first request, keep
            # draining until the window closes or the largest batch
            # bucket fills — the window trades a bounded latency add
            # for occupancy under bursty arrivals.
            t_close = first.t_dequeue + self.batch_window_s
            while len(batch) < self.ladder.max_batch:
                left = t_close - time.perf_counter()
                try:
                    p = (self._q.get_nowait() if left <= 0
                         else self._q.get(timeout=left))
                except _queue.Empty:
                    break
                p.t_dequeue = time.perf_counter()
                batch.append(p)
            self._serve_batch(batch)

    def _serve_batch(self, batch: list[_Pending]) -> None:
        now = time.perf_counter()
        live: list[_Pending] = []
        for p in batch:
            if p.deadline is not None and now > p.deadline:
                self._shed(p, "deadline expired before execution")
            else:
                live.append(p)
        if not live:
            return
        for pb in pack([p.length for p in live], self.ladder):
            self._execute(pb.batch, pb.length,
                          [live[i] for i in pb.indices])

    def _execute(self, bb: int, lb: int, ps: list[_Pending]) -> None:
        t_asm0 = time.perf_counter()
        with _trace.span("serve.batch", cat="serve", batch=bb, length=lb,
                         fill=len(ps)):
            tok = np.full((bb, lb), self.pad_id, np.int32)
            for i, p in enumerate(ps):
                tok[i, :p.length] = p.tokens
            tok = jnp.asarray(tok)
            program = self._program(bb, lb)
        _batch_occupancy.observe(len(ps) / bb)
        t_exec0 = time.perf_counter()
        try:
            with _trace.span("serve.execute", cat="serve", batch=bb,
                             length=lb):
                out = fault.retry(
                    lambda: jax.block_until_ready(
                        self._call_program(program, tok)),
                    attempts=self.retry_attempts,
                    backoff=self.retry_backoff_s,
                    exceptions=self.transient, on_retry=self._on_retry)
        except Exception as e:
            # Futures must never hang: the whole bucket batch fails
            # together once retries are exhausted.
            for p in ps:
                _requests_total.labels(outcome="error").inc()
                p.future.set_exception(e)
            return
        t_done = time.perf_counter()
        for i, p in enumerate(ps):
            _latency.labels(phase="queue_wait").observe(
                (p.t_dequeue - p.t_submit) * 1e6)
            _latency.labels(phase="assemble").observe(
                (t_exec0 - t_asm0) * 1e6)
            _latency.labels(phase="execute").observe(
                (t_done - t_exec0) * 1e6)
            _latency.labels(phase="total").observe(
                (t_done - p.t_submit) * 1e6)
            _requests_total.labels(outcome="ok").inc()
            p.future.set_result(self._slice(out, i, p.length))

    def _call_program(self, program, tokens):
        """One compiled-program invocation (override point for fault
        injection in tests)."""
        return program(self.state, tokens)

    def _slice(self, out, i: int, length: int):
        def g(x):
            x = x[i]
            if self.trim and getattr(x, "ndim", 0) >= 1:
                x = x[:length]
            return x

        return jax.tree.map(g, out)

    def _on_retry(self, attempt: int, exc: Exception) -> None:
        _retries_total.inc()
        if _trace._enabled:
            _trace.event("serve.retry", cat="serve", attempt=attempt,
                         error=type(exc).__name__)

    def _shed(self, p: _Pending, why: str) -> None:
        _requests_total.labels(outcome="shed").inc()
        if _trace._enabled:
            _trace.event("serve.shed", cat="serve", length=p.length,
                         why=why)
        p.future.set_exception(RequestShed(why))
