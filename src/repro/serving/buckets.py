"""Shape buckets for online serving: the pre-compiled program ladder.

Ragged requests (arbitrary prompt length, arbitrary count per batch)
cannot each get their own compiled program — shape-polymorphic serving
would recompile on every new ``(batch, length)`` pair, and a compile is
orders of magnitude slower than the forward it serves.  The ladder
quantizes both axes to a small geometric set of buckets: a request of
length ``L`` runs in the smallest length bucket ``>= L``, and a group of
``R`` requests runs at the smallest batch bucket ``>= R``, so the whole
open stream is served by ``len(lengths) * len(batches)`` programs, all
compiled once at startup (``Server.warmup``).  Power-of-two spacing
bounds the padding waste: above the ladder floor a bucket is always
``< 2x`` its occupant on each axis, so the padded area is ``< 4x`` the
true work.

:func:`pack` is the pure batcher core — property-tested in
``tests/test_serving_property.py`` (fixed-seed twins in
``tests/test_serving.py``): every request lands in exactly one packed
batch, FIFO order is preserved within a length bucket, and bucket
rounding is bounded by the ladder geometry.
"""
from __future__ import annotations

import dataclasses
from collections.abc import Sequence


def _pow2_rungs(lo: int, hi: int) -> tuple[int, ...]:
    """``lo``, then doublings until the rung covers ``hi``."""
    if lo <= 0 or hi <= 0:
        raise ValueError(f"ladder bounds must be positive, got {lo}..{hi}")
    rungs = [lo]
    while rungs[-1] < hi:
        rungs.append(rungs[-1] * 2)
    return tuple(rungs)


@dataclasses.dataclass(frozen=True)
class BucketLadder:
    """The static (length, batch) bucket grid a server compiles against.

    ``lengths``/``batches`` are strictly ascending; the largest rung on
    each axis is the admission-control hard cap — a request longer than
    ``max_len`` is rejected at ``submit`` rather than silently truncated.
    """

    lengths: tuple[int, ...]
    batches: tuple[int, ...]

    def __post_init__(self):
        for name, axis in (("lengths", self.lengths),
                           ("batches", self.batches)):
            axis = tuple(int(x) for x in axis)
            object.__setattr__(self, name, axis)
            if not axis:
                raise ValueError(f"BucketLadder: {name} is empty")
            if any(x <= 0 for x in axis):
                raise ValueError(
                    f"BucketLadder: {name} must be positive, got {axis}")
            if list(axis) != sorted(set(axis)):
                raise ValueError(
                    f"BucketLadder: {name} must be strictly ascending, "
                    f"got {axis}")

    @classmethod
    def from_max(cls, max_len: int, max_batch: int, *, min_len: int = 8,
                 min_batch: int = 1) -> "BucketLadder":
        """Power-of-two ladder covering requests up to ``max_len`` tokens
        packed up to ``max_batch`` at a time."""
        return cls(lengths=_pow2_rungs(min(min_len, max_len), max_len),
                   batches=_pow2_rungs(min(min_batch, max_batch),
                                       max_batch))

    @property
    def max_len(self) -> int:
        return self.lengths[-1]

    @property
    def max_batch(self) -> int:
        return self.batches[-1]

    def _bucket(self, axis: tuple[int, ...], n: int, what: str) -> int:
        if n <= 0:
            raise ValueError(f"{what} must be positive, got {n}")
        for rung in axis:
            if rung >= n:
                return rung
        raise ValueError(
            f"{what} {n} exceeds the largest bucket {axis[-1]} — "
            "grow the ladder or shed the request")

    def length_bucket(self, length: int) -> int:
        """Smallest length rung >= ``length`` (raises above ``max_len``)."""
        return self._bucket(self.lengths, length, "request length")

    def batch_bucket(self, count: int) -> int:
        """Smallest batch rung >= ``count`` (raises above ``max_batch``)."""
        return self._bucket(self.batches, count, "batch count")

    def shapes(self) -> tuple[tuple[int, int], ...]:
        """Every ``(batch, length)`` program shape, warmup order."""
        return tuple((b, s) for s in self.lengths for b in self.batches)


@dataclasses.dataclass(frozen=True)
class PackedBatch:
    """One executable group: ``indices`` into the gathered request list,
    padded to the ``(batch, length)`` bucket shape."""

    length: int
    batch: int
    indices: tuple[int, ...]


def pack(lengths: Sequence[int], ladder: BucketLadder) -> list[PackedBatch]:
    """Assign each request (by its token length) to a padded bucket batch.

    Requests group by length bucket in first-arrival order; each group
    splits into FIFO chunks of at most ``ladder.max_batch`` and each
    chunk's batch axis rounds up to its batch bucket.  Every index
    appears in exactly one :class:`PackedBatch`.
    """
    groups: dict[int, list[int]] = {}
    for i, n in enumerate(lengths):
        groups.setdefault(ladder.length_bucket(n), []).append(i)
    out: list[PackedBatch] = []
    for lb, idxs in groups.items():
        for s in range(0, len(idxs), ladder.max_batch):
            chunk = idxs[s:s + ladder.max_batch]
            out.append(PackedBatch(length=lb,
                                   batch=ladder.batch_bucket(len(chunk)),
                                   indices=tuple(chunk)))
    return out
