"""Synthetic Poisson load for the serving benchmark and smoke tests.

Open-loop arrivals: inter-arrival gaps are exponential at the offered
rate and do **not** wait for completions, so under overload the queue
grows and admission control (not the generator) decides who gets served
— the regime where continuous batching earns its throughput.  The
schedule is fully determined by its seed (``random.Random``, no global
RNG), so a test can replay the exact same arrival tape against two
servers and compare outcomes request-for-request.
"""
from __future__ import annotations

import dataclasses
import random
import time
from collections.abc import Sequence
from concurrent.futures import Future

import numpy as np

from .server import RequestShed, Server


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One scheduled request: arrival offset (s) and prompt length."""

    at_s: float
    length: int


def poisson_schedule(n: int, rate_rps: float,
                     lengths: tuple[int, int],
                     seed: int = 0) -> list[Arrival]:
    """``n`` arrivals at ``rate_rps`` with lengths uniform in
    ``lengths`` (inclusive), deterministic under ``seed``."""
    if n <= 0:
        raise ValueError(f"need a positive request count, got {n}")
    if rate_rps <= 0:
        raise ValueError(f"need a positive rate, got {rate_rps}")
    lo, hi = lengths
    rng = random.Random(seed)
    t = 0.0
    out: list[Arrival] = []
    for _ in range(n):
        t += rng.expovariate(rate_rps)
        out.append(Arrival(at_s=t, length=rng.randint(lo, hi)))
    return out


def make_tokens(length: int, vocab: int, seed: int) -> np.ndarray:
    """Deterministic token ids for one request."""
    rng = np.random.default_rng(seed)
    return rng.integers(0, vocab, size=(length,), dtype=np.int32)


@dataclasses.dataclass
class LoadReport:
    """Outcome of one load run; latencies cover ok requests only."""

    n: int
    ok: int
    shed: int
    error: int
    wall_s: float
    throughput_rps: float
    p50_us: float
    p99_us: float

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def run_load(server: Server, schedule: Sequence[Arrival], *,
             vocab: int, deadline_s: float | None = None,
             seed: int = 0) -> LoadReport:
    """Replay ``schedule`` against a started server; block until every
    future resolves and aggregate outcomes + client-side latency."""
    t0 = time.perf_counter()
    done_at: dict[int, float] = {}
    futures: list[tuple[int, float, Future]] = []
    for i, a in enumerate(schedule):
        delay = (t0 + a.at_s) - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        tokens = make_tokens(a.length, vocab, seed=seed * 100003 + i)
        t_sub = time.perf_counter()
        fut = server.submit(tokens, deadline_s=deadline_s)
        fut.add_done_callback(
            lambda _f, i=i: done_at.__setitem__(i, time.perf_counter()))
        futures.append((i, t_sub, fut))
    ok = shed = error = 0
    lat_us: list[float] = []
    for i, t_sub, fut in futures:
        try:
            fut.result()
        except RequestShed:
            shed += 1
            continue
        except Exception:
            error += 1
            continue
        ok += 1
        lat_us.append((done_at[i] - t_sub) * 1e6)
    wall = time.perf_counter() - t0
    lat = np.asarray(lat_us) if lat_us else np.asarray([0.0])
    return LoadReport(
        n=len(schedule), ok=ok, shed=shed, error=error, wall_s=wall,
        throughput_rps=ok / wall if wall > 0 else 0.0,
        p50_us=float(np.percentile(lat, 50)),
        p99_us=float(np.percentile(lat, 99)))
