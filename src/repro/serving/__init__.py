"""Online serving: continuous batching over pre-compiled shape buckets.

    ladder = serving.BucketLadder.from_max(max_len=128, max_batch=8)
    server = serving.Server(forward, params, ladder).start()
    fut = server.submit(tokens)            # 1-D int array, any length
    out = fut.result()                     # rows trimmed to true length
    assert server.recompiles() == 0        # ladder covered the stream
    server.stop()

``buckets`` holds the pure ladder/packer core, ``server`` the queue +
batcher + admission control + AOT program warmup, ``loadgen`` the
deterministic Poisson driver used by ``benchmarks/bench_serving.py``.
"""
from . import loadgen
from .buckets import BucketLadder, PackedBatch, pack
from .server import RequestShed, Server, ServerClosed

__all__ = ["BucketLadder", "PackedBatch", "RequestShed", "Server",
           "ServerClosed", "loadgen", "pack"]
