"""Engine-wide observability: tracing, metrics, roofline accounting.

Three legs, all zero-cost when disabled:

* :mod:`repro.obs.trace` — scoped spans/instant events in a ring buffer,
  exportable as Chrome trace-event JSON (Perfetto-viewable).  Enable via
  ``REPRO_TRACE=1``, :func:`enable`, or ``with obs.tracing(): ...``.
* :mod:`repro.obs.metrics` — typed counter/gauge/histogram families with
  labels on the process-global :data:`registry`; ``report()`` /
  ``dump_metrics()`` expose them.
* :mod:`repro.obs.roofline` — bytes/flops models + the global
  :data:`accountant` relating measured wall time to modeled minimum
  traffic, as a fraction of a measured streaming roof.

This package imports only the stdlib at module load (jax is imported
lazily inside the roofline calibrator and profiler annotations), so core
engine modules may import it freely without cycles.
"""
from __future__ import annotations

from . import trace as trace
from .metrics import (Counter, Gauge, Histogram, MetricFamily,
                      MetricsRegistry)
from .roofline import (Roof, RooflineAccountant, fused_epilogue_ceiling,
                       measure_roof, plan_bwd_min_bytes, plan_min_bytes,
                       sddmm_min_bytes, spmm_flops, spmm_min_bytes)
from .trace import (Tracer, disable, enable, event, get_tracer, is_enabled,
                    span, tracing)

# Process-global instances: instrumentation sites across the engine share
# these without import-order coupling.
registry = MetricsRegistry()
accountant = RooflineAccountant()

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricFamily", "MetricsRegistry",
    "Roof", "RooflineAccountant", "Tracer", "accountant", "disable",
    "dump_metrics", "enable", "event", "fused_epilogue_ceiling",
    "get_tracer", "is_enabled", "measure_roof", "plan_bwd_min_bytes",
    "plan_min_bytes", "registry", "report", "reset", "sddmm_min_bytes",
    "span", "spmm_flops", "spmm_min_bytes", "trace", "tracing",
]


def dump_metrics(path: str, *, extra: dict | None = None) -> str:
    """Write the global registry snapshot as JSON; returns the path."""
    return registry.dump(path, extra=extra)


def _rung_rates() -> dict[str, float]:
    """Ladder-rung hit rates from ``plan_resolve_total``, as fractions."""
    fam = registry.get("plan_resolve_total")
    if fam is None:
        return {}
    by_rung: dict[str, int] = {}
    for c in fam.children():
        rung = c.labels.get("rung", "?")
        by_rung[rung] = by_rung.get(rung, 0) + c.value
    total = sum(by_rung.values())
    if total == 0:
        return {}
    return {r: n / total for r, n in sorted(by_rung.items())}


def report(*, roof: Roof | None = None) -> str:
    """Text snapshot of the whole subsystem: metrics exposition,
    ladder-rung hit rates, and the roofline accountant's verdicts.

    Pass a :class:`Roof` (from :func:`measure_roof`) to get
    percent-of-roof numbers; omitted, achieved bandwidth still prints.
    """
    parts = []
    rates = _rung_rates()
    if rates:
        parts.append("== resolution ladder ==")
        parts.append("  ".join(f"{r}={v * 100:.1f}%"
                               for r, v in rates.items()))
    m = registry.report()
    if m:
        parts.append("== metrics ==")
        parts.append(m)
    parts.append("== roofline ==")
    parts.append(accountant.report(roof))
    tr = get_tracer()
    if tr is not None:
        parts.append(f"== trace == {len(tr)} events buffered"
                     + (f" ({tr.dropped} dropped)" if tr.dropped else ""))
    return "\n".join(parts)


def reset() -> None:
    """Zero metrics + roofline entries; clear the tracer ring (tests)."""
    registry.reset()
    accountant.reset()
    tr = get_tracer()
    if tr is not None:
        tr.clear()
