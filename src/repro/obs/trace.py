"""Structured tracing: scoped spans + instant events in a ring buffer.

Zero-cost when disabled: every instrumentation site guards on the
module-level ``_enabled`` flag (one attribute read), so the engine's warm
execute path pays nothing while observability is off.  When enabled
(``REPRO_TRACE=1`` in the environment, :func:`enable`, or the
:func:`tracing` context manager), spans land in a bounded ring buffer as
Chrome trace-event records — exportable with :meth:`Tracer.export` and
viewable in Perfetto / ``chrome://tracing``.

Spans double as ``jax.profiler.TraceAnnotation`` scopes (when jax is
importable), so host-side engine phases — plan resolution, plan builds,
kernel dispatch — line up against XLA device activity inside a
``jax.profiler.trace`` capture.

The emitting sites (see ``core/config.py``, ``engine/cache.py``,
``core/spmm.py``, ``distributed/spmm.py``) use four categories:

* ``plan``     — ``PlanPolicy.resolve`` (which ladder rung fired),
  ``plan.build``, sharded plan assembly,
* ``cache``    — plan-cache hit / miss / eviction,
* ``dispatch`` — kernel dispatch (method, impl, dtypes, epilogue, tk),
* ``serve`` / ``train`` — launcher request/step scopes.
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import deque

DEFAULT_CAPACITY = 65536

# Fast-path flag: instrumentation sites read this attribute directly.
_enabled: bool = False
_tracer: "Tracer" | None = None
_lock = threading.Lock()


def _now_us() -> float:
    return time.perf_counter_ns() / 1e3


class Tracer:
    """Bounded ring buffer of Chrome trace events (thread-safe appends).

    Events are dicts in the Chrome trace-event format: complete spans
    (``ph="X"`` with ``ts``/``dur`` in µs) and instant events
    (``ph="i"``).  The ring (``capacity`` events) keeps a long traced
    serving session bounded: old events fall off the front.
    """

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        self.capacity = capacity
        self._events: deque = deque(maxlen=capacity)
        self._elock = threading.Lock()
        self._pid = os.getpid()
        self.dropped = 0

    def record(self, ev: dict) -> None:
        with self._elock:
            if len(self._events) == self.capacity:
                self.dropped += 1
            self._events.append(ev)

    def add_complete(self, name: str, cat: str, ts_us: float, dur_us: float,
                     args: dict) -> None:
        self.record({"name": name, "cat": cat or "default", "ph": "X",
                     "ts": ts_us, "dur": dur_us, "pid": self._pid,
                     "tid": threading.get_ident(), "args": args})

    def add_instant(self, name: str, cat: str, args: dict) -> None:
        self.record({"name": name, "cat": cat or "default", "ph": "i",
                     "ts": _now_us(), "pid": self._pid,
                     "tid": threading.get_ident(), "s": "t", "args": args})

    def events(self, *, cat: str | None = None,
               name: str | None = None) -> list:
        """Snapshot of the ring, optionally filtered by category/name."""
        with self._elock:
            evs = list(self._events)
        if cat is not None:
            evs = [e for e in evs if e.get("cat") == cat]
        if name is not None:
            evs = [e for e in evs if e.get("name") == name]
        return evs

    def clear(self) -> None:
        with self._elock:
            self._events.clear()
            self.dropped = 0

    def __len__(self) -> int:
        with self._elock:
            return len(self._events)

    def chrome_trace(self) -> dict:
        """The ring as a Chrome trace-event JSON object."""
        return {"traceEvents": self.events(), "displayTimeUnit": "ms",
                "otherData": {"producer": "repro.obs",
                              "dropped_events": self.dropped}}

    def export(self, path: str) -> str:
        """Write the Chrome trace JSON to ``path`` (returns the path)."""
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
        return path


# ------------------------------------------------------------ span scopes ---


def _annotation(name: str):
    """A jax.profiler.TraceAnnotation for ``name``, or None off-jax."""
    try:
        import jax.profiler
        return jax.profiler.TraceAnnotation(name)
    except Exception:
        return None


class _Span:
    """A live span: records a complete ("X") event on exit.

    ``set(**kw)`` adds args after entry (e.g. the resolution rung, known
    only mid-body).  Also enters a ``jax.profiler.TraceAnnotation`` so the
    span shows up inside XLA profiler captures.
    """

    __slots__ = ("name", "cat", "args", "_t0", "_ann")

    def __init__(self, name: str, cat: str, args: dict):
        self.name = name
        self.cat = cat
        self.args = args
        self._t0 = 0.0
        self._ann = None

    def set(self, **kw) -> None:
        self.args.update(kw)

    def __enter__(self) -> "_Span":
        self._ann = _annotation(self.name)
        if self._ann is not None:
            try:
                self._ann.__enter__()
            except Exception:
                self._ann = None
        self._t0 = _now_us()
        return self

    def __exit__(self, *exc) -> None:
        t1 = _now_us()
        if self._ann is not None:
            try:
                self._ann.__exit__(*exc)
            except Exception:
                pass
        tr = _tracer
        if tr is not None:
            tr.add_complete(self.name, self.cat, self._t0, t1 - self._t0,
                            self.args)


class _NullSpan:
    """Disabled-path span: a shared, do-nothing context manager."""

    __slots__ = ()

    def set(self, **kw) -> None:
        pass

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


_NULL_SPAN = _NullSpan()


def span(name: str, cat: str = "", **args):
    """A scoped span — ``with obs.span("plan.build", cat="plan", ...):``.

    Returns a shared null context when tracing is disabled (no event, no
    timestamps, no annotation)."""
    if not _enabled:
        return _NULL_SPAN
    return _Span(name, cat, args)


def event(name: str, cat: str = "", **args) -> None:
    """An instant event (no duration). No-op when tracing is disabled."""
    if not _enabled:
        return
    tr = _tracer
    if tr is not None:
        tr.add_instant(name, cat, args)


# ------------------------------------------------------------- lifecycle ---


def is_enabled() -> bool:
    return _enabled


def get_tracer() -> Tracer | None:
    """The active Tracer, or None when tracing is disabled."""
    return _tracer


def enable(capacity: int = DEFAULT_CAPACITY) -> Tracer:
    """Turn tracing on (idempotent); returns the active Tracer."""
    global _enabled, _tracer
    with _lock:
        if _tracer is None:
            _tracer = Tracer(capacity)
        _enabled = True
        return _tracer


def disable() -> None:
    """Turn tracing off. The tracer (and its events) stay readable."""
    global _enabled
    with _lock:
        _enabled = False


class _Tracing:
    """``with obs.tracing() as tracer:`` — scoped enable/restore."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self._prev: tuple | None = None

    def __enter__(self) -> Tracer:
        global _enabled, _tracer
        with _lock:
            self._prev = (_enabled, _tracer)
            _tracer = Tracer(self.capacity)
            _enabled = True
            return _tracer

    def __exit__(self, *exc) -> None:
        global _enabled, _tracer
        with _lock:
            _enabled, _tracer = self._prev


def tracing(capacity: int = DEFAULT_CAPACITY) -> _Tracing:
    """Context manager: enable tracing with a fresh Tracer, restore the
    previous state (including a previously active tracer) on exit."""
    return _Tracing(capacity)


# REPRO_TRACE=1 (any non-empty value except "0") enables tracing at import
# — the launcher-facing switch; make trace-smoke uses it.
if os.environ.get("REPRO_TRACE", "") not in ("", "0"):
    enable()
