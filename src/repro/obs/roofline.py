"""Live roofline accountant: measured wall time vs. modeled minimum bytes.

The paper's verdict criterion is *distance to the memory-bandwidth roof*:
merge-based balancing and coalesced access matter exactly because SpMM at
interesting sparsities is bandwidth-bound.  This module turns that from
an offline benchmark argument into an engine-wide measurement:

* :func:`spmm_min_bytes` / :func:`plan_min_bytes` — the compulsory-traffic
  model (each operand/result crosses HBM once; moved here from
  ``benchmarks/roofline.py``, which now re-exports it),
* :func:`plan_bwd_min_bytes` / :func:`sddmm_min_bytes` — the same model
  for the custom-VJP backward (transpose-merge dB + SDDMM dvals), the
  floor the static traffic analyzer (``repro.analysis.traffic``) holds
  the backward programs against,
* :func:`measure_roof` — a streaming (copy-scale) benchmark calibrating
  the backend's achievable bandwidth once, cached under ``artifacts/``
  keyed by backend,
* :class:`RooflineAccountant` — per ``(kind, method, impl, dtype)`` key,
  accumulates measured wall time next to modeled minimum bytes and
  reports achieved bandwidth as a fraction of the measured roof:
  "kernel X ran at Y% of roof".

The fraction is a *lower bound* on efficiency (the model counts
compulsory bytes only; a kernel moving more than compulsory traffic looks
worse, never better), which is the honest direction for a verification
harness: the GPU/TPU port is judged by how close these numbers get to 1.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time

# ------------------------------------------------------ bytes/flops model ---


def spmm_min_bytes(m: int, k: int, n: int, nnz: int, *, val_bytes: int = 4,
                   idx_bytes: int = 4, out_bytes: int = 4) -> int:
    """Compulsory traffic of one CSR SpMM: vals + col indices once, the
    dense B panel once, the output C once."""
    return (nnz * (val_bytes + idx_bytes) + k * n * val_bytes
            + m * n * out_bytes)


def epilogue_tail_bytes(m: int, n: int, *, out_bytes: int = 4,
                        bias: bool = False, residual: bool = False) -> int:
    """Traffic of a *separate* elementwise tail program: read C, read the
    epilogue operands, write the result."""
    extra = (m * out_bytes if bias else 0) + \
        (m * n * out_bytes if residual else 0)
    return 2 * m * n * out_bytes + extra


def fused_epilogue_ceiling(m: int, k: int, n: int, nnz: int, *,
                           val_bytes: int = 4, out_bytes: int = 4,
                           bias: bool = True,
                           residual: bool = False) -> float:
    """Bytes-moved speedup ceiling of fusing the tail into the SpMM."""
    spmm = spmm_min_bytes(m, k, n, nnz, val_bytes=val_bytes,
                          out_bytes=out_bytes)
    tail = epilogue_tail_bytes(m, n, out_bytes=out_bytes, bias=bias,
                               residual=residual)
    fused_extra = (m * out_bytes if bias else 0) + \
        (m * n * out_bytes if residual else 0)
    return (spmm + tail) / (spmm + fused_extra)


_DTYPE_BYTES = {"float64": 8, "float32": 4, "bfloat16": 2, "float16": 2}


def _dtype_bytes(name: str | None) -> int:
    return _DTYPE_BYTES.get(str(name), 4)


def plan_min_bytes(meta, n: int, *, val_dtype: str = "float32",
                   out_dtype: str | None = None, batch: int = 1,
                   epilogue=None, b_dtype: str | None = None) -> int:
    """Compulsory bytes of executing a plan against an n-column B.

    ``meta`` is a ``core.plan.PlanMeta`` or ``distributed.spmm.
    ShardedMeta`` — both carry ``shape`` and ``nnz_pad`` (the static
    nonzero capacity the kernels actually stream, padding included).
    ``batch`` scales the dense legs (B, C, a flagged residual);
    ``b_dtype`` widens/narrows the B leg independently of the values
    (defaults to ``val_dtype``); a fused ``epilogue`` adds its operand
    reads (bias once, residual per batch).  The old
    ``plan_min_bytes(meta, n, val_dtype=..., out_dtype=...)`` spelling
    is unchanged.
    """
    m, k = meta.shape
    vb = _dtype_bytes(val_dtype)
    bb = _dtype_bytes(b_dtype or val_dtype)
    ob = _dtype_bytes(out_dtype or val_dtype)
    total = (meta.nnz_pad * (vb + 4) + batch * k * n * bb
             + batch * m * n * ob)
    if epilogue is not None:
        if getattr(epilogue, "bias", False):
            total += m * bb
        if getattr(epilogue, "residual", False):
            total += batch * m * n * bb
    return total


def sddmm_min_bytes(nnz: int, m: int, k: int, n: int, *, batch: int = 1,
                    dc_dtype: str = "float32",
                    b_dtype: str = "float32") -> int:
    """Compulsory traffic of the SDDMM values-cotangent pass: read the
    output cotangent and B once, the nonzero coordinate streams once,
    write one f32 value per nonzero (``kernels.sddmm``)."""
    dcb = _dtype_bytes(dc_dtype)
    bb = _dtype_bytes(b_dtype)
    return (batch * m * n * dcb + batch * k * n * bb
            + nnz * (4 + 4) + nnz * 4)


def plan_bwd_min_bytes(meta, n: int, *, val_dtype: str = "float32",
                       b_dtype: str | None = None,
                       batch: int = 1) -> int:
    """Compulsory *extra* bytes of the custom-VJP backward, on top of
    the forward: the transpose-merge dB pass (stream the transposed
    structure and values, read the f32 output cotangent, write dB in
    B's dtype) plus the SDDMM dvals pass (:func:`sddmm_min_bytes`).
    The static traffic analyzer holds the traced fwd+bwd program
    against ``plan_min_bytes + plan_bwd_min_bytes``.
    """
    m, k = meta.shape
    vb = _dtype_bytes(val_dtype)
    bb = _dtype_bytes(b_dtype or val_dtype)
    db = (meta.nnz_pad * (vb + 4) + batch * m * n * 4
          + batch * k * n * bb)
    return db + sddmm_min_bytes(meta.nnz_pad, m, k, n, batch=batch,
                                b_dtype=b_dtype or val_dtype)


def spmm_flops(nnz: int, n: int) -> float:
    """Useful flops of one SpMM: a multiply-add per (nonzero, column)."""
    return 2.0 * nnz * n


# ------------------------------------------------------- roof calibration ---


@dataclasses.dataclass(frozen=True)
class Roof:
    """A backend's measured streaming-bandwidth roof."""

    backend: str
    bytes_per_s: float
    elements: int                  # array length of the calibration run
    source: str                    # "measured" | "cached"

    @property
    def gb_per_s(self) -> float:
        return self.bytes_per_s / 1e9


_ROOF_CACHE_FILE = "roofline_roof.json"
_roof_memo: dict[str, Roof] = {}
_roof_lock = threading.Lock()


def _measure_stream_bw(elements: int, repeat: int) -> float:
    """Best-case streaming bandwidth via a jitted copy-scale kernel.

    ``y = x * 1.5 + 0.25`` over an f32 array far larger than L2: one read
    + one write per element.  The *minimum* wall time over ``repeat``
    runs is the roof — the question is what the memory system can do, not
    what it does on an average run.
    """
    import jax
    import jax.numpy as jnp

    x = jnp.ones((elements,), jnp.float32)
    f = jax.jit(lambda x: x * 1.5 + 0.25)
    jax.block_until_ready(f(x))           # compile
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(f(x))
        best = min(best, time.perf_counter() - t0)
    return 2.0 * elements * 4 / best


def measure_roof(*, cache_dir: str = "artifacts", force: bool = False,
                 elements: int = 1 << 24, repeat: int = 5) -> Roof:
    """The backend's streaming roof, calibrated once and cached.

    Cached two ways: in-process (per backend) and in
    ``<cache_dir>/roofline_roof.json`` so every bench/serve run on this
    machine shares one calibration.  ``force`` re-measures.
    ``cache_dir=None`` skips the file cache.
    """
    import jax

    backend = jax.default_backend()
    with _roof_lock:
        memo = _roof_memo.get(backend)
    if memo is not None and not force:
        return memo
    path = (os.path.join(cache_dir, _ROOF_CACHE_FILE)
            if cache_dir else None)
    if path and not force and os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
            rec = data.get(backend)
            if rec and rec.get("bytes_per_s", 0) > 0:
                roof = Roof(backend=backend,
                            bytes_per_s=float(rec["bytes_per_s"]),
                            elements=int(rec.get("elements", elements)),
                            source="cached")
                with _roof_lock:
                    _roof_memo[backend] = roof
                return roof
        except (OSError, ValueError, KeyError):
            pass                    # unreadable cache: re-measure
    bw = _measure_stream_bw(elements, repeat)
    roof = Roof(backend=backend, bytes_per_s=bw, elements=elements,
                source="measured")
    with _roof_lock:
        _roof_memo[backend] = roof
    if path:
        try:
            os.makedirs(cache_dir, exist_ok=True)
            data = {}
            if os.path.exists(path):
                try:
                    with open(path) as f:
                        data = json.load(f)
                except (OSError, ValueError):
                    data = {}
            data[backend] = {"bytes_per_s": bw, "elements": elements,
                             "measured_at": time.time()}
            with open(path, "w") as f:
                json.dump(data, f, indent=1)
        except OSError:
            pass                    # read-only checkout: memo still holds
    return roof


def clear_roof_memo() -> None:
    """Forget in-process roof calibrations (tests)."""
    with _roof_lock:
        _roof_memo.clear()


# ------------------------------------------------------------- accountant ---


@dataclasses.dataclass
class _Entry:
    calls: int = 0
    wall_us: float = 0.0
    min_bytes: float = 0.0
    flops: float = 0.0
    hlo_bytes: float = 0.0          # optional: parsed-HLO traffic


class RooflineAccountant:
    """Accumulates (measured wall, modeled bytes) per execution key.

    Keys are ``(kind, method, impl, dtype)`` tuples — e.g. ``("spmm",
    "merge", "xla", "float32")``.  Feed it from any site that owns a wall
    time for a known program: benchmark loops (``benchmarks/bench_obs``),
    serve sessions, tuner sweeps.
    """

    def __init__(self):
        self._entries: dict[tuple, _Entry] = {}
        self._lock = threading.Lock()

    def record(self, key: tuple, *, wall_us: float, min_bytes: float,
               flops: float = 0.0, calls: int = 1,
               hlo_bytes: float = 0.0) -> None:
        """Add ``calls`` executions totaling ``wall_us`` that each moved
        at least ``min_bytes / calls`` compulsory bytes."""
        with self._lock:
            e = self._entries.setdefault(tuple(key), _Entry())
            e.calls += calls
            e.wall_us += wall_us
            e.min_bytes += min_bytes
            e.flops += flops
            e.hlo_bytes += hlo_bytes

    def account_plan(self, meta, n: int, *, wall_us: float,
                     impl: str = "pallas", val_dtype: str = "float32",
                     out_dtype: str | None = None, calls: int = 1,
                     kind: str = "spmm", hlo_bytes: float = 0.0) -> None:
        """Record executions of a plan (``meta``: PlanMeta/ShardedMeta)
        against an n-column B, deriving bytes/flops from the model."""
        method = getattr(meta, "method", "?")
        per_call = plan_min_bytes(meta, n, val_dtype=val_dtype,
                                  out_dtype=out_dtype)
        self.record((kind, method, impl, str(val_dtype)),
                    wall_us=wall_us, min_bytes=per_call * calls,
                    flops=spmm_flops(meta.nnz_pad, n) * calls,
                    calls=calls, hlo_bytes=hlo_bytes)

    def rows(self, roof: Roof | None = None) -> list[dict]:
        """One dict per key: achieved bandwidth, roof fraction, flops."""
        with self._lock:
            items = sorted(self._entries.items())
        out = []
        for key, e in items:
            secs = e.wall_us / 1e6
            bw = e.min_bytes / secs if secs > 0 else 0.0
            row = {
                "kind": key[0],
                "method": key[1] if len(key) > 1 else "",
                "impl": key[2] if len(key) > 2 else "",
                "dtype": key[3] if len(key) > 3 else "",
                "calls": e.calls,
                "wall_us": e.wall_us,
                "min_bytes": e.min_bytes,
                "achieved_bytes_per_s": bw,
                "gflops_per_s": (e.flops / secs / 1e9) if secs > 0 else 0.0,
            }
            if e.hlo_bytes:
                row["hlo_bytes"] = e.hlo_bytes
            if roof is not None and roof.bytes_per_s > 0:
                row["roof_bytes_per_s"] = roof.bytes_per_s
                row["roof_fraction"] = bw / roof.bytes_per_s
            out.append(row)
        return out

    def report(self, roof: Roof | None = None) -> str:
        """Text verdicts: "kernel X ran at Y% of roof"."""
        rows = self.rows(roof)
        if not rows:
            return "roofline: no executions recorded"
        lines = []
        if roof is not None:
            lines.append(
                f"roofline roof ({roof.backend}, {roof.source}): "
                f"{roof.gb_per_s:.2f} GB/s streaming")
        for r in rows:
            head = (f"{r['kind']} {r['method']}/{r['impl']} {r['dtype']}: "
                    f"{r['achieved_bytes_per_s'] / 1e9:.2f} GB/s achieved")
            if "roof_fraction" in r:
                head += f" = {r['roof_fraction'] * 100:.1f}% of roof"
            head += (f" ({r['calls']} calls, "
                     f"{r['min_bytes'] / max(r['calls'], 1) / 1e6:.2f} "
                     "MB/call min)")
            lines.append(head)
        return "\n".join(lines)

    def reset(self) -> None:
        with self._lock:
            self._entries.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)
