"""Validate observability artifacts: Chrome traces and metrics dumps.

``make trace-smoke`` runs a traced serve+train smoke and then calls this
module on the emitted files — a malformed trace or an empty span set
fails CI instead of uploading a useless artifact.

CLI::

    python -m repro.obs.validate --trace artifacts/serve_trace.json \
        --require-cats plan,cache,dispatch \
        --metrics artifacts/serve_metrics.json

Exit code 0 on success, 1 with a diagnostic on the first failure.
"""
from __future__ import annotations

import argparse
import json
import sys

_SPAN_REQUIRED = ("name", "ph", "ts", "pid", "tid")


def validate_trace(path: str, *, require_cats: tuple[str, ...] = (),
                   min_events: int = 1) -> list[str]:
    """Schema-check a Chrome trace-event JSON file.

    Returns a list of problems (empty = valid): top-level shape,
    per-event required fields, ``ph=X`` events carrying a numeric
    ``dur``, at least ``min_events`` events, and at least one event in
    every category named in ``require_cats``.
    """
    problems: list[str] = []
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        return [f"{path}: unreadable or not JSON: {e}"]
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return [f"{path}: missing top-level 'traceEvents'"]
    evs = doc["traceEvents"]
    if not isinstance(evs, list):
        return [f"{path}: 'traceEvents' is not a list"]
    if len(evs) < min_events:
        problems.append(
            f"{path}: only {len(evs)} events (< {min_events} required)")
    cats = set()
    for i, ev in enumerate(evs):
        if not isinstance(ev, dict):
            problems.append(f"{path}: event[{i}] is not an object")
            continue
        for k in _SPAN_REQUIRED:
            if k not in ev:
                problems.append(f"{path}: event[{i}] missing {k!r}")
        if ev.get("ph") == "X" and not isinstance(
                ev.get("dur"), (int, float)):
            problems.append(
                f"{path}: event[{i}] ph=X without numeric 'dur'")
        cats.add(ev.get("cat", ""))
    for c in require_cats:
        if c not in cats:
            problems.append(
                f"{path}: no events in required category {c!r} "
                f"(saw: {sorted(cats)})")
    return problems


def validate_metrics(path: str, *, require_names: tuple[str, ...] = ()
                     ) -> list[str]:
    """Schema-check a ``--metrics-out`` JSON dump."""
    problems: list[str] = []
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        return [f"{path}: unreadable or not JSON: {e}"]
    if not isinstance(doc, dict) or doc.get("schema") != 1:
        return [f"{path}: missing or unexpected 'schema' (want 1)"]
    metrics = doc.get("metrics")
    if not isinstance(metrics, dict) or not metrics:
        return [f"{path}: 'metrics' missing or empty"]
    for name, fam in metrics.items():
        if not isinstance(fam, dict) or "type" not in fam \
                or "values" not in fam:
            problems.append(f"{path}: family {name!r} malformed")
    for name in require_names:
        if name not in metrics:
            problems.append(f"{path}: required metric {name!r} absent "
                            f"(saw: {sorted(metrics)})")
    return problems


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        description="validate Chrome trace / metrics-dump artifacts")
    p.add_argument("--trace", action="append", default=[],
                   help="Chrome trace JSON to validate (repeatable)")
    p.add_argument("--metrics", action="append", default=[],
                   help="metrics dump JSON to validate (repeatable)")
    p.add_argument("--require-cats", default="",
                   help="comma-separated categories every trace must have")
    p.add_argument("--require-metrics", default="",
                   help="comma-separated metric names every dump must have")
    p.add_argument("--min-events", type=int, default=1)
    args = p.parse_args(argv)
    if not args.trace and not args.metrics:
        p.error("nothing to validate: pass --trace and/or --metrics")
    cats = tuple(c for c in args.require_cats.split(",") if c)
    names = tuple(n for n in args.require_metrics.split(",") if n)
    problems: list[str] = []
    for t in args.trace:
        problems += validate_trace(t, require_cats=cats,
                                   min_events=args.min_events)
    for m in args.metrics:
        problems += validate_metrics(m, require_names=names)
    if problems:
        for pr in problems:
            print(f"validate: FAIL {pr}", file=sys.stderr)
        return 1
    for t in args.trace:
        print(f"validate: OK trace {t}")
    for m in args.metrics:
        print(f"validate: OK metrics {m}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
