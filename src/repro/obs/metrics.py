"""Typed metrics: counters, gauges, histograms with labels.

A small in-process registry in the Prometheus shape — metric *families*
declared once with a label schema, label-bound children created on
demand — backing the engine's counters (plan-cache hits/misses, ladder
rung rates, dispatch counts) and the launchers' latency histograms.

Every child guards its state with its own lock, so concurrent executors
can never lose increments (the pre-obs ``PlanCache`` counters were plain
``int`` fields mutated under the cache's lock; anything incrementing
outside it raced).  Counters and gauges are cheap enough to stay always
on; per-call instrumentation sites additionally gate on
``trace._enabled`` where a hot path is at stake.

``MetricsRegistry.snapshot()`` returns a JSON-serializable dict (the
``--metrics-out`` dump), ``report()`` a text exposition for terminals.
"""
from __future__ import annotations

import json
import math
import os
import threading
from collections import deque
from collections.abc import Iterable, Sequence

DEFAULT_RESERVOIR = 1024


class _Child:
    __slots__ = ("_lock", "labels")

    def __init__(self, labels: dict):
        self._lock = threading.Lock()
        self.labels = labels


class Counter(_Child):
    """Monotone counter. ``inc`` is atomic under the child's lock."""

    __slots__ = ("_value",)

    def __init__(self, labels: dict):
        super().__init__(labels)
        self._value = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        with self._lock:
            return self._value

    def reset(self) -> None:
        with self._lock:
            self._value = 0

    def snapshot(self) -> dict:
        return {"value": self.value}


class Gauge(_Child):
    """Set-to-current-value metric (cache size, shard imbalance, ...)."""

    __slots__ = ("_value",)

    def __init__(self, labels: dict):
        super().__init__(labels)
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    def dec(self, n: float = 1.0) -> None:
        self.inc(-n)

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def reset(self) -> None:
        with self._lock:
            self._value = 0.0

    def snapshot(self) -> dict:
        return {"value": self.value}


class Histogram(_Child):
    """Latency-style histogram: count/sum/min/max plus a bounded sample
    reservoir (most recent ``reservoir`` observations) for p50/p95."""

    __slots__ = ("_count", "_sum", "_min", "_max", "_samples")

    def __init__(self, labels: dict, reservoir: int = DEFAULT_RESERVOIR):
        super().__init__(labels)
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf
        self._samples: deque = deque(maxlen=reservoir)

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self._count += 1
            self._sum += v
            self._min = min(self._min, v)
            self._max = max(self._max, v)
            self._samples.append(v)

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def percentile(self, p: float) -> float:
        """p in [0, 100], over the retained reservoir. NaN when empty."""
        with self._lock:
            xs = sorted(self._samples)
        if not xs:
            return math.nan
        if len(xs) == 1:
            return xs[0]
        # linear interpolation between closest ranks
        rank = (p / 100.0) * (len(xs) - 1)
        lo = int(math.floor(rank))
        hi = min(lo + 1, len(xs) - 1)
        frac = rank - lo
        return xs[lo] * (1 - frac) + xs[hi] * frac

    def reset(self) -> None:
        with self._lock:
            self._count = 0
            self._sum = 0.0
            self._min = math.inf
            self._max = -math.inf
            self._samples.clear()

    def snapshot(self) -> dict:
        with self._lock:
            count, total = self._count, self._sum
            mn, mx = self._min, self._max
        if count == 0:
            return {"count": 0, "sum": 0.0}
        return {"count": count, "sum": total, "min": mn, "max": mx,
                "mean": total / count, "p50": self.percentile(50),
                "p95": self.percentile(95)}


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricFamily:
    """One named metric + label schema; children per label-value tuple."""

    def __init__(self, name: str, kind: str, help: str,
                 label_names: Sequence[str], **child_kw):
        self.name = name
        self.kind = kind
        self.help = help
        self.label_names = tuple(label_names)
        self._child_kw = child_kw
        self._children: dict[tuple, _Child] = {}
        self._lock = threading.Lock()

    def labels(self, **kv) -> _Child:
        """The child bound to these label values (created on demand)."""
        if set(kv) != set(self.label_names):
            raise ValueError(
                f"metric {self.name!r} takes labels {self.label_names}, "
                f"got {tuple(sorted(kv))}")
        key = tuple(str(kv[n]) for n in self.label_names)
        child = self._children.get(key)
        if child is None:
            with self._lock:
                child = self._children.get(key)
                if child is None:
                    child = _KINDS[self.kind](
                        dict(zip(self.label_names, key)), **self._child_kw)
                    self._children[key] = child
        return child

    # Unlabeled convenience: family acts as its single () child.
    def _default(self) -> _Child:
        if self.label_names:
            raise ValueError(
                f"metric {self.name!r} has labels {self.label_names}; "
                "bind them with .labels(...)")
        return self.labels()

    def inc(self, n=1) -> None:
        self._default().inc(n)

    def set(self, v) -> None:
        self._default().set(v)

    def dec(self, n=1) -> None:
        self._default().dec(n)

    def observe(self, v) -> None:
        self._default().observe(v)

    @property
    def value(self):
        return self._default().value

    def children(self) -> Iterable[_Child]:
        with self._lock:
            return list(self._children.values())

    def reset(self) -> None:
        for c in self.children():
            c.reset()

    def snapshot(self) -> dict:
        return {
            "type": self.kind,
            "help": self.help,
            "labels": list(self.label_names),
            "values": [dict(labels=c.labels, **c.snapshot())
                       for c in self.children()],
        }


class MetricsRegistry:
    """Declare-once metric families; snapshot/report/dump the lot.

    Re-declaring a name with the same (kind, labels) returns the existing
    family — instrumentation sites in different modules can share a
    metric without import-order coupling; a conflicting re-declaration
    raises.
    """

    def __init__(self):
        self._families: dict[str, MetricFamily] = {}
        self._lock = threading.Lock()

    def _declare(self, name: str, kind: str, help: str,
                 labels: Sequence[str], **child_kw) -> MetricFamily:
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                if fam.kind != kind or fam.label_names != tuple(labels):
                    raise ValueError(
                        f"metric {name!r} already declared as {fam.kind} "
                        f"with labels {fam.label_names}; cannot re-declare "
                        f"as {kind} with labels {tuple(labels)}")
                return fam
            fam = MetricFamily(name, kind, help, labels, **child_kw)
            self._families[name] = fam
            return fam

    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = ()) -> MetricFamily:
        return self._declare(name, "counter", help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = ()) -> MetricFamily:
        return self._declare(name, "gauge", help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: Sequence[str] = (),
                  reservoir: int = DEFAULT_RESERVOIR) -> MetricFamily:
        return self._declare(name, "histogram", help, labels,
                             reservoir=reservoir)

    def get(self, name: str) -> MetricFamily | None:
        with self._lock:
            return self._families.get(name)

    def families(self) -> list[MetricFamily]:
        with self._lock:
            return list(self._families.values())

    def snapshot(self) -> dict:
        return {f.name: f.snapshot() for f in self.families()}

    def report(self) -> str:
        """Text exposition: one ``name{labels} value`` line per child."""
        lines = []
        for fam in sorted(self.families(), key=lambda f: f.name):
            children = [c for c in fam.children()]
            if not children:
                continue
            if fam.help:
                lines.append(f"# {fam.name}: {fam.help}")
            for c in sorted(children,
                            key=lambda c: tuple(c.labels.values())):
                lab = ",".join(f"{k}={v}" for k, v in c.labels.items())
                lab = "{" + lab + "}" if lab else ""
                if fam.kind == "histogram":
                    s = c.snapshot()
                    if s["count"] == 0:
                        lines.append(f"{fam.name}{lab} count=0")
                    else:
                        lines.append(
                            f"{fam.name}{lab} count={s['count']} "
                            f"mean={s['mean']:.1f} p50={s['p50']:.1f} "
                            f"p95={s['p95']:.1f} min={s['min']:.1f} "
                            f"max={s['max']:.1f}")
                else:
                    v = c.value
                    vs = f"{v:g}" if isinstance(v, float) else str(v)
                    lines.append(f"{fam.name}{lab} {vs}")
        return "\n".join(lines)

    def dump(self, path: str, *, extra: dict | None = None) -> str:
        """Write a JSON snapshot (``--metrics-out``); returns the path."""
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        payload = {"schema": 1, "metrics": self.snapshot()}
        if extra:
            payload.update(extra)
        with open(path, "w") as f:
            json.dump(payload, f, indent=1)
        return path

    def reset(self) -> None:
        """Zero every child (tests / between bench sections)."""
        for fam in self.families():
            fam.reset()
