from .pipeline import DataConfig, PackedFileSource, SyntheticLM, make_source

__all__ = ["DataConfig", "PackedFileSource", "SyntheticLM", "make_source"]
