"""Deterministic, shard-aware, resumable data pipeline.

Fault-tolerance cornerstone: batches are a pure function of
``(seed, step, shard)`` — no iterator state to checkpoint, any replica can
regenerate any step (straggler backfill, elastic re-sharding, bit-exact
restart).  Two sources:

* ``SyntheticLM`` — counter-based hash → tokens (CPU tests, dry-run).
* ``PackedFileSource`` — memory-mapped binary token file with the same
  index-based access (a real corpus path that keeps statelessness).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    input_mode: str = "tokens"     # tokens | embeddings (stub frontends)
    d_model: int = 0               # for embeddings mode


class SyntheticLM:
    """Counter-based generator: tokens[i] = hash(seed, step, row, i)."""

    def __init__(self, cfg: DataConfig, shard_index: int = 0,
                 num_shards: int = 1):
        assert cfg.global_batch % num_shards == 0
        self.cfg = cfg
        self.shard_index = shard_index
        self.num_shards = num_shards
        self.local_batch = cfg.global_batch // num_shards

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        with np.errstate(over="ignore"):  # uint64 hash wraps by design
            rows = (np.arange(self.local_batch, dtype=np.uint64)
                    + self.shard_index * self.local_batch)
            cols = np.arange(cfg.seq_len + 1, dtype=np.uint64)
            # splitmix64-style hash of (seed, step, row, col)
            x = (np.uint64(cfg.seed) * np.uint64(0x9E3779B97F4A7C15)
                 ^ np.uint64(step) * np.uint64(0xBF58476D1CE4E5B9))
            h = (rows[:, None] * np.uint64(0x94D049BB133111EB)
                 ^ cols[None, :] ^ x)
            h ^= h >> np.uint64(31)
            h *= np.uint64(0xBF58476D1CE4E5B9)
            h ^= h >> np.uint64(27)
            toks = (h % np.uint64(cfg.vocab_size)).astype(np.int32)
        batch = {"tokens": jnp.asarray(toks[:, :-1]),
                 "labels": jnp.asarray(toks[:, 1:])}
        if cfg.input_mode == "embeddings":
            # stub modality frontend: pseudo-embeddings from the token hash
            f = (toks[:, :-1, None]
                 * np.arange(1, cfg.d_model + 1, dtype=np.int64)) % 4096
            emb = (f.astype(np.float32) / 2048.0 - 1.0)
            batch = {"embeds": jnp.asarray(emb, jnp.float32),
                     "labels": batch["labels"]}
        return batch


class PackedFileSource:
    """Flat binary int32 token file, deterministic index-based slicing."""

    def __init__(self, path: str, cfg: DataConfig, shard_index: int = 0,
                 num_shards: int = 1):
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        self.cfg = cfg
        self.shard_index = shard_index
        self.num_shards = num_shards
        self.local_batch = cfg.global_batch // num_shards
        self.n_windows = (len(self.tokens) - 1) // cfg.seq_len

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        base = step * cfg.global_batch + self.shard_index * self.local_batch
        idx = (base + np.arange(self.local_batch)) % self.n_windows
        rows = np.stack([
            self.tokens[i * cfg.seq_len: i * cfg.seq_len + cfg.seq_len + 1]
            for i in idx])
        return {"tokens": jnp.asarray(rows[:, :-1]),
                "labels": jnp.asarray(rows[:, 1:])}


def make_source(cfg: DataConfig, path: str | None = None,
                shard_index: int = 0, num_shards: int = 1):
    if path:
        return PackedFileSource(path, cfg, shard_index, num_shards)
    return SyntheticLM(cfg, shard_index, num_shards)
