from . import elastic, fault, sharding, spmm

__all__ = ["elastic", "fault", "sharding", "spmm"]
