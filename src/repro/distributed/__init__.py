from . import elastic, fault, sharding

__all__ = ["elastic", "fault", "sharding"]
