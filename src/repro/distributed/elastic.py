"""Elastic scaling: re-shard a training state onto a different mesh.

Grow/shrink the data-parallel width (node failures, capacity changes)
without conversion tooling: checkpoints store logical arrays; placing them
on a new mesh is ``device_put`` with the new sharding rules.  The data
pipeline is index-based, so changing ``num_shards`` re-partitions batches
deterministically — combined, a job can restart on K-n pods and continue
bit-exact (modulo batch layout) from the last step.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

from . import sharding as sh


def reshard_params(params, new_mesh: Mesh):
    return jax.device_put(params, sh.params_shardings(params, new_mesh))


def reshard_state(state, new_mesh: Mesh):
    """Optimizer state follows parameter sharding (m/v mirror params)."""
    out = dict(state)
    out["m"] = reshard_params(state["m"], new_mesh)
    out["v"] = reshard_params(state["v"], new_mesh)
    out["step"] = jax.device_put(state["step"], sh.replicated(new_mesh))
    return out


def validate_elastic_resize(old_mesh: Mesh, new_mesh: Mesh,
                            global_batch: int) -> list[str]:
    """Static checks before attempting a live resize."""
    problems = []
    if new_mesh.shape.get("model", 1) != old_mesh.shape.get("model", 1):
        problems.append(
            "model-axis resize changes TP layout; requires full re-shard "
            "(supported, but flagging for operator confirmation)")
    dp = 1
    for a in sh.dp_axes(new_mesh):
        dp *= new_mesh.shape[a]
    if global_batch % dp:
        problems.append(
            f"global_batch {global_batch} not divisible by new DP width {dp}")
    return problems
