"""Device-sharded SpMM: nnz-balanced row/column shards with per-shard plans.

The paper's core design principle — give every processor an equal number
of *nonzeroes*, not an equal number of rows (§4, ``core/partition.py``) —
lifted from the Pallas-grid level to the device level.  A sparse matrix is
cut into contiguous row ranges (or, for the tensor-parallel variant,
column ranges) holding ~equal nonzero counts via the same
``searchsorted``-on-``row_ptr`` machinery as ``partition_spmm``; each
shard gets its *own* :class:`~repro.core.plan.SpmmPlan`, resolved through
the method registry and TuneDB ladder independently — a shard holding a
few dense rows and a shard holding many sparse rows can (and should) pick
different kernels, which is the whole point of balance-aware sharding.

Execution:

* ``dim="rows"`` (data parallel): every device runs its local planned
  kernel on its row block against the replicated dense ``B``; ``C`` is
  the row concatenation of the local blocks.
* ``dim="cols"`` (tensor parallel): ``A`` is column-sharded by nnz, each
  device multiplies its column slice against its row block of ``B`` and
  the rank-``m`` partial sums are all-reduced (``lax.psum``) over the
  mesh axis.

When every shard resolves to the same method and static parameters
(shapes are unified by padding rows/nonzeroes to the per-shard maxima),
the whole sharded multiply is one ``shard_map`` dispatch over the mesh
axis — a single SPMD program, differentiable end to end (the per-shard
``custom_vjp`` plans run inside the mapped body; the replicated-``B``
cotangent is psum'd by shard_map's transpose).  Heterogeneous shards
(different methods, or rowgroup's per-shard group tables) fall back to a
per-shard loop that is numerically identical and still differentiable —
correctness never depends on the mesh.

Plans are built through ``repro.engine``'s cache: each shard's local
pattern lands as its own entry (keyed on the shard's fingerprint), and
the :class:`ShardedSpmmPlan` itself is cached under the global pattern +
shard spec, so re-sharding with a different mesh size can never poison
either level.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import obs as _obs
from repro.analysis import _flags as _verify_flags
from repro.core.config import (ExecutionConfig, PlanPolicy, _UNSET,
                               coalesce_exec)
from repro.core.csr import CSR
from repro.core.plan import SpmmPlan, build_plan
from repro.core.spmm import execute_plan
from repro.obs import trace as _trace

# Shard-balance gauges are plan-time (amortized) and stay always-on; the
# per-execute counter below is gated on the tracing flag like the core
# dispatch path.
_shard_imbalance = _obs.registry.gauge(
    "shard_nnz_imbalance", "max/mean nnz ratio of the last sharded build",
    labels=("dim",))
_sharded_execute = _obs.registry.counter(
    "sharded_execute_total", "execute_sharded dispatches by path",
    labels=("path",))


def _nnz_cuts(ptr: np.ndarray, n_shards: int) -> np.ndarray:
    """Cut positions splitting ``ptr``'s span into ~equal-nnz ranges.

    ``ptr`` is any monotone prefix-sum array (``row_ptr`` for row shards,
    the CSC column pointer for column shards).  Returns ``n_shards + 1``
    monotone boundaries with ``bounds[0] == 0`` and ``bounds[-1] ==
    len(ptr) - 1``; each boundary is the row containing the ideal cut
    nonzero — the same ``searchsorted`` rule as ``partition_spmm``, so
    every range's nonzero count is within one max-row-length of the ideal
    ``nnz / n_shards``.
    """
    m = ptr.shape[0] - 1
    nnz = int(ptr[-1])
    targets = (np.arange(1, n_shards, dtype=np.int64) * nnz) // n_shards
    cuts = np.searchsorted(ptr, targets, side="right").astype(np.int64) - 1
    bounds = np.concatenate([[0], np.minimum(cuts, m), [m]])
    return np.maximum.accumulate(bounds)


@dataclasses.dataclass(frozen=True)
class CsrShards:
    """Host-side result of :func:`shard_csr_by_nnz`.

    ``csrs`` are the per-shard local patterns, padded to uniform static
    shapes (rows to the max shard row count, nonzeroes to the max shard
    nnz) so that same-method plans can stack into one SPMD dispatch.
    ``vals_slots[i]`` gathers shard ``i``'s local values out of the
    *global* value vector (sentinel ``nnz_pad`` → an appended zero), which
    is what keeps the sharded execution differentiable in the shared
    values.  For ``dim="cols"``, ``b_rows[i]`` gathers shard ``i``'s row
    block of ``B`` (sentinel ``k`` → an appended zero row).
    """

    dim: str                        # "rows" | "cols"
    shape: tuple[int, int]          # global (m, k)
    nnz_pad: int                    # global static nonzero capacity
    bounds: tuple[int, ...]         # n_shards+1 cuts over rows (or cols)
    csrs: tuple[CSR, ...]           # padded local patterns, uniform shapes
    vals_slots: tuple[jax.Array, ...]
    b_rows: tuple[jax.Array, ...] | None   # cols-dim only

    @property
    def n_shards(self) -> int:
        return len(self.csrs)

    def sizes(self) -> tuple[int, ...]:
        """True (unpadded) rows/cols per shard."""
        return tuple(self.bounds[i + 1] - self.bounds[i]
                     for i in range(self.n_shards))

    def unpadded(self, i: int) -> CSR:
        """Shard ``i`` without the uniform-shape padding.

        This is the view method resolution must see: the padded ``csrs``
        carry empty filler rows that dilute a shard's local stats (a
        3-dense-row shard padded to 500 rows looks sparse to ``d =
        nnz/m``), which would defeat per-shard method selection.
        """
        c = self.csrs[i]
        if self.dim == "cols":          # columns padded: d is unaffected
            return c
        rows = self.bounds[i + 1] - self.bounds[i]
        return CSR(c.row_ptr[:rows + 1], c.col_ind, c.vals, (rows, c.shape[1]))

    def nnz_per_shard(self) -> tuple[int, ...]:
        return tuple(int(np.asarray(c.row_ptr)[-1]) for c in self.csrs)


def _require_host(a: CSR) -> None:
    from repro.core.plan import _require_concrete
    _require_concrete(a, "shard_csr_by_nnz")


def shard_csr_by_nnz(a: CSR, n_shards: int, *, dim: str = "rows") -> CsrShards:
    """Cut ``a`` into ``n_shards`` contiguous ranges of ~equal nonzeroes.

    ``dim="rows"``: contiguous row ranges (each shard a ``(max_rows, k)``
    CSR — trailing empty rows pad shards to a common height).
    ``dim="cols"``: contiguous column ranges of the CSC view (each shard a
    ``(m, max_cols)`` CSR with columns remapped to shard-local ids).
    Host-side; the pattern must be concrete.
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    if dim not in ("rows", "cols"):
        raise ValueError(f"shard dim must be 'rows' or 'cols', got {dim!r}")
    _require_host(a)
    m, k = a.shape
    rp = np.asarray(a.row_ptr)
    ci = np.asarray(a.col_ind)
    nnz = int(rp[-1])
    if dim == "rows":
        bounds = _nnz_cuts(rp, n_shards)
        max_rows = int(np.max(np.diff(bounds))) if n_shards else 0
        loc_nnz = [int(rp[bounds[i + 1]] - rp[bounds[i]])
                   for i in range(n_shards)]
        loc_pad = max(max(loc_nnz, default=0), 1)
        csrs, slots = [], []
        for i in range(n_shards):
            r0, r1 = int(bounds[i]), int(bounds[i + 1])
            lrp = np.zeros(max_rows + 1, np.int32)
            lrp[:r1 - r0 + 1] = rp[r0:r1 + 1] - rp[r0]
            lrp[r1 - r0 + 1:] = lrp[r1 - r0]      # padded rows are empty
            lci = np.zeros(loc_pad, np.int32)
            lci[:loc_nnz[i]] = ci[rp[r0]:rp[r1]]
            csrs.append(CSR(jnp.asarray(lrp), jnp.asarray(lci),
                            jnp.zeros(loc_pad, a.vals.dtype), (max_rows, k)))
            slot = np.full(loc_pad, a.nnz_pad, np.int32)
            slot[:loc_nnz[i]] = np.arange(rp[r0], rp[r1], dtype=np.int32)
            slots.append(jnp.asarray(slot))
        return CsrShards(dim="rows", shape=a.shape, nnz_pad=a.nnz_pad,
                         bounds=tuple(int(b) for b in bounds),
                         csrs=tuple(csrs), vals_slots=tuple(slots),
                         b_rows=None)

    # dim == "cols": balance over the CSC view's column nonzero counts.
    rows_all = np.repeat(np.arange(m, dtype=np.int32), np.diff(rp))
    cols_all = ci[:nnz]
    col_ptr = np.zeros(k + 1, np.int64)
    np.cumsum(np.bincount(cols_all, minlength=k), out=col_ptr[1:])
    bounds = _nnz_cuts(col_ptr, n_shards)
    max_cols = int(np.max(np.diff(bounds))) if n_shards else 0
    max_cols = max(max_cols, 1)
    sels = [(cols_all >= bounds[i]) & (cols_all < bounds[i + 1])
            for i in range(n_shards)]
    loc_pad = max(max((int(s.sum()) for s in sels), default=0), 1)
    csrs, slots, b_rows = [], [], []
    for i in range(n_shards):
        c0, c1 = int(bounds[i]), int(bounds[i + 1])
        sel = sels[i]
        pos = np.nonzero(sel)[0].astype(np.int32)  # row-major order kept
        lrp = np.zeros(m + 1, np.int32)
        np.cumsum(np.bincount(rows_all[sel], minlength=m), out=lrp[1:])
        lci = np.zeros(loc_pad, np.int32)
        lci[:pos.shape[0]] = cols_all[sel] - c0
        csrs.append(CSR(jnp.asarray(lrp), jnp.asarray(lci),
                        jnp.zeros(loc_pad, a.vals.dtype), (m, max_cols)))
        slot = np.full(loc_pad, a.nnz_pad, np.int32)
        slot[:pos.shape[0]] = pos
        slots.append(jnp.asarray(slot))
        rows_idx = np.full(max_cols, k, np.int32)   # sentinel: zero row of B
        rows_idx[:c1 - c0] = np.arange(c0, c1, dtype=np.int32)
        b_rows.append(jnp.asarray(rows_idx))
    return CsrShards(dim="cols", shape=a.shape, nnz_pad=a.nnz_pad,
                     bounds=tuple(int(b) for b in bounds),
                     csrs=tuple(csrs), vals_slots=tuple(slots),
                     b_rows=tuple(b_rows))


# ------------------------------------------------------------------ plans ---


@dataclasses.dataclass(frozen=True)
class ShardedMeta:
    """Static (hashable) metadata of a ShardedSpmmPlan."""

    shape: tuple[int, int]          # global (m, k)
    nnz_pad: int                    # global static nonzero capacity
    dim: str                        # "rows" | "cols"
    bounds: tuple[int, ...]
    axis: str                       # mesh axis name
    mesh: jax.sharding.Mesh | None
    uniform: bool                   # all shards share method + statics
    local_metas: tuple              # one PlanMeta per shard

    def __post_init__(self):
        # Like PlanMeta: this is a jit-static constant — an unhashable
        # field must fail loudly at assembly, not inside jax's cache.
        try:
            hash((self.bounds, self.local_metas))
        except TypeError:
            raise TypeError(
                "ShardedMeta must be hashable (it is a jit-static "
                f"constant): bounds={self.bounds!r} and every local "
                "PlanMeta must be built from tuples, not lists/arrays."
            ) from None

    @property
    def n_shards(self) -> int:
        return len(self.local_metas)

    @property
    def m(self) -> int:
        return self.shape[0]

    @property
    def k(self) -> int:
        return self.shape[1]

    @property
    def method(self) -> str:
        methods = {lm.method for lm in self.local_metas}
        return methods.pop() if len(methods) == 1 else "mixed"

    @property
    def l_pad(self) -> int | None:
        pads = {lm.l_pad for lm in self.local_metas}
        return pads.pop() if len(pads) == 1 else None

    @property
    def has_transpose(self) -> bool:
        return all(lm.has_transpose for lm in self.local_metas)

    def spmd_mesh(self):
        """The mesh to shard_map over, or None (per-shard loop)."""
        mesh = self.mesh
        if (not self.uniform or mesh is None
                or self.axis not in mesh.axis_names
                or mesh.shape[self.axis] != self.n_shards):
            return None
        return mesh


@dataclasses.dataclass(frozen=True)
class ShardedSpmmPlan:
    """Per-shard SpmmPlans + the value/B gathers that stitch them together.

    A pytree (per-shard plans and gather indices are the children; the
    shard layout is static aux data), so it lives inside model pytrees and
    passes through jit boundaries exactly like a single-device
    ``SpmmPlan``.  Execute with :func:`execute_sharded` (or ``A @ B`` on a
    sharded ``SparseMatrix``).
    """

    shards: tuple[SpmmPlan, ...]
    vals_slots: tuple[jax.Array, ...]
    b_rows: tuple[jax.Array, ...] | None
    meta: ShardedMeta

    @property
    def method(self) -> str:
        return self.meta.method

    def execute(self, vals: jax.Array, b: jax.Array,
                exec: ExecutionConfig | None = None, *,
                bias: jax.Array | None = None,
                residual: jax.Array | None = None) -> jax.Array:
        return execute_sharded(self, vals, b, exec, bias=bias,
                               residual=residual)

    # Stacked leaves for the SPMD path, memoized per live (concrete) plan
    # object so the execute-many regime stacks once, not per call.  Traced
    # leaves are never cached (tracers must not outlive their trace).
    def _stacked(self):
        cached = getattr(self, "_stack_cache", None)
        if cached is not None:
            return cached
        stacked_plan = jax.tree.map(lambda *xs: jnp.stack(xs), *self.shards)
        slot_stack = jnp.stack(self.vals_slots)
        brow_stack = jnp.stack(self.b_rows) if self.b_rows else None
        mesh = self.meta.spmd_mesh()
        concrete = not any(isinstance(x, jax.core.Tracer)
                           for x in jax.tree.leaves(stacked_plan))
        if concrete and mesh is not None:
            # Pre-place shard-major leaves on the mesh axis so shard_map
            # never reshards per call.
            sh = NamedSharding(mesh, P(self.meta.axis))
            stacked_plan = jax.device_put(stacked_plan, sh)
            slot_stack = jax.device_put(slot_stack, sh)
            if brow_stack is not None:
                brow_stack = jax.device_put(brow_stack, sh)
        out = (stacked_plan, slot_stack, brow_stack)
        if concrete:
            object.__setattr__(self, "_stack_cache", out)
        return out


def _unflatten_sharded(aux, children):
    sp = object.__new__(ShardedSpmmPlan)
    object.__setattr__(sp, "shards", children[0])
    object.__setattr__(sp, "vals_slots", children[1])
    object.__setattr__(sp, "b_rows", children[2])
    object.__setattr__(sp, "meta", aux)
    return sp


jax.tree_util.register_pytree_node(
    ShardedSpmmPlan,
    lambda sp: ((sp.shards, sp.vals_slots, sp.b_rows), sp.meta),
    _unflatten_sharded,
)


def _unify_params(rs) -> tuple:
    """Static params every shard can run: the per-shard maxima.

    A larger ``l_pad`` is valid for every rowsplit-style shard (its rows
    pad further), any ``t``/``tl`` is valid everywhere, so the maxima are
    the cheapest params that make same-method shards shape-compatible for
    one stacked SPMD dispatch.
    """
    t = max(r.t for r in rs)
    tl = max(r.tl for r in rs)
    pads = [r.l_pad for r in rs if r.l_pad is not None]
    return t, tl, (max(pads) if pads else None)


def build_sharded_plan(a: CSR, policy: PlanPolicy,
                       cache=None) -> ShardedSpmmPlan:
    """Shard ``a`` by nnz and plan each shard independently.

    Each shard's method resolves through the full ladder (TuneDB exact →
    class → calibrated threshold → registry cost hooks) *on its own local
    stats* — an imbalanced matrix can mix kernels across shards.  When the
    shards agree on a method, their static parameters are unified to the
    per-shard maxima so the plans stack into one ``shard_map`` program
    (``meta.uniform``); otherwise execution falls back to the per-shard
    loop.  ``cache`` (a ``repro.engine.PlanCache``) makes every local plan
    a distinct cache entry keyed on the shard's own pattern fingerprint.
    """
    spec = policy.shards
    if spec is None:
        raise ValueError("build_sharded_plan needs a policy with shards= "
                         "set (a repro.core.ShardSpec)")
    from repro.kernels import registry

    n = spec.resolved_n()
    local_policy = dataclasses.replace(policy, shards=None)
    with _trace.span("plan.build_sharded", cat="plan", n_shards=n,
                     dim=spec.dim, m=int(a.shape[0]),
                     k=int(a.shape[1])) as sp:
        shards = shard_csr_by_nnz(a, n, dim=spec.dim)
        nnz_per = shards.nnz_per_shard()
        mean_nnz = sum(nnz_per) / max(len(nnz_per), 1)
        imbalance = (max(nnz_per) / mean_nnz) if mean_nnz > 0 else 1.0
        _shard_imbalance.labels(dim=spec.dim).set(imbalance)
        # Resolve on the *unpadded* local patterns: a shard's method must
        # come from its true local stats, not stats diluted by
        # shape-padding.
        resolved = [local_policy.resolve(shards.unpadded(i))
                    for i in range(n)]
        sp.set(methods=[r.method for r in resolved],
               nnz_per_shard=list(nnz_per),
               nnz_imbalance=round(imbalance, 4))
    methods = {r.method for r in resolved}
    stackable = False
    if len(methods) == 1:
        # One method everywhere: unify the static params and check that
        # the method derives identical method-specific statics on the
        # shape-padded locals — then the plans stack into one SPMD body.
        t, tl, l_pad = _unify_params(resolved)
        mspec = registry.get_method(resolved[0].method)
        extras = [mspec.resolve_params(c, t=t, tl=tl, l_pad=l_pad)[3]
                  for c in shards.csrs]
        stackable = all(e == extras[0] for e in extras)
    if stackable:
        pinned = [PlanPolicy(method=resolved[0].method, t=t, tl=tl,
                             l_pad=l_pad, tunedb=None,
                             with_transpose=policy.with_transpose)] * n
        build_csrs = shards.csrs
    else:
        # Heterogeneous shards run the per-shard loop, where shape
        # padding buys nothing and can cost plenty (a rowsplit shard
        # would ELL-pad every filler row) — plan the true local patterns.
        pinned = [PlanPolicy(method=r.method, t=r.t, tl=r.tl, l_pad=r.l_pad,
                             tunedb=None,
                             with_transpose=policy.with_transpose)
                  for r in resolved]
        build_csrs = [shards.unpadded(i) for i in range(n)]
    if cache is not None:
        plans = tuple(cache.get(c, p) for c, p in zip(build_csrs, pinned))
    else:
        plans = tuple(build_plan(c, policy=p)
                      for c, p in zip(build_csrs, pinned))
    uniform = stackable and all(p.meta == plans[0].meta for p in plans)
    if _trace._enabled:
        _trace.event("plan.sharded_assembled", cat="plan", n_shards=n,
                     dim=spec.dim, uniform=uniform,
                     methods=[p.meta.method for p in plans])
    meta = ShardedMeta(shape=a.shape, nnz_pad=a.nnz_pad, dim=spec.dim,
                       bounds=shards.bounds, axis=spec.axis, mesh=spec.mesh,
                       uniform=uniform, local_metas=tuple(p.meta
                                                          for p in plans))
    plan = ShardedSpmmPlan(shards=plans, vals_slots=shards.vals_slots,
                           b_rows=shards.b_rows, meta=meta)
    if _verify_flags.verify_plans:
        # REPRO_VERIFY_PLANS debug hook: the per-shard plans were each
        # verified by build_plan; this checks the assembly — bounds tile
        # the global span, the values gather covers every global nonzero
        # exactly once, b_rows slice per shard (repro.analysis.planlint).
        from repro.analysis.planlint import check_plan
        check_plan(plan, a)
    return plan


# -------------------------------------------------------------- execution ---


def _local_vals(vals: jax.Array, slot: jax.Array) -> jax.Array:
    vals_ext = jnp.concatenate([vals, jnp.zeros(1, vals.dtype)])
    return vals_ext[slot]


def _local_b(b: jax.Array, rows: jax.Array) -> jax.Array:
    zero_row = jnp.zeros(b.shape[:-2] + (1, b.shape[-1]), b.dtype)
    b_ext = jnp.concatenate([b, zero_row], axis=-2)
    return jnp.take(b_ext, rows, axis=-2)


def _concat_rows(outs, bounds):
    """Row-concatenate per-shard outputs, dropping each shard's pad rows."""
    sizes = [bounds[i + 1] - bounds[i] for i in range(len(outs))]
    return jnp.concatenate(
        [o[..., :sz, :] for o, sz in zip(outs, sizes)], axis=-2)


def execute_sharded(plan: ShardedSpmmPlan, vals: jax.Array, b: jax.Array,
                    exec: ExecutionConfig | None = None, *,
                    bias: jax.Array | None = None,
                    residual: jax.Array | None = None,
                    interpret=_UNSET, impl=_UNSET, tk=_UNSET) -> jax.Array:
    """C = A @ B through a sharded plan, with A's *global* values per call.

    Mirrors ``core.spmm.execute_plan``: trace-safe, differentiable in
    ``vals``, ``b``, ``bias`` and ``residual``, batched ``b (..., k, n) →
    (..., m, n)``.  With a uniform plan and a matching mesh this is one
    ``shard_map`` dispatch (each device runs its local planned kernel);
    otherwise a per-shard loop computes the same values on whatever
    devices hold the data.

    The epilogue applies *after* shard assembly — a row shard holds only a
    row slice of C (the bias/residual would need slicing), and a column
    shard holds a rank-``m`` *partial sum*, through which a nonlinear
    activation does not commute — so the shards run epilogue-free in
    ``acc_dtype`` and the single tail pass lands on the assembled C.
    """
    exec = coalesce_exec("execute_sharded", exec, impl=impl,
                         interpret=interpret, tk=tk)
    meta = plan.meta
    if vals.shape != (meta.nnz_pad,):
        raise ValueError(
            f"sharded plan expects the global vals of shape "
            f"({meta.nnz_pad},) for pattern {meta.shape}, got {vals.shape}")
    if b.ndim < 2 or b.shape[-2] != meta.k:
        raise ValueError(
            f"sharded plan expects B of shape (..., {meta.k}, n) for "
            f"pattern {meta.shape}, got {b.shape}")
    from repro.core.spmm import _resolve_exec
    exec = _resolve_exec("execute_sharded", meta.m, vals, b, exec,
                         bias, residual)
    ep = exec.epilogue
    # Shards emit acc-precision blocks/partials (a cols-dim psum must not
    # sum down-cast partials); the out_dtype cast waits for the tail.
    inner = dataclasses.replace(exec, epilogue=None,
                                out_dtype=exec.acc_dtype)
    mesh = meta.spmd_mesh()
    if _trace._enabled:
        path = "spmd" if mesh is not None else "loop"
        _sharded_execute.labels(path=path).inc()
        _trace.event("dispatch.sharded", cat="dispatch", path=path,
                     n_shards=meta.n_shards, dim=meta.dim,
                     uniform=meta.uniform, impl=exec.impl,
                     method=meta.method, n=int(b.shape[-1]),
                     acc_dtype=exec.acc_dtype, out_dtype=exec.out_dtype)
    out = _execute_spmd(plan, vals, b, inner, mesh) if mesh is not None \
        else _execute_loop(plan, vals, b, inner)
    if ep is not None:
        from repro.core.epilogue import apply_epilogue
        acc = jnp.dtype(exec.acc_dtype)
        out = apply_epilogue(
            out, ep,
            bias.astype(acc)[:, None] if ep.bias else None,
            residual if ep.residual else None)
    return out.astype(jnp.dtype(exec.out_dtype))


def _execute_loop(plan, vals, b, exec):
    """Per-shard execution: correct for any shard mix, any device count."""
    meta = plan.meta
    outs = []
    for i, (p, slot) in enumerate(zip(plan.shards, plan.vals_slots)):
        lb = _local_b(b, plan.b_rows[i]) if meta.dim == "cols" else b
        outs.append(execute_plan(p, _local_vals(vals, slot), lb, exec))
    if meta.dim == "rows":
        return _concat_rows(outs, meta.bounds)
    return sum(outs[1:], outs[0])


def _execute_spmd(plan, vals, b, exec, mesh):
    """One shard_map dispatch: every device runs its local planned kernel."""
    meta = plan.meta
    axis = meta.axis
    stacked_plan, slot_stack, brow_stack = plan._stacked()

    if meta.dim == "rows":
        def body(plan_s, slot_s, vals, b):
            local = jax.tree.map(lambda x: x[0], plan_s)
            out = execute_plan(local, _local_vals(vals, slot_s[0]), b, exec)
            return out[None]

        out = shard_map(
            body, mesh=mesh,
            in_specs=(P(axis), P(axis), P(), P()),
            out_specs=P(axis), check_rep=False,
        )(stacked_plan, slot_stack, vals, b)
        return _concat_rows([out[i] for i in range(meta.n_shards)],
                            meta.bounds)

    def body(plan_s, slot_s, brow_s, vals, b):
        local = jax.tree.map(lambda x: x[0], plan_s)
        partial = execute_plan(local, _local_vals(vals, slot_s[0]),
                               _local_b(b, brow_s[0]), exec)
        return jax.lax.psum(partial, axis)

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(), P()),
        out_specs=P(), check_rep=False,
    )(stacked_plan, slot_stack, brow_stack, vals, b)
