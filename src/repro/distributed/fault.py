"""Fault tolerance hooks: preemption-safe checkpointing and straggler
watermarking.

At 1000+ nodes, failures are routine.  The strategy (see DESIGN.md §7):

* **Preemption** (SIGTERM from the scheduler): set a flag; the training
  loop checkpoints at the next step boundary and exits 0 so the scheduler
  restarts it; ``--resume auto`` picks up the latest step.
* **Hard node failure**: the persistent checkpoint cadence bounds lost
  work; the deterministic data pipeline replays the exact remaining
  batches.
* **Stragglers**: in SPMD, one slow chip slows the step — per-step wall
  times are watermarked against a running median and offenders logged with
  their step index so the operator (or an outer controller) can cordon the
  pod and trigger an elastic resize.  The hot-spare-pod pattern: keep the
  ``pod`` axis outermost, shadow a spare pod on the same data shards, and
  swap at the collective boundary.
"""
from __future__ import annotations

import signal
import time


class PreemptionGuard:
    def __init__(self):
        self.requested = False
        self._installed = False

    def install(self):
        if self._installed:
            return self
        self._prev = signal.signal(signal.SIGTERM, self._handler)
        self._installed = True
        return self

    def _handler(self, signum, frame):
        self.requested = True

    def should_checkpoint(self) -> bool:
        return self.requested


class StragglerWatermark:
    """EMA-median step-time monitor; flags steps > factor × median."""

    def __init__(self, factor: float = 2.0, warmup: int = 5):
        self.factor = factor
        self.warmup = warmup
        self.median = None
        self.count = 0
        self.flagged: list[tuple[int, float]] = []

    def observe(self, step: int, seconds: float) -> bool:
        self.count += 1
        if self.median is None:
            self.median = seconds
        is_straggler = (self.count > self.warmup
                        and seconds > self.factor * self.median)
        # robust-ish streaming median: bounded multiplicative update
        self.median += 0.1 * self.median * (
            1.0 if seconds > self.median else -1.0)
        if is_straggler:
            self.flagged.append((step, seconds))
        return is_straggler


class StepTimer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.seconds = time.perf_counter() - self.t0


def retry(fn, attempts: int = 3, backoff: float = 1.0,
          exceptions=(IOError, OSError), on_retry=None):
    """Retry transient failures with exponential backoff.

    Covers checkpoint I/O to network filesystems and the serving layer's
    batch execution (``repro.serving.Server``).  ``on_retry(attempt,
    exc)`` fires before each backoff sleep — the hook the serving loop
    uses to count retries on the metrics registry; the final attempt's
    exception propagates unchanged.
    """
    for i in range(attempts):
        try:
            return fn()
        except exceptions as e:
            if i == attempts - 1:
                raise
            if on_retry is not None:
                on_retry(i + 1, e)
            time.sleep(backoff * (2 ** i))
