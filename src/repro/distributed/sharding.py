"""Sharding rules: parameter FSDP×TP, activation DP, cache layouts.

Scheme (MaxText-style 2D + optional pod axis):

* mesh axes: ``("data", "model")`` single-pod, ``("pod", "data", "model")``
  multi-pod.  ``dp`` below = ``("pod", "data")`` when the pod axis exists.
* params: FSDP-shard the *contracting-free* large dim over ``data`` and
  tensor-shard the other over ``model`` (XLA GSPMD inserts the FSDP
  all-gathers, overlapped with the layer scan, and the TP partial-sum
  all-reduces).
* every rule is divisibility-checked: an axis that does not divide the dim
  is dropped (e.g. granite's vocab 49155 over 16) — correctness first,
  the roofline shows the cost.
* batch:  ``(dp, None, ...)``;  KV caches: batch over ``dp`` when it
  divides, sequence over ``model`` (flash-decoding style), and over
  ``dp×model`` for the 500k single-sequence cell.
"""
from __future__ import annotations


import contextlib
import threading

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def dp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _fit(mesh: Mesh, dim: int, axes):
    """Return axes if they divide dim, else progressively drop axes."""
    if axes is None:
        return None
    if isinstance(axes, str):
        axes = (axes,)
    while axes and dim % _axis_size(mesh, axes) != 0:
        axes = axes[:-1]
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def _p(mesh, dims, *axes):
    """PartitionSpec with divisibility-checked axes per dim."""
    return P(*[_fit(mesh, d, a) for d, a in zip(dims, axes)])


# ----------------------------------------------------------- parameters ----

_ROW = object()   # shard dim over fsdp(data)
_COL = object()   # shard dim over model

_PARAM_RULES = {
    # name -> axes for the *last* ndims (leading scan dims -> None)
    "embed": ("data", "model"),
    "unembed": ("data", "model"),
    "router": ("data", None),
    "wq": ("data", "model"), "wk": ("data", "model"),
    "wv": ("data", "model"), "wo": ("model", "data"),
    "bq": ("model",), "bk": ("model",), "bv": ("model",),
    "w1": ("data", "model"), "w3": ("data", "model"),
    "w2": ("model", "data"),
    "in_proj": ("data", "model"), "out_proj": ("model", "data"),
    "conv": (None, "model"),
    "wx_in": ("data", "model"), "wg_in": ("data", "model"),
    "out": ("model", "data"),
    "gate_a": ("model",), "gate_x": ("model",), "lam": ("model",),
    "scale": (None,), "bias": (None,),
    "a_log": (None,), "d_skip": (None,), "dt_bias": (None,),
}

_MOE_3D = {"w1": (None, "data", "model"), "w3": (None, "data", "model"),
           "w2": (None, "model", "data")}


def param_pspec(path, leaf, mesh: Mesh, mode: str = "fsdp") -> P:
    """mode: "fsdp" (data-FSDP × model-TP), "zero1" (model-TP only —
    compute replica; master is FSDP inside the optimizer), "fsdp2"
    (pure ZeRO-3 over the flattened data×model axes, no TP)."""
    name = None
    for k in reversed(path):
        if isinstance(k, jax.tree_util.DictKey):
            name = str(k.key)
            break
    dims = leaf.shape
    if name not in _PARAM_RULES:
        return P()
    rules = _PARAM_RULES[name]
    # MoE expert weights have a trailing (E, d_in, d_out) signature
    if name in _MOE_3D and len(dims) >= 3 and name in ("w1", "w2", "w3"):
        # distinguish from stacked dense mlp (count, d, ff) by checking the
        # path for "moe"
        if any(isinstance(k, jax.tree_util.DictKey) and str(k.key) == "moe"
               for k in path):
            rules = _MOE_3D[name]
            # NOTE §Perf iteration 10: expert-parallel weight sharding
            # (E over data) was tested and REFUTED — GSPMD reshards the
            # token buffer to the expert layout at 9× the wire bytes.
    if mode == "zero1":       # compute replica: model axes only
        rules = tuple(None if r == "data" else r for r in rules)
    elif mode == "fsdp2":     # ZeRO-3 over every device, no TP
        dpm = dp_axes(mesh) + ("model",)
        rules = tuple(dpm if r == "data" else None for r in rules)
    lead = len(dims) - len(rules)
    if lead < 0:  # unexpected rank; replicate
        return P()
    axes = (None,) * lead + tuple(rules)
    return _p(mesh, dims, *axes)


def params_shardings(params, mesh: Mesh, mode: str = "fsdp"):
    if mode is True:   # backwards compat: fsdp flag
        mode = "fsdp"
    elif mode is False:
        mode = "zero1"
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, param_pspec(path, leaf, mesh, mode)),
        params)


# ------------------------------------------------------------ batches ------


def batch_pspec(shape, mesh: Mesh, batch_axis: int = 0,
                include_model: bool = False) -> P:
    dp = dp_axes(mesh)
    if include_model:
        dp = dp + ("model",)
    axes = [None] * len(shape)
    axes[batch_axis] = dp
    return _p(mesh, shape, *axes)


def batch_shardings(batch, mesh: Mesh, batch_axis: int = 0,
                    include_model: bool = False):
    return jax.tree.map(
        lambda leaf: NamedSharding(
            mesh, batch_pspec(leaf.shape, mesh, batch_axis, include_model)),
        batch)


# ------------------------------------------------------------- caches ------


def cache_pspec(path, leaf, mesh: Mesh) -> P:
    """KV caches (b, S, kv, dh): batch over dp, seq over model; if the
    batch doesn't shard (e.g. b=1 at 500k), sequence takes dp too.
    Recurrent states (b, ...): batch over dp, widest trailing dim over
    model."""
    dims = leaf.shape
    name = None
    for k in reversed(path):
        if isinstance(k, jax.tree_util.DictKey):
            name = str(k.key)
            break
    dp = dp_axes(mesh)
    if name in ("k", "v") and len(dims) == 5:   # (layers, b, S, kv, dh)
        b, s = dims[1], dims[2]
        if b % _axis_size(mesh, dp) == 0:
            return _p(mesh, dims, None, dp, "model", None, None)
        return _p(mesh, dims, None, None, dp + ("model",), None, None)
    if name == "ssm" and len(dims) == 5:        # (layers, b, H, P, N)
        return _p(mesh, dims, None, dp, "model", None, None)
    if name == "conv" and len(dims) == 4:       # (layers, b, w-1, c)
        return _p(mesh, dims, None, dp, None, "model")
    if name == "h" and len(dims) == 3:          # (layers, b, w)
        return _p(mesh, dims, None, dp, "model")
    # fallback: batch over dp on axis 1 (after layer-stack axis)
    axes = [None] * len(dims)
    if len(dims) >= 2:
        axes[1] = dp
    return _p(mesh, dims, *axes)


def cache_shardings(caches, mesh: Mesh):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, cache_pspec(path, leaf, mesh)),
        caches)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


# ------------------------------------------- activation constraints --------
#
# GSPMD left alone will reshard activations inside scan bodies (per-chunk
# collective-permutes / all-gathers — see EXPERIMENTS.md §Perf iteration 0).
# The model code pins the layouts it wants through ``constrain``, which is a
# no-op unless a launcher activates a mesh via ``use_mesh`` (CPU unit tests
# run unconstrained).

_TLS = threading.local()


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    """Activate activation-sharding constraints for model code."""
    prev = getattr(_TLS, "mesh", None)
    _TLS.mesh = mesh
    try:
        with mesh:
            yield mesh
    finally:
        _TLS.mesh = prev


def active_mesh() -> Mesh | None:
    return getattr(_TLS, "mesh", None)


def constrain(x, *axes):
    """with_sharding_constraint(x, P(axes…)) under the active mesh.

    Axis entries: ``"dp"`` → the data(+pod) axes, ``"dpm"`` → data(+pod)
    +model flattened (pure-FSDP mode), ``"model"``, ``None``.
    Divisibility-checked like every other rule; identity when no mesh is
    active."""
    mesh = active_mesh()
    if mesh is None:
        return x
    def resolve(a):
        if a == "dp":
            return dp_axes(mesh)
        if a == "dpm":
            return dp_axes(mesh) + ("model",)
        return a
    named = [resolve(a) for a in axes]
    spec = _p(mesh, x.shape, *named)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec))
