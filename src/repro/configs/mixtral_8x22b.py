"""mixtral-8x22b [moe] — 8 experts top-2, SWA [arXiv:2401.04088; hf]."""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    segments=((("moe",), 56),),
    num_experts=8,
    top_k=2,
    attention="swa",
    window=4096,
    tie_embeddings=False,
    rope_theta=1_000_000.0,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=0, d_ff=96, vocab_size=256, num_experts=4, top_k=2,
        window=16, segments=((("moe",), 2),))
