"""internvl2-76b [vlm] — InternViT + LLM backbone [arXiv:2404.16821;
unverified].  The InternViT patch frontend is a STUB per the brief:
``input_specs()`` provides precomputed patch/text embeddings."""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    input_mode="embeddings",
    tie_embeddings=False,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=0, d_ff=128, vocab_size=512, segments=())
