"""command-r-35b [dense] — GQA, no-bias, parallel blocks
[hf:CohereForAI/c4ai-command-r-v01; unverified]."""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22528,
    vocab_size=256000,
    norm="layernorm",
    parallel_block=True,
    tie_embeddings=True,
    rope_theta=8_000_000.0,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=0, d_ff=128, vocab_size=512, segments=())
