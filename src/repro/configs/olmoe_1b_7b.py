"""olmoe-1b-7b [moe] — 64 experts top-8 [arXiv:2409.02060; hf]."""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    segments=((("moe",), 16),),
    num_experts=64,
    top_k=8,
    tie_embeddings=False,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=0, d_ff=32, vocab_size=256, num_experts=8, top_k=2,
        segments=((("moe",), 2),))
