"""musicgen-large [audio] — decoder-only over EnCodec tokens
[arXiv:2306.05284; hf].  The EnCodec frontend is a STUB per the brief:
``input_specs()`` provides precomputed frame embeddings."""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    input_mode="embeddings",
    mlp="gelu",
    norm="layernorm",
    rope_theta=0.0,          # sinusoidal absolute positions
    tie_embeddings=False,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=0, d_ff=128, vocab_size=128, segments=())
