"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1 attn per 2
recurrent layers [arXiv:2402.19427; hf].  26 layers = 8×(rec,rec,attn)+2rec.
Sub-quadratic (local window 2048) → runs long_500k."""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    d_ff=7680,
    vocab_size=256000,
    segments=(
        (("rglru", "rglru", "attn"), 8),
        (("rglru", "rglru"), 1),
    ),
    attention="local",
    window=2048,
    lru_width=2560,
    conv_width=4,
    embed_scale=True,
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=5, d_model=64, num_heads=4, num_kv_heads=1,
        head_dim=0, d_ff=128, vocab_size=256, window=16, lru_width=64,
        segments=((("rglru", "rglru", "attn"), 1), (("rglru", "rglru"), 1)))
