"""Architecture registry: ``get_config(name)`` / ``get_smoke_config(name)``.

The 10 assigned architectures, selectable via ``--arch <id>`` in the
launchers, plus the paper's own SpMM benchmark config.
"""
from __future__ import annotations

import importlib

from .base import SHAPES, ModelConfig, ShapeConfig, TrainConfig

_MODULES = {
    "olmoe-1b-7b": "olmoe_1b_7b",
    "mixtral-8x22b": "mixtral_8x22b",
    "command-r-35b": "command_r_35b",
    "granite-3-2b": "granite_3_2b",
    "qwen2-72b": "qwen2_72b",
    "llama3.2-1b": "llama3_2_1b",
    "musicgen-large": "musicgen_large",
    "internvl2-76b": "internvl2_76b",
    "mamba2-1.3b": "mamba2_1_3b",
    "recurrentgemma-2b": "recurrentgemma_2b",
}

ARCHS = tuple(_MODULES)

# long_500k needs sub-quadratic sequence mixing (see DESIGN.md §5):
SUBQUADRATIC = ("mixtral-8x22b", "mamba2-1.3b", "recurrentgemma-2b")


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {list(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    return _module(name).smoke_config()


def shape_cells(arch: str):
    """The (arch × shape) cells that run for this arch (skips documented
    in DESIGN.md §5: long_500k for pure full-attention archs)."""
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if arch in SUBQUADRATIC:
        cells.append("long_500k")
    return cells


__all__ = ["ARCHS", "SHAPES", "SUBQUADRATIC", "ModelConfig", "ShapeConfig",
           "TrainConfig", "get_config", "get_smoke_config", "shape_cells"]
