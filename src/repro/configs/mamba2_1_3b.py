"""mamba2-1.3b [ssm] — SSD (state-space duality) [arXiv:2405.21060;
unverified].  Attention-free; sub-quadratic → runs long_500k."""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    segments=((("ssd",), 48),),
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=128,
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, vocab_size=256, ssm_state=16,
        ssm_head_dim=16, ssm_chunk=8, segments=((("ssd",), 2),))
