"""qwen2-72b [dense] — GQA, QKV bias [arXiv:2407.10671; hf]."""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    tie_embeddings=False,
    rope_theta=1_000_000.0,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=0, d_ff=128, vocab_size=512, segments=())
