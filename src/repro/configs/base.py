"""Config system: model architecture + run shapes.

Every assigned architecture gets a ``configs/<id>.py`` exporting
``CONFIG`` (exact published numbers) and ``smoke_config()`` (reduced
same-family variant for CPU tests).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int              # query heads (0 for attention-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 → d_model // num_heads

    # --- block structure -------------------------------------------------
    # segments: ((pattern, repeat), ...) where pattern is a tuple of block
    # types from {"attn", "moe", "ssd", "rglru"}; "attn" blocks carry an MLP,
    # per standard pre-norm transformer blocks.
    segments: tuple[tuple[tuple[str, ...], int], ...] = ()

    # --- attention --------------------------------------------------------
    attention: str = "full"     # full | swa | local
    window: int = 4096
    qkv_bias: bool = False
    attn_logit_softcap: float = 0.0

    # --- MoE ----------------------------------------------------------------
    num_experts: int = 0
    top_k: int = 0
    moe_impl: str = "sort"      # sort (merge-based, paper) | dense (einsum)
    moe_groups: int = 0         # >1: hierarchical (per-shard) dispatch

    # --- SSM (mamba2) -----------------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 64

    # --- RG-LRU (recurrentgemma) -------------------------------------------
    lru_width: int = 0          # 0 → d_model
    conv_width: int = 4

    # --- embeddings / io ----------------------------------------------------
    input_mode: str = "tokens"  # tokens | embeddings (audio/vlm stub frontend)
    tie_embeddings: bool = True
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    rope_theta: float = 10_000.0  # 0 → sinusoidal absolute positions
    logit_softcap: float = 0.0
    parallel_block: bool = False  # command-r style parallel attn+FFN
    embed_scale: bool = False     # multiply embeddings by sqrt(d)
    mlp: str = "swiglu"           # swiglu | gelu

    # --- paper technique ----------------------------------------------------
    ffn_prune: float = 0.0      # >0: serve FFN via CSR SpMM, keep fraction

    # --- numerics -----------------------------------------------------------
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    # --- distribution ----------------------------------------------------
    # layout of the (b, s, d) residual stream between blocks:
    #   ("dp", None, None)     — batch-sharded, d replicated (TP classic)
    #   ("dp", "model", None)  — + sequence-parallel over the model axis
    #   ("dpm", None, None)    — pure-FSDP: batch over every device
    residual_spec: Tuple = ("dp", None, None)
    # False → no tensor parallelism: internal activations follow the batch
    # (pure ZeRO-3 data parallel; used with param_mode="fsdp2")
    tp: bool = True

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim",
                               self.d_model // self.num_heads)
        if not self.segments:
            object.__setattr__(self, "segments",
                               ((("attn",), self.num_layers),))
        n = sum(len(p) * r for p, r in self.segments)
        assert n == self.num_layers, \
            f"segments cover {n} layers, config says {self.num_layers}"

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def block_types(self):
        out = []
        for pattern, reps in self.segments:
            out += list(pattern) * reps
        return out

    def param_count(self) -> int:
        """Approximate parameter count N (for MODEL_FLOPS = 6·N·D)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim
        n = v * d  # embed
        if not self.tie_embeddings:
            n += v * d
        for bt in self.block_types():
            if bt == "attn":
                n += d * (self.num_heads * hd)
                n += 2 * d * (self.num_kv_heads * hd)
                n += (self.num_heads * hd) * d
                if ff:
                    n += 3 * d * ff  # SwiGLU
            elif bt == "moe":
                n += d * (self.num_heads * hd)
                n += 2 * d * (self.num_kv_heads * hd)
                n += (self.num_heads * hd) * d
                n += self.num_experts * 3 * d * ff + d * self.num_experts
            elif bt == "ssd":
                din = self.ssm_expand * d
                heads = din // self.ssm_head_dim
                n += d * (2 * din + 2 * self.ssm_state + heads) + din * d
            elif bt == "rglru":
                w = self.lru_width or d
                n += 2 * d * w + w * d + 3 * w
        return n

    def active_param_count(self) -> int:
        """N_active for MoE (top_k of num_experts)."""
        if self.num_experts == 0:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        dense_experts = 0
        for bt in self.block_types():
            if bt == "moe":
                dense_experts += (self.num_experts - self.top_k) * 3 * d * ff
        return self.param_count() - dense_experts


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str               # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    seq_len: int = 2048
    global_batch: int = 8
    microbatches: int = 1        # gradient accumulation steps
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    remat: bool = True
    seed: int = 0
    # distributed-optimization tricks
    grad_compression: str = "none"   # none | int8_ef
    loss_chunk: int = 512            # vocab-chunked CE sequence chunk
