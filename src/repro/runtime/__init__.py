from .steps import (init_train_state, make_decode_step, make_prefill_step,
                    make_train_step)

__all__ = ["init_train_state", "make_decode_step", "make_prefill_step",
           "make_train_step"]
