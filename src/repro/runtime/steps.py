"""Step functions: the units the launcher jits and the dry-run lowers.

``make_train_step``: fwd+bwd+AdamW with scan-over-microbatches gradient
accumulation (bounds live activations), remat, and optional int8
error-feedback gradient compression of the cross-replica payload.

``make_prefill_step`` / ``make_decode_step``: the serving pair.

``ensure_spmm_plans`` / ``make_sparse_train_step``: the SpMM-engine hooks —
plans are (re)built through the engine cache once, outside jit, and the
jitted steps only ever execute them.

``microbatched``: wrap a jitted step so one compiled program serves any
request batch in fixed-size leading-axis slices — the serving loop's
dispatch amortizer on top of the engine's batched plan execution.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models import sparse as S
from repro.optim import adamw
from repro.optim import compression as gc


def make_train_step(cfg, opt_cfg: adamw.AdamWConfig, *,
                    microbatches: int = 1, remat: bool = True,
                    loss_chunk: int = 512,
                    grad_compression: str = "none",
                    param_mode: str = "fsdp"):
    """Returns train_step(state, batch) -> (state, metrics).

    state = {params, opt, [residual]};  batch leaves have leading dim
    global_batch (sharded over dp by the caller's in_shardings).
    ``param_mode``: "fsdp" (f32 params sharded over data+model; gathered
    per use) or "zero1" (bf16 compute params sharded over model only; f32
    master + moments FSDP-sharded in the optimizer state).
    """

    def loss_fn(params, mb):
        loss, aux = M.loss_and_aux(params, cfg, mb, remat=remat,
                                   loss_chunk=loss_chunk)
        return loss, aux

    def grads_of(params, batch):
        if microbatches == 1:
            (loss, aux), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return loss, aux, grads

        # batch arrives pre-shaped (microbatches, local, ...) — sharded on
        # axis 1 — and is scanned over axis 0.  (Slicing a dp-sharded batch
        # axis instead makes GSPMD all-gather the whole batch per
        # microbatch; see EXPERIMENTS.md §Perf iteration 0.)
        def acc_step(carry, mb):
            loss_acc, grads_acc = carry
            (loss, aux), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            grads_acc = jax.tree.map(jnp.add, grads_acc, grads)
            return (loss_acc + loss, grads_acc), aux

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, grads), auxs = jax.lax.scan(
            acc_step, (jnp.zeros(()), zeros), batch)
        inv = 1.0 / microbatches
        grads = jax.tree.map(lambda g: g * inv, grads)
        aux = jax.tree.map(lambda a: a[-1], auxs)
        return loss_sum * inv, aux, grads

    def train_step(state, batch):
        params, opt = state["params"], state["opt"]
        loss, aux, grads = grads_of(params, batch)
        if grad_compression == "int8_ef":
            grads, residual = gc.roundtrip(grads, state["residual"])
        if param_mode == "zero1":
            new_params, new_opt, metrics = adamw.apply_updates_zero1(
                params, grads, opt, opt_cfg)
        else:
            new_params, new_opt, metrics = adamw.apply_updates(
                params, grads, opt, opt_cfg)
        new_state = {"params": new_params, "opt": new_opt}
        if grad_compression == "int8_ef":
            new_state["residual"] = residual
        metrics = dict(metrics, loss=loss, nll=aux["nll"], aux=aux["aux"])
        return new_state, metrics

    return train_step


def make_prefill_step(cfg, *, cache_len: int | None = None):
    def prefill_step(params, batch):
        caches, logits, pos = M.prefill(params, cfg, batch,
                                        cache_len=cache_len)
        return {"caches": caches, "logits": logits, "pos": pos}

    return prefill_step


def make_decode_step(cfg):
    def decode_step(params, caches, batch, pos):
        logits, new_caches = M.decode_step(params, cfg, caches, batch, pos)
        return logits, new_caches

    return decode_step


def ensure_spmm_plans(tree, policy=None, mesh=None):
    """(Re)attach engine-cached SpmmPlans to every sparse leaf in a tree.

    Covers both ``SparseLinear`` layers and bare ``SparseMatrix`` leaves.
    Call once, outside jit, after init / checkpoint restore / pattern
    surgery — the engine cache makes it free when plans already exist, and
    it is the identity for trees without sparse leaves.  Jitted steps then
    receive prebuilt plans and never replan (verified by the cache-hit
    counter test in tests/test_engine.py).  ``policy`` (a
    ``repro.PlanPolicy``) pins the plan request for every leaf; with
    ``mesh`` given (or ``policy.shards`` set) every leaf gets a
    device-sharded plan — nnz-balanced row shards, one local plan per
    shard (``repro.distributed.spmm``).
    """
    from repro.core import SparseMatrix

    def attach(x):
        if mesh is not None:
            if policy is not None and policy.shards is not None:
                raise ValueError(
                    "ensure_spmm_plans: pass the mesh either as mesh= or "
                    "inside policy.shards, not both")
            return x.shard(mesh, policy=policy)   # SparseLinear or matrix
        if isinstance(x, S.SparseLinear):
            return x.with_plan(policy=policy)
        if policy is None and x.spmm_plan is not None:
            # Replay the existing plan's full statics (method AND tuned
            # t/tl/l_pad — mirrors the SparseLinear branch) instead of
            # re-resolving "auto" to defaults; falls back to the method
            # alone if pattern surgery outgrew a derived parameter.
            return x.plan_like(x.spmm_plan.meta)
        return x.plan(policy)

    is_sparse = lambda x: isinstance(x, (S.SparseLinear, SparseMatrix))
    return jax.tree.map(lambda x: attach(x) if is_sparse(x) else x, tree,
                        is_leaf=is_sparse)


def make_sparse_train_step(sparse_p: dict, *, lr: float = 1e-2,
                           impl: str = "pallas",
                           interpret: bool | None = None):
    """SGD step over the CSR *values* of a SparseLinear MLP (sparse
    fine-tuning: the pruned pattern — and therefore every plan — is
    frozen; values are the degrees of freedom).

    Returns ``(step, vals0)``; ``step(vals, x, y) -> (vals, loss)`` is
    jit-ready and exercises the full differentiable SpMM: forward through
    the cached plans, ``dB`` through the transpose merge plans, ``dvals``
    through the SDDMM kernel.
    """
    from repro.core import ExecutionConfig

    sparse_p = ensure_spmm_plans(sparse_p)
    run = ExecutionConfig(impl=impl, interpret=interpret)

    def loss_fn(vals, x, y):
        layers = S.mlp_with_vals(sparse_p, vals)
        pred = S.sparse_mlp_apply(layers, x, None, exec=run)
        return jnp.mean((pred - y) ** 2)

    def step(vals, x, y):
        loss, grads = jax.value_and_grad(loss_fn)(vals, x, y)
        vals = jax.tree.map(lambda v, g: v - lr * g.astype(v.dtype),
                            vals, grads)
        return vals, loss

    return step, S.mlp_vals(sparse_p)


def microbatched(fn, microbatch: int, *, argnums=(0,), pad=True):
    """Run ``fn`` over fixed-size slices of the selected args' leading axis.

    ``fn`` (typically jitted) is called once per ``microbatch``-sized slice
    of every arg in ``argnums`` (other args pass through whole), and the
    per-slice outputs are concatenated along axis 0.  Because every slice
    has the same static shape, a single compiled program serves any request
    batch — a ragged tail (``total % microbatch != 0``, or ``total``
    smaller than one microbatch) is padded up to the microbatch shape by
    repeating its last row and the padded rows are trimmed from the
    concatenated outputs, so ``fn`` never sees a second shape and jit
    never recompiles.  ``pad=False`` restores the strict behaviour:
    ragged totals raise instead of padding (for callers whose ``fn``
    mixes rows, e.g. a batch-mean loss, where silent padding would skew
    the result).
    """
    if microbatch <= 0:
        raise ValueError(f"microbatch must be positive, got {microbatch}")

    def run(*args):
        sizes = {args[i].shape[0] for i in argnums}
        if len(sizes) != 1:
            raise ValueError(
                f"microbatched args disagree on the leading axis: {sizes}")
        (total,) = sizes
        if total == 0:
            raise ValueError("microbatched got an empty batch")
        rem = total % microbatch
        if rem and not pad:
            raise ValueError(
                f"batch {total} does not divide into microbatches of "
                f"{microbatch}; pad the batch or change --microbatch")
        outs = []
        for s in range(0, total, microbatch):
            n = min(microbatch, total - s)

            def cut(a):
                sl = a[s:s + n]
                if n < microbatch:
                    fill = jnp.repeat(sl[-1:], microbatch - n, axis=0)
                    sl = jnp.concatenate([sl, fill], axis=0)
                return sl

            sliced = [cut(a) if i in argnums else a
                      for i, a in enumerate(args)]
            outs.append(fn(*sliced))
        out = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *outs)
        if rem:
            out = jax.tree.map(lambda x: x[:total], out)
        return out

    return run


def init_train_state(cfg, key, *, grad_compression: str = "none",
                     param_mode: str = "fsdp"):
    params = M.init_params(cfg, key)
    if param_mode == "zero1":
        params, opt = adamw.init_state_zero1(params, cfg.cdtype)
        state = {"params": params, "opt": opt}
    else:
        state = {"params": params, "opt": adamw.init_state(params)}
    if grad_compression == "int8_ef":
        state["residual"] = gc.init_residual(state["params"])
    return state
