"""MatrixMarket coordinate I/O producing :class:`repro.core.CSR`.

Supports the subset that covers SuiteSparse sparsity corpora: banner
``%%MatrixMarket matrix coordinate {real|integer|pattern}
{general|symmetric|skew-symmetric}``.  Symmetric storage keeps only the
lower (or upper) triangle; the reader expands off-diagonal entries to both
``(i, j)`` and ``(j, i)`` (negated for skew-symmetric), so the returned CSR
always holds the *full* pattern.  Duplicate coordinates are summed, the
assembly convention finite-element exporters rely on.

The writer emits only the true (unpadded) nonzeroes, 1-based, with
``%.17g`` values — a write→read round-trip is exact on the pattern and
bit-exact on float64 values (well within the ≤1e-6 acceptance bound).
"""
from __future__ import annotations

import io
import os
from collections.abc import Iterable
from typing import IO

import jax.numpy as jnp
import numpy as np

from repro.core.csr import CSR

_FIELDS = ("real", "integer", "pattern")
_SYMMETRIES = ("general", "symmetric", "skew-symmetric")


def _coo_to_csr(rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
                m: int, k: int, dtype) -> CSR:
    """Assemble (possibly duplicated, unsorted) COO triplets into CSR."""
    if rows.size:
        order = np.lexsort((cols, rows))
        rows, cols, vals = rows[order], cols[order], vals[order]
        # Sum duplicates: collapse runs of identical (row, col).
        keep = np.ones(rows.size, bool)
        keep[1:] = (rows[1:] != rows[:-1]) | (cols[1:] != cols[:-1])
        if not keep.all():
            seg = np.cumsum(keep) - 1
            summed = np.zeros(int(seg[-1]) + 1, np.float64)
            np.add.at(summed, seg, vals)
            rows, cols, vals = rows[keep], cols[keep], summed
    nnz = rows.size
    row_ptr = np.zeros(m + 1, np.int32)
    np.cumsum(np.bincount(rows, minlength=m), out=row_ptr[1:])
    nnz_pad = max(nnz, 1)
    col_ind = np.zeros(nnz_pad, np.int32)
    out_vals = np.zeros(nnz_pad, np.float64)
    col_ind[:nnz] = cols
    out_vals[:nnz] = vals
    return CSR(jnp.asarray(row_ptr), jnp.asarray(col_ind),
               jnp.asarray(out_vals, dtype=dtype), (m, k))


def read_mtx(source: str | os.PathLike | IO[str], *,
             dtype=jnp.float32) -> CSR:
    """Read a MatrixMarket coordinate file into a CSR.

    ``source`` is a path or an open text stream.  Pattern matrices get
    value 1.0 on every stored entry.
    """
    if hasattr(source, "read"):
        return _read_stream(source, dtype)
    with open(source, "r") as f:
        return _read_stream(f, dtype)


def _read_stream(f: IO[str], dtype) -> CSR:
    banner = f.readline().split()
    if len(banner) < 5 or banner[0] != "%%MatrixMarket" \
            or banner[1].lower() != "matrix":
        raise ValueError(f"not a MatrixMarket matrix file: {banner!r}")
    layout, field, symmetry = (s.lower() for s in banner[2:5])
    if layout != "coordinate":
        raise ValueError(f"only coordinate layout is supported, got "
                         f"{layout!r} (array = dense; densify upstream)")
    if field not in _FIELDS:
        raise ValueError(f"unsupported field {field!r} (supported: "
                         f"{_FIELDS}; complex matrices have no SpMM here)")
    if symmetry not in _SYMMETRIES:
        raise ValueError(f"unsupported symmetry {symmetry!r} "
                         f"(supported: {_SYMMETRIES})")

    line = f.readline()
    while line and (line.startswith("%") or not line.strip()):
        line = f.readline()
    if not line:
        raise ValueError("missing size line")
    m, k, nnz_decl = (int(tok) for tok in line.split()[:3])

    rows = np.empty(nnz_decl, np.int64)
    cols = np.empty(nnz_decl, np.int64)
    vals = np.ones(nnz_decl, np.float64)
    n = 0
    for line in f:
        toks = line.split()
        if not toks or toks[0].startswith("%"):
            continue
        if n >= nnz_decl:
            raise ValueError(f"more entries than declared ({nnz_decl})")
        rows[n] = int(toks[0]) - 1
        cols[n] = int(toks[1]) - 1
        if field != "pattern":
            vals[n] = float(toks[2])
        n += 1
    if n != nnz_decl:
        raise ValueError(f"declared {nnz_decl} entries, found {n}")
    if n and (rows.min() < 0 or rows.max() >= m
              or cols.min() < 0 or cols.max() >= k):
        raise ValueError(f"entry index out of declared bounds ({m} x {k})")

    if symmetry != "general":
        off = rows != cols
        sign = -1.0 if symmetry == "skew-symmetric" else 1.0
        rows = np.concatenate([rows, cols[off]])
        cols = np.concatenate([cols, rows[:n][off]])
        vals = np.concatenate([vals, sign * vals[off]])
    return _coo_to_csr(rows.astype(np.int64), cols.astype(np.int64),
                       vals, m, k, dtype)


def write_mtx(dest: str | os.PathLike | IO[str], a: CSR, *,
              field: str = "real",
              comments: Iterable[str] = ()) -> None:
    """Write a CSR as MatrixMarket ``coordinate <field> general``.

    Only the true nonzeroes are emitted (the static pad is an in-memory
    artifact, not part of the matrix).  ``field="pattern"`` drops values.
    """
    if field not in ("real", "integer", "pattern"):
        raise ValueError(f"unsupported write field {field!r}")
    rp = np.asarray(a.row_ptr)
    nnz = int(rp[-1])
    rows = np.repeat(np.arange(a.m, dtype=np.int64), np.diff(rp))
    cols = np.asarray(a.col_ind)[:nnz]
    vals = np.asarray(a.vals, dtype=np.float64)[:nnz]

    buf = io.StringIO()
    buf.write(f"%%MatrixMarket matrix coordinate {field} general\n")
    for c in comments:
        buf.write(f"% {c}\n")
    buf.write(f"{a.m} {a.k} {nnz}\n")
    if field == "pattern":
        for r, c in zip(rows, cols):
            buf.write(f"{r + 1} {c + 1}\n")
    elif field == "integer":
        for r, c, v in zip(rows, cols, vals):
            buf.write(f"{r + 1} {c + 1} {int(round(v))}\n")
    else:
        for r, c, v in zip(rows, cols, vals):
            buf.write(f"{r + 1} {c + 1} {v:.17g}\n")
    text = buf.getvalue()
    if hasattr(dest, "write"):
        dest.write(text)
    else:
        with open(dest, "w") as f:
            f.write(text)
