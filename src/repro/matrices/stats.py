"""Per-matrix row-length statistics — the axes the paper plots.

``d`` (mean row length) is the §5.4 heuristic input; the coefficient of
variation and Gini coefficient quantify the Fig. 1 imbalance axis (Type 1:
few long rows; Type 2: many short rows).  These are also the features the
autotuner bins into pattern-class signatures (``repro.tune``), so they are
computed host-side from the concrete pattern.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.csr import CSR


@dataclasses.dataclass(frozen=True)
class MatrixStats:
    m: int
    k: int
    nnz: int
    d: float          # mean row length, the §5.4 heuristic quantity
    cv: float         # std / mean of row lengths (0 = perfectly regular)
    gini: float       # row-length Gini imbalance in [0, 1) (Fig. 1 axis)
    max_len: int      # the row-split ELL pad (l_pad) driver

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def compute_stats(a: CSR) -> MatrixStats:
    """Host-side row-length statistics of a concrete CSR."""
    lengths = np.diff(np.asarray(a.row_ptr)).astype(np.float64)
    nnz = float(lengths.sum())
    d = nnz / max(a.m, 1)
    if nnz > 0:
        cv = float(lengths.std() / d) if d > 0 else 0.0
        sorted_l = np.sort(lengths)
        n = sorted_l.size
        # Gini = sum_i (2i - n - 1) x_(i) / (n * sum(x)), i = 1..n sorted
        ranks = 2.0 * np.arange(1, n + 1, dtype=np.float64) - n - 1.0
        gini = float((ranks * sorted_l).sum() / (n * nnz)) if n else 0.0
        gini = max(gini, 0.0)
    else:
        cv, gini = 0.0, 0.0
    return MatrixStats(m=a.m, k=a.k, nnz=int(nnz), d=d, cv=cv,
                       gini=gini, max_len=int(lengths.max()) if
                       lengths.size else 0)
