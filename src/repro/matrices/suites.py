"""Named corpus suites: the matrices the autotuner and benches sweep.

A :class:`MatrixSpec` is a lazily-built, seed-deterministic matrix with a
stable name — the unit the TuneDB records, ``python -m repro.tune``
iterates, and ``benchmarks/bench_corpus.py`` reports per-row.  Suites:

* ``mini`` — 3 matrices (one per major regime), the CI smoke corpus,
* ``paper`` — ~18 matrices spanning the paper's Fig. 6 spectrum: power-law
  graphs, banded stencils, block-sparse pruned weights, and the uniform
  regular/irregular sweep, across the merge/rowsplit crossover,
* ``pruned`` — block/unstructured pruning masks at serving shapes.

``specs_from_mtx_dir`` turns a directory of ``.mtx`` files (e.g. a local
SuiteSparse slice) into specs, so real-world corpora plug into the same
autotune/bench pipeline as the synthetic families.
"""
from __future__ import annotations

import dataclasses
import os
from collections.abc import Callable

from repro.core.csr import CSR

from . import generators as G
from .mmio import read_mtx


@dataclasses.dataclass(frozen=True)
class MatrixSpec:
    name: str
    build: Callable[[], CSR]     # deterministic: same spec → same pattern
    family: str = "synthetic"

    def __call__(self) -> CSR:
        return self.build()


_SPECS: dict[str, MatrixSpec] = {}
_SUITES: dict[str, tuple[str, ...]] = {}


def register_spec(spec: MatrixSpec) -> MatrixSpec:
    if spec.name in _SPECS:
        raise ValueError(f"duplicate matrix spec name: {spec.name!r}")
    _SPECS[spec.name] = spec
    return spec


def register_suite(name: str, spec_names: tuple[str, ...]) -> None:
    missing = [s for s in spec_names if s not in _SPECS]
    if missing:
        raise ValueError(f"suite {name!r} references unknown specs "
                         f"{missing}")
    _SUITES[name] = tuple(spec_names)


def suite_names() -> list[str]:
    return sorted(_SUITES)


def get_suite(name: str) -> list[MatrixSpec]:
    if name not in _SUITES:
        raise KeyError(f"unknown suite {name!r}; available: "
                       f"{suite_names()}")
    return [_SPECS[s] for s in _SUITES[name]]


def specs_from_mtx_dir(path: str | os.PathLike) -> list[MatrixSpec]:
    """One spec per ``.mtx`` file in ``path`` (sorted, non-recursive)."""
    specs = []
    for fname in sorted(os.listdir(path)):
        if not fname.endswith(".mtx"):
            continue
        full = os.path.join(path, fname)
        specs.append(MatrixSpec(name=os.path.splitext(fname)[0],
                                build=lambda p=full: read_mtx(p),
                                family="mtx"))
    return specs


# ----------------------------------------------------- built-in corpus ---
#
# Shapes are sized for CPU-container timing budgets (the backend the DB is
# keyed to); the *relative* merge/rowsplit crossover is what matters, and
# every family crosses it: d sweeps from ~2 (deep merge territory) past
# the paper's 9.35 into rowsplit territory (d ≥ 16).

def _spec(name: str, family: str, fn: Callable[[], CSR]) -> None:
    register_spec(MatrixSpec(name=name, build=fn, family=family))


_spec("mini_powlaw", "graph", lambda: G.power_law(11, 512, 512, 4.0))
_spec("mini_banded", "stencil", lambda: G.banded(12, 768, 768, 3))
_spec("mini_uniform", "uniform", lambda: G.uniform(13, 256, 1024, 24))

_spec("graph_powlaw_sparse", "graph",
      lambda: G.power_law(21, 2048, 2048, 3.0))
_spec("graph_powlaw_mid", "graph",
      lambda: G.power_law(22, 2048, 2048, 8.0))
_spec("graph_powlaw_dense", "graph",
      lambda: G.power_law(23, 1024, 2048, 24.0))
_spec("graph_powlaw_heavy_tail", "graph",
      lambda: G.power_law(24, 2048, 2048, 6.0, alpha=1.2))

_spec("stencil_tri", "stencil", lambda: G.banded(31, 4096, 4096, 1))
_spec("stencil_band9", "stencil", lambda: G.banded(32, 2048, 2048, 4))
_spec("stencil_band33", "stencil", lambda: G.banded(33, 1024, 1024, 16))
_spec("stencil_band_loose", "stencil",
      lambda: G.banded(34, 2048, 2048, 12, fill=0.5))

_spec("pruned_block8_10pct", "pruned",
      lambda: G.block_sparse(41, 1024, 1024, block=8, keep=0.10))
_spec("pruned_block16_25pct", "pruned",
      lambda: G.block_sparse(42, 1024, 1024, block=16, keep=0.25))
_spec("pruned_block4_50pct", "pruned",
      lambda: G.block_sparse(43, 512, 2048, block=4, keep=0.50))

_spec("uniform_d2", "uniform", lambda: G.uniform(51, 2048, 4096, 2))
_spec("uniform_d8", "uniform", lambda: G.uniform(52, 2048, 4096, 8))
_spec("uniform_d32", "uniform", lambda: G.uniform(53, 1024, 4096, 32))
_spec("uniform_irr_d4", "uniform",
      lambda: G.uniform_irregular(54, 2048, 4096, 4))
_spec("uniform_irr_d16", "uniform",
      lambda: G.uniform_irregular(55, 1024, 4096, 16))
_spec("tall_skinny_d6", "uniform",
      lambda: G.uniform_irregular(56, 8192, 1024, 6))
_spec("short_wide_d48", "uniform",
      lambda: G.uniform(57, 256, 8192, 48))

register_suite("mini", ("mini_powlaw", "mini_banded", "mini_uniform"))
register_suite("paper", (
    "graph_powlaw_sparse", "graph_powlaw_mid", "graph_powlaw_dense",
    "graph_powlaw_heavy_tail",
    "stencil_tri", "stencil_band9", "stencil_band33", "stencil_band_loose",
    "pruned_block8_10pct", "pruned_block16_25pct", "pruned_block4_50pct",
    "uniform_d2", "uniform_d8", "uniform_d32",
    "uniform_irr_d4", "uniform_irr_d16",
    "tall_skinny_d6", "short_wide_d48",
))
register_suite("pruned", (
    "pruned_block8_10pct", "pruned_block16_25pct", "pruned_block4_50pct",
))
