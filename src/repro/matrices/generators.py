"""Deterministic synthetic matrix families spanning the paper's regimes.

Each generator is a pure function of an integer ``seed`` (numpy
``default_rng`` — no JAX key threading), so corpus suites are reproducible
across processes and backends: the autotuner can fingerprint a generated
pattern today and hit the same fingerprint in next week's serving job.

Families and the regime they cover (Fig. 1 / §5 of the paper):

* :func:`uniform` / :func:`uniform_irregular` — regular rows / mild Type-2
  imbalance, the ``random_csr`` regime the seed repo already measured,
* :func:`power_law` — heavy-tailed row lengths (web/social graphs), the
  Type-1 imbalance that breaks row-per-thread kernels,
* :func:`banded` — FEM/stencil diagonals: near-constant short rows, the
  regime where row-split's ELL padding is free,
* :func:`block_sparse` — structured blocks surviving magnitude pruning of
  a weight matrix, the paper's §1 serving use case.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.csr import CSR


def _csr_from_lengths(rng: np.random.Generator, lengths: np.ndarray,
                      m: int, k: int, dtype) -> CSR:
    """Rows with given lengths; sorted unique uniform columns per row."""
    lengths = np.minimum(np.maximum(lengths, 0), k).astype(np.int64)
    row_ptr = np.zeros(m + 1, np.int32)
    np.cumsum(lengths, out=row_ptr[1:])
    nnz = int(row_ptr[-1])
    nnz_pad = max(nnz, 1)
    col_ind = np.zeros(nnz_pad, np.int32)
    for r in range(m):
        s, e = row_ptr[r], row_ptr[r + 1]
        if e > s:
            col_ind[s:e] = np.sort(rng.choice(k, size=e - s, replace=False))
    vals = np.zeros(nnz_pad, np.float64)
    vals[:nnz] = rng.standard_normal(nnz)
    return CSR(jnp.asarray(row_ptr), jnp.asarray(col_ind),
               jnp.asarray(vals, dtype=dtype), (m, k))


def uniform(seed: int, m: int, k: int, d: int, *,
            dtype=jnp.float32) -> CSR:
    """Every row has exactly ``d`` nonzeroes (regular, zero imbalance)."""
    rng = np.random.default_rng(seed)
    return _csr_from_lengths(rng, np.full(m, d), m, k, dtype)


def uniform_irregular(seed: int, m: int, k: int, d: int, *,
                      dtype=jnp.float32) -> CSR:
    """Row lengths uniform in [0, 2d] (mean ``d``) — mild imbalance."""
    rng = np.random.default_rng(seed)
    return _csr_from_lengths(rng, rng.integers(0, 2 * d + 1, size=m),
                             m, k, dtype)


def power_law(seed: int, m: int, k: int, d: float, *, alpha: float = 1.6,
              dtype=jnp.float32) -> CSR:
    """Heavy-tailed (Pareto) row lengths rescaled to mean ``d``.

    ``alpha`` is the Pareto tail index: smaller → heavier tail → a few
    huge rows dominate (web-graph-like; high Gini).  Lengths are clipped
    to ``k`` after rescaling, so the realized mean can sit slightly below
    the target for extreme tails.
    """
    rng = np.random.default_rng(seed)
    raw = rng.pareto(alpha, size=m) + 1.0
    lengths = np.floor(raw * (d / raw.mean())).astype(np.int64)
    return _csr_from_lengths(rng, lengths, m, k, dtype)


def banded(seed: int, m: int, k: int, band: int, *,
           fill: float = 1.0, dtype=jnp.float32) -> CSR:
    """Stencil-style band of half-width ``band`` around the scaled diagonal.

    ``fill < 1`` keeps each in-band entry with that probability (a
    partially assembled FEM operator); ``fill = 1`` is the dense band.
    Rows are near-constant length — the paper's low-variance regime.
    """
    rng = np.random.default_rng(seed)
    row_ptr = np.zeros(m + 1, np.int32)
    cols_per_row = []
    for r in range(m):
        center = int(round(r * (k - 1) / max(m - 1, 1)))
        lo, hi = max(center - band, 0), min(center + band + 1, k)
        cols = np.arange(lo, hi, dtype=np.int32)
        if fill < 1.0:
            cols = cols[rng.random(cols.size) < fill]
        cols_per_row.append(cols)
        row_ptr[r + 1] = row_ptr[r] + cols.size
    nnz = int(row_ptr[-1])
    nnz_pad = max(nnz, 1)
    col_ind = np.zeros(nnz_pad, np.int32)
    if nnz:
        col_ind[:nnz] = np.concatenate(cols_per_row)
    vals = np.zeros(nnz_pad, np.float64)
    vals[:nnz] = rng.standard_normal(nnz)
    return CSR(jnp.asarray(row_ptr), jnp.asarray(col_ind),
               jnp.asarray(vals, dtype=dtype), (m, k))


def block_sparse(seed: int, m: int, k: int, *, block: int = 8,
                 keep: float = 0.25, dtype=jnp.float32) -> CSR:
    """Block-structured pruning mask: keep whole ``block×block`` tiles.

    Models a magnitude-pruned weight with structured sparsity: a uniform
    ``keep`` fraction of tiles survives; rows inside a surviving tile are
    dense across it.  ``m`` and ``k`` need not divide ``block`` — edge
    tiles are clipped.
    """
    rng = np.random.default_rng(seed)
    mb = (m + block - 1) // block
    kb = (k + block - 1) // block
    mask = rng.random((mb, kb)) < keep
    row_ptr = np.zeros(m + 1, np.int32)
    cols_per_row = []
    for r in range(m):
        tiles = np.nonzero(mask[r // block])[0]
        cols = np.concatenate(
            [np.arange(t * block, min((t + 1) * block, k), dtype=np.int32)
             for t in tiles]) if tiles.size else np.empty(0, np.int32)
        cols_per_row.append(cols)
        row_ptr[r + 1] = row_ptr[r] + cols.size
    nnz = int(row_ptr[-1])
    nnz_pad = max(nnz, 1)
    col_ind = np.zeros(nnz_pad, np.int32)
    if nnz:
        col_ind[:nnz] = np.concatenate(cols_per_row)
    vals = np.zeros(nnz_pad, np.float64)
    vals[:nnz] = rng.standard_normal(nnz)
    return CSR(jnp.asarray(row_ptr), jnp.asarray(col_ind),
               jnp.asarray(vals, dtype=dtype), (m, k))
