"""Matrix corpus subsystem: real + synthetic sparsity patterns.

The paper's headline numbers (31.7% geomean speedup, 99.3%-accurate kernel
selection) are claims about *real-world matrices* — SuiteSparse graphs,
FEM stencils, pruned weights — not about the near-uniform `random_csr`
patterns the seed repo could generate.  This package supplies the inputs
that make those claims measurable on this backend:

* ``mmio`` — MatrixMarket ``.mtx`` reader/writer (coordinate
  real/integer/pattern, general/symmetric/skew-symmetric with expansion)
  producing/consuming :class:`repro.core.CSR`,
* ``generators`` — deterministic synthetic families spanning the paper's
  regimes: power-law (graph), banded (stencil), block-sparse (pruned
  weight), uniform (regular / irregular),
* ``stats`` — per-matrix row-length statistics: mean ``d`` (the §5.4
  heuristic axis), coefficient of variation, Gini imbalance (the Fig. 1
  axis), max row length,
* ``suites`` — a named-suite registry (``mini``, ``paper``, ``pruned``)
  the autotuner (``repro.tune``) and ``benchmarks/bench_corpus.py``
  iterate over, plus ``specs_from_mtx_dir`` for on-disk corpora.
"""
from .generators import (banded, block_sparse, power_law, uniform,
                         uniform_irregular)
from .mmio import read_mtx, write_mtx
from .stats import MatrixStats, compute_stats
from .suites import (MatrixSpec, get_suite, register_spec, register_suite,
                     specs_from_mtx_dir, suite_names)

__all__ = [
    "banded", "block_sparse", "power_law", "uniform", "uniform_irregular",
    "read_mtx", "write_mtx",
    "MatrixStats", "compute_stats",
    "MatrixSpec", "get_suite", "register_spec", "register_suite",
    "specs_from_mtx_dir", "suite_names",
]
