"""Checkpointing: atomic, content-hashed, retention-managed, resumable.

Layout (one directory per step)::

    <dir>/step_000042/
        index.msgpack.zst    # pytree structure + shapes/dtypes + hashes
        arr_00000.npy ...    # one file per leaf (process-local shards on
                             # multi-host: each process writes its own
                             # addressable shards, suffix _pNN)
    <dir>/LATEST             # atomically-updated pointer

Fault model (1000+ nodes): any writer can die mid-checkpoint — we write to
``step_X.tmp`` then ``rename()`` (atomic on POSIX), and ``restore_latest``
verifies the content hash of every array, falling back to older steps on
corruption.  SIGTERM-triggered save is wired in distributed/fault.py.
"""
from __future__ import annotations

import hashlib
import os
import shutil

import jax
import msgpack
import numpy as np

try:
    import zstandard
except ImportError:           # container without zstd: fall back to stdlib
    import zlib

    class _ZlibCodec:
        """Drop-in for the two zstandard module functions we use.

        Checkpoints written with one codec are unreadable by the other —
        acceptable: the fallback only exists for environments that never
        had zstandard to begin with.
        """
        compress = staticmethod(zlib.compress)
        decompress = staticmethod(zlib.decompress)

    zstandard = _ZlibCodec()


def _leaf_hash(arr: np.ndarray) -> str:
    return hashlib.blake2b(arr.tobytes(), digest_size=16).hexdigest()


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, process_index: int = 0):
        self.dir = directory
        self.keep = keep
        self.process_index = process_index
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save ----
    def save(self, step: int, tree, extra: dict | None = None) -> str:
        leaves, treedef = jax.tree.flatten(tree)
        name = f"step_{step:08d}"
        final = os.path.join(self.dir, name)
        tmp = final + f".tmp{self.process_index}"
        os.makedirs(tmp, exist_ok=True)
        index = {"treedef": str(treedef), "n": len(leaves), "step": step,
                 "extra": extra or {}, "leaves": []}
        for i, leaf in enumerate(leaves):
            arr = np.asarray(jax.device_get(leaf))
            fn = f"arr_{i:05d}_p{self.process_index:02d}.npy"
            np.save(os.path.join(tmp, fn), arr)
            index["leaves"].append({
                "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype),
                "hash": _leaf_hash(arr)})
        blob = zstandard.compress(msgpack.packb(index))
        with open(os.path.join(tmp, "index.msgpack.zst"), "wb") as f:
            f.write(blob)
        os.replace(tmp, final)  # atomic publish
        self._write_latest(name)
        self._retain()
        return final

    def _write_latest(self, name: str):
        tmp = os.path.join(self.dir, f".LATEST.tmp{self.process_index}")
        with open(tmp, "w") as f:
            f.write(name)
        os.replace(tmp, os.path.join(self.dir, "LATEST"))

    def _retain(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore ----
    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(
                    tuple(f".tmp{i}" for i in range(100))):
                try:
                    out.append(int(d[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def _load(self, step: int, like):
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "index.msgpack.zst"), "rb") as f:
            index = msgpack.unpackb(zstandard.decompress(f.read()))
        leaves = []
        for meta in index["leaves"]:
            arr = np.load(os.path.join(path, meta["file"]))
            if _leaf_hash(arr) != meta["hash"]:
                raise IOError(f"corrupt leaf {meta['file']} at step {step}")
            leaves.append(arr)
        _, treedef = jax.tree.flatten(like)
        tree = jax.tree.unflatten(treedef, leaves)
        return tree, index["step"], index["extra"]

    def restore(self, step: int, like):
        return self._load(step, like)

    def restore_latest(self, like):
        """Newest → oldest with corruption fallback.  Returns
        (tree, step, extra) or (None, -1, {})."""
        for step in reversed(self.all_steps()):
            try:
                return self._load(step, like)
            except (IOError, OSError, ValueError) as e:
                print(f"[checkpoint] step {step} unreadable ({e}); "
                      f"falling back")
        return None, -1, {}
