"""repro: merge-spmm (Yang, Buluç, Owens, Euro-Par 2018) on TPU in JAX."""
__version__ = "1.0.0"
