"""repro: merge-spmm (Yang, Buluç, Owens, Euro-Par 2018) on TPU in JAX.

The v1 public surface — everything a user needs for plan-once/execute-many
sparse matmul — is re-exported here:

    import repro

    A = repro.SparseMatrix.from_dense(w)        # CSR + lazily attached plan
    C = A @ B                                   # engine-cached planning
    C = repro.spmm(a_csr, B,
                   repro.PlanPolicy(method="merge"),
                   repro.ExecutionConfig(impl="xla"))
    plan = repro.get_plan(a_csr)                # explicit plan handle
    C = repro.execute_plan(plan, a_csr.vals, B)

``tests/test_api.py`` snapshots this surface: a public name appearing or
disappearing unannounced fails CI.
"""
from repro.core import (CSR, Epilogue, ExecutionConfig, PlanPolicy,
                        ShardSpec, SparseMatrix, SpmmPlan, execute_plan,
                        spmm)
from repro.engine import get_plan

__version__ = "1.0.0"

__all__ = [
    "CSR",
    "Epilogue",
    "ExecutionConfig",
    "PlanPolicy",
    "ShardSpec",
    "SparseMatrix",
    "SpmmPlan",
    "__version__",
    "execute_plan",
    "get_plan",
    "spmm",
]
