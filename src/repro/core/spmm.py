"""Public SpMM API (v1): registry-dispatched, plan-once/execute-many,
batched, and differentiable.

    C = spmm(A, B)                            # auto: TuneDB ladder → §5.4
    C = spmm(A, B, PlanPolicy(method="merge"))         # force a method
    C = spmm(A, B, exec=ExecutionConfig(impl="xla"))   # pick the backend

    plan = repro.engine.get_plan(A)           # once per sparsity pattern
    C = spmm(A, B, plan=plan)                 # jit-safe, never replans
    C = execute_plan(plan, A.vals, B)         # the explicit-plan core
    C = execute_plan(plan, A.vals, Bs)        # Bs (batch, k, n): one plan,
                                              # many problems, one dispatch

The two halves of the old kwarg sprawl are split by lifetime:
``PlanPolicy`` (method/t/l_pad/heuristic/tunedb — decided once per
pattern, host-side, hashed into the engine cache key) and
``ExecutionConfig`` (impl/interpret/tk — per call, trace-safe).  The
pre-v1 kwargs survive as deprecation shims for one release.  Method
dispatch — including the inline plan-per-call path — goes through the
method registry (``repro.kernels.registry``), and ``method="auto"``
resolves through one ``PlanPolicy.resolve`` for both the planned and the
inline path, so the two can never pick different kernels for the same
matrix.

With a concrete (non-traced) CSR, ``spmm`` routes through the engine's
plan cache automatically.  Either way execution is differentiable via
``jax.custom_vjp``: ``dB = Aᵀ @ dC`` runs through the plan's cached
transpose (CSC-view) merge plan — equal-nonzero balanced, like the forward
— and ``dvals`` is a sampled-dense-dense (gather-dot) kernel over the
pattern (``repro.kernels.sddmm``).

Batching is first-class in two equivalent forms: pass ``B`` with leading
batch dims (``(..., k, n)``, folded into the kernels' batch grid axis) or
``jax.vmap`` the 2-D call — the custom-VJP's forward/backward bodies call
``custom_vmap``-wrapped ops (``registry.execute_op``), whose explicit vmap
rule rewrites a vmapped axis onto that same native batch path.  Values are
shared across the batch (one frozen pattern, one value vector, many dense
operands — the serving regime), so the batched VJP reduces the
values-cotangent over the batch dims.

Device sharding rides the same dispatch: a ``PlanPolicy`` with ``shards=``
set resolves to a ``repro.distributed.spmm.ShardedSpmmPlan`` — nnz-balanced
row (or column) shards, one local plan per shard — and both ``spmm`` and
``A @ B`` execute it transparently.
"""
from __future__ import annotations

import functools

import jax
import numpy as np

from .config import (ExecutionConfig, PlanPolicy, _UNSET, coalesce_exec,
                     coalesce_policy)
from .csr import CSR
from .plan import SpmmPlan, PlanMeta


def _ops():
    # deferred: repro.kernels imports repro.core.csr at module scope, so an
    # eager import here would be circular
    from repro.kernels import ops
    return ops


def _registry():
    from repro.kernels import registry
    return registry


def _is_traced(a: CSR) -> bool:
    return isinstance(a.row_ptr, jax.core.Tracer) or \
        isinstance(a.col_ind, jax.core.Tracer)


# --------------------------------------------------- plan execution core ---


def _forward(meta: PlanMeta, fwd: dict, vals, b, interpret, impl, tk, *,
             vmappable: bool):
    registry = _registry()
    if vmappable:
        return registry.execute_op(meta, tk, interpret, impl)(fwd, vals, b)
    return registry.get_method(meta.method).execute(
        meta, fwd, vals, b, tk=tk, interpret=interpret, impl=impl)


def _int_zeros(tree):
    # Cotangents for the integer plan arrays: symbolic float0 zeros.
    return jax.tree.map(
        lambda x: np.zeros(x.shape, jax.dtypes.float0), tree)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _execute_vjp(meta, interpret, impl, tk, fwd, bwd, vals, b):
    # The fwd/bwd bodies call the custom_vmap-wrapped ops: JAX vmaps these
    # bodies (it never differentiates them), so a vmapped batch axis lands
    # on the kernels' native batch grid instead of tracing into pallas_call.
    return _forward(meta, fwd, vals, b, interpret, impl, tk, vmappable=True)


def _execute_vjp_fwd(meta, interpret, impl, tk, fwd, bwd, vals, b):
    out = _forward(meta, fwd, vals, b, interpret, impl, tk, vmappable=True)
    return out, (fwd, bwd, vals, b)


def _execute_vjp_bwd(meta, interpret, impl, tk, res, dc):
    fwd, bwd, vals, b = res
    ops = _ops()
    # dB = Aᵀ @ dC through the transpose merge plan: the CSC view gets the
    # same equal-nonzero balancing as the forward pass (batched like it).
    db = ops.merge_execute_op(meta.k, tk, interpret, impl)(
        bwd, vals, dc).astype(b.dtype)
    # dvals = (dC · Bᵀ) sampled at the pattern (gather-dot SDDMM), reduced
    # over any explicit batch dims — the values are shared across the batch.
    # (Under vmap the axis is implicit and JAX itself sums the cotangent
    # for the unbatched values primal.)
    dvals = ops.sddmm_op(interpret, impl)(
        fwd["nz_rows"], fwd["nz_cols"], fwd["nz_valid"], dc, b)
    if dvals.ndim > 1:
        dvals = dvals.sum(axis=tuple(range(dvals.ndim - 1)))
    return (_int_zeros(fwd), _int_zeros(bwd), dvals.astype(vals.dtype), db)


_execute_vjp.defvjp(_execute_vjp_fwd, _execute_vjp_bwd)


def execute_plan(plan: SpmmPlan, vals: jax.Array, b: jax.Array,
                 exec: ExecutionConfig | None = None, *,
                 interpret=_UNSET, impl=_UNSET, tk=_UNSET) -> jax.Array:
    """Execute a prebuilt plan: C = A @ B with A's values given per call.

    Trace-safe (every static decision was captured at plan build) and
    differentiable in ``vals`` and ``b`` when the plan carries its
    transpose (``build_plan(..., with_transpose=True)``, the default).

    ``exec`` is the per-call :class:`ExecutionConfig` (implementation,
    interpret mode, K-tile cap); the bare ``interpret``/``impl``/``tk``
    kwargs are pre-v1 shims that warn once.  ``b`` may carry leading batch
    dims — ``(..., k, n) → (..., m, n)`` runs the whole stack through one
    kernel dispatch with shared values, and ``jax.vmap`` over the 2-D form
    lowers to the same batched path.
    """
    exec = coalesce_exec("execute_plan", exec, impl=impl,
                         interpret=interpret, tk=tk)
    # Static shape guards: gathers clamp out-of-bounds indices silently, so
    # a stale plan would otherwise produce garbage instead of an error.
    if vals.shape != (plan.meta.nnz_pad,):
        raise ValueError(
            f"plan expects vals of shape ({plan.meta.nnz_pad},) for pattern "
            f"{plan.meta.shape}, got {vals.shape} — was the plan built for "
            "a different sparsity pattern?")
    if b.ndim < 2 or b.shape[-2] != plan.meta.k:
        raise ValueError(
            f"plan expects B of shape (..., {plan.meta.k}, n) for pattern "
            f"{plan.meta.shape}, got {b.shape}")
    if plan.bwd is None:
        # Forward-only plan: plain ops (keeps ordinary XLA autodiff for
        # impl="xla" callers; build with a transpose for vmap support).
        return _forward(plan.meta, plan.fwd, vals, b, exec.interpret,
                        exec.impl, exec.tk, vmappable=False)
    return _execute_vjp(plan.meta, exec.interpret, exec.impl, exec.tk,
                        plan.fwd, plan.bwd, vals, b)


# ------------------------------------------------------------ public API ---


def _check_plan_overrides(plan: SpmmPlan, policy: PlanPolicy) -> None:
    """Raise on an explicit policy that contradicts the supplied plan.

    A plan's method/t/l_pad were fixed at build time; silently ignoring a
    conflicting override would execute something other than what the call
    asked for (ISSUE 3: the silent-wrong-answer paths).
    """
    meta = plan.meta
    conflicts = []
    if policy.method != "auto" and policy.method != meta.method:
        conflicts.append(f"method={policy.method!r} (plan: {meta.method!r})")
    if policy.t is not None and policy.t != meta.t:
        conflicts.append(f"t={policy.t} (plan: {meta.t})")
    if policy.tl is not None and policy.tl != meta.tl:
        conflicts.append(f"tl={policy.tl} (plan: {meta.tl})")
    if policy.l_pad is not None and policy.l_pad != meta.l_pad:
        conflicts.append(f"l_pad={policy.l_pad} (plan: {meta.l_pad})")
    if policy.shards is not None:
        conflicts.append(f"shards={policy.shards} (plan: unsharded — build "
                         "a sharded plan via engine.get_plan or "
                         "SparseMatrix.shard)")
    if conflicts:
        raise ValueError(
            "spmm() overrides conflict with the supplied plan's static "
            "decisions: " + "; ".join(conflicts) + ". Rebuild the plan with "
            "these parameters (repro.core.build_plan / "
            "repro.engine.get_plan) or drop the overrides.")


def _check_sharded_overrides(plan, policy: PlanPolicy) -> None:
    """Raise on an explicit policy contradicting a sharded plan's statics."""
    meta = plan.meta
    conflicts = []
    if policy.shards is not None:
        spec = policy.shards
        if spec.resolved_n() != meta.n_shards:
            conflicts.append(f"shards n={spec.resolved_n()} "
                             f"(plan: {meta.n_shards})")
        if spec.dim != meta.dim:
            conflicts.append(f"shards dim={spec.dim!r} (plan: {meta.dim!r})")
    if policy.method != "auto":
        mismatched = sorted({lm.method for lm in meta.local_metas
                             if lm.method != policy.method})
        if mismatched:
            conflicts.append(f"method={policy.method!r} (plan shards use "
                             f"{mismatched})")
    for name in ("t", "tl", "l_pad"):
        want = getattr(policy, name)
        if want is None:
            continue
        got = sorted({getattr(lm, name) for lm in meta.local_metas},
                     key=lambda x: (x is None, x))
        if got != [want]:
            conflicts.append(f"{name}={want} (plan shards: {got})")
    if conflicts:
        raise ValueError(
            "spmm() overrides conflict with the supplied sharded plan's "
            "static decisions: " + "; ".join(conflicts) + ". Rebuild the "
            "sharded plan with these parameters (engine.get_plan with a "
            "shards= policy) or drop the overrides.")


def spmm(a: CSR, b: jax.Array, policy: PlanPolicy | None = None,
         exec: ExecutionConfig | None = None, *,
         plan: SpmmPlan | str | None = None,
         method=_UNSET, l_pad=_UNSET, t=_UNSET, heuristic=_UNSET,
         interpret=_UNSET, impl=_UNSET, tk=_UNSET) -> jax.Array:
    """Sparse(CSR) × dense = dense.  ``b`` is (..., k, n); returns (..., m, n).

    ``policy`` (a :class:`PlanPolicy`) holds every pattern-static decision
    — method, static kernel parameters, heuristic/TuneDB — and ``exec``
    (an :class:`ExecutionConfig`) the per-call backend knobs.  The bare
    ``method``/``l_pad``/``t``/``heuristic``/``interpret``/``impl``/``tk``
    kwargs are pre-v1 shims: they still work (warning once per process)
    but raise when combined with ``policy``/``exec``.

    Dispatch on ``plan``:

    * an ``SpmmPlan`` — execute it (jit-safe; ``a`` supplies only values).
      An explicit ``policy`` must agree with the plan's statics —
      conflicts raise instead of being silently ignored.
    * ``None`` (default) with concrete ``a`` — look up / build the
      pattern's plan in the engine cache, then execute.  Repeated calls
      with the same pattern (any values) never replan.
    * ``None`` with traced ``a``, or the string ``"inline"`` — plan inside
      the traced computation, every call (the paper's original per-call
      regime; benchmarks time it deliberately).  With a concrete ``a``
      the method and its parameters resolve through the same
      ``PlanPolicy.resolve`` as the planned path (TuneDB ladder included);
      under trace an explicit method is required — resolution is a
      host-side decision.
    """
    policy = coalesce_policy("spmm", policy, method=method, t=t,
                             l_pad=l_pad, heuristic=heuristic)
    exec = coalesce_exec("spmm", exec, impl=impl, interpret=interpret,
                         tk=tk)
    if isinstance(plan, SpmmPlan):
        _check_plan_overrides(plan, policy)
        return execute_plan(plan, a.vals, b, exec)
    if plan is not None and not isinstance(plan, str):
        from repro.distributed.spmm import ShardedSpmmPlan
        if isinstance(plan, ShardedSpmmPlan):
            _check_sharded_overrides(plan, policy)
            return plan.execute(a.vals, b, exec)
    if plan is None and not _is_traced(a):
        from repro.engine import get_plan
        built = get_plan(a, policy=policy)
        if isinstance(built, SpmmPlan):
            return execute_plan(built, a.vals, b, exec)
        return built.execute(a.vals, b, exec)
    if plan not in (None, "inline"):
        raise ValueError(f"plan must be an SpmmPlan, a ShardedSpmmPlan, "
                         f"None, or 'inline'; got {plan!r}")
    if policy.shards is not None:
        raise ValueError(
            "the inline (plan-per-call) spmm path cannot shard: sharding "
            "is a host-side plan decision. Build the sharded plan outside "
            "jit (repro.engine.get_plan with a shards= policy, or "
            "SparseMatrix.shard) and pass it through the jitted function.")
    if b.ndim != 2:
        raise ValueError(
            "the inline (plan-per-call) spmm path takes a 2-D B; batched "
            f"B {b.shape} needs a prebuilt plan — repro.engine.get_plan(a) "
            "— whose execution folds the batch into the kernel grid.")
    registry = _registry()
    m_name, t_val, tl_val, l_val = (policy.method, policy.t, policy.tl,
                                    policy.l_pad)
    extra = None
    if not _is_traced(a):
        # One resolution for both regimes: the inline path consults the
        # same TuneDB ladder / heuristic / parameter validation as the
        # planned path, so the two can never pick different kernels for
        # the same matrix.
        r = policy.resolve(a)
        m_name, t_val, tl_val, l_val = r.method, r.t, r.tl, r.l_pad
        extra = r.extra
    elif m_name == "auto":
        raise ValueError(
            "spmm(method='auto') on a traced CSR would need a host-side "
            "heuristic decision per call. Build a plan outside jit "
            "(repro.engine.get_plan) — the kernel choice is captured "
            "statically at plan-build time — or pass an explicit method.")
    spec = registry.get_method(m_name)
    if spec.inline is None:
        raise ValueError(
            f"SpMM method {m_name!r} has no inline (plan-per-call) form; "
            "build a plan instead: repro.engine.get_plan(a, policy=...)")
    return spec.inline(a, b, t=t_val, tl=tl_val, l_pad=l_val, extra=extra,
                       tk=exec.tk, interpret=exec.interpret, impl=exec.impl)
