"""Public SpMM API (v1): registry-dispatched, plan-once/execute-many,
batched, and differentiable.

    C = spmm(A, B)                            # auto: TuneDB ladder → §5.4
    C = spmm(A, B, PlanPolicy(method="merge"))         # force a method
    C = spmm(A, B, exec=ExecutionConfig(impl="xla"))   # pick the backend

    plan = repro.engine.get_plan(A)           # once per sparsity pattern
    C = spmm(A, B, plan=plan)                 # jit-safe, never replans
    C = execute_plan(plan, A.vals, B)         # the explicit-plan core
    C = execute_plan(plan, A.vals, Bs)        # Bs (batch, k, n): one plan,
                                              # many problems, one dispatch

The two halves of the old kwarg sprawl are split by lifetime:
``PlanPolicy`` (method/t/l_pad/heuristic/tunedb — decided once per
pattern, host-side, hashed into the engine cache key) and
``ExecutionConfig`` (impl/interpret/tk — per call, trace-safe).  The
pre-v1 kwargs survive as deprecation shims for one release.  Method
dispatch — including the inline plan-per-call path — goes through the
method registry (``repro.kernels.registry``), and ``method="auto"``
resolves through one ``PlanPolicy.resolve`` for both the planned and the
inline path, so the two can never pick different kernels for the same
matrix.

With a concrete (non-traced) CSR, ``spmm`` routes through the engine's
plan cache automatically.  Either way execution is differentiable via
``jax.custom_vjp``: ``dB = Aᵀ @ dC`` runs through the plan's cached
transpose (CSC-view) merge plan — equal-nonzero balanced, like the forward
— and ``dvals`` is a sampled-dense-dense (gather-dot) kernel over the
pattern (``repro.kernels.sddmm``).

Batching is first-class in two equivalent forms: pass ``B`` with leading
batch dims (``(..., k, n)``, folded into the kernels' batch grid axis) or
``jax.vmap`` the 2-D call — the custom-VJP's forward/backward bodies call
``custom_vmap``-wrapped ops (``registry.execute_op``), whose explicit vmap
rule rewrites a vmapped axis onto that same native batch path.  Values are
shared across the batch (one frozen pattern, one value vector, many dense
operands — the serving regime), so the batched VJP reduces the
values-cotangent over the batch dims.

Device sharding rides the same dispatch: a ``PlanPolicy`` with ``shards=``
set resolves to a ``repro.distributed.spmm.ShardedSpmmPlan`` — nnz-balanced
row (or column) shards, one local plan per shard — and both ``spmm`` and
``A @ B`` execute it transparently.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs as _obs
from repro.obs import trace as _trace

from .config import (ExecutionConfig, PlanPolicy, _UNSET, coalesce_exec,
                     coalesce_policy)
from .csr import CSR
from .epilogue import Epilogue, activation_fn, apply_epilogue
from .plan import SpmmPlan, PlanMeta

# Per-plan execute counts.  Gated on the tracing flag at the call site:
# execute_plan is the engine's hottest eager entry point and the
# observability contract is zero-cost-when-disabled.
_plan_execute = _obs.registry.counter(
    "plan_execute_total", "execute_plan dispatches by plan and impl",
    labels=("plan", "impl"))


def _plan_label(meta: PlanMeta) -> str:
    m, k = meta.shape
    return f"{meta.method}:{m}x{k}:nnz{meta.nnz_pad}"


def _record_dispatch(meta: PlanMeta, b, exec: ExecutionConfig) -> None:
    # Callers gate on _trace._enabled.
    _plan_execute.labels(plan=_plan_label(meta), impl=exec.impl).inc()
    ep = exec.epilogue
    _trace.event(
        "dispatch", cat="dispatch", method=meta.method, impl=exec.impl,
        m=int(meta.shape[0]), k=int(meta.shape[1]),
        nnz_pad=int(meta.nnz_pad), n=int(b.shape[-1]),
        batch=list(b.shape[:-2]), tk=exec.tk, acc_dtype=exec.acc_dtype,
        out_dtype=exec.out_dtype,
        epilogue=(dict(bias=ep.bias, residual=ep.residual,
                       activation=ep.activation,
                       scale=ep.scale is not None)
                  if ep is not None else None))


def _ops():
    # deferred: repro.kernels imports repro.core.csr at module scope, so an
    # eager import here would be circular
    from repro.kernels import ops
    return ops


def _registry():
    from repro.kernels import registry
    return registry


def _is_traced(a: CSR) -> bool:
    return isinstance(a.row_ptr, jax.core.Tracer) or \
        isinstance(a.col_ind, jax.core.Tracer)


# --------------------------------------------------- plan execution core ---


def _resolve_exec(where: str, m: int, vals, b, exec: ExecutionConfig,
                  bias, residual) -> ExecutionConfig:
    """Normalize the per-call config against the actual operands.

    Resolves the epilogue (auto-derived when ``bias``/``residual`` are
    passed without one; flag/operand mismatches raise), canonicalizes
    ``acc_dtype``/``out_dtype`` against the operand dtypes, and rejects
    non-floating or precision-losing combinations up front — the kernels'
    gathers and accumulators would otherwise return silently-wrong C.
    """
    for name, x in (("vals", vals), ("b", b)):
        if not jnp.issubdtype(x.dtype, jnp.floating):
            raise TypeError(
                f"{where}() requires floating-point operands; {name} has "
                f"dtype {x.dtype}. Cast explicitly — integer/bool "
                "accumulation is not supported by the kernels.")
    promoted = jnp.promote_types(vals.dtype, b.dtype)
    acc = jnp.dtype(exec.acc_dtype) if exec.acc_dtype is not None \
        else jnp.promote_types(promoted, jnp.float32)
    if jnp.promote_types(promoted, acc) != acc:
        raise ValueError(
            f"acc_dtype={acc.name} cannot hold the promoted operand dtype "
            f"{promoted.name} (vals {vals.dtype}, b {b.dtype}): "
            "accumulating below the input precision silently loses bits. "
            "Use a wider acc_dtype, or cast the operands down explicitly.")
    out = jnp.dtype(exec.out_dtype) if exec.out_dtype is not None \
        else promoted
    ep = exec.epilogue
    if ep is None and (bias is not None or residual is not None):
        ep = Epilogue(bias=bias is not None, residual=residual is not None)
    if ep is not None:
        for flag, operand, name in ((ep.bias, bias, "bias"),
                                    (ep.residual, residual, "residual")):
            if flag and operand is None:
                raise ValueError(
                    f"{where}(): the epilogue flags {name} but no {name}= "
                    "operand was passed.")
            if not flag and operand is not None:
                raise ValueError(
                    f"{where}(): a {name}= operand was passed but the "
                    f"explicit epilogue does not flag {name} — it would "
                    f"be silently ignored. Set Epilogue({name}=True) or "
                    "drop the operand.")
        if ep.bias and bias.shape != (m,):
            raise ValueError(
                f"{where}(): bias must have shape ({m},) — one entry per "
                f"C row — got {bias.shape}.")
        if ep.residual and (residual.ndim < 2
                            or residual.shape[-2:] != (m, b.shape[-1])):
            raise ValueError(
                f"{where}(): residual must have shape (..., {m}, "
                f"{b.shape[-1]}) matching C, got {residual.shape}.")
        if ep.is_identity():
            ep = None
    return dataclasses.replace(exec, epilogue=ep, acc_dtype=acc.name,
                               out_dtype=out.name)


def _forward(meta: PlanMeta, fwd: dict, vals, b, exec: ExecutionConfig,
             bias, residual, *, vmappable: bool):
    registry = _registry()
    if _trace._enabled:
        # Label the kernel region in any enclosing XLA profile; the
        # host-side span/event was already emitted by the dispatcher.
        with jax.named_scope(f"spmm_{meta.method}_{exec.impl}"):
            return _forward_inner(registry, meta, fwd, vals, b, exec,
                                  bias, residual, vmappable=vmappable)
    return _forward_inner(registry, meta, fwd, vals, b, exec, bias,
                          residual, vmappable=vmappable)


def _forward_inner(registry, meta, fwd, vals, b, exec, bias, residual, *,
                   vmappable: bool):
    if vmappable:
        op = registry.execute_op(meta, exec.tk, exec.interpret, exec.impl,
                                 exec.epilogue, exec.acc_dtype,
                                 exec.out_dtype)
        return op(fwd, vals, b, bias, residual)
    return registry.get_method(meta.method).execute(
        meta, fwd, vals, b, tk=exec.tk, interpret=exec.interpret,
        impl=exec.impl, epilogue=exec.epilogue, bias=bias,
        residual=residual, acc_dtype=exec.acc_dtype,
        out_dtype=exec.out_dtype)


def _int_zeros(tree):
    # Cotangents for the integer plan arrays: symbolic float0 zeros.
    return jax.tree.map(
        lambda x: np.zeros(x.shape, jax.dtypes.float0), tree)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _execute_vjp(meta, exec, fwd, bwd, vals, b, bias, residual):
    # The fwd/bwd bodies call the custom_vmap-wrapped ops: JAX vmaps these
    # bodies (it never differentiates them), so a vmapped batch axis lands
    # on the kernels' native batch grid instead of tracing into pallas_call.
    # ``exec`` is the normalized ExecutionConfig (frozen/hashable) — it
    # rides as a nondiff arg so the epilogue and dtypes reach both bodies.
    return _forward(meta, fwd, vals, b, exec, bias, residual,
                    vmappable=True)


def _execute_vjp_fwd(meta, exec, fwd, bwd, vals, b, bias, residual):
    ep = exec.epilogue
    if ep is None or ep.activation == "none":
        # Linear tail: fully fused forward; the backward needs no extra
        # saved intermediate (the chain rule through +bias/*scale/+residual
        # is dc-algebra only).
        out = _forward(meta, fwd, vals, b, exec, bias, residual,
                       vmappable=True)
        return out, (fwd, bwd, vals, b, bias, residual, None)
    # Nonlinear activation: fuse up to the pre-activation (C + bias, in acc
    # precision) and save it — the backward re-derives act'(pre) from it.
    # The act/scale/residual tail runs outside the kernel here; the
    # forward-only path (no grad) keeps the full fusion.
    pre_ep = dataclasses.replace(ep, activation="none", scale=None,
                                 residual=False)
    pre_exec = dataclasses.replace(
        exec, epilogue=None if pre_ep.is_identity() else pre_ep,
        out_dtype=exec.acc_dtype)
    pre = _forward(meta, fwd, vals, b, pre_exec,
                   bias if ep.bias else None, None, vmappable=True)
    tail = dataclasses.replace(ep, bias=False)
    out = apply_epilogue(pre, tail, None,
                         residual if ep.residual else None)
    return out.astype(jnp.dtype(exec.out_dtype)), \
        (fwd, bwd, vals, b, bias, residual, pre)


def _execute_vjp_bwd(meta, exec, res, dc):
    fwd, bwd, vals, b, bias, residual, pre = res
    ops = _ops()
    ep = exec.epilogue
    acc = jnp.dtype(exec.acc_dtype) if exec.acc_dtype else jnp.float32
    # Epilogue chain rule, peeled outside-in: out = act(C + bias) * scale
    # + residual  ⇒  d_residual = dc;  g = act'(pre) · (dc * scale) is the
    # cotangent of C (and of bias, row-summed).
    d_res = dc.astype(residual.dtype) \
        if ep is not None and ep.residual else None
    g = dc.astype(acc)
    if ep is not None:
        if ep.scale is not None:
            g = g * ep.scale
        if ep.activation != "none":
            _, act_vjp = jax.vjp(activation_fn(ep.activation),
                                 pre.astype(acc))
            g = act_vjp(g)[0]
    d_bias = None
    if ep is not None and ep.bias:
        d_bias = g.sum(axis=-1)
        if d_bias.ndim > 1:
            # Explicit leading batch dims: the bias is shared across them.
            d_bias = d_bias.sum(axis=tuple(range(d_bias.ndim - 1)))
        d_bias = d_bias.astype(bias.dtype)
    # dB = Aᵀ @ g through the transpose merge plan: the CSC view gets the
    # same equal-nonzero balancing as the forward pass (batched like it).
    db = ops.merge_execute_op(meta.k, exec.tk, exec.interpret, exec.impl)(
        bwd, vals, g).astype(b.dtype)
    # dvals = (g · Bᵀ) sampled at the pattern (gather-dot SDDMM), reduced
    # over any explicit batch dims — the values are shared across the batch.
    # (Under vmap the axis is implicit and JAX itself sums the cotangent
    # for the unbatched values primal.)
    dvals = ops.sddmm_op(exec.interpret, exec.impl)(
        fwd["nz_rows"], fwd["nz_cols"], fwd["nz_valid"], g, b)
    if dvals.ndim > 1:
        dvals = dvals.sum(axis=tuple(range(dvals.ndim - 1)))
    return (_int_zeros(fwd), _int_zeros(bwd), dvals.astype(vals.dtype), db,
            d_bias, d_res)


_execute_vjp.defvjp(_execute_vjp_fwd, _execute_vjp_bwd)


def execute_plan(plan: SpmmPlan, vals: jax.Array, b: jax.Array,
                 exec: ExecutionConfig | None = None, *,
                 bias: jax.Array | None = None,
                 residual: jax.Array | None = None,
                 interpret=_UNSET, impl=_UNSET, tk=_UNSET) -> jax.Array:
    """Execute a prebuilt plan: C = A @ B with A's values given per call.

    Trace-safe (every static decision was captured at plan build) and
    differentiable in ``vals``, ``b``, ``bias`` and ``residual`` when the
    plan carries its transpose (``build_plan(..., with_transpose=True)``,
    the default).

    ``exec`` is the per-call :class:`ExecutionConfig` (implementation,
    interpret mode, K-tile cap, fused epilogue, accumulation/output
    dtypes); the bare ``interpret``/``impl``/``tk`` kwargs are pre-v1
    shims that warn once.  ``b`` may carry leading batch dims —
    ``(..., k, n) → (..., m, n)`` runs the whole stack through one kernel
    dispatch with shared values, and ``jax.vmap`` over the 2-D form lowers
    to the same batched path.

    ``bias (m,)`` / ``residual (..., m, n)`` feed the fused epilogue
    ``act(C + bias) * scale + residual`` — flags in ``exec.epilogue`` (an
    :class:`Epilogue`; auto-derived from the operands when unset) —
    applied at the kernels' accumulator flush in ``exec.acc_dtype`` (f32
    by default, also under bf16 inputs) with one cast to
    ``exec.out_dtype``.  One pass over C instead of a write + re-read per
    tail op.
    """
    exec = coalesce_exec("execute_plan", exec, impl=impl,
                         interpret=interpret, tk=tk)
    # Static shape guards: gathers clamp out-of-bounds indices silently, so
    # a stale plan would otherwise produce garbage instead of an error.
    if vals.shape != (plan.meta.nnz_pad,):
        raise ValueError(
            f"plan expects vals of shape ({plan.meta.nnz_pad},) for pattern "
            f"{plan.meta.shape}, got {vals.shape} — was the plan built for "
            "a different sparsity pattern?")
    if b.ndim < 2 or b.shape[-2] != plan.meta.k:
        raise ValueError(
            f"plan expects B of shape (..., {plan.meta.k}, n) for pattern "
            f"{plan.meta.shape}, got {b.shape}")
    exec = _resolve_exec("execute_plan", plan.meta.m, vals, b, exec,
                         bias, residual)
    if _trace._enabled:
        _record_dispatch(plan.meta, b, exec)
    if plan.bwd is None:
        # Forward-only plan: plain ops (keeps ordinary XLA autodiff for
        # impl="xla" callers; build with a transpose for vmap support).
        return _forward(plan.meta, plan.fwd, vals, b, exec, bias, residual,
                        vmappable=False)
    return _execute_vjp(plan.meta, exec, plan.fwd, plan.bwd, vals, b,
                        bias, residual)


# ------------------------------------------------------------ public API ---


def _check_plan_overrides(plan: SpmmPlan, policy: PlanPolicy) -> None:
    """Raise on an explicit policy that contradicts the supplied plan.

    A plan's method/t/l_pad were fixed at build time; silently ignoring a
    conflicting override would execute something other than what the call
    asked for (ISSUE 3: the silent-wrong-answer paths).
    """
    meta = plan.meta
    conflicts = []
    if policy.method != "auto" and policy.method != meta.method:
        conflicts.append(f"method={policy.method!r} (plan: {meta.method!r})")
    if policy.t is not None and policy.t != meta.t:
        conflicts.append(f"t={policy.t} (plan: {meta.t})")
    if policy.tl is not None and policy.tl != meta.tl:
        conflicts.append(f"tl={policy.tl} (plan: {meta.tl})")
    if policy.l_pad is not None and policy.l_pad != meta.l_pad:
        conflicts.append(f"l_pad={policy.l_pad} (plan: {meta.l_pad})")
    if policy.shards is not None:
        conflicts.append(f"shards={policy.shards} (plan: unsharded — build "
                         "a sharded plan via engine.get_plan or "
                         "SparseMatrix.shard)")
    if conflicts:
        raise ValueError(
            "spmm() overrides conflict with the supplied plan's static "
            "decisions: " + "; ".join(conflicts) + ". Rebuild the plan with "
            "these parameters (repro.core.build_plan / "
            "repro.engine.get_plan) or drop the overrides.")


def _check_sharded_overrides(plan, policy: PlanPolicy) -> None:
    """Raise on an explicit policy contradicting a sharded plan's statics."""
    meta = plan.meta
    conflicts = []
    if policy.shards is not None:
        spec = policy.shards
        if spec.resolved_n() != meta.n_shards:
            conflicts.append(f"shards n={spec.resolved_n()} "
                             f"(plan: {meta.n_shards})")
        if spec.dim != meta.dim:
            conflicts.append(f"shards dim={spec.dim!r} (plan: {meta.dim!r})")
    if policy.method != "auto":
        mismatched = sorted({lm.method for lm in meta.local_metas
                             if lm.method != policy.method})
        if mismatched:
            conflicts.append(f"method={policy.method!r} (plan shards use "
                             f"{mismatched})")
    for name in ("t", "tl", "l_pad"):
        want = getattr(policy, name)
        if want is None:
            continue
        got = sorted({getattr(lm, name) for lm in meta.local_metas},
                     key=lambda x: (x is None, x))
        if got != [want]:
            conflicts.append(f"{name}={want} (plan shards: {got})")
    if conflicts:
        raise ValueError(
            "spmm() overrides conflict with the supplied sharded plan's "
            "static decisions: " + "; ".join(conflicts) + ". Rebuild the "
            "sharded plan with these parameters (engine.get_plan with a "
            "shards= policy) or drop the overrides.")


def spmm(a: CSR, b: jax.Array, policy: PlanPolicy | None = None,
         exec: ExecutionConfig | None = None, *,
         plan: SpmmPlan | str | None = None,
         bias: jax.Array | None = None,
         residual: jax.Array | None = None,
         method=_UNSET, l_pad=_UNSET, t=_UNSET, heuristic=_UNSET,
         interpret=_UNSET, impl=_UNSET, tk=_UNSET) -> jax.Array:
    """Sparse(CSR) × dense = dense.  ``b`` is (..., k, n); returns (..., m, n).

    ``policy`` (a :class:`PlanPolicy`) holds every pattern-static decision
    — method, static kernel parameters, heuristic/TuneDB — and ``exec``
    (an :class:`ExecutionConfig`) the per-call backend knobs.  The bare
    ``method``/``l_pad``/``t``/``heuristic``/``interpret``/``impl``/``tk``
    kwargs are pre-v1 shims: they still work (warning once per process)
    but raise when combined with ``policy``/``exec``.

    Dispatch on ``plan``:

    * an ``SpmmPlan`` — execute it (jit-safe; ``a`` supplies only values).
      An explicit ``policy`` must agree with the plan's statics —
      conflicts raise instead of being silently ignored.
    * ``None`` (default) with concrete ``a`` — look up / build the
      pattern's plan in the engine cache, then execute.  Repeated calls
      with the same pattern (any values) never replan.
    * ``None`` with traced ``a``, or the string ``"inline"`` — plan inside
      the traced computation, every call (the paper's original per-call
      regime; benchmarks time it deliberately).  With a concrete ``a``
      the method and its parameters resolve through the same
      ``PlanPolicy.resolve`` as the planned path (TuneDB ladder included);
      under trace an explicit method is required — resolution is a
      host-side decision.

    ``bias``/``residual`` feed the epilogue ``act(C + bias) * scale +
    residual`` (flags in ``exec.epilogue``; see :func:`execute_plan`).
    On the planned and sharded paths the epilogue fuses into the kernels'
    output write; the inline path plans per call and applies it as a
    separate XLA tail — same math, none of the fusion.
    """
    policy = coalesce_policy("spmm", policy, method=method, t=t,
                             l_pad=l_pad, heuristic=heuristic)
    exec = coalesce_exec("spmm", exec, impl=impl, interpret=interpret,
                         tk=tk)
    if isinstance(plan, SpmmPlan):
        _check_plan_overrides(plan, policy)
        return execute_plan(plan, a.vals, b, exec, bias=bias,
                            residual=residual)
    if plan is not None and not isinstance(plan, str):
        from repro.distributed.spmm import ShardedSpmmPlan
        if isinstance(plan, ShardedSpmmPlan):
            _check_sharded_overrides(plan, policy)
            return plan.execute(a.vals, b, exec, bias=bias,
                                residual=residual)
    if plan is None and not _is_traced(a):
        from repro.engine import get_plan
        built = get_plan(a, policy=policy)
        if isinstance(built, SpmmPlan):
            return execute_plan(built, a.vals, b, exec, bias=bias,
                                residual=residual)
        return built.execute(a.vals, b, exec, bias=bias, residual=residual)
    if plan not in (None, "inline"):
        raise ValueError(f"plan must be an SpmmPlan, a ShardedSpmmPlan, "
                         f"None, or 'inline'; got {plan!r}")
    if policy.shards is not None:
        raise ValueError(
            "the inline (plan-per-call) spmm path cannot shard: sharding "
            "is a host-side plan decision. Build the sharded plan outside "
            "jit (repro.engine.get_plan with a shards= policy, or "
            "SparseMatrix.shard) and pass it through the jitted function.")
    if b.ndim != 2:
        raise ValueError(
            "the inline (plan-per-call) spmm path takes a 2-D B; batched "
            f"B {b.shape} needs a prebuilt plan — repro.engine.get_plan(a) "
            "— whose execution folds the batch into the kernel grid.")
    registry = _registry()
    m_name, t_val, tl_val, l_val = (policy.method, policy.t, policy.tl,
                                    policy.l_pad)
    extra = None
    if not _is_traced(a):
        # One resolution for both regimes: the inline path consults the
        # same TuneDB ladder / heuristic / parameter validation as the
        # planned path, so the two can never pick different kernels for
        # the same matrix.
        r = policy.resolve(a)
        m_name, t_val, tl_val, l_val = r.method, r.t, r.tl, r.l_pad
        extra = r.extra
    elif m_name == "auto":
        raise ValueError(
            "spmm(method='auto') on a traced CSR would need a host-side "
            "heuristic decision per call. Build a plan outside jit "
            "(repro.engine.get_plan) — the kernel choice is captured "
            "statically at plan-build time — or pass an explicit method.")
    spec = registry.get_method(m_name)
    if spec.inline is None:
        raise ValueError(
            f"SpMM method {m_name!r} has no inline (plan-per-call) form; "
            "build a plan instead: repro.engine.get_plan(a, policy=...)")
    exec = _resolve_exec("spmm", a.m, a.vals, b, exec, bias, residual)
    if _trace._enabled:
        _trace.event("dispatch", cat="dispatch", method=m_name,
                     impl=exec.impl, inline=True, n=int(b.shape[-1]),
                     tk=exec.tk, acc_dtype=exec.acc_dtype,
                     out_dtype=exec.out_dtype)
    out = spec.inline(a, b, t=t_val, tl=tl_val, l_pad=l_val, extra=extra,
                      tk=exec.tk, interpret=exec.interpret, impl=exec.impl)
    # The inline forms predate the fused tail: apply the epilogue (and the
    # dtype contract) post hoc — same math as the fused paths, none of the
    # fusion, which only matters in the plan-once serving regime anyway.
    ep = exec.epilogue
    if ep is not None:
        acc = jnp.dtype(exec.acc_dtype)
        out = apply_epilogue(
            out.astype(acc), ep,
            bias.astype(acc)[:, None] if ep.bias else None,
            residual if ep.residual else None)
    return out.astype(jnp.dtype(exec.out_dtype))
