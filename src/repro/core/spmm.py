"""Public SpMM API: the paper's multi-algorithm with heuristic dispatch,
now plan-once/execute-many and differentiable.

    C = spmm(A, B)                  # auto: paper §5.4 heuristic
    C = spmm(A, B, method="merge")  # force merge-based  (paper §4.2)
    C = spmm(A, B, method="rowsplit", l_pad=64)  # force row-split (§4.1)

    plan = repro.engine.get_plan(A)          # once per sparsity pattern
    C = spmm(A, B, plan=plan)                # jit-safe, never replans
    C = execute_plan(plan, A.vals, B)        # the explicit-plan core

With a concrete (non-traced) CSR, ``spmm`` routes through the engine's
plan cache automatically.  Either way execution is differentiable via
``jax.custom_vjp``: ``dB = Aᵀ @ dC`` runs through the plan's cached
transpose (CSC-view) merge plan — equal-nonzero balanced, like the forward
— and ``dvals`` is a sampled-dense-dense (gather-dot) kernel over the
pattern (``repro.kernels.sddmm``).
"""
from __future__ import annotations

import functools

import jax
import numpy as np

from .csr import CSR
from .heuristic import Heuristic
from .plan import SpmmPlan, PlanMeta

_DEFAULT_HEURISTIC = Heuristic()


def _ops():
    # deferred: repro.kernels imports repro.core.csr at module scope, so an
    # eager import here would be circular
    from repro.kernels import ops
    return ops


def _is_traced(a: CSR) -> bool:
    return isinstance(a.row_ptr, jax.core.Tracer) or \
        isinstance(a.col_ind, jax.core.Tracer)


# --------------------------------------------------- plan execution core ---


def _forward(meta: PlanMeta, fwd: dict, vals, b, interpret, impl):
    ops = _ops()
    if meta.method == "merge":
        return ops.merge_execute(fwd, vals, b, m=meta.m,
                                 interpret=interpret, impl=impl)
    return ops.rowsplit_execute(fwd, vals, b, m=meta.m, tl=meta.tl,
                                interpret=interpret, impl=impl)


def _int_zeros(tree):
    # Cotangents for the integer plan arrays: symbolic float0 zeros.
    return jax.tree.map(
        lambda x: np.zeros(x.shape, jax.dtypes.float0), tree)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _execute_vjp(meta, interpret, impl, fwd, bwd, vals, b):
    return _forward(meta, fwd, vals, b, interpret, impl)


def _execute_vjp_fwd(meta, interpret, impl, fwd, bwd, vals, b):
    out = _forward(meta, fwd, vals, b, interpret, impl)
    return out, (fwd, bwd, vals, b)


def _execute_vjp_bwd(meta, interpret, impl, res, dc):
    fwd, bwd, vals, b = res
    ops = _ops()
    # dB = Aᵀ @ dC through the transpose merge plan: the CSC view gets the
    # same equal-nonzero balancing as the forward pass.
    db = ops.merge_execute(bwd, vals, dc, m=meta.k, interpret=interpret,
                           impl=impl).astype(b.dtype)
    # dvals = (dC · Bᵀ) sampled at the pattern (gather-dot SDDMM).
    dvals = ops.sddmm(fwd["nz_rows"], fwd["nz_cols"], fwd["nz_valid"],
                      dc, b, interpret=interpret,
                      impl=impl).astype(vals.dtype)
    return _int_zeros(fwd), _int_zeros(bwd), dvals, db


_execute_vjp.defvjp(_execute_vjp_fwd, _execute_vjp_bwd)


def execute_plan(plan: SpmmPlan, vals: jax.Array, b: jax.Array, *,
                 interpret: bool | None = None,
                 impl: str = "pallas") -> jax.Array:
    """Execute a prebuilt plan: C = A @ B with A's values given per call.

    Trace-safe (every static decision was captured at plan build) and
    differentiable in ``vals`` and ``b`` when the plan carries its
    transpose (``build_plan(..., with_transpose=True)``, the default).
    """
    # Static shape guards: gathers clamp out-of-bounds indices silently, so
    # a stale plan would otherwise produce garbage instead of an error.
    if vals.shape != (plan.meta.nnz_pad,):
        raise ValueError(
            f"plan expects vals of shape ({plan.meta.nnz_pad},) for pattern "
            f"{plan.meta.shape}, got {vals.shape} — was the plan built for "
            "a different sparsity pattern?")
    if b.ndim != 2 or b.shape[0] != plan.meta.k:
        raise ValueError(
            f"plan expects B of shape ({plan.meta.k}, n) for pattern "
            f"{plan.meta.shape}, got {b.shape}")
    if plan.bwd is None:
        return _forward(plan.meta, plan.fwd, vals, b, interpret, impl)
    return _execute_vjp(plan.meta, interpret, impl, plan.fwd, plan.bwd,
                        vals, b)


# ------------------------------------------------------------ public API ---


def spmm(a: CSR, b: jax.Array, *, method: str = "auto",
         l_pad: int | None = None, t: int = 16,
         heuristic: Heuristic | None = None,
         interpret: bool | None = None, impl: str = "pallas",
         plan: SpmmPlan | str | None = None) -> jax.Array:
    """Sparse(CSR) × dense = dense.  ``b`` is (k, n); returns (m, n).

    Dispatch on ``plan``:

    * an ``SpmmPlan`` — execute it (jit-safe; ``a`` supplies only values).
    * ``None`` (default) with concrete ``a`` — look up / build the
      pattern's plan in the engine cache, then execute.  Repeated calls
      with the same pattern (any values) never replan.
    * ``None`` with traced ``a``, or the string ``"inline"`` — plan inside
      the traced computation, every call (the paper's original per-call
      regime; benchmarks time it deliberately).  Requires an explicit
      ``method`` under trace — the heuristic is a host-side decision.
    """
    if isinstance(plan, SpmmPlan):
        return execute_plan(plan, a.vals, b, interpret=interpret, impl=impl)
    if plan is None and not _is_traced(a):
        from repro.engine import get_plan
        built = get_plan(a, method=method, t=t, l_pad=l_pad,
                         heuristic=heuristic)
        return execute_plan(built, a.vals, b, interpret=interpret, impl=impl)
    if plan not in (None, "inline"):
        raise ValueError(f"plan must be an SpmmPlan, None, or 'inline'; "
                         f"got {plan!r}")
    if method == "auto" and not _is_traced(a):
        method = (heuristic or _DEFAULT_HEURISTIC).choose(a)
    if method == "auto":
        raise ValueError(
            "spmm(method='auto') on a traced CSR would need a host-side "
            "heuristic decision per call. Build a plan outside jit "
            "(repro.engine.get_plan) — the kernel choice is captured "
            "statically at plan-build time — or pass method= explicitly.")
    if method == "merge":
        return _ops().merge_spmm(a, b, t=t, interpret=interpret, impl=impl)
    if method == "rowsplit":
        return _ops().rowsplit_spmm(a, b, l_pad=l_pad, interpret=interpret,
                                    impl=impl)
    raise ValueError(f"unknown SpMM method: {method!r}")
