"""Public SpMM API: the paper's multi-algorithm with heuristic dispatch.

    C = spmm(A, B)                  # auto: paper §5.4 heuristic
    C = spmm(A, B, method="merge")  # force merge-based  (paper §4.2)
    C = spmm(A, B, method="rowsplit", l_pad=64)  # force row-split (§4.1)
"""
from __future__ import annotations

import jax

from .csr import CSR
from .heuristic import Heuristic

_DEFAULT_HEURISTIC = Heuristic()


def _ops():
    # deferred: repro.kernels imports repro.core.csr at module scope, so an
    # eager import here would be circular
    from repro.kernels import ops
    return ops


def spmm(a: CSR, b: jax.Array, *, method: str = "auto",
         l_pad: int | None = None, t: int = 16,
         heuristic: Heuristic = _DEFAULT_HEURISTIC,
         interpret: bool | None = None, impl: str = "pallas") -> jax.Array:
    """Sparse(CSR) × dense = dense.  ``b`` is (k, n); returns (m, n)."""
    if method == "auto":
        method = heuristic.choose(a)
    if method == "merge":
        return _ops().merge_spmm(a, b, t=t, interpret=interpret, impl=impl)
    if method == "rowsplit":
        return _ops().rowsplit_spmm(a, b, l_pad=l_pad, interpret=interpret,
                                    impl=impl)
    raise ValueError(f"unknown SpMM method: {method!r}")
