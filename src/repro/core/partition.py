"""Nonzero-split (merge-based) work partitioning — paper §4, Fig. 2(b).

Phase 1 of the paper's two-phase decomposition (``PartitionSpmm``,
Algorithm 1 line 2): assign an *equal number of nonzeroes* to each
processor/chunk, then binary-search ``row_ptr`` to find which row each chunk
starts in.  On TPU the "processor" is a Pallas grid step; the search is a
vectorized ``jnp.searchsorted`` fused into the surrounding jit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .csr import CSR, rows_from_row_ptr


def num_chunks(nnz_pad: int, t: int) -> int:
    return max(1, -(-nnz_pad // t))


def partition_spmm(a: CSR, t: int):
    """Nonzero-split partition with T nonzeroes per chunk.

    Returns ``(chunk_start_rows, nnz_rows)`` where ``chunk_start_rows[c]`` is
    the row containing nonzero ``c*t`` (the paper's ``limits[]``) and
    ``nnz_rows`` is the per-nonzero row id (CSR→COO flattening, the paper's
    ``PrepareSpmm``).  Both are O(nnz log m) binary searches on the VPU — the
    TPU analogue of the MGPU 1-D merge-path search.
    """
    n_chunks = num_chunks(a.nnz_pad, t)
    starts = jnp.arange(n_chunks, dtype=a.row_ptr.dtype) * t
    # side='right' − 1 gives the row r with row_ptr[r] <= start < row_ptr[r+1].
    chunk_start_rows = (
        jnp.searchsorted(a.row_ptr, starts, side="right").astype(jnp.int32) - 1
    )
    nnz_rows = rows_from_row_ptr(a.row_ptr, a.nnz_pad)
    return chunk_start_rows, nnz_rows


def chunk_segments(nnz_rows: jax.Array, t: int, m: int):
    """Per-chunk local segment structure for the carry-out scratch.

    For chunk ``c`` covering nonzeroes ``[c*t, (c+1)*t)``:

    * ``local``    (n_chunks, t): rank of each nonzero's row *within* the
      chunk (0-based count of row changes) — robust to runs of empty rows,
      which the paper singles out as the pathological case merge handles.
    * ``seg_rows`` (n_chunks, t): global row id owning each local segment,
      or ``m`` (dropped by the epilogue ``segment_sum``) for unused slots.

    A chunk of T nonzeroes touches ≤ T distinct rows, so the scratch segment
    axis is T wide.  The scatter of per-(chunk, segment) partial sums into C
    is the paper's ``FixCarryout`` generalized to every row a chunk touches.
    """
    n_chunks = num_chunks(nnz_rows.shape[0], t)
    pad = n_chunks * t - nnz_rows.shape[0]
    rows = jnp.pad(nnz_rows, (0, pad), constant_values=m)
    rows = rows.reshape(n_chunks, t)
    change = jnp.concatenate(
        [jnp.zeros((n_chunks, 1), jnp.int32),
         (rows[:, 1:] != rows[:, :-1]).astype(jnp.int32)], axis=1)
    local = jnp.cumsum(change, axis=1)  # (n_chunks, t), values in [0, t-1]
    seg_rows = jnp.full((n_chunks, t), m, jnp.int32)
    chunk_ids = jnp.broadcast_to(
        jnp.arange(n_chunks, dtype=jnp.int32)[:, None], (n_chunks, t))
    seg_rows = seg_rows.at[chunk_ids, local].set(rows)
    return rows, local, seg_rows
