"""CSR sparse matrix as a JAX pytree.

The paper's input format: compressed sparse row.  ``row_ptr`` has ``m+1``
entries, ``col_ind``/``vals`` have ``nnz`` entries (``nnz`` is a *static*
trailing pad — padded entries carry ``col_ind = 0`` and ``vals = 0`` so every
kernel can consume them harmlessly).  Shape ``(m, k)`` is static metadata.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CSR:
    """Sparse m×k matrix in CSR format (paper §2.2)."""

    row_ptr: jax.Array  # (m + 1,) int32, row_ptr[m] == nnz_true
    col_ind: jax.Array  # (nnz_pad,) int32, padded with 0
    vals: jax.Array     # (nnz_pad,) dtype, padded with 0
    shape: tuple[int, int] = dataclasses.field(metadata=dict(static=True))

    @property
    def m(self) -> int:
        return self.shape[0]

    @property
    def k(self) -> int:
        return self.shape[1]

    @property
    def nnz_pad(self) -> int:
        """Static (padded) nonzero capacity."""
        return self.col_ind.shape[0]

    @property
    def dtype(self):
        return self.vals.dtype

    def nnz(self) -> jax.Array:
        """True (traced) number of nonzeroes."""
        return self.row_ptr[-1]

    def mean_row_length(self) -> jax.Array:
        """The paper's heuristic quantity d = nnz / m (§5.4)."""
        return self.nnz().astype(jnp.float32) / self.m

    def row_lengths(self) -> jax.Array:
        return jnp.diff(self.row_ptr)

    def to_dense(self) -> jax.Array:
        """Densify (oracle / small matrices only)."""
        m, k = self.shape
        rows = rows_from_row_ptr(self.row_ptr, self.nnz_pad)
        valid = jnp.arange(self.nnz_pad) < self.nnz()
        dense = jnp.zeros((m, k), self.vals.dtype)
        # Padded entries scatter 0 into [0, 0]; harmless because vals are 0.
        return dense.at[jnp.where(valid, rows, 0),
                        jnp.where(valid, self.col_ind, 0)].add(
                            jnp.where(valid, self.vals, 0))


def rows_from_row_ptr(row_ptr: jax.Array, nnz_pad: int) -> jax.Array:
    """Expand row_ptr to a per-nonzero row-id vector.

    This is the CSR→COO flattening the paper calls ``PrepareSpmm``
    (Algorithm 1 line 21), done with a vectorized binary search.
    Padded tail entries receive row id ``m`` (one past the last row).
    """
    return jnp.searchsorted(
        row_ptr, jnp.arange(nnz_pad, dtype=row_ptr.dtype), side="right"
    ).astype(jnp.int32) - 1


def from_dense(dense, nnz_pad: int | None = None) -> CSR:
    """Build CSR from a dense matrix (host-side; numpy semantics)."""
    dense = np.asarray(dense)
    m, k = dense.shape
    mask = dense != 0
    counts = mask.sum(axis=1).astype(np.int32)
    row_ptr = np.zeros(m + 1, np.int32)
    np.cumsum(counts, out=row_ptr[1:])
    nnz = int(row_ptr[-1])
    if nnz_pad is None:
        nnz_pad = max(nnz, 1)
    assert nnz_pad >= nnz, f"nnz_pad {nnz_pad} < nnz {nnz}"
    rows, cols = np.nonzero(mask)
    col_ind = np.zeros(nnz_pad, np.int32)
    vals = np.zeros(nnz_pad, dense.dtype)
    col_ind[:nnz] = cols
    vals[:nnz] = dense[rows, cols]
    return CSR(jnp.asarray(row_ptr), jnp.asarray(col_ind), jnp.asarray(vals),
               (m, k))


def random_csr(key, m: int, k: int, *, nnz_per_row=None, density=None,
               dtype=jnp.float32, pad_to: int | None = None) -> CSR:
    """Random CSR with controllable irregularity.

    ``nnz_per_row`` may be an int (regular rows), a (lo, hi) tuple (uniform
    irregular rows — the paper's Type 1/2 imbalance driver), or None with
    ``density`` given.  Built host-side with numpy for test/bench setup.
    """
    rng = np.random.default_rng(int(jax.random.randint(key, (), 0, 2**31 - 1)))
    if nnz_per_row is None:
        assert density is not None
        nnz_per_row = max(int(round(density * k)), 0)
    if isinstance(nnz_per_row, tuple):
        lo, hi = nnz_per_row
        lengths = rng.integers(lo, hi + 1, size=m)
    else:
        lengths = np.full(m, int(nnz_per_row))
    lengths = np.minimum(lengths, k).astype(np.int64)
    row_ptr = np.zeros(m + 1, np.int32)
    np.cumsum(lengths, out=row_ptr[1:])
    nnz = int(row_ptr[-1])
    nnz_pad = max(nnz if pad_to is None else pad_to, 1)
    assert nnz_pad >= nnz
    col_ind = np.zeros(nnz_pad, np.int32)
    vals = np.zeros(nnz_pad, np.float64)
    for r in range(m):
        s, e = row_ptr[r], row_ptr[r + 1]
        if e > s:
            col_ind[s:e] = np.sort(rng.choice(k, size=e - s, replace=False))
    vals[:nnz] = rng.standard_normal(nnz)
    return CSR(jnp.asarray(row_ptr), jnp.asarray(col_ind),
               jnp.asarray(vals, dtype=dtype), (m, k))


def prune_to_csr(w: jax.Array, keep_fraction: float) -> CSR:
    """Magnitude-prune a dense weight to CSR (the paper's use case §1 [1]).

    Keeps the top ``keep_fraction`` of entries *per row* so every row has the
    same nonzero count — and then the interesting irregularity comes from the
    matrix the user hands us, not the pruner.
    """
    w = np.asarray(w)
    m, k = w.shape
    keep = max(1, min(int(round(keep_fraction * k)), k))
    idx = np.argsort(-np.abs(w), axis=1)[:, :keep]
    idx.sort(axis=1)
    vals = np.take_along_axis(w, idx, axis=1)
    row_ptr = np.arange(m + 1, dtype=np.int32) * keep
    return CSR(jnp.asarray(row_ptr),
               jnp.asarray(idx.reshape(-1).astype(np.int32)),
               jnp.asarray(vals.reshape(-1)), (m, k))
