"""API v1 configuration split: plan policy vs. execution config.

The pre-v1 ``spmm`` accreted eight orthogonal kwargs.  They are really two
objects with different lifetimes:

* :class:`PlanPolicy` — **decided once per sparsity pattern, host-side**:
  which method (``"auto"`` resolves through the TuneDB ladder, then the
  registry's heuristic cost hooks), static kernel parameters (``t``,
  ``tl``, ``l_pad``), whether to build the transpose plan.  A policy is
  hashed into the engine's plan-cache key; resolving it
  (:meth:`PlanPolicy.resolve`) is the single choke point every plan
  request — planned *and* inline — funnels through, so the two paths can
  never pick different methods for the same matrix.

* :class:`ExecutionConfig` — **per call, trace-safe**: which
  implementation runs (``pallas`` | ``xla``), interpret mode, the K-tile
  cap ``tk``, the fused :class:`~repro.core.epilogue.Epilogue`, and the
  accumulator/output dtype overrides.  Changing it never invalidates a
  plan.

Canonical v1 signatures::

    spmm(a, b, policy=PlanPolicy(...), exec=ExecutionConfig(...))
    execute_plan(plan, vals, b, exec=ExecutionConfig(...))

The pre-v1 kwargs remain as deprecation shims for one release: they warn
once per process and raise when combined with the new-style objects.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, NamedTuple

from repro import obs as _obs
from repro.obs import trace as _trace

from .epilogue import Epilogue
from .heuristic import Heuristic

# Ladder-rung outcomes of PlanPolicy.resolve: explicit | exact | class |
# calibrated | analytic.  Always-on (plan-time, not per-execute):
# obs.report() derives ladder hit rates from this family.
_resolve_total = _obs.registry.counter(
    "plan_resolve_total", "PlanPolicy.resolve outcomes by ladder rung",
    labels=("rung", "method"))


def _canon_dtype(x) -> str | None:
    """Normalize a dtype-ish to its canonical name string (or None).

    Stored as a string so ExecutionConfig stays hashable and printable
    without importing jax at config time; resolved back to a dtype at the
    kernel boundary.
    """
    if x is None:
        return None
    if isinstance(x, str) and x in ("float32", "bfloat16", "float16",
                                    "float64"):
        return x
    import jax.numpy as jnp

    dt = jnp.dtype(x)
    if not jnp.issubdtype(dt, jnp.floating):
        raise ValueError(
            f"ExecutionConfig dtypes must be floating, got {dt.name!r}")
    return dt.name


class _DefaultTuneDB:
    """Sentinel: 'use the process-default TuneDB' (``engine.set_tunedb``).

    Distinct from ``None``, which explicitly opts out of measured
    resolution and falls back to the analytic heuristic.
    """

    _instance: "_DefaultTuneDB" | None = None

    def __new__(cls):
        if cls._instance is None:
            cls._instance = super().__new__(cls)
        return cls._instance

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return "DEFAULT_TUNEDB"


DEFAULT_TUNEDB = _DefaultTuneDB()


@dataclasses.dataclass(frozen=True)
class ShardSpec:
    """How to shard an SpMM over devices (``PlanPolicy.shards``).

    ``n`` is the shard count (defaults to ``mesh.shape[axis]`` when a mesh
    is given); ``dim`` picks the nnz-balanced cut direction — ``"rows"``
    (data parallel: per-device row blocks, row-concatenated C) or
    ``"cols"`` (tensor parallel: per-device column slices of A against row
    blocks of B, partial sums all-reduced).  ``mesh`` is optional: without
    one, execution runs the per-shard loop on whatever devices hold the
    data (numerically identical); with one whose ``axis`` size matches
    ``n``, uniform plans execute as a single ``shard_map`` program.
    Hashable — a ShardSpec is part of the engine's plan-cache key.
    """

    n: int | None = None
    dim: str = "rows"
    axis: str | None = None        # default: "data" (rows) / "model"
    mesh: Any = None                  # jax.sharding.Mesh | None

    def __post_init__(self):
        if self.dim not in ("rows", "cols"):
            raise ValueError(
                f"ShardSpec.dim must be 'rows' or 'cols', got {self.dim!r}")
        if self.n is None and self.mesh is None:
            raise ValueError("ShardSpec needs n= (shard count) or mesh=")
        if self.n is not None and self.n < 1:
            raise ValueError(f"ShardSpec.n must be >= 1, got {self.n}")
        if self.axis is None:
            object.__setattr__(
                self, "axis", "model" if self.dim == "cols" else "data")
        if self.mesh is not None:
            if self.axis not in self.mesh.axis_names:
                raise ValueError(
                    f"ShardSpec axis {self.axis!r} is not an axis of the "
                    f"mesh (axes: {self.mesh.axis_names})")
            axis_size = self.mesh.shape[self.axis]
            if self.n is not None and self.n != axis_size:
                raise ValueError(
                    f"ShardSpec n={self.n} conflicts with mesh axis "
                    f"{self.axis!r} of size {axis_size}; drop n= to take "
                    "the axis size, or pass a matching mesh")

    def resolved_n(self) -> int:
        return self.n if self.n is not None else self.mesh.shape[self.axis]


def _as_shard_spec(shards) -> ShardSpec | None:
    if shards is None or isinstance(shards, ShardSpec):
        return shards
    if isinstance(shards, int):
        return ShardSpec(n=shards)
    raise TypeError(
        f"PlanPolicy.shards must be a ShardSpec, an int shard count, or "
        f"None; got {type(shards).__name__}")


class ResolvedPlan(NamedTuple):
    """A fully pinned-down plan request (every static decision made)."""

    method: str
    t: int
    tl: int
    l_pad: int | None
    extra: tuple                  # hashable method-specific statics


@dataclasses.dataclass(frozen=True)
class PlanPolicy:
    """How to *plan*: method selection + pattern-static parameters.

    All fields are host-side decisions captured at plan-build time and
    hashed into the engine cache key — never consulted inside jit.
    ``method="auto"`` resolves through the empirical TuneDB ladder (exact
    pattern → binned class → DB-calibrated threshold) and then the method
    registry's heuristic cost hooks; explicit methods name a registered
    ``MethodSpec`` (``repro.kernels.registry``).
    """

    method: str = "auto"
    t: int | None = None            # merge: nonzeroes per chunk
    tl: int | None = None           # rowsplit/rowgroup: row batch size
    l_pad: int | None = None        # rowsplit: static max row length
    heuristic: Heuristic | None = None
    tunedb: Any = DEFAULT_TUNEDB       # TuneDB | None (opt out) | default
    with_transpose: bool = True        # build the backward (CSC) plan
    shards: ShardSpec | None = None  # device sharding (int = n shards)

    def __post_init__(self):
        object.__setattr__(self, "shards", _as_shard_spec(self.shards))

    @classmethod
    def from_meta(cls, meta) -> "PlanPolicy":
        """The policy that replays an existing plan's full statics.

        Use to rebuild a plan identical to one in hand (checkpoint
        restore, ``ensure_spmm_plans``): method *and* tuned parameters
        are pinned, so nothing silently re-derives to defaults.
        """
        return cls(method=meta.method, t=meta.t, tl=meta.tl,
                   l_pad=meta.l_pad, with_transpose=meta.has_transpose)

    def resolved_tunedb(self):
        """The TuneDB this policy actually consults (may be None)."""
        if self.tunedb is DEFAULT_TUNEDB:
            from repro.engine import current_tunedb
            return current_tunedb()
        return self.tunedb

    def resolve(self, a) -> ResolvedPlan:
        """Pin down every pattern-static decision for a concrete CSR.

        The single source of truth for ``build_plan``, the engine cache
        key, and the inline (plan-per-call) ``spmm`` path — they can never
        disagree on the method or its static parameters.  Host-side only.
        """
        from repro.kernels import registry

        from .plan import _require_concrete, pattern_fingerprint

        if self.shards is not None:
            raise ValueError(
                "PlanPolicy.resolve() pins down the statics of ONE "
                "pattern; a sharded policy resolves per shard — each "
                "shard's local stats pick its own method — inside "
                "repro.distributed.spmm.build_sharded_plan (or via "
                "engine.get_plan, which dispatches on shards=).")
        _require_concrete(a, "PlanPolicy.resolve")
        method, t, l_pad = self.method, self.t, self.l_pad
        heuristic = self.heuristic
        tunedb = self.resolved_tunedb()
        # Which ladder rung decides the method (recorded below): explicit
        # requests skip the ladder entirely; "analytic" covers both the
        # no-TuneDB heuristic and a user-supplied Heuristic.
        rung = "explicit" if method != "auto" else "analytic"
        fallback = False
        if method == "auto" and tunedb is not None:
            registered = registry.method_names()
            rec = tunedb.lookup_exact(pattern_fingerprint(a))
            if rec is not None and rec.method not in registered:
                # Stale DB naming a method this process doesn't have
                # (e.g. built with a plugin): drop to the next rungs of
                # the ladder instead of crashing every plan on this
                # pattern.
                warnings.warn(
                    f"TuneDB exact record names unregistered method "
                    f"{rec.method!r} (registered: "
                    f"{', '.join(registered)}); falling back to "
                    "class/heuristic resolution", stacklevel=2)
                rec = None
            if rec is not None:
                # Exact hit: replay the measured winner and tuned params.
                method = rec.method
                t = rec.t if t is None else t
                l_pad = rec.l_pad if l_pad is None else l_pad
                rung = "exact"
            else:
                cls_method = tunedb.lookup_class_for(a)
                if cls_method is not None and cls_method in registered:
                    method = cls_method
                    rung = "class"
                elif heuristic is None:
                    heuristic = tunedb.heuristic()   # calibrated threshold
                    rung = "calibrated"
        auto_resolved = method != self.method     # ladder picked it
        if method == "auto":
            method = registry.choose_auto(a, heuristic or Heuristic())
            auto_resolved = True
        spec = registry.get_method(method)
        try:
            t, tl, l_pad, extra = spec.resolve_params(a, t=t, tl=self.tl,
                                                      l_pad=l_pad)
        except ValueError:
            if not auto_resolved:
                raise                             # the user asked for it
            # The ladder's winner rejects the caller's explicit params
            # (e.g. a TuneDB exact record replays "rowgroup" but the
            # caller passed a global l_pad, which only rowsplit-style
            # methods accept).  An "auto" request must not crash on a
            # constraint the caller never chose the method for — fall
            # back to the analytic choice among the core methods.
            method = registry.choose_auto(a, heuristic or Heuristic())
            spec = registry.get_method(method)
            t, tl, l_pad, extra = spec.resolve_params(
                a, t=self.t, tl=self.tl, l_pad=self.l_pad)
            rung, fallback = "analytic", True
        _resolve_total.labels(rung=rung, method=method).inc()
        if _trace._enabled:
            m_, k_ = a.shape
            _trace.event("plan.resolve", cat="plan", rung=rung,
                         method=method, m=int(m_), k=int(k_),
                         nnz_pad=int(a.nnz_pad), t=t, tl=tl,
                         l_pad=l_pad, fallback=fallback)
        return ResolvedPlan(method=method, t=t, tl=tl, l_pad=l_pad,
                            extra=extra)


@dataclasses.dataclass(frozen=True)
class ExecutionConfig:
    """How to *execute*: per-call, trace-safe backend knobs.

    ``impl``: ``"pallas"`` (the TPU kernels; interpret mode on CPU) or
    ``"xla"`` (the pure-XLA twins).  ``interpret``: force Pallas interpret
    mode (None: auto — interpret off TPU).  ``tk``: cap the K-tile of the
    streamed B panel (None: whole ``k`` up to
    ``kernels.merge_spmm.DEFAULT_TK_MAX``).

    ``epilogue``: a fused :class:`~repro.core.epilogue.Epilogue` spec —
    ``y = act(C + bias) * scale + residual`` applied at the kernels'
    accumulator flush; the ``bias``/``residual`` *arrays* travel as
    ``execute_plan``/``spmm`` call arguments.  ``acc_dtype``: accumulator
    precision (None → float32 — e.g. bf16 values/B with f32
    accumulation); ``out_dtype``: C's dtype (None → the promotion of the
    input dtypes).  Dtypes are stored as canonical name strings so the
    config stays hashable; anything ``jnp.dtype`` accepts is normalized.
    An ``acc_dtype`` the inputs don't fit in (f32 inputs, bf16
    accumulator) is rejected at call time — silent precision loss is a
    silent wrong answer.
    """

    impl: str = "pallas"
    interpret: bool | None = None
    tk: int | None = None
    epilogue: Epilogue | None = None
    acc_dtype: str | None = None
    out_dtype: str | None = None

    def __post_init__(self):
        object.__setattr__(self, "acc_dtype", _canon_dtype(self.acc_dtype))
        object.__setattr__(self, "out_dtype", _canon_dtype(self.out_dtype))
        if self.epilogue is not None and \
                not isinstance(self.epilogue, Epilogue):
            raise TypeError(
                "ExecutionConfig.epilogue must be a repro.core.Epilogue "
                f"(got {type(self.epilogue).__name__})")


DEFAULT_EXECUTION = ExecutionConfig()


# ------------------------------------------------------ deprecation shims ---

_UNSET = object()

_warned: set = set()


def _warn_deprecated(what: str, instead: str, *, stacklevel: int = 5) -> None:
    """DeprecationWarning, once per process per spelling.

    ``stacklevel`` is relative to ``warnings.warn`` inside this function;
    the default of 5 fits the ``spmm``/``execute_plan`` →
    ``coalesce_*`` → ``_coalesce`` chain — direct callers sitting fewer
    frames deep must pass their own so the warning points at the user's
    deprecated call site.
    """
    if what in _warned:
        return
    _warned.add(what)
    warnings.warn(f"{what} is deprecated; {instead}",
                  DeprecationWarning, stacklevel=stacklevel)


def reset_deprecation_warnings() -> None:
    """Forget which deprecations already warned (tests only)."""
    _warned.clear()


def _coalesce(context: str, new_name: str, new_obj, cls, legacy: dict):
    given = {k: v for k, v in legacy.items() if v is not _UNSET}
    if not given:
        return new_obj
    if new_obj is not None:
        raise ValueError(
            f"{context}: pass either {new_name}= or the legacy kwargs "
            f"{sorted(given)}, not both — the legacy kwargs are shims for "
            f"{cls.__name__} and cannot override it")
    for k in given:
        _warn_deprecated(
            f"{context}({k}=...)",
            f"pass {new_name}={cls.__name__}({k}=...) "
            "(see README.md: Migrating to API v1)")
    return cls(**given)


def coalesce_policy(context: str, policy: PlanPolicy | None, *,
                    method=_UNSET, t=_UNSET, l_pad=_UNSET,
                    heuristic=_UNSET) -> PlanPolicy:
    """Fold pre-v1 plan kwargs into a PlanPolicy (warn once; conflicts
    with an explicit ``policy=`` raise)."""
    out = _coalesce(context, "policy", policy, PlanPolicy,
                    dict(method=method, t=t, l_pad=l_pad,
                         heuristic=heuristic))
    return out if out is not None else PlanPolicy()


def coalesce_exec(context: str, exec_: ExecutionConfig | None, *,
                  impl=_UNSET, interpret=_UNSET,
                  tk=_UNSET) -> ExecutionConfig:
    """Fold pre-v1 execution kwargs into an ExecutionConfig."""
    out = _coalesce(context, "exec", exec_, ExecutionConfig,
                    dict(impl=impl, interpret=interpret, tk=tk))
    return out if out is not None else DEFAULT_EXECUTION
