"""Plan-once / execute-many SpMM plans.

The paper's thesis is that CSR-native SpMM wins by paying for *planning*
(load-balanced work partitioning) instead of format conversion.  For the
motivating workload — a pruned weight whose sparsity pattern is frozen for
the lifetime of the model — even that planning cost should be paid once,
not once per jitted call.  ``SpmmPlan`` captures everything derived from
the pattern:

* the forward execute structure — built by the resolved method's
  registered ``build_structure`` hook (``repro.kernels.registry``): merge
  chunk layout, row-split ELL layout (with its static ``l_pad``),
  row-grouped per-bucket ELL blocks, or whatever a registered method
  defines (method-specific statics land in ``PlanMeta.extra``),
* the kernel choice (``PlanPolicy.resolve``: the TuneDB ladder and the
  §5.4 heuristic evaluated *statically at plan-build time*, so jitted
  code never host-syncs on a method decision),
* per-nonzero (row, col) coordinates for the values-cotangent SDDMM, and
* a *transpose plan*: the same merge-based equal-nonzero balancing applied
  to the CSC view of A, so the backward ``dB = Aᵀ @ dC`` inherits the
  paper's load-balance guarantees.

Plans are pytrees of int32 device arrays plus static ``PlanMeta`` — they
thread through ``jax.jit`` boundaries as ordinary arguments and live inside
model pytrees (``repro.models.sparse.SparseLinear``).  Values are *not*
part of a plan: they are re-applied per call via the ``slot_nz``
indirection, which is what makes a plan reusable across training steps that
update the values but not the pattern.

Build plans eagerly (outside jit) with ``build_plan`` or, cached per
pattern, with ``repro.engine.get_plan``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import _flags as _verify_flags

from .csr import CSR
from .heuristic import Heuristic


@dataclasses.dataclass(frozen=True)
class PlanMeta:
    """Static (hashable) metadata of an SpmmPlan — safe as a jit constant."""

    method: str                  # a registered method name (e.g. "merge")
    shape: tuple[int, int]       # (m, k) of A
    nnz_pad: int                 # static nonzero capacity
    t: int                       # merge: nonzeroes per chunk
    tl: int                      # rowsplit: nonzeroes per row batch
    l_pad: int | None         # rowsplit: static max row length
    has_transpose: bool          # backward (CSC-view) plan present
    extra: tuple = ()            # method-specific statics (hashable), e.g.
                                 # rowgroup's ((m_g, l_g), ...) group table

    def __post_init__(self):
        # PlanMeta rides through jit as a static (hashable) constant; an
        # unhashable ``extra`` would otherwise surface much later as an
        # opaque "unhashable type" error deep inside jax's caching.  Fail
        # here, at construction, with the actual culprit named.
        try:
            hash(self.extra)
        except TypeError:
            raise TypeError(
                f"PlanMeta.extra must be hashable (it is a jit-static "
                f"constant), got {type(self.extra).__name__}: "
                f"{self.extra!r}. Use nested tuples instead of "
                "lists/dicts/arrays for method-specific statics."
            ) from None

    @property
    def m(self) -> int:
        return self.shape[0]

    @property
    def k(self) -> int:
        return self.shape[1]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SpmmPlan:
    """Pattern-derived execute state for C = A @ B (and its VJP)."""

    fwd: dict                    # forward structure + nz coordinate arrays
    bwd: dict | None          # transpose merge structure (CSC view)
    meta: PlanMeta = dataclasses.field(metadata=dict(static=True))

    @property
    def method(self) -> str:
        return self.meta.method

    @property
    def l_pad(self) -> int | None:
        return self.meta.l_pad


def _kernels():
    # deferred: repro.kernels imports repro.core.csr at module scope
    from repro.kernels import merge_spmm, rowsplit_spmm
    return merge_spmm, rowsplit_spmm


def _require_concrete(a: CSR, what: str) -> None:
    if isinstance(a.row_ptr, jax.core.Tracer) or \
            isinstance(a.col_ind, jax.core.Tracer):
        raise ValueError(
            f"{what} needs a concrete sparsity pattern, but the CSR is "
            "traced. Build the plan once outside jit (repro.engine.get_plan "
            "or repro.core.plan.build_plan) and pass the SpmmPlan through "
            "the jitted function — plans are ordinary pytrees.")


def transpose_pattern(a: CSR):
    """CSC view of A as a CSR matrix of Aᵀ, plus the nonzero permutation.

    Returns ``(a_t, perm)`` where ``a_t`` is a (k, m) CSR holding the same
    pattern transposed (vals are zeros — structure only) and ``perm`` is an
    (nnz_pad,) int32 map from transpose nonzero position to original
    nonzero position (``nnz_pad`` sentinel past the valid range, so
    ``vals_ext[perm]`` with an appended zero yields the transposed values).
    Host-side; pattern must be concrete.
    """
    rp = np.asarray(a.row_ptr)
    ci = np.asarray(a.col_ind)
    m, k = a.shape
    nnz = int(rp[-1])
    nnz_pad = a.nnz_pad
    rows = np.repeat(np.arange(m, dtype=np.int32), np.diff(rp))
    cols = ci[:nnz]
    perm_valid = np.argsort(cols, kind="stable")           # CSC order
    t_row_ptr = np.zeros(k + 1, np.int32)
    np.cumsum(np.bincount(cols, minlength=k), out=t_row_ptr[1:])
    t_col_ind = np.zeros(nnz_pad, np.int32)
    t_col_ind[:nnz] = rows[perm_valid]
    perm = np.full(nnz_pad, nnz_pad, np.int32)
    perm[:nnz] = perm_valid
    a_t = CSR(jnp.asarray(t_row_ptr), jnp.asarray(t_col_ind),
              jnp.zeros(nnz_pad, a.vals.dtype), (k, m))
    return a_t, jnp.asarray(perm)


def _compose_slots(slot_nz: jax.Array, perm: jax.Array,
                   nnz_pad: int) -> jax.Array:
    """Remap slot indices through a nonzero permutation (sentinel-safe)."""
    perm_ext = jnp.concatenate(
        [perm, jnp.full((1,), nnz_pad, jnp.int32)])
    return perm_ext[slot_nz]


def _policy_from_kwargs(policy, method, heuristic, t, tl, l_pad,
                        with_transpose, tunedb):
    """Unify the explicit-kwarg and PlanPolicy spellings of a request."""
    from .config import PlanPolicy

    if policy is not None:
        if (method, heuristic, t, tl, l_pad, tunedb) != \
                ("auto", None, None, None, None, None) or not with_transpose:
            raise ValueError(
                "pass either policy= or the explicit method/heuristic/t/tl/"
                "l_pad/tunedb/with_transpose kwargs, not both")
        return policy
    return PlanPolicy(method=method, heuristic=heuristic, t=t, tl=tl,
                      l_pad=l_pad, tunedb=tunedb,
                      with_transpose=with_transpose)


def resolve_static(a: CSR, *, method: str = "auto",
                   heuristic: Heuristic | None = None,
                   t: int | None = None, tl: int | None = None,
                   l_pad: int | None = None, tunedb=None, policy=None):
    """Pin down every pattern-static decision of a plan request.

    Legacy spelling of ``PlanPolicy.resolve`` (``repro.core.config``):
    returns ``(method, t, tl, l_pad)`` fully resolved — ``auto`` goes
    through the TuneDB ladder (exact → class → calibrated threshold) and
    then the method registry's heuristic cost hooks; per-method parameter
    defaults and validation (e.g. the rowsplit ``l_pad`` silent-truncation
    guard) come from each method's registered ``resolve_params`` hook.
    All host-side, never inside jit.  Single source of truth for
    ``build_plan`` and the engine cache key — they can never disagree.
    """
    policy = _policy_from_kwargs(policy, method, heuristic, t, tl, l_pad,
                                 True, tunedb)
    r = policy.resolve(a)
    return r.method, r.t, r.tl, r.l_pad


def build_plan(a: CSR, *, method: str = "auto",
               heuristic: Heuristic | None = None,
               t: int | None = None, tl: int | None = None,
               l_pad: int | None = None,
               with_transpose: bool = True, tunedb=None,
               policy=None, _resolved=None) -> SpmmPlan:
    """Build an SpmmPlan from a concrete CSR (once per sparsity pattern).

    The request — a ``PlanPolicy`` or the equivalent explicit kwargs —
    resolves through ``PlanPolicy.resolve`` (TuneDB ladder, registry cost
    hooks, per-method parameter validation), a static decision captured in
    the plan so execution never host-syncs on it.  The plan structure
    itself comes from the resolved method's registered ``build_structure``
    hook.  ``with_transpose`` additionally builds the CSC-view merge plan
    that powers the ``dB`` backward pass; forward-only callers can skip it.
    """
    from repro.kernels import registry

    merge_k, _ = _kernels()
    _require_concrete(a, "build_plan")
    policy = _policy_from_kwargs(policy, method, heuristic, t, tl, l_pad,
                                 with_transpose, tunedb)
    # ``_resolved``: a ResolvedPlan the caller (the engine cache) already
    # computed for this exact request — skips re-running the ladder and
    # per-method derivation (e.g. rowgroup's host-side bucketing).
    r = _resolved if _resolved is not None else policy.resolve(a)
    meta = PlanMeta(method=r.method, shape=a.shape, nnz_pad=a.nnz_pad,
                    t=r.t, tl=r.tl, l_pad=r.l_pad,
                    has_transpose=policy.with_transpose, extra=r.extra)
    fwd = dict(registry.get_method(r.method).build_structure(a, meta))

    # Per-nonzero coordinates for the SDDMM values-cotangent (in-bounds
    # everywhere; validity carried separately).
    rp = np.asarray(a.row_ptr)
    nnz = int(rp[-1])
    nnz_pad = a.nnz_pad
    nz_rows = np.zeros(nnz_pad, np.int32)
    nz_rows[:nnz] = np.repeat(np.arange(a.m, dtype=np.int32), np.diff(rp))
    fwd["nz_rows"] = jnp.asarray(nz_rows)
    fwd["nz_cols"] = a.col_ind
    fwd["nz_valid"] = jnp.asarray(np.arange(nnz_pad) < nnz)

    bwd = None
    if policy.with_transpose:
        a_t, perm = transpose_pattern(a)
        # The backward dB = Aᵀ @ dC always runs merge-based: equal-nonzero
        # balancing on the CSC view, independent of the forward method.
        bwd = dict(merge_k.plan_merge_structure(a_t, t=r.t))
        # Backward slots index *original* vals: compose chunk slots with the
        # transpose permutation once, at build time.
        bwd["slot_nz"] = _compose_slots(bwd["slot_nz"], perm, nnz_pad)
    plan = SpmmPlan(fwd=fwd, bwd=bwd, meta=meta)
    if _verify_flags.verify_plans:
        # Opt-in debug hook (REPRO_VERIFY_PLANS=1): full host-side
        # structural verification of the freshly built plan.  One module
        # attribute read when off — the obs gating pattern.
        from repro.analysis.planlint import check_plan
        check_plan(plan, a)
    return plan


_fingerprint_memo: dict = {}


def pattern_fingerprint(a: CSR) -> str:
    """Content hash of the sparsity pattern (not the values).

    Two CSR matrices with equal fingerprints (and shapes) share every plan
    — this is the engine cache key, so retraced/re-pruned models with the
    same mask reuse plans instead of replanning.

    Memoized per live CSR object (identity-checked via weakref), so the
    O(nnz) device→host hash is paid once per object, not per call — a
    serving loop that holds one CSR hits the plan cache in O(1).
    """
    import hashlib
    import weakref

    _require_concrete(a, "pattern_fingerprint")
    key = id(a)
    memo = _fingerprint_memo.get(key)
    if memo is not None and memo[0]() is a:
        return memo[1]
    h = hashlib.sha1()
    h.update(np.asarray(a.row_ptr).tobytes())
    h.update(np.asarray(a.col_ind).tobytes())
    fp = h.hexdigest()
    try:
        ref = weakref.ref(a, lambda _, k=key: _fingerprint_memo.pop(k, None))
    except TypeError:       # object not weakref-able: skip the memo
        return fp
    _fingerprint_memo[key] = (ref, fp)
    return fp
