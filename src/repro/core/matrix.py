"""``SparseMatrix``: the v1 user-facing sparse-matrix frontend.

A thin, pytree-registered wrapper pairing a CSR pattern+values with its
lazily attached execution plan:

    A = SparseMatrix.from_dense(w)            # or .from_csr(csr)
    C = A @ B                                 # plans via the engine cache
    A = A.plan(PlanPolicy(method="merge"))    # pin the plan explicitly
    C = jax.jit(lambda A, B: A @ B)(A, B)     # jit-safe once planned
    A2 = A.with_vals(new_vals)                # same pattern, same plan

``A @ B`` with a concrete, un-planned matrix resolves through the engine
cache (so repeated multiplies never replan); under jit the plan must be
attached beforehand — plans are host-side artifacts.  ``with_vals`` is
the sparse-fine-tuning parameterization: the pattern (and therefore the
plan) is frozen while values are the degrees of freedom, which is why the
plan survives the value swap.
"""
from __future__ import annotations

import dataclasses

import jax

from .config import ExecutionConfig, PlanPolicy
from .csr import CSR, from_dense as _csr_from_dense, prune_to_csr
from .plan import SpmmPlan
from .spmm import _is_traced, execute_plan


@dataclasses.dataclass(frozen=True)
class SparseMatrix:
    """CSR pattern + values + (lazily attached) execution plan."""

    data: CSR
    spmm_plan: SpmmPlan | None = None

    def __post_init__(self):
        p = self.spmm_plan
        if p is not None and (p.meta.shape != self.data.shape or
                              p.meta.nnz_pad != self.data.nnz_pad):
            raise ValueError(
                f"plan was built for pattern {p.meta.shape} "
                f"(nnz_pad={p.meta.nnz_pad}) but the matrix is "
                f"{self.data.shape} (nnz_pad={self.data.nnz_pad})")

    # ------------------------------------------------------ constructors ---

    @classmethod
    def from_csr(cls, csr: CSR,
                 policy: PlanPolicy | None = None) -> "SparseMatrix":
        """Wrap a CSR; with ``policy`` given, attach its plan eagerly."""
        mtx = cls(csr)
        return mtx.plan(policy) if policy is not None else mtx

    @classmethod
    def from_dense(cls, dense, nnz_pad: int | None = None,
                   policy: PlanPolicy | None = None) -> "SparseMatrix":
        return cls.from_csr(_csr_from_dense(dense, nnz_pad), policy)

    @classmethod
    def prune(cls, w, keep_fraction: float,
              policy: PlanPolicy | None = None) -> "SparseMatrix":
        """Magnitude-prune a dense weight (top ``keep_fraction`` per row)."""
        return cls.from_csr(prune_to_csr(w, keep_fraction), policy)

    # ----------------------------------------------------------- pattern ---

    @property
    def shape(self):
        return self.data.shape

    @property
    def m(self) -> int:
        return self.data.m

    @property
    def k(self) -> int:
        return self.data.k

    @property
    def vals(self) -> jax.Array:
        return self.data.vals

    @property
    def dtype(self):
        return self.data.dtype

    @property
    def nnz_pad(self) -> int:
        return self.data.nnz_pad

    def nnz(self):
        return self.data.nnz()

    @property
    def method(self) -> str | None:
        """The planned kernel method, or None while un-planned."""
        return self.spmm_plan.meta.method if self.spmm_plan else None

    def to_dense(self) -> jax.Array:
        return self.data.to_dense()

    # ------------------------------------------------------------- plans ---

    def plan(self, policy: PlanPolicy | None = None) -> "SparseMatrix":
        """Attach the engine-cached plan for this pattern (host-side).

        Identity-cheap when the pattern's plan is already cached; the
        returned matrix is jit-safe (``A @ B`` under trace executes the
        attached plan and never replans).
        """
        from repro.engine import get_plan
        return dataclasses.replace(
            self, spmm_plan=get_plan(self.data,
                                     policy=policy or PlanPolicy()))

    def plan_like(self, meta) -> "SparseMatrix":
        """Re-plan replaying an existing plan's full statics.

        Preserves the method *and* tuned parameters (a TuneDB-tuned
        ``l_pad`` survives a checkpoint restore).  If a pattern-derived
        parameter no longer fits this matrix's pattern — pattern surgery
        lengthened a row past the old pad — fall back to the method
        alone and re-derive the rest, as a fresh plan request would.
        """
        if hasattr(meta, "local_metas"):   # sharded plan: replay the layout
            from .config import ShardSpec
            spec = ShardSpec(n=meta.n_shards, dim=meta.dim, axis=meta.axis,
                             mesh=meta.mesh)
            if meta.uniform:
                lm = meta.local_metas[0]
                try:
                    return self.plan(PlanPolicy(
                        method=lm.method, t=lm.t, tl=lm.tl, l_pad=lm.l_pad,
                        with_transpose=lm.has_transpose, shards=spec))
                except ValueError:
                    pass
            return self.plan(PlanPolicy(
                shards=spec, with_transpose=meta.has_transpose))
        try:
            return self.plan(PlanPolicy.from_meta(meta))
        except ValueError:
            return self.plan(PlanPolicy(
                method=meta.method, with_transpose=meta.has_transpose))

    def shard(self, mesh=None, *, n: int | None = None,
              dim: str = "rows", axis: str | None = None,
              policy: PlanPolicy | None = None) -> "SparseMatrix":
        """Attach a device-sharded plan: nnz-balanced shards, one local
        plan per shard (``repro.distributed.spmm``).

        ``mesh`` (a ``jax.sharding.Mesh``) makes uniform-method plans
        execute as a single ``shard_map`` program over ``axis``
        (``"data"`` for row shards, ``"model"`` for the tensor-parallel
        column shards); without one, ``n`` logical shards execute as a
        per-shard loop — numerically identical.  ``policy`` pins the
        per-shard plan requests (method, params, TuneDB); each shard
        still resolves "auto" against its own local stats.
        """
        from .config import ShardSpec
        spec = ShardSpec(n=n, dim=dim, axis=axis, mesh=mesh)
        base = policy if policy is not None else PlanPolicy()
        if base.shards is not None:
            raise ValueError(
                "SparseMatrix.shard: pass the shard layout via "
                "mesh/n/dim/axis, not inside policy.shards — the two "
                "spellings cannot be mixed")
        return self.plan(dataclasses.replace(base, shards=spec))

    def with_vals(self, vals: jax.Array) -> "SparseMatrix":
        """Rebind values onto the frozen pattern — the plan survives."""
        return dataclasses.replace(
            self, data=dataclasses.replace(self.data, vals=vals))

    # --------------------------------------------------------- execution ---

    def matmul(self, b: jax.Array, exec: ExecutionConfig | None = None,
               *, bias: jax.Array | None = None,
               residual: jax.Array | None = None, **legacy) -> jax.Array:
        """C = A @ B (``b`` (..., k, n) → (..., m, n)), differentiable.

        ``bias``/``residual`` feed the fused epilogue (flags in
        ``exec.epilogue``; auto-derived when unset — see
        ``core.spmm.execute_plan``).  ``legacy`` forwards pre-v1
        ``impl``/``interpret``/``tk`` kwargs to the ``execute_plan``
        deprecation shims.
        """
        plan = self.spmm_plan
        if plan is None:
            if _is_traced(self.data):
                raise ValueError(
                    "A @ B under jit needs the plan attached beforehand: "
                    "call A = A.plan() (or engine.get_plan) outside jit — "
                    "SparseMatrix is a pytree, so the planned matrix "
                    "passes through jit boundaries unchanged.")
            from repro.engine import get_plan
            plan = get_plan(self.data)
        if not isinstance(plan, SpmmPlan):     # device-sharded plan
            from repro.distributed.spmm import execute_sharded
            return execute_sharded(plan, self.data.vals, b, exec, bias=bias,
                                   residual=residual, **legacy)
        return execute_plan(plan, self.data.vals, b, exec, bias=bias,
                            residual=residual, **legacy)

    def __matmul__(self, b) -> jax.Array:
        return self.matmul(b)


def _unflatten(aux, children):
    # Bypass __post_init__: transformations may unflatten with placeholder
    # leaves that carry no shape metadata.
    sm = object.__new__(SparseMatrix)
    object.__setattr__(sm, "data", children[0])
    object.__setattr__(sm, "spmm_plan", children[1])
    return sm


jax.tree_util.register_pytree_node(
    SparseMatrix,
    lambda sm: ((sm.data, sm.spmm_plan), ()),
    _unflatten,
)
