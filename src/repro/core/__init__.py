from .config import (DEFAULT_TUNEDB, ExecutionConfig, PlanPolicy,
                     ResolvedPlan, ShardSpec)
from .csr import CSR, from_dense, prune_to_csr, random_csr
from .epilogue import Epilogue, apply_epilogue
from .heuristic import Heuristic, PAPER_THRESHOLD, calibrate
from .matrix import SparseMatrix
from .partition import chunk_segments, partition_spmm
from .plan import PlanMeta, SpmmPlan, build_plan, pattern_fingerprint
from .spmm import execute_plan, spmm

__all__ = [
    "DEFAULT_TUNEDB", "ExecutionConfig", "PlanPolicy", "ResolvedPlan",
    "ShardSpec",
    "CSR", "from_dense", "prune_to_csr", "random_csr",
    "Epilogue", "apply_epilogue",
    "Heuristic", "PAPER_THRESHOLD", "calibrate",
    "SparseMatrix",
    "chunk_segments", "partition_spmm",
    "PlanMeta", "SpmmPlan", "build_plan", "pattern_fingerprint",
    "execute_plan", "spmm",
]
