from .csr import CSR, from_dense, prune_to_csr, random_csr
from .heuristic import Heuristic, PAPER_THRESHOLD, calibrate
from .partition import chunk_segments, partition_spmm
from .plan import PlanMeta, SpmmPlan, build_plan, pattern_fingerprint
from .spmm import execute_plan, spmm

__all__ = [
    "CSR", "from_dense", "prune_to_csr", "random_csr",
    "Heuristic", "PAPER_THRESHOLD", "calibrate",
    "chunk_segments", "partition_spmm",
    "PlanMeta", "SpmmPlan", "build_plan", "pattern_fingerprint",
    "execute_plan", "spmm",
]
