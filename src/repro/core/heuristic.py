"""The paper's O(1) kernel-selection heuristic (§5.4).

``d = nnz / m`` (mean row length); ``d < threshold → merge-based`` else
row-split.  The paper calibrates threshold = 9.35 on a K40c with 99.3%
accuracy vs. an oracle; the crossover is backend-dependent, so the threshold
is a parameter and ``benchmarks/bench_fig6_heuristic.py`` recalibrates it
for this backend and reports accuracy the same way.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .csr import CSR

PAPER_THRESHOLD = 9.35


@dataclasses.dataclass(frozen=True)
class Heuristic:
    threshold: float = PAPER_THRESHOLD

    def mean_row_length(self, a: CSR) -> float:
        # Host-side: method choice is static (selects which kernel to trace).
        self._require_concrete(a)
        nnz = int(np.asarray(a.row_ptr)[-1])
        return nnz / max(a.m, 1)

    def choose(self, a: CSR) -> str:
        """Return 'merge' or 'rowsplit' per the paper's rule.

        A *static* decision: it selects which kernel gets traced, so it
        must see a concrete ``row_ptr``.  Inside jitted code the decision
        is already captured in the ``SpmmPlan`` built at plan time
        (``repro.engine.get_plan``) — never call this per step.
        """
        self._require_concrete(a)
        return "merge" if self.mean_row_length(a) < self.threshold \
            else "rowsplit"

    @staticmethod
    def _require_concrete(a: CSR) -> None:
        import jax

        # Either structure array being traced means the pattern is traced
        # (matches core.spmm._is_traced): vmapped/scanned CSRs can carry a
        # concrete row_ptr next to a traced col_ind.
        if isinstance(a.row_ptr, jax.core.Tracer) or \
                isinstance(a.col_ind, jax.core.Tracer):
            raise ValueError(
                "Heuristic.choose is a static (host-side) decision and "
                "cannot run on a traced CSR. Capture it once at plan-build "
                "time: plan = repro.engine.get_plan(a) outside jit, then "
                "pass the plan (or the resolved method) into jitted code.")


def calibrate(ds: np.ndarray, rowsplit_us: np.ndarray,
              merge_us: np.ndarray) -> tuple[float, float]:
    """Fit the threshold from measured timings.

    Sweeps candidate thresholds over the observed ``d`` values and returns
    ``(best_threshold, accuracy)`` where accuracy is agreement with the
    oracle (pick-the-faster), mirroring the paper's 99.3% metric.
    """
    ds = np.asarray(ds, dtype=np.float64)
    oracle_merge = np.asarray(merge_us) < np.asarray(rowsplit_us)
    cands = np.unique(np.concatenate([ds, ds + 1e-9, [0.0, np.inf]]))
    best_thr, best_acc = 0.0, -1.0
    for thr in cands:
        pred_merge = ds < thr
        acc = float(np.mean(pred_merge == oracle_merge))
        if acc > best_acc:
            best_thr, best_acc = float(thr), acc
    return best_thr, best_acc
