"""Fused epilogue spec: what happens to C between accumulator and HBM.

The paper's design principle is minimizing global-memory round-trips; an
unfused serving path violates it right after the kernel returns — the
``(tokens, d_ff)`` SpMM output is written to HBM only to be immediately
re-read for bias + GELU.  An :class:`Epilogue` describes that tail as a
*static, hashable* spec so the kernels can apply it at accumulator-flush
time (one pass over C instead of three) and the XLA refs can apply
bit-identical math:

    y = act(C + bias) * scale + residual

with each stage optional.  The spec carries only *flags and constants*;
the operand arrays (``bias (m,)``, ``residual (..., m, n)``) travel as
ordinary call arguments so the spec stays jit-static and usable in
``lru_cache`` keys.

:func:`apply_epilogue` is the single implementation of the math — the
Pallas kernels, the XLA refs, the sharded post-assembly path, and the
test oracles all call it, so "fused" and "unfused" can never disagree on
semantics (gelu is ``jax.nn.gelu`` with its default tanh approximation
everywhere).
"""
from __future__ import annotations

import dataclasses

_ACTIVATIONS = ("none", "relu", "gelu")


@dataclasses.dataclass(frozen=True)
class Epilogue:
    """A fused C-tail: ``y = act(C + bias) * scale + residual``.

    ``bias``/``residual`` are *flags* — the arrays ride as call arguments
    (``execute_plan(..., bias=..., residual=...)``) and must be present
    exactly when the flag is set.  ``activation`` is one of ``"none"`` |
    ``"relu"`` | ``"gelu"``; ``scale`` is a static float (``None`` = 1).
    Frozen and hashable: an Epilogue is part of the jit static signature
    and the registry's op-cache key, like every other static decision.
    """

    bias: bool = False
    activation: str = "none"
    residual: bool = False
    scale: float | None = None

    def __post_init__(self):
        if self.activation not in _ACTIVATIONS:
            raise ValueError(
                f"Epilogue.activation must be one of {_ACTIVATIONS}, got "
                f"{self.activation!r}")
        if self.scale is not None:
            object.__setattr__(self, "scale", float(self.scale))

    def is_identity(self) -> bool:
        """True iff this epilogue changes nothing (drop it entirely)."""
        return (not self.bias and self.activation == "none"
                and not self.residual and self.scale is None)


def activation_fn(name: str):
    """The activation callable — one definition for kernels, refs, and
    oracles (``gelu`` is ``jax.nn.gelu``'s default tanh approximation)."""
    import jax

    if name == "relu":
        return jax.nn.relu
    if name == "gelu":
        return jax.nn.gelu
    raise ValueError(f"unknown epilogue activation {name!r}")


def apply_epilogue(c, ep: Epilogue | None, bias=None, residual=None):
    """Apply ``ep`` to an accumulator array *in its dtype*.

    ``c`` is ``(..., m, n)`` (or a kernel's ``(tm, tn)`` tile);  ``bias``
    must already be broadcastable against it (callers reshape ``(m,)`` →
    ``(..., m, 1)`` / a tile's ``(tm, 1)``), ``residual`` likewise.
    Operands are cast to ``c``'s dtype, so calling on the f32 accumulator
    applies the whole tail in accumulation precision before the single
    cast to the output dtype.
    """
    import jax.numpy as jnp

    if ep is None:
        return c
    if ep.bias:
        c = c + bias.astype(c.dtype)
    if ep.activation != "none":
        c = activation_fn(ep.activation)(c)
    if ep.scale is not None:
        c = c * jnp.asarray(ep.scale, c.dtype)
    if ep.residual:
        c = c + residual.astype(c.dtype)
    return c
