"""Static bytes-moved analyzer + baseline regression gate.

The paper's verdict criterion is distance to the memory-bandwidth roof,
so the quantity to protect in review is *bytes moved*.  This module
computes, without running anything, the HBM traffic of every registered
``MethodSpec`` × impl × dtype/epilogue variant × {fwd, bwd} on the
audit's representative problem, and holds it against the compulsory
floor (``repro.obs.roofline``):

* ``impl="pallas"`` — the transition-counted DMA model of the kernel's
  launch models (``MethodSpec.traffic`` → ``repro.kernels.introspect``):
  a block fetch is counted only when its index map's value changes
  between consecutive grid steps (Mosaic elides unchanged-index
  copies).  The backward adds the transpose-merge dB launch (over
  ``plan.bwd``) and the SDDMM dvals launch.
* ``impl="xla"`` — the parsed post-optimization HLO of the jitted
  program (``repro.analysis.hlo``), with the plan arrays passed as
  parameters so plan reads are visible.  The backward is the full
  fwd+vjp program.

Diagnostics (all bidirectionally loud, like the K-codes):

* **T010** — static bytes exceed the compulsory floor by more than the
  per-(method, impl, pass) tolerance calibrated at HEAD: a hidden copy,
  a widened materialization, or a tiling regression.
* **T011** — more ``transpose`` ops in the traced program than the
  calibrated allowance: an unexpected layout flip.
* **T012** — more floating-widening ``convert_element_type`` bytes than
  the allowance: a silent bf16→f32 materialization at HBM level
  (in-kernel VMEM converts inside ``pallas_call`` are free and not
  counted).
* **T020/T021/T022** — the baseline gate: current bytes grew beyond the
  committed ``artifacts/traffic_baseline.json`` (+2% slack), a variant
  is missing from the baseline (or the backend has none), or the
  baseline carries a stale variant.

``python -m repro.analysis traffic --check`` runs the gate in CI;
``make traffic-baseline`` regenerates the baseline after an intentional
traffic change.
"""
from __future__ import annotations

import dataclasses
import json
import os
import types

from .diagnostics import Diagnostic

SCHEMA_VERSION = 1
BASELINE_PATH = os.path.join("artifacts", "traffic_baseline.json")
IMPLS = ("pallas", "xla")
PASSES = ("fwd", "bwd")
#: baseline growth slack (T020): fractional headroom for harmless
#: lowering jitter before a growth is a finding.
BASELINE_SLACK = 0.02


def _variants():
    """The full dtype × epilogue grid the analyzer sweeps (a superset of
    the kernel audit's two corners)."""
    from repro.core.epilogue import Epilogue

    from .kernel_audit import Variant
    epi = Epilogue(bias=True, activation="gelu", residual=True)
    return (
        Variant("f32", "float32", "float32", "float32", None, None),
        Variant("f32+epi", "float32", "float32", "float32", None, epi),
        Variant("bf16_acc32", "bfloat16", "bfloat16", "float32",
                "bfloat16", None),
        Variant("bf16_acc32+epi", "bfloat16", "bfloat16", "float32",
                "bfloat16", epi),
    )


@dataclasses.dataclass(frozen=True)
class TrafficRow:
    """One analyzed program: method × impl × variant × pass."""

    method: str
    impl: str
    variant: str
    pass_: str                  # "fwd" | "bwd"
    bytes: int
    min_bytes: int
    transposes: int
    widen_bytes: int

    @property
    def key(self) -> str:
        return f"{self.method}/{self.impl}/{self.variant}/{self.pass_}"

    @property
    def ratio(self) -> float:
        return self.bytes / self.min_bytes if self.min_bytes else 0.0

    def to_dict(self) -> dict:
        return {"method": self.method, "impl": self.impl,
                "variant": self.variant, "pass": self.pass_,
                "bytes": self.bytes, "min_bytes": self.min_bytes,
                "transposes": self.transposes,
                "widen_bytes": self.widen_bytes}


# ------------------------------------------------------------ calibration ---

# Per-(method, impl, pass) ceilings on bytes/min_bytes, calibrated at
# HEAD on the fixed representative problem (kernel_audit's
# _representative: PRNGKey(0), m=48, k=192, nnz_per_row=(1, 23),
# n=256, batch=2, tk=64): the worst variant's ratio with ~25% headroom.
# The merge kernel re-streams the B panel once per (chunk, k-tile)
# pair, so its pallas DMA bytes sit well above the compulsory floor by
# design — the tolerance pins today's re-streaming factor so any
# *further* growth (an extra copy, a lost block-index elision) still
# fires.  The XLA bwd numbers are dominated by the parser's
# trip-count-scaled accounting of the ref merge's chunk scan (the
# carried state is re-read every trip), hence the large pinned ratios
# there; the 2%-slack baseline gate (T020) is the precision instrument
# on top of this structural floor.
_TOLERANCE = {
    ("merge", "pallas", "fwd"): 44.0,
    ("merge", "pallas", "bwd"): 18.0,
    ("merge", "xla", "fwd"): 5900.0,
    ("merge", "xla", "bwd"): 5600.0,
    ("rowsplit", "pallas", "fwd"): 13.0,
    ("rowsplit", "pallas", "bwd"): 7.0,
    ("rowsplit", "xla", "fwd"): 41.0,
    ("rowsplit", "xla", "bwd"): 4200.0,
    ("rowgroup", "pallas", "fwd"): 12.0,
    ("rowgroup", "pallas", "bwd"): 7.0,
    ("rowgroup", "xla", "fwd"): 59.0,
    ("rowgroup", "xla", "bwd"): 4200.0,
}
_DEFAULT_TOLERANCE = 6.0

# transpose-op allowances per (method, impl, pass): zero everywhere at
# HEAD — even the dB path reaches the CSC view through the precomputed
# plan.bwd structure, never a runtime transpose.  Any transpose is T011.
_TRANSPOSE_ALLOW = {}
_DEFAULT_TRANSPOSE = 0

# floating-widening convert bytes per (method, impl, pass): exact HEAD
# maxima over the variants (widen bytes are deterministic, so no
# headroom).  Every bwd carries the dc.astype(f32) cotangent cast
# (batch*m*n*4 = 98,304 here; +residual cotangent with the epilogue);
# the XLA ref casts gathered operands to the accumulator dtype, so
# bf16 xla variants carry real widen bytes; rowgroup's fused-epilogue
# fwd un-groups in f32 before the output cast.
_WIDEN_ALLOW = {
    ("merge", "pallas", "fwd"): 0,
    ("merge", "pallas", "bwd"): 196_608,
    ("merge", "xla", "fwd"): 248_768,
    ("merge", "xla", "bwd"): 1_013_568,
    ("rowsplit", "pallas", "fwd"): 0,
    ("rowsplit", "pallas", "bwd"): 196_608,
    ("rowsplit", "xla", "fwd"): 252_096,
    ("rowsplit", "xla", "bwd"): 1_016_896,
    ("rowgroup", "pallas", "fwd"): 98_304,
    ("rowgroup", "pallas", "bwd"): 196_608,
    ("rowgroup", "xla", "fwd"): 1_283_776,
    ("rowgroup", "xla", "bwd"): 1_999_424,
}
_DEFAULT_WIDEN = 0


# -------------------------------------------------------- program tracing ---


def _operands(plan, var, n, batch):
    import jax.numpy as jnp
    meta = plan.meta
    ep = var.epilogue
    vals = jnp.zeros((meta.nnz_pad,), var.vals_dtype)
    b = jnp.zeros((batch, meta.k, n), var.b_dtype)
    bias = jnp.zeros((meta.m,), var.b_dtype) \
        if ep is not None and ep.bias else None
    residual = jnp.zeros((batch, meta.m, n), var.b_dtype) \
        if ep is not None and ep.residual else None
    return vals, b, bias, residual


def _make_program(plan, var, impl, pass_, n, batch, tk):
    """The traced program of one row: ``fn(*args)`` with the plan arrays
    as pytree-leaf parameters (so plan reads are HLO parameters, not
    baked-in constants) — fwd executes the plan, bwd is fwd + the full
    custom-VJP pullback over every differentiable operand."""
    import jax

    from repro.core.config import ExecutionConfig
    from repro.core.spmm import execute_plan

    cfg = ExecutionConfig(impl=impl, interpret=True, tk=tk,
                          epilogue=var.epilogue, acc_dtype=var.acc_dtype,
                          out_dtype=var.out_dtype)
    leaves, treedef = jax.tree.flatten(plan)
    vals, b, bias, residual = _operands(plan, var, n, batch)
    has_bias = bias is not None
    has_res = residual is not None
    prims = tuple(x for x in (vals, b, bias, residual) if x is not None)

    def call(p, prims2):
        it = iter(prims2)
        v, bb = next(it), next(it)
        bi = next(it) if has_bias else None
        r = next(it) if has_res else None
        return execute_plan(p, v, bb, cfg, bias=bi, residual=r)

    if pass_ == "fwd":
        def fn(leaves, *prims2):
            p = jax.tree.unflatten(treedef, leaves)
            return call(p, prims2)
        return fn, (leaves, *prims)

    out = jax.eval_shape(lambda *pr: call(plan, pr), *prims)
    dc = jax.numpy.zeros(out.shape, out.dtype)

    def fn(leaves, dc, *prims2):
        p = jax.tree.unflatten(treedef, leaves)
        _, vjp = jax.vjp(lambda *pr: call(p, pr), *prims2)
        return vjp(dc)
    return fn, (leaves, dc, *prims)


def _subjaxprs(v):
    from .kernel_audit import _subjaxprs as sub
    return sub(v)


def _jaxpr_stats(jaxpr):
    """(transpose count, floating-widening convert bytes) of the outer
    graph — recursion stops at ``pallas_call`` (in-kernel VMEM converts
    never touch HBM)."""
    import jax.numpy as jnp
    import numpy as np
    transposes = 0
    widen = 0

    def visit(jx):
        nonlocal transposes, widen
        for eqn in jx.eqns:
            name = eqn.primitive.name
            if name == "transpose":
                transposes += 1
            elif name == "convert_element_type":
                iav = eqn.invars[0].aval
                oav = eqn.outvars[0].aval
                if (hasattr(iav, "dtype")
                        and jnp.issubdtype(iav.dtype, jnp.floating)
                        and jnp.dtype(oav.dtype).itemsize
                        > jnp.dtype(iav.dtype).itemsize):
                    widen += (int(np.prod(oav.shape))
                              * jnp.dtype(oav.dtype).itemsize)
            if name == "pallas_call":
                continue
            for v in eqn.params.values():
                for sub in _subjaxprs(v):
                    visit(sub)

    visit(jaxpr)
    return transposes, widen


# ------------------------------------------------------------ bytes models ---


def _pallas_bytes(spec, plan, var, pass_, n, batch, tk):
    """Transition-counted DMA bytes of the launch models; the backward
    adds the transpose-merge dB launch and the SDDMM dvals launch."""
    from repro.kernels import merge_spmm as _merge
    from repro.kernels import sddmm as _sddmm

    from .kernel_audit import Variant

    total = sum(m.hbm_bytes()
                for m in spec.traffic(plan, n, batch, var, tk))
    if pass_ == "fwd":
        return total
    meta = plan.meta
    # dB = Aᵀ @ g through the transpose-merge plan: B-operand is the f32
    # cotangent, the output flushes f32 before the cast back to B's dtype.
    meta_t = dataclasses.replace(meta, shape=(meta.k, meta.m))
    shim = types.SimpleNamespace(meta=meta_t, fwd=plan.bwd)
    db_var = Variant("db", var.vals_dtype, "float32", "float32",
                     "float32", None)
    total += sum(m.hbm_bytes()
                 for m in _merge.launch_models(shim, n, batch, db_var, tk))
    total += sum(m.hbm_bytes() for m in _sddmm.launch_models(
        nnz_pad=meta.nnz_pad, m=meta.m, k=meta.k, n=n, batch=batch,
        dc_dtype="float32", b_dtype=var.b_dtype))
    return total


def _min_bytes(meta, var, pass_, n, batch):
    from repro.obs.roofline import plan_bwd_min_bytes, plan_min_bytes
    total = plan_min_bytes(meta, n, val_dtype=var.vals_dtype,
                           out_dtype=var.out_dtype, batch=batch,
                           epilogue=var.epilogue, b_dtype=var.b_dtype)
    if pass_ == "bwd":
        total += plan_bwd_min_bytes(meta, n, val_dtype=var.vals_dtype,
                                    b_dtype=var.b_dtype, batch=batch)
    return total


# --------------------------------------------------------------- analysis ---


def analyze_variant(spec, plan, var, impl, pass_, *, n: int = 256,
                    batch: int = 2, tk: int | None = 64) -> TrafficRow:
    """One row: trace the program for jaxpr stats, model its bytes."""
    import jax

    from . import hlo

    fn, args = _make_program(plan, var, impl, pass_, n, batch, tk)
    jaxpr = jax.make_jaxpr(fn)(*args)
    transposes, widen = _jaxpr_stats(jaxpr.jaxpr)
    if impl == "pallas":
        nbytes = int(_pallas_bytes(spec, plan, var, pass_, n, batch, tk))
    else:
        nbytes = int(hlo.parse_compiled(fn, *args)["hbm_bytes"])
    return TrafficRow(
        method=spec.name, impl=impl, variant=var.name, pass_=pass_,
        bytes=nbytes,
        min_bytes=int(_min_bytes(plan.meta, var, pass_, n, batch)),
        transposes=transposes, widen_bytes=widen)


def _check_row(row: TrafficRow) -> list[Diagnostic]:
    diags = []
    k = (row.method, row.impl, row.pass_)
    tol = _TOLERANCE.get(k, _DEFAULT_TOLERANCE)
    if row.min_bytes and row.bytes > row.min_bytes * tol:
        diags.append(Diagnostic(
            "T010", row.key,
            f"static bytes {row.bytes:,} exceed the compulsory floor "
            f"{row.min_bytes:,} by {row.ratio:.1f}x (tolerance {tol}x) "
            "— hidden copy, widened materialization, or tiling "
            "regression"))
    allow_t = _TRANSPOSE_ALLOW.get(k, _DEFAULT_TRANSPOSE)
    if row.transposes > allow_t:
        diags.append(Diagnostic(
            "T011", row.key,
            f"{row.transposes} transpose op(s) in the traced program "
            f"(allowance {allow_t}) — unexpected layout flip"))
    allow_w = _WIDEN_ALLOW.get(k, _DEFAULT_WIDEN)
    if row.widen_bytes > allow_w:
        diags.append(Diagnostic(
            "T012", row.key,
            f"{row.widen_bytes:,} floating-widening convert bytes "
            f"(allowance {allow_w:,}) — silent low-precision operand "
            "materialized wide at HBM level"))
    return diags


def analyze_all(*, n: int = 256, batch: int = 2, tk: int | None = 64):
    """Every method × impl × variant × pass on the representative
    problem; returns ``(rows, diagnostics)``.  Methods without a
    ``traffic`` hook are skipped here — ``access.check_coverage``
    reports them (T101), keeping the gap loud exactly once."""
    from repro.core.plan import build_plan
    from repro.kernels import registry

    from .kernel_audit import _representative

    rows, diags = [], []
    a = _representative()
    for name in registry.method_names():
        spec = registry.get_method(name)
        if spec.traffic is None:
            continue
        plan = build_plan(a, method=name, with_transpose=True)
        for var in _variants():
            for impl in IMPLS:
                for pass_ in PASSES:
                    row = analyze_variant(spec, plan, var, impl, pass_,
                                          n=n, batch=batch, tk=tk)
                    rows.append(row)
                    diags.extend(_check_row(row))
    return rows, diags


# ---------------------------------------------------------------- baseline ---


def _backend() -> str:
    import jax
    return jax.default_backend()


def load_baseline(path: str = BASELINE_PATH) -> dict:
    if not os.path.exists(path):
        return {"schema": SCHEMA_VERSION, "backends": {}}
    with open(path, encoding="utf-8") as f:
        data = json.load(f)
    if data.get("schema") != SCHEMA_VERSION:
        raise ValueError(
            f"traffic baseline {path} has schema "
            f"{data.get('schema')!r}, expected {SCHEMA_VERSION} — "
            "regenerate with `make traffic-baseline`")
    return data


def update_baseline(rows, path: str = BASELINE_PATH,
                    backend: str | None = None) -> dict:
    """Write the current rows as this backend's baseline (other
    backends' entries are preserved, like the TuneDB)."""
    backend = backend or _backend()
    data = load_baseline(path) if os.path.exists(path) else \
        {"schema": SCHEMA_VERSION, "backends": {}}
    data["backends"][backend] = {
        "rows": {r.key: {"bytes": r.bytes, "min_bytes": r.min_bytes,
                         "transposes": r.transposes,
                         "widen_bytes": r.widen_bytes}
                 for r in sorted(rows, key=lambda r: r.key)}}
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w", encoding="utf-8") as f:
        json.dump(data, f, indent=1, sort_keys=True)
        f.write("\n")
    return data


def check_baseline(rows, data: dict, backend: str | None = None, *,
                   slack: float = BASELINE_SLACK) -> list[Diagnostic]:
    """Diff current rows against the committed baseline: unexplained
    growth is T020, coverage gaps are T021, stale entries T022."""
    backend = backend or _backend()
    diags = []
    rec = data.get("backends", {}).get(backend)
    if rec is None:
        return [Diagnostic(
            "T021", f"baseline[{backend}]",
            f"no committed traffic baseline for backend {backend!r} — "
            "run `make traffic-baseline` and commit the result")]
    base = rec.get("rows", {})
    seen = set()
    for r in rows:
        seen.add(r.key)
        b = base.get(r.key)
        if b is None:
            diags.append(Diagnostic(
                "T021", r.key,
                "variant missing from the committed baseline — run "
                "`make traffic-baseline` and commit the diff"))
            continue
        ceiling = b["bytes"] * (1.0 + slack)
        if r.bytes > ceiling:
            diags.append(Diagnostic(
                "T020", r.key,
                f"static bytes grew {b['bytes']:,} -> {r.bytes:,} "
                f"(>{slack * 100:.0f}% slack) — if intentional, "
                "regenerate the baseline in the same commit"))
        if r.transposes > b.get("transposes", 0):
            diags.append(Diagnostic(
                "T020", r.key,
                f"transpose count grew {b.get('transposes', 0)} -> "
                f"{r.transposes}"))
        if r.widen_bytes > b.get("widen_bytes", 0):
            diags.append(Diagnostic(
                "T020", r.key,
                f"widening convert bytes grew "
                f"{b.get('widen_bytes', 0):,} -> {r.widen_bytes:,}"))
    for key in sorted(set(base) - seen):
        diags.append(Diagnostic(
            "T022", key,
            "baseline entry no longer produced by the analyzer (stale "
            "variant?) — regenerate the baseline"))
    return diags


# ------------------------------------------------------------------ report ---


def format_report(rows, diags) -> str:
    header = (f"{'method':<10} {'impl':<7} {'variant':<16} {'pass':<4} "
              f"{'bytes':>12} {'min':>12} {'x':>6} {'tr':>3} "
              f"{'widen':>10}")
    lines = ["static traffic report", header, "-" * len(header)]
    for r in rows:
        lines.append(
            f"{r.method:<10} {r.impl:<7} {r.variant:<16} {r.pass_:<4} "
            f"{r.bytes:>12,} {r.min_bytes:>12,} {r.ratio:>6.1f} "
            f"{r.transposes:>3} {r.widen_bytes:>10,}")
    if diags:
        lines.append("")
        lines.append(f"{len(diags)} finding(s):")
        lines.extend(f"  {d}" for d in diags)
    else:
        lines.append("no findings")
    return "\n".join(lines)
