"""Host-side structural verification of SpMM plans.

The paper's correctness argument is *structural*: the merge decomposition
is right because every nonzero is consumed exactly once and every output
tile is flushed exactly once — properties of the plan arrays, not of any
particular execution.  This module checks them host-side, before a
kernel ever launches:

* CSR sanity — ``row_ptr`` monotone/bounded, ``col_ind`` in range;
* slot coverage — across *all* ``slot_nz`` arrays of a structure (merge
  chunks, rowsplit ELL rows, rowgroup per-bucket blocks) each live
  nonzero id appears exactly once and every other slot holds the
  ``nnz_pad`` sentinel (which reads the appended zero — a slot aimed at
  the dead range ``[nnz, nnz_pad)`` would read stale padding instead);
* merge path — the chunk→tile stream is non-decreasing, visits every
  output row tile, and its ``first``/``last`` flags mark exactly the
  tile boundaries (the single-writer precondition of the kernel flush);
* rowsplit — the static ``l_pad`` bounds the true max row length and
  every ELL slot sits on its own row;
* rowgroup — ``extra``'s group table covers all rows and ``inv_pos`` is
  a valid inverse permutation;
* sharded plans — shard bounds tile the global rows/cols, the global
  value gather covers each nonzero exactly once across shards, per-shard
  metas are consistent with the cut, and the ``uniform`` flag is honest;
* every static (``PlanMeta``, ``extra``, ``ShardedMeta``) is hashable.

Entry points: :func:`verify_plan` / :func:`verify_sharded_plan` return
``Diagnostic`` lists (empty = clean); :func:`check_plan` raises
:class:`PlanVerificationError` on findings.  All checks run on host
numpy copies — safe to call on any concrete plan, never inside jit.

Wired as the opt-in debug hook behind ``REPRO_VERIFY_PLANS=1``
(``repro.analysis._flags``) in ``core.plan.build_plan``,
``engine.PlanCache.get`` and ``distributed.build_sharded_plan``.

Method-specific checkers live in :data:`STRUCTURE_CHECKS`; a new
registered method can add its own entry, and until it does, its plans
still get the generic CSR/coverage/meta checks.
"""
from __future__ import annotations

import numpy as np

from .diagnostics import Diagnostic, format_diagnostics

# Row-tile height shared by the kernels (merge lrow / ELL row padding).
_TM = 8


class PlanVerificationError(AssertionError):
    """A built plan violates a structural invariant (see .diagnostics)."""

    def __init__(self, diagnostics):
        self.diagnostics = tuple(diagnostics)
        super().__init__(format_diagnostics(
            self.diagnostics,
            header=f"plan verification failed "
                   f"({len(self.diagnostics)} finding(s)):"))


def _np(x) -> np.ndarray:
    return np.asarray(x)


def _head(ids, limit: int = 5) -> str:
    ids = list(ids[:limit + 1])
    if len(ids) > limit:
        return f"{ids[:limit]}…"
    return str(ids)


# ------------------------------------------------------------- CSR checks ---


def verify_csr(a, out: list | None = None, where: str = "csr") -> list:
    """P001/P002: ``row_ptr`` monotone and bounded, ``col_ind`` in range."""
    diags = [] if out is None else out
    rp = _np(a.row_ptr)
    m, k = a.shape
    if rp.shape != (m + 1,):
        diags.append(Diagnostic(
            "P001", f"{where}.row_ptr",
            f"expected shape ({m + 1},) for m={m}, got {rp.shape}"))
        return diags
    if rp[0] != 0:
        diags.append(Diagnostic(
            "P001", f"{where}.row_ptr", f"row_ptr[0] must be 0, got {rp[0]}"))
    drops = np.nonzero(np.diff(rp) < 0)[0]
    if drops.size:
        diags.append(Diagnostic(
            "P001", f"{where}.row_ptr",
            f"not non-decreasing at rows {_head(drops)}"))
    if rp[-1] > a.nnz_pad:
        diags.append(Diagnostic(
            "P001", f"{where}.row_ptr",
            f"nnz {rp[-1]} exceeds nnz_pad {a.nnz_pad}"))
    ci = _np(a.col_ind)
    if ci.shape != (a.nnz_pad,):
        diags.append(Diagnostic(
            "P002", f"{where}.col_ind",
            f"expected shape ({a.nnz_pad},), got {ci.shape}"))
        return diags
    nnz = max(int(rp[-1]), 0) if not diags else 0
    bad = np.nonzero((ci[:nnz] < 0) | (ci[:nnz] >= k))[0]
    if bad.size:
        diags.append(Diagnostic(
            "P002", f"{where}.col_ind",
            f"{bad.size} live column(s) outside [0, {k}) at "
            f"positions {_head(bad)}"))
    return diags


# ----------------------------------------------------- generic plan checks ---


def _check_hashable(obj, where: str, diags: list) -> None:
    try:
        hash(obj)
    except TypeError as e:
        diags.append(Diagnostic(
            "P010", where,
            f"static metadata must be hashable (jit constant / cache "
            f"key), but hashing raised: {e}"))


def _slot_arrays(fwd: dict) -> list[tuple[str, np.ndarray]]:
    """All ``slot_nz`` arrays of a structure, with their plan paths."""
    found = []
    if "slot_nz" in fwd:
        found.append(("fwd.slot_nz", _np(fwd["slot_nz"])))
    for g, grp in enumerate(fwd.get("groups", ())):
        if isinstance(grp, dict) and "slot_nz" in grp:
            found.append((f"fwd.groups[{g}].slot_nz", _np(grp["slot_nz"])))
    return found


def _check_coverage(slots, nnz: int, nnz_pad: int, where: str,
                    diags: list) -> None:
    """P020/P021/P022: each live nonzero in exactly one slot; everything
    else is the ``nnz_pad`` sentinel (never the dead range)."""
    ids = np.concatenate([s.reshape(-1) for _, s in slots]) if slots \
        else np.zeros(0, np.int64)
    oob = np.nonzero((ids < 0) | (ids > nnz_pad))[0]
    if oob.size:
        diags.append(Diagnostic(
            "P022", where,
            f"{oob.size} slot id(s) outside [0, nnz_pad={nnz_pad}]: "
            f"{_head(ids[oob])}"))
        ids = ids[(ids >= 0) & (ids <= nnz_pad)]
    dead = ids[(ids >= nnz) & (ids < nnz_pad)]
    if dead.size:
        diags.append(Diagnostic(
            "P022", where,
            f"{dead.size} slot(s) aim at the dead range [nnz={nnz}, "
            f"nnz_pad={nnz_pad}) — they would read stale padding instead "
            f"of the appended zero: ids {_head(np.unique(dead))}"))
    if nnz == 0:
        return
    counts = np.bincount(ids[ids < nnz], minlength=nnz)
    dup = np.nonzero(counts > 1)[0]
    if dup.size:
        diags.append(Diagnostic(
            "P020", where,
            f"{dup.size} nonzero id(s) covered more than once (values "
            f"would be double-counted): ids {_head(dup)}"))
    missing = np.nonzero(counts == 0)[0]
    if missing.size:
        diags.append(Diagnostic(
            "P021", where,
            f"{missing.size} nonzero id(s) never covered (values would "
            f"be dropped): ids {_head(missing)}"))


def _check_nz_arrays(fwd: dict, meta, a, diags: list) -> int | None:
    """P012: the SDDMM coordinate arrays; returns the live nnz count."""
    m, k = meta.shape
    nnz_pad = meta.nnz_pad
    for key in ("nz_rows", "nz_cols", "nz_valid"):
        if key not in fwd:
            diags.append(Diagnostic(
                "P012", f"plan.fwd.{key}", "coordinate array missing"))
            return None
    valid = _np(fwd["nz_valid"]).astype(bool)
    if valid.shape != (nnz_pad,):
        diags.append(Diagnostic(
            "P012", "plan.fwd.nz_valid",
            f"expected shape ({nnz_pad},), got {valid.shape}"))
        return None
    if valid.size and np.any(valid[:-1] < valid[1:]):
        diags.append(Diagnostic(
            "P012", "plan.fwd.nz_valid",
            "validity mask is not a prefix (CSR order packs live "
            "nonzeroes first)"))
    nnz = int(valid.sum())
    rows = _np(fwd["nz_rows"])
    cols = _np(fwd["nz_cols"])
    if m and np.any((rows[:nnz] < 0) | (rows[:nnz] >= m)):
        diags.append(Diagnostic(
            "P012", "plan.fwd.nz_rows", f"live row ids outside [0, {m})"))
    if k and np.any((cols[:nnz] < 0) | (cols[:nnz] >= k)):
        diags.append(Diagnostic(
            "P012", "plan.fwd.nz_cols", f"live col ids outside [0, {k})"))
    if a is not None:
        rp = _np(a.row_ptr)
        if int(rp[-1]) != nnz:
            diags.append(Diagnostic(
                "P012", "plan.fwd.nz_valid",
                f"live count {nnz} disagrees with the CSR's nnz "
                f"{int(rp[-1])}"))
        else:
            want_rows = np.repeat(np.arange(m, dtype=np.int64), np.diff(rp))
            if not np.array_equal(rows[:nnz], want_rows):
                diags.append(Diagnostic(
                    "P012", "plan.fwd.nz_rows",
                    "row ids disagree with the CSR row_ptr expansion"))
            if not np.array_equal(cols[:nnz], _np(a.col_ind)[:nnz]):
                diags.append(Diagnostic(
                    "P012", "plan.fwd.nz_cols",
                    "col ids disagree with the CSR col_ind"))
    return nnz


# ------------------------------------------------ method-specific checkers ---


def _check_merge_structure(s: dict, *, n_tiles: int, nnz: int,
                           rows_of_nz: np.ndarray, where: str,
                           diags: list, tm: int = _TM) -> None:
    """P030/P031/P032: the merge-path chunk stream."""
    need = ("cols", "lrow", "slot_nz", "tile", "first", "last")
    missing = [kk for kk in need if kk not in s]
    if missing:
        diags.append(Diagnostic(
            "P011", where, f"merge structure missing keys {missing}"))
        return
    tile = _np(s["tile"])
    first = _np(s["first"])
    last = _np(s["last"])
    c = tile.shape[0]
    for kk in ("cols", "lrow", "slot_nz"):
        if _np(s[kk]).ndim != 2 or _np(s[kk]).shape[0] != c:
            diags.append(Diagnostic(
                "P011", f"{where}.{kk}",
                f"expected (C={c}, t) chunk array, got {_np(s[kk]).shape}"))
            return
    if np.any((tile < 0) | (tile >= max(n_tiles, 1))):
        diags.append(Diagnostic(
            "P030", f"{where}.tile",
            f"chunk tiles outside [0, {n_tiles})"))
    drops = np.nonzero(np.diff(tile) < 0)[0]
    if drops.size:
        diags.append(Diagnostic(
            "P030", f"{where}.tile",
            f"tile stream decreases at chunks {_head(drops + 1)} — a "
            "revisited output tile would overwrite its earlier flush"))
    seen = np.unique(tile)
    if n_tiles and seen.size != n_tiles:
        missing_t = np.setdiff1d(np.arange(n_tiles), seen)
        diags.append(Diagnostic(
            "P031", f"{where}.tile",
            f"{missing_t.size} output tile(s) never visited (their C "
            f"rows would hold garbage): tiles {_head(missing_t)}"))
    want_first = np.concatenate([[1], (tile[1:] != tile[:-1]).astype(int)])
    want_last = np.concatenate([(tile[1:] != tile[:-1]).astype(int), [1]])
    if not np.array_equal(first, want_first):
        diags.append(Diagnostic(
            "P031", f"{where}.first",
            "first flags disagree with the tile boundaries (accumulator "
            "would not reset per tile)"))
    if not np.array_equal(last, want_last):
        diags.append(Diagnostic(
            "P031", f"{where}.last",
            "last flags disagree with the tile boundaries (flush would "
            "fire on the wrong chunk)"))
    slot = _np(s["slot_nz"])
    lrow = _np(s["lrow"])
    if np.any((lrow < 0) | (lrow >= tm)):
        diags.append(Diagnostic(
            "P032", f"{where}.lrow", f"row offsets outside [0, {tm})"))
        return
    live = slot < nnz
    if live.any() and rows_of_nz.size:
        want = tile[:, None] * tm + lrow         # (C, t) absolute rows
        got = rows_of_nz[np.where(live, slot, 0)]
        bad = live & (want != got)
        if bad.any():
            cc, ss = np.nonzero(bad)
            diags.append(Diagnostic(
                "P032", f"{where}.lrow",
                f"{int(bad.sum())} slot(s) scatter to the wrong output "
                f"row (chunk,slot) {_head(list(zip(cc, ss)))}"))


def _check_merge(plan, meta, nnz, diags) -> None:
    m = meta.m
    rows = _np(plan.fwd["nz_rows"]) if "nz_rows" in plan.fwd else \
        np.zeros(0, np.int64)
    _check_merge_structure(
        plan.fwd, n_tiles=-(-m // _TM) if m else 0, nnz=nnz,
        rows_of_nz=rows, where="plan.fwd", diags=diags)


def _ell_row_check(slot: np.ndarray, group_rows: np.ndarray, nnz: int,
                   rows_of_nz: np.ndarray, where: str, diags: list) -> None:
    """P041/P042: each live ELL slot sits on its own row; pad rows dead."""
    r = group_rows.shape[0]
    live = slot < nnz
    pad_live = live[r:]
    if pad_live.any():
        diags.append(Diagnostic(
            "P042", where,
            f"{int(pad_live.sum())} live slot(s) on tile-padding rows "
            f">= {r} (their contributions would be dropped)"))
    if not rows_of_nz.size:
        return
    body = live[:r]
    if body.any():
        got = rows_of_nz[np.where(body, slot[:r], 0)]
        want = np.broadcast_to(group_rows[:, None], body.shape)
        bad = body & (got != want)
        if bad.any():
            rr, ss = np.nonzero(bad)
            diags.append(Diagnostic(
                "P041", where,
                f"{int(bad.sum())} slot(s) hold a nonzero of a different "
                f"row (row,slot) {_head(list(zip(rr, ss)))}"))


def _check_rowsplit(plan, meta, nnz, diags) -> None:
    m = meta.m
    slot = _np(plan.fwd.get("slot_nz", np.zeros((0, 0), np.int32)))
    rows = _np(plan.fwd["nz_rows"]) if "nz_rows" in plan.fwd else \
        np.zeros(0, np.int64)
    if slot.ndim != 2 or slot.shape[0] < m:
        diags.append(Diagnostic(
            "P011", "plan.fwd.slot_nz",
            f"expected (m_pad >= {m}, L) ELL array, got {slot.shape}"))
        return
    length = slot.shape[1]
    if meta.l_pad is not None and length < meta.l_pad:
        diags.append(Diagnostic(
            "P040", "plan.fwd.slot_nz",
            f"ELL width {length} is narrower than meta.l_pad="
            f"{meta.l_pad}"))
    if rows.size and nnz:
        max_len = int(np.bincount(rows[:nnz], minlength=max(m, 1)).max())
        bound = length if meta.l_pad is None else meta.l_pad
        if bound < max_len:
            diags.append(Diagnostic(
                "P040", "plan.meta.l_pad",
                f"l_pad={bound} is smaller than the pattern's longest "
                f"row ({max_len} nonzeroes) — the ELL layout silently "
                "truncates rows"))
    _ell_row_check(slot, np.arange(m, dtype=np.int64), nnz, rows,
                   "plan.fwd.slot_nz", diags)


def _check_rowgroup(plan, meta, nnz, diags) -> None:
    m = meta.m
    groups_meta = meta.extra
    groups = plan.fwd.get("groups", ())
    inv = plan.fwd.get("inv_pos")
    if inv is None or len(groups_meta) != len(groups):
        diags.append(Diagnostic(
            "P050", "plan.meta.extra",
            f"group table has {len(groups_meta)} entries but the "
            f"structure holds {len(groups)} groups"
            + ("" if inv is not None else "; inv_pos missing")))
        return
    sizes = [int(g[0]) for g in groups_meta]
    if sum(sizes) != m:
        diags.append(Diagnostic(
            "P050", "plan.meta.extra",
            f"group sizes {sizes} sum to {sum(sizes)}, not m={m}"))
        return
    inv = _np(inv)
    if inv.shape != (m,) or not np.array_equal(np.sort(inv), np.arange(m)):
        diags.append(Diagnostic(
            "P051", "plan.fwd.inv_pos",
            "not a permutation of [0, m) — the un-grouping gather would "
            "duplicate some rows and drop others"))
        return
    row_at = np.empty(m, np.int64)
    row_at[inv] = np.arange(m)
    rows = _np(plan.fwd["nz_rows"]) if "nz_rows" in plan.fwd else \
        np.zeros(0, np.int64)
    lengths = np.bincount(rows[:nnz], minlength=max(m, 1)) if rows.size \
        else np.zeros(max(m, 1), np.int64)
    start = 0
    for g, ((m_g, l_g), gs) in enumerate(zip(groups_meta, groups)):
        grp_rows = row_at[start:start + m_g]
        start += m_g
        slot = _np(gs["slot_nz"])
        if m_g and lengths.size:
            max_len = int(lengths[grp_rows].max())
            if l_g < max_len:
                diags.append(Diagnostic(
                    "P040", f"plan.meta.extra[{g}]",
                    f"group pad l_g={l_g} is smaller than the group's "
                    f"longest row ({max_len} nonzeroes)"))
        _ell_row_check(slot, grp_rows, nnz, rows,
                       f"plan.fwd.groups[{g}].slot_nz", diags)


#: method name -> checker(plan, meta, nnz, diags).  New registered methods
#: may add an entry; without one they still get the generic CSR, slot-
#: coverage, coordinate-array and hashability checks.
STRUCTURE_CHECKS = {
    "merge": _check_merge,
    "rowsplit": _check_rowsplit,
    "rowgroup": _check_rowgroup,
}


# ------------------------------------------------------------ entry points ---


def verify_plan(plan, a=None) -> list:
    """Verify one ``SpmmPlan``; returns a (possibly empty) diagnostic list.

    ``a`` (optional): the concrete CSR the plan was built from — adds the
    CSR-vs-plan cross checks on top of the plan-internal invariants.
    """
    diags: list = []
    meta = plan.meta
    _check_hashable(meta, "plan.meta", diags)
    _check_hashable(meta.extra, "plan.meta.extra", diags)
    from repro.kernels import registry
    if meta.method not in registry.method_names():
        diags.append(Diagnostic(
            "P011", "plan.meta.method",
            f"{meta.method!r} is not a registered method "
            f"(registered: {', '.join(registry.method_names())})"))
    if a is not None:
        verify_csr(a, diags)
        if a.shape != meta.shape or a.nnz_pad != meta.nnz_pad:
            diags.append(Diagnostic(
                "P003", "plan.meta",
                f"plan is for shape {meta.shape} / nnz_pad "
                f"{meta.nnz_pad}, CSR is {a.shape} / {a.nnz_pad}"))
            return diags
    nnz = _check_nz_arrays(plan.fwd, meta, a, diags)
    if nnz is None:
        return diags
    _check_coverage(_slot_arrays(plan.fwd), nnz, meta.nnz_pad,
                    "plan.fwd", diags)
    checker = STRUCTURE_CHECKS.get(meta.method)
    if checker is not None:
        checker(plan, meta, nnz, diags)
    elif not _slot_arrays(plan.fwd):
        diags.append(Diagnostic(
            "P011", "plan.fwd",
            f"method {meta.method!r} has no STRUCTURE_CHECKS entry and "
            "no slot_nz arrays — nothing verifiable about its structure"))
    if (plan.bwd is None) != (not meta.has_transpose):
        diags.append(Diagnostic(
            "P060", "plan.bwd",
            f"meta.has_transpose={meta.has_transpose} but bwd is "
            f"{'missing' if plan.bwd is None else 'present'}"))
    if plan.bwd is not None:
        # The backward is a merge structure on the CSC view: its rows are
        # the original columns, its slots index the original values.
        _check_coverage([("bwd.slot_nz", _np(plan.bwd["slot_nz"]))],
                        nnz, meta.nnz_pad, "plan.bwd", diags)
        cols = _np(plan.fwd["nz_cols"])
        _check_merge_structure(
            plan.bwd, n_tiles=-(-meta.k // _TM) if meta.k else 0,
            nnz=nnz, rows_of_nz=cols, where="plan.bwd", diags=diags)
    return diags


def verify_sharded_plan(plan, a=None) -> list:
    """Verify a ``ShardedSpmmPlan``: shard layout, per-shard plans, and
    the global value-gather coverage."""
    diags: list = []
    meta = plan.meta
    _check_hashable(meta, "plan.meta", diags)
    m, k = meta.shape
    n = meta.n_shards
    span = m if meta.dim == "rows" else k
    bounds = np.asarray(meta.bounds, np.int64)
    if (bounds.shape != (n + 1,) or bounds[0] != 0 or bounds[-1] != span
            or np.any(np.diff(bounds) < 0)):
        diags.append(Diagnostic(
            "P070", "plan.meta.bounds",
            f"bounds {tuple(bounds)} do not tile [0, {span}] into "
            f"{n} monotone {meta.dim} ranges"))
        return diags
    if len(plan.shards) != n or len(plan.vals_slots) != n:
        diags.append(Diagnostic(
            "P071", "plan.shards",
            f"{len(plan.shards)} shard plan(s) / "
            f"{len(plan.vals_slots)} value gather(s) for {n} bound(s)"))
        return diags
    if meta.uniform and any(lm != meta.local_metas[0]
                            for lm in meta.local_metas):
        diags.append(Diagnostic(
            "P073", "plan.meta.uniform",
            "uniform=True but local metas differ — the stacked SPMD "
            "dispatch would run the wrong statics on some shards"))
    covered: list = []
    live_counts = []
    for i, (shard, slot) in enumerate(zip(plan.shards, plan.vals_slots)):
        lm = meta.local_metas[i]
        if shard.meta != lm:
            diags.append(Diagnostic(
                "P071", f"plan.shards[{i}].meta",
                "shard plan meta disagrees with meta.local_metas"))
        size = int(bounds[i + 1] - bounds[i])
        lm_span = lm.shape[0] if meta.dim == "rows" else lm.shape[1]
        other = lm.shape[1] if meta.dim == "rows" else lm.shape[0]
        want_other = k if meta.dim == "rows" else m
        if lm_span < size or other != want_other:
            diags.append(Diagnostic(
                "P071", f"plan.shards[{i}].meta.shape",
                f"local shape {lm.shape} cannot hold {meta.dim} range "
                f"[{bounds[i]}, {bounds[i + 1]}) of global {meta.shape}"))
        sl = _np(slot)
        live = sl[sl != meta.nnz_pad]
        covered.append(live)
        live_counts.append(live.size)
        for d in verify_plan(shard):
            diags.append(Diagnostic(
                d.code, f"shard[{i}].{d.where}", d.message))
        local_valid = _np(shard.fwd.get("nz_valid", np.zeros(0, bool)))
        if int(local_valid.sum()) != live.size:
            diags.append(Diagnostic(
                "P072", f"plan.vals_slots[{i}]",
                f"gathers {live.size} live value(s) but the shard plan "
                f"holds {int(local_valid.sum())} nonzero(es)"))
    ids = np.concatenate(covered) if covered else np.zeros(0, np.int64)
    nnz = int(_np(a.row_ptr)[-1]) if a is not None else ids.size
    _check_coverage(
        [("vals_slots", ids)], nnz, meta.nnz_pad, "plan.vals_slots", diags)
    if meta.dim == "cols":
        if plan.b_rows is None or len(plan.b_rows) != n:
            diags.append(Diagnostic(
                "P074", "plan.b_rows",
                "cols-dim plan without one B row gather per shard"))
        else:
            for i in range(n):
                br = _np(plan.b_rows[i])
                size = int(bounds[i + 1] - bounds[i])
                want = np.full(br.shape[0], k, np.int64)
                want[:size] = np.arange(bounds[i], bounds[i + 1])
                if not np.array_equal(br, want):
                    diags.append(Diagnostic(
                        "P074", f"plan.b_rows[{i}]",
                        f"B row gather does not select columns "
                        f"[{bounds[i]}, {bounds[i + 1]}) (sentinel {k})"))
    if a is not None:
        verify_csr(a, diags)
        if a.shape != meta.shape or a.nnz_pad != meta.nnz_pad:
            diags.append(Diagnostic(
                "P003", "plan.meta",
                f"sharded plan is for shape {meta.shape} / nnz_pad "
                f"{meta.nnz_pad}, CSR is {a.shape} / {a.nnz_pad}"))
    return diags


def verify(plan, a=None) -> list:
    """Dispatch on plan type (``SpmmPlan`` vs ``ShardedSpmmPlan``)."""
    if hasattr(plan, "shards"):
        return verify_sharded_plan(plan, a)
    return verify_plan(plan, a)


def check_plan(plan, a=None) -> None:
    """Raise :class:`PlanVerificationError` if ``plan`` has findings."""
    diags = verify(plan, a)
    if diags:
        raise PlanVerificationError(diags)
