"""Trip-count-aware HLO module analysis for the roofline.

``compiled.cost_analysis()`` counts each while-loop *body once* — useless
for scan-over-layers graphs (80× undercount).  This parser walks the
post-SPMD HLO text, builds a per-computation symbol table, and accumulates

* **flops** — from ``dot`` ops: 2 × result_elements × contracted_size
  (matmul-dominated workloads; fusion-internal elementwise flops are
  ignored and noted in EXPERIMENTS.md),
* **hbm bytes** — fusion-level traffic model: every top-level instruction
  reads its operands and writes its result (gather/dynamic-slice count
  2×result — index-driven reads; updates count 2×update — in-place),
* **collective wire bytes** — ring-algorithm per-device wire cost of every
  all-gather / all-reduce / reduce-scatter / all-to-all /
  collective-permute,

each scaled by the enclosing while-loops' trip counts (parsed from the loop
condition's bound constant — JAX counted loops start at 0).

Grown out of ``repro.launch.hlo_stats`` (which remains as a re-export
shim): this is the shared backend of the dry-run validator
(``repro.launch.dryrun``), the roofline accountant (``repro.obs``) and
the static bytes-moved analyzer (``repro.analysis.traffic``).
``parse_compiled(..., detail=True)`` additionally reports the
multi-computation breakdown and the fusion/op histogram so hidden
copies and transposes are attributable to a computation.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
    "s4": 1, "u4": 1,
}

_ARRAY_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(.*?\)|[\w\[\],{}]+)\s+"
    r"([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s+->\s+.*\{")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "while", "conditional", "call", "after-all",
               "iota", "broadcast"}


def _type_bytes(t: str) -> int:
    return sum(_el_count(dims) * _DTYPE_BYTES[dt]
               for dt, dims in _ARRAY_RE.findall(t))


def _el_count(dims: str) -> int:
    if not dims:
        return 1
    n = 1
    for d in dims.split(","):
        n *= int(d)
    return n


def _wire_factor(op: str, g: int) -> float:
    if g <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * (g - 1) / g
    if op in ("all-gather", "all-to-all"):
        return (g - 1) / g
    if op == "reduce-scatter":
        return float(g - 1)          # result = operand / g
    return 1.0                        # collective-permute


@dataclass
class CompStats:
    flops: float = 0.0
    bytes: float = 0.0
    coll_wire: float = 0.0
    coll_count: int = 0
    coll_by_op: dict = field(default_factory=lambda: defaultdict(float))
    # (callee, multiplier) pairs: while bodies × trip count, calls × 1
    calls: list = field(default_factory=list)


def _split_computations(text: str):
    comps, name, lines = {}, None, []
    for line in text.splitlines():
        m = _COMP_RE.match(line)
        if m and line.rstrip().endswith("{"):
            name, lines = m.group(1), []
            comps[name] = lines
        elif line.startswith("}"):
            name = None
        elif name is not None:
            lines.append(line)
    return comps


def _entry_name(text: str):
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
    return m.group(1) if m else None


def _trip_count(cond_lines) -> int:
    # JAX counted loops: cond compares the (0-initialised) counter with the
    # bound constant; take the max integer constant in the condition.
    best = 1
    for line in cond_lines:
        for c in _CONST_RE.findall(line):
            best = max(best, int(c))
    return best


def parse_module(text: str, *, detail: bool = False) -> dict:
    """Traffic model of one HLO module (multi-computation, trip-scaled).

    ``detail=True`` adds ``"computations"`` — each computation's *own*
    (unscaled, pre-call-graph) {flops, hbm_bytes} so hot loop bodies and
    hidden copy computations are attributable — and ``"fusion_ops"``,
    the module-wide op histogram of :func:`fusion_stats`.
    """
    comps = _split_computations(text)
    entry = _entry_name(text)
    stats: dict[str, CompStats] = {}

    for cname, lines in comps.items():
        cs = CompStats()
        symbols: dict[str, str] = {}
        for line in lines:
            m = _INSTR_RE.match(line)
            if not m:
                continue
            iname, itype, op, rest = m.groups()
            symbols[iname] = itype
            if op.endswith("-start"):
                op = op[:-6]
            if op.endswith("-done"):
                continue  # counted at -start
            operands = re.findall(r"%([\w.\-]+)", rest.split("),")[0]
                                  if ")," in rest else rest)

            if op == "dot":
                cdims = _CDIMS_RE.search(rest)
                lhs_t = symbols.get(operands[0] if operands else "", "")
                arr = _ARRAY_RE.search(lhs_t or "")
                contracted = 1
                if cdims and arr:
                    dims = [int(d) for d in arr.group(2).split(",") if d]
                    for ci in cdims.group(1).split(","):
                        if ci:
                            contracted *= dims[int(ci)]
                cs.flops += 2.0 * _el_count(
                    _ARRAY_RE.search(itype).group(2)) * contracted

            if op in _COLLECTIVES:
                rb = _type_bytes(itype)
                g = 1
                mg = _GROUPS_RE.search(rest)
                if mg:
                    g = len(mg.group(1).split(","))
                else:
                    mg = _GROUPS_IOTA_RE.search(rest)
                    if mg:
                        g = int(mg.group(2))
                cs.coll_wire += rb * _wire_factor(op, g)
                cs.coll_count += 1
                cs.coll_by_op[op] += rb * _wire_factor(op, g)

            if op == "while":
                mb = re.search(r"body=%?([\w.\-]+)", rest)
                mc = re.search(r"condition=%?([\w.\-]+)", rest)
                trips = _trip_count(comps.get(mc.group(1), [])) if mc else 1
                if mb:
                    cs.calls.append((mb.group(1), trips))
            elif op in ("call", "fusion"):
                # fusion bodies don't touch HBM; call bodies do (count ×1)
                if op == "call":
                    mt = re.search(r"to_apply=%?([\w.\-]+)", rest)
                    if mt:
                        cs.calls.append((mt.group(1), 1))
            elif op == "conditional":
                for mt in re.finditer(
                        r"(?:branch_computations=\{|true_computation=|"
                        r"false_computation=)%?([\w.\-]+)", rest):
                    cs.calls.append((mt.group(1), 1))

            if op not in _SKIP_BYTES:
                rb = _type_bytes(itype)
                if op in ("gather", "dynamic-slice"):
                    cs.bytes += 2.0 * rb
                elif op in ("scatter", "dynamic-update-slice"):
                    upd = (symbols.get(operands[1], "")
                           if len(operands) > 1 else itype)
                    cs.bytes += 2.0 * _type_bytes(upd)
                else:
                    ob = sum(_type_bytes(symbols.get(o, ""))
                             for o in operands)
                    cs.bytes += rb + ob
        stats[cname] = cs

    # accumulate from entry through the call graph with multipliers
    memo: dict[str, tuple] = {}

    def total(cname: str):
        if cname in memo:
            return memo[cname]
        cs = stats.get(cname)
        if cs is None:
            return (0.0, 0.0, 0.0, 0, {})
        f, b, w, n = cs.flops, cs.bytes, cs.coll_wire, cs.coll_count
        by = dict(cs.coll_by_op)
        memo[cname] = (f, b, w, n, by)  # break cycles defensively
        for callee, mult in cs.calls:
            cf, cb, cw, cn, cby = total(callee)
            f += cf * mult
            b += cb * mult
            w += cw * mult
            n += cn * mult
            for k, v in cby.items():
                by[k] = by.get(k, 0.0) + v * mult
        memo[cname] = (f, b, w, n, by)
        return memo[cname]

    f, b, w, n, by = total(entry) if entry else (0, 0, 0, 0, {})
    out = {
        "flops": f,
        "hbm_bytes": b,
        "collective_wire_bytes": w,
        "collective_count": n,
        "collective_by_op": by,
    }
    if detail:
        out["computations"] = {
            cname: {"flops": cs.flops, "hbm_bytes": cs.bytes}
            for cname, cs in stats.items()
            if cs.flops or cs.bytes or cs.calls}
        out["fusion_ops"] = fusion_stats(text)
    return out


def parse_compiled(fn, *args, detail: bool = False, **kwargs) -> dict:
    """``parse_module`` of a callable's compiled (post-SPMD) HLO.

    ``fn`` is jit-wrapped if it isn't already; ``*args``/``**kwargs`` are
    the abstract or concrete operands to lower for.  The convenience the
    roofline accountant, the obs bench and the traffic analyzer use: one
    call from a callable to the traffic model's {flops, hbm_bytes,
    collective_*} dict.  ``detail=True`` adds the per-computation
    breakdown and the ``fusion_ops`` histogram (see :func:`parse_module`)
    so a bytes regression points at the computation that grew.
    """
    import jax

    jitted = fn if hasattr(fn, "lower") else jax.jit(fn)
    compiled = jitted.lower(*args, **kwargs).compile()
    return parse_module(compiled.as_text(), detail=detail)


# ---- legacy summary API (kept for tests/benchmarks) ------------------------


def collective_stats(hlo_text: str) -> dict:
    r = parse_module(hlo_text)
    return {"total": {"count": r["collective_count"],
                      "wire_bytes": r["collective_wire_bytes"]},
            "by_op": r["collective_by_op"]}


def fusion_stats(hlo_text: str) -> dict:
    """Op histogram of the optimized module (entry only, unscaled) — used
    in §Perf to spot redundant gathers / transposes."""
    ops = defaultdict(int)
    for m in re.finditer(r"=\s*(?:[\w\[\],<>{}\s]*?)\s([a-z][\w\-]*)\(",
                         hlo_text):
        ops[m.group(1)] += 1
    keep = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
            "collective-permute", "transpose", "reshape", "copy", "fusion",
            "while", "dot", "convolution", "dynamic-slice",
            "dynamic-update-slice", "gather", "scatter")
    return {k: ops[k] for k in keep if ops[k]}
