"""AST-based repo lint: the call-site disciplines review keeps re-enforcing.

Rules (suppress a line with ``# noqa: RLxxx`` or a bare ``# noqa``):

* **RL001** — no host-sync calls (``.item()``, ``np.asarray``/``np.array``,
  ``float(traced)``) inside jit-reachable functions.  A function is
  jit-reachable when it is decorated with ``jit``/``custom_vjp``/
  ``custom_vmap`` (directly or through ``functools.partial``), registered
  via ``X.defvjp(...)``/``X.def_vmap(...)``, or is a Pallas kernel body
  (calls ``pl.program_id``/``pl.when``/``pl.load``/``pl.store``).  Host
  syncs there either crash under trace (the ``_require_concrete`` rule
  from PR 2) or silently block the device stream.
* **RL002** — no legacy pre-v1 kwargs at first-party call sites: the bare
  ``method=``/``interpret=``/... shims on ``spmm``/``execute_plan``/
  ``execute_sharded``/``get_plan`` warn at runtime and fold into
  ``PlanPolicy``/``ExecutionConfig``; first-party code must use the v1
  spelling (tests of the deprecation shims themselves are exempt).
* **RL003** — every ``MethodSpec(...)`` registration supplies the complete
  hook set as keywords; a positional or partial registration compiles
  but strands the method outside the tuner/heuristic/audit machinery.
* **RL004** — every ``benchmarks/bench_*.py`` on disk is referenced in
  ``benchmarks/run.py::_mods`` (PR 7's ``check_registration``, proven
  statically so the gap is caught before any benchmark imports jax).
* **RL005** — no new imports of the deprecated ``benchmarks.roofline``
  re-export shim: the roofline model lives in ``repro.obs.roofline``
  (the shim file itself is exempt; it stays only so external scripts
  keep importing).
* **RL006** — the Makefile keeps the analysis gates wired: the
  ``analyze`` recipe must run the traffic gate (``traffic --check``)
  and a ``traffic-baseline`` regeneration target must exist, so the
  bytes-moved baseline cannot silently drop out of CI.

``run_lint(paths)`` returns ``Diagnostic`` rows with ``file:line``
locations; the CLI (``python -m repro.analysis lint``) exits non-zero on
any finding.
"""
from __future__ import annotations

import ast
import os
import re
from collections.abc import Iterable

from .diagnostics import Diagnostic

_JIT_MARKERS = {"jit", "custom_vjp", "custom_vmap", "pallas_call"}
_KERNEL_MARKERS = {"program_id", "when", "load", "store"}
_NP_ALIASES = {"np", "numpy", "onp"}
_HOST_SYNC_NP = {"asarray", "array"}

#: first-party entry point -> pre-v1 kwargs that fold into
#: PlanPolicy/ExecutionConfig (see core/spmm.py, engine/cache.py).
LEGACY_KWARGS = {
    "spmm": {"method", "l_pad", "t", "heuristic", "interpret", "impl",
             "tk"},
    "execute_plan": {"interpret", "impl", "tk"},
    "execute_sharded": {"interpret", "impl", "tk"},
    "get_plan": {"method", "heuristic", "t", "tl", "l_pad",
                 "with_transpose", "tunedb"},
}

#: the complete MethodSpec hook set (kernels/registry.py) — RL003.
METHODSPEC_FIELDS = {
    "name", "description", "build_structure", "execute", "inline",
    "resolve_params", "tune_candidates", "heuristic_rank", "traffic",
}

_NOQA_RE = re.compile(r"#\s*noqa(?::\s*(?P<codes>[A-Z0-9, ]+))?",
                      re.IGNORECASE)


def _suppressed(lines: list[str], lineno: int, code: str) -> bool:
    if not 1 <= lineno <= len(lines):
        return False
    m = _NOQA_RE.search(lines[lineno - 1])
    if not m:
        return False
    codes = m.group("codes")
    if codes is None:
        return True
    return code in {c.strip().upper() for c in codes.split(",")}


def _dotted_names(node: ast.AST) -> Iterable[str]:
    """Every Name id / Attribute attr under ``node`` (decorator scan)."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name):
            yield sub.id
        elif isinstance(sub, ast.Attribute):
            yield sub.attr


def _call_name(call: ast.Call) -> str | None:
    """The final identifier of the called object (``f`` / ``mod.f``)."""
    f = call.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return None


def _defvjp_targets(tree: ast.Module) -> set[str]:
    """Function names registered through ``X.defvjp(f, g)`` / def_vmap."""
    out: set[str] = set()
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in ("defvjp", "def_vmap")):
            for arg in node.args:
                if isinstance(arg, ast.Name):
                    out.add(arg.id)
    return out


def _is_jit_reachable(fn: ast.FunctionDef, vjp_targets: set[str]) -> bool:
    if fn.name in vjp_targets:
        return True
    for dec in fn.decorator_list:
        if _JIT_MARKERS.intersection(_dotted_names(dec)):
            return True
    for node in ast.walk(fn):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id == "pl"
                and node.func.attr in _KERNEL_MARKERS):
            return True
    return False


def _check_host_sync(fn: ast.FunctionDef, path: str, lines,
                     diags: list) -> None:
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        where = f"{path}:{node.lineno}"
        f = node.func
        if isinstance(f, ast.Attribute) and f.attr == "item" \
                and not node.args:
            msg = (f"host sync `.item()` inside jit-reachable "
                   f"`{fn.name}` — return the array (or gate on "
                   "concreteness via _require_concrete)")
        elif (isinstance(f, ast.Attribute)
              and f.attr in _HOST_SYNC_NP
              and isinstance(f.value, ast.Name)
              and f.value.id in _NP_ALIASES):
            msg = (f"`{f.value.id}.{f.attr}(...)` inside jit-reachable "
                   f"`{fn.name}` pulls a traced value to host — use "
                   "jnp, or hoist to plan build time")
        elif (isinstance(f, ast.Name) and f.id == "float" and node.args
              and not isinstance(node.args[0], ast.Constant)):
            msg = (f"`float(...)` on a non-literal inside jit-reachable "
                   f"`{fn.name}` forces a device sync under trace")
        else:
            continue
        if not _suppressed(lines, node.lineno, "RL001"):
            diags.append(Diagnostic("RL001", where, msg))


def _check_legacy_kwargs(tree, path: str, lines, diags: list) -> None:
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = _call_name(node)
        legacy = LEGACY_KWARGS.get(name or "")
        if not legacy:
            continue
        used = sorted(kw.arg for kw in node.keywords
                      if kw.arg in legacy)
        if used and not _suppressed(lines, node.lineno, "RL002"):
            diags.append(Diagnostic(
                "RL002", f"{path}:{node.lineno}",
                f"legacy pre-v1 kwargs {used} on `{name}` — fold into "
                "PlanPolicy/ExecutionConfig (README: Migrating to API "
                "v1)"))


def _check_methodspec(tree, path: str, lines, diags: list) -> None:
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        if _call_name(node) != "MethodSpec":
            continue
        if _suppressed(lines, node.lineno, "RL003"):
            continue
        where = f"{path}:{node.lineno}"
        if node.args:
            diags.append(Diagnostic(
                "RL003", where,
                "MethodSpec must be constructed with keywords only, so "
                "the full hook set is auditable"))
            continue
        given = {kw.arg for kw in node.keywords if kw.arg}
        missing = sorted(METHODSPEC_FIELDS - given)
        if missing:
            diags.append(Diagnostic(
                "RL003", where,
                f"MethodSpec registration missing hooks {missing} — "
                "every method supplies the complete set (explicit None "
                "is fine) so tuner/heuristic/audit coverage is total"))


def _check_roofline_shim(tree, path: str, lines, diags: list) -> None:
    norm = path.replace(os.sep, "/")
    if norm.endswith("benchmarks/roofline.py"):
        return                      # the shim itself is exempt
    in_benchmarks = "benchmarks/" in norm
    for node in ast.walk(tree):
        hit = None
        if isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            if mod == "benchmarks.roofline" or mod.endswith(
                    ".roofline") and "benchmarks" in mod:
                hit = mod
            elif in_benchmarks and node.level == 1 and mod == "roofline":
                hit = ".roofline"
        elif isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name == "benchmarks.roofline" or \
                        alias.name.endswith(".roofline") and \
                        "benchmarks" in alias.name:
                    hit = alias.name
        if hit is None or _suppressed(lines, node.lineno, "RL005"):
            continue
        diags.append(Diagnostic(
            "RL005", f"{path}:{node.lineno}",
            f"import of the deprecated `{hit}` re-export shim — the "
            "roofline model lives in repro.obs.roofline"))


def check_makefile_targets(repo_root: str, diags: list) -> None:
    """RL006: the analysis gates must stay wired into the Makefile."""
    makefile = os.path.join(repo_root, "Makefile")
    if not os.path.exists(makefile):
        return
    with open(makefile, encoding="utf-8") as f:
        text = f.read()
    analyze = re.search(r"^analyze:.*\n((?:\t.*\n?)*)", text, re.M)
    if analyze is None or "traffic --check" not in analyze.group(1):
        diags.append(Diagnostic(
            "RL006", f"{makefile}:1",
            "the `analyze` recipe does not run `traffic --check` — the "
            "bytes-moved regression gate is not in CI"))
    if re.search(r"^traffic-baseline:", text, re.M) is None:
        diags.append(Diagnostic(
            "RL006", f"{makefile}:1",
            "no `traffic-baseline` target — the committed traffic "
            "baseline has no documented regeneration path"))


def _bench_mentions(run_py: str) -> set[str]:
    """bench_* identifiers referenced inside run.py::_mods."""
    with open(run_py, encoding="utf-8") as f:
        tree = ast.parse(f.read(), run_py)
    out: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef) and node.name == "_mods":
            for sub in ast.walk(node):
                if isinstance(sub, ast.alias):
                    out.add(sub.name)
                elif isinstance(sub, ast.Name):
                    out.add(sub.id)
    return {n for n in out if n.startswith("bench_")}


def check_bench_registration(bench_dir: str, diags: list) -> None:
    run_py = os.path.join(bench_dir, "run.py")
    if not os.path.exists(run_py):
        return
    on_disk = {f[:-3] for f in sorted(os.listdir(bench_dir))
               if f.startswith("bench_") and f.endswith(".py")}
    mentioned = _bench_mentions(run_py)
    for stem in sorted(on_disk - mentioned):
        diags.append(Diagnostic(
            "RL004", f"{run_py}:1",
            f"benchmarks/{stem}.py is not registered in run.py::_mods — "
            "it will never run in CI"))
    for stem in sorted(mentioned - on_disk):
        diags.append(Diagnostic(
            "RL004", f"{run_py}:1",
            f"run.py::_mods references {stem} but benchmarks/{stem}.py "
            "does not exist"))


def lint_file(path: str, *,
              rules=("RL001", "RL002", "RL003", "RL005"),
              _exempt_legacy=("tests/test_api.py",)) -> list[Diagnostic]:
    with open(path, encoding="utf-8") as f:
        src = f.read()
    lines = src.splitlines()
    try:
        tree = ast.parse(src, path)
    except SyntaxError as e:
        return [Diagnostic("RL000", f"{path}:{e.lineno or 1}",
                           f"does not parse: {e.msg}")]
    diags: list[Diagnostic] = []
    if "RL001" in rules:
        vjp_targets = _defvjp_targets(tree)
        for node in ast.walk(tree):
            if isinstance(node, ast.FunctionDef) and \
                    _is_jit_reachable(node, vjp_targets):
                _check_host_sync(node, path, lines, diags)
    norm = path.replace(os.sep, "/")
    if "RL002" in rules and not any(norm.endswith(e)
                                    for e in _exempt_legacy):
        _check_legacy_kwargs(tree, path, lines, diags)
    if "RL003" in rules:
        _check_methodspec(tree, path, lines, diags)
    if "RL005" in rules:
        _check_roofline_shim(tree, path, lines, diags)
    return diags


def _default_roots(repo_root: str) -> list[str]:
    roots = []
    for rel in ("src", "benchmarks", "examples"):
        p = os.path.join(repo_root, rel)
        if os.path.isdir(p):
            roots.append(p)
    return roots


def _py_files(paths: Iterable[str]) -> list[str]:
    out = []
    for p in paths:
        if os.path.isfile(p):
            out.append(p)
            continue
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames[:] = [d for d in dirnames
                           if d not in ("__pycache__", ".git")]
            out.extend(os.path.join(dirpath, f)
                       for f in sorted(filenames) if f.endswith(".py"))
    return sorted(out)


def run_lint(paths: Iterable[str] | None = None, *,
             repo_root: str | None = None) -> list[Diagnostic]:
    """Lint ``paths`` (default: src/, benchmarks/, examples/ under the
    repo root) and the benchmark registration; returns diagnostics."""
    if repo_root is None:
        repo_root = os.getcwd()
    targets = list(paths) if paths else _default_roots(repo_root)
    diags: list[Diagnostic] = []
    for path in _py_files(targets):
        diags.extend(lint_file(path))
    bench_dir = os.path.join(repo_root, "benchmarks")
    if paths is None and os.path.isdir(bench_dir):
        check_bench_registration(bench_dir, diags)
    if paths is None:
        check_makefile_targets(repo_root, diags)
    return diags
