"""Shared diagnostic record for the static-analysis subsystem.

Every analysis leg (plan linter, kernel audit, repo lint) reports
findings as :class:`Diagnostic` rows so the CLI can render them
uniformly: ``<where>: <CODE> <message>``.  ``where`` is a ``file:line``
location for source-level findings and a plan path (``plan.fwd.slot_nz``,
``shard[2].meta.l_pad``) for structural ones.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One finding: a stable code, a location, and a message."""

    code: str           # e.g. "P020", "K101", "RL001"
    where: str          # file:line or plan path
    message: str

    def __str__(self) -> str:
        return f"{self.where}: {self.code} {self.message}"


def format_diagnostics(diags, *, header: str | None = None) -> str:
    """Render diagnostics one per line (with an optional header)."""
    lines = [] if header is None else [header]
    lines.extend(str(d) for d in diags)
    return "\n".join(lines)
