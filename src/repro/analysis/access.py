"""Machine-checked coalescing: paper principle (ii) as a static proof.

The paper's second design principle is coalesced, streaming access: the
minor (lane) dimension of every operand tile must be contiguous and
advance with unit stride as the grid walks, and the k-tile stream must
advance monotonically so the B panel is read as a forward stream, never
re-wound mid-pass.  The kernels encode this in their BlockSpec index
maps; this module *proves* it by enumerating every registered launch
model (``MethodSpec.traffic`` → ``repro.kernels.introspect``) over its
full grid:

* **T110** — a minor-dimension block-index delta outside ``{0, +1}``
  along some grid axis: the lane dimension strides or rewinds, breaking
  coalescing (e.g. a transposed B index map).
* **T120** — a non-minor delta outside ``{0, +1}``: a k-tile or row-tile
  stream that skips or rewinds (the merge ``tile[c]`` stream and the
  ``kk`` axis must both be monotone, one step at a time).
* **T130/T131** — the rowgroup permutation invariants: ``inv_pos`` must
  be a permutation of the rows, and within each length bucket the
  source rows must stay in original (ascending) order — the stable-sort
  guarantee that keeps per-group gathers themselves streaming.
* **T101/T102** — bidirectional coverage (the K001/K002 idiom): a
  kernel-defining module in ``repro.kernels`` that no ``traffic`` hook
  or :data:`EXTRA_KERNELS` entry models is T101; a stale
  ``EXTRA_KERNELS`` entry naming a module with no kernel is T102.

``EXTRA_KERNELS`` covers launches outside the per-method registry —
today the backward SDDMM (``kernels.sddmm``), which no forward
``MethodSpec`` dispatches.
"""
from __future__ import annotations

import os

import numpy as np

from .diagnostics import Diagnostic


def _sddmm_models(plan, n, batch):
    from repro.kernels import sddmm as _sddmm
    meta = plan.meta
    return _sddmm.launch_models(nnz_pad=meta.nnz_pad, m=meta.m,
                                k=meta.k, n=n, batch=batch)


def _flash_models(plan, n, batch):
    from repro.kernels import flash_attention as _fa
    # Representative serving shape: 2 batch × 2 heads, 2×2 q/kv blocks.
    return _fa.launch_models(bh=2 * batch, s=256, dh=128)


def _moe_models(plan, n, batch):
    from repro.kernels import moe_gemm as _moe
    # 4 experts, one 64-token block each (dense routing: the be stream
    # advances one expert at a time, the case the checker proves).
    be = np.arange(4, dtype=np.int32)
    return _moe.launch_models(be, tokens=256, d_in=1024, d_out=256,
                              n_experts=4)


#: kernels with no MethodSpec of their own: name of the defining module
#: in ``repro.kernels`` -> builder(plan, n, batch) -> [KernelLaunch].
EXTRA_KERNELS = {
    "sddmm": _sddmm_models,
    "flash_attention": _flash_models,
    "moe_gemm": _moe_models,
}


def check_launch(model, *, where: str = "") -> list[Diagnostic]:
    """Enumerate per-axis block-index deltas of every in/out block.

    For each grid axis ``a`` and point ``p`` the delta is
    ``index_map(p + e_a) - index_map(p)`` componentwise; the minor
    (last) component must stay in ``{0, +1}`` (T110) and every other
    component too (T120).  One diagnostic per (block, axis) — the first
    violating point is named.
    """
    diags = []
    label = f"{where}:{model.label}" if where else model.label
    for blk in model.blocks:
        if blk.index_map is None or blk.kind not in ("in", "out"):
            continue
        for axis in range(len(model.grid)):
            if model.grid[axis] < 2:
                continue
            hit = False
            for point in np.ndindex(*model.grid):
                if hit or point[axis] + 1 >= model.grid[axis]:
                    continue
                nxt = list(point)
                nxt[axis] += 1
                i0 = tuple(int(x) for x in blk.index_map(*point))
                i1 = tuple(int(x) for x in blk.index_map(*nxt))
                delta = tuple(b - a for a, b in zip(i0, i1))
                if delta[-1] not in (0, 1):
                    hit = True
                    diags.append(Diagnostic(
                        "T110", f"{label}:{blk.name}",
                        f"minor-dim block index steps by {delta[-1]} "
                        f"along grid axis {axis} at {tuple(point)} — "
                        "the lane dimension must advance contiguously "
                        "(unit stride) or hold"))
                elif any(d not in (0, 1) for d in delta[:-1]):
                    hit = True
                    diags.append(Diagnostic(
                        "T120", f"{label}:{blk.name}",
                        f"non-minor block index delta {delta[:-1]} "
                        f"along grid axis {axis} at {tuple(point)} — "
                        "tile streams must advance monotonically, one "
                        "step at a time (no rewinds, no skips)"))
    return diags


def check_rowgroup_plan(plan, *, where: str = "rowgroup") -> \
        list[Diagnostic]:
    """T130/T131: the un-grouping gather must be a permutation and the
    per-group gathers must read source rows in ascending order."""
    diags = []
    inv = np.asarray(plan.fwd["inv_pos"])
    m = inv.shape[0]
    if not np.array_equal(np.sort(inv), np.arange(m)):
        diags.append(Diagnostic(
            "T130", f"{where}:inv_pos",
            "inv_pos is not a permutation of the rows — the un-grouping "
            "gather would drop or duplicate output rows"))
        return diags
    order = np.argsort(inv)
    start = 0
    for g, (m_g, _) in enumerate(plan.meta.extra):
        rows = order[start:start + m_g]
        start += m_g
        if rows.size > 1 and np.any(np.diff(rows) <= 0):
            diags.append(Diagnostic(
                "T131", f"{where}[g{g}]",
                "source rows within the length bucket are not in "
                "ascending original order — the stable-sort guarantee "
                "behind streaming per-group gathers is broken"))
    return diags


def _kernel_modules() -> set[str]:
    """Module names under ``repro.kernels`` that define a Pallas kernel
    (contain a ``pl.pallas_call``)."""
    import repro.kernels as kpkg
    root = os.path.dirname(kpkg.__file__)
    out = set()
    for fname in sorted(os.listdir(root)):
        if not fname.endswith(".py"):
            continue
        with open(os.path.join(root, fname), encoding="utf-8") as f:
            if "pl.pallas_call" in f.read():
                out.add(fname[:-3])
    return out


def check_coverage() -> list[Diagnostic]:
    """T101/T102: every kernel-defining module must be modeled; every
    :data:`EXTRA_KERNELS` entry must still name a kernel module."""
    from repro.kernels import registry
    diags = []
    defined = _kernel_modules()
    covered = set(EXTRA_KERNELS)
    for name in registry.method_names():
        spec = registry.get_method(name)
        if spec.traffic is None:
            diags.append(Diagnostic(
                "T101", name,
                "registered method has no MethodSpec.traffic launch "
                "model — its access patterns are unverifiable (the "
                "checker never skips silently)"))
        else:
            covered.add(spec.traffic.__module__.rsplit(".", 1)[-1])
    for mod in sorted(defined - covered):
        diags.append(Diagnostic(
            "T101", f"repro.kernels.{mod}",
            "module defines a pallas_call that no MethodSpec.traffic "
            "hook or access.EXTRA_KERNELS entry models"))
    for mod in sorted(set(EXTRA_KERNELS) - defined):
        diags.append(Diagnostic(
            "T102", f"repro.kernels.{mod}",
            "EXTRA_KERNELS entry for a module that defines no kernel "
            "(stale entry?)"))
    return diags


def check_all(*, n: int = 256, batch: int = 2, tk: int | None = 64) -> \
        list[Diagnostic]:
    """Run the coalescing checks over every registered method ×
    representative variant, the extra kernels, and coverage."""
    from repro.core.plan import build_plan
    from repro.kernels import registry

    from .kernel_audit import _representative, _variants

    diags = check_coverage()
    a = _representative()
    merge_plan = None
    for name in registry.method_names():
        spec = registry.get_method(name)
        if spec.traffic is None:
            continue                     # already T101 via check_coverage
        plan = build_plan(a, method=name)
        if name == "merge":
            merge_plan = plan
        for var in _variants():
            for model in spec.traffic(plan, n, batch, var, tk):
                diags.extend(check_launch(
                    model, where=f"{name}/{var.name}"))
        if name == "rowgroup":
            diags.extend(check_rowgroup_plan(plan))
    if merge_plan is None:
        merge_plan = build_plan(a, method="merge")
    for kname, builder in EXTRA_KERNELS.items():
        if kname not in _kernel_modules():
            continue                     # already T102
        for model in builder(merge_plan, n, batch):
            diags.extend(check_launch(model, where=f"extra/{kname}"))
    return diags
