"""Static verification layer: plan linter, kernel audit, repo lint.

Three legs behind one CLI (``python -m repro.analysis``):

* :mod:`repro.analysis.planlint` — host-side structural verification of
  built ``SpmmPlan``/``ShardedSpmmPlan`` objects (exactly-once nonzero
  coverage, merge-path tiling, sentinel hygiene, ...).  Also available
  as an opt-in hook on every plan build: ``REPRO_VERIFY_PLANS=1`` (or
  :func:`set_verify_plans`).
* :mod:`repro.analysis.kernel_audit` — registry-driven static audit of
  the Pallas lowerings (VMEM budget, index-map bounds, single-writer
  flush, accumulator dtype) without executing a kernel.
* :mod:`repro.analysis.lint` — AST rules for repo-wide call-site
  discipline (RL001–RL006).
* :mod:`repro.analysis.traffic` — static bytes-moved analyzer over
  every method × impl × dtype/epilogue variant × {fwd, bwd}, with the
  committed-baseline regression gate (``traffic --check``).
* :mod:`repro.analysis.access` — machine-checked coalescing: every
  BlockSpec index map proven unit-stride/monotone over its full grid.
* :mod:`repro.analysis.hlo` — the post-optimization HLO parser the
  traffic analyzer and ``launch.dryrun`` share.

This package is imported at load time by ``repro.core.plan`` (for the
``_flags`` gate), so the top level stays import-light: the heavy legs
load lazily via PEP 562.
"""
from __future__ import annotations

from . import _flags
from ._flags import set_verify_plans
from .diagnostics import Diagnostic, format_diagnostics

__all__ = [
    "Diagnostic",
    "format_diagnostics",
    "set_verify_plans",
    "_flags",
    "planlint",
    "kernel_audit",
    "lint",
    "traffic",
    "access",
    "hlo",
]

_LAZY = ("planlint", "kernel_audit", "lint", "traffic", "access", "hlo")


def __getattr__(name):
    if name in _LAZY:
        import importlib
        mod = importlib.import_module(f".{name}", __name__)
        globals()[name] = mod
        return mod
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_LAZY))
