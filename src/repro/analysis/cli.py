"""``python -m repro.analysis {planlint,audit,lint,all}``.

One entry point for the three static-analysis legs:

* ``planlint`` — build a plan per registered method (plus row- and
  column-sharded plans) for every matrix in a suite and run the full
  structural linter over each; a corrupt planner fails here before any
  kernel would read the structure.
* ``audit``    — the registry-driven kernel audit; ``--out`` writes the
  per-method report table (the CI artifact).
* ``lint``     — the repo-wide AST rules (RL001–RL004).
* ``all``      — all three; exit status is non-zero iff any leg found
  anything, which is the CI gate.
"""
from __future__ import annotations

import argparse
import os

from .diagnostics import format_diagnostics


def _repo_root() -> str:
    # src/repro/analysis/cli.py -> repo root, when run from a checkout;
    # fall back to cwd for installed trees.
    here = os.path.dirname(os.path.abspath(__file__))
    root = os.path.dirname(os.path.dirname(os.path.dirname(here)))
    if os.path.isdir(os.path.join(root, "benchmarks")):
        return root
    return os.getcwd()


def run_planlint(suite: str = "mini", out=None) -> int:
    """Self-check: verify every (suite matrix × method × sharding) plan."""
    from repro.analysis import planlint
    from repro.core.config import PlanPolicy, ShardSpec
    from repro.core.plan import build_plan
    from repro.distributed.spmm import build_sharded_plan
    from repro.kernels import registry
    from repro.matrices.suites import get_suite

    failures = 0
    checked = 0
    for spec in get_suite(suite):
        a = spec.build()
        for method in registry.method_names():
            plan = build_plan(a, method=method)
            diags = planlint.verify_plan(plan, a)
            checked += 1
            if diags:
                failures += len(diags)
                print(format_diagnostics(
                    diags, header=f"{spec.name} × {method}:"), file=out)
        for dim in ("rows", "cols"):
            policy = PlanPolicy(shards=ShardSpec(n=2, dim=dim))
            plan = build_sharded_plan(a, policy)
            diags = planlint.verify_sharded_plan(plan, a)
            checked += 1
            if diags:
                failures += len(diags)
                print(format_diagnostics(
                    diags, header=f"{spec.name} × sharded/{dim}:"),
                    file=out)
    print(f"planlint: {checked} plan(s) verified on suite {suite!r}, "
          f"{failures} finding(s)", file=out)
    return 1 if failures else 0


def run_audit(report_path=None, out=None) -> int:
    from repro.analysis import kernel_audit

    rows, diags = kernel_audit.audit_all()
    report = kernel_audit.format_report(rows, diags)
    print(report, file=out)
    if report_path:
        os.makedirs(os.path.dirname(report_path) or ".", exist_ok=True)
        with open(report_path, "w", encoding="utf-8") as f:
            f.write(report + "\n")
        print(f"audit: report written to {report_path}", file=out)
    return 1 if diags else 0


def run_repo_lint(paths=None, out=None) -> int:
    from repro.analysis import lint

    diags = lint.run_lint(paths or None, repo_root=_repo_root())
    if diags:
        print(format_diagnostics(diags), file=out)
    print(f"lint: {len(diags)} finding(s)", file=out)
    return 1 if diags else 0


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static verification: plan linter, kernel audit, "
                    "repo lint")
    sub = p.add_subparsers(dest="cmd", required=True)
    pl = sub.add_parser("planlint", help="verify plans over a suite")
    pl.add_argument("--suite", default="mini")
    au = sub.add_parser("audit", help="static Pallas kernel audit")
    au.add_argument("--out", default=None,
                    help="write the report table to this path")
    li = sub.add_parser("lint", help="repo-wide AST lint")
    li.add_argument("paths", nargs="*", help="files/dirs (default: src, "
                    "benchmarks, examples)")
    al = sub.add_parser("all", help="planlint + audit + lint (CI gate)")
    al.add_argument("--suite", default="mini")
    al.add_argument("--audit-out", default=None)
    args = p.parse_args(argv)

    if args.cmd == "planlint":
        return run_planlint(args.suite)
    if args.cmd == "audit":
        return run_audit(args.out)
    if args.cmd == "lint":
        return run_repo_lint(args.paths)
    rc = run_repo_lint(None)          # cheapest first: no jax import
    rc = run_planlint(args.suite) or rc
    rc = run_audit(args.audit_out) or rc
    return rc
