"""``python -m repro.analysis {planlint,audit,lint,traffic,all}``.

One entry point for the four static-analysis legs:

* ``planlint`` — build a plan per registered method (plus row- and
  column-sharded plans) for every matrix in a suite and run the full
  structural linter over each; a corrupt planner fails here before any
  kernel would read the structure.
* ``audit``    — the registry-driven kernel audit; ``--out`` writes the
  per-method report table (the CI artifact).
* ``lint``     — the repo-wide AST rules (RL001–RL006).
* ``traffic``  — the static bytes-moved analyzer + coalescing checker;
  ``--check`` also diffs against the committed baseline (the CI
  regression gate), ``--update`` regenerates it.
* ``all``      — every leg; exit status is non-zero iff any leg found
  anything, which is the CI gate.

Every subcommand takes ``--json PATH`` to write a machine-readable
report (``{"command", "exit", "diagnostics": [{code, where, message}],
...}``); ``all --json`` nests the per-leg payloads.
"""
from __future__ import annotations

import argparse
import json
import os

from .diagnostics import format_diagnostics


def _repo_root() -> str:
    # src/repro/analysis/cli.py -> repo root, when run from a checkout;
    # fall back to cwd for installed trees.
    here = os.path.dirname(os.path.abspath(__file__))
    root = os.path.dirname(os.path.dirname(os.path.dirname(here)))
    if os.path.isdir(os.path.join(root, "benchmarks")):
        return root
    return os.getcwd()


def _diag_dicts(diags):
    return [{"code": d.code, "where": d.where, "message": d.message}
            for d in diags]


def _write_json(path, payload) -> None:
    if not path:
        return
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w", encoding="utf-8") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")


def run_planlint(suite: str = "mini", out=None, *, json_path=None,
                 payload=None) -> int:
    """Self-check: verify every (suite matrix × method × sharding) plan."""
    from repro.analysis import planlint
    from repro.core.config import PlanPolicy, ShardSpec
    from repro.core.plan import build_plan
    from repro.distributed.spmm import build_sharded_plan
    from repro.kernels import registry
    from repro.matrices.suites import get_suite

    all_diags = []
    checked = 0
    for spec in get_suite(suite):
        a = spec.build()
        for method in registry.method_names():
            plan = build_plan(a, method=method)
            diags = planlint.verify_plan(plan, a)
            checked += 1
            if diags:
                all_diags.extend(diags)
                print(format_diagnostics(
                    diags, header=f"{spec.name} × {method}:"), file=out)
        for dim in ("rows", "cols"):
            policy = PlanPolicy(shards=ShardSpec(n=2, dim=dim))
            plan = build_sharded_plan(a, policy)
            diags = planlint.verify_sharded_plan(plan, a)
            checked += 1
            if diags:
                all_diags.extend(diags)
                print(format_diagnostics(
                    diags, header=f"{spec.name} × sharded/{dim}:"),
                    file=out)
    print(f"planlint: {checked} plan(s) verified on suite {suite!r}, "
          f"{len(all_diags)} finding(s)", file=out)
    rc = 1 if all_diags else 0
    rec = {"command": "planlint", "exit": rc, "suite": suite,
           "plans_checked": checked, "diagnostics": _diag_dicts(all_diags)}
    if payload is not None:
        payload["planlint"] = rec
    _write_json(json_path, rec)
    return rc


def run_audit(report_path=None, out=None, *, json_path=None,
              payload=None) -> int:
    from repro.analysis import kernel_audit

    rows, diags = kernel_audit.audit_all()
    report = kernel_audit.format_report(rows, diags)
    print(report, file=out)
    if report_path:
        os.makedirs(os.path.dirname(report_path) or ".", exist_ok=True)
        with open(report_path, "w", encoding="utf-8") as f:
            f.write(report + "\n")
        print(f"audit: report written to {report_path}", file=out)
    rc = 1 if diags else 0
    rec = {"command": "audit", "exit": rc,
           "rows": [{"method": r.method, "impl": r.impl,
                     "variant": r.variant, "vmem_bytes": r.vmem_bytes}
                    for r in rows],
           "diagnostics": _diag_dicts(diags)}
    if payload is not None:
        payload["audit"] = rec
    _write_json(json_path, rec)
    return rc


def run_repo_lint(paths=None, out=None, *, json_path=None,
                  payload=None) -> int:
    from repro.analysis import lint

    diags = lint.run_lint(paths or None, repo_root=_repo_root())
    if diags:
        print(format_diagnostics(diags), file=out)
    print(f"lint: {len(diags)} finding(s)", file=out)
    rc = 1 if diags else 0
    rec = {"command": "lint", "exit": rc,
           "diagnostics": _diag_dicts(diags)}
    if payload is not None:
        payload["lint"] = rec
    _write_json(json_path, rec)
    return rc


def run_traffic(*, check: bool = False, update: bool = False,
                baseline_path=None, out=None, json_path=None,
                payload=None) -> int:
    """Bytes-moved analysis + coalescing checks (+ the baseline gate)."""
    from repro.analysis import access, traffic

    baseline_path = baseline_path or os.path.join(
        _repo_root(), traffic.BASELINE_PATH)
    rows, diags = traffic.analyze_all()
    diags = list(diags) + access.check_all()
    base_diags = []
    if update:
        traffic.update_baseline(rows, baseline_path)
        print(f"traffic: baseline written to {baseline_path}", file=out)
    elif check:
        base_diags = traffic.check_baseline(
            rows, traffic.load_baseline(baseline_path))
        diags += base_diags
    print(traffic.format_report(rows, diags), file=out)
    rc = 1 if diags else 0
    rec = {"command": "traffic", "exit": rc,
           "baseline": os.path.relpath(baseline_path, _repo_root()),
           "checked_baseline": bool(check and not update),
           "rows": [r.to_dict() for r in rows],
           "diagnostics": _diag_dicts(diags)}
    if payload is not None:
        payload["traffic"] = rec
    _write_json(json_path, rec)
    return rc


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static verification: plan linter, kernel audit, "
                    "repo lint, traffic analyzer")
    sub = p.add_subparsers(dest="cmd", required=True)
    pl = sub.add_parser("planlint", help="verify plans over a suite")
    pl.add_argument("--suite", default="mini")
    pl.add_argument("--json", default=None, dest="json_path",
                    help="write a machine-readable report to this path")
    au = sub.add_parser("audit", help="static Pallas kernel audit")
    au.add_argument("--out", default=None,
                    help="write the report table to this path")
    au.add_argument("--json", default=None, dest="json_path")
    li = sub.add_parser("lint", help="repo-wide AST lint")
    li.add_argument("paths", nargs="*", help="files/dirs (default: src, "
                    "benchmarks, examples)")
    li.add_argument("--json", default=None, dest="json_path")
    tr = sub.add_parser(
        "traffic", help="static bytes-moved + coalescing analysis")
    tr.add_argument("--check", action="store_true",
                    help="also diff against the committed baseline "
                    "(exit 1 on unexplained growth)")
    tr.add_argument("--update", action="store_true",
                    help="regenerate the baseline from the current tree")
    tr.add_argument("--baseline", default=None,
                    help="baseline path (default: "
                    "<repo>/artifacts/traffic_baseline.json)")
    tr.add_argument("--json", default=None, dest="json_path")
    al = sub.add_parser("all",
                        help="planlint + audit + lint + traffic (CI gate)")
    al.add_argument("--suite", default="mini")
    al.add_argument("--audit-out", default=None)
    al.add_argument("--json", default=None, dest="json_path")
    args = p.parse_args(argv)

    if args.cmd == "planlint":
        return run_planlint(args.suite, json_path=args.json_path)
    if args.cmd == "audit":
        return run_audit(args.out, json_path=args.json_path)
    if args.cmd == "lint":
        return run_repo_lint(args.paths, json_path=args.json_path)
    if args.cmd == "traffic":
        return run_traffic(check=args.check, update=args.update,
                           baseline_path=args.baseline,
                           json_path=args.json_path)
    payload: dict = {}
    rcs = [run_repo_lint(None, payload=payload)]  # cheapest: no jax
    rcs.append(run_planlint(args.suite, payload=payload))
    rcs.append(run_audit(args.audit_out, payload=payload))
    rcs.append(run_traffic(check=True, payload=payload))
    rc = max(rcs)
    _write_json(args.json_path,
                {"command": "all", "exit": rc, "legs": payload})
    return rc
