"""Registry-driven static audit of the Pallas SpMM kernels.

For every registered ``MethodSpec`` × impl × representative dtype/epilogue
variant, this module traces the method's ``execute`` to a jaxpr *without
running it* and checks, statically:

* the lowering shape — ``impl="pallas"`` must stage exactly the expected
  number of ``pallas_call`` launches (one per merge/rowsplit dispatch,
  one per group for rowgroup) and ``impl="xla"`` none; the traced output
  dtype must match the requested ``out_dtype``/promotion rule;
* **VMEM footprint** — each launch is re-modeled block-for-block from
  the kernel's BlockSpecs (double-buffered in/out blocks + scratch) and
  summed against the per-backend budget, catching ``resolve_tk``/operand
  blowups before any compile;
* **grid/index-map in-bounds** — every index map is evaluated over every
  point of the static grid (with the real scalar-prefetch arrays, e.g.
  the merge ``tile`` stream) and each block must land inside its operand;
* **single-writer discipline** — the accumulator-flush predicate is
  enumerated over the grid and every output tile must be written exactly
  once (the invariant that replaces the paper's GPU carry-out fix-up);
* **accumulator dtype** — ``acc_dtype`` is never narrower than the
  promotion of the input dtypes (PR 6's runtime guard, proven per
  variant).

The launch models live with the kernels: each method's ``MethodSpec``
supplies them through its ``traffic`` hook (``repro.kernels.introspect``
— the same models feed ``repro.analysis.access`` and ``.traffic``), and
:data:`_AUDITS` is an override table (``register_audit``) for tests and
out-of-tree methods.  A method registered in ``repro.kernels.registry``
with neither is a *hard failure* (``K001``), not a silent skip — new
methods must either provide a model or explicitly inherit one.
:func:`audit_all` returns ``(rows, diagnostics)``; ``rows`` is the
per-launch report table that ``make analyze`` uploads as a CI artifact.
"""
from __future__ import annotations

import dataclasses
from collections import Counter as _Counter
from collections.abc import Callable

import numpy as np

from repro.kernels.introspect import KernelBlock, KernelLaunch

from .diagnostics import Diagnostic

#: Static on-chip memory budget per backend, bytes.  TPU cores have
#: ~16 MiB of VMEM (see /opt guides); the audit models the TPU target —
#: the CPU interpret substrate has no such limit but must not mask a
#: lowering that could never fit real hardware.
VMEM_BUDGET_BYTES = {"tpu": 16 * 2 ** 20}

AUDIT_IMPLS = ("pallas", "xla")


@dataclasses.dataclass(frozen=True)
class Variant:
    """One representative dtype/epilogue corner audited per method."""

    name: str
    vals_dtype: str
    b_dtype: str
    acc_dtype: str
    out_dtype: str | None
    epilogue: object            # repro.core.Epilogue | None


def _variants():
    from repro.core.epilogue import Epilogue
    return (
        Variant("f32", "float32", "float32", "float32", None, None),
        Variant("bf16_acc32+epi", "bfloat16", "bfloat16", "float32",
                "bfloat16",
                Epilogue(bias=True, activation="gelu", residual=True)),
    )


# The model classes live next to the kernels (repro.kernels.introspect);
# these aliases keep the audit's public vocabulary and existing callers.
Block = KernelBlock
LaunchModel = KernelLaunch


#: method name -> model builder(plan, n, batch, variant, tk) ->
#: [LaunchModel] — *overrides* for the registry's ``MethodSpec.traffic``
#: hook (tests, out-of-tree methods).  Built-in methods ship their
#: models on the spec itself; a method with neither is K001.
_AUDITS: dict[str, Callable] = {}


def register_audit(name: str, models: Callable, *,
                   override: bool = False) -> None:
    """Override the launch models for a registered method (takes
    precedence over its ``MethodSpec.traffic`` hook)."""
    if name in _AUDITS and not override:
        raise ValueError(f"audit for method {name!r} already registered")
    _AUDITS[name] = models


# ----------------------------------------------------------- static checks ---


def _n_blocks(block: Block) -> int:
    return int(np.prod([
        -(-a // s) for a, s in zip(block.array_shape, block.shape)]))


def check_in_bounds(model: LaunchModel) -> list[str]:
    """Evaluate every index map over every grid point; returns violation
    strings (empty = proven in-bounds by enumeration)."""
    bad = []
    for point in np.ndindex(*model.grid):
        for blk in model.blocks:
            if blk.index_map is None:
                continue
            idx = blk.index_map(*point)
            for d, (bi, bs, asz) in enumerate(
                    zip(idx, blk.shape, blk.array_shape)):
                if bi < 0 or (int(bi) + 1) * bs > asz:
                    bad.append(
                        f"{blk.name}@grid{tuple(point)}: block index "
                        f"{tuple(int(i) for i in idx)} dim {d} outside "
                        f"operand {blk.array_shape}")
                    if len(bad) >= 5:
                        return bad
    return bad


def check_single_writer(model: LaunchModel) -> list[str]:
    """The flush predicate must write every output tile exactly once."""
    writes = _Counter()
    for point in np.ndindex(*model.grid):
        if model.flush(*point):
            writes[tuple(int(i) for i in model.out.index_map(*point))] += 1
    problems = []
    multi = {ix: c for ix, c in writes.items() if c != 1}
    if multi:
        some = list(multi.items())[:3]
        problems.append(f"tiles written != once: {some}")
    expected = _n_blocks(model.out)
    if len(writes) != expected:
        problems.append(
            f"{len(writes)} of {expected} output tiles ever flushed")
    return problems


def _promotes_ok(var: Variant) -> bool:
    import jax.numpy as jnp
    promoted = jnp.promote_types(var.vals_dtype, var.b_dtype)
    return jnp.promote_types(promoted, var.acc_dtype) == \
        jnp.dtype(var.acc_dtype)


def _count_pallas_calls(jaxpr) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            n += 1
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                n += _count_pallas_calls(sub)
    return n


def _subjaxprs(v):
    if hasattr(v, "jaxpr") and hasattr(getattr(v, "jaxpr"), "eqns"):
        return [v.jaxpr]
    if hasattr(v, "eqns"):
        return [v]
    if isinstance(v, (list, tuple)):
        out = []
        for item in v:
            out.extend(_subjaxprs(item))
        return out
    return []


# -------------------------------------------------------------- the audit ---


@dataclasses.dataclass(frozen=True)
class AuditRow:
    """One line of the report table (per method × impl × variant)."""

    method: str
    impl: str
    variant: str
    launches: int
    grid_points: int
    vmem_bytes: int
    vmem_frac: float            # of the TPU budget (max over launches)
    ok: bool
    notes: str = ""


def _representative(m: int = 48, k: int = 192, batch: int = 2):
    """A small irregular pattern every method plans against: row lengths
    span [1, 24) so rowgroup gets several buckets and rowsplit a
    nontrivial L; k and n are sized so the audit's explicit ``tk`` makes
    the k-tile axis and the column-tile axis both multi-step."""
    import jax
    from repro.core.csr import random_csr
    a = random_csr(jax.random.PRNGKey(0), m, k, nnz_per_row=(1, 23))
    return a


def _trace_execute(spec, plan, var, impl, n, batch):
    """Trace the method's execute to a jaxpr + output aval (no run)."""
    import jax
    import jax.numpy as jnp
    meta, fwd = plan.meta, plan.fwd
    ep = var.epilogue
    vals = jnp.zeros((meta.nnz_pad,), var.vals_dtype)
    b = jnp.zeros((batch, meta.k, n), var.b_dtype)
    bias = jnp.zeros((meta.m,), var.b_dtype) \
        if ep is not None and ep.bias else None
    residual = jnp.zeros((batch, meta.m, n), var.b_dtype) \
        if ep is not None and ep.residual else None

    def f(vals, b, bias, residual):
        return spec.execute(meta, fwd, vals, b, tk=None, interpret=True,
                            impl=impl, epilogue=ep, bias=bias,
                            residual=residual, acc_dtype=var.acc_dtype,
                            out_dtype=var.out_dtype)

    jaxpr = jax.make_jaxpr(f)(vals, b, bias, residual)
    out = jax.eval_shape(f, vals, b, bias, residual)
    return jaxpr.jaxpr, out


def audit_method(name: str, *, n: int = 256, batch: int = 2,
                 tk: int | None = 64, backend: str = "tpu"):
    """Audit one registered method; returns ``(rows, diagnostics)``."""
    import jax.numpy as jnp
    from repro.core.plan import build_plan
    from repro.kernels import registry

    spec = registry.get_method(name)
    models_fn = _AUDITS.get(name, spec.traffic)
    rows, diags = [], []
    if models_fn is None:
        diags.append(Diagnostic(
            "K001", name,
            "registered method has no static launch model — set the "
            "MethodSpec.traffic hook or override via "
            "repro.analysis.kernel_audit.register_audit (the audit "
            "never skips silently)"))
        return rows, diags
    a = _representative()
    plan = build_plan(a, method=name)
    budget = VMEM_BUDGET_BYTES[backend]
    for var in _variants():
        if not _promotes_ok(var):
            diags.append(Diagnostic(
                "K050", f"{name}/{var.name}",
                f"acc_dtype {var.acc_dtype} is narrower than the "
                f"promotion of ({var.vals_dtype}, {var.b_dtype})"))
        models = models_fn(plan, n, batch, var, tk)
        expect_odt = jnp.dtype(var.out_dtype) if var.out_dtype else \
            jnp.promote_types(var.vals_dtype, var.b_dtype)
        for impl in AUDIT_IMPLS:
            where = f"{name}/{impl}/{var.name}"
            notes, ok = [], True
            try:
                jaxpr, out = _trace_execute(spec, plan, var, impl, n,
                                            batch)
            except Exception as e:       # noqa: BLE001 — report, not die
                diags.append(Diagnostic(
                    "K010", where, f"tracing the kernel failed: {e!r}"))
                rows.append(AuditRow(name, impl, var.name, 0, 0, 0, 0.0,
                                     False, "trace failed"))
                continue
            n_calls = _count_pallas_calls(jaxpr)
            want_calls = len(models) if impl == "pallas" else 0
            if n_calls != want_calls:
                ok = False
                diags.append(Diagnostic(
                    "K011", where,
                    f"expected {want_calls} pallas_call launch(es) in "
                    f"the jaxpr, found {n_calls}"))
            if jnp.dtype(out.dtype) != expect_odt:
                ok = False
                diags.append(Diagnostic(
                    "K012", where,
                    f"traced output dtype {out.dtype} != requested "
                    f"{expect_odt}"))
            vmem = grid_pts = 0
            frac = 0.0
            if impl == "pallas":
                for model in models:
                    mb = model.vmem_bytes()
                    vmem = max(vmem, mb)
                    frac = max(frac, mb / budget)
                    grid_pts += int(np.prod(model.grid))
                    if mb > budget:
                        ok = False
                        diags.append(Diagnostic(
                            "K020", f"{where}:{model.label}",
                            f"modeled VMEM {mb} B exceeds the {backend} "
                            f"budget {budget} B"))
                    for viol in check_in_bounds(model):
                        ok = False
                        diags.append(Diagnostic(
                            "K030", f"{where}:{model.label}", viol))
                    for prob in check_single_writer(model):
                        ok = False
                        diags.append(Diagnostic(
                            "K040", f"{where}:{model.label}", prob))
                notes.append(f"{len(models)} launch(es)")
            rows.append(AuditRow(
                name, impl, var.name, want_calls if impl == "pallas"
                else 0, grid_pts, vmem, round(frac, 4), ok,
                "; ".join(notes)))
    return rows, diags


def nnz_vmem_ceiling(*, dtype: str = "float32", k: int = 29568,
                     backend: str = "tpu") -> int:
    """Largest ``nnz_pad`` whose whole-block values operand still fits.

    The merge/rowsplit kernels pin the raw values in VMEM as one
    ``(1, NV)`` block (see ``merge_spmm_pallas``); with the ``(TK, TN)``
    B panel and the C tile double-buffered beside it, this is the static
    ceiling a real-TPU port must window past.
    """
    import jax.numpy as jnp
    from repro.kernels.merge_spmm import TM, TN, resolve_tk
    budget = VMEM_BUDGET_BYTES[backend]
    isz = jnp.dtype(dtype).itemsize
    tk, _ = resolve_tk(k, None)
    fixed = 2 * (tk * TN * isz) + 2 * (TM * TN * isz) + TM * TN * 4
    nv = (budget - fixed) // (2 * isz)
    return max(int(nv - 1), 0)


def scale_rows(*, k: int = 29568) -> list[str]:
    """Informational serving-scale probe lines for the report (the
    representative audit proves the invariants; this states where the
    static VMEM model says the current lowering stops scaling)."""
    from repro.kernels.merge_spmm import resolve_tk
    tk, n_k = resolve_tk(k, None)
    lines = [
        f"scale probe: k={k} resolves to tk={tk} ({n_k} K-tiles) — the "
        f"B panel stays {tk * 128 * 4 // 1024} KiB/buffer at any d_in",
    ]
    for dt in ("float32", "bfloat16"):
        ceil_nnz = nnz_vmem_ceiling(dtype=dt, k=k)
        lines.append(
            f"scale probe: whole-block values operand caps nnz_pad at "
            f"~{ceil_nnz:,} ({dt}) before VMEM overflows — larger "
            "patterns need the per-chunk values window noted in "
            "merge_spmm_pallas")
    return lines


def audit_all(*, n: int = 256, batch: int = 2, tk: int | None = 64):
    """Audit every registered method; returns ``(rows, diagnostics)``.

    Coverage is bidirectional and loud: a registered method with neither
    a ``MethodSpec.traffic`` hook nor an ``_AUDITS`` override is K001; a
    stale ``_AUDITS`` override naming an unregistered method is K002.
    """
    from repro.kernels import registry
    rows, diags = [], []
    for name in registry.method_names():
        r, d = audit_method(name, n=n, batch=batch, tk=tk)
        rows.extend(r)
        diags.extend(d)
    for name in _AUDITS:
        if name not in registry.method_names():
            diags.append(Diagnostic(
                "K002", name,
                "kernel-audit entry for a method that is not registered "
                "(stale model?)"))
    return rows, diags


def format_report(rows, diags) -> str:
    """The per-method report table ``make analyze`` uploads to CI."""
    header = (f"{'method':<10} {'impl':<7} {'variant':<16} "
              f"{'launches':>8} {'grid':>6} {'vmem_kib':>9} "
              f"{'vmem%':>6} {'ok':>3}")
    lines = ["kernel audit report", header, "-" * len(header)]
    for r in rows:
        lines.append(
            f"{r.method:<10} {r.impl:<7} {r.variant:<16} "
            f"{r.launches:>8} {r.grid_points:>6} "
            f"{r.vmem_bytes / 1024:>9.1f} {r.vmem_frac * 100:>5.1f}% "
            f"{'ok' if r.ok else 'FAIL':>4}"
            + (f"  {r.notes}" if r.notes else ""))
    lines.extend(scale_rows())
    if diags:
        lines.append("")
        lines.append(f"{len(diags)} finding(s):")
        lines.extend(f"  {d}" for d in diags)
    else:
        lines.append("no findings")
    return "\n".join(lines)
