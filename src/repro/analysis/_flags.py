"""Debug-verification flags for the static-analysis hooks.

Deliberately tiny: ``repro.core.plan`` and ``repro.distributed.spmm``
import this module at load time to gate the ``REPRO_VERIFY_PLANS`` hook,
so it must import nothing heavier than ``os`` (the ``obs`` gating
pattern: one module-level attribute read when the hook is off, zero
other cost).
"""
from __future__ import annotations

import os

# True: every plan built through build_plan / PlanCache.get /
# build_sharded_plan is verified host-side (repro.analysis.planlint)
# immediately after construction.  Off by default; enable with
# REPRO_VERIFY_PLANS=1 or set_verify_plans(True).
verify_plans: bool = os.environ.get("REPRO_VERIFY_PLANS", "") not in (
    "", "0", "false", "no")


def set_verify_plans(on: bool) -> bool:
    """Flip the plan-verification hook; returns the previous value."""
    global verify_plans
    prev, verify_plans = verify_plans, bool(on)
    return prev
