"""AdamW with global-norm clipping, cosine schedule, and optional
error-feedback int8 gradient compression for the DP all-reduce.

Pure-pytree implementation (no optax dependency); optimizer state is
sharded identically to the parameters, so FSDP sharding of params gives
ZeRO-style sharded optimizer state for free.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    learning_rate: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.learning_rate * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(params) -> dict:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def init_state_zero1(params_f32, compute_dtype) -> tuple:
    """ZeRO-1: compute params are a low-precision *replica* (sharded over
    the model axes only); the f32 master + moments are FSDP-sharded inside
    the optimizer state.  Per step the data-parallel traffic is ONE grad
    reduce-scatter + ONE param all-gather instead of per-microbatch,
    per-layer re-gathers (§Perf iteration 3)."""
    cast = lambda p: p.astype(compute_dtype) \
        if jnp.issubdtype(p.dtype, jnp.floating) else p
    state = init_state(params_f32)
    state["master"] = params_f32
    return jax.tree.map(cast, params_f32), state


def apply_updates_zero1(params, grads, state, cfg: AdamWConfig,
                        skip_nonfinite: bool = True):
    """AdamW against the f32 master; emits fresh low-precision params."""
    new_master, new_state, metrics = apply_updates(
        state["master"], grads, {k: state[k] for k in ("step", "m", "v")},
        cfg, skip_nonfinite)
    new_state["master"] = new_master
    cast = lambda mp, p: mp.astype(p.dtype)
    new_params = jax.tree.map(cast, new_master, params)
    return new_params, new_state, metrics


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def apply_updates(params, grads, state, cfg: AdamWConfig,
                  skip_nonfinite: bool = True):
    """Returns (new_params, new_state, metrics).

    ``skip_nonfinite``: fault-tolerance guard — a step with inf/nan grads
    (e.g. from a replica that died mid-all-reduce and was recovered) is
    skipped instead of poisoning the weights.
    """
    step = state["step"] + 1
    gnorm = global_norm(grads)
    finite = jnp.isfinite(gnorm)
    scale = jnp.where(gnorm > cfg.grad_clip, cfg.grad_clip / gnorm, 1.0)
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        u = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * u
        if skip_nonfinite:
            p_new = jnp.where(finite, p_new, p.astype(jnp.float32))
            m_new = jnp.where(finite, m_new, m)
            v_new = jnp.where(finite, v_new, v)
        return p_new.astype(p.dtype), m_new, v_new

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"step": jnp.where(finite, step, state["step"]),
                 "m": new_m, "v": new_v}
    metrics = {"grad_norm": gnorm, "lr": lr,
               "skipped": (~finite).astype(jnp.float32)}
    return new_params, new_state, metrics
