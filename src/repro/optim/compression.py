"""Error-feedback int8 gradient compression for the DP all-reduce.

At 1000+ nodes the data-parallel gradient all-reduce dominates step time for
small-per-chip models.  Quantizing gradients to int8 with per-tensor scales
cuts the all-reduce payload 4× (f32) / 2× (bf16); the *error-feedback*
residual keeps the scheme unbiased over time (Seide et al., 1-bit SGD
lineage): the quantization error of step t is added back into step t+1's
gradient before quantizing again.

Usage inside a shard_map'd or jit'd step:

    g_q, scales = compress(grads, residual)
    g_q = lax.psum(g_q_as_int32, 'data')          # 1/4 the bytes on the wire
    grads, residual = decompress_and_residual(...)

The jit path in ``runtime/steps.py`` applies compress→decompress around the
gradient tree so XLA's all-reduce runs on the int8 payload.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _q(x, res):
    xf = x.astype(jnp.float32) + res
    scale = jnp.max(jnp.abs(xf)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    err = xf - q.astype(jnp.float32) * scale
    return q, scale, err


def compress(grads, residual):
    """→ (int8 tree, scale tree, new residual tree)."""
    out = jax.tree.map(lambda g, r: _q(g, r), grads, residual)
    is3 = lambda t: isinstance(t, tuple)
    return (jax.tree.map(lambda t: t[0], out, is_leaf=is3),
            jax.tree.map(lambda t: t[1], out, is_leaf=is3),
            jax.tree.map(lambda t: t[2], out, is_leaf=is3))


def decompress(q_tree, scale_tree):
    return jax.tree.map(
        lambda q, s: q.astype(jnp.float32) * s, q_tree, scale_tree)


def init_residual(params):
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def roundtrip(grads, residual):
    """compress→decompress in one jit region (XLA keeps the int8 tensor as
    the cross-replica payload).  Returns (grads', residual')."""
    q, s, err = compress(grads, residual)
    return decompress(q, s), err
