"""Training driver: real steps on the local mesh (CPU here, TPU pod in
production), with checkpoint/resume, preemption handling, straggler
watermarking, and deterministic data.

    python -m repro.launch.train --arch llama3.2-1b --smoke \
        --steps 50 --global-batch 8 --seq-len 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse

import jax

from repro import obs
from repro.checkpoint import CheckpointManager
from repro.configs import ARCHS, get_config, get_smoke_config
from repro.data import DataConfig, make_source
from repro.distributed import fault
from repro.launch.mesh import make_local_mesh
from repro.optim import adamw
from repro.runtime import steps as R

# Step latency (the first observation includes compile; the histogram's
# p50 reads as steady state, max as the compile step).
_step_latency = obs.registry.histogram(
    "train_step_latency_us", "train.py per-step wall time")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default="auto", choices=["auto", "none"])
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8_ef"])
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--data-path", default="")
    ap.add_argument("--tunedb", default="", metavar="PATH",
                    help="TuneDB JSON (python -m repro.tune) — SparseLinear "
                    "plan (re)builds resolve their kernel method from "
                    "measurements instead of the analytic heuristic")
    ap.add_argument("--spmm-method", default="", metavar="METHOD",
                    help="force the SpMM kernel method for sparse-layer "
                    "plan rebuilds (any method registered in "
                    "repro.kernels.registry; default: auto)")
    ap.add_argument("--spmm-shards", type=int, default=0, metavar="N",
                    help="rebuild sparse-layer plans as N nnz-balanced "
                    "row shards (repro.distributed.spmm); when N matches "
                    "the local mesh's data axis the shards execute as one "
                    "shard_map program, otherwise as a per-shard loop")
    ap.add_argument("--trace-out", default="", metavar="PATH",
                    help="enable structured tracing and write the Chrome "
                    "trace-event JSON here on exit")
    ap.add_argument("--metrics-out", default="", metavar="PATH",
                    help="write a JSON snapshot of the metrics registry "
                    "(step-latency histogram, plan counters) on exit")
    args = ap.parse_args(argv)

    if args.trace_out:
        obs.enable()

    if args.tunedb:
        from repro import engine
        db = engine.load_tunedb(args.tunedb)
        print(f"[train] tunedb {args.tunedb}: backend={db.backend} "
              f"entries={len(db)} threshold={db.threshold}")

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_local_mesh()
    opt_cfg = adamw.AdamWConfig(learning_rate=args.lr,
                                warmup_steps=args.warmup,
                                total_steps=args.steps)
    step_fn = R.make_train_step(
        cfg, opt_cfg, microbatches=args.microbatches,
        loss_chunk=min(512, args.seq_len),
        grad_compression=args.grad_compression)

    state = R.init_train_state(cfg, jax.random.PRNGKey(args.seed),
                               grad_compression=args.grad_compression)
    start_step = 0
    manager = None
    if args.ckpt_dir:
        manager = CheckpointManager(args.ckpt_dir, keep=3)
        if args.resume == "auto":
            restored, step, extra = manager.restore_latest(state)
            if restored is not None:
                state, start_step = restored, step
                print(f"[train] resumed from step {step}")
    # Route any sparse layers/matrices through the SpMM engine: plans are
    # (re)built once here, outside jit — the jitted step never replans.
    spmm_policy = None
    if args.spmm_method or args.spmm_shards:
        from repro.core import PlanPolicy, ShardSpec
        shards = None
        if args.spmm_shards:
            shard_mesh = (mesh if mesh.shape.get("data") == args.spmm_shards
                          else None)
            shards = ShardSpec(n=args.spmm_shards, mesh=shard_mesh)
        spmm_policy = PlanPolicy(method=args.spmm_method or "auto",
                                 shards=shards)
    state["params"] = R.ensure_spmm_plans(state["params"],
                                          policy=spmm_policy)

    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                          global_batch=args.global_batch, seed=args.seed,
                          input_mode=cfg.input_mode, d_model=cfg.d_model)
    source = make_source(data_cfg, args.data_path or None)

    with mesh:
        jitted = jax.jit(step_fn, donate_argnums=(0,))
        guard = fault.PreemptionGuard().install()
        watermark = fault.StragglerWatermark()
        for step in range(start_step, args.steps):
            batch = source.batch_at(step)
            with fault.StepTimer() as t:
                with obs.span("train.step", cat="train", step=step):
                    state, metrics = jitted(state, batch)
                    jax.block_until_ready(metrics["loss"])
            _step_latency.observe(t.seconds * 1e6)
            if watermark.observe(step, t.seconds):
                print(f"[straggler] step {step} took {t.seconds:.2f}s")
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss={float(metrics['loss']):.4f} "
                      f"nll={float(metrics['nll']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"lr={float(metrics['lr']):.2e} {t.seconds:.2f}s")
            want_ckpt = manager and (
                (step + 1) % args.ckpt_every == 0 or step == args.steps - 1
                or guard.should_checkpoint())
            if want_ckpt:
                fault.retry(lambda: manager.save(step + 1, state))
            if guard.should_checkpoint():
                print(f"[train] preempted; checkpointed at {step + 1}; "
                      f"exiting for restart")
                _export_obs(args)
                return 0
    if watermark.flagged:
        print(f"[train] stragglers flagged: {watermark.flagged[:5]}")
    _export_obs(args)
    return 0


def _export_obs(args) -> None:
    if args.trace_out:
        tr = obs.get_tracer()
        if tr is not None:
            print(f"[train] trace: {tr.export(args.trace_out)} "
                  f"({len(tr)} events)")
    if args.metrics_out:
        print(f"[train] metrics: {obs.dump_metrics(args.metrics_out)}")


if __name__ == "__main__":
    raise SystemExit(main())
