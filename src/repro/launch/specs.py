"""ShapeDtypeStruct stand-ins for every model input — the dry-run never
allocates; weak-type-correct, shardable specs only."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config
from repro.models import model as M
from repro.runtime import steps as R


def _sds(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def batch_specs(cfg, shape, microbatches: int = 1) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        if cfg.input_mode == "tokens":
            return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
        return {"embeds": jax.ShapeDtypeStruct((b, 1, cfg.d_model),
                                               cfg.cdtype)}
    # train batches are pre-shaped (microbatches, local, ...) and scanned
    lead = (microbatches, b // microbatches) if microbatches > 1 else (b,)
    out = {"labels": jax.ShapeDtypeStruct((*lead, s), jnp.int32)}
    if cfg.input_mode == "tokens":
        out["tokens"] = jax.ShapeDtypeStruct((*lead, s), jnp.int32)
    else:
        out["embeds"] = jax.ShapeDtypeStruct((*lead, s, cfg.d_model),
                                             cfg.cdtype)
    if shape.kind == "prefill":
        out.pop("labels")
    return out


def params_specs(cfg, dtype=None):
    specs = jax.eval_shape(
        lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    if dtype is None:
        return specs
    # serving checkpoints are compute-dtype (bf16): halves weight traffic
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, dtype if jnp.issubdtype(s.dtype, jnp.floating)
            else s.dtype),
        specs)


def state_specs(cfg, grad_compression: str = "none",
                param_mode: str = "fsdp"):
    return jax.eval_shape(
        lambda: R.init_train_state(cfg, jax.random.PRNGKey(0),
                                   grad_compression=grad_compression,
                                   param_mode=param_mode))


def cache_specs(cfg, batch: int, cache_len: int):
    return jax.eval_shape(
        lambda: M.init_caches(cfg, batch, cache_len))


def input_specs(arch: str, shape_name: str = "train_4k",
                grad_compression: str = "none",
                microbatches: int = 1, param_mode: str = "fsdp") -> dict:
    """Kwargs for the step function of this (arch, shape) cell."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return {"state": state_specs(cfg, grad_compression, param_mode),
                "batch": batch_specs(cfg, shape, microbatches)}
    if shape.kind == "prefill":
        return {"params": params_specs(cfg, cfg.cdtype),
                "batch": batch_specs(cfg, shape)}
    # decode: one new token against a cache of seq_len
    return {"params": params_specs(cfg, cfg.cdtype),
            "caches": cache_specs(cfg, shape.global_batch, shape.seq_len),
            "batch": batch_specs(cfg, shape),
            "pos": jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)}
