"""Serving driver: prefill + batched greedy decode on the local mesh,
with optional pruned-FFN SpMM (the paper's use case).

    python -m repro.launch.serve --arch llama3.2-1b --smoke \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import model as M
from repro.runtime import steps as R


def generate(cfg, params, prompt_tokens, gen_len: int, *, cache_extra=8):
    """Greedy decode. prompt_tokens (b, s) → (b, s+gen_len)."""
    b, s = prompt_tokens.shape
    prefill = jax.jit(R.make_prefill_step(cfg, cache_len=s + gen_len
                                          + cache_extra))
    decode = jax.jit(R.make_decode_step(cfg))
    out = prefill(params, {"tokens": prompt_tokens})
    caches, logits, pos = out["caches"], out["logits"], out["pos"]
    toks = [prompt_tokens]
    cur = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    for _ in range(gen_len):
        toks.append(cur)
        logits, caches = decode(params, caches, {"tokens": cur}, pos)
        cur = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        pos = pos + 1
    return jnp.concatenate(toks, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    assert cfg.input_mode == "tokens", \
        "serve.py drives token models; embeddings-mode archs use the " \
        "prefill/decode steps directly (see examples/)"
    key = jax.random.PRNGKey(args.seed)
    params = M.init_params(cfg, key)
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    t0 = time.perf_counter()
    out = generate(cfg, params, prompt, args.gen)
    dt = time.perf_counter() - t0
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print(out[0, -args.gen:])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
