"""Serving driver: prefill + batched greedy decode on the local mesh,
with optional pruned-FFN SpMM (the paper's use case).

    python -m repro.launch.serve --arch llama3.2-1b --smoke \
        --batch 4 --prompt-len 32 --gen 16

    # pruned-FFN scoring through the plan-once/execute-many SpMM engine
    python -m repro.launch.serve --arch llama3.2-1b --smoke \
        --batch 2 --prompt-len 16 --prune-ffn 0.25
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import obs
from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import layers as L
from repro.models import model as M
from repro.models import sparse as S
from repro.runtime import steps as R

# Per-phase serving latency: "plan" (prune + plan build), "cold" (first
# jitted forward, compile included), "warm" (steady state).
_serve_latency = obs.registry.histogram(
    "serve_latency_us", "serve.py phase latency", labels=("phase",))
_serve_replans = obs.registry.counter(
    "serve_replans_total",
    "plans built inside the jitted serving path (must stay 0)")


def _check_replans(before, after) -> int:
    """Count plan-cache misses between two ``engine.cache_stats()``
    snapshots and fail loudly if the jitted serving path built any.

    A real check, not an ``assert`` — ``python -O`` strips asserts, and
    a replanning hot path is exactly the regression serving must never
    ship with.  The count lands on ``serve_replans_total`` either way so
    dashboards see the violation even if the exception is swallowed.
    """
    replans = after.misses - before.misses
    if replans:
        _serve_replans.inc(replans)
        raise RuntimeError(
            f"jitted serving replanned: {replans} plan(s) built during "
            f"the warm forward (cache misses {before.misses} -> "
            f"{after.misses}). Plans must be attached before jit — "
            "rebuild the sparse params with ensure_spmm_plans/prune_mlp "
            "outside the traced function.")
    return replans


def generate(cfg, params, prompt_tokens, gen_len: int, *, cache_extra=8):
    """Greedy decode. prompt_tokens (b, s) → (b, s+gen_len)."""
    b, s = prompt_tokens.shape
    prefill = jax.jit(R.make_prefill_step(cfg, cache_len=s + gen_len
                                          + cache_extra))
    decode = jax.jit(R.make_decode_step(cfg))
    out = prefill(params, {"tokens": prompt_tokens})
    caches, logits, pos = out["caches"], out["logits"], out["pos"]
    toks = [prompt_tokens]
    cur = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    for _ in range(gen_len):
        toks.append(cur)
        logits, caches = decode(params, caches, {"tokens": cur}, pos)
        cur = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        pos = pos + 1
    return jnp.concatenate(toks, axis=1)


_PRUNABLE_BTYPES = ("attn", "rglru")   # blocks that own a dense "mlp"


def check_prunable(cfg):
    btypes = {bt for pattern, _ in cfg.segments for bt in pattern}
    unsupported = btypes - set(_PRUNABLE_BTYPES)
    if unsupported:
        raise SystemExit(
            f"--prune-ffn needs every block to own a dense MLP "
            f"(btypes {_PRUNABLE_BTYPES}); arch has {sorted(unsupported)} "
            "blocks (MoE experts / SSD cores have no per-block dense FFN "
            "to prune)")


def prune_ffn_blocks(params, cfg, keep: float, policy=None):
    """Unstack each block's params and prune its MLP once, building each
    pattern's plan through the engine cache — plans are reused by every
    subsequent jitted call.  ``policy`` (a ``repro.PlanPolicy``) pins the
    plan request, e.g. a forced kernel method from ``--spmm-method``."""
    blocks = []
    for si, (pattern, count) in enumerate(cfg.segments):
        for ci in range(count):
            for pi, btype in enumerate(pattern):
                lp = jax.tree.map(lambda x: x[ci],
                                  params["segments"][si][pi])
                lp["mlp"] = S.prune_mlp(lp["mlp"], keep, policy=policy)
                blocks.append(lp)
    return blocks


def block_types(cfg):
    return [btype for pattern, count in cfg.segments
            for _ in range(count) for btype in pattern]


def make_pruned_forward(cfg):
    """Unstacked full forward with SparseLinear MLPs (jit-ready).

    Routes through ``model.block_apply`` — parallel blocks, attention
    windows, and norms behave exactly as in the dense model; only
    ``mlp_apply`` dispatches to the sparse layers.  The SparseLinear
    leaves carry their SpmmPlans, so the jitted trace executes prebuilt
    plans — no replanning, no host syncs.
    """
    btypes = block_types(cfg)      # static: jit sees only the param pytree

    def fwd(params, blocks, tokens):
        h = M.embed_inputs(params, cfg, {"tokens": tokens})
        for btype, lp in zip(btypes, blocks):
            h, _, _ = M.block_apply(lp, btype, h, cfg)
        h = L.norm_apply(params["final_norm"], h, cfg.norm)
        return h.astype(jnp.float32) @ M.unembed_matrix(
            params, cfg).T.astype(jnp.float32)

    return fwd


def serve_pruned(cfg, params, prompt, keep: float, *, microbatch: int = 0,
                 policy=None):
    from repro import engine

    check_prunable(cfg)
    t0 = time.perf_counter()
    with obs.span("serve.plan", cat="serve", keep=keep):
        blocks = prune_ffn_blocks(params, cfg, keep, policy=policy)
    t_plan = time.perf_counter() - t0
    _serve_latency.labels(phase="plan").observe(t_plan * 1e6)
    stats = engine.cache_stats()
    methods = {k: v.method for k, v in blocks[0]["mlp"].items()}
    print(f"[serve] pruned {len(blocks)} MLPs (keep={keep:.0%}) "
          f"in {t_plan:.2f}s; methods={methods}; "
          f"plan cache: {stats.misses} built, {stats.hits} reused")

    fwd = jax.jit(make_pruned_forward(cfg))
    if microbatch:
        # One compiled microbatch program serves the whole request batch:
        # compile cost is paid for the microbatch shape only, and each
        # slice's batch axis rides the engine's batched plan execution.
        fwd = R.microbatched(fwd, microbatch, argnums=(2,))
    t_cold0 = time.perf_counter()
    with obs.span("serve.forward_cold", cat="serve"):
        logits = jax.block_until_ready(fwd(params, blocks, prompt))
    _serve_latency.labels(phase="cold").observe(
        (time.perf_counter() - t_cold0) * 1e6)
    t1 = time.perf_counter()
    with obs.span("serve.forward_warm", cat="serve"):
        logits = jax.block_until_ready(fwd(params, blocks, prompt))
    t_warm = time.perf_counter() - t1
    _serve_latency.labels(phase="warm").observe(t_warm * 1e6)
    after = engine.cache_stats()
    replans = _check_replans(stats, after)
    mb = f" (microbatch={microbatch})" if microbatch else ""
    print(f"[serve] warm pruned forward{mb} {t_warm * 1e3:.1f}ms "
          f"({prompt.size / t_warm:.0f} tok/s); plans built during "
          f"serving: {replans}")
    return logits


def serve_online(cfg, params, keep: float, args, policy=None) -> int:
    """``--serve``: online continuous batching over the pruned-FFN
    forward.  Ragged Poisson arrivals pack into pre-compiled
    ``(batch, length)`` bucket programs (``repro.serving``); after
    warmup the run must neither replan nor recompile — both asserted.
    """
    from repro import engine, serving
    from repro.serving import loadgen

    check_prunable(cfg)
    with obs.span("serve.plan", cat="serve", keep=keep):
        blocks = prune_ffn_blocks(params, cfg, keep, policy=policy)
    base = make_pruned_forward(cfg)

    def forward(state, tokens):
        p, blk = state
        return base(p, blk, tokens)

    ladder = serving.BucketLadder.from_max(
        args.prompt_len, max(args.batch, 1),
        min_len=min(8, args.prompt_len))
    server = serving.Server(
        forward, (params, blocks), ladder,
        queue_depth=args.serve_queue_depth,
        default_deadline_s=(args.serve_deadline_ms / 1e3
                            if args.serve_deadline_ms else None),
        name="serve.online")
    t0 = time.perf_counter()
    server.warmup()
    shapes = ladder.shapes()
    print(f"[serve] warmed {len(shapes)} bucket programs "
          f"(lengths={ladder.lengths} batches={ladder.batches}) "
          f"in {time.perf_counter() - t0:.2f}s")
    plan_stats = engine.cache_stats()

    rate = args.serve_rate
    if rate <= 0:
        # Auto-rate: drive at ~4x the solo warm-call capacity so the
        # batcher actually batches.
        solo = min(server.probe(ladder.batches[0], ladder.max_len)
                   for _ in range(3))
        rate = 4.0 / solo
        print(f"[serve] auto rate: solo call {solo * 1e3:.1f}ms "
              f"-> offered {rate:.1f} req/s")
    sched = loadgen.poisson_schedule(
        args.serve_requests, rate,
        (max(1, args.prompt_len // 4), args.prompt_len), seed=args.seed)
    server.start()
    report = loadgen.run_load(server, sched, vocab=cfg.vocab_size,
                              seed=args.seed)
    server.stop()
    _check_replans(plan_stats, engine.cache_stats())
    rc = server.recompiles()
    if rc:
        raise RuntimeError(
            f"online serving recompiled {rc} program(s) after warmup — "
            "the bucket ladder must cover every served shape")
    print(f"[serve] online: {report.ok}/{report.n} ok "
          f"({report.shed} shed, {report.error} error) in "
          f"{report.wall_s:.2f}s = {report.throughput_rps:.1f} req/s; "
          f"p50 {report.p50_us / 1e3:.1f}ms p99 "
          f"{report.p99_us / 1e3:.1f}ms; recompiles after warmup: {rc}")
    _export_obs(args)
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--prune-ffn", type=float, default=0.0, metavar="KEEP",
                    help="serve with magnitude-pruned FFNs (CSR SpMM via "
                    "the plan engine); KEEP is the kept fraction per row")
    ap.add_argument("--microbatch", type=int, default=0, metavar="MB",
                    help="score pruned-FFN requests in fixed-size "
                    "microbatches (must divide --batch): one compiled "
                    "program per microbatch shape, batch axis folded into "
                    "the SpMM kernel grid")
    ap.add_argument("--tunedb", default="", metavar="PATH",
                    help="TuneDB JSON (python -m repro.tune) — pruned-FFN "
                    "plans resolve their method from measurements "
                    "instead of the paper's fixed threshold")
    ap.add_argument("--mesh", type=int, default=0, metavar="N",
                    help="shard every pruned-FFN weight over a N-device "
                    "data mesh: nnz-balanced row shards, one local plan "
                    "per shard, executed as a single shard_map program "
                    "(CPU dev boxes: XLA_FLAGS="
                    "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--trace-out", default="", metavar="PATH",
                    help="enable structured tracing and write the Chrome "
                    "trace-event JSON (Perfetto-viewable) here on exit "
                    "(REPRO_TRACE=1 enables tracing without a file)")
    ap.add_argument("--metrics-out", default="", metavar="PATH",
                    help="write a JSON snapshot of the metrics registry "
                    "(latency histograms, plan-cache counters, ladder "
                    "rung rates) here on exit")
    from repro.kernels import registry
    ap.add_argument("--spmm-method", default="auto",
                    choices=("auto",) + registry.method_names(),
                    help="force the SpMM kernel method for pruned-FFN "
                    "plans (any registered method; 'auto' resolves "
                    "through the TuneDB ladder + heuristic)")
    ap.add_argument("--serve", action="store_true",
                    help="online mode: continuous batching of ragged "
                    "Poisson requests over pre-compiled shape-bucket "
                    "programs (requires --prune-ffn); --batch and "
                    "--prompt-len bound the bucket ladder")
    ap.add_argument("--serve-requests", type=int, default=24,
                    metavar="N", help="requests in the Poisson load")
    ap.add_argument("--serve-rate", type=float, default=0.0,
                    metavar="RPS", help="offered load (0 = auto: 4x the "
                    "measured solo-call capacity)")
    ap.add_argument("--serve-deadline-ms", type=float, default=0.0,
                    metavar="MS", help="per-request deadline; expired "
                    "requests are shed, not served (0 = none)")
    ap.add_argument("--serve-queue-depth", type=int, default=64,
                    metavar="N", help="admission queue bound; submits "
                    "beyond it are shed immediately")
    args = ap.parse_args(argv)

    if args.prune_ffn <= 0.0:
        # These flags only shape the pruned-FFN path; silently ignoring
        # them hides typos like a forgotten --prune-ffn.
        dead = [fl for fl, on in (
            ("--serve", args.serve),
            ("--microbatch", args.microbatch != 0),
            ("--mesh", args.mesh != 0),
            ("--spmm-method", args.spmm_method != "auto"),
        ) if on]
        if dead:
            ap.error(f"{', '.join(dead)}: no effect without "
                     "--prune-ffn KEEP (the dense decode path ignores "
                     "these flags); add --prune-ffn or drop them")

    if args.trace_out:
        obs.enable()

    if args.tunedb:
        from repro import engine
        db = engine.load_tunedb(args.tunedb)
        print(f"[serve] tunedb {args.tunedb}: backend={db.backend} "
              f"entries={len(db)} threshold={db.threshold}")

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    assert cfg.input_mode == "tokens", \
        "serve.py drives token models; embeddings-mode archs use the " \
        "prefill/decode steps directly (see examples/)"
    key = jax.random.PRNGKey(args.seed)
    params = M.init_params(cfg, key)
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    if args.prune_ffn > 0.0:
        import dataclasses

        from repro.core import PlanPolicy, ShardSpec
        policy = PlanPolicy(method=args.spmm_method)
        if args.mesh:
            import numpy as np
            from jax.sharding import Mesh
            ndev = len(jax.devices())
            if args.mesh > ndev:
                raise SystemExit(
                    f"--mesh {args.mesh} exceeds the {ndev} local "
                    "device(s); on CPU force more with XLA_FLAGS="
                    f"--xla_force_host_platform_device_count={args.mesh}")
            mesh = Mesh(np.array(jax.devices()[:args.mesh]), ("data",))
            policy = dataclasses.replace(policy,
                                         shards=ShardSpec(mesh=mesh))
            print(f"[serve] sharding pruned-FFN plans over {args.mesh} "
                  f"devices (nnz-balanced row shards)")
        if args.serve:
            return serve_online(cfg, params, args.prune_ffn, args,
                                policy=policy)
        logits = serve_pruned(cfg, params, prompt, args.prune_ffn,
                              microbatch=args.microbatch, policy=policy)
        print(f"pruned-FFN logits {logits.shape}; "
              f"argmax@last {jnp.argmax(logits[:, -1], -1)}")
        _export_obs(args)
        return 0
    t0 = time.perf_counter()
    with obs.span("serve.generate", cat="serve", gen=args.gen):
        out = generate(cfg, params, prompt, args.gen)
    dt = time.perf_counter() - t0
    _serve_latency.labels(phase="generate").observe(dt * 1e6)
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print(out[0, -args.gen:])
    _export_obs(args)
    return 0


def _export_obs(args) -> None:
    if args.trace_out:
        tr = obs.get_tracer()
        if tr is not None:
            print(f"[serve] trace: {tr.export(args.trace_out)} "
                  f"({len(tr)} events)")
    if args.metrics_out:
        print(f"[serve] metrics: {obs.dump_metrics(args.metrics_out)}")


if __name__ == "__main__":
    raise SystemExit(main())
