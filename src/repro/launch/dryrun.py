import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax-importing import: jax locks the device count on
# first backend init.  512 placeholder host devices let jax.make_mesh build
# the production meshes; the dry-run never allocates real buffers.

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture × input shape × mesh) cell::

    with mesh:
        lowered = jax.jit(step, in_shardings=…, out_shardings=…).lower(**specs)
        compiled = lowered.compile()
        print(compiled.memory_analysis())   # proves it fits
        print(compiled.cost_analysis())     # FLOPs/bytes for §Roofline

Failures (sharding mismatch, OOM at compile, unsupported collective) are
bugs in the system.  Results land in results/dryrun/*.json for the
roofline CLI (``python -m benchmarks.roofline``, model in
``repro.obs.roofline``).

Usage:
    python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--out results/dryrun]
"""
import argparse
import json
import time
import traceback

import jax

from repro.analysis import hlo as hlo_stats
from repro.configs import ARCHS, SHAPES, get_config, shape_cells
from repro.distributed import sharding as sh
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs
from repro.optim import adamw
from repro.runtime import steps as R


def default_microbatches(cfg, global_batch: int = 256,
                         dp: int = 16) -> int:
    # keep live activations per device bounded; hillclimbed in §Perf.
    # Constraint: the per-microbatch batch must stay divisible by the DP
    # width or GSPMD pads every activation (half-empty devices — found in
    # §Perf iteration 2 on the 2×16×16 mesh).
    want = 16 if cfg.d_model >= 6144 else 4
    return max(1, min(want, global_batch // dp))


def build_step_and_shardings(arch: str, shape_name: str, mesh, *,
                             microbatches: int | None = None,
                             grad_compression: str = "none",
                             remat: bool = True,
                             param_mode: str = "fsdp",
                             seq_shard: bool = False):
    import dataclasses
    cfg = get_config(arch)
    if seq_shard:  # sequence parallelism for the residual stream
        cfg = dataclasses.replace(cfg,
                                  residual_spec=("dp", "model", None))
    if param_mode == "fsdp2":  # pure ZeRO-3: no TP, batch over every chip
        cfg = dataclasses.replace(cfg, tp=False,
                                  residual_spec=("dpm", None, None))
    shape = SHAPES[shape_name]
    dp = 1
    for a in sh.dp_axes(mesh):
        dp *= mesh.shape[a]
    if param_mode == "fsdp2":
        dp *= mesh.shape["model"]
    mb = microbatches or (
        default_microbatches(cfg, shape.global_batch, dp)
        if shape.kind == "train" else 1)
    specs = input_specs(arch, shape_name, grad_compression, mb, param_mode)
    rep = sh.replicated(mesh)

    def pshard(tree, mode=None):
        m = mode or ("fsdp2" if param_mode == "fsdp2" else "fsdp")
        return sh.params_shardings(tree, mesh, m)

    batch_model = param_mode == "fsdp2"
    if shape.kind == "train":
        step = R.make_train_step(
            cfg, adamw.AdamWConfig(), microbatches=mb, remat=remat,
            grad_compression=grad_compression, param_mode=param_mode)
        opt = specs["state"]["opt"]
        state_sh = {"params": pshard(specs["state"]["params"],
                                     mode="zero1" if param_mode == "zero1"
                                     else None),
                    "opt": {"step": rep,
                            "m": pshard(opt["m"]),
                            "v": pshard(opt["v"])}}
        if "master" in opt:
            state_sh["opt"]["master"] = pshard(opt["master"])
        if "residual" in specs["state"]:
            state_sh["residual"] = pshard(specs["state"]["residual"])
        in_sh = {"state": state_sh,
                 "batch": sh.batch_shardings(specs["batch"], mesh,
                                             batch_axis=1 if mb > 1 else 0,
                                             include_model=batch_model)}
        metrics_sh = jax.tree.map(
            lambda _: rep,
            jax.eval_shape(step, specs["state"], specs["batch"])[1])
        out_sh = (state_sh, metrics_sh)
        return step, specs, in_sh, out_sh, cfg

    if shape.kind == "prefill":
        step = R.make_prefill_step(cfg)
        out_eval = jax.eval_shape(step, specs["params"], specs["batch"])
        in_sh = {"params": pshard(specs["params"]),
                 "batch": sh.batch_shardings(specs["batch"], mesh)}
        out_sh = {"caches": sh.cache_shardings(out_eval["caches"], mesh),
                  "logits": sh.batch_shardings(out_eval["logits"], mesh),
                  "pos": sh.batch_shardings(out_eval["pos"], mesh)}
        return step, specs, in_sh, out_sh, cfg

    # decode
    step = R.make_decode_step(cfg)
    out_eval = jax.eval_shape(step, specs["params"], specs["caches"],
                              specs["batch"], specs["pos"])
    in_sh = {"params": pshard(specs["params"]),
             "caches": sh.cache_shardings(specs["caches"], mesh),
             "batch": sh.batch_shardings(specs["batch"], mesh),
             "pos": sh.batch_shardings(specs["pos"], mesh)}
    out_sh = (sh.batch_shardings(out_eval[0], mesh),
              sh.cache_shardings(out_eval[1], mesh))
    return step, specs, in_sh, out_sh, cfg


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             microbatches: int | None = None,
             grad_compression: str = "none", remat: bool = True,
             param_mode: str = "fsdp", seq_shard: bool = False,
             verbose: bool = True) -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "ok": False,
           "param_mode": param_mode, "seq_shard": seq_shard}
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        step, specs, in_sh, out_sh, cfg = build_step_and_shardings(
            arch, shape_name, mesh, microbatches=microbatches,
            grad_compression=grad_compression, remat=remat,
            param_mode=param_mode, seq_shard=seq_shard)
        with sh.use_mesh(mesh):
            # specs dicts are built in the step functions' positional order
            jitted = jax.jit(step,
                             in_shardings=tuple(in_sh[k] for k in specs),
                             out_shardings=out_sh)
            lowered = jitted.lower(*specs.values())
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        parsed = hlo_stats.parse_module(hlo)  # trip-count-scaled
        fus = hlo_stats.fusion_stats(hlo)
        rec.update(
            ok=True, lower_s=round(t1 - t0, 2), compile_s=round(t2 - t1, 2),
            memory_analysis=_mem_dict(mem),
            cost_analysis={k: float(v) for k, v in (cost or {}).items()
                           if isinstance(v, (int, float))},
            hlo_parsed=parsed, hlo_ops=fus,
            model_params=cfg.param_count(),
            model_params_active=cfg.active_param_count(),
        )
        if verbose:
            print(f"[{arch} × {shape_name} × {mesh_name}] "
                  f"compile {rec['compile_s']}s")
            print("  memory_analysis:", rec["memory_analysis"])
            print(f"  per-device (trip-scaled): "
                  f"flops={parsed['flops']:.3e} "
                  f"hbm={parsed['hbm_bytes']:.3e}B "
                  f"wire={parsed['collective_wire_bytes']:.3e}B "
                  f"({parsed['collective_count']} colls)")
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc(limit=20)
        if verbose:
            print(f"[{arch} × {shape_name} × {mesh_name}] FAILED: "
                  f"{rec['error']}")
    return rec


def _mem_dict(mem) -> dict:
    if mem is None:
        return {}
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            out[attr] = int(v)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8_ef"])
    ap.add_argument("--param-mode", default="fsdp",
                    choices=["fsdp", "zero1", "fsdp2"])
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ARCHS:
            for shp in shape_cells(arch):
                cells.append((arch, shp))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    n_ok = 0
    for arch, shp in cells:
        for mp in meshes:
            rec = run_cell(arch, shp, multi_pod=mp,
                           microbatches=args.microbatches,
                           grad_compression=args.grad_compression,
                           remat=not args.no_remat,
                           param_mode=args.param_mode)
            n_ok += rec["ok"]
            name = f"{arch}__{shp}__{rec['mesh']}.json"
            with open(os.path.join(args.out, name), "w") as f:
                json.dump(rec, f, indent=1)
    total = len(cells) * len(meshes)
    print(f"\ndry-run: {n_ok}/{total} cells compiled")
    raise SystemExit(0 if n_ok == total else 1)


if __name__ == "__main__":
    main()
