"""Production mesh builders.

A FUNCTION (not module-level constant) so importing never touches jax
device state.  Single pod: 16×16 = 256 chips (v5e pod).  Multi-pod: 2 pods
= 512 chips with the "pod" axis outermost (data-parallel across pods over
DCN; hot-spare-pod swap happens at this axis, see distributed/fault.py).
"""
from __future__ import annotations

import jax


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_local_mesh(model: int = 1):
    """Whatever this host has — used by examples/tests (1 CPU device)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"),
                         axis_types=_auto(2))
