"""Production mesh builders.

A FUNCTION (not module-level constant) so importing never touches jax
device state.  Single pod: 16×16 = 256 chips (v5e pod).  Multi-pod: 2 pods
= 512 chips with the "pod" axis outermost (data-parallel across pods over
DCN; hot-spare-pod swap happens at this axis, see distributed/fault.py).
"""
from __future__ import annotations

import jax


def _mesh_kwargs(n):
    # jax.sharding.AxisType landed after 0.4.x; older jax defaults every
    # axis to auto sharding, which is exactly what we want anyway.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_mesh(shape, axes):
    """Version-gated ``jax.make_mesh`` with all-auto axis types."""
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(model: int = 1):
    """Whatever this host has — used by examples/tests (1 CPU device)."""
    n = len(jax.devices())
    return make_mesh((n // model, model), ("data", "model"))
