"""Deprecated location: the HLO parser moved to ``repro.analysis.hlo``.

This silent re-export shim keeps ``repro.launch.hlo_stats`` imports
working (the parser started life beside the launch-path dry-run
validator); new code imports ``repro.analysis.hlo``.
"""
from repro.analysis.hlo import (  # noqa: F401
    CompStats,
    _ARRAY_RE,
    _DTYPE_BYTES,
    _INSTR_RE,
    _type_bytes,
    _wire_factor,
    collective_stats,
    fusion_stats,
    parse_compiled,
    parse_module,
)
