# NOTE: do not import dryrun here — it sets XLA_FLAGS at import time.
from . import mesh, specs

__all__ = ["mesh", "specs"]
