"""LRU cache of SpmmPlans keyed on sparsity-pattern identity.

The amortization layer of the plan-once/execute-many engine: a pruned
weight's pattern is frozen for the lifetime of the model, so every plan
derived from it — forward chunk/ELL structure, heuristic decision,
transpose plan — is built at most once per pattern and shared by every
layer, step, and restart that presents the same mask.

Keys are *content* fingerprints of (row_ptr, col_ind) plus the build
configuration, not object identity — re-pruning with the same mask,
checkpoint restore, or two layers tied to one mask all hit.  Counters
(hits/misses/evictions) are exposed for tests and ops dashboards; the
acceptance criterion "plans are built at most once per pattern in a jitted
loop" is asserted against them in ``tests/test_engine.py``.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
from collections import OrderedDict

from repro import obs as _obs
from repro.analysis import _flags as _verify_flags
from repro.core.config import (DEFAULT_TUNEDB, PlanPolicy, _UNSET,
                               _warn_deprecated)
from repro.core.csr import CSR
from repro.core.plan import SpmmPlan, build_plan, pattern_fingerprint
from repro.obs import trace as _trace

DEFAULT_MAXSIZE = 256

# Cache counters live on the global metrics registry, one labeled child
# per (cache instance, event).  Each child increments under its own lock,
# so executors sharing a cache can never lose counts; ``stats()`` keeps
# presenting them as the historical CacheStats view.
_cache_events = _obs.registry.counter(
    "plan_cache_events_total", "PlanCache events by cache instance",
    labels=("cache", "event"))
_cache_size = _obs.registry.gauge(
    "plan_cache_size", "live entries per PlanCache", labels=("cache",))
_cache_alias_size = _obs.registry.gauge(
    "plan_cache_aliases", "live alias-map entries per PlanCache",
    labels=("cache",))

_cache_ids = itertools.count()

# Legacy sentinel: "no tunedb argument given — use the process default".
_USE_DEFAULT = DEFAULT_TUNEDB


def _verify_hit(plan, a: CSR) -> None:
    """REPRO_VERIFY_PLANS debug hook on cache hits: misses verify inside
    ``build_plan`` itself, but a hit serves a stored plan keyed by content
    fingerprint — re-verify it against the CSR actually presented, so a
    fingerprint collision or stale alias fails here, not in a kernel."""
    from repro.analysis.planlint import check_plan
    check_plan(plan, a)

# Process-wide empirical tuning database (repro.tune.TuneDB).  When set,
# every "auto" plan request resolves its method through measurements
# (exact pattern -> pattern class -> calibrated threshold) instead of the
# paper's fixed K40c threshold.  Host-side only: consulted at plan build,
# never inside jit.
_default_tunedb = None


def set_tunedb(db) -> None:
    """Install (or clear, with None) the process-default TuneDB."""
    global _default_tunedb
    _default_tunedb = db


def current_tunedb():
    return _default_tunedb


def load_tunedb(path, **kw):
    """Load a TuneDB from ``path`` and install it as the process default.

    Forgiving like ``TuneDB.load``: a corrupt/mismatched file installs an
    empty DB (with a warning), so plan building falls back to the
    analytic heuristic rather than crashing the launcher.
    """
    from repro.tune.db import TuneDB

    db = TuneDB.load(path, **kw)
    set_tunedb(db)
    return db


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    size: int = 0
    aliases: int = 0
    alias_evictions: int = 0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class PlanCache:
    """Thread-safe LRU over ``build_plan`` results."""

    def __init__(self, maxsize: int = DEFAULT_MAXSIZE,
                 alias_maxsize: int | None = None,
                 name: str | None = None):
        self.maxsize = maxsize
        # The metric label distinguishing this instance's counters on the
        # global registry (the process-default cache is "default").
        self.name = name if name is not None else f"cache{next(_cache_ids)}"
        self._c_hit = _cache_events.labels(cache=self.name, event="hit")
        self._c_miss = _cache_events.labels(cache=self.name, event="miss")
        self._c_evict = _cache_events.labels(cache=self.name,
                                             event="eviction")
        self._c_alias_evict = _cache_events.labels(
            cache=self.name, event="alias_eviction")
        self._g_size = _cache_size.labels(cache=self.name)
        self._g_aliases = _cache_alias_size.labels(cache=self.name)
        # The alias map is its own (cheap, key-only) LRU: raw request keys
        # embed per-request objects' attributes (heuristic thresholds,
        # TuneDB digests), so a long-lived server cycling those would
        # otherwise grow it without bound even while the plan LRU stays
        # capped (ISSUE 3 satellite).  A few aliases per plan is the
        # steady state; 4x leaves room for method/param spellings.
        self.alias_maxsize = (4 * maxsize if alias_maxsize is None
                              else alias_maxsize)
        self._entries: OrderedDict[tuple, SpmmPlan] = OrderedDict()
        # raw (unresolved) request key -> canonical key, so a hit on a
        # repeated request skips resolve_static's host sync entirely.
        self._aliases: OrderedDict[tuple, tuple] = OrderedDict()
        self._lock = threading.Lock()

    def _alias_insert(self, raw: tuple, key: tuple) -> None:
        # Callers hold self._lock.
        self._aliases[raw] = key
        self._aliases.move_to_end(raw)
        while len(self._aliases) > self.alias_maxsize:
            self._aliases.popitem(last=False)
            self._c_alias_evict.inc()
        self._g_aliases.set(len(self._aliases))

    def get(self, a: CSR, policy: PlanPolicy | None = None, *,
            method=_UNSET, heuristic=_UNSET, t=_UNSET, tl=_UNSET,
            l_pad=_UNSET, with_transpose=_UNSET,
            tunedb=_USE_DEFAULT) -> SpmmPlan:
        """Cached ``build_plan`` — the engine's plan-once entry point.

        The request is a :class:`PlanPolicy` (the bare kwargs remain as a
        pre-v1 spelling and fold into one; mixing both raises).  Canonical
        keys pin down the static decisions through the same
        ``PlanPolicy.resolve`` that ``build_plan`` uses, so "auto" and its
        resolved form share one entry and key/plan can never disagree.
        A raw-request alias map makes repeated identical requests O(1):
        neither the heuristic's host read nor the l_pad scan reruns on a
        hit (the fingerprint itself is memoized per CSR object).

        ``policy.tunedb`` (default: the process-wide DB from
        ``set_tunedb``) resolves "auto" methods from measurements; its
        content digest is part of the raw key, so swapping databases can
        never serve a plan resolved against the old one (explicit
        ``tunedb=None`` opts out).
        """
        legacy = {k: v for k, v in dict(
            method=method, heuristic=heuristic, t=t, tl=tl, l_pad=l_pad,
            with_transpose=with_transpose).items() if v is not _UNSET}
        if tunedb is not _USE_DEFAULT:
            legacy["tunedb"] = tunedb
        if legacy:
            if policy is not None:
                raise ValueError(
                    "PlanCache.get: pass either policy= or the bare kwargs "
                    f"{sorted(legacy)}, not both")
            for k in legacy:
                _warn_deprecated(
                    f"PlanCache.get({k}=...)",
                    f"pass policy=PlanPolicy({k}=...) "
                    "(see README.md: Migrating to API v1)", stacklevel=3)
            policy = PlanPolicy(**legacy)
        elif policy is None:
            policy = PlanPolicy()
        if policy.shards is not None:
            return self._get_sharded(a, policy)
        db = policy.resolved_tunedb()
        if policy.method == "auto":
            hkey = (policy.heuristic.threshold
                    if policy.heuristic is not None else None,
                    db.digest() if db is not None else None)
        else:
            hkey = None
        raw = (pattern_fingerprint(a), a.shape, a.nnz_pad, policy.method,
               hkey, policy.t, policy.tl, policy.l_pad,
               policy.with_transpose)
        with self._lock:
            canonical = self._aliases.get(raw)
            plan = self._entries.get(canonical) if canonical else None
            if plan is not None:
                self._entries.move_to_end(canonical)
                self._aliases.move_to_end(raw)
                self._c_hit.inc()
                if _trace._enabled:
                    _trace.event("cache.hit", cat="cache", cache=self.name,
                                 alias=True, method=plan.meta.method)
                if _verify_flags.verify_plans:
                    _verify_hit(plan, a)
                return plan
        r = policy.resolve(a)
        key = (raw[0], a.shape, a.nnz_pad, r.method, r.t, r.tl, r.l_pad,
               policy.with_transpose)
        with self._lock:
            plan = self._entries.get(key)
            if plan is not None:
                self._entries.move_to_end(key)
                self._alias_insert(raw, key)
                self._c_hit.inc()
                if _trace._enabled:
                    _trace.event("cache.hit", cat="cache", cache=self.name,
                                 alias=False, method=plan.meta.method)
                if _verify_flags.verify_plans:
                    _verify_hit(plan, a)
                return plan
        # Build outside the lock — plans are pure functions of the key.
        if _trace._enabled:
            _trace.event("cache.miss", cat="cache", cache=self.name,
                         method=r.method)
        with _trace.span("plan.build", cat="plan", method=r.method,
                         m=int(a.shape[0]), k=int(a.shape[1]),
                         nnz_pad=int(a.nnz_pad), t=r.t, tl=r.tl,
                         l_pad=r.l_pad):
            plan = build_plan(a, method=r.method, t=r.t, tl=r.tl,
                              l_pad=r.l_pad,
                              with_transpose=policy.with_transpose,
                              _resolved=r)
        with self._lock:
            self._c_miss.inc()
            self._entries[key] = plan
            self._entries.move_to_end(key)
            self._alias_insert(raw, key)
            while len(self._entries) > self.maxsize:
                evicted, _ = self._entries.popitem(last=False)
                self._aliases = OrderedDict(
                    (r, c) for r, c in self._aliases.items() if c != evicted)
                self._c_evict.inc()
                if _trace._enabled:
                    _trace.event("cache.eviction", cat="cache",
                                 cache=self.name)
            self._g_size.set(len(self._entries))
            self._g_aliases.set(len(self._aliases))
        return plan

    def _get_sharded(self, a: CSR, policy: PlanPolicy):
        """Cached sharded-plan build (``policy.shards`` set).

        The sharded plan is one cache entry keyed on the *global* pattern
        plus the full shard spec (count, dim, axis, mesh), while every
        per-shard local plan lands as its own entry keyed on the shard's
        fingerprint (``build_sharded_plan`` funnels them back through
        ``get``).  Because the shard spec is in the key, re-sharding the
        same matrix over a different mesh size builds a sibling entry —
        it can never poison, nor be served from, the old one.
        """
        spec = policy.shards
        db = policy.resolved_tunedb()
        if policy.method == "auto":
            hkey = (policy.heuristic.threshold
                    if policy.heuristic is not None else None,
                    db.digest() if db is not None else None)
        else:
            hkey = None
        key = (pattern_fingerprint(a), a.shape, a.nnz_pad, "sharded",
               spec.resolved_n(), spec.dim, spec.axis, spec.mesh,
               policy.method, hkey, policy.t, policy.tl, policy.l_pad,
               policy.with_transpose)
        with self._lock:
            plan = self._entries.get(key)
            if plan is not None:
                self._entries.move_to_end(key)
                self._c_hit.inc()
                if _trace._enabled:
                    _trace.event("cache.hit", cat="cache", cache=self.name,
                                 alias=False, sharded=True)
                if _verify_flags.verify_plans:
                    _verify_hit(plan, a)
                return plan
        # Build outside the lock; the per-shard plans recurse through
        # self.get (each takes the lock for its own entry).
        from repro.distributed.spmm import build_sharded_plan

        if _trace._enabled:
            _trace.event("cache.miss", cat="cache", cache=self.name,
                         sharded=True)
        plan = build_sharded_plan(a, policy, cache=self)
        with self._lock:
            self._c_miss.inc()
            self._entries[key] = plan
            self._entries.move_to_end(key)
            while len(self._entries) > self.maxsize:
                evicted, _ = self._entries.popitem(last=False)
                self._aliases = OrderedDict(
                    (r, c) for r, c in self._aliases.items() if c != evicted)
                self._c_evict.inc()
                if _trace._enabled:
                    _trace.event("cache.eviction", cat="cache",
                                 cache=self.name)
            self._g_size.set(len(self._entries))
        return plan

    # ------------------------------------------------------ maintenance ---

    def stats(self) -> CacheStats:
        """The historical attribute view, assembled from the registry's
        per-instance children (still the API tests and callers use)."""
        return CacheStats(
            hits=self._c_hit.value, misses=self._c_miss.value,
            evictions=self._c_evict.value,
            size=int(self._g_size.value),
            aliases=int(self._g_aliases.value),
            alias_evictions=self._c_alias_evict.value)

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._aliases.clear()
            for c in (self._c_hit, self._c_miss, self._c_evict,
                      self._c_alias_evict, self._g_size, self._g_aliases):
                c.reset()

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)


_default_cache = PlanCache(name="default")


def default_cache() -> PlanCache:
    return _default_cache


def get_plan(a: CSR, policy: PlanPolicy | None = None, **kw) -> SpmmPlan:
    """Module-level convenience over the process-wide default cache."""
    return _default_cache.get(a, policy, **kw)


def cache_stats() -> CacheStats:
    return _default_cache.stats()


def clear_cache() -> None:
    _default_cache.clear()
