"""Plan-once / execute-many SpMM engine.

    plan = repro.engine.get_plan(a)            # cached per pattern
    c = repro.core.spmm(a, b, plan=plan)       # never replans, jit-safe

See ``repro.core.plan`` for what a plan holds and ``engine.cache`` for the
LRU keyed on pattern fingerprints.
"""
from .cache import (CacheStats, PlanCache, cache_stats, clear_cache,
                    default_cache, get_plan)

__all__ = ["CacheStats", "PlanCache", "cache_stats", "clear_cache",
           "default_cache", "get_plan"]
