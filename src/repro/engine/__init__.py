"""Plan-once / execute-many SpMM engine.

    plan = repro.engine.get_plan(a)            # cached per pattern
    plan = repro.engine.get_plan(a, repro.PlanPolicy(method="rowgroup"))
    c = repro.spmm(a, b, plan=plan)            # never replans, jit-safe

    engine.load_tunedb("tune.json")            # measured kernel selection
    plan = repro.engine.get_plan(a)            # exact/class/threshold

See ``repro.core.plan`` for what a plan holds, ``repro.core.config`` for
``PlanPolicy`` (the plan request object), ``engine.cache`` for the LRU
keyed on pattern fingerprints, and ``repro.tune`` for building the TuneDB
that replaces the analytic heuristic with measurements.
"""
from .cache import (CacheStats, PlanCache, cache_stats, clear_cache,
                    current_tunedb, default_cache, get_plan, load_tunedb,
                    set_tunedb)
from .programs import ProgramCache, ProgramStats

__all__ = ["CacheStats", "PlanCache", "ProgramCache", "ProgramStats",
           "cache_stats", "clear_cache", "current_tunedb",
           "default_cache", "get_plan", "load_tunedb", "set_tunedb"]
