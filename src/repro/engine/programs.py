"""Bucket-keyed compiled-program cache — the serving twin of PlanCache.

The plan cache amortizes *pattern*-derived work (chunk layout, kernel
choice); online serving adds a second static axis, the request shape.  A
:class:`ProgramCache` pins each key — for the serving layer, a
``(batch, length)`` shape bucket — to one AOT-compiled executable
(``jax.jit(fn).lower(...).compile()``), with hit/miss/eviction counters
on the global metrics registry (``program_cache_events_total{cache,
event}`` / ``program_cache_size{cache}``), so a serving loop can assert
"zero recompiles after warmup" the same way the engine asserts "zero
replans in a jitted step" — against a counter, not a hope.

The cache itself is compilation-agnostic: ``get(key, build)`` runs
``build()`` on a miss outside the lock (compiles are long; concurrent
misses on *different* keys must not serialize) and double-checks the
entry before inserting, so two threads racing the same key do at most
one redundant compile and share one stored program.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
from collections import OrderedDict
from collections.abc import Callable, Hashable

from repro import obs as _obs

DEFAULT_MAXSIZE = 64

_prog_events = _obs.registry.counter(
    "program_cache_events_total",
    "ProgramCache events by cache instance", labels=("cache", "event"))
_prog_size = _obs.registry.gauge(
    "program_cache_size", "live entries per ProgramCache",
    labels=("cache",))

_prog_ids = itertools.count()


@dataclasses.dataclass
class ProgramStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    size: int = 0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class ProgramCache:
    """Thread-safe LRU of compiled programs keyed on static shape keys."""

    def __init__(self, maxsize: int = DEFAULT_MAXSIZE,
                 name: str | None = None):
        self.maxsize = maxsize
        self.name = name if name is not None else \
            f"programs{next(_prog_ids)}"
        self._c_hit = _prog_events.labels(cache=self.name, event="hit")
        self._c_miss = _prog_events.labels(cache=self.name, event="miss")
        self._c_evict = _prog_events.labels(cache=self.name,
                                            event="eviction")
        self._g_size = _prog_size.labels(cache=self.name)
        self._entries: OrderedDict[Hashable, object] = OrderedDict()
        self._lock = threading.Lock()

    def get(self, key: Hashable, build: Callable[[], object]):
        """The program for ``key``; a miss runs ``build()`` (outside the
        lock) and caches its result."""
        with self._lock:
            prog = self._entries.get(key)
            if prog is not None:
                self._entries.move_to_end(key)
                self._c_hit.inc()
                return prog
        prog = build()
        with self._lock:
            raced = self._entries.get(key)
            if raced is not None:
                # Another thread built the same key first — count our
                # build as the miss it was, serve the stored program.
                self._entries.move_to_end(key)
                self._c_miss.inc()
                return raced
            self._c_miss.inc()
            self._entries[key] = prog
            while len(self._entries) > self.maxsize:
                self._entries.popitem(last=False)
                self._c_evict.inc()
            self._g_size.set(len(self._entries))
        return prog

    def keys(self) -> list:
        with self._lock:
            return list(self._entries)

    def stats(self) -> ProgramStats:
        return ProgramStats(
            hits=self._c_hit.value, misses=self._c_miss.value,
            evictions=self._c_evict.value, size=int(self._g_size.value))

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            for c in (self._c_hit, self._c_miss, self._c_evict,
                      self._g_size):
                c.reset()

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)
