"""Batched, K-tiled plan execution — and the silent-wrong-answer guards.

Tentpole acceptance (ISSUE 3): ``execute_plan`` on ``(batch, k, n)`` B and
``jax.vmap(execute_plan)`` agree with a stacked per-matrix loop to 1e-5
(f32), gradients included, through both kernel methods and both impls;
K-tiled kernels bit-match the whole-K dataflow when a single panel covers
``k``.  Satellites: undersized ``l_pad`` raises instead of truncating,
conflicting plan overrides raise instead of being ignored, degenerate
patterns (0-nnz, 0-row, 1-row) execute and differentiate.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.sparse as S
from repro.core import (CSR, ExecutionConfig, PlanPolicy, build_plan,
                        execute_plan, random_csr, spmm)
from repro.kernels import ref

EC = ExecutionConfig  # keep call sites within the line limit

TOL = dict(rtol=1e-5, atol=1e-5)
METHODS = ["merge", "rowsplit"]
IMPLS = ["xla", "pallas"]
BATCH = 3


def _case(seed=0, m=40, k=32, n=16, npr=(0, 10)):
    a = random_csr(jax.random.PRNGKey(seed), m, k, nnz_per_row=npr)
    bs = jax.random.normal(jax.random.PRNGKey(seed + 1), (BATCH, k, n))
    w = jax.random.normal(jax.random.PRNGKey(seed + 2), (BATCH, m, n))
    return a, bs, w


def _loop(plan, vals, bs, impl):
    return jnp.stack([execute_plan(plan, vals, bs[i], EC(impl=impl))
                      for i in range(bs.shape[0])])


# ------------------------------------------------------- batched forward ---


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("method", METHODS)
def test_batched_matches_per_matrix_loop(method, impl):
    a, bs, _ = _case()
    plan = build_plan(a, method=method)
    got = execute_plan(plan, a.vals, bs, EC(impl=impl))
    want = _loop(plan, a.vals, bs, impl)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)
    dense = np.asarray(a.to_dense())
    np.testing.assert_allclose(
        np.asarray(got), np.stack([dense @ np.asarray(bs[i])
                                   for i in range(BATCH)]), **TOL)


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("method", METHODS)
def test_vmap_matches_per_matrix_loop(method, impl):
    a, bs, _ = _case(seed=3)
    plan = build_plan(a, method=method)
    got = jax.vmap(lambda b: execute_plan(plan, a.vals, b, EC(impl=impl)))(bs)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(_loop(plan, a.vals, bs, impl)),
                               **TOL)


def test_batched_under_jit_and_leading_dims():
    """Extra leading dims fold into one batch axis; jit changes nothing."""
    a, bs, _ = _case(seed=4)
    plan = build_plan(a, method="merge")
    b4 = jnp.stack([bs, 2.0 * bs])                 # (2, BATCH, k, n)
    got = jax.jit(lambda v, b: execute_plan(plan, v, b, EC(impl="pallas")))(
        a.vals, b4)
    assert got.shape == (2, BATCH, a.m, bs.shape[-1])
    np.testing.assert_allclose(np.asarray(got[1]),
                               2 * np.asarray(got[0]), **TOL)
    np.testing.assert_allclose(
        np.asarray(got[0]), np.asarray(_loop(plan, a.vals, bs, "pallas")),
        **TOL)


def test_stale_plan_shape_guard_batched():
    a, bs, _ = _case(seed=5)
    plan = build_plan(a, method="merge")
    with pytest.raises(ValueError, match="expects B of shape"):
        execute_plan(plan, a.vals, bs[:, :-1])     # wrong k
    with pytest.raises(ValueError, match="expects B of shape"):
        execute_plan(plan, a.vals, bs[0, :, 0])    # 1-D


# ------------------------------------------------------------- gradients ---


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("method", METHODS)
def test_batched_grad_matches_loop(method, impl):
    """Shared-values grads: batched == sum-over-stack loop, dB per element."""
    a, bs, w = _case(seed=6)
    plan = build_plan(a, method=method)

    def loss(vals, b):
        return jnp.sum(execute_plan(plan, vals, b, EC(impl=impl)) * w)

    def loss_loop(vals, b):
        return sum(
            jnp.sum(execute_plan(plan, vals, b[i], EC(impl=impl)) * w[i])
            for i in range(BATCH))

    gv, gb = jax.grad(loss, argnums=(0, 1))(a.vals, bs)
    wv, wb = jax.grad(loss_loop, argnums=(0, 1))(a.vals, bs)
    np.testing.assert_allclose(np.asarray(gv), np.asarray(wv), **TOL)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(wb), **TOL)


@pytest.mark.parametrize("method", METHODS)
def test_grad_of_vmap_matches_loop(method):
    a, bs, w = _case(seed=7)
    plan = build_plan(a, method=method)

    def loss(vals, b):
        out = jax.vmap(lambda bi: execute_plan(plan, vals, bi,
                                               EC(impl="pallas")))(b)
        return jnp.sum(out * w)

    def loss_loop(vals, b):
        return sum(jnp.sum(execute_plan(plan, vals, b[i],
                                        EC(impl="pallas")) * w[i])
                   for i in range(BATCH))

    gv, gb = jax.grad(loss, argnums=(0, 1))(a.vals, bs)
    wv, wb = jax.grad(loss_loop, argnums=(0, 1))(a.vals, bs)
    np.testing.assert_allclose(np.asarray(gv), np.asarray(wv), **TOL)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(wb), **TOL)


@pytest.mark.parametrize("method", METHODS)
def test_vmap_of_grad_per_example(method):
    """Per-example value-grads under vmap(grad) match the explicit stack."""
    a, bs, w = _case(seed=8)
    plan = build_plan(a, method=method)

    def one_loss(vals, b, wi):
        return jnp.sum(execute_plan(plan, vals, b, EC(impl="pallas")) * wi)

    per = jax.vmap(jax.grad(one_loss), in_axes=(None, 0, 0))(a.vals, bs, w)
    want = jnp.stack([jax.grad(one_loss)(a.vals, bs[i], w[i])
                      for i in range(BATCH)])
    np.testing.assert_allclose(np.asarray(per), np.asarray(want), **TOL)


def test_batched_grad_matches_dense_oracle():
    a, bs, w = _case(seed=9)
    plan = build_plan(a, method="merge")
    row_ptr, col_ind, shape = a.row_ptr, a.col_ind, a.shape

    def dense_loss(vals, b):
        dense = CSR(row_ptr, col_ind, vals, shape).to_dense()
        return jnp.sum(jnp.einsum("mk,bkn->bmn", dense, b) * w)

    gv, gb = jax.grad(
        lambda v, b: jnp.sum(execute_plan(plan, v, b, EC(impl="pallas")) * w),
        argnums=(0, 1))(a.vals, bs)
    wv, wb = jax.grad(dense_loss, argnums=(0, 1))(a.vals, bs)
    np.testing.assert_allclose(np.asarray(gv), np.asarray(wv),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(wb),
                               rtol=1e-4, atol=1e-5)


# -------------------------------------------------------------- K-tiling ---


@pytest.mark.parametrize("method", METHODS)
def test_ktile_bitmatches_whole_k(method):
    """Default tk covers small k in one panel == explicit whole-k panel,
    bit for bit (the unsplit kernel's exact dataflow)."""
    a = random_csr(jax.random.PRNGKey(10), 48, 96, nnz_per_row=(0, 12))
    b = jax.random.normal(jax.random.PRNGKey(11), (96, 128))
    plan = build_plan(a, method=method)
    o_default = execute_plan(plan, a.vals, b, EC(impl="pallas"))
    o_whole = execute_plan(plan, a.vals, b, EC(impl="pallas", tk=96))
    np.testing.assert_array_equal(np.asarray(o_default), np.asarray(o_whole))


@pytest.mark.parametrize("method", METHODS)
def test_ktile_bitmatch_on_mini_suite(method):
    """Acceptance: K-tiled kernels bit-match the whole-K dataflow on the
    mini corpus (every mini k fits one default panel)."""
    from repro.matrices.suites import get_suite
    rng = np.random.default_rng(23)
    for spec in get_suite("mini"):
        a = spec()
        vals = jnp.asarray(rng.standard_normal(a.nnz_pad), jnp.float32)
        b = jnp.asarray(rng.standard_normal((a.k, 128)), jnp.float32)
        plan = build_plan(a, method=method, with_transpose=False)
        o_default = execute_plan(plan, vals, b, EC(impl="pallas"))
        o_whole = execute_plan(plan, vals, b, EC(impl="pallas", tk=a.k))
        np.testing.assert_array_equal(np.asarray(o_default),
                                      np.asarray(o_whole), err_msg=spec.name)
        dense = CSR(a.row_ptr, a.col_ind, vals, a.shape).to_dense()
        np.testing.assert_allclose(np.asarray(o_default),
                                   np.asarray(dense @ b), rtol=3e-5,
                                   atol=3e-5, err_msg=spec.name)


@pytest.mark.parametrize("tk", [8, 24, 64])
@pytest.mark.parametrize("method", METHODS)
def test_ktile_stream_matches_oracle(method, tk):
    """Forcing multiple K panels (accumulator carry) stays correct."""
    a, bs, w = _case(seed=12, k=96, npr=(0, 20))
    plan = build_plan(a, method=method)
    got = execute_plan(plan, a.vals, bs, EC(impl="pallas", tk=tk))
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(_loop(plan, a.vals, bs, "pallas")),
                               **TOL)
    gv = jax.grad(lambda v: jnp.sum(
        execute_plan(plan, v, bs, EC(impl="pallas", tk=tk)) * w))(a.vals)
    wv = jax.grad(lambda v: jnp.sum(
        execute_plan(plan, v, bs, EC(impl="xla")) * w))(a.vals)
    np.testing.assert_allclose(np.asarray(gv), np.asarray(wv), **TOL)


def test_resolve_tk_bounds_vmem():
    from repro.kernels.merge_spmm import DEFAULT_TK_MAX, resolve_tk
    assert resolve_tk(64, None) == (64, 1)
    assert resolve_tk(65, None) == (72, 1)           # sublane-padded
    tk, n_k = resolve_tk(29568, None)                # Qwen2-72B d_in
    assert tk == DEFAULT_TK_MAX and n_k * tk >= 29568
    assert resolve_tk(100, 16) == (16, 7)
    assert resolve_tk(100, 3) == (8, 13)             # sublane floor


# --------------------------------------------------- degenerate patterns ---


def _degenerates():
    return {
        "zero_nnz": CSR(jnp.zeros(5, jnp.int32), jnp.zeros(0, jnp.int32),
                        jnp.zeros(0), (4, 8)),
        "pad_only": CSR(jnp.zeros(5, jnp.int32), jnp.zeros(3, jnp.int32),
                        jnp.zeros(3), (4, 8)),
        "zero_rows": CSR(jnp.zeros(1, jnp.int32), jnp.zeros(2, jnp.int32),
                         jnp.zeros(2), (0, 8)),
        "one_row": random_csr(jax.random.PRNGKey(13), 1, 8, nnz_per_row=4),
    }


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("method", METHODS)
@pytest.mark.parametrize("name", sorted(_degenerates()))
def test_degenerate_forward_and_grad(name, method, impl):
    """0-nnz, 0-row, and 1-row patterns execute and differentiate, 2-D and
    batched (the sddmm 0-nnz reshape crash and m=0 plan crash, ISSUE 3)."""
    a = _degenerates()[name]
    b = jax.random.normal(jax.random.PRNGKey(14), (8, 16))
    bs = jax.random.normal(jax.random.PRNGKey(15), (2, 8, 16))
    dense = np.asarray(a.to_dense())
    plan = build_plan(a, method=method)
    got = execute_plan(plan, a.vals, b, EC(impl=impl))
    np.testing.assert_allclose(np.asarray(got), dense @ np.asarray(b), **TOL)
    got3 = execute_plan(plan, a.vals, bs, EC(impl=impl))
    assert got3.shape == (2, a.m, 16)
    w = jnp.ones((2, a.m, 16))
    gv, gb = jax.grad(
        lambda v, bb: jnp.sum(execute_plan(plan, v, bb, EC(impl=impl)) * w),
        argnums=(0, 1))(a.vals, bs)
    assert gv.shape == a.vals.shape and gb.shape == bs.shape
    nnz = int(np.asarray(a.row_ptr)[-1])
    assert not np.any(np.asarray(gv)[nnz:]), \
        "padded values received nonzero cotangents"


def test_degenerate_through_spmm_api():
    for name, a in _degenerates().items():
        b = jax.random.normal(jax.random.PRNGKey(16), (8, 16))
        got = spmm(a, b, exec=EC(impl="xla"))
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(a.to_dense()) @ np.asarray(b),
                                   err_msg=name, **TOL)


# ------------------------------------------- silent-wrong-answer guards ---


def test_undersized_l_pad_raises():
    """A 16-long row with l_pad=8 must raise, not silently truncate."""
    a = random_csr(jax.random.PRNGKey(17), 8, 32, nnz_per_row=16)
    b = jax.random.normal(jax.random.PRNGKey(18), (32, 8))
    with pytest.raises(ValueError, match="silently drop"):
        build_plan(a, method="rowsplit", l_pad=8)
    with pytest.raises(ValueError, match="silently drop"):
        spmm(a, b, PlanPolicy(method="rowsplit", l_pad=8))
    with pytest.raises(ValueError, match="silently drop"):
        spmm(a, b, PlanPolicy(method="rowsplit", l_pad=8), plan="inline")
    # exact bound and larger are fine
    for lp in (16, 24):
        got = spmm(a, b, PlanPolicy(method="rowsplit", l_pad=lp),
                   EC(impl="xla"))
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(ref.spmm_dense_ref(a, b)),
                                   **TOL)


def test_plan_override_conflicts_raise():
    a, bs, _ = _case(seed=19)
    b = bs[0]
    plan = build_plan(a, method="merge")
    with pytest.raises(ValueError, match="conflict"):
        spmm(a, b, PlanPolicy(method="rowsplit"), plan=plan)
    with pytest.raises(ValueError, match="conflict"):
        spmm(a, b, PlanPolicy(t=plan.meta.t + 1), plan=plan)
    with pytest.raises(ValueError, match="conflict"):
        spmm(a, b, PlanPolicy(l_pad=64), plan=plan)
    # agreeing overrides execute fine
    got = spmm(a, b, PlanPolicy(method="merge", t=plan.meta.t),
               EC(impl="xla"), plan=plan)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.spmm_dense_ref(a, b)), **TOL)
    rplan = build_plan(a, method="rowsplit")
    with pytest.raises(ValueError, match="conflict"):
        spmm(a, b, PlanPolicy(l_pad=(rplan.meta.l_pad or 0) + 1),
             plan=rplan)


def test_inline_batched_b_raises():
    a, bs, _ = _case(seed=20)
    with pytest.raises(ValueError, match="prebuilt plan"):
        spmm(a, bs, PlanPolicy(method="merge"), plan="inline")


# ------------------------------------------------- SparseLinear batching ---


def test_sparse_linear_batched_path_matches_flat(monkeypatch):
    rng = np.random.default_rng(21)
    w = jnp.asarray(rng.standard_normal((24, 32)), jnp.float32)
    sl = S.SparseLinear.from_dense(w, 0.25)
    x = jnp.asarray(rng.standard_normal((2, 5, 24)), jnp.float32)
    flat = sl(x, EC(impl="xla"))
    monkeypatch.setattr(S, "BATCHED_MIN_TOKENS", 1)
    for impl in IMPLS:
        np.testing.assert_allclose(np.asarray(sl(x, EC(impl=impl))),
                                   np.asarray(flat), **TOL)
    g_b = jax.grad(lambda xx: jnp.sum(sl(xx, EC(impl="xla")) ** 2))(x)
    monkeypatch.setattr(S, "BATCHED_MIN_TOKENS", 128)
    g_f = jax.grad(lambda xx: jnp.sum(sl(xx, EC(impl="xla")) ** 2))(x)
    np.testing.assert_allclose(np.asarray(g_b), np.asarray(g_f), **TOL)


def test_sparse_linear_vmap():
    """jax.vmap over a SparseLinear call is first-class."""
    rng = np.random.default_rng(22)
    w = jnp.asarray(rng.standard_normal((16, 24)), jnp.float32)
    sl = S.SparseLinear.from_dense(w, 0.3)
    x = jnp.asarray(rng.standard_normal((4, 6, 16)), jnp.float32)
    got = jax.vmap(lambda xi: sl(xi, EC(impl="pallas")))(x)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(sl(x, EC(impl="xla"))), **TOL)


# ----------------------------------------------------------- microbatching ---


def test_microbatched_runner():
    from repro.runtime import steps as R
    calls = []

    @jax.jit
    def fn(x, y):
        return {"out": x * 2.0 + y}

    def counted(x, y):
        calls.append(x.shape)
        return fn(x, y)

    x = jnp.arange(12.0).reshape(6, 2)
    y = jnp.ones((2,))
    run = R.microbatched(counted, 2, argnums=(0,))
    out = run(x, y)
    np.testing.assert_allclose(np.asarray(out["out"]),
                               np.asarray(x) * 2 + 1)
    assert calls == [(2, 2)] * 3
    strict = R.microbatched(counted, 2, argnums=(0,), pad=False)
    with pytest.raises(ValueError, match="does not divide"):
        strict(jnp.ones((5, 2)), y)
    with pytest.raises(ValueError, match="positive"):
        R.microbatched(fn, 0)
