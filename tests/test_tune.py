"""Autotuning subsystem: TuneDB persistence + resolution ladder +
calibrate edge cases + engine integration.

Acceptance (ISSUE 2): ``engine.get_plan`` with a TuneDB built on this
backend selects the oracle-faster method on >= 90% of the mini-suite —
asserted via *recorded* timings (the records below), not live
benchmarking.  The recorded oracles are chosen to contradict the analytic
heuristic where possible, so the assertion can only pass if the DB (not
the fallback) drives the decision.
"""
import json

import numpy as np
import pytest

from repro import engine
from repro.core import Heuristic, PlanPolicy, build_plan, calibrate
from repro.core.plan import pattern_fingerprint
from repro.engine.cache import PlanCache
from repro.matrices import compute_stats, get_suite, power_law, uniform
from repro.tune import SCHEMA_VERSION, TuneDB, TuneRecord, tune_suite


def _rec(method, merge_us, rowsplit_us, a, **kw):
    s = compute_stats(a)
    return TuneRecord(method=method, merge_us=merge_us,
                      rowsplit_us=rowsplit_us, m=s.m, k=s.k, d=s.d,
                      cv=s.cv, n=64, **kw)


def _mini_db_with_recorded_timings():
    """TuneDB over the mini suite from recorded (synthetic) timings whose
    oracle contradicts the paper-threshold heuristic on every matrix."""
    db = TuneDB(backend="test")
    oracles = {}
    for spec in get_suite("mini"):
        a = spec()
        d = compute_stats(a).d
        analytic = Heuristic().choose(a)
        oracle = "rowsplit" if analytic == "merge" else "merge"
        merge_us, rowsplit_us = (50.0, 100.0) if oracle == "merge" \
            else (100.0, 50.0)
        db.record(pattern_fingerprint(a),
                  _rec(oracle, merge_us, rowsplit_us, a, name=spec.name))
        oracles[spec.name] = (a, oracle, analytic, d)
    return db, oracles


# -------------------------------------------------------- persistence ---


def test_tunedb_roundtrip(tmp_path):
    db = TuneDB(backend="test")
    a = uniform(0, 32, 32, 4)
    db.record("fp0", _rec("merge", 10.0, 20.0, a, t=16, name="u"))
    db.record("fp1", _rec("rowsplit", 30.0, 15.0, a, l_pad=7))
    db.calibrate_threshold()
    path = tmp_path / "tune.json"
    db.save(path)
    back = TuneDB.load(path, backend="test")
    assert back.as_dict() == db.as_dict()
    assert back.digest() == db.digest()
    assert back.lookup_exact("fp1").l_pad == 7
    assert back.threshold == db.threshold


def test_tunedb_schema_version_mismatch(tmp_path):
    path = tmp_path / "tune.json"
    raw = {"schema_version": SCHEMA_VERSION + 1, "backend": "test",
           "entries": {}}
    path.write_text(json.dumps(raw))
    with pytest.warns(UserWarning, match="schema version"):
        db = TuneDB.load(path, backend="test")
    assert len(db) == 0
    # analytic-heuristic fallback still functions on the empty DB
    assert db.choose(uniform(1, 16, 64, 2)) == "merge"
    with pytest.raises(ValueError, match="schema version"):
        TuneDB.load(path, backend="test", strict=True)


def test_tunedb_corrupt_file_falls_back(tmp_path):
    path = tmp_path / "tune.json"
    path.write_text("{this is not json")
    with pytest.warns(UserWarning, match="corrupt"):
        db = TuneDB.load(path, backend="test")
    assert len(db) == 0
    a = uniform(2, 16, 64, 30)
    assert db.choose(a) == Heuristic().choose(a) == "rowsplit"


def test_tunedb_backend_mismatch(tmp_path):
    db = TuneDB(backend="tpu:v5e")
    db.record("fp", _rec("merge", 1.0, 2.0, uniform(0, 8, 8, 2)))
    path = tmp_path / "tune.json"
    db.save(path)
    with pytest.warns(UserWarning, match="backend"):
        loaded = TuneDB.load(path, backend="cpu:cpu")
    assert len(loaded) == 0
    assert len(TuneDB.load(path, backend="tpu:v5e")) == 1


def test_tunedb_malformed_entry(tmp_path):
    path = tmp_path / "tune.json"
    raw = {"schema_version": SCHEMA_VERSION, "backend": "test",
           "entries": {"fp": {"not_a_field": 1}}}
    path.write_text(json.dumps(raw))
    with pytest.warns(UserWarning, match="malformed"):
        db = TuneDB.load(path, backend="test")
    assert len(db) == 0


def test_tunedb_missing_file_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        TuneDB.load(tmp_path / "absent.json", backend="test")


# -------------------------------------------------- resolution ladder ---


def test_resolve_exact_beats_class_beats_threshold():
    db = TuneDB(backend="test")
    a = power_law(7, 256, 256, 4.0)
    twin = power_law(8, 256, 256, 4.0)       # same class, other pattern
    # Class evidence says rowsplit (from the twin)...
    db.record(pattern_fingerprint(twin),
              _rec("rowsplit", 100.0, 50.0, twin))
    assert db.resolve(a) == ("rowsplit", "class")
    # ...but an exact record for `a` itself says merge, and wins.
    db.record(pattern_fingerprint(a), _rec("merge", 50.0, 100.0, a))
    assert db.resolve(a) == ("merge", "exact")
    # A pattern in a class nobody tuned falls through to the threshold.
    far = uniform(9, 16, 2048, 512)
    method, src = db.resolve(far)
    assert (method, src) == (None, "miss")
    assert db.choose(far) == db.heuristic().choose(far)


def test_class_majority_vote():
    db = TuneDB(backend="test")
    sig = None
    for seed, (method, mu, ru) in enumerate(
            [("merge", 10, 20), ("merge", 10, 20), ("rowsplit", 20, 10)]):
        a = power_law(20 + seed, 256, 256, 4.0)
        rec = _rec(method, float(mu), float(ru), a)
        sig = rec.signature
        db.record(pattern_fingerprint(a), rec)
    assert db.lookup_class(sig) == "merge"


def test_calibrated_threshold_fallback():
    db = TuneDB(backend="test")
    # Recorded timings: merge wins up to d=8, rowsplit after — so the
    # calibrated threshold lands in (8, 16], far from the paper's 9.35
    # being the point; d=12 would flip under threshold=13 vs 9.35.
    for seed, (d, mu, ru) in enumerate(
            [(2, 10, 30), (4, 10, 30), (8, 10, 30), (16, 30, 10),
             (32, 30, 10)]):
        a = uniform(seed, 64, 64, d)
        db.record(pattern_fingerprint(a),
                  _rec("merge" if mu < ru else "rowsplit",
                       float(mu), float(ru), a))
    thr, acc = db.calibrate_threshold()
    assert 8.0 < thr <= 16.0 and acc == 1.0
    assert db.heuristic().threshold == thr


def test_record_overwrite_updates_class_aggregate():
    db = TuneDB(backend="test")
    a = power_law(30, 256, 256, 4.0)
    rec = _rec("merge", 10.0, 20.0, a)
    db.record("fp", rec)
    assert db.lookup_class(rec.signature) == "merge"
    db.record("fp", _rec("rowsplit", 20.0, 10.0, a))
    assert db.lookup_class(rec.signature) == "rowsplit"
    assert len(db) == 1


def test_digest_tracks_content():
    db = TuneDB(backend="test")
    d0 = db.digest()
    db.record("fp", _rec("merge", 1.0, 2.0, uniform(0, 8, 8, 2)))
    d1 = db.digest()
    assert d0 != d1
    db.calibrate_threshold()
    assert db.digest() != d1


# ------------------------------------------------ calibrate edge cases ---


def test_calibrate_tied_timings():
    ds = np.array([2.0, 8.0, 32.0])
    same = np.array([10.0, 10.0, 10.0])
    thr, acc = calibrate(ds, same, same)
    assert acc == 1.0 and np.isfinite(thr)


def test_calibrate_single_point():
    thr, acc = calibrate(np.array([5.0]), rowsplit_us=np.array([20.0]),
                         merge_us=np.array([10.0]))
    assert acc == 1.0 and thr > 5.0
    thr, acc = calibrate(np.array([5.0]), rowsplit_us=np.array([10.0]),
                         merge_us=np.array([20.0]))
    assert acc == 1.0 and thr <= 5.0


def test_calibrate_all_merge_oracle():
    ds = np.array([2.0, 8.0, 32.0])
    thr, acc = calibrate(ds, rowsplit_us=np.full(3, 20.0),
                         merge_us=np.full(3, 10.0))
    assert acc == 1.0 and thr > ds.max()


def test_calibrate_all_rowsplit_oracle():
    ds = np.array([2.0, 8.0, 32.0])
    thr, acc = calibrate(ds, rowsplit_us=np.full(3, 10.0),
                         merge_us=np.full(3, 20.0))
    assert acc == 1.0 and thr <= ds.min()


# ------------------------------------------------- engine integration ---


def test_get_plan_selects_oracle_on_mini_suite():
    """The ISSUE 2 acceptance criterion: >= 90% oracle agreement on the
    mini-suite through engine.get_plan, from recorded timings."""
    db, oracles = _mini_db_with_recorded_timings()
    cache = PlanCache()
    hits = 0
    for name, (a, oracle, analytic, d) in oracles.items():
        plan = cache.get(a, PlanPolicy(tunedb=db))
        assert plan.meta.method != analytic or oracle == analytic
        hits += plan.meta.method == oracle
    assert hits / len(oracles) >= 0.9


def test_exact_hit_replays_tuned_params():
    a = uniform(40, 32, 48, 6)
    lmax = int(np.diff(np.asarray(a.row_ptr)).max())
    db = TuneDB(backend="test")
    db.record(pattern_fingerprint(a),
              _rec("rowsplit", 100.0, 50.0, a, l_pad=lmax + 3))
    plan = build_plan(a, tunedb=db)
    assert plan.meta.method == "rowsplit"
    assert plan.meta.l_pad == lmax + 3
    # explicit arguments still beat the record
    plan2 = build_plan(a, tunedb=db, l_pad=lmax)
    assert plan2.meta.l_pad == lmax


def test_cache_keys_include_tunedb_digest():
    """Swapping DBs must never serve a plan resolved against the old one."""
    a = power_law(41, 128, 128, 4.0)
    fp = pattern_fingerprint(a)
    db_merge = TuneDB(backend="test")
    db_merge.record(fp, _rec("merge", 10.0, 20.0, a))
    db_rowsplit = TuneDB(backend="test")
    db_rowsplit.record(fp, _rec("rowsplit", 20.0, 10.0, a))
    cache = PlanCache()
    assert cache.get(a, PlanPolicy(tunedb=db_merge)).meta.method == "merge"
    assert cache.get(a,
                     PlanPolicy(tunedb=db_rowsplit)).meta.method == "rowsplit"
    assert cache.get(a, PlanPolicy(tunedb=None)).meta.method == \
        Heuristic().choose(a)


def test_process_default_tunedb():
    a = uniform(42, 32, 512, 30)             # analytic: rowsplit (d=30)
    db = TuneDB(backend="test")
    db.record(pattern_fingerprint(a), _rec("merge", 10.0, 20.0, a))
    cache = PlanCache()
    try:
        engine.set_tunedb(db)
        assert engine.current_tunedb() is db
        assert cache.get(a).meta.method == "merge"
    finally:
        engine.set_tunedb(None)
    assert cache.get(a).meta.method == "rowsplit"


def test_sparse_linear_reaches_calibrated_threshold_rung():
    """A pattern with no exact/class hit must fall through to the DB's
    *calibrated* threshold, not the paper's 9.35 — including via the
    SparseLinear path (which must not pin the analytic default)."""
    import jax.numpy as jnp
    from repro.models.sparse import SparseLinear

    # prune_to_csr keeps 50% per row -> d = 16 on a 16x32 weight.T ...
    w = jnp.asarray(np.random.default_rng(0).standard_normal((32, 16)),
                    jnp.float32)
    d = 0.5 * 32                              # 16: analytic(9.35)=rowsplit
    db = TuneDB(backend="test")
    far = uniform(50, 8, 8, 2)                # some far-away class
    db.record(pattern_fingerprint(far), _rec("merge", 1.0, 2.0, far))
    db.threshold = d + 1.0                    # calibrated: d=16 -> merge
    try:
        engine.set_tunedb(db)
        sl = SparseLinear.from_dense(w, 0.5)
        assert sl.plan.meta.method == "merge"
    finally:
        engine.set_tunedb(None)
    assert SparseLinear.from_dense(w, 0.5).plan.meta.method == "rowsplit"


def test_cli_refuses_to_overwrite_mismatched_db(tmp_path):
    from repro.tune.cli import main

    path = tmp_path / "tune.json"
    path.write_text("{corrupt")
    with pytest.raises(SystemExit):
        main(["--suite", "mini", "--out", str(path)])
    assert path.read_text() == "{corrupt"    # untouched


def test_load_tunedb_corrupt_installs_empty(tmp_path):
    path = tmp_path / "tune.json"
    path.write_text("garbage{")
    try:
        with pytest.warns(UserWarning, match="corrupt"):
            db = engine.load_tunedb(path)
        assert len(db) == 0 and engine.current_tunedb() is db
        a = uniform(43, 32, 512, 30)
        assert PlanCache().get(a).meta.method == Heuristic().choose(a)
    finally:
        engine.set_tunedb(None)


# ----------------------------------------------------- live tuning (S) ---


def test_tune_suite_records_and_calibrates():
    """One real (tiny) tuning pass: records exist, oracle is respected,
    threshold gets calibrated.  Timings are real but minimal (repeat=1)."""
    specs = [sp for sp in get_suite("mini")][:1]
    db = TuneDB(backend="test")
    logs = []
    tune_suite(specs, db, n=8, warmup=0, repeat=1, log=logs.append)
    assert len(db) == 1
    rec = next(iter(db.entries.values()))
    # Every registered method was timed; the winner is the overall argmin
    # (may be a non-core method, e.g. rowgroup) while the oracle stays the
    # merge/rowsplit pair that anchors threshold calibration.
    from repro.kernels import registry
    assert set(rec.timings) == set(registry.method_names())
    assert rec.method == min(rec.timings, key=rec.timings.get)
    assert rec.oracle in ("merge", "rowsplit")
    assert rec.merge_us > 0 and rec.rowsplit_us > 0
    assert db.threshold is not None
    assert any("calibrated" in line for line in logs)
    # idempotent: second pass skips the cached pattern
    tune_suite(specs, db, n=8, warmup=0, repeat=1, log=logs.append)
    assert any("cached" in line for line in logs)


def test_heuristic_rejects_traced_col_ind():
    """Satellite: _require_concrete must reject a traced col_ind too,
    matching core.spmm._is_traced."""
    import jax
    import jax.numpy as jnp
    from repro.core import CSR

    a = uniform(44, 8, 8, 2)

    def f(ci):
        traced = CSR(a.row_ptr, ci, a.vals, a.shape)
        return jnp.zeros(()) if Heuristic().choose(traced) else jnp.ones(())

    with pytest.raises(ValueError, match="plan-build time"):
        jax.jit(f)(a.col_ind)
