"""Nonzero-split partitioning invariants (paper §4.2 Phase 1)."""
import jax
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install -r "
    "requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import partition_spmm, chunk_segments, random_csr
from repro.kernels.merge_spmm import plan_merge


@st.composite
def csr_cases(draw):
    m = draw(st.integers(1, 40))
    k = draw(st.integers(1, 32))
    hi = draw(st.integers(0, min(k, 12)))
    seed = draw(st.integers(0, 2**31 - 1))
    pad = draw(st.integers(0, 9))
    a = random_csr(jax.random.PRNGKey(seed), m, k, nnz_per_row=(0, hi))
    if pad:
        a = random_csr(jax.random.PRNGKey(seed), m, k, nnz_per_row=(0, hi),
                       pad_to=a.nnz_pad + pad)
    return a


@settings(max_examples=30, deadline=None)
@given(csr_cases(), st.integers(1, 9))
def test_partition_equal_nonzeros(a, t):
    """Every chunk gets exactly t nonzeroes; starts are the owning rows."""
    chunk_start_rows, nnz_rows = partition_spmm(a, t)
    rp = np.asarray(a.row_ptr)
    rows = np.asarray(nnz_rows)
    nnz = int(rp[-1])
    for c, r in enumerate(np.asarray(chunk_start_rows)):
        s = c * t
        if s < nnz:
            assert rp[r] <= s < rp[r + 1]
    # CSR→COO flattening is exact
    want = np.repeat(np.arange(a.m), np.diff(rp))
    np.testing.assert_array_equal(rows[:nnz], want)


@settings(max_examples=30, deadline=None)
@given(csr_cases(), st.integers(1, 9))
def test_chunk_segments_cover_every_nonzero(a, t):
    _, nnz_rows = partition_spmm(a, t)
    rows, local, seg_rows = chunk_segments(nnz_rows, t, a.m)
    n_chunks = rows.shape[0]
    rows, local, seg_rows = map(np.asarray, (rows, local, seg_rows))
    nnz = int(np.asarray(a.row_ptr)[-1])
    # Each in-range nonzero's (chunk, local segment) maps back to its row.
    for i in range(nnz):
        c, s = divmod(i, t)
        assert seg_rows[c, local[c, s]] == rows[c, s]
    # local ids increase only at row changes
    assert np.all((np.diff(local, axis=1) == 0) | (np.diff(rows, axis=1) != 0))


@settings(max_examples=30, deadline=None)
@given(csr_cases(), st.integers(1, 9), st.sampled_from([4, 8]))
def test_plan_merge_invariants(a, t, tm):
    """The Pallas merge plan: every valid nonzero lands in exactly one slot
    of a chunk belonging to its row tile; tiles are monotone; `first` marks
    each tile's first chunk; every row tile is visited."""
    plan = jax.tree.map(np.asarray, plan_merge(a, t=t, tm=tm))
    n_tiles = -(-a.m // tm)
    tile, first = plan["tile"], plan["first"]
    assert np.all(np.diff(tile) >= 0), "tile stream must be monotone"
    np.testing.assert_array_equal(
        first, np.r_[1, (tile[1:] != tile[:-1]).astype(np.int32)])
    assert set(range(n_tiles)) <= set(tile.tolist()), "every tile visited"

    # Reconstruct the matrix from the plan and compare against to_dense.
    m_pad = n_tiles * tm
    recon = np.zeros((m_pad, a.k), np.float64)
    n_chunks, tt = plan["cols"].shape
    for c in range(n_chunks):
        for s in range(tt):
            v = plan["vals"][c, s]
            if v != 0:
                row = tile[c] * tm + plan["lrow"][c, s]
                recon[row, plan["cols"][c, s]] += v
    np.testing.assert_allclose(recon[: a.m], np.asarray(a.to_dense()),
                               rtol=1e-6, atol=1e-6)
