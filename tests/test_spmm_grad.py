"""Differentiable SpMM: jax.grad through plans vs. the dense-autodiff
oracle (values and B cotangents), for both kernel methods.

The backward pass is custom: dB rides the cached transpose (CSC-view)
merge plan, dvals rides the SDDMM gather-dot kernel — so the oracle is a
densify-and-matmul loss differentiated by plain autodiff.  Acceptance
criterion: float32 agreement to 1e-4.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CSR, ExecutionConfig, PlanPolicy, build_plan,
                        execute_plan, random_csr, spmm)
from repro.models.sparse import SparseLinear, prune_mlp
from repro.runtime import steps as R

EC = ExecutionConfig  # keep call sites within the line limit

TOL = dict(rtol=1e-4, atol=1e-5)


def _case(seed=0, m=48, k=40, n=24, npr=(0, 12)):
    a = random_csr(jax.random.PRNGKey(seed), m, k, nnz_per_row=npr)
    b = jax.random.normal(jax.random.PRNGKey(seed + 1), (k, n))
    w = jax.random.normal(jax.random.PRNGKey(seed + 2), (m, n))
    return a, b, w


def _dense_loss(a: CSR, w):
    row_ptr, col_ind, shape = a.row_ptr, a.col_ind, a.shape

    def loss(vals, b):
        dense = CSR(row_ptr, col_ind, vals, shape).to_dense()
        return jnp.sum((dense @ b) * w)

    return loss


@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("method", ["merge", "rowsplit"])
def test_grad_matches_dense_oracle(method, impl):
    a, b, w = _case()
    plan = build_plan(a, method=method)

    def loss(vals, bb):
        return jnp.sum(execute_plan(plan, vals, bb, EC(impl=impl)) * w)

    g_vals, g_b = jax.grad(loss, argnums=(0, 1))(a.vals, b)
    want_vals, want_b = jax.grad(_dense_loss(a, w), argnums=(0, 1))(a.vals, b)
    np.testing.assert_allclose(np.asarray(g_vals), np.asarray(want_vals),
                               **TOL)
    np.testing.assert_allclose(np.asarray(g_b), np.asarray(want_b), **TOL)


@pytest.mark.parametrize("method", ["merge", "rowsplit"])
def test_grad_through_spmm_api(method):
    """spmm() with a concrete pattern closed over is differentiable."""
    a, b, w = _case(seed=3)

    def loss(bb):
        return jnp.sum(spmm(a, bb, PlanPolicy(method=method),
                            EC(impl="xla")) * w)

    g = jax.grad(loss)(b)
    want = jax.grad(lambda bb: _dense_loss(a, w)(a.vals, bb))(b)
    np.testing.assert_allclose(np.asarray(g), np.asarray(want), **TOL)


@pytest.mark.parametrize("method", ["merge", "rowsplit"])
def test_grad_under_jit(method):
    a, b, w = _case(seed=4, m=32, k=24, n=16)
    plan = build_plan(a, method=method)

    @jax.jit
    def grads(vals, bb):
        return jax.grad(
            lambda v, x: jnp.sum(execute_plan(plan, v, x, EC(impl="xla")) * w),
            argnums=(0, 1))(vals, bb)

    g_vals, g_b = grads(a.vals, b)
    want_vals, want_b = jax.grad(_dense_loss(a, w), argnums=(0, 1))(a.vals, b)
    np.testing.assert_allclose(np.asarray(g_vals), np.asarray(want_vals),
                               **TOL)
    np.testing.assert_allclose(np.asarray(g_b), np.asarray(want_b), **TOL)


def test_grad_empty_and_degenerate_rows():
    """Empty rows / empty matrix tails: cotangents stay masked to zero."""
    a, b, w = _case(seed=5, m=16, k=12, n=8, npr=(0, 2))
    for method in ("merge", "rowsplit"):
        plan = build_plan(a, method=method)
        g_vals = jax.grad(lambda v: jnp.sum(
            execute_plan(plan, v, b, EC(impl="xla")) * w))(a.vals)
        want = jax.grad(
            lambda v: _dense_loss(a, w)(v, b))(a.vals)
        np.testing.assert_allclose(np.asarray(g_vals), np.asarray(want),
                                   **TOL)
        nnz = int(np.asarray(a.row_ptr)[-1])
        assert not np.any(np.asarray(g_vals)[nnz:]), \
            "padded values received nonzero cotangents"


def test_sparse_linear_loss_grad():
    """jax.grad of a SparseLinear loss vs. the dense-autodiff oracle."""
    rng = np.random.default_rng(0)
    # (d_in, d_out)
    w = jnp.asarray(rng.standard_normal((24, 32)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((8, 24)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((8, 32)), jnp.float32)
    sl = SparseLinear.from_dense(w, 0.25)

    def loss_sparse(vals):
        layer = dataclasses.replace(
            sl, weight=dataclasses.replace(sl.weight, vals=vals))
        return jnp.mean((layer(x, EC(impl="xla")) - y) ** 2)

    def loss_dense(vals):
        # (d_out, d_in)
        wd = dataclasses.replace(sl.weight, vals=vals).to_dense()
        return jnp.mean((x @ wd.T - y) ** 2)

    g = jax.grad(loss_sparse)(sl.weight.vals)
    want = jax.grad(loss_dense)(sl.weight.vals)
    np.testing.assert_allclose(np.asarray(g), np.asarray(want), **TOL)


def test_sparse_train_step_learns():
    """End-to-end: the runtime's sparse fine-tuning step reduces loss."""
    rng = np.random.default_rng(1)
    p = {"w1": jnp.asarray(rng.standard_normal((16, 48)), jnp.float32),
         "w2": jnp.asarray(rng.standard_normal((48, 16)), jnp.float32)}
    sp = prune_mlp(p, 0.25)
    step, vals = R.make_sparse_train_step(sp, lr=5e-3, impl="xla")
    jstep = jax.jit(step)
    x = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
    losses = []
    for _ in range(10):
        vals, loss = jstep(vals, x, y)
        losses.append(float(loss))
    assert losses[-1] < 0.7 * losses[0], losses
