"""Static-analysis subsystem tests (ISSUE 8).

The core of the coverage is *invariant mutation*: take a valid plan,
corrupt exactly one checked invariant, and assert the plan linter fires
the specific diagnostic for it — so each check is proven live, not just
present.  Plus: the REPRO_VERIFY_PLANS hook gating, kernel-audit model
checks and loud coverage failure, repo-lint rules on synthetic sources,
and the CLI exit codes.
"""
from __future__ import annotations

import dataclasses
import os
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import planlint, set_verify_plans
from repro.analysis.planlint import (PlanVerificationError, check_plan,
                                     verify_csr, verify_plan,
                                     verify_sharded_plan)
from repro.core.config import PlanPolicy, ShardSpec
from repro.core.csr import CSR, random_csr
from repro.core.plan import PlanMeta, build_plan
from repro.distributed.spmm import build_sharded_plan


def codes(diags):
    return {d.code for d in diags}


@pytest.fixture(scope="module")
def a():
    # m=41 (not a TM multiple) so the ELL structures carry padding rows,
    # and nnz_pad > nnz so the dead-slot range (P022) is non-empty.
    key = jax.random.PRNGKey(7)
    a0 = random_csr(key, 41, 96, nnz_per_row=(1, 17))
    nnz = int(np.asarray(a0.row_ptr)[-1])
    return random_csr(key, 41, 96, nnz_per_row=(1, 17), pad_to=nnz + 8)


@pytest.fixture(scope="module")
def merge_plan(a):
    return build_plan(a, method="merge")


@pytest.fixture(scope="module")
def rowsplit_plan(a):
    return build_plan(a, method="rowsplit")


@pytest.fixture(scope="module")
def rowgroup_plan(a):
    return build_plan(a, method="rowgroup")


def with_fwd(plan, **over):
    fwd = dict(plan.fwd)
    fwd.update(over)
    return dataclasses.replace(plan, fwd=fwd)


# ------------------------------------------------------------- clean runs ---


def test_clean_plans_verify(a, merge_plan, rowsplit_plan, rowgroup_plan):
    for plan in (merge_plan, rowsplit_plan, rowgroup_plan):
        assert verify_plan(plan, a) == []
        assert verify_plan(plan) == []      # CSR-free path too


def test_clean_sharded_verifies(a):
    for dim in ("rows", "cols"):
        plan = build_sharded_plan(a, PlanPolicy(shards=ShardSpec(
            n=3, dim=dim)))
        assert verify_sharded_plan(plan, a) == []


# -------------------------------------------------- CSR-level corruption ---


def test_non_monotone_row_ptr_p001(a):
    rp = np.asarray(a.row_ptr).copy()
    rp[2], rp[3] = rp[3] + 1, rp[2]
    bad = CSR(jnp.asarray(rp), a.col_ind, a.vals, a.shape)
    assert "P001" in codes(verify_csr(bad))


def test_col_ind_out_of_range_p002(a):
    ci = np.asarray(a.col_ind).copy()
    ci[0] = a.shape[1] + 5
    bad = CSR(a.row_ptr, jnp.asarray(ci), a.vals, a.shape)
    assert "P002" in codes(verify_csr(bad))


def test_plan_csr_mismatch_p003(merge_plan):
    other = random_csr(jax.random.PRNGKey(8), 8, 8, nnz_per_row=2)
    assert "P003" in codes(verify_plan(merge_plan, other))


# ---------------------------------------------- slot coverage corruption ---


def test_duplicate_slot_p020(a, merge_plan):
    slot = np.asarray(merge_plan.fwd["slot_nz"]).copy()
    live = np.argwhere(slot < merge_plan.meta.nnz_pad)
    (r0, c0), (r1, c1) = live[0], live[1]
    slot[r1, c1] = slot[r0, c0]             # one nonzero consumed twice
    diags = verify_plan(with_fwd(merge_plan, slot_nz=jnp.asarray(slot)), a)
    assert "P020" in codes(diags)
    assert "P021" in codes(diags)           # ...and one now missing


def test_sentinel_aimed_at_live_data_p020(a, merge_plan):
    # A sentinel slot redirected at live values double-counts a nonzero:
    # exactly the corruption the exactly-once invariant exists for.
    slot = np.asarray(merge_plan.fwd["slot_nz"]).copy()
    sent = np.argwhere(slot == merge_plan.meta.nnz_pad)
    assert len(sent), "merge structure always pads the last chunk"
    r, c = sent[0]
    slot[r, c] = 0
    diags = verify_plan(with_fwd(merge_plan, slot_nz=jnp.asarray(slot)), a)
    assert "P020" in codes(diags)


def test_missing_nonzero_p021(a, merge_plan):
    slot = np.asarray(merge_plan.fwd["slot_nz"]).copy()
    live = np.argwhere(slot < merge_plan.meta.nnz_pad)
    r0, c0 = live[0]
    slot[r0, c0] = merge_plan.meta.nnz_pad      # retired to sentinel
    diags = verify_plan(with_fwd(merge_plan, slot_nz=jnp.asarray(slot)), a)
    assert "P021" in codes(diags)


def test_out_of_range_slot_p022(a, merge_plan):
    slot = np.asarray(merge_plan.fwd["slot_nz"]).copy()
    slot[0, 0] = merge_plan.meta.nnz_pad + 3    # past even the sentinel
    diags = verify_plan(with_fwd(merge_plan, slot_nz=jnp.asarray(slot)), a)
    assert "P022" in codes(diags)


def test_dead_range_slot_p022(a, merge_plan):
    # In-range as an index but pointing at padding values (nnz..nnz_pad):
    # reads a stale value, not a zero — distinct from the sentinel.
    nnz = int(np.asarray(a.row_ptr)[-1])
    if nnz == merge_plan.meta.nnz_pad:
        pytest.skip("pattern has no dead padding range")
    slot = np.asarray(merge_plan.fwd["slot_nz"]).copy()
    sent = np.argwhere(slot == merge_plan.meta.nnz_pad)
    r, c = sent[0]
    slot[r, c] = nnz                            # first dead slot
    diags = verify_plan(with_fwd(merge_plan, slot_nz=jnp.asarray(slot)), a)
    assert "P022" in codes(diags)


# -------------------------------------------------- merge-path corruption ---


def test_double_covered_merge_tile_p030_p031(a, merge_plan):
    tile = np.asarray(merge_plan.fwd["tile"]).copy()
    tile[1:] = tile[:-1][::-1][: len(tile) - 1]  # scrambled, decreasing
    diags = verify_plan(with_fwd(merge_plan, tile=jnp.asarray(tile)), a)
    assert codes(diags) & {"P030", "P031", "P032"}


def test_tile_skipped_p031(a, merge_plan):
    tile = np.asarray(merge_plan.fwd["tile"]).copy()
    n_tiles = -(-merge_plan.meta.m // planlint._TM)
    if n_tiles < 2:
        pytest.skip("needs >= 2 row tiles")
    tile[tile == 1] = 0                          # tile 1 never visited
    diags = verify_plan(with_fwd(merge_plan, tile=jnp.asarray(tile)), a)
    assert "P031" in codes(diags)


def test_wrong_first_last_flags_p031(a, merge_plan):
    first = np.asarray(merge_plan.fwd["first"]).copy()
    first[0] = 0                                  # chunk 0 must start a tile
    diags = verify_plan(with_fwd(merge_plan, first=jnp.asarray(first)), a)
    assert "P031" in codes(diags)


def test_lrow_scatters_to_wrong_row_p032(a, merge_plan):
    lrow = np.asarray(merge_plan.fwd["lrow"]).copy()
    slot = np.asarray(merge_plan.fwd["slot_nz"])
    live = np.argwhere(slot < merge_plan.meta.nnz_pad)
    r0, c0 = live[0]
    lrow[r0, c0] = (lrow[r0, c0] + 1) % planlint._TM
    diags = verify_plan(with_fwd(merge_plan, lrow=jnp.asarray(lrow)), a)
    assert "P032" in codes(diags)


# ------------------------------------------- rowsplit / rowgroup mutation ---


def test_truncated_l_pad_p040(a, rowsplit_plan):
    meta = dataclasses.replace(rowsplit_plan.meta,
                               l_pad=rowsplit_plan.meta.l_pad - 1)
    bad = dataclasses.replace(rowsplit_plan, meta=meta)
    assert "P040" in codes(verify_plan(bad, a))


def test_ell_slot_wrong_row_p041(a, rowsplit_plan):
    slot = np.asarray(rowsplit_plan.fwd["slot_nz"]).copy()
    nnz_pad = rowsplit_plan.meta.nnz_pad
    rows_live = [r for r in range(slot.shape[0])
                 if (slot[r] < nnz_pad).any()]
    r0, r1 = rows_live[0], rows_live[1]
    c0 = int(np.argwhere(slot[r0] < nnz_pad)[0, 0])
    c1 = int(np.argwhere(slot[r1] < nnz_pad)[0, 0])
    slot[r0, c0], slot[r1, c1] = slot[r1, c1], slot[r0, c0]
    diags = verify_plan(
        with_fwd(rowsplit_plan, slot_nz=jnp.asarray(slot)), a)
    assert "P041" in codes(diags)


def test_live_slot_on_padding_row_p042(a, rowsplit_plan):
    slot = np.asarray(rowsplit_plan.fwd["slot_nz"]).copy()
    if slot.shape[0] <= rowsplit_plan.meta.m:
        pytest.skip("no tile-padding rows on this shape")
    slot[-1, 0] = 0                               # pad row reads live data
    diags = verify_plan(
        with_fwd(rowsplit_plan, slot_nz=jnp.asarray(slot)), a)
    assert "P042" in codes(diags)


def test_rowgroup_bad_group_table_p050(a, rowgroup_plan):
    extra = list(rowgroup_plan.meta.extra)
    (m_g, l_g) = extra[0]
    extra[0] = (m_g + 1, l_g)                     # counts no longer sum to m
    meta = dataclasses.replace(rowgroup_plan.meta, extra=tuple(extra))
    bad = dataclasses.replace(rowgroup_plan, meta=meta)
    assert "P050" in codes(verify_plan(bad, a))


def test_rowgroup_non_permutation_p051(a, rowgroup_plan):
    inv = np.asarray(rowgroup_plan.fwd["inv_pos"]).copy()
    inv[1] = inv[0]                               # two rows, one source
    diags = verify_plan(
        with_fwd(rowgroup_plan, inv_pos=jnp.asarray(inv)), a)
    assert "P051" in codes(diags)


# ------------------------------------------------------ bwd-plan mutation ---


def test_bwd_missing_vs_meta_p060(a, merge_plan):
    bad = dataclasses.replace(merge_plan, bwd=None)
    assert "P060" in codes(verify_plan(bad, a))


def test_bwd_coverage_corruption_p021(a, merge_plan):
    bwd = dict(merge_plan.bwd)
    slot = np.asarray(bwd["slot_nz"]).copy()
    live = np.argwhere(slot < merge_plan.meta.nnz_pad)
    r0, c0 = live[0]
    slot[r0, c0] = merge_plan.meta.nnz_pad
    bwd["slot_nz"] = jnp.asarray(slot)
    bad = dataclasses.replace(merge_plan, bwd=bwd)
    diags = verify_plan(bad, a)
    assert any(d.code == "P021" and "bwd" in d.where for d in diags)


# ------------------------------------------------------- sharded mutation ---


def test_sharded_bounds_dont_tile_p070(a):
    plan = build_sharded_plan(a, PlanPolicy(shards=ShardSpec(n=2)))
    bounds = list(plan.meta.bounds)
    bounds[1] += 1
    meta = dataclasses.replace(plan.meta, bounds=tuple(bounds))
    bad = dataclasses.replace(plan, meta=meta)
    assert codes(verify_sharded_plan(bad, a)) & {"P070", "P071", "P072"}


def test_sharded_gather_not_exactly_once_p072(a):
    plan = build_sharded_plan(a, PlanPolicy(shards=ShardSpec(n=2)))
    vs = [np.asarray(v).copy() for v in plan.vals_slots]
    nnz_pad = plan.meta.nnz_pad
    live = np.argwhere(vs[0] < nnz_pad)
    vs[0][tuple(live[0])] = nnz_pad               # drop one global nonzero
    bad = dataclasses.replace(
        plan, vals_slots=tuple(jnp.asarray(v) for v in vs))
    assert "P072" in codes(verify_sharded_plan(bad, a))


def test_sharded_bad_b_rows_p074(a):
    plan = build_sharded_plan(
        a, PlanPolicy(shards=ShardSpec(n=2, dim="cols")))
    br = [np.asarray(v).copy() for v in plan.b_rows]
    live = np.argwhere(br[0] < a.shape[1])
    br[0][tuple(live[0])] += 1
    bad = dataclasses.replace(
        plan, b_rows=tuple(jnp.asarray(v) for v in br))
    assert "P074" in codes(verify_sharded_plan(bad, a))


def test_sharded_uniform_flag_lie_p073(a):
    plan = build_sharded_plan(a, PlanPolicy(shards=ShardSpec(n=2)))
    metas = list(plan.meta.local_metas)
    metas[0] = dataclasses.replace(metas[0], t=metas[0].t * 2)
    meta = dataclasses.replace(plan.meta, uniform=True,
                               local_metas=tuple(metas))
    bad = dataclasses.replace(plan, meta=meta)
    assert codes(verify_sharded_plan(bad)) & {"P073", "P071", "P003"}


# ------------------------------------------------------- hook + eager meta ---


def test_unhashable_extra_raises_eagerly():
    with pytest.raises(TypeError, match="hashable"):
        PlanMeta(method="merge", shape=(4, 4), nnz_pad=4, t=16, tl=16,
                 l_pad=None, has_transpose=False, extra=[1, 2])


def test_verify_hook_gating(a, monkeypatch):
    built = {}
    prev = set_verify_plans(False)
    try:
        build_plan(a, method="merge")         # off: no verification runs
        set_verify_plans(True)
        plan = build_plan(a, method="merge")  # on: clean plan passes
        built["plan"] = plan
    finally:
        set_verify_plans(prev)
    assert built["plan"].meta.method == "merge"


def test_verify_hook_env_var():
    import subprocess
    import sys
    code = ("from repro.analysis import _flags; "
            "raise SystemExit(0 if _flags.verify_plans else 1)")
    env = dict(os.environ, REPRO_VERIFY_PLANS="1",
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src")]
                   + os.environ.get("PYTHONPATH", "").split(os.pathsep)))
    assert subprocess.run([sys.executable, "-c", code],
                          env=env).returncode == 0


def test_check_plan_raises_with_diagnostics(a, merge_plan):
    slot = np.asarray(merge_plan.fwd["slot_nz"]).copy()
    live = np.argwhere(slot < merge_plan.meta.nnz_pad)
    slot[tuple(live[0])] = merge_plan.meta.nnz_pad
    bad = with_fwd(merge_plan, slot_nz=jnp.asarray(slot))
    with pytest.raises(PlanVerificationError) as ei:
        check_plan(bad, a)
    assert "P021" in {d.code for d in ei.value.diagnostics}


# ------------------------------------------------------------ kernel audit ---


def test_audit_all_clean():
    from repro.analysis import kernel_audit
    rows, diags = kernel_audit.audit_all()
    assert diags == []
    from repro.kernels import registry
    covered = {(r.method, r.impl) for r in rows}
    for name in registry.method_names():
        for impl in kernel_audit.AUDIT_IMPLS:
            assert (name, impl) in covered
    report = kernel_audit.format_report(rows, diags)
    assert "no findings" in report


def test_audit_fails_loudly_on_uncovered_method():
    from repro.analysis import kernel_audit
    from repro.kernels import registry
    spec = registry.get_method("merge")
    # replace() would inherit merge's traffic hook — a ghost method with
    # no launch model anywhere must trip K001.
    ghost = dataclasses.replace(spec, name="ghost", traffic=None)
    registry.register_method(ghost)
    try:
        rows, diags = kernel_audit.audit_all()
        assert "K001" in {d.code for d in diags}
        assert any("ghost" in d.where for d in diags)
    finally:
        registry._REGISTRY.pop("ghost", None)


def test_audit_stale_model_k002():
    from repro.analysis import kernel_audit
    kernel_audit.register_audit("no_such_method", lambda *a: [])
    try:
        _, diags = kernel_audit.audit_all()
        assert "K002" in {d.code for d in diags}
    finally:
        kernel_audit._AUDITS.pop("no_such_method", None)


def test_audit_single_writer_catches_double_flush():
    from repro.analysis.kernel_audit import Block, LaunchModel, \
        check_single_writer
    out = Block("out", (1, 8, 128), "float32",
                lambda i, j: (0, 0, 0), (1, 8, 128), "out")
    model = LaunchModel("bad", grid=(2, 2), blocks=(out,),
                        flush=lambda i, j: True, out=out)
    assert check_single_writer(model)         # 4 writes to one tile
    good = LaunchModel("good", grid=(2, 2), blocks=(out,),
                       flush=lambda i, j: (i, j) == (1, 1), out=out)
    assert check_single_writer(good) == []


def test_audit_in_bounds_catches_overrun():
    from repro.analysis.kernel_audit import Block, LaunchModel, \
        check_in_bounds
    blk = Block("b", (8, 128), "float32", lambda i: (i, 0),
                (16, 128), "in")
    ok = LaunchModel("ok", grid=(2,), blocks=(blk,),
                     flush=lambda i: True, out=blk)
    assert check_in_bounds(ok) == []
    bad = LaunchModel("bad", grid=(3,), blocks=(blk,),
                      flush=lambda i: True, out=blk)
    assert check_in_bounds(bad)


def test_audit_vmem_budget_flags_blowup():
    from repro.analysis.kernel_audit import nnz_vmem_ceiling
    # The documented ceiling must be consistent: one more f32 nonzero
    # than the ceiling overflows the 16 MiB model.
    c = nnz_vmem_ceiling(dtype="float32")
    assert 0 < c < 16 * 2 ** 20
    assert nnz_vmem_ceiling(dtype="bfloat16") > c


# --------------------------------------------------------------- repo lint ---


def _lint_src(tmp_path, source, name="mod.py"):
    from repro.analysis import lint
    p = tmp_path / name
    p.write_text(textwrap.dedent(source))
    return lint.lint_file(str(p))


def test_rl001_host_sync_in_jit(tmp_path):
    diags = _lint_src(tmp_path, """
        import jax, numpy as np

        @jax.jit
        def f(x):
            return np.asarray(x) + x.item()

        def host_only(x):
            return float(np.asarray(x))    # fine: not jit-reachable
    """)
    assert [d.code for d in diags] == ["RL001", "RL001"]


def test_rl001_kernel_body_and_defvjp(tmp_path):
    diags = _lint_src(tmp_path, """
        from jax.experimental import pallas as pl

        def kernel(x_ref, o_ref):
            i = pl.program_id(0)
            o_ref[...] = float(i)

        def bwd(res, ct):
            return np.asarray(ct)

        op.defvjp(kernel, bwd)
    """)
    assert {d.code for d in diags} == {"RL001"}
    assert len(diags) == 2


def test_rl001_noqa_suppresses(tmp_path):
    diags = _lint_src(tmp_path, """
        import jax

        @jax.jit
        def f(x):
            return x.item()    # noqa: RL001
    """)
    assert diags == []


def test_rl002_legacy_kwargs(tmp_path):
    diags = _lint_src(tmp_path, """
        from repro import spmm
        c = spmm(a, b, method="merge", interpret=True)
        d = spmm(a, b, policy)                 # v1 spelling: clean
        e = get_plan(a, l_pad=32)
    """)
    assert [d.code for d in diags] == ["RL002", "RL002"]


def test_rl002_test_api_exempt(tmp_path):
    from repro.analysis import lint
    sub = tmp_path / "tests"
    sub.mkdir()
    p = sub / "test_api.py"
    p.write_text("spmm(a, b, method='merge')\n")
    assert lint.lint_file(str(p)) == []


def test_rl003_incomplete_methodspec(tmp_path):
    diags = _lint_src(tmp_path, """
        spec = MethodSpec(name="x", description="d", build_structure=f,
                          execute=g, inline=h)
        ok = registry.MethodSpec(
            name="y", description="d", build_structure=f, execute=g,
            inline=h, resolve_params=r, tune_candidates=None,
            heuristic_rank=None, traffic=None)
    """)
    assert [d.code for d in diags] == ["RL003"]
    assert "resolve_params" in diags[0].message


def test_rl004_unregistered_bench(tmp_path):
    from repro.analysis import lint
    bench = tmp_path / "benchmarks"
    bench.mkdir()
    (bench / "run.py").write_text(textwrap.dedent("""
        def _mods():
            from . import bench_a
            return [("a", bench_a)]
    """))
    (bench / "bench_a.py").write_text("")
    (bench / "bench_orphan.py").write_text("")
    diags = []
    lint.check_bench_registration(str(bench), diags)
    assert [d.code for d in diags] == ["RL004"]
    assert "bench_orphan" in diags[0].message


def test_repo_lint_is_clean():
    from repro.analysis import lint
    root = os.path.join(os.path.dirname(__file__), "..")
    diags = lint.run_lint(repo_root=os.path.abspath(root))
    assert diags == [], "\n".join(str(d) for d in diags)


# ---------------------------------------------------------------- CLI glue ---


def test_cli_lint_exit_codes(tmp_path):
    from repro.analysis import cli
    assert cli.run_repo_lint(None) == 0
    bad = tmp_path / "bad.py"
    bad.write_text("import jax\n@jax.jit\ndef f(x):\n    return x.item()\n")
    assert cli.run_repo_lint([str(bad)]) == 1


def test_cli_planlint_smoke(a, capsys):
    from repro.analysis import cli
    from repro.matrices import suites
    suites.register_spec(suites.MatrixSpec(
        name="_analysis_smoke", build=lambda: a, family="synthetic"))
    suites.register_suite("_analysis_smoke", ("_analysis_smoke",))
    try:
        assert cli.run_planlint("_analysis_smoke") == 0
        assert "verified" in capsys.readouterr().out
    finally:
        suites._SUITES.pop("_analysis_smoke", None)
        suites._SPECS.pop("_analysis_smoke", None)


# ----------------------------------------------- property-based round trip ---


def test_hypothesis_roundtrip_mini_suite():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    from repro.kernels import registry
    from repro.matrices.suites import get_suite

    specs = list(get_suite("mini"))
    plans = {}

    @hyp.settings(max_examples=len(specs) * len(registry.method_names()),
                  deadline=None)
    @hyp.given(i=st.integers(0, len(specs) - 1),
               method=st.sampled_from(sorted(registry.method_names())))
    def roundtrip(i, method):
        spec = specs[i]
        key = (spec.name, method)
        if key not in plans:
            a = spec.build()
            plans[key] = (a, build_plan(a, method=method))
        a, plan = plans[key]
        assert verify_plan(plan, a) == []

    roundtrip()
