"""MoE dispatch properties: capacity semantics, skew insensitivity,
hierarchical-groups equivalence (hypothesis)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install -r "
    "requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke_config
from repro.models import moe as MOE


def _cfg(**kw):
    cfg = get_smoke_config("olmoe-1b-7b")
    return dataclasses.replace(cfg, compute_dtype="float32", **kw)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_dispatch_covers_all_tokens_when_dropless(seed):
    cfg = _cfg()
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (16, cfg.d_model))
    experts = jax.random.randint(k2, (16, cfg.top_k), 0, cfg.num_experts)
    buf, meta = MOE._sorted_dispatch(x, experts, cfg, tt=8,
                                     capacity_factor=float(cfg.num_experts))
    assert bool(jnp.all(meta["keep"]))               # dropless capacity
    # every (token, replica) lands in its expert's slot range
    slots = np.asarray(meta["slot"])
    sorted_e = np.asarray(experts).reshape(-1)[np.asarray(meta["order"])]
    cap = meta["cap"]
    assert np.all(slots // cap == sorted_e)
    # and the buffer rows hold the right token vectors
    tok = np.asarray(meta["order"]) // cfg.top_k
    np.testing.assert_allclose(np.asarray(buf)[slots],
                               np.asarray(x)[tok], rtol=1e-6)


def test_capacity_drops_overflow_deterministically():
    cfg = _cfg(num_experts=4, top_k=1)
    x = jax.random.normal(jax.random.PRNGKey(0), (32, cfg.d_model))
    experts = jnp.zeros((32, 1), jnp.int32)          # all to expert 0
    buf, meta = MOE._sorted_dispatch(x, experts, cfg, tt=8,
                                     capacity_factor=1.0)
    # cap = ceil(32*1/4/8)*8 = 8 → exactly 8 kept, first-come order
    keep = np.asarray(meta["keep"])
    assert keep.sum() == meta["cap"] == 8
    assert keep[:8].all() and not keep[8:].any()


def test_moe_output_zero_for_dropped_tokens_only():
    cfg = _cfg(num_experts=4, top_k=1)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, cfg.d_model))
    p = MOE.init_moe(jax.random.PRNGKey(2), cfg)
    experts = jnp.zeros((32, 1), jnp.int32)          # force expert 0
    gates = jnp.ones((32, 1), jnp.float32)
    y = MOE._sort_moe(p, x, gates, experts, cfg, tt=8, use_kernel=False,
                      capacity_factor=1.0)           # cap = 8
    norms = np.linalg.norm(np.asarray(y), axis=-1)
    assert (norms[:8] > 1e-6).all()                  # kept tokens computed
    assert (norms[8:] < 1e-6).all()                  # dropped → zero


def test_hierarchical_groups_match_global_when_dropless():
    cfg = _cfg()
    cfgG = dataclasses.replace(cfg, moe_groups=4)
    p = MOE.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y_global, _ = MOE.moe_apply(p, x, cfg, use_kernel=False,
                                capacity_factor=float(cfg.num_experts))
    y_groups, _ = MOE.moe_apply(p, x, cfgG, use_kernel=False,
                                capacity_factor=float(cfg.num_experts))
    np.testing.assert_allclose(np.asarray(y_groups), np.asarray(y_global),
                               rtol=2e-4, atol=2e-4)


def test_skew_does_not_change_work_shape():
    """The merge principle: buffer/FLOP shapes are identical under uniform
    and pathological routing (work is equal-per-block by construction)."""
    cfg = _cfg()
    x = jax.random.normal(jax.random.PRNGKey(0), (64, cfg.d_model))
    uni = jax.random.randint(jax.random.PRNGKey(1), (64, cfg.top_k), 0,
                             cfg.num_experts)
    hot = jnp.zeros((64, cfg.top_k), jnp.int32)      # all to expert 0
    b1, _ = MOE._sorted_dispatch(x, uni, cfg, tt=8)
    b2, _ = MOE._sorted_dispatch(x, hot, cfg, tt=8)
    assert b1.shape == b2.shape
