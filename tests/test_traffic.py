"""Traffic analyzer + access checker: clean at HEAD, loud under mutation.

The contract under test is bidirectional:

* the committed tree produces **zero** findings (T010/T011/T012 clean,
  coalescing clean, committed baseline covers the full grid), and
* each injected regression — a gratuitous transpose in the XLA ref, a
  forced f32 materialization in the bf16 pallas path, a stride-permuted
  BlockSpec index map, a dropped coverage entry — is caught by its
  *specific* diagnostic code, not a generic failure.

Mutation tests use :func:`traffic.analyze_variant` (one row) and
:func:`access.check_launch` (one model) so the suite stays fast; the
full 48-row sweep + baseline diff runs in ``make analyze``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import types

import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import access, traffic
from repro.analysis.kernel_audit import _representative
from repro.core.plan import build_plan
from repro.kernels import ops, ref, registry

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def merge_plan():
    return build_plan(_representative(), method="merge")


@pytest.fixture(scope="module")
def merge_spec():
    return registry.get_method("merge")


def _variant(name):
    return next(v for v in traffic._variants() if v.name == name)


def _codes(diags):
    return [d.code for d in diags]


# ------------------------------------------------------------ clean tree ---


def test_pallas_rows_clean_at_head(merge_plan, merge_spec):
    for vname in ("f32", "bf16_acc32"):
        for pass_ in ("fwd", "bwd"):
            row = traffic.analyze_variant(
                merge_spec, merge_plan, _variant(vname), "pallas", pass_)
            assert traffic._check_row(row) == [], row.key
            assert row.bytes > row.min_bytes > 0
            assert row.transposes == 0


def test_xla_row_clean_at_head(merge_plan, merge_spec):
    row = traffic.analyze_variant(
        merge_spec, merge_plan, _variant("f32"), "xla", "fwd")
    assert traffic._check_row(row) == [], row.key
    assert row.transposes == 0 and row.widen_bytes == 0


def test_access_checker_clean_at_head():
    assert access.check_all() == []


def test_committed_baseline_covers_full_grid():
    path = os.path.join(REPO_ROOT, traffic.BASELINE_PATH)
    data = traffic.load_baseline(path)
    assert data["schema"] == traffic.SCHEMA_VERSION
    rows = data["backends"]["cpu"]["rows"]
    methods = [n for n in registry.method_names()
               if registry.get_method(n).traffic is not None]
    want = {f"{m}/{i}/{v.name}/{p}"
            for m in methods for i in traffic.IMPLS
            for v in traffic._variants() for p in traffic.PASSES}
    assert set(rows) == want
    for rec in rows.values():
        assert rec["bytes"] > rec["min_bytes"] > 0


# -------------------------------------------------------- baseline gate ---


def _fake_row(key, nbytes=1000):
    method, impl, variant, pass_ = key.split("/")
    return traffic.TrafficRow(method=method, impl=impl, variant=variant,
                              pass_=pass_, bytes=nbytes, min_bytes=100,
                              transposes=0, widen_bytes=0)


def test_baseline_roundtrip_and_gate(tmp_path):
    path = str(tmp_path / "base.json")
    rows = [_fake_row("merge/pallas/f32/fwd"),
            _fake_row("merge/xla/f32/fwd", 2000)]
    data = traffic.update_baseline(rows, path, backend="cpu")
    # round-trips through disk, clean against itself
    assert traffic.load_baseline(path) == data
    assert traffic.check_baseline(rows, data, "cpu") == []
    # T020: bytes grew past the slack
    grown = [dataclasses.replace(rows[0], bytes=1100), rows[1]]
    assert _codes(traffic.check_baseline(grown, data, "cpu")) == ["T020"]
    # within slack: still clean
    jitter = [dataclasses.replace(rows[0], bytes=1010), rows[1]]
    assert traffic.check_baseline(jitter, data, "cpu") == []
    # T020 also guards the jaxpr stats, not just bytes
    flipped = [dataclasses.replace(rows[0], transposes=1), rows[1]]
    assert _codes(traffic.check_baseline(flipped, data, "cpu")) == ["T020"]
    # T021: variant missing from the baseline / unknown backend
    extra = rows + [_fake_row("merge/pallas/f32/bwd")]
    assert _codes(traffic.check_baseline(extra, data, "cpu")) == ["T021"]
    assert _codes(traffic.check_baseline(rows, data, "tpu")) == ["T021"]
    # T022: stale baseline entry no longer produced
    assert _codes(traffic.check_baseline(rows[:1], data, "cpu")) == ["T022"]


def test_baseline_schema_mismatch_is_loud(tmp_path):
    path = str(tmp_path / "base.json")
    with open(path, "w", encoding="utf-8") as f:
        json.dump({"schema": 999, "backends": {}}, f)
    with pytest.raises(ValueError, match="schema"):
        traffic.load_baseline(path)


# ---------------------------------------------------- injected mutations ---


def test_gratuitous_transpose_fires_t011(merge_plan, merge_spec,
                                         monkeypatch):
    """Two cancelling swapaxes in the XLA ref: invisible to the output,
    caught by the transpose census (the jaxpr, not the optimized HLO)."""
    orig = ref.merge_execute_ref

    def bad(structure, vals, b, *a, **kw):
        b = jnp.swapaxes(jnp.swapaxes(b, -1, -2), -1, -2)
        return orig(structure, vals, b, *a, **kw)

    monkeypatch.setattr(ref, "merge_execute_ref", bad)
    # the mutated call site sits inside jitted ops.merge_execute: drop
    # its trace cache so the patch is traced (and again after, so the
    # mutated trace can't leak into later tests)
    ops.merge_execute.clear_cache()
    try:
        row = traffic.analyze_variant(
            merge_spec, merge_plan, _variant("f32"), "xla", "fwd")
    finally:
        ops.merge_execute.clear_cache()
    assert row.transposes == 2
    assert "T011" in _codes(traffic._check_row(row))


def test_forced_f32_materialization_fires_t012(merge_plan, merge_spec,
                                               monkeypatch):
    """Upcast-then-narrow of the bf16 B panel outside the kernel: a
    silent HBM-level widening the DMA model alone would never see."""
    orig = ops.merge_execute

    def bad(structure, vals, b, **kw):
        b = b.astype(jnp.float32).astype(b.dtype)
        return orig(structure, vals, b, **kw)

    monkeypatch.setattr(ops, "merge_execute", bad)
    row = traffic.analyze_variant(
        merge_spec, merge_plan, _variant("bf16_acc32"), "pallas", "fwd")
    # batch * k * n * 4 widened bytes against a zero allowance
    assert row.widen_bytes >= 2 * merge_plan.meta.k * 256 * 4
    assert "T012" in _codes(traffic._check_row(row))


def test_stride_permuted_index_map_fires_t110(merge_plan, merge_spec):
    """Double the minor block index of the B panel (a stride-2 lane
    walk): the coalescing proof must reject it."""
    var = _variant("f32")
    models = merge_spec.traffic(merge_plan, 256, 2, var, 64)
    mutated = []
    for model in models:
        assert access.check_launch(model) == []   # clean before mutation
        blocks = []
        for blk in model.blocks:
            if blk.name == "b":
                orig_map = blk.index_map
                blocks.append(dataclasses.replace(
                    blk, index_map=lambda *p, _o=orig_map:
                        (*_o(*p)[:-1], 2 * _o(*p)[-1])))
            else:
                blocks.append(blk)
        mutated.append(dataclasses.replace(model, blocks=tuple(blocks)))
    codes = [c for m in mutated for c in _codes(access.check_launch(m))]
    assert "T110" in codes


def test_rowgroup_permutation_mutations_fire_t130_t131():
    plan = build_plan(_representative(), method="rowgroup")
    assert access.check_rowgroup_plan(plan) == []
    inv = np.asarray(plan.fwd["inv_pos"]).copy()
    # T130: duplicate a destination slot — no longer a permutation
    broken = inv.copy()
    broken[1] = broken[0]
    shim = types.SimpleNamespace(meta=plan.meta,
                                 fwd={**plan.fwd, "inv_pos": broken})
    assert _codes(access.check_rowgroup_plan(shim)) == ["T130"]
    # T131: swap two source rows inside one bucket — still a
    # permutation, but the stable-sort order is gone
    order = np.argsort(inv)
    start = 0
    for m_g, _ in plan.meta.extra:
        if m_g > 1:
            r0, r1 = order[start], order[start + 1]
            swapped = inv.copy()
            swapped[r0], swapped[r1] = inv[r1], inv[r0]
            break
        start += m_g
    else:
        pytest.skip("no length bucket with >1 row in the representative")
    shim = types.SimpleNamespace(meta=plan.meta,
                                 fwd={**plan.fwd, "inv_pos": swapped})
    assert "T131" in _codes(access.check_rowgroup_plan(shim))


def test_coverage_is_bidirectional_t101_t102(monkeypatch):
    # dropping a kernel's model entry is loud ...
    pruned = {k: v for k, v in access.EXTRA_KERNELS.items()
              if k != "sddmm"}
    monkeypatch.setattr(access, "EXTRA_KERNELS", pruned)
    diags = access.check_coverage()
    assert ("T101", "repro.kernels.sddmm") in [(d.code, d.where)
                                               for d in diags]
    # ... and so is a stale entry for a kernel that no longer exists
    stale = dict(access.EXTRA_KERNELS, ghost=lambda plan, n, batch: [])
    monkeypatch.setattr(access, "EXTRA_KERNELS", stale)
    diags = access.check_coverage()
    assert ("T102", "repro.kernels.ghost") in [(d.code, d.where)
                                               for d in diags]


# ------------------------------------------------------------------- CLI ---


def test_cli_json_report(tmp_path):
    from repro.analysis import cli
    path = str(tmp_path / "lint.json")
    rc = cli.run_repo_lint(None, out=open(os.devnull, "w"),
                           json_path=path)
    with open(path, encoding="utf-8") as f:
        rec = json.load(f)
    assert rec["command"] == "lint"
    assert rec["exit"] == rc == 0
    assert rec["diagnostics"] == []
