"""Model-component correctness: each fast path vs. its naive reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import rglru as R
from repro.models import ssm as S
from repro.models import model as M


def naive_attention(q, k, v, window=None, softcap=0.0):
    b, sq, h, dh = q.shape
    _, skv, kvh, _ = k.shape
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, dh).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    s = s * dh ** -0.5
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(sq)
    kpos = jnp.arange(skv)
    mask = qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= kpos[None, :] > (qpos[:, None] - window)
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bkgqd", p, v.astype(jnp.float32))
    return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dh)


@pytest.mark.parametrize("window", [None, 8, 32])
@pytest.mark.parametrize("gqa", [1, 4])
def test_flash_attention_vs_naive(window, gqa):
    b, s, kvh, dh = 2, 64, 2, 16
    h = kvh * gqa
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, dh))
    k = jax.random.normal(ks[1], (b, s, kvh, dh))
    v = jax.random.normal(ks[2], (b, s, kvh, dh))
    got = L.flash_attention(q, k, v, window=window, q_chunk=16, kv_chunk=16)
    want = naive_attention(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [None, 16])
def test_decode_attention_matches_last_row(window):
    """decode at position s-1 == last row of full attention."""
    b, s, kvh, g, dh = 2, 32, 2, 2, 16
    h = kvh * g
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, s, h, dh))
    k = jax.random.normal(ks[1], (b, s, kvh, dh))
    v = jax.random.normal(ks[2], (b, s, kvh, dh))
    full = naive_attention(q, k, v, window=window)
    pos = jnp.full((b,), s - 1, jnp.int32)
    got = L.decode_attention(q[:, -1:], k, v, pos, window=window)
    np.testing.assert_allclose(np.asarray(got[:, 0]),
                               np.asarray(full[:, -1]), rtol=2e-5, atol=2e-5)


def _naive_ssd(x, dt, a, b, c):
    """Sequential SSD recurrence (float64-ish reference)."""
    bs, s, h, p = x.shape
    n = b.shape[-1]
    hstate = np.zeros((bs, h, p, n))
    ys = np.zeros((bs, s, h, p))
    x, dt, a, b, c = map(np.asarray, (x, dt, a, b, c))
    for t in range(s):
        decay = np.exp(dt[:, t] * a[None])                    # (bs, h)
        upd = np.einsum("bh,bn,bhp->bhpn", dt[:, t], b[:, t], x[:, t])
        hstate = hstate * decay[..., None, None] + upd
        ys[:, t] = np.einsum("bn,bhpn->bhp", c[:, t], hstate)
    return ys, hstate


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_ssd_chunked_vs_sequential(chunk):
    bs, s, h, p, n = 2, 16, 3, 4, 5
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = jax.random.normal(ks[0], (bs, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bs, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    b = jax.random.normal(ks[3], (bs, s, n))
    c = jax.random.normal(ks[4], (bs, s, n))
    y, st = S.ssd_scan_chunked(x, dt, a, b, c, chunk=chunk)
    y_ref, st_ref = _naive_ssd(x, dt, a, b, c)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st), st_ref, rtol=1e-4, atol=1e-4)


def test_ssd_decode_continues_prefill():
    """prefill(s tokens) then decode == forward over s+1 tokens."""
    cfg = get_smoke_config("mamba2-1.3b")
    p = S.init_ssd(jax.random.PRNGKey(0), cfg)
    u = jax.random.normal(jax.random.PRNGKey(1), (2, 9, cfg.d_model))
    full, _ = S.ssd_apply(p, u, cfg)
    out_pre, state = S.ssd_apply(p, u[:, :8], cfg)
    out_dec, _ = S.ssd_apply(p, u[:, 8:9], cfg, state=state)
    np.testing.assert_allclose(np.asarray(out_dec), np.asarray(full[:, 8:9]),
                               rtol=2e-3, atol=2e-3)


def test_rglru_decode_continues_prefill():
    cfg = get_smoke_config("recurrentgemma-2b")
    p = R.init_rglru(jax.random.PRNGKey(0), cfg)
    u = jax.random.normal(jax.random.PRNGKey(1), (2, 9, cfg.d_model))
    full, _ = R.rglru_apply(p, u, cfg)
    _, state = R.rglru_apply(p, u[:, :8], cfg)
    out_dec, _ = R.rglru_apply(p, u[:, 8:9], cfg, state=state)
    np.testing.assert_allclose(np.asarray(out_dec), np.asarray(full[:, 8:9]),
                               rtol=2e-4, atol=2e-4)


def test_rglru_scan_vs_sequential():
    cfg = get_smoke_config("recurrentgemma-2b")
    p = R.init_rglru(jax.random.PRNGKey(3), cfg)
    u = jax.random.normal(jax.random.PRNGKey(4), (1, 12, cfg.d_model))
    full, final = R.rglru_apply(p, u, cfg)
    # step one token at a time
    state = R.init_rglru_state(cfg, 1)
    outs = []
    for t in range(12):
        o, state = R.rglru_apply(p, u[:, t:t + 1], cfg, state=state)
        outs.append(o)
    seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(seq), np.asarray(full),
                               rtol=2e-4, atol=2e-4)


def test_moe_sort_matches_dense():
    import dataclasses
    cfg = get_smoke_config("olmoe-1b-7b")
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    p = MOE.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y_sort, aux1 = MOE.moe_apply(p, x, cfg, use_kernel=False,
                                 capacity_factor=float(cfg.num_experts))
    cfg_d = dataclasses.replace(cfg, moe_impl="dense")
    y_dense, aux2 = MOE.moe_apply(p, x, cfg_d, use_kernel=False)
    np.testing.assert_allclose(np.asarray(y_sort), np.asarray(y_dense),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(aux1), float(aux2), rtol=1e-5)


def test_moe_sort_matches_pallas_kernel():
    cfg = get_smoke_config("olmoe-1b-7b")
    p = MOE.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y_xla, _ = MOE.moe_apply(p, x, cfg, use_kernel=False, tt=8,
                             capacity_factor=float(cfg.num_experts))
    y_pal, _ = MOE.moe_apply(p, x, cfg, use_kernel=True, tt=8,
                             capacity_factor=float(cfg.num_experts))
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_xla),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "recurrentgemma-2b",
                                  "mamba2-1.3b", "olmoe-1b-7b"])
def test_prefill_decode_consistency(arch):
    """Gold standard: prefill(s) + decode == teacher-forced full forward.

    MoE archs compare in f32: top-k routing is discontinuous, so bf16
    noise can flip a near-tied expert choice between the two (individually
    correct) paths — f32 isolates the algorithm (2e-6 agreement)."""
    import dataclasses
    cfg = get_smoke_config(arch)
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, compute_dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    b, s = 1, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s + 1), 0,
                                cfg.vocab_size)
    # full forward logits at position s-1 predict token s
    h = M.embed_inputs(params, cfg, {"tokens": tokens[:, :s + 1]})
    h, _, _ = M.forward(params, cfg, h)
    h = L.norm_apply(params["final_norm"], h, cfg.norm)
    full_logits = h[:, s - 1].astype(jnp.float32) @ M.unembed_matrix(
        params, cfg).T.astype(jnp.float32)
    # prefill s tokens, then the same position's logits come from prefill
    caches, pre_logits, pos = M.prefill(params, cfg,
                                        {"tokens": tokens[:, :s]},
                                        cache_len=s + 4)
    np.testing.assert_allclose(np.asarray(pre_logits[:, 0]),
                               np.asarray(full_logits), rtol=3e-2, atol=3e-2)
    # decode token s: logits must match full forward at position s
    full_logits_s = h[:, s].astype(jnp.float32) @ M.unembed_matrix(
        params, cfg).T.astype(jnp.float32)
    dec_logits, _ = M.decode_step(params, cfg, caches,
                                  {"tokens": tokens[:, s:s + 1]}, pos)
    np.testing.assert_allclose(np.asarray(dec_logits[:, 0]),
                               np.asarray(full_logits_s),
                               rtol=3e-2, atol=3e-2)


def test_chunked_ce_matches_full():
    from repro.models.losses import chunked_cross_entropy
    b, s, d, v = 2, 16, 8, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    h = jax.random.normal(ks[0], (b, s, d))
    emb = jax.random.normal(ks[1], (v, d))
    y = jax.random.randint(ks[2], (b, s), 0, v)
    nll, cnt = chunked_cross_entropy(h, emb, y, chunk=4)
    logits = h @ emb.T
    want = (jax.nn.logsumexp(logits, -1)
            - jnp.take_along_axis(logits, y[..., None], -1)[..., 0]).mean()
    np.testing.assert_allclose(float(nll), float(want), rtol=1e-5)
    assert float(cnt) == b * s
