"""Observability subsystem: tracing, metrics, roofline, engine wiring.

Covers the three legs of ``repro.obs`` (ring-buffer tracer + Chrome
export, the typed metrics registry, the roofline accountant and its
measured roof cache) and — more importantly — the *engine integration*:
``PlanPolicy.resolve`` records which ladder rung fired, ``PlanCache``
counts hits/misses/evictions through the registry (back-compat
``stats()`` preserved), ``execute_plan`` emits dispatch events under
tracing, and sharded builds trace the per-shard method mix + nnz
imbalance.  The disabled path must be a no-op (shared null span, no
events): the warm execute path pays one attribute read.

The sharded-trace tests need 8 devices; like ``test_distributed_spmm``
they are re-run in a forced 8-device subprocess when the parent came up
single-device, so they execute everywhere.
"""
import json
import os
import threading

import jax
import numpy as np
import pytest

from repro import obs
from repro.core import (ExecutionConfig, PlanPolicy, ShardSpec, build_plan,
                        execute_plan, random_csr)
from repro.core.plan import pattern_fingerprint
from repro.engine import PlanCache
from repro.matrices import compute_stats
from repro.obs import validate as obs_validate
from repro.obs.metrics import MetricsRegistry
from repro.obs.roofline import clear_roof_memo
from repro.obs.trace import _NULL_SPAN
from repro.tune.db import TuneDB, TuneRecord

NDEV = 8
IN_CHILD = bool(os.environ.get("_REPRO_FORCED_CHILD"))
needs_devices = pytest.mark.skipif(
    jax.device_count() < NDEV,
    reason=f"needs {NDEV} devices (covered by the forced-subprocess "
    "wrapper / make test-sharded)")

_XLA = ExecutionConfig(impl="xla")


def _csr(seed=0, m=24, k=16, npr=(0, 6)):
    return random_csr(jax.random.PRNGKey(seed), m, k, nnz_per_row=npr)


def _b(a, n=5, seed=1):
    return jax.random.normal(jax.random.PRNGKey(seed), (a.k, n))


# ------------------------------------------------------------- metrics ---


def test_counter_labels_and_values():
    reg = MetricsRegistry()
    fam = reg.counter("c", "help", labels=("x",))
    fam.labels(x="a").inc()
    fam.labels(x="a").inc(3)
    fam.labels(x="b").inc()
    assert fam.labels(x="a").value == 4
    assert fam.labels(x="b").value == 1
    assert {tuple(c.labels.items()) for c in fam.children()} == \
        {(("x", "a"),), (("x", "b"),)}


def test_gauge_set_inc_dec():
    reg = MetricsRegistry()
    g = reg.gauge("g")
    g.set(2.5)
    g.inc()
    g.dec(0.5)
    assert g.value == 3.0


def test_histogram_snapshot_and_percentiles():
    reg = MetricsRegistry()
    h = reg.histogram("h")
    for v in range(1, 101):
        h.observe(float(v))
    s = reg.get("h").labels().snapshot()
    assert s["count"] == 100 and s["sum"] == 5050.0
    assert s["min"] == 1.0 and s["max"] == 100.0
    assert s["mean"] == pytest.approx(50.5)
    assert s["p50"] == pytest.approx(50.5)
    assert s["p95"] == pytest.approx(95.05)
    # empty histogram: count-0 snapshot, NaN percentile
    h2 = reg.histogram("h2")
    assert reg.get("h2").snapshot()["values"] == []
    h2.observe(7.0)
    assert reg.get("h2").labels().percentile(50) == 7.0


def test_registry_declare_idempotent_and_conflicting():
    reg = MetricsRegistry()
    a = reg.counter("n", "first", labels=("l",))
    b = reg.counter("n", "second", labels=("l",))
    assert a is b
    with pytest.raises(ValueError, match="already declared"):
        reg.gauge("n", labels=("l",))
    with pytest.raises(ValueError, match="already declared"):
        reg.counter("n", labels=("other",))


def test_label_schema_enforced():
    reg = MetricsRegistry()
    fam = reg.counter("n", labels=("x", "y"))
    with pytest.raises(ValueError, match="takes labels"):
        fam.labels(x="a")
    with pytest.raises(ValueError, match="bind them"):
        fam.inc()                      # unlabeled convenience needs no labels


def test_counter_concurrent_increments_lose_nothing():
    reg = MetricsRegistry()
    c = reg.counter("c", labels=("who",)).labels(who="race")
    threads = [threading.Thread(target=lambda: [c.inc() for _ in range(500)])
               for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == 8 * 500


def test_report_and_dump_roundtrip(tmp_path):
    reg = MetricsRegistry()
    reg.counter("hits", "cache hits", labels=("cache",)) \
        .labels(cache="c0").inc(2)
    reg.histogram("lat").observe(10.0)
    text = reg.report()
    assert "hits{cache=c0} 2" in text
    assert "lat count=1" in text
    path = reg.dump(str(tmp_path / "m.json"), extra={"run": "t"})
    doc = json.loads(open(path).read())
    assert doc["schema"] == 1 and doc["run"] == "t"
    assert doc["metrics"]["hits"]["values"][0]["value"] == 2
    assert obs_validate.validate_metrics(path, require_names=("hits",)) == []


# -------------------------------------------------------------- tracer ---


def test_span_event_ring_and_chrome_export(tmp_path):
    with obs.tracing(capacity=64) as tr:
        with obs.span("work", cat="plan", m=3) as sp:
            sp.set(rung="exact")
        obs.event("tick", cat="cache", hit=True)
        evs = tr.events()
        assert [e["ph"] for e in evs] == ["X", "i"]
        assert evs[0]["args"] == {"m": 3, "rung": "exact"}
        assert evs[0]["dur"] >= 0
        assert tr.events(cat="cache", name="tick")
        path = tr.export(str(tmp_path / "t.json"))
    doc = json.loads(open(path).read())
    assert {e["name"] for e in doc["traceEvents"]} == {"work", "tick"}
    assert obs_validate.validate_trace(
        path, require_cats=("plan", "cache")) == []


def test_ring_capacity_drops_oldest():
    with obs.tracing(capacity=4) as tr:
        for i in range(6):
            obs.event(f"e{i}")
        assert len(tr) == 4
        assert tr.dropped == 2
        assert [e["name"] for e in tr.events()] == ["e2", "e3", "e4", "e5"]
        tr.clear()
        assert len(tr) == 0 and tr.dropped == 0


def test_disabled_path_is_noop():
    assert not obs.is_enabled()        # tier-1 runs untraced
    assert obs.span("x", cat="plan") is _NULL_SPAN
    with obs.span("x") as sp:
        sp.set(anything=1)             # swallowed
    obs.event("x")                     # no tracer, no error
    before = obs.get_tracer()
    with obs.tracing() as tr:
        assert obs.is_enabled() and obs.get_tracer() is tr
        obs.event("inner")
    assert not obs.is_enabled() and obs.get_tracer() is before


def test_tracing_nests_and_restores():
    with obs.tracing() as outer:
        obs.event("a")
        with obs.tracing() as inner:
            obs.event("b")
        assert [e["name"] for e in inner.events()] == ["b"]
        assert obs.get_tracer() is outer
        obs.event("c")
        assert [e["name"] for e in outer.events()] == ["a", "c"]


# ----------------------------------------------------- engine: PlanCache ---


def test_plan_cache_metrics_and_backcompat_stats():
    cache = PlanCache(maxsize=2, name="t-obs-cache")
    a1, a2, a3 = _csr(1), _csr(2), _csr(3)
    pol = PlanPolicy(method="merge")
    cache.get(a1, pol)
    cache.get(a1, pol)                 # hit
    cache.get(a2, pol)
    cache.get(a3, pol)                 # evicts a1
    s = cache.stats()
    assert (s.hits, s.misses, s.evictions) == (1, 3, 1)
    fam = obs.registry.get("plan_cache_events_total")
    assert fam.labels(cache="t-obs-cache", event="hit").value == 1
    assert fam.labels(cache="t-obs-cache", event="miss").value == 3
    assert obs.registry.get("plan_cache_size") \
        .labels(cache="t-obs-cache").value == 2
    cache.clear()
    s = cache.stats()
    assert (s.hits, s.misses, s.evictions) == (0, 0, 0)


def test_plan_cache_events_traced():
    cache = PlanCache(name="t-obs-trace")
    a = _csr(4)
    with obs.tracing() as tr:
        cache.get(a, PlanPolicy(method="merge"))
        cache.get(a, PlanPolicy(method="merge"))
    miss = tr.events(cat="cache", name="cache.miss")
    hit = tr.events(cat="cache", name="cache.hit")
    assert len(miss) == 1 and len(hit) == 1
    assert miss[0]["args"]["cache"] == "t-obs-trace"
    assert hit[0]["args"]["method"] == "merge"
    assert tr.events(cat="plan", name="plan.build")  # span around build


# ------------------------------------------------ resolve: ladder rungs ---


def _rung_value(rung, method):
    return obs.registry.get("plan_resolve_total") \
        .labels(rung=rung, method=method).value


def _resolve_delta(rung, method, policy, a):
    before = _rung_value(rung, method)
    r = policy.resolve(a)
    return r, _rung_value(rung, method) - before


def test_resolve_rung_explicit_and_analytic():
    a = _csr(5)
    r, d = _resolve_delta("explicit", "rowsplit",
                          PlanPolicy(method="rowsplit"), a)
    assert r.method == "rowsplit" and d == 1
    # tunedb=None opts out of the ladder: analytic heuristic decides
    r, d = _resolve_delta("analytic", None, PlanPolicy(tunedb=None), a)
    assert d == 0 or True              # method unknown a priori; check below
    assert _rung_value("analytic", r.method) >= 1


def _db_with(a, method="merge", fingerprint=None, l_pad=None):
    s = compute_stats(a)
    db = TuneDB(backend="test")
    db.record(fingerprint or pattern_fingerprint(a),
              TuneRecord(method=method, merge_us=1.0, rowsplit_us=2.0,
                         m=s.m, k=s.k, d=s.d, cv=s.cv, n=8, l_pad=l_pad))
    return db


def test_resolve_rung_exact():
    a = _csr(6)
    r, d = _resolve_delta("exact", "merge",
                          PlanPolicy(tunedb=_db_with(a)), a)
    assert r.method == "merge" and d == 1


def test_resolve_rung_class():
    a = _csr(7)
    # same class signature (stats copied from `a`), different fingerprint:
    # the exact rung misses, the binned class rung hits.
    db = _db_with(a, fingerprint="some-other-pattern")
    r, d = _resolve_delta("class", "merge", PlanPolicy(tunedb=db), a)
    assert r.method == "merge" and d == 1


def test_resolve_rung_calibrated():
    a = _csr(8)
    # non-None TuneDB with no matching record: the ladder bottoms out in
    # the DB-calibrated threshold heuristic.
    db = TuneDB(backend="test")
    before = sum(c.value
                 for c in obs.registry.get("plan_resolve_total").children()
                 if c.labels["rung"] == "calibrated")
    PlanPolicy(tunedb=db).resolve(a)
    after = sum(c.value
                for c in obs.registry.get("plan_resolve_total").children()
                if c.labels["rung"] == "calibrated")
    assert after - before == 1


def test_resolve_fallback_traced():
    """Exact record replays rowgroup, caller's l_pad rejects it: the
    analytic fallback fires and the trace event carries fallback=True."""
    a = _csr(9)
    lmax = int(np.diff(np.asarray(a.row_ptr)).max())
    db = _db_with(a, method="rowgroup")
    with obs.tracing() as tr:
        r = PlanPolicy(tunedb=db, l_pad=lmax + 2).resolve(a)
    assert r.method in ("merge", "rowsplit")
    evs = tr.events(cat="plan", name="plan.resolve")
    assert len(evs) == 1
    assert evs[0]["args"]["fallback"] is True
    assert evs[0]["args"]["rung"] == "analytic"


def test_resolve_trace_event_args():
    a = _csr(10)
    with obs.tracing() as tr:
        PlanPolicy(method="merge").resolve(a)
    ev, = tr.events(cat="plan", name="plan.resolve")
    assert ev["args"]["rung"] == "explicit"
    assert ev["args"]["method"] == "merge"
    assert ev["args"]["m"] == a.m and ev["args"]["k"] == a.k
    assert ev["args"]["nnz_pad"] == a.nnz_pad


# --------------------------------------------------- dispatch + execute ---


def test_dispatch_event_and_execute_counter():
    a = _csr(11)
    plan = build_plan(a, method="merge", with_transpose=False)
    b = _b(a)
    fam = obs.registry.get("plan_execute_total")
    with obs.tracing() as tr:
        execute_plan(plan, a.vals, b, _XLA)
    ev = tr.events(cat="dispatch", name="dispatch")
    assert len(ev) == 1
    args = ev[0]["args"]
    assert args["method"] == "merge" and args["impl"] == "xla"
    assert args["n"] == b.shape[-1]
    label = f"merge:{a.m}x{a.k}:nnz{a.nnz_pad}"
    assert fam.labels(plan=label, impl="xla").value >= 1
    # per-execute accounting is gated on tracing: untraced calls add nothing
    before = fam.labels(plan=label, impl="xla").value
    execute_plan(plan, a.vals, b, _XLA)
    assert fam.labels(plan=label, impl="xla").value == before


# ------------------------------------------------------------- roofline ---


def test_spmm_min_bytes_model():
    assert obs.spmm_min_bytes(4, 8, 2, 10) == 10 * 8 + 8 * 2 * 4 + 4 * 2 * 4
    assert obs.spmm_flops(10, 2) == 40.0


def test_plan_min_bytes_dtype_scaling():
    a = _csr(12)
    plan = build_plan(a, method="merge", with_transpose=False)
    f32 = obs.plan_min_bytes(plan.meta, 16)
    bf16 = obs.plan_min_bytes(plan.meta, 16, val_dtype="bfloat16")
    assert f32 > bf16                  # half-width vals, B, and C
    m, k = plan.meta.shape
    nnz = plan.meta.nnz_pad
    assert f32 == obs.spmm_min_bytes(m, k, 16, nnz)
    assert bf16 == obs.spmm_min_bytes(m, k, 16, nnz, val_bytes=2,
                                      out_bytes=2)


def test_accountant_math_and_report():
    acc = obs.RooflineAccountant()
    # 10 calls totaling 1000 us, 1 MB/call: 10 MB / 1e-3 s = 1e10 B/s
    acc.record(("spmm", "merge", "xla", "float32"), wall_us=1000.0,
               min_bytes=10e6, flops=2e6, calls=10)
    roof = obs.Roof(backend="cpu", bytes_per_s=2e10, elements=1,
                    source="measured")
    row, = acc.rows(roof)
    assert row["achieved_bytes_per_s"] == pytest.approx(1e10)
    assert row["roof_fraction"] == pytest.approx(0.5)
    assert row["gflops_per_s"] == pytest.approx(2.0)
    text = acc.report(roof)
    assert "50.0% of roof" in text and "merge/xla" in text
    acc.reset()
    assert len(acc) == 0
    assert "no executions" in acc.report()


def test_accountant_account_plan_uses_model():
    acc = obs.RooflineAccountant()
    a = _csr(13)
    plan = build_plan(a, method="rowsplit", with_transpose=False)
    acc.account_plan(plan.meta, 16, wall_us=100.0, impl="xla", calls=4)
    row, = acc.rows()
    assert row["method"] == "rowsplit" and row["calls"] == 4
    assert row["min_bytes"] == 4 * obs.plan_min_bytes(plan.meta, 16)


def test_measure_roof_file_cache(tmp_path):
    clear_roof_memo()
    cache = str(tmp_path / "arts")
    r1 = obs.measure_roof(cache_dir=cache, elements=1 << 12, repeat=1)
    assert r1.source == "measured" and r1.bytes_per_s > 0
    assert os.path.exists(os.path.join(cache, "roofline_roof.json"))
    clear_roof_memo()                  # drop the in-process memo
    r2 = obs.measure_roof(cache_dir=cache, elements=1 << 12, repeat=1)
    assert r2.source == "cached"
    assert r2.bytes_per_s == pytest.approx(r1.bytes_per_s)
    r3 = obs.measure_roof(cache_dir=cache, force=True, elements=1 << 12,
                          repeat=1)
    assert r3.source == "measured"
    clear_roof_memo()


def test_obs_report_combines_legs():
    a = _csr(14)
    PlanPolicy(method="merge").resolve(a)
    plan = build_plan(a, method="merge", with_transpose=False)
    obs.accountant.account_plan(plan.meta, 8, wall_us=50.0, impl="xla")
    roof = obs.Roof(backend="cpu", bytes_per_s=1e10, elements=1,
                    source="cached")
    try:
        text = obs.report(roof=roof)
        assert "resolution ladder" in text and "explicit=" in text
        assert "plan_resolve_total{rung=explicit,method=merge}" in text
        assert "% of roof" in text and "merge/xla" in text
    finally:
        obs.accountant.reset()


# ------------------------------------------------------------- validate ---


def test_validate_trace_rejects_garbage(tmp_path):
    p = tmp_path / "bad.json"
    p.write_text("not json")
    assert obs_validate.validate_trace(str(p))
    p.write_text(json.dumps({"events": []}))
    assert "traceEvents" in obs_validate.validate_trace(str(p))[0]
    p.write_text(json.dumps({"traceEvents": [
        {"name": "x", "ph": "X", "ts": 0, "pid": 1, "tid": 1}]}))
    probs = obs_validate.validate_trace(str(p))
    assert any("without numeric 'dur'" in x for x in probs)
    p.write_text(json.dumps({"traceEvents": [
        {"name": "x", "cat": "plan", "ph": "i", "ts": 0, "pid": 1,
         "tid": 1}]}))
    assert obs_validate.validate_trace(str(p)) == []
    probs = obs_validate.validate_trace(str(p), require_cats=("dispatch",))
    assert any("required category 'dispatch'" in x for x in probs)
    assert obs_validate.validate_trace(str(p), min_events=2)


def test_validate_metrics_rejects_garbage(tmp_path):
    p = tmp_path / "m.json"
    p.write_text(json.dumps({"schema": 2, "metrics": {}}))
    assert "schema" in obs_validate.validate_metrics(str(p))[0]
    p.write_text(json.dumps({"schema": 1, "metrics": {}}))
    assert obs_validate.validate_metrics(str(p))
    p.write_text(json.dumps(
        {"schema": 1, "metrics": {"c": {"type": "counter", "values": []}}}))
    assert obs_validate.validate_metrics(str(p)) == []
    assert obs_validate.validate_metrics(str(p), require_names=("absent",))


def test_validate_cli_exit_codes(tmp_path, capsys):
    with obs.tracing() as tr:
        obs.event("x", cat="plan")
        trace = tr.export(str(tmp_path / "t.json"))
    metrics = obs.registry.dump(str(tmp_path / "m.json"))
    assert obs_validate.main(["--trace", trace, "--metrics", metrics,
                              "--require-cats", "plan"]) == 0
    assert obs_validate.main(["--trace", trace,
                              "--require-cats", "nonexistent"]) == 1


# ------------------------------------------------- benchmarks stay wired ---


def test_bench_modules_all_registered():
    from benchmarks import run as bench_run
    assert bench_run.check_registration() == []
    # drop one module: the check names the missing stem
    mods = bench_run._mods()
    missing = bench_run.check_registration(mods[:-1])
    assert mods[-1][1].__name__.rsplit(".", 1)[-1] in missing


def test_timeit_result_surface():
    from repro.tune import TimingResult, timeit
    t = timeit(lambda: None, warmup=0, repeat=5)
    assert isinstance(t, TimingResult) and isinstance(t, float)
    assert len(t.samples) == 5
    assert t.min <= t.p50 <= t.p95 <= t.max
    assert float(t) == t.median and t.cv >= 0.0
    # benchmarks.common re-exports the same objects
    from benchmarks import common
    assert common.timeit is timeit and common.TimingResult is TimingResult


# -------------------------------------------- sharded trace (8 devices) ---


@needs_devices
def test_sharded_build_and_execute_traced():
    a = _csr(20, m=64, k=32, npr=(0, 9))
    b = _b(a, n=6)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:NDEV]), ("data",))
    from repro.distributed.spmm import build_sharded_plan, execute_sharded
    with obs.tracing() as tr:
        plan = build_sharded_plan(
            a, PlanPolicy(method="merge",
                          shards=ShardSpec(n=NDEV, mesh=mesh)))
        execute_sharded(plan, a.vals, b, _XLA)
    sp, = tr.events(cat="plan", name="plan.build_sharded")
    assert sp["args"]["n_shards"] == NDEV
    assert len(sp["args"]["methods"]) == NDEV
    assert sp["args"]["nnz_imbalance"] >= 1.0
    assert len(sp["args"]["nnz_per_shard"]) == NDEV
    asm, = tr.events(cat="plan", name="plan.sharded_assembled")
    assert asm["args"]["methods"] == ["merge"] * NDEV
    d, = tr.events(cat="dispatch", name="dispatch.sharded")
    assert d["args"]["path"] == "spmd" if asm["args"]["uniform"] else "loop"
    assert d["args"]["n_shards"] == NDEV
    gauge = obs.registry.get("shard_nnz_imbalance").labels(dim="rows")
    assert gauge.value == pytest.approx(sp["args"]["nnz_imbalance"],
                                        abs=1e-3)


@pytest.mark.skipif(jax.device_count() >= NDEV or IN_CHILD,
                    reason="already running with a forced multi-device "
                    "substrate")
def test_sharded_trace_in_forced_subprocess(forced_device_run):
    res = forced_device_run(
        "tests/test_obs.py::test_sharded_build_and_execute_traced", NDEV)
    assert res.returncode == 0, (
        f"forced {NDEV}-device run failed:\n{res.stdout}\n{res.stderr}")
    assert " passed" in res.stdout
