"""Coverage extras: PackedFileSource, masked/capped chunked CE."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data import DataConfig, PackedFileSource
from repro.models.losses import chunked_cross_entropy


def test_packed_file_source_roundtrip(tmp_path):
    path = str(tmp_path / "tokens.bin")
    toks = np.arange(1000, dtype=np.int32) % 97
    toks.tofile(path)
    cfg = DataConfig(vocab_size=97, seq_len=16, global_batch=4)
    src = PackedFileSource(path, cfg)
    b0 = src.batch_at(0)
    assert b0["tokens"].shape == (4, 16)
    # labels are the next token of the same stream window
    np.testing.assert_array_equal(np.asarray(b0["tokens"][:, 1:]),
                                  np.asarray(b0["labels"][:, :-1]))
    # deterministic across instantiations (fault-tolerant replay)
    b0b = PackedFileSource(path, cfg).batch_at(0)
    np.testing.assert_array_equal(np.asarray(b0["tokens"]),
                                  np.asarray(b0b["tokens"]))
    # shards concatenate to the global batch
    s0 = PackedFileSource(path, cfg, 0, 2).batch_at(3)
    s1 = PackedFileSource(path, cfg, 1, 2).batch_at(3)
    full = src.batch_at(3)
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(s0["tokens"]), np.asarray(s1["tokens"])]),
        np.asarray(full["tokens"]))


def test_chunked_ce_mask_excludes_tokens():
    b, s, d, v = 2, 8, 4, 11
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    h = jax.random.normal(ks[0], (b, s, d))
    emb = jax.random.normal(ks[1], (v, d))
    y = jax.random.randint(ks[2], (b, s), 0, v)
    mask = jnp.ones((b, s)).at[:, -2:].set(0.0)  # ignore last 2 positions
    nll_m, cnt = chunked_cross_entropy(h, emb, y, chunk=4, mask=mask)
    assert float(cnt) == b * (s - 2)
    # reference over the unmasked prefix only
    logits = h[:, :-2] @ emb.T
    want = (jax.nn.logsumexp(logits, -1) - jnp.take_along_axis(
        logits, y[:, :-2, None], -1)[..., 0]).mean()
    np.testing.assert_allclose(float(nll_m), float(want), rtol=1e-5)


def test_chunked_ce_softcap_changes_hard_logits():
    b, s, d, v = 1, 4, 4, 7
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    h = jax.random.normal(ks[0], (b, s, d)) * 10  # large logits
    emb = jax.random.normal(ks[1], (v, d))
    y = jax.random.randint(ks[2], (b, s), 0, v)
    plain, _ = chunked_cross_entropy(h, emb, y, chunk=4)
    capped, _ = chunked_cross_entropy(h, emb, y, chunk=4, logit_softcap=5.0)
    assert abs(float(plain) - float(capped)) > 1e-3
