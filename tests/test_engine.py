"""Plan engine: cache identity semantics, no-replan guarantee, trace
ergonomics.

The acceptance criterion for the plan-once/execute-many refactor:
``plan_merge``/``plan_rowsplit`` run at most once per sparsity pattern in
a jitted train/serve loop — asserted here with a cache-hit counter and
with call counters monkeypatched onto the planning phase itself.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.core import (ExecutionConfig, Heuristic, PlanPolicy,
                        build_plan, execute_plan, pattern_fingerprint,
                        random_csr, spmm)
from repro.kernels import merge_spmm, ops, ref, rowsplit_spmm
from repro.models.sparse import SparseLinear
from repro.runtime import steps as R


def _csr(seed=0, m=32, k=24, npr=(0, 8)):
    return random_csr(jax.random.PRNGKey(seed), m, k, nnz_per_row=npr)


def _with_vals(a, seed):
    vals = jax.random.normal(jax.random.PRNGKey(seed), a.vals.shape)
    return dataclasses.replace(a, vals=vals)


# ------------------------------------------------------------ cache hits ---


def test_cache_hit_same_pattern_different_values():
    cache = engine.PlanCache()
    a = _csr(0)
    p1 = cache.get(a)
    p2 = cache.get(_with_vals(a, 7))        # same pattern, new values
    assert p1 is p2
    s = cache.stats()
    assert (s.hits, s.misses) == (1, 1)


def test_cache_miss_different_pattern():
    cache = engine.PlanCache()
    cache.get(_csr(0))
    cache.get(_csr(1))                       # different pattern
    s = cache.stats()
    assert (s.hits, s.misses) == (0, 2)


def test_cache_key_resolves_auto_and_defaults():
    cache = engine.PlanCache()
    a = _csr(2, npr=(0, 4))                  # short rows → heuristic: merge
    assert Heuristic().choose(a) == "merge"
    p1 = cache.get(a, PlanPolicy(method="auto"))
    p2 = cache.get(a, PlanPolicy(method="merge", t=merge_spmm.DEFAULT_T))
    assert p1 is p2 and cache.stats().hits == 1


def test_cache_lru_eviction():
    cache = engine.PlanCache(maxsize=2)
    a0, a1, a2 = _csr(0), _csr(1), _csr(2)
    cache.get(a0)
    cache.get(a1)
    cache.get(a2)                            # evicts a0
    assert cache.stats().evictions == 1
    cache.get(a1)                            # still resident
    assert cache.stats().hits == 1
    cache.get(a0)                            # rebuilt
    assert cache.stats().misses == 4


def test_alias_map_is_bounded():
    """Cycling distinct raw request keys (fresh heuristic objects with new
    thresholds) must not grow the alias map without bound — the long-lived
    server leak of ISSUE 3."""
    cache = engine.PlanCache(maxsize=4, alias_maxsize=8)
    a = _csr(20, npr=(0, 4))                 # short rows: merge either way
    for i in range(50):
        cache.get(a, PlanPolicy(heuristic=Heuristic(threshold=100.0 + i)))
    s = cache.stats()
    assert s.misses == 1, "distinct thresholds resolved to the same plan"
    assert len(cache._aliases) <= 8
    assert s.aliases <= 8
    assert s.alias_evictions == 50 - 8
    # aliased fast path still hits after evictions
    cache.get(a, PlanPolicy(heuristic=Heuristic(threshold=149.0)))
    assert cache.stats().hits == 50


def test_alias_map_pruned_with_canonical_eviction():
    cache = engine.PlanCache(maxsize=1)
    a0, a1 = _csr(21), _csr(22)
    cache.get(a0)
    cache.get(a1)                            # evicts a0's plan
    assert cache.stats().evictions == 1
    assert all(c in cache._entries for c in cache._aliases.values())


def test_fingerprint_is_pattern_identity():
    a = _csr(3)
    assert pattern_fingerprint(a) == pattern_fingerprint(_with_vals(a, 9))
    assert pattern_fingerprint(a) != pattern_fingerprint(_csr(4))


# ------------------------------------------------- the no-replan criterion ---


def test_jitted_loop_never_replans(monkeypatch):
    """plan_merge/plan_rowsplit run at most once per pattern — zero times
    inside the jitted loop, because the plan was built at layer-build."""
    calls = {"merge": 0, "rowsplit": 0}
    orig_m = merge_spmm.plan_merge_structure
    orig_r = rowsplit_spmm.plan_rowsplit_structure
    monkeypatch.setattr(
        merge_spmm, "plan_merge_structure",
        lambda *a, **k: calls.__setitem__("merge", calls["merge"] + 1)
        or orig_m(*a, **k))
    monkeypatch.setattr(
        rowsplit_spmm, "plan_rowsplit_structure",
        lambda *a, **k: calls.__setitem__("rowsplit", calls["rowsplit"] + 1)
        or orig_r(*a, **k))

    cache = engine.PlanCache()
    a = _csr(5, m=24, k=16)
    plan = cache.get(a, PlanPolicy(method="rowsplit"))
    built = dict(calls)
    assert built["rowsplit"] == 1

    @jax.jit
    def step(p, vals, b):
        return execute_plan(p, vals, b, ExecutionConfig(impl="xla"))

    b = jax.random.normal(jax.random.PRNGKey(0), (a.k, 8))
    for i in range(4):                       # fresh values every step
        step(plan, jax.random.normal(jax.random.PRNGKey(i),
                                     a.vals.shape), b)
    assert calls == built, "jitted loop replanned"
    assert cache.get(_with_vals(a, 1),
                     PlanPolicy(method="rowsplit")) is plan
    assert calls == built, "cache hit replanned"


def test_sparse_linear_carries_plan_through_jit():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((16, 24)), jnp.float32)
    sl = SparseLinear.from_dense(w, 0.3)
    x = jnp.asarray(rng.standard_normal((4, 16)), jnp.float32)

    @jax.jit
    def f(layer, xx):
        return layer(xx, ExecutionConfig(impl="xla"))

    misses0 = engine.cache_stats().misses
    y1 = f(sl, x)
    y2 = f(sl, 2.0 * x)
    assert engine.cache_stats().misses == misses0
    np.testing.assert_allclose(np.asarray(y2), 2 * np.asarray(y1),
                               rtol=1e-5, atol=1e-5)


def test_ensure_spmm_plans_roundtrip():
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.standard_normal((12, 16)), jnp.float32)
    sl = SparseLinear.from_dense(w, 0.5)
    stripped = {"mlp": {"w1": dataclasses.replace(sl, plan=None)},
                "dense": jnp.ones((3, 3))}
    fixed = R.ensure_spmm_plans(stripped)
    assert fixed["mlp"]["w1"].plan is not None
    assert fixed["mlp"]["w1"].plan.meta == sl.plan.meta
    np.testing.assert_array_equal(np.asarray(fixed["dense"]), np.ones((3, 3)))


# -------------------------------------------------------- plan execution ---


@pytest.mark.parametrize("method", ["merge", "rowsplit"])
def test_execute_plan_matches_dense(method):
    a = _csr(6, m=40, k=32, npr=(0, 10))
    b = jax.random.normal(jax.random.PRNGKey(1), (a.k, 20))
    plan = build_plan(a, method=method)
    want = np.asarray(ref.spmm_dense_ref(a, b))
    for impl in ("xla", "pallas"):
        got = execute_plan(plan, a.vals, b, ExecutionConfig(impl=impl))
        np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5,
                                   atol=2e-5)


def test_spmm_routes_through_engine_cache():
    a = _csr(7)
    b = jax.random.normal(jax.random.PRNGKey(2), (a.k, 8))
    engine.clear_cache()
    spmm(a, b, exec=ExecutionConfig(impl="xla"))
    misses = engine.cache_stats().misses
    assert misses == 1
    spmm(_with_vals(a, 3), b,
         exec=ExecutionConfig(impl="xla"))    # same pattern → no rebuild
    s = engine.cache_stats()
    assert (s.misses, s.hits) == (misses, 1)


# ------------------------------------------------------- trace ergonomics ---


def test_get_plan_under_trace_raises():
    a = _csr(8)
    with pytest.raises(ValueError, match="outside jit"):
        jax.jit(lambda aa: engine.get_plan(aa))(a)


def test_heuristic_under_trace_raises():
    a = _csr(9)
    with pytest.raises(ValueError, match="plan-build time"):
        jax.jit(lambda aa: jnp.zeros(())
                if Heuristic().choose(aa) else jnp.ones(()))(a)


def test_spmm_auto_under_trace_raises():
    a = _csr(10)
    b = jax.random.normal(jax.random.PRNGKey(3), (a.k, 8))
    with pytest.raises(ValueError, match="plan-build time"):
        jax.jit(spmm)(a, b)


def test_rowsplit_under_trace_error_mentions_plan():
    a = _csr(11)
    b = jax.random.normal(jax.random.PRNGKey(4), (a.k, 8))
    with pytest.raises(ValueError, match="SpmmPlan"):
        jax.jit(lambda aa, bb: ops.rowsplit_spmm(aa, bb))(a, b)


def test_rowsplit_l_pad_lives_in_plan():
    """Under trace, the plan supplies the static l_pad — no argument."""
    a = _csr(12, npr=(0, 6))
    b = jax.random.normal(jax.random.PRNGKey(5), (a.k, 8))
    plan = build_plan(a, method="rowsplit")    # derives l_pad statically
    assert plan.l_pad == int(np.diff(np.asarray(a.row_ptr)).max())
    got = jax.jit(lambda p, v, bb: execute_plan(
        p, v, bb, ExecutionConfig(impl="xla")))(
        plan, a.vals, b)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.spmm_dense_ref(a, b)),
                               rtol=2e-5, atol=2e-5)


# -------------------------------------------------------- sharded plans ---


def test_sharded_plans_land_as_distinct_entries():
    """One sharded request = one entry per shard (keyed on the shard's own
    fingerprint) + one entry for the assembled ShardedSpmmPlan."""
    from repro.core import PlanPolicy, ShardSpec
    from repro.distributed.spmm import shard_csr_by_nnz

    cache = engine.PlanCache()
    a = _csr(20, m=40)
    plan = cache.get(a, PlanPolicy(method="merge", shards=ShardSpec(n=4)))
    s = cache.stats()
    assert (s.hits, s.misses, s.size) == (0, 5, 5)
    fps = {pattern_fingerprint(c) for c in shard_csr_by_nnz(a, 4).csrs}
    assert len(fps) == len(set(fps) | {pattern_fingerprint(a)}) - 1
    # a repeat of the same request is one O(1) hit on the sharded entry
    again = cache.get(a, PlanPolicy(method="merge", shards=ShardSpec(n=4)))
    assert again is plan
    assert cache.stats().hits == 1


def test_reshard_different_mesh_size_does_not_poison_cache():
    from repro.core import PlanPolicy, ShardSpec

    cache = engine.PlanCache()
    a = _csr(21, m=40)
    p4 = cache.get(a, PlanPolicy(method="merge", shards=ShardSpec(n=4)))
    p2 = cache.get(a, PlanPolicy(method="merge", shards=ShardSpec(n=2)))
    assert p4 is not p2
    assert p4.meta.n_shards == 4 and p2.meta.n_shards == 2
    # both shard layouts stay live and hit independently
    assert cache.get(a, PlanPolicy(method="merge",
                                   shards=ShardSpec(n=4))) is p4
    assert cache.get(a, PlanPolicy(method="merge",
                                   shards=ShardSpec(n=2))) is p2
    # and the unsharded plan is yet another entry, untouched by either
    p1 = cache.get(a, PlanPolicy(method="merge"))
    assert p1 is not p4 and p1 is not p2


def test_sharded_and_local_entries_share_one_lru():
    """Sharded entries participate in the same LRU/eviction accounting."""
    from repro.core import PlanPolicy, ShardSpec

    cache = engine.PlanCache(maxsize=3)
    a = _csr(22, m=24)
    cache.get(a, PlanPolicy(method="merge", shards=ShardSpec(n=2)))
    s = cache.stats()
    assert s.misses == 3 and s.size == 3 and s.evictions == 0
    cache.get(_csr(23), PlanPolicy(method="merge"))
    assert cache.stats().evictions == 1


def test_policy_shards_conflict_guards():
    from repro.core import PlanPolicy, ShardSpec

    a = _csr(24)
    b = jax.random.normal(jax.random.PRNGKey(1), (a.k, 4))
    plan = build_plan(a, method="merge")
    # an unsharded plan refuses a sharded policy override
    with pytest.raises(ValueError, match="unsharded"):
        spmm(a, b, PlanPolicy(shards=2), plan=plan)
    # a sharded plan refuses mismatched shard counts / dims / methods
    sharded = engine.get_plan(a, PlanPolicy(method="merge",
                                            shards=ShardSpec(n=2)))
    with pytest.raises(ValueError, match="shards n=4"):
        spmm(a, b, PlanPolicy(shards=ShardSpec(n=4)), plan=sharded)
    with pytest.raises(ValueError, match="dim"):
        spmm(a, b, PlanPolicy(shards=ShardSpec(n=2, dim="cols")),
             plan=sharded)
    with pytest.raises(ValueError, match="method"):
        spmm(a, b, PlanPolicy(method="rowsplit"), plan=sharded)
    # agreeing overrides pass through
    got = spmm(a, b, PlanPolicy(method="merge",
                                shards=ShardSpec(n=2)), plan=sharded)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.spmm_dense_ref(a, b)),
                               rtol=2e-5, atol=2e-5)
    # resolve() on a sharded policy is a per-shard decision — guarded
    with pytest.raises(ValueError, match="per shard"):
        PlanPolicy(shards=2).resolve(a)
    # the inline path cannot shard
    with pytest.raises(ValueError, match="inline"):
        spmm(a, b, PlanPolicy(method="merge", shards=2), plan="inline")
    # ShardSpec itself validates its fields
    with pytest.raises(ValueError, match="dim"):
        ShardSpec(n=2, dim="diag")
    with pytest.raises(ValueError, match="n= "):
        ShardSpec()


def test_ensure_spmm_plans_shards_leaves():
    from repro.core import PlanPolicy, ShardSpec, SparseMatrix
    from repro.distributed.spmm import ShardedSpmmPlan

    a = _csr(25, m=40)
    w = jax.random.normal(jax.random.PRNGKey(3), (16, 12))
    tree = {"mtx": SparseMatrix.from_csr(a),
            "layer": SparseLinear.from_dense(w, 0.25)}
    planned = R.ensure_spmm_plans(tree, policy=PlanPolicy(shards=2))
    assert isinstance(planned["mtx"].spmm_plan, ShardedSpmmPlan)
    assert isinstance(planned["layer"].plan, ShardedSpmmPlan)
    assert planned["layer"].method in ("merge", "rowsplit", "mixed")
    # replan with no policy replays the shard layout (plan_like path)
    again = R.ensure_spmm_plans(planned)
    assert isinstance(again["mtx"].spmm_plan, ShardedSpmmPlan)
    assert again["mtx"].spmm_plan.meta.n_shards == 2
