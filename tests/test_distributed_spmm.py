"""Device-sharded SpMM vs. the single-device engine, on a forced mesh.

The device-needing tests run when the process has >= 8 devices — which
``make test-sharded`` forces via ``REPRO_FORCE_DEVICES=8`` (see
``conftest.py``).  Under a plain single-device ``pytest -q`` they are
exercised anyway: ``test_sharded_suite_in_forced_subprocess`` re-runs
this module in a subprocess with 8 forced CPU devices, so the sharded
matrix is *runnable, not skipped*, on any dev box and in CI.

The ``shard_csr_by_nnz`` hypothesis properties are host-side and run in
every configuration.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CSR, ExecutionConfig, PlanPolicy, ShardSpec,
                        SparseMatrix, execute_plan, random_csr)
from repro.core.csr import from_dense
from repro.distributed.spmm import (ShardedSpmmPlan, execute_sharded,
                                    shard_csr_by_nnz)
from repro.engine import PlanCache

NDEV = 8
IN_CHILD = bool(os.environ.get("_REPRO_FORCED_CHILD"))

needs_devices = pytest.mark.skipif(
    jax.device_count() < NDEV,
    reason=f"needs {NDEV} devices (covered by the forced-subprocess "
    "wrapper / make test-sharded)")

METHODS = ("merge", "rowsplit", "rowgroup")


def _mesh(n, axis="data"):
    return jax.sharding.Mesh(np.array(jax.devices()[:n]), (axis,))


def _case(seed=0, m=41, k=24, npr=(0, 9)):
    a = random_csr(jax.random.PRNGKey(seed), m, k, nnz_per_row=npr)
    b = jax.random.normal(jax.random.PRNGKey(seed + 1), (k, 7))
    return a, b


def _sharded(a, n, method="auto", dim="rows", mesh=None):
    cache = PlanCache()
    spec = (ShardSpec(mesh=mesh, dim=dim) if mesh is not None
            else ShardSpec(n=n, dim=dim))
    return cache.get(a, PlanPolicy(method=method, shards=spec))


def _assert_matches(plan, a, b, method, tol=1e-5):
    """Sharded forward + dvals/dB grads match single-device execute_plan."""
    ref_plan = PlanCache().get(a, PlanPolicy(method=method))

    def loss_sharded(vals, b):
        return jnp.sum(jnp.sin(execute_sharded(plan, vals, b)))

    def loss_ref(vals, b):
        return jnp.sum(jnp.sin(execute_plan(ref_plan, vals, b)))

    np.testing.assert_allclose(
        np.asarray(execute_sharded(plan, a.vals, b)),
        np.asarray(execute_plan(ref_plan, a.vals, b)), rtol=tol, atol=tol)
    g = jax.grad(loss_sharded, argnums=(0, 1))(a.vals, b)
    w = jax.grad(loss_ref, argnums=(0, 1))(a.vals, b)
    np.testing.assert_allclose(np.asarray(g[0]), np.asarray(w[0]),
                               rtol=tol, atol=tol, err_msg="dvals")
    np.testing.assert_allclose(np.asarray(g[1]), np.asarray(w[1]),
                               rtol=tol, atol=tol, err_msg="dB")


# ------------------------------------------------- forced-mesh numerics ---


@needs_devices
@pytest.mark.parametrize("method", METHODS)
@pytest.mark.parametrize("n", (1, 2, NDEV))
def test_sharded_matches_single_device(method, n):
    a, b = _case()
    plan = _sharded(a, n, method, mesh=_mesh(n))
    assert plan.meta.n_shards == n
    _assert_matches(plan, a, b, method)


@needs_devices
@pytest.mark.parametrize("method", METHODS)
@pytest.mark.parametrize("n", (2, NDEV))
def test_tp_cols_matches_single_device(method, n):
    a, b = _case(seed=3)
    plan = _sharded(a, n, method, dim="cols", mesh=_mesh(n, axis="model"))
    _assert_matches(plan, a, b, method)


@needs_devices
@pytest.mark.parametrize("method", METHODS)
def test_batched_b_matches(method):
    a, _ = _case(seed=5)
    bs = jax.random.normal(jax.random.PRNGKey(9), (3, a.k, 6))
    plan = _sharded(a, NDEV, method, mesh=_mesh(NDEV))
    ref_plan = PlanCache().get(a, PlanPolicy(method=method))
    got = execute_sharded(plan, a.vals, bs)
    want = execute_plan(ref_plan, a.vals, bs)
    assert got.shape == (3, a.m, 6)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    # grads through the batched path, too
    g = jax.grad(lambda v: jnp.sum(jnp.cos(
        execute_sharded(plan, v, bs))))(a.vals)
    w = jax.grad(lambda v: jnp.sum(jnp.cos(
        execute_plan(ref_plan, v, bs))))(a.vals)
    np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                               rtol=1e-5, atol=1e-5)


@needs_devices
def test_zero_nnz_shards():
    """A pattern whose nonzeroes sit in one row: 7 of 8 shards are empty."""
    dense = np.zeros((16, 12), np.float32)
    dense[3] = np.arange(1, 13)
    a = from_dense(dense)
    b = jax.random.normal(jax.random.PRNGKey(2), (12, 5))
    plan = _sharded(a, NDEV, "merge", mesh=_mesh(NDEV))
    nnz = shard_csr_by_nnz(a, NDEV).nnz_per_shard()
    assert sorted(nnz, reverse=True)[1:] == [0] * (NDEV - 1)
    _assert_matches(plan, a, b, "merge")


@needs_devices
def test_more_shards_than_rows():
    a, b = _case(seed=7, m=3, k=10, npr=(1, 4))
    assert a.m < NDEV
    plan = _sharded(a, NDEV, "merge", mesh=_mesh(NDEV))
    _assert_matches(plan, a, b, "merge")


@needs_devices
def test_spmd_single_dispatch_and_jit():
    """A uniform plan on a matching mesh takes the shard_map path, and the
    whole thing jits with the plan passed through the boundary."""
    a, b = _case(seed=11)
    plan = _sharded(a, NDEV, "rowsplit", mesh=_mesh(NDEV))
    assert plan.meta.uniform and plan.meta.spmd_mesh() is not None
    A = SparseMatrix(a, plan)
    want = np.asarray(execute_plan(
        PlanCache().get(a, PlanPolicy(method="rowsplit")), a.vals, b))
    got = jax.jit(lambda A, b: A @ b)(A, b)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)


@needs_devices
def test_sparse_matrix_shard_frontend():
    a, b = _case(seed=13)
    A = SparseMatrix.from_csr(a).shard(_mesh(2))
    assert isinstance(A.spmm_plan, ShardedSpmmPlan)
    assert A.spmm_plan.meta.n_shards == 2
    want = np.asarray(SparseMatrix.from_csr(a).plan() @ b)
    np.testing.assert_allclose(np.asarray(A @ b), want,
                               rtol=1e-5, atol=1e-5)
    # values rebind without replanning, exactly like the unsharded frontend
    A2 = A.with_vals(a.vals * 2)
    assert A2.spmm_plan is A.spmm_plan
    np.testing.assert_allclose(np.asarray(A2 @ b), 2 * want,
                               rtol=1e-5, atol=1e-5)


@needs_devices
def test_xla_impl_matches():
    a, b = _case(seed=17)
    plan = _sharded(a, NDEV, "merge", mesh=_mesh(NDEV))
    got = execute_sharded(plan, a.vals, b, ExecutionConfig(impl="xla"))
    want = execute_plan(PlanCache().get(a, PlanPolicy(method="merge")),
                        a.vals, b, ExecutionConfig(impl="xla"))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------- loop fallback (any devices) ---


@pytest.mark.parametrize("dim", ("rows", "cols"))
def test_loop_fallback_no_mesh(dim):
    """Logical shards without a mesh are numerically identical."""
    a, b = _case(seed=19)
    plan = _sharded(a, 4, "auto", dim=dim)
    assert plan.meta.spmd_mesh() is None      # no mesh: per-shard loop
    ref = PlanCache().get(a, PlanPolicy())
    np.testing.assert_allclose(
        np.asarray(execute_sharded(plan, a.vals, b)),
        np.asarray(execute_plan(ref, a.vals, b)), rtol=1e-5, atol=1e-5)


def test_rowgroup_heterogeneous_falls_back():
    """rowgroup's per-shard group tables differ → non-uniform, still right."""
    a, b = _case(seed=23, m=48, npr=(0, 12))
    plan = _sharded(a, 4, "rowgroup")
    assert not plan.meta.uniform
    ref = PlanCache().get(a, PlanPolicy(method="rowgroup"))
    np.testing.assert_allclose(
        np.asarray(execute_sharded(plan, a.vals, b)),
        np.asarray(execute_plan(ref, a.vals, b)), rtol=1e-5, atol=1e-5)


def test_stale_vals_shape_raises():
    a, b = _case(seed=29)
    plan = _sharded(a, 2, "merge")
    with pytest.raises(ValueError, match="global vals"):
        execute_sharded(plan, a.vals[:-1], b)
    with pytest.raises(ValueError, match="expects B"):
        execute_sharded(plan, a.vals, b[:-1])


# ------------------------------------------------- subprocess substrate ---


@pytest.mark.skipif(jax.device_count() >= NDEV or IN_CHILD,
                    reason="already running with a forced multi-device "
                    "substrate")
def test_sharded_suite_in_forced_subprocess(forced_device_run):
    """Run this module under 8 forced CPU devices in a fresh process, so
    the mesh tests execute for real even when the parent run came up
    single-device."""
    res = forced_device_run("tests/test_distributed_spmm.py", NDEV)
    assert res.returncode == 0, (
        f"forced {NDEV}-device run failed:\n{res.stdout}\n{res.stderr}")
    assert " passed" in res.stdout


# ------------------------------------- shard_csr_by_nnz degenerates --------
# (the hypothesis property suite lives in tests/test_shard_property.py)


def test_shard_degenerate_inputs():
    # empty matrix
    empty = CSR(jnp.zeros(1, jnp.int32), jnp.zeros(1, jnp.int32),
                jnp.zeros(1, jnp.float32), (0, 5))
    s = shard_csr_by_nnz(empty, 4)
    assert s.sizes() == (0, 0, 0, 0)
    # one dense row holding most of the nnz
    dense = np.zeros((9, 32), np.float32)
    dense[4] = 1.0
    dense[0, 0] = dense[8, 31] = 1.0
    s = shard_csr_by_nnz(from_dense(dense), 6)
    assert sum(s.sizes()) == 9
    assert sum(s.nnz_per_shard()) == 34
    # invalid arguments
    with pytest.raises(ValueError, match="n_shards"):
        shard_csr_by_nnz(empty, 0)
    with pytest.raises(ValueError, match="dim"):
        shard_csr_by_nnz(empty, 2, dim="diag")
