"""Per-architecture smoke tests (deliverable f): reduced same-family config,
one forward/train step on CPU, asserting output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import model as M
from repro.optim import adamw
from repro.runtime import steps as R


def _batch(cfg, b=2, s=16, key=0):
    k = jax.random.PRNGKey(key)
    if cfg.input_mode == "tokens":
        toks = jax.random.randint(k, (b, s + 1), 0, cfg.vocab_size)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    return {"embeds": jax.random.normal(k, (b, s, cfg.d_model), jnp.float32),
            "labels": jax.random.randint(k, (b, s), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The full configs carry the exact published numbers."""
    cfg = get_config(arch)
    expected = {
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
        "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
        "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
        "mamba2-1.3b": (48, 2048, 0, 0, 0, 50280),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected
    if arch == "olmoe-1b-7b":
        assert (cfg.num_experts, cfg.top_k) == (64, 8)
    if arch == "mixtral-8x22b":
        assert (cfg.num_experts, cfg.top_k) == (8, 2)
        assert cfg.attention == "swa"
    if arch == "qwen2-72b":
        assert cfg.qkv_bias
    if arch == "mamba2-1.3b":
        assert cfg.ssm_state == 128
    if arch == "recurrentgemma-2b":
        assert cfg.attention == "local" and cfg.window == 2048


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    h = M.embed_inputs(params, cfg, batch)
    h, _, aux = M.forward(params, cfg, h)
    assert h.shape == (2, 16, cfg.d_model)
    assert np.isfinite(np.asarray(h, np.float32)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    step = R.make_train_step(cfg, adamw.AdamWConfig(warmup_steps=2,
                                                    total_steps=10),
                             loss_chunk=8)
    state = R.init_train_state(cfg, jax.random.PRNGKey(0))
    state2, metrics = jax.jit(step)(state, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["skipped"]) == 0.0
    # params actually moved
    moved = jax.tree.reduce(
        lambda a, kv: a + float(jnp.sum(jnp.abs(kv))), jax.tree.map(
            lambda a, b: (a.astype(jnp.float32) - b.astype(jnp.float32)),
            state["params"], state2["params"]), 0.0)
    assert moved > 0.0


@pytest.mark.parametrize("arch", ["llama3.2-1b", "mamba2-1.3b",
                                  "recurrentgemma-2b", "olmoe-1b-7b",
                                  "musicgen-large"])
def test_smoke_decode_shapes(arch):
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 8
    batch = _batch(cfg, b, s)
    batch.pop("labels")
    caches, logits, pos = M.prefill(params, cfg, batch, cache_len=s + 4)
    assert logits.shape == (b, 1, cfg.vocab_size)
    step_in = ({"tokens": jnp.zeros((b, 1), jnp.int32)}
               if cfg.input_mode == "tokens" else
               {"embeds": jnp.zeros((b, 1, cfg.d_model), jnp.float32)})
    lg, caches2 = M.decode_step(params, cfg, caches, step_in, pos)
    assert lg.shape == (b, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(lg, np.float32)).all()
    # cache pytree structure is stable across steps (scan compatibility)
    jax.tree.map(lambda a, b: None, caches, caches2)


def test_microbatched_train_step_matches_single():
    """Gradient accumulation is loss-equivalent to one big batch."""
    import dataclasses
    cfg = dataclasses.replace(get_smoke_config("llama3.2-1b"),
                              compute_dtype="float32")
    ocfg = adamw.AdamWConfig(warmup_steps=2, total_steps=10)
    b = _batch(cfg, b=4, s=16)
    state = R.init_train_state(cfg, jax.random.PRNGKey(0))
    s1 = R.make_train_step(cfg, ocfg, microbatches=1, loss_chunk=8)
    s2 = R.make_train_step(cfg, ocfg, microbatches=2, loss_chunk=8)
    mb = jax.tree.map(lambda x: x.reshape(2, 2, *x.shape[1:]), b)
    st1, m1 = jax.jit(s1)(state, b)
    st2, m2 = jax.jit(s2)(state, mb)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     st1["params"], st2["params"])
    assert max(jax.tree.leaves(d)) < 5e-5
