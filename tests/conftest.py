"""Test substrate: forced multi-device CPU runs for sharded tests.

A real TPU pod isn't available on a dev box or in CI, but XLA can split
the host CPU into any number of devices — *if* the flag lands before jax
initializes.  Two pieces make sharded tests runnable (not skipped)
everywhere:

* ``REPRO_FORCE_DEVICES=N``: honored here, at conftest import time —
  before any test module imports jax — by appending
  ``--xla_force_host_platform_device_count=N`` to ``XLA_FLAGS``.  This is
  what ``make test-sharded`` sets.
* :func:`run_pytest_forced_devices`: runs a pytest target in a *fresh
  subprocess* with the env var set.  ``tests/test_distributed_spmm.py``
  uses it to wrap its device-hungry tests when the current process came
  up with too few devices (the usual single-device ``pytest -q``), so the
  tier-1 suite exercises the full 8-device matrix on any box.
"""
from __future__ import annotations

import os
import subprocess
import sys

_FORCE = os.environ.get("REPRO_FORCE_DEVICES")
if _FORCE:
    _flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            f"{_flags} --xla_force_host_platform_device_count="
            f"{int(_FORCE)}").strip()

import pytest


def run_pytest_forced_devices(target: str, n_devices: int,
                              timeout: int = 1500):
    """Run ``pytest <target>`` in a subprocess with N forced CPU devices.

    Returns the completed process (stdout/stderr captured, text mode).
    The child inherits the parent's interpreter and gets ``src`` on its
    PYTHONPATH, ``REPRO_FORCE_DEVICES`` (picked up by this conftest
    before jax initializes there), and a marker env var tests can use to
    avoid re-spawning recursively.
    """
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["REPRO_FORCE_DEVICES"] = str(n_devices)
    env["_REPRO_FORCED_CHILD"] = "1"
    # Drop any existing device-count force so the child's conftest can
    # apply N; every other XLA flag passes through.
    flags = [f for f in env.get("XLA_FLAGS", "").split()
             if not f.startswith("--xla_force_host_platform_device_count")]
    if flags:
        env["XLA_FLAGS"] = " ".join(flags)
    else:
        env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    return subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q", "-p", "no:cacheprovider",
         "-W", "error::DeprecationWarning", target],
        cwd=root, env=env, capture_output=True, text=True, timeout=timeout)


@pytest.fixture(scope="session")
def forced_device_run():
    """Fixture handle on :func:`run_pytest_forced_devices`."""
    return run_pytest_forced_devices
