"""Property-based system invariants for the SpMM core (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install -r "
    "requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import (Heuristic, PlanPolicy, calibrate, random_csr,
                        spmm)
from repro.kernels import ref, ops


@st.composite
def spmm_cases(draw):
    m = draw(st.integers(1, 24))
    k = draw(st.integers(1, 24))
    n = draw(st.integers(1, 24))
    hi = draw(st.integers(0, min(k, 8)))
    seed = draw(st.integers(0, 2**31 - 1))
    a = random_csr(jax.random.PRNGKey(seed), m, k, nnz_per_row=(0, hi))
    b = jax.random.normal(jax.random.PRNGKey(seed + 1), (k, n))
    return a, b


@settings(max_examples=25, deadline=None)
@given(spmm_cases())
def test_methods_agree(case):
    """Row-split, merge, and the oracle agree on arbitrary matrices."""
    a, b = case
    want = np.asarray(ref.spmm_dense_ref(a, b))
    for method in ("merge", "rowsplit"):
        got = np.asarray(spmm(a, b, PlanPolicy(method=method)))
        np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5,
                                   err_msg=method)


@settings(max_examples=25, deadline=None)
@given(spmm_cases(), st.floats(-3, 3), st.floats(-3, 3))
def test_linearity(case, alpha, beta):
    """spmm(A, αB1 + βB2) == α spmm(A,B1) + β spmm(A,B2)."""
    a, b = case
    b2 = jnp.roll(b, 1, axis=0)
    lhs = ops.merge_spmm(a, alpha * b + beta * b2)
    rhs = alpha * ops.merge_spmm(a, b) + beta * ops.merge_spmm(a, b2)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               rtol=1e-3, atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(spmm_cases())
def test_identity_rows(case):
    """A row with a single unit nonzero at column j copies B[j]."""
    a, b = case
    d = np.asarray(a.to_dense())
    out = np.asarray(ops.merge_spmm(a, b))
    for r in range(d.shape[0]):
        nz = np.nonzero(d[r])[0]
        if len(nz) == 1 and d[r, nz[0]] == 1.0:
            np.testing.assert_allclose(out[r], np.asarray(b)[nz[0]],
                                       rtol=1e-5, atol=1e-5)


def test_heuristic_rule_matches_paper():
    h = Heuristic()  # default threshold = 9.35 (paper §5.4)
    short = random_csr(jax.random.PRNGKey(0), 64, 64, nnz_per_row=4)
    long = random_csr(jax.random.PRNGKey(1), 64, 64, nnz_per_row=32)
    assert h.choose(short) == "merge"
    assert h.choose(long) == "rowsplit"


@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(0.5, 60), min_size=3, max_size=40),
       st.floats(1, 30))
def test_calibrate_recovers_separable_threshold(ds, true_thr):
    """If timings are perfectly separated by a threshold, calibrate finds a
    100%-accurate one (the paper's oracle-agreement metric)."""
    ds = np.asarray(ds)
    merge_us = np.where(ds < true_thr, 1.0, 2.0)
    rowsplit_us = np.where(ds < true_thr, 2.0, 1.0)
    thr, acc = calibrate(ds, rowsplit_us, merge_us)
    assert acc == 1.0
