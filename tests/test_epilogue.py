"""Fused epilogue + mixed precision vs. the dense oracle.

Every combination the fused path claims to support is checked against a
densify-and-matmul oracle that applies the *same* ``apply_epilogue``
math: forward and gradients for all three registered methods on both
impls, batched/vmapped operands, bf16 inputs under f32 accumulation, and
the dtype/flag guard rails.  The sharded-epilogue tests run on a forced
8-device mesh (re-spawned in a subprocess when the parent is
single-device, like ``test_distributed_spmm``).
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import (Epilogue, ExecutionConfig, PlanPolicy, ShardSpec,
                        apply_epilogue, execute_plan, random_csr)
from repro.engine import PlanCache
from repro.models.sparse import SparseLinear, prune_mlp, sparse_mlp_apply

NDEV = 8
IN_CHILD = bool(os.environ.get("_REPRO_FORCED_CHILD"))
METHODS = ("merge", "rowsplit", "rowgroup")
IMPLS = ("pallas", "xla")

needs_devices = pytest.mark.skipif(
    jax.device_count() < NDEV,
    reason=f"needs {NDEV} devices (covered by the forced-subprocess "
    "wrapper / make test-sharded)")

FULL_EP = Epilogue(bias=True, activation="gelu", residual=True, scale=0.5)


def _case(seed=0, m=37, k=53, n=19, density=0.2):
    a = random_csr(jax.random.PRNGKey(seed), m, k, density=density,
                   nnz_per_row=(0, 9))
    b = jax.random.normal(jax.random.PRNGKey(seed + 1), (k, n))
    bias = jax.random.normal(jax.random.PRNGKey(seed + 2), (m,))
    res = jax.random.normal(jax.random.PRNGKey(seed + 3), (m, n))
    return a, b, bias, res


def _oracle(a, vals, b, ep, bias, res):
    dense = dataclasses.replace(a, vals=vals).to_dense()
    bias_col = bias[..., :, None] if ep is not None and ep.bias else None
    return apply_epilogue(dense @ b, ep, bias_col,
                          res if ep is not None and ep.residual else None)


def _plan(a, method):
    return PlanCache().get(a, PlanPolicy(method=method))


# ------------------------------------------------ forward vs dense oracle ---


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("method", METHODS)
@pytest.mark.parametrize("ep", [
    Epilogue(bias=True),
    Epilogue(activation="relu"),
    Epilogue(activation="gelu", scale=0.5),
    FULL_EP,
], ids=["bias", "relu", "gelu_scale", "full"])
def test_fused_forward_matches_oracle(method, impl, ep):
    a, b, bias, res = _case()
    plan = _plan(a, method)
    exec = ExecutionConfig(impl=impl, epilogue=ep)
    got = execute_plan(plan, a.vals, b, exec,
                       bias=bias if ep.bias else None,
                       residual=res if ep.residual else None)
    want = _oracle(a, a.vals, b, ep, bias, res)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("method", METHODS)
def test_fused_grad_matches_oracle(method, impl):
    a, b, bias, res = _case(seed=5)
    plan = _plan(a, method)
    exec = ExecutionConfig(impl=impl, epilogue=FULL_EP)

    def fused(vals, b, bias, res):
        return execute_plan(plan, vals, b, exec, bias=bias,
                            residual=res).sum()

    def oracle(vals, b, bias, res):
        return _oracle(a, vals, b, FULL_EP, bias, res).sum()

    got = jax.grad(fused, argnums=(0, 1, 2, 3))(a.vals, b, bias, res)
    want = jax.grad(oracle, argnums=(0, 1, 2, 3))(a.vals, b, bias, res)
    for name, g, w in zip(("dvals", "dB", "dbias", "dresidual"), got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-4,
                                   atol=1e-4, err_msg=name)


@pytest.mark.parametrize("impl", IMPLS)
def test_linear_epilogue_grad(impl):
    """bias + scale, no activation: the fully-fused backward branch."""
    a, b, bias, _ = _case(seed=9)
    ep = Epilogue(bias=True, scale=2.0)
    plan = _plan(a, "merge")
    exec = ExecutionConfig(impl=impl, epilogue=ep)

    def fused(vals, bias):
        return (execute_plan(plan, vals, b, exec, bias=bias) ** 2).sum()

    def oracle(vals, bias):
        return (_oracle(a, vals, b, ep, bias, None) ** 2).sum()

    got = jax.grad(fused, argnums=(0, 1))(a.vals, bias)
    want = jax.grad(oracle, argnums=(0, 1))(a.vals, bias)
    for name, g, w in zip(("dvals", "dbias"), got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-4,
                                   atol=1e-4, err_msg=name)


# ------------------------------------------------------- batched and vmap ---


@pytest.mark.parametrize("method", METHODS)
def test_batched_epilogue(method):
    a, _, bias, _ = _case()
    B = jax.random.normal(jax.random.PRNGKey(7), (3, a.k, 19))
    R = jax.random.normal(jax.random.PRNGKey(8), (3, a.m, 19))
    plan = _plan(a, method)
    exec = ExecutionConfig(epilogue=FULL_EP)
    got = execute_plan(plan, a.vals, B, exec, bias=bias, residual=R)
    want = _oracle(a, a.vals, B, FULL_EP, bias, R)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_vmap_epilogue_forward_and_grad():
    a, _, bias, _ = _case()
    B = jax.random.normal(jax.random.PRNGKey(7), (3, a.k, 19))
    R = jax.random.normal(jax.random.PRNGKey(8), (3, a.m, 19))
    plan = _plan(a, "merge")
    exec = ExecutionConfig(epilogue=FULL_EP)
    got = jax.vmap(lambda bb, rr: execute_plan(plan, a.vals, bb, exec,
                                               bias=bias, residual=rr))(B, R)
    want = _oracle(a, a.vals, B, FULL_EP, bias, R)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)

    # Shared (unbatched) vals and bias: JAX must sum their cotangents
    # across the vmapped axis.
    def fused(vals, bias):
        return jax.vmap(lambda bb, rr: execute_plan(
            plan, vals, bb, exec, bias=bias, residual=rr))(B, R).sum()

    def oracle(vals, bias):
        return _oracle(a, vals, B, FULL_EP, bias, R).sum()

    got_g = jax.grad(fused, argnums=(0, 1))(a.vals, bias)
    want_g = jax.grad(oracle, argnums=(0, 1))(a.vals, bias)
    for name, g, w in zip(("dvals", "dbias"), got_g, want_g):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-4,
                                   atol=1e-4, err_msg=name)


# ------------------------------------------------------- mixed precision ---


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("method", METHODS)
def test_bf16_inputs_f32_acc(method, impl):
    a, b, bias, _ = _case(seed=3)
    vals16, b16 = a.vals.astype(jnp.bfloat16), b.astype(jnp.bfloat16)
    plan = _plan(a, method)
    ep = Epilogue(bias=True, activation="gelu")
    exec = ExecutionConfig(impl=impl, epilogue=ep, acc_dtype="float32",
                           out_dtype="float32")
    got = execute_plan(plan, vals16, b16, exec, bias=bias)
    assert got.dtype == jnp.float32
    # f32 oracle on the bf16-rounded inputs: the tolerance covers only the
    # input rounding, not accumulation-order noise (accumulation is f32).
    want = _oracle(a, vals16.astype(jnp.float32), b16.astype(jnp.float32),
                   ep, bias, None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-2, atol=3e-2)


def test_bf16_default_out_dtype_is_promotion():
    a, b, _, _ = _case()
    plan = _plan(a, "merge")
    vals16, b16 = a.vals.astype(jnp.bfloat16), b.astype(jnp.bfloat16)
    assert execute_plan(plan, vals16, b16).dtype == jnp.bfloat16
    assert execute_plan(plan, vals16, b).dtype == jnp.float32
    assert execute_plan(plan, a.vals, b16).dtype == jnp.float32
    assert execute_plan(plan, a.vals, b).dtype == jnp.float32


def test_out_dtype_override():
    a, b, _, _ = _case()
    plan = _plan(a, "merge")
    got = execute_plan(plan, a.vals, b,
                       ExecutionConfig(out_dtype="bfloat16"))
    assert got.dtype == jnp.bfloat16
    want = _oracle(a, a.vals, b, None, None, None)
    np.testing.assert_allclose(np.asarray(got, dtype=np.float32),
                               np.asarray(want), rtol=3e-2, atol=3e-2)


def test_bf16_grad_tolerance():
    a, b, bias, _ = _case(seed=13)
    plan = _plan(a, "merge")
    vals16, b16 = a.vals.astype(jnp.bfloat16), b.astype(jnp.bfloat16)
    ep = Epilogue(bias=True, activation="gelu")
    exec = ExecutionConfig(epilogue=ep, acc_dtype="float32",
                           out_dtype="float32")

    def fused(vals, bb):
        return execute_plan(plan, vals, bb, exec, bias=bias).sum()

    got = jax.grad(fused, argnums=(0, 1))(vals16, b16)
    assert got[0].dtype == jnp.bfloat16 and got[1].dtype == jnp.bfloat16

    def oracle(vals, bb):
        return _oracle(a, vals, bb, ep, bias, None).sum()

    want = jax.grad(oracle, argnums=(0, 1))(
        vals16.astype(jnp.float32), b16.astype(jnp.float32))
    for name, g, w in zip(("dvals", "dB"), got, want):
        np.testing.assert_allclose(np.asarray(g, dtype=np.float32),
                                   np.asarray(w), rtol=1e-1, atol=1e-1,
                                   err_msg=name)


# ----------------------------------------------------------- guard rails ---


@pytest.mark.parametrize("bad", [jnp.int32, jnp.int8, jnp.bool_])
def test_non_floating_operands_raise(bad):
    a, b, _, _ = _case()
    plan = _plan(a, "merge")
    with pytest.raises(TypeError, match="floating-point"):
        execute_plan(plan, a.vals.astype(bad), b)
    with pytest.raises(TypeError, match="floating-point"):
        execute_plan(plan, a.vals, (b != 0).astype(bad))


def test_narrow_acc_dtype_raises():
    a, b, _, _ = _case()
    plan = _plan(a, "merge")
    with pytest.raises(ValueError, match="acc_dtype"):
        execute_plan(plan, a.vals, b, ExecutionConfig(acc_dtype="bfloat16"))
    # bf16 inputs may accumulate in bf16 when asked to.
    got = execute_plan(plan, a.vals.astype(jnp.bfloat16),
                       b.astype(jnp.bfloat16),
                       ExecutionConfig(acc_dtype="bfloat16"))
    assert got.dtype == jnp.bfloat16


def test_epilogue_flag_operand_mismatches_raise():
    a, b, bias, res = _case()
    plan = _plan(a, "merge")
    with pytest.raises(ValueError, match="flags bias"):
        execute_plan(plan, a.vals, b,
                     ExecutionConfig(epilogue=Epilogue(bias=True)))
    with pytest.raises(ValueError, match="does not flag bias"):
        execute_plan(plan, a.vals, b,
                     ExecutionConfig(epilogue=Epilogue(activation="relu")),
                     bias=bias)
    with pytest.raises(ValueError, match="flags residual"):
        execute_plan(plan, a.vals, b,
                     ExecutionConfig(epilogue=Epilogue(residual=True)))
    with pytest.raises(ValueError, match="bias must have shape"):
        execute_plan(plan, a.vals, b, bias=bias[:-1])
    with pytest.raises(ValueError, match="residual must have shape"):
        execute_plan(plan, a.vals, b, residual=res[:, :-1])


def test_epilogue_spec_validation():
    with pytest.raises(ValueError, match="activation"):
        Epilogue(activation="silu")
    assert Epilogue().is_identity()
    assert not Epilogue(scale=2).is_identity()
    assert Epilogue(scale=2.0).scale == 2.0


def test_auto_derived_epilogue_from_operands():
    a, b, bias, res = _case()
    got = repro.spmm(a, b, bias=bias, residual=res)
    want = _oracle(a, a.vals, b, Epilogue(bias=True, residual=True),
                   bias, res)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_inline_path_applies_epilogue():
    a, b, bias, res = _case()
    got = repro.spmm(a, b, PlanPolicy(method="merge"),
                     ExecutionConfig(epilogue=FULL_EP), plan="inline",
                     bias=bias, residual=res)
    want = _oracle(a, a.vals, b, FULL_EP, bias, res)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------- model path ---


def test_sparse_linear_fused_bias_residual():
    w = jax.random.normal(jax.random.PRNGKey(20), (53, 37))  # (d_in, d_out)
    sl = SparseLinear.from_dense(w, 0.3)
    x = jax.random.normal(jax.random.PRNGKey(21), (11, 53))
    bias = jax.random.normal(jax.random.PRNGKey(22), (37,))
    res = jax.random.normal(jax.random.PRNGKey(23), (11, 37))
    ep = Epilogue(bias=True, activation="gelu", residual=True)
    got = sl(x, ExecutionConfig(epilogue=ep), bias=bias, residual=res)
    wd = sl.matrix.to_dense()                                # (d_out, d_in)
    want = jax.nn.gelu(x @ wd.T + bias[None, :]) + res
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_sparse_mlp_apply_fused_matches_unfused():
    p = prune_mlp(
        {"w1": jax.random.normal(jax.random.PRNGKey(30), (53, 64)),
         "w2": jax.random.normal(jax.random.PRNGKey(31), (64, 53))}, 0.3)
    x = jax.random.normal(jax.random.PRNGKey(32), (7, 53))
    got = sparse_mlp_apply(p, x, None)
    want = p["w2"](jax.nn.gelu(p["w1"](x)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    # and the fused block differentiates
    def loss(vals):
        import dataclasses as dc
        p2 = {"w1": dc.replace(p["w1"], weight=dc.replace(
            p["w1"].weight, vals=vals)), "w2": p["w2"]}
        return sparse_mlp_apply(p2, x, None).sum()
    g = jax.grad(loss)(p["w1"].weight.vals)
    assert g.shape == p["w1"].weight.vals.shape
    assert bool(jnp.any(g != 0))


# ------------------------------------------------------- sharded epilogue ---


def _mesh(n, axis="data"):
    return jax.sharding.Mesh(np.array(jax.devices()[:n]), (axis,))


@needs_devices
@pytest.mark.parametrize("dim,axis", [("rows", "data"), ("cols", "model")])
def test_sharded_epilogue_matches_oracle(dim, axis):
    a, b, bias, res = _case(seed=17, m=41, k=29)
    from repro.distributed.spmm import build_sharded_plan
    plan = build_sharded_plan(
        a, PlanPolicy(method="merge",
                      shards=ShardSpec(dim=dim, mesh=_mesh(NDEV, axis),
                                       axis=axis)),
        cache=PlanCache())
    exec = ExecutionConfig(epilogue=FULL_EP)
    got = plan.execute(a.vals, b, exec, bias=bias, residual=res)
    want = _oracle(a, a.vals, b, FULL_EP, bias, res)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)

    def fused(vals, bias):
        return plan.execute(vals, b, exec, bias=bias, residual=res).sum()

    def oracle(vals, bias):
        return _oracle(a, vals, b, FULL_EP, bias, res).sum()

    g = jax.grad(fused, argnums=(0, 1))(a.vals, bias)
    w = jax.grad(oracle, argnums=(0, 1))(a.vals, bias)
    for name, gg, ww in zip(("dvals", "dbias"), g, w):
        np.testing.assert_allclose(np.asarray(gg), np.asarray(ww),
                                   rtol=1e-4, atol=1e-4, err_msg=name)


@pytest.mark.skipif(jax.device_count() >= NDEV or IN_CHILD,
                    reason="already running with a forced multi-device "
                    "substrate")
def test_sharded_epilogue_in_forced_subprocess(forced_device_run):
    """Run the mesh tests above under 8 forced CPU devices so they execute
    for real on a single-device box."""
    res = forced_device_run(
        "tests/test_epilogue.py::test_sharded_epilogue_matches_oracle", NDEV)
    assert res.returncode == 0, (
        f"forced {NDEV}-device run failed:\n{res.stdout}\n{res.stderr}")
    assert " passed" in res.stdout
