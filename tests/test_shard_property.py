"""Property-based invariants of nnz-balanced device sharding (hypothesis).

``shard_csr_by_nnz`` is pure host-side partitioning, so these run on any
device count; the forced-mesh execution tests live in
``tests/test_distributed_spmm.py``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install -r "
    "requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import CSR, random_csr
from repro.distributed.spmm import shard_csr_by_nnz


@st.composite
def shard_cases(draw):
    m = draw(st.integers(0, 40))
    k = draw(st.integers(1, 24))
    hi = draw(st.integers(0, min(k, 10)))
    n_shards = draw(st.integers(1, 10))
    seed = draw(st.integers(0, 2**31 - 1))
    a = random_csr(jax.random.PRNGKey(seed), max(m, 1), k,
                   nnz_per_row=(0, hi))
    if m == 0:
        a = CSR(jnp.zeros(1, jnp.int32), a.col_ind, a.vals, (0, k))
    return a, n_shards


@settings(max_examples=40, deadline=None)
@given(shard_cases())
def test_shards_tile_rows_exactly_once(case):
    a, n = case
    s = shard_csr_by_nnz(a, n)
    assert len(s.bounds) == n + 1
    assert s.bounds[0] == 0 and s.bounds[-1] == a.m
    assert all(s.bounds[i] <= s.bounds[i + 1] for i in range(n))
    assert sum(s.sizes()) == a.m          # every row in exactly one shard


@settings(max_examples=40, deadline=None)
@given(shard_cases())
def test_shard_nnz_within_one_max_row_of_ideal(case):
    """The paper's equal-nonzero guarantee at shard granularity: each
    shard's nnz deviates from the ideal nnz/n_shards by at most one max
    row length (the boundary rounds to a row boundary, and a cut can miss
    its target nonzero by less than the row containing it)."""
    a, n = case
    s = shard_csr_by_nnz(a, n)
    lengths = np.diff(np.asarray(a.row_ptr))
    max_len = int(lengths.max()) if lengths.size else 0
    ideal = int(np.asarray(a.row_ptr)[-1]) / n
    for nnz_i in s.nnz_per_shard():
        assert abs(nnz_i - ideal) <= max_len + 1


@settings(max_examples=40, deadline=None)
@given(shard_cases())
def test_shard_vals_slots_cover_all_nonzeroes(case):
    """Every global nonzero lands in exactly one shard's value gather."""
    a, n = case
    s = shard_csr_by_nnz(a, n)
    nnz = int(np.asarray(a.row_ptr)[-1])
    valid = np.concatenate(
        [np.asarray(sl)[np.asarray(sl) < a.nnz_pad] for sl in s.vals_slots])
    assert np.array_equal(np.sort(valid), np.arange(nnz))


@settings(max_examples=40, deadline=None)
@given(shard_cases())
def test_shard_local_patterns_reassemble(case):
    """Stacking the (unpadded) local rows reproduces the dense matrix."""
    a, n = case
    s = shard_csr_by_nnz(a, n)
    vals_ext = np.concatenate([np.asarray(a.vals), np.zeros(1, a.dtype)])
    blocks = []
    for i, (c, slot) in enumerate(zip(s.csrs, s.vals_slots)):
        local = CSR(c.row_ptr, c.col_ind, jnp.asarray(vals_ext[slot]),
                    c.shape)
        rows = s.bounds[i + 1] - s.bounds[i]
        blocks.append(np.asarray(local.to_dense())[:rows])
    got = (np.concatenate(blocks, axis=0) if blocks
           else np.zeros(a.shape, a.dtype))
    np.testing.assert_allclose(got, np.asarray(a.to_dense()),
                               rtol=1e-6, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(shard_cases())
def test_col_shards_tile_columns(case):
    a, n = case
    s = shard_csr_by_nnz(a, n, dim="cols")
    assert s.bounds[0] == 0 and s.bounds[-1] == a.k
    nnz = int(np.asarray(a.row_ptr)[-1])
    assert sum(s.nnz_per_shard()) == nnz
