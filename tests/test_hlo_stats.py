"""Validate the trip-count-aware HLO parser against ground truth:
unrolled modules (exact flop counts) and hand-built collectives."""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis import hlo as hlo_stats


def _parse(fn, *specs):
    comp = jax.jit(fn).lower(*specs).compile()
    return hlo_stats.parse_module(comp.as_text())


def test_scan_flops_scaled_by_trip_count():
    def f(w, x):
        def step(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(step, x, None, length=7)
        return y.sum()

    r = _parse(f, jax.ShapeDtypeStruct((64, 64), jnp.float32),
               jax.ShapeDtypeStruct((8, 64), jnp.float32))
    assert r["flops"] == 7 * 2 * 8 * 64 * 64


def test_nested_scan_flops_multiply():
    def g(w, x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=5)
            return ci, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y.sum()

    r = _parse(g, jax.ShapeDtypeStruct((64, 64), jnp.float32),
               jax.ShapeDtypeStruct((8, 64), jnp.float32))
    assert r["flops"] == 15 * 2 * 8 * 64 * 64


def test_scan_matches_unrolled():
    """Scanned and unrolled versions of the same program must agree on
    flops (the whole point of trip-count scaling)."""
    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((4, 32), jnp.float32)

    def scanned(w, x):
        y, _ = jax.lax.scan(lambda c, _: (jnp.tanh(c @ w), None), x, None,
                            length=6)
        return y.sum()

    def unrolled(w, x):
        for _ in range(6):
            x = jnp.tanh(x @ w)
        return x.sum()

    rs = _parse(scanned, w, x)
    ru = _parse(unrolled, w, x)
    assert rs["flops"] == ru["flops"] == 6 * 2 * 4 * 32 * 32


def test_collective_wire_bytes():
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((len(jax.devices()),), ("model",))
    n = len(jax.devices())
    if n == 1:
        pytest.skip("single device — no collectives emitted")


def test_batch_dot_general_flops():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)

    r = _parse(f, jax.ShapeDtypeStruct((4, 8, 16), jnp.float32),
               jax.ShapeDtypeStruct((4, 16, 32), jnp.float32))
    assert r["flops"] == 2 * 4 * 8 * 16 * 32


def test_dtype_bytes_parsing():
    assert hlo_stats._type_bytes("bf16[8,64]{1,0}") == 8 * 64 * 2
    assert hlo_stats._type_bytes("(s32[], f32[8,64]{1,0})") == 4 + 8 * 64 * 4
    assert hlo_stats._type_bytes("pred[16]") == 16


def test_wire_factors():
    assert hlo_stats._wire_factor("all-reduce", 8) == pytest.approx(1.75)
    assert hlo_stats._wire_factor("all-gather", 8) == pytest.approx(0.875)
    assert hlo_stats._wire_factor("reduce-scatter", 8) == 7.0
    assert hlo_stats._wire_factor("collective-permute", 2) == 1.0
    assert hlo_stats._wire_factor("all-reduce", 1) == 0.0


# ------------------------------------- engine programs (parse_compiled) ---


def _epilogue_program_bytes(in_dtype):
    """Parsed HBM traffic of an epilogue-fused SpMM program:
    ``gelu(A @ B + bias)`` with f32 accumulation, in/out in ``in_dtype``."""
    from repro.core import Epilogue, ExecutionConfig, build_plan, \
        execute_plan, random_csr
    from repro.obs import plan_min_bytes

    a = random_csr(jax.random.PRNGKey(3), 48, 32, nnz_per_row=(0, 8))
    n = 16
    plan = build_plan(a, method="merge", with_transpose=False)
    ex = ExecutionConfig(impl="xla", acc_dtype="float32",
                         epilogue=Epilogue(bias=True, activation="gelu"))
    vals = jax.ShapeDtypeStruct(a.vals.shape, in_dtype)
    b = jax.ShapeDtypeStruct((a.k, n), in_dtype)
    bias = jax.ShapeDtypeStruct((a.m,), in_dtype)
    r = hlo_stats.parse_compiled(
        lambda v, b2, bb: execute_plan(plan, v, b2, ex, bias=bb),
        vals, b, bias)
    model = plan_min_bytes(plan.meta, n, val_dtype=in_dtype.dtype.name
                           if hasattr(in_dtype, "dtype") else str(in_dtype))
    return r, model


def test_parse_compiled_epilogue_fused_bf16_acc_f32():
    """The fused bias+gelu mixed-precision serving program: the parser
    must see a real module whose HBM bytes are at least the
    compulsory-traffic model (the model is a lower bound).  No flops
    assertion: the gather/segment-sum SpMM lowering has no ``dot`` op,
    and the parser's flop leg counts contractions only."""
    r32, model32 = _epilogue_program_bytes(jnp.float32)
    r16, model16 = _epilogue_program_bytes(jnp.bfloat16)
    for r, model in ((r32, model32), (r16, model16)):
        assert r["hbm_bytes"] >= model
        assert r["collective_count"] == 0
    # half-width ins/outs must shrink both the model and the parsed
    # traffic: the f32 accumulator stays internal to the fusion.
    assert model16 < model32
    assert r16["hbm_bytes"] < r32["hbm_bytes"]


def test_parse_compiled_jit_wraps_plain_callables():
    def f(x):
        return (x @ x.T).sum()

    spec = jax.ShapeDtypeStruct((8, 4), jnp.float32)
    plain = hlo_stats.parse_compiled(f, spec)
    jitted = hlo_stats.parse_compiled(jax.jit(f), spec)
    assert plain["flops"] == jitted["flops"] == 2 * 8 * 8 * 4


def test_parse_compiled_detail_breakdown():
    """detail=True must attribute flops to computations (the scan body,
    not the entry) and surface the op histogram — additive keys only."""
    def f(x):
        y, _ = jax.lax.scan(lambda c, _: (c @ c, None), x, None, length=3)
        return y.sum()

    spec = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    plain = hlo_stats.parse_compiled(f, spec)
    r = hlo_stats.parse_compiled(f, spec, detail=True)
    assert {k: r[k] for k in plain} == plain
    assert r["computations"] and r["fusion_ops"]
    own = sum(c["flops"] for c in r["computations"].values())
    assert 0 < own <= r["flops"]        # trip scaling only in the total


def test_launch_hlo_stats_shim_reexports():
    from repro.launch import hlo_stats as shim
    assert shim.parse_module is hlo_stats.parse_module
    assert shim.parse_compiled is hlo_stats.parse_compiled
