"""Validate the trip-count-aware HLO parser against ground truth:
unrolled modules (exact flop counts) and hand-built collectives."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_stats


def _parse(fn, *specs):
    comp = jax.jit(fn).lower(*specs).compile()
    return hlo_stats.parse_module(comp.as_text())


def test_scan_flops_scaled_by_trip_count():
    def f(w, x):
        def step(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(step, x, None, length=7)
        return y.sum()

    r = _parse(f, jax.ShapeDtypeStruct((64, 64), jnp.float32),
               jax.ShapeDtypeStruct((8, 64), jnp.float32))
    assert r["flops"] == 7 * 2 * 8 * 64 * 64


def test_nested_scan_flops_multiply():
    def g(w, x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=5)
            return ci, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y.sum()

    r = _parse(g, jax.ShapeDtypeStruct((64, 64), jnp.float32),
               jax.ShapeDtypeStruct((8, 64), jnp.float32))
    assert r["flops"] == 15 * 2 * 8 * 64 * 64


def test_scan_matches_unrolled():
    """Scanned and unrolled versions of the same program must agree on
    flops (the whole point of trip-count scaling)."""
    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((4, 32), jnp.float32)

    def scanned(w, x):
        y, _ = jax.lax.scan(lambda c, _: (jnp.tanh(c @ w), None), x, None,
                            length=6)
        return y.sum()

    def unrolled(w, x):
        for _ in range(6):
            x = jnp.tanh(x @ w)
        return x.sum()

    rs = _parse(scanned, w, x)
    ru = _parse(unrolled, w, x)
    assert rs["flops"] == ru["flops"] == 6 * 2 * 4 * 32 * 32


def test_collective_wire_bytes():
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((len(jax.devices()),), ("model",))
    n = len(jax.devices())
    if n == 1:
        pytest.skip("single device — no collectives emitted")


def test_batch_dot_general_flops():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)

    r = _parse(f, jax.ShapeDtypeStruct((4, 8, 16), jnp.float32),
               jax.ShapeDtypeStruct((4, 16, 32), jnp.float32))
    assert r["flops"] == 2 * 4 * 8 * 16 * 32


def test_dtype_bytes_parsing():
    assert hlo_stats._type_bytes("bf16[8,64]{1,0}") == 8 * 64 * 2
    assert hlo_stats._type_bytes("(s32[], f32[8,64]{1,0})") == 4 + 8 * 64 * 4
    assert hlo_stats._type_bytes("pred[16]") == 16


def test_wire_factors():
    assert hlo_stats._wire_factor("all-reduce", 8) == pytest.approx(1.75)
    assert hlo_stats._wire_factor("all-gather", 8) == pytest.approx(0.875)
    assert hlo_stats._wire_factor("reduce-scatter", 8) == 7.0
    assert hlo_stats._wire_factor("collective-permute", 2) == 1.0
    assert hlo_stats._wire_factor("all-reduce", 1) == 0.0
