"""Pallas flash-attention kernel: allclose sweeps vs naive oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops


def naive(q, k, v):
    """q (b,s,h,dh), k/v (b,s,kv,dh) — causal GQA reference."""
    b, s, h, dh = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, dh)
    sc = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                    preferred_element_type=jnp.float32) * dh ** -0.5
    mask = jnp.tril(jnp.ones((s, s), bool))
    sc = jnp.where(mask[None, None, None], sc, -jnp.inf)
    p = jax.nn.softmax(sc, -1)
    o = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.transpose(0, 3, 1, 2, 4).reshape(b, s, h, dh).astype(q.dtype)


def _mk(b, s, h, kvh, dh, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (b, s, h, dh), dtype),
            jax.random.normal(ks[1], (b, s, kvh, dh), dtype),
            jax.random.normal(ks[2], (b, s, kvh, dh), dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
@pytest.mark.parametrize("b,s,h,kvh,dh", [
    (1, 256, 4, 4, 64),     # MHA
    (2, 128, 4, 2, 32),     # GQA g=2
    (1, 384, 8, 2, 64),     # GQA g=4, 3 blocks
])
def test_flash_kernel_sweep(b, s, h, kvh, dh, dtype):
    q, k, v = _mk(b, s, h, kvh, dh, dtype)
    got = ops.flash_attention(q, k, v, bq=128, bk=128)
    want = naive(q.astype(jnp.float32), k.astype(jnp.float32),
                 v.astype(jnp.float32))
    tol = dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)


def test_flash_kernel_ragged_seq_padding():
    """Non-block-multiple sequence lengths are padded & sliced back."""
    q, k, v = _mk(1, 200, 4, 4, 32, jnp.float32, seed=3)
    got = ops.flash_attention(q, k, v, bq=128, bk=128)
    want = naive(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_kernel_matches_model_flash():
    """Kernel agrees with the XLA blockwise implementation the model uses."""
    from repro.models.layers import flash_attention as xla_flash
    q, k, v = _mk(2, 256, 4, 2, 64, jnp.float32, seed=5)
    a = ops.flash_attention(q, k, v)
    b_ = xla_flash(q, k, v, q_chunk=64, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                               rtol=2e-5, atol=2e-5)
