"""Matrix corpus subsystem: .mtx round-trips, generator determinism,
row-length statistics, suite registry.

Acceptance (ISSUE 2): write→read round-trip exact on pattern and ≤1e-6 on
values; generator suites are seed-deterministic.
"""
import io

import numpy as np
import pytest

from repro.matrices import (MatrixSpec, banded, block_sparse, compute_stats,
                            get_suite, power_law, read_mtx, register_spec,
                            specs_from_mtx_dir, suite_names, uniform,
                            uniform_irregular, write_mtx)


def _np(x):
    return np.asarray(x)


def _pattern_equal(a, b):
    nnz = int(_np(a.row_ptr)[-1])
    assert a.shape == b.shape
    np.testing.assert_array_equal(_np(a.row_ptr), _np(b.row_ptr))
    np.testing.assert_array_equal(_np(a.col_ind)[:nnz], _np(b.col_ind)[:nnz])
    return nnz


# ------------------------------------------------------------- mmio ---


@pytest.mark.parametrize("gen", [
    lambda: power_law(3, 64, 48, 4.0),
    lambda: uniform_irregular(4, 32, 32, 5),
    lambda: banded(5, 40, 40, 2),
])
def test_mtx_roundtrip_real(gen):
    a = gen()
    buf = io.StringIO()
    write_mtx(buf, a, comments=["roundtrip test"])
    buf.seek(0)
    r = read_mtx(buf)
    nnz = _pattern_equal(a, r)
    np.testing.assert_allclose(_np(r.vals)[:nnz], _np(a.vals)[:nnz],
                               atol=1e-6, rtol=0)


def test_mtx_roundtrip_pattern_field():
    a = uniform(6, 16, 24, 3)
    buf = io.StringIO()
    write_mtx(buf, a, field="pattern")
    buf.seek(0)
    r = read_mtx(buf)
    nnz = _pattern_equal(a, r)
    np.testing.assert_array_equal(_np(r.vals)[:nnz], np.ones(nnz))


def test_mtx_roundtrip_integer_field():
    import dataclasses
    import jax.numpy as jnp
    a = uniform(7, 8, 8, 2)
    nnz = int(_np(a.row_ptr)[-1])
    ints = np.arange(1, a.nnz_pad + 1, dtype=np.float64)
    a = dataclasses.replace(a, vals=jnp.asarray(ints, jnp.float32))
    buf = io.StringIO()
    write_mtx(buf, a, field="integer")
    buf.seek(0)
    r = read_mtx(buf)
    _pattern_equal(a, r)
    np.testing.assert_array_equal(_np(r.vals)[:nnz], ints[:nnz])


def test_mtx_symmetric_expansion():
    text = """%%MatrixMarket matrix coordinate real symmetric
% lower triangle of a 3x3
3 3 4
1 1 2.0
2 1 -1.0
3 2 0.5
3 3 4.0
"""
    a = read_mtx(io.StringIO(text))
    dense = _np(a.to_dense())
    want = np.array([[2.0, -1.0, 0.0],
                     [-1.0, 0.0, 0.5],
                     [0.0, 0.5, 4.0]], np.float32)
    np.testing.assert_allclose(dense, want, atol=1e-6)


def test_mtx_skew_symmetric_expansion():
    text = """%%MatrixMarket matrix coordinate real skew-symmetric
2 2 1
2 1 3.0
"""
    a = read_mtx(io.StringIO(text))
    np.testing.assert_allclose(_np(a.to_dense()),
                               [[0.0, -3.0], [3.0, 0.0]], atol=1e-6)


def test_mtx_duplicates_summed():
    text = """%%MatrixMarket matrix coordinate real general
2 2 3
1 1 1.5
1 1 2.5
2 2 1.0
"""
    a = read_mtx(io.StringIO(text))
    np.testing.assert_allclose(_np(a.to_dense()),
                               [[4.0, 0.0], [0.0, 1.0]], atol=1e-6)


def test_mtx_rejects_garbage():
    with pytest.raises(ValueError, match="not a MatrixMarket"):
        read_mtx(io.StringIO("garbage\n1 1 1\n"))
    with pytest.raises(ValueError, match="coordinate"):
        read_mtx(io.StringIO("%%MatrixMarket matrix array real general\n"))
    with pytest.raises(ValueError, match="declared"):
        read_mtx(io.StringIO(
            "%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1\n"))
    with pytest.raises(ValueError, match="bounds"):
        read_mtx(io.StringIO(
            "%%MatrixMarket matrix coordinate real general\n2 2 1\n3 1 1\n"))


def test_mtx_file_roundtrip(tmp_path):
    a = block_sparse(9, 32, 32, block=4, keep=0.5)
    path = tmp_path / "bs.mtx"
    write_mtx(path, a)
    r = read_mtx(path)
    nnz = _pattern_equal(a, r)
    np.testing.assert_allclose(_np(r.vals)[:nnz], _np(a.vals)[:nnz],
                               atol=1e-6, rtol=0)


# ------------------------------------------------------- generators ---


@pytest.mark.parametrize("gen", [
    lambda s: power_law(s, 64, 64, 4.0),
    lambda s: banded(s, 64, 64, 3, fill=0.7),
    lambda s: block_sparse(s, 64, 64, block=8, keep=0.3),
    lambda s: uniform(s, 64, 64, 5),
    lambda s: uniform_irregular(s, 64, 64, 5),
])
def test_generators_seed_deterministic(gen):
    a, b = gen(42), gen(42)
    np.testing.assert_array_equal(_np(a.row_ptr), _np(b.row_ptr))
    np.testing.assert_array_equal(_np(a.col_ind), _np(b.col_ind))
    np.testing.assert_array_equal(_np(a.vals), _np(b.vals))
    c = gen(43)
    assert not (np.array_equal(_np(a.row_ptr), _np(c.row_ptr))
                and np.array_equal(_np(a.col_ind), _np(c.col_ind)))


def test_generator_columns_sorted_unique_in_bounds():
    for a in (power_law(1, 48, 40, 6.0), block_sparse(2, 48, 40, block=8),
              banded(3, 48, 40, 4)):
        rp, ci = _np(a.row_ptr), _np(a.col_ind)
        assert rp[-1] <= a.nnz_pad
        for r in range(a.m):
            cols = ci[rp[r]:rp[r + 1]]
            assert (np.diff(cols) > 0).all()      # sorted and unique
            if cols.size:
                assert 0 <= cols[0] and cols[-1] < a.k


# ------------------------------------------------------------ stats ---


def test_stats_uniform_regular():
    s = compute_stats(uniform(1, 32, 64, 8))
    assert s.d == 8.0 and s.cv == 0.0 and s.gini == pytest.approx(0.0)
    assert s.max_len == 8 and s.nnz == 32 * 8


def test_stats_imbalance_ordering():
    flat = compute_stats(banded(2, 256, 256, 3))
    heavy = compute_stats(power_law(2, 256, 256, 4.0, alpha=1.2))
    assert heavy.gini > flat.gini
    assert heavy.cv > flat.cv
    assert 0.0 <= flat.gini < heavy.gini < 1.0


def test_stats_empty_matrix():
    s = compute_stats(uniform(1, 16, 16, 0))
    assert s.nnz == 0 and s.d == 0.0 and s.cv == 0.0 and s.gini == 0.0


# ----------------------------------------------------------- suites ---


def test_suite_registry():
    assert {"mini", "paper", "pruned"} <= set(suite_names())
    mini = get_suite("mini")
    assert len(mini) == 3
    assert len({sp.name for sp in get_suite("paper")}) == \
        len(get_suite("paper"))
    with pytest.raises(KeyError, match="unknown suite"):
        get_suite("nope")
    with pytest.raises(ValueError, match="duplicate"):
        register_spec(MatrixSpec(name=mini[0].name, build=mini[0].build))


def test_mini_suite_builds_deterministically():
    for spec in get_suite("mini"):
        a, b = spec(), spec()
        np.testing.assert_array_equal(_np(a.row_ptr), _np(b.row_ptr))
        np.testing.assert_array_equal(_np(a.col_ind), _np(b.col_ind))


def test_specs_from_mtx_dir(tmp_path):
    for i in range(2):
        write_mtx(tmp_path / f"mat{i}.mtx", uniform(i, 8, 8, 2))
    (tmp_path / "notes.txt").write_text("ignored")
    specs = specs_from_mtx_dir(tmp_path)
    assert [sp.name for sp in specs] == ["mat0", "mat1"]
    assert all(sp.family == "mtx" for sp in specs)
    a = specs[0]()
    assert a.shape == (8, 8)
