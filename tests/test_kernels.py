"""Per-kernel allclose sweeps against the ref.py pure-jnp oracles.

Shapes/dtypes swept per the brief; kernels run in interpret mode (the body
executes in Python on CPU — bit-level dataflow validation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ExecutionConfig, PlanPolicy, random_csr, spmm
from repro.kernels import ops, ref

MATRIX_KINDS = {
    "regular_long": (64, 96, 33),         # nnz_per_row fixed
    "irregular": (48, 64, (0, 24)),       # the paper's Type 1+2 driver
    "short_rows": (96, 64, (0, 4)),       # merge's home turf (Fig. 5b)
    "empty_heavy": (64, 32, (0, 2)),      # pathological empty-row case
    "single_row": (1, 128, 64),
    "single_col": (64, 1, 1),
}
NS = [1, 32, 64, 128, 160]   # B columns (tall-skinny regime + non-tile)
DTYPES = [jnp.float32, jnp.bfloat16]


def _mk(kind, n, dtype, seed=0):
    m, k, npr = MATRIX_KINDS[kind]
    a = random_csr(jax.random.PRNGKey(seed), m, k, nnz_per_row=npr,
                   dtype=dtype)
    b = jax.random.normal(jax.random.PRNGKey(seed + 1), (k, n), dtype)
    return a, b


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
@pytest.mark.parametrize("n", NS)
@pytest.mark.parametrize("kind", sorted(MATRIX_KINDS))
def test_merge_spmm_sweep(kind, n, dtype):
    a, b = _mk(kind, n, dtype)
    want = ref.spmm_dense_ref(a, b.astype(jnp.float32))
    got = ops.merge_spmm(a, b, t=8)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
@pytest.mark.parametrize("n", NS)
@pytest.mark.parametrize("kind", sorted(MATRIX_KINDS))
def test_rowsplit_spmm_sweep(kind, n, dtype):
    a, b = _mk(kind, n, dtype)
    want = ref.spmm_dense_ref(a, b.astype(jnp.float32))
    got = ops.rowsplit_spmm(a, b)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("t", [1, 3, 8, 17])
def test_merge_chunk_size_invariance(t):
    a, b = _mk("irregular", 64, jnp.float32)
    want = ref.spmm_dense_ref(a, b)
    got = ops.merge_spmm(a, b, t=t)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("tl", [4, 16])
def test_rowsplit_tl_invariance(tl):
    a, b = _mk("irregular", 64, jnp.float32)
    want = ref.spmm_dense_ref(a, b)
    got = ops.rowsplit_spmm(a, b, tl=tl)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_xla_impl_matches_pallas():
    a, b = _mk("irregular", 96, jnp.float32)
    for method in ("merge", "rowsplit"):
        p = spmm(a, b, PlanPolicy(method=method),
                 ExecutionConfig(impl="pallas"))
        x = spmm(a, b, PlanPolicy(method=method),
                 ExecutionConfig(impl="xla"))
        np.testing.assert_allclose(np.asarray(p), np.asarray(x),
                                   rtol=2e-5, atol=2e-5)


def test_spmm_grad_through_xla_impl():
    """The XLA dataflow is differentiable — used on the training path."""
    a, b = _mk("short_rows", 32, jnp.float32)

    def loss(bb):
        return jnp.sum(spmm(a, bb, PlanPolicy(method="merge"),
                            ExecutionConfig(impl="xla")) ** 2)

    g = jax.grad(loss)(b)
    # finite-difference check on a single coordinate
    eps = 1e-3
    e = jnp.zeros_like(b).at[3, 5].set(eps)
    fd = (loss(b + e) - loss(b - e)) / (2 * eps)
    np.testing.assert_allclose(float(g[3, 5]), float(fd), rtol=2e-2)


@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
@pytest.mark.parametrize(
    "sizes,din,dout",
    [((64, 0, 64, 128), 64, 96),
     ((8, 8, 8, 8), 16, 16),
     ((256,), 32, 48)],
)
def test_moe_group_gemm_sweep(sizes, din, dout, dtype):
    tt = 8
    e = len(sizes)
    sizes = jnp.asarray(sizes, jnp.int32)
    tok = int(sizes.sum())
    x = jax.random.normal(jax.random.PRNGKey(0), (tok, din), dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (e, din, dout), dtype)
    ge = jnp.asarray(np.repeat(np.arange(e), np.asarray(sizes)))
    want = ref.moe_group_gemm_ref(x.astype(jnp.float32),
                                  w.astype(jnp.float32), ge)
    got = ops.moe_group_gemm(x, w, sizes, tt=tt)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))
