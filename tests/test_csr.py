"""CSR container: roundtrips, invariants (property-based)."""
import jax
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install -r "
    "requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import from_dense, prune_to_csr, random_csr
from repro.core.csr import rows_from_row_ptr

jax.config.update("jax_platform_name", "cpu")


@st.composite
def dense_matrices(draw):
    m = draw(st.integers(1, 12))
    k = draw(st.integers(1, 12))
    density = draw(st.floats(0.0, 1.0))
    rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
    d = rng.standard_normal((m, k)) * (rng.random((m, k)) < density)
    return d.astype(np.float32)


@settings(max_examples=30, deadline=None)
@given(dense_matrices())
def test_from_dense_roundtrip(d):
    a = from_dense(d)
    np.testing.assert_array_equal(np.asarray(a.to_dense()), d)


@settings(max_examples=30, deadline=None)
@given(dense_matrices(), st.integers(0, 7))
def test_roundtrip_with_padding(d, extra_pad):
    nnz = int((d != 0).sum())
    a = from_dense(d, nnz_pad=max(nnz, 1) + extra_pad)
    np.testing.assert_array_equal(np.asarray(a.to_dense()), d)
    assert int(a.nnz()) == nnz


@settings(max_examples=30, deadline=None)
@given(dense_matrices())
def test_rows_from_row_ptr(d):
    a = from_dense(d)
    rows = np.asarray(rows_from_row_ptr(a.row_ptr, a.nnz_pad))
    want_rows, _ = np.nonzero(d)
    nnz = len(want_rows)
    if nnz:
        np.testing.assert_array_equal(rows[:nnz], want_rows)
    # padded tail must land out of range (row id == m) so epilogues drop it
    assert np.all(rows[nnz:] == d.shape[0])


def test_random_csr_row_lengths():
    a = random_csr(jax.random.PRNGKey(0), 50, 64, nnz_per_row=(2, 10))
    lengths = np.diff(np.asarray(a.row_ptr))
    assert lengths.min() >= 2 and lengths.max() <= 10
    # col indices sorted and unique within each row
    cols = np.asarray(a.col_ind)
    rp = np.asarray(a.row_ptr)
    for r in range(50):
        row_cols = cols[rp[r]:rp[r + 1]]
        assert np.all(np.diff(row_cols) > 0)


def test_prune_to_csr_keeps_top_magnitude():
    rng = np.random.default_rng(3)
    w = rng.standard_normal((16, 32)).astype(np.float32)
    a = prune_to_csr(w, keep_fraction=0.25)
    d = np.asarray(a.to_dense())
    kept = int((d != 0).sum())
    assert kept == 16 * 8
    # every kept entry must be among the row's top-8 magnitudes
    for r in range(16):
        thresh = np.sort(np.abs(w[r]))[-8]
        nz = d[r] != 0
        assert np.all(np.abs(w[r][nz]) >= thresh - 1e-6)
        np.testing.assert_array_equal(d[r][nz], w[r][nz])


def test_mean_row_length():
    a = random_csr(jax.random.PRNGKey(1), 10, 20, nnz_per_row=4)
    assert float(a.mean_row_length()) == pytest.approx(4.0)
