"""Online serving: bucket packer, continuous batcher, admission control.

Tentpole acceptance (ISSUE 10): every submitted request is served
exactly once, per-request rows are bit-identical to a solo forward at
the same bucket shape, padding waste is bounded by the ladder geometry,
and a warmed server neither replans nor recompiles (asserted against
the program-cache counter, not timing).  Satellites: ``microbatched``
pads ragged tails instead of raising (one compiled program across
ragged totals), overload sheds deterministically with exact
``outcome=shed`` accounting, transient execution failures retry through
``fault.retry``, and ``serve.py`` rejects no-effect flag combinations.

Timing-free by design: batching efficiency is asserted through executed
-batch *counts* (occupancy histogram deltas), never wall-clock — the
throughput gate lives in ``benchmarks/bench_serving.py``.
"""
import threading
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.sparse as S
from repro import obs
from repro.core import ExecutionConfig
from repro.engine import ProgramCache
from repro.runtime import steps as R
from repro.serving import (BucketLadder, RequestShed, Server,
                           ServerClosed, loadgen, pack)

EC = ExecutionConfig


# ------------------------------------------------- microbatched ragged ---


def _counted(calls):
    @jax.jit
    def fn(x):
        calls.append(x.shape)
        return {"out": x * 2.0, "sum": jnp.sum(x, axis=1)}

    return fn


def test_microbatched_ragged_tail():
    """5 rows / microbatch 2: tail of 1 pads to 2, outputs trim to 5."""
    calls = []
    run = R.microbatched(_counted(calls), 2)
    x = jnp.arange(10.0).reshape(5, 2)
    out = run(x)
    np.testing.assert_array_equal(np.asarray(out["out"]),
                                  np.asarray(x) * 2.0)
    np.testing.assert_array_equal(np.asarray(out["sum"]),
                                  np.asarray(x).sum(axis=1))
    assert calls == [(2, 2)], "padding must not add a second shape"


def test_microbatched_total_smaller_than_microbatch():
    calls = []
    run = R.microbatched(_counted(calls), 4)
    x = jnp.ones((1, 3))
    out = run(x)
    assert out["out"].shape == (1, 3)
    assert calls == [(4, 3)]


def test_microbatched_zero_remainder_untrimmed():
    """Exact division stays on the old path: no pad, no trim."""
    calls = []
    run = R.microbatched(_counted(calls), 3)
    x = jnp.arange(18.0).reshape(6, 3)
    out = run(x)
    assert out["out"].shape == (6, 3)
    np.testing.assert_array_equal(np.asarray(out["out"]),
                                  np.asarray(x) * 2.0)


def test_microbatched_single_trace_across_ragged_totals():
    """One jit trace serves totals 6, 5, 3, 1 at microbatch 3 — the
    no-recompile-for-ragged-batches property the serving loop needs."""
    calls = []
    run = R.microbatched(_counted(calls), 3)
    for total in (6, 5, 3, 1):
        out = run(jnp.ones((total, 4)))
        assert out["out"].shape == (total, 4)
    assert calls == [(3, 4)], f"expected one trace, saw {calls}"


def test_microbatched_strict_and_empty():
    run = R.microbatched(lambda x: x, 2, pad=False)
    with pytest.raises(ValueError, match="does not divide"):
        run(jnp.ones((5, 2)))
    with pytest.raises(ValueError, match="empty"):
        R.microbatched(lambda x: x, 2)(jnp.ones((0, 2)))


def test_microbatched_sparse_linear_bit_identical():
    """Padded-and-trimmed microbatched SpMM == whole-batch, bitwise."""
    rng = np.random.default_rng(7)
    w = jnp.asarray(rng.standard_normal((12, 20)), jnp.float32)
    sl = S.SparseLinear.from_dense(w, 0.4)
    x = jnp.asarray(rng.standard_normal((5, 3, 12)), jnp.float32)
    fn = jax.jit(lambda xi: sl(xi, EC(impl="xla")))
    got = R.microbatched(fn, 2)(x)
    want = jnp.stack([fn(x[i:i + 2])[j] for i, j in
                      ((0, 0), (0, 1), (2, 0), (2, 1), (4, 0))])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ------------------------------------------------------- bucket ladder ---


def test_ladder_rounding_and_caps():
    lad = BucketLadder.from_max(100, 8, min_len=8)
    assert lad.lengths == (8, 16, 32, 64, 128)
    assert lad.batches == (1, 2, 4, 8)
    assert lad.length_bucket(1) == 8
    assert lad.length_bucket(9) == 16
    assert lad.length_bucket(128) == 128
    assert lad.batch_bucket(3) == 4
    with pytest.raises(ValueError, match="exceeds the largest bucket"):
        lad.length_bucket(129)
    with pytest.raises(ValueError, match="positive"):
        lad.length_bucket(0)
    with pytest.raises(ValueError, match="ascending"):
        BucketLadder(lengths=(8, 8), batches=(1,))
    with pytest.raises(ValueError, match="empty"):
        BucketLadder(lengths=(), batches=(1,))


def test_ladder_waste_bounded():
    """Above the floor, a pow-2 rung is always < 2x its occupant."""
    lad = BucketLadder.from_max(256, 16, min_len=8)
    for n in range(8, 257):
        assert n <= lad.length_bucket(n) < 2 * n
    for c in range(1, 17):
        assert c <= lad.batch_bucket(c) < 2 * c


def test_pack_groups_fifo_chunks():
    lad = BucketLadder(lengths=(8, 16), batches=(1, 2, 4))
    pbs = pack([3, 12, 8, 15, 2, 9, 1, 5, 7], lad)
    by_len = {pb.length: [] for pb in pbs}
    for pb in pbs:
        by_len[pb.length].extend(pb.indices)
    assert by_len[8] == [0, 2, 4, 6, 7, 8]     # FIFO within bucket
    assert by_len[16] == [1, 3, 5]
    # 6 short requests at max_batch 4 -> chunks of 4 + 2
    assert [pb.batch for pb in pbs if pb.length == 8] == [4, 2]


# Randomized pack/schedule properties live in test_serving_property.py
# (hypothesis, absent in this container); fixed-seed twins stay here so
# the core invariants run everywhere.


def test_pack_exactly_once_fixed_cases():
    lad = BucketLadder.from_max(100, 8)
    for lengths in ([], [1], [100] * 20, [3, 99, 8, 8, 8, 8, 8, 1, 64],
                    list(range(1, 41))):
        served = sorted(i for pb in pack(lengths, lad)
                        for i in pb.indices)
        assert served == list(range(len(lengths)))


def test_poisson_schedule_deterministic():
    for seed in (0, 7, 12345):
        a = loadgen.poisson_schedule(12, 50.0, (1, 32), seed=seed)
        assert a == loadgen.poisson_schedule(12, 50.0, (1, 32),
                                             seed=seed)
        assert all(x.at_s <= y.at_s for x, y in zip(a, a[1:]))
        assert all(1 <= x.length <= 32 for x in a)
    assert loadgen.poisson_schedule(12, 50.0, (1, 32), seed=0) != \
        loadgen.poisson_schedule(12, 50.0, (1, 32), seed=1)


# ---------------------------------------------------- server end-to-end ---


def _scorer(seed=11, vocab=37, d_model=16, d_ff=48):
    """Tiny SpMM scorer with row-independent forward (xla impl)."""
    rng = np.random.default_rng(seed)
    state = {
        "embed": jnp.asarray(
            rng.normal(0, 0.1, (vocab, d_model)).astype(np.float32)),
        "mlp": S.prune_mlp(
            {"w1": jnp.asarray(
                rng.normal(0, 0.1, (d_model, d_ff)).astype(np.float32)),
             "w2": jnp.asarray(
                 rng.normal(0, 0.1, (d_ff, d_model)).astype(np.float32))},
            0.4),
    }

    def forward(state, tokens):
        h = state["embed"][tokens]
        h = h + S.sparse_mlp_apply(state["mlp"], h, None,
                                   exec=EC(impl="xla"))
        return h @ state["embed"].T

    return forward, state, vocab


def test_server_warmup_compiles_every_bucket_and_no_recompiles():
    fwd, state, vocab = _scorer()
    lad = BucketLadder(lengths=(4, 8), batches=(1, 2, 4))
    srv = Server(fwd, state, lad, name="t.warm")
    srv.warmup()
    st_ = srv.programs.stats()
    assert st_.misses == len(lad.shapes()) == 6
    assert sorted(srv.programs.keys()) == sorted(lad.shapes())
    srv.warmup()                      # idempotent: all hits
    assert srv.programs.stats().misses == 6
    assert srv.recompiles() == 0


def test_server_bit_identical_to_solo_forward():
    """Packed rows == a solo forward at the same bucket shape, bitwise.

    Requests of mixed lengths are submitted *before* start() so the
    batcher drains them into maximal packed batches; each result is
    compared to an independently jitted forward on a matrix holding only
    that request (same bucket shape, same padding)."""
    fwd, state, vocab = _scorer()
    lad = BucketLadder(lengths=(4, 8), batches=(1, 2, 4))
    lens = [3, 8, 4, 7, 1, 5]
    reqs = [loadgen.make_tokens(n, vocab, seed=100 + n) for n in lens]
    srv = Server(fwd, state, lad, name="t.bitid")
    futs = [srv.submit(t) for t in reqs]
    srv.start()
    outs = [f.result(timeout=120) for f in futs]
    srv.stop()
    assert srv.recompiles() == 0
    solo = jax.jit(fwd)
    for toks, out in zip(reqs, outs):
        n = len(toks)
        lb = lad.length_bucket(n)
        bb = lad.batch_bucket(1)      # row-independence: solo at bucket
        mat = np.zeros((bb, lb), np.int32)
        mat[0, :n] = toks
        want = np.asarray(solo(state, jnp.asarray(mat))[0][:n])
        assert out.shape == (n, vocab)
        np.testing.assert_array_equal(np.asarray(out), want)


def test_server_batches_instead_of_serving_solo():
    """16 same-length requests, max_batch 8 -> exactly 2 executed
    batches (occupancy histogram count delta), vs 16 for a naive
    ladder.  Count-based: no timing."""
    fwd, state, vocab = _scorer()
    occ = obs.registry.get("serve_batch_occupancy")
    reqs = [loadgen.make_tokens(6, vocab, seed=i) for i in range(16)]

    def count_batches(batches):
        srv = Server(fwd, state,
                     BucketLadder(lengths=(8,), batches=batches),
                     name=f"t.occ{len(batches)}")
        before = sum(c.count for c in occ.children())
        futs = [srv.submit(t) for t in reqs]
        srv.start()
        for f in futs:
            f.result(timeout=120)
        srv.stop()
        assert srv.recompiles() == 0
        return sum(c.count for c in occ.children()) - before

    assert count_batches((1, 2, 4, 8)) == 2
    assert count_batches((1,)) == 16


def test_server_sheds_deterministically_under_overload():
    """Bounded queue + expired deadlines: 20 offered, depth 2 -> all 20
    shed (18 at admission, 2 at dequeue), exact counter accounting."""
    fwd, state, vocab = _scorer()
    fam = obs.registry.counter("serve_requests_total",
                               "served requests by outcome",
                               labels=("outcome",))
    shed_c = fam.labels(outcome="shed")
    ok_c = fam.labels(outcome="ok")
    before_shed, before_ok = shed_c.value, ok_c.value
    srv = Server(fwd, state, BucketLadder(lengths=(4,), batches=(1, 2)),
                 queue_depth=2, name="t.shed")
    futs = [srv.submit(loadgen.make_tokens(4, vocab, seed=i),
                       deadline_s=1e-9) for i in range(20)]
    srv.start()
    outcomes = []
    for f in futs:
        with pytest.raises(RequestShed):
            f.result(timeout=120)
        outcomes.append("shed")
    srv.stop()
    assert len(outcomes) == 20
    assert shed_c.value - before_shed == 20
    assert ok_c.value - before_ok == 0
    with pytest.raises(ServerClosed):
        srv.submit(loadgen.make_tokens(4, vocab, seed=0))


def test_server_rejects_oversized_and_bad_requests():
    fwd, state, vocab = _scorer()
    srv = Server(fwd, state, BucketLadder(lengths=(4,), batches=(1,)),
                 name="t.rej")
    with pytest.raises(ValueError, match="exceeds the largest bucket"):
        srv.submit(np.zeros(5, np.int32))
    with pytest.raises(ValueError, match="1-D"):
        srv.submit(np.zeros((2, 3), np.int32))


def test_server_retries_transient_failures():
    """Two injected OSErrors then success: request completes, retries
    land on serve_retries_total."""
    fwd, state, vocab = _scorer()

    class Flaky(Server):
        fails = 2

        def _call_program(self, program, tokens):
            if self.fails:
                self.fails -= 1
                raise OSError("injected transient fault")
            return super()._call_program(program, tokens)

    retries = obs.registry.counter(
        "serve_retries_total", "transient execution failures retried")
    before = retries.value
    srv = Flaky(fwd, state, BucketLadder(lengths=(4,), batches=(1,)),
                retry_backoff_s=0.001, name="t.retry")
    fut = srv.submit(loadgen.make_tokens(3, vocab, seed=1))
    srv.start()
    out = fut.result(timeout=120)
    srv.stop()
    assert out.shape == (3, vocab)
    assert retries.value - before == 2


def test_server_exhausted_retries_fail_the_future():
    fwd, state, vocab = _scorer()

    class Dead(Server):
        def _call_program(self, program, tokens):
            raise OSError("permanent fault")

    srv = Dead(fwd, state, BucketLadder(lengths=(4,), batches=(1,)),
               retry_attempts=2, retry_backoff_s=0.001, name="t.dead")
    fut = srv.submit(loadgen.make_tokens(2, vocab, seed=1))
    srv.start()
    with pytest.raises(OSError, match="permanent"):
        fut.result(timeout=120)
    srv.stop()


def test_server_concurrent_submitters():
    """Many client threads racing submit: every request served once."""
    fwd, state, vocab = _scorer()
    srv = Server(fwd, state, BucketLadder(lengths=(8,), batches=(1, 4)),
                 name="t.conc").start()
    results = {}

    def client(i):
        n = 1 + (i % 8)
        fut = srv.submit(loadgen.make_tokens(n, vocab, seed=i))
        results[i] = fut.result(timeout=120).shape == (n, vocab)

    threads = [threading.Thread(target=client, args=(i,))
               for i in range(12)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    srv.stop()
    assert len(results) == 12 and all(results.values())
    assert srv.recompiles() == 0


# ------------------------------------------------------- program cache ---


def test_program_cache_hit_miss_evict():
    pc = ProgramCache(maxsize=2, name="t.pc")
    built = []

    def mk(k):
        return lambda: built.append(k) or k

    assert pc.get("a", mk("a")) == "a"
    assert pc.get("a", mk("a2")) == "a"
    assert pc.get("b", mk("b")) == "b"
    assert pc.get("c", mk("c")) == "c"          # evicts "a" (LRU)
    assert pc.keys() == ["b", "c"]
    s = pc.stats()
    assert (s.hits, s.misses, s.evictions, s.size) == (1, 3, 1, 2)
    assert built == ["a", "b", "c"]
    pc.clear()
    assert len(pc) == 0 and pc.stats().misses == 0


# -------------------------------------------------------- serve.py CLI ---


def test_serve_flags_require_prune_ffn():
    from repro.launch import serve
    for argv in (["--microbatch", "2"], ["--mesh", "1"],
                 ["--spmm-method", "merge"], ["--serve"]):
        with pytest.raises(SystemExit) as ei:
            serve.main(argv + ["--smoke"])
        assert ei.value.code == 2


def test_check_replans_raises_and_counts():
    from repro.launch import serve
    assert serve._check_replans(SimpleNamespace(misses=3),
                                SimpleNamespace(misses=3)) == 0
    before = serve._serve_replans.value
    with pytest.raises(RuntimeError, match="replanned: 2"):
        serve._check_replans(SimpleNamespace(misses=3),
                             SimpleNamespace(misses=5))
    assert serve._serve_replans.value - before == 2


# ------------------------------------------------------------- loadgen ---


def test_run_load_serves_schedule():
    fwd, state, vocab = _scorer()
    srv = Server(fwd, state, BucketLadder(lengths=(4, 8), batches=(1, 2)),
                 name="t.load").start()
    sched = loadgen.poisson_schedule(8, 500.0, (1, 8), seed=5)
    rep = loadgen.run_load(srv, sched, vocab=vocab, seed=5)
    srv.stop()
    assert (rep.n, rep.ok, rep.shed, rep.error) == (8, 8, 0, 0)
    assert rep.throughput_rps > 0 and rep.p99_us >= rep.p50_us
    assert srv.recompiles() == 0


def test_loadgen_rejects_degenerate_schedules():
    with pytest.raises(ValueError, match="positive request count"):
        loadgen.poisson_schedule(0, 1.0, (1, 4))
    with pytest.raises(ValueError, match="positive rate"):
        loadgen.poisson_schedule(1, 0.0, (1, 4))


def test_server_latency_phases_recorded():
    fwd, state, vocab = _scorer()
    fam = obs.registry.get("serve_request_latency_us")

    def counts():
        return {tuple(c.labels.items()): c.count
                for c in fam.children()}

    before = counts()
    srv = Server(fwd, state, BucketLadder(lengths=(4,), batches=(1,)),
                 name="t.lat").start()
    srv.submit(loadgen.make_tokens(3, vocab, seed=2)).result(timeout=120)
    srv.stop()
    after = counts()
    for phase in ("queue_wait", "assemble", "execute", "total"):
        key = (("phase", phase),)
        assert after.get(key, 0) - before.get(key, 0) == 1, phase
