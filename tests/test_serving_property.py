"""Property tests for the serving bucket packer and load generator.

Hypothesis-driven (skipped wholesale where hypothesis is absent, like
the other *_property modules): over arbitrary ladders and request
streams, ``pack`` serves every request exactly once in FIFO order
within its length bucket, batch rounding is exactly the ladder's rung,
padding waste stays < 2x per axis above the ladder floor, and Poisson
schedules are bit-deterministic under their seed.  Fixed-seed twins of
the core invariants run unconditionally in tests/test_serving.py.
"""
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.serving import BucketLadder, loadgen, pack

_ladders = st.builds(
    BucketLadder.from_max,
    st.integers(min_value=1, max_value=200),
    st.integers(min_value=1, max_value=12),
    min_len=st.integers(min_value=1, max_value=16))


@settings(deadline=None, max_examples=60)
@given(st.data(), _ladders)
def test_pack_serves_every_request_exactly_once(data, lad):
    lengths = data.draw(st.lists(
        st.integers(min_value=1, max_value=lad.max_len), max_size=40))
    pbs = pack(lengths, lad)
    served = [i for pb in pbs for i in pb.indices]
    assert sorted(served) == list(range(len(lengths)))


@settings(deadline=None, max_examples=60)
@given(st.data(), _ladders)
def test_pack_fifo_within_length_bucket(data, lad):
    lengths = data.draw(st.lists(
        st.integers(min_value=1, max_value=lad.max_len),
        min_size=1, max_size=40))
    by_bucket = {}
    for pb in pack(lengths, lad):
        by_bucket.setdefault(pb.length, []).extend(pb.indices)
    for lb, idxs in by_bucket.items():
        assert idxs == sorted(idxs)
        assert idxs == [i for i, n in enumerate(lengths)
                        if lad.length_bucket(n) == lb]


@settings(deadline=None, max_examples=60)
@given(st.data(), _ladders)
def test_pack_waste_bounded_by_ladder(data, lad):
    lengths = data.draw(st.lists(
        st.integers(min_value=1, max_value=lad.max_len),
        min_size=1, max_size=40))
    for pb in pack(lengths, lad):
        assert len(pb.indices) <= pb.batch <= lad.max_batch
        assert pb.batch == lad.batch_bucket(len(pb.indices))
        for i in pb.indices:
            assert pb.length == lad.length_bucket(lengths[i])
            # pow-2 rungs: < 2x waste above the ladder floor
            if lengths[i] >= lad.lengths[0]:
                assert pb.length < 2 * lengths[i]


@settings(deadline=None, max_examples=30)
@given(st.integers(min_value=0, max_value=2 ** 31))
def test_poisson_schedule_deterministic(seed):
    a = loadgen.poisson_schedule(12, 50.0, (1, 32), seed=seed)
    b = loadgen.poisson_schedule(12, 50.0, (1, 32), seed=seed)
    assert a == b
    assert all(x.at_s <= y.at_s for x, y in zip(a, a[1:]))
    assert all(1 <= x.length <= 32 for x in a)
