"""Deliverable (f) validation without compilation: every (arch × shape)
cell's input specs are well-formed ShapeDtypeStructs with the assigned
shapes — train shapes lower train_step inputs, decode shapes lower
serve-step inputs with a seq_len cache."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, SHAPES, get_config, shape_cells
from repro.launch.specs import input_specs


@pytest.mark.parametrize("arch", ARCHS)
def test_cells_match_assignment(arch):
    cells = shape_cells(arch)
    assert {"train_4k", "prefill_32k", "decode_32k"} <= set(cells)
    if arch in ("mixtral-8x22b", "mamba2-1.3b", "recurrentgemma-2b"):
        assert "long_500k" in cells     # sub-quadratic archs
    else:
        assert "long_500k" not in cells  # documented skip (DESIGN.md §5)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "musicgen-large",
                                  "mamba2-1.3b"])
def test_train_specs_shapes(arch):
    cfg = get_config(arch)
    shape = SHAPES["train_4k"]
    specs = input_specs(arch, "train_4k", microbatches=4)
    b = specs["batch"]
    assert b["labels"].shape == (4, 64, 4096)
    if cfg.input_mode == "tokens":
        assert b["tokens"].shape == (4, 64, 4096)
        assert b["tokens"].dtype == jnp.int32
    else:
        assert b["embeds"].shape == (4, 64, 4096, cfg.d_model)
    # state covers params + opt moments
    st = specs["state"]
    assert {"params", "opt"} <= set(st)
    n_leaves = len(jax.tree.leaves(st["params"]))
    assert n_leaves == len(jax.tree.leaves(st["opt"]["m"]))


@pytest.mark.parametrize("arch,shape_name", [
    ("qwen2-72b", "decode_32k"),
    ("mixtral-8x22b", "long_500k"),
    ("mamba2-1.3b", "long_500k"),
    ("recurrentgemma-2b", "decode_32k"),
])
def test_decode_specs_have_seqlen_cache(arch, shape_name):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    specs = input_specs(arch, shape_name)
    assert specs["pos"].shape == (shape.global_batch,)
    tok = specs["batch"].get("tokens")
    if tok is not None:
        assert tok.shape == (shape.global_batch, 1)  # ONE new token
    leaves = jax.tree.leaves(specs["caches"])
    assert leaves, "decode must carry a cache"
    kv = [l for l in leaves if l.ndim == 5]
    if cfg.num_heads:  # attention archs: (layers?, b, S, kv, dh)
        assert any(l.shape[2] == shape.seq_len for l in kv), \
            "KV cache capacity must equal seq_len"
    if arch == "mamba2-1.3b":
        # O(1) state instead of a KV cache — no seq_len-sized leaf at all
        assert not any(shape.seq_len in l.shape for l in leaves)
    # serving params are compute-dtype (bf16) — §Perf
    pl = [l for l in jax.tree.leaves(specs["params"])
          if jnp.issubdtype(l.dtype, jnp.floating)]
    assert all(l.dtype == cfg.cdtype for l in pl)


def test_prefill_specs_no_labels():
    specs = input_specs("granite-3-2b", "prefill_32k")
    assert "labels" not in specs["batch"]
    assert specs["batch"]["tokens"].shape == (32, 32768)


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_sane(arch):
    """Sanity: configured param counts are within 2× of the advertised
    model size (embedding-heavy small models overshoot their nameplate)."""
    nameplate = {
        "olmoe-1b-7b": 7e9, "mixtral-8x22b": 141e9, "command-r-35b": 35e9,
        "granite-3-2b": 2.5e9, "qwen2-72b": 72e9, "llama3.2-1b": 1.2e9,
        "musicgen-large": 3.3e9, "internvl2-76b": 76e9,
        "mamba2-1.3b": 1.3e9, "recurrentgemma-2b": 2.7e9,
    }[arch]
    n = get_config(arch).param_count()
    assert 0.5 * nameplate < n < 2.1 * nameplate, (arch, n)
