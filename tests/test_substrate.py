"""Substrate tests: optimizer, data pipeline, checkpoint manager,
compression, fault hooks."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install -r "
    "requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticLM
from repro.distributed import fault
from repro.optim import adamw, compression


# ------------------------------------------------------------- optimizer ---


def test_adamw_decreases_quadratic():
    cfg = adamw.AdamWConfig(learning_rate=0.1, warmup_steps=0,
                            total_steps=100, weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = adamw.init_state(params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, state, m = adamw.apply_updates(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_adamw_skips_nonfinite():
    cfg = adamw.AdamWConfig()
    params = {"w": jnp.ones(3)}
    state = adamw.init_state(params)
    p2, s2, m = adamw.apply_updates(
        params, {"w": jnp.array([1.0, jnp.nan, 1.0])}, state, cfg)
    assert float(m["skipped"]) == 1.0
    np.testing.assert_array_equal(np.asarray(p2["w"]), np.ones(3))
    assert int(s2["step"]) == 0  # step not consumed


def test_schedule_warmup_and_cosine():
    cfg = adamw.AdamWConfig(learning_rate=1.0, warmup_steps=10,
                            total_steps=110, min_lr_ratio=0.1)
    lr5 = float(adamw.schedule(cfg, jnp.asarray(5)))
    lr10 = float(adamw.schedule(cfg, jnp.asarray(10)))
    lr110 = float(adamw.schedule(cfg, jnp.asarray(110)))
    assert lr5 == pytest.approx(0.5)
    assert lr10 == pytest.approx(1.0)
    assert lr110 == pytest.approx(0.1, rel=1e-3)


# ------------------------------------------------------------ compression --


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_int8_ef_residual_bounds_error(seed):
    """Error feedback: value + residual is preserved to within one
    quantization step of the *combined* signal."""
    g = jax.random.normal(jax.random.PRNGKey(seed), (64,))
    res = jnp.zeros((64,))
    total_in = g + res
    out, res2 = compression.roundtrip({"g": g}, {"g": res})
    np.testing.assert_allclose(
        np.asarray(out["g"] + res2["g"]), np.asarray(total_in),
        rtol=1e-5, atol=1e-5)
    scale = float(jnp.max(jnp.abs(total_in))) / 127.0
    assert float(jnp.max(jnp.abs(res2["g"]))) <= scale * 0.5 + 1e-6


def test_int8_ef_converges_over_steps():
    """Accumulated compressed gradients track the true sum (unbiased-ish)."""
    key = jax.random.PRNGKey(0)
    res = {"g": jnp.zeros((32,))}
    true_sum = jnp.zeros((32,))
    comp_sum = jnp.zeros((32,))
    for i in range(50):
        g = jax.random.normal(jax.random.fold_in(key, i), (32,))
        true_sum += g
        out, res = compression.roundtrip({"g": g}, res)
        comp_sum += out["g"]
    resid = float(jnp.max(jnp.abs(comp_sum + res["g"] - true_sum)))
    assert resid < 1e-3


# ------------------------------------------------------------------ data ---


def test_data_deterministic_and_shard_consistent():
    cfg = DataConfig(vocab_size=101, seq_len=16, global_batch=8, seed=3)
    full = SyntheticLM(cfg, 0, 1)
    b0 = full.batch_at(7)
    b0b = SyntheticLM(cfg, 0, 1).batch_at(7)
    np.testing.assert_array_equal(np.asarray(b0["tokens"]),
                                  np.asarray(b0b["tokens"]))
    # two shards concatenate to the full batch (elastic re-shard invariant)
    s0 = SyntheticLM(cfg, 0, 2).batch_at(7)
    s1 = SyntheticLM(cfg, 1, 2).batch_at(7)
    np.testing.assert_array_equal(
        np.asarray(jnp.concatenate([s0["tokens"], s1["tokens"]], 0)),
        np.asarray(b0["tokens"]))


def test_data_labels_are_shifted_tokens():
    cfg = DataConfig(vocab_size=50, seq_len=12, global_batch=2)
    b = SyntheticLM(cfg).batch_at(0)
    assert b["tokens"].shape == (2, 12)
    assert b["labels"].shape == (2, 12)
    assert int(b["tokens"].max()) < 50


# ------------------------------------------------------------ checkpoint ---


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (4, 5)),
                       "b": jnp.zeros(5)},
            "opt": {"step": jnp.asarray(7, jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    mgr.save(10, t, extra={"data_step": 10})
    restored, step, extra = mgr.restore_latest(t)
    assert step == 10 and extra["data_step"] == 10
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), t, restored)


def test_checkpoint_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, t)
    assert mgr.all_steps() == [3, 4]


def test_checkpoint_corruption_fallback(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    t = _tree()
    mgr.save(1, t)
    mgr.save(2, t)
    # corrupt the newest step's first array
    victim = os.path.join(str(tmp_path), "step_00000002", "arr_00000_p00.npy")
    arr = np.load(victim)
    np.save(victim, arr + 1.0)
    restored, step, _ = mgr.restore_latest(t)
    assert step == 1  # fell back past the corrupt step


def test_checkpoint_atomicity_no_partial_dirs(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(5, _tree())
    entries = os.listdir(str(tmp_path))
    assert not any(e.endswith(".tmp0") for e in entries)
    assert "LATEST" in entries


# ----------------------------------------------------------------- fault ---


def test_straggler_watermark_flags_slow_steps():
    w = fault.StragglerWatermark(factor=2.0, warmup=3)
    for i in range(10):
        w.observe(i, 1.0)
    assert w.observe(10, 5.0) is True
    assert not w.observe(11, 1.0)
    assert w.flagged and w.flagged[0][0] == 10


def test_retry_retries_then_succeeds():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise IOError("transient")
        return "ok"

    assert fault.retry(flaky, attempts=5, backoff=0.0) == "ok"
    assert calls["n"] == 3
