"""API v1 surface: snapshot, deprecation shims, method registry,
SparseMatrix frontend, and the unified inline/planned resolution.

The snapshot test is the contract: a public name appearing or
disappearing unannounced fails here first.  The shim tests prove every
pre-v1 call form still returns bit-identical results while warning once;
the registry tests prove method dispatch is a registration, not an
if/elif edit (the ``rowgroup`` method exercises every dispatch surface
without any core change).
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro import (CSR, ExecutionConfig, PlanPolicy, SparseMatrix,
                   execute_plan, get_plan, spmm)
from repro.core import build_plan, random_csr
from repro.core.config import reset_deprecation_warnings
from repro.core.plan import pattern_fingerprint
from repro.engine.cache import PlanCache
from repro.kernels import ref, registry
from repro.tune.db import TuneDB, TuneRecord


def _csr(seed=0, m=32, k=24, npr=(0, 8)):
    return random_csr(jax.random.PRNGKey(seed), m, k, nnz_per_row=npr)


def _b(a, n=8, seed=1):
    return jax.random.normal(jax.random.PRNGKey(seed), (a.k, n))


XLA = ExecutionConfig(impl="xla")


# ------------------------------------------------------------- snapshot ---


EXPECTED_API = {
    "CSR",
    "Epilogue",
    "ExecutionConfig",
    "PlanPolicy",
    "ShardSpec",
    "SparseMatrix",
    "SpmmPlan",
    "__version__",
    "execute_plan",
    "get_plan",
    "spmm",
}


def test_api_surface_snapshot():
    """The v1 surface is frozen: update EXPECTED_API *deliberately* (and
    the README migration table) when the public API changes."""
    assert set(repro.__all__) == EXPECTED_API
    for name in EXPECTED_API:
        assert getattr(repro, name) is not None


# ----------------------------------------------------- deprecation shims ---


@pytest.fixture
def fresh_warnings():
    reset_deprecation_warnings()
    yield
    reset_deprecation_warnings()


def test_legacy_spmm_kwargs_warn_once_and_match(fresh_warnings):
    a = _csr(0)
    b = _b(a)
    want = np.asarray(spmm(a, b, PlanPolicy(method="merge"), XLA))
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        got = spmm(a, b, method="merge", impl="xla")
    assert {str(x.message).split(" is deprecated")[0] for x in w} == \
        {"spmm(method=...)", "spmm(impl=...)"}
    # bit-identical to the v1 spelling
    np.testing.assert_array_equal(np.asarray(got), want)
    # ...and each spelling warns only once per process
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        spmm(a, b, method="merge", impl="xla")
    assert not [x for x in w if issubclass(x.category, DeprecationWarning)]


def test_legacy_execute_plan_kwargs_match_exec(fresh_warnings):
    a = _csr(1)
    b = _b(a)
    plan = build_plan(a, method="rowsplit")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        old = execute_plan(plan, a.vals, b, impl="xla")
    assert any("execute_plan(impl=...)" in str(x.message) for x in w)
    new = execute_plan(plan, a.vals, b, XLA)
    np.testing.assert_array_equal(np.asarray(old), np.asarray(new))


@pytest.mark.parametrize("method", ["merge", "rowsplit", "rowgroup"])
def test_every_pre_v1_call_form_bit_identical(fresh_warnings, method):
    """Acceptance: pre-v1 spellings return bit-identical results to v1."""
    a = _csr(2, m=40, k=32, npr=(0, 10))
    b = _b(a, n=16)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        pairs = [
            (spmm(a, b, method=method, impl="xla"),
             spmm(a, b, PlanPolicy(method=method), XLA)),
            (spmm(a, b, method=method, impl="xla", plan="inline"),
             spmm(a, b, PlanPolicy(method=method), XLA, plan="inline")),
        ]
        plan = build_plan(a, method=method)
        pairs.append((execute_plan(plan, a.vals, b, impl="xla"),
                      execute_plan(plan, a.vals, b, XLA)))
    for old, new in pairs:
        np.testing.assert_array_equal(np.asarray(old), np.asarray(new))


def test_legacy_kwargs_conflict_with_policy_raises():
    a = _csr(3)
    b = _b(a)
    with pytest.raises(ValueError, match="not both"):
        spmm(a, b, PlanPolicy(method="merge"), method="rowsplit")
    with pytest.raises(ValueError, match="not both"):
        spmm(a, b, exec=XLA, impl="pallas")
    plan = build_plan(a, method="merge")
    with pytest.raises(ValueError, match="not both"):
        execute_plan(plan, a.vals, b, XLA, impl="xla")
    with pytest.raises(ValueError, match="not both"):
        PlanCache().get(a, PlanPolicy(method="merge"), method="merge")


def test_plan_policy_conflicts_with_supplied_plan_raise():
    a = _csr(4)
    b = _b(a)
    plan = build_plan(a, method="merge")
    with pytest.raises(ValueError, match="conflict"):
        spmm(a, b, PlanPolicy(method="rowsplit"), plan=plan)
    got = spmm(a, b, PlanPolicy(method="merge"), XLA, plan=plan)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.spmm_dense_ref(a, b)),
                               rtol=2e-5, atol=2e-5)


# ------------------------------------------------------- method registry ---


def test_unknown_method_error_lists_registered_names():
    a = _csr(5)
    b = _b(a)
    for fn in (lambda: spmm(a, b, PlanPolicy(method="bogus")),
               lambda: build_plan(a, method="bogus"),
               lambda: get_plan(a, PlanPolicy(method="bogus"))):
        with pytest.raises(ValueError) as ei:
            fn()
        msg = str(ei.value)
        assert "unknown SpMM method" in msg and "'bogus'" in msg
        for name in registry.method_names():
            assert name in msg


def test_registry_rejects_duplicate_registration():
    spec = registry.get_method("merge")
    with pytest.raises(ValueError, match="already registered"):
        registry.register_method(spec)
    registry.register_method(spec, override=True)   # tests may swap specs


def test_choose_auto_matches_paper_heuristic():
    from repro.core import Heuristic
    for seed in range(6):
        a = _csr(30 + seed, npr=(0, 4 + 8 * (seed % 2)))
        assert registry.choose_auto(a, Heuristic()) == \
            Heuristic().choose(a)


# -------------------------------------------- rowgroup via registry only ---


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_rowgroup_matches_dense_oracle(impl):
    a = _csr(6, m=48, k=40, npr=(0, 12))
    b = _b(a, n=16)
    want = np.asarray(ref.spmm_dense_ref(a, b))
    got = spmm(a, b, PlanPolicy(method="rowgroup"),
               ExecutionConfig(impl=impl))
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5, atol=2e-5)


def test_rowgroup_through_engine_cache_and_jit():
    cache = PlanCache()
    a = _csr(7, m=40, k=32, npr=(0, 10))
    b = _b(a)
    plan = cache.get(a, PlanPolicy(method="rowgroup"))
    assert plan.meta.method == "rowgroup" and plan.meta.extra
    assert cache.get(a, PlanPolicy(method="rowgroup")) is plan
    got = jax.jit(lambda p, v, bb: execute_plan(p, v, bb, XLA))(
        plan, a.vals, b)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.spmm_dense_ref(a, b)),
                               rtol=2e-5, atol=2e-5)


def test_rowgroup_grad_and_vmap():
    a = _csr(8, m=24, k=20, npr=(0, 6))
    plan = build_plan(a, method="rowgroup")
    bs = jax.random.normal(jax.random.PRNGKey(9), (3, a.k, 8))
    dense = jnp.asarray(a.to_dense())

    def loss(vals, b):
        return jnp.sum(execute_plan(plan, vals, b, XLA) ** 2)

    gv, gb = jax.grad(loss, argnums=(0, 1))(a.vals, bs)
    gd = jax.grad(lambda b: jnp.sum((dense @ b) ** 2))(bs)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(gd),
                               rtol=1e-4, atol=1e-4)
    # values-cotangent vs the dense oracle, compared through the pattern
    gvd = jax.grad(lambda d: jnp.sum(jnp.einsum("mk,bkn->bmn",
                                                d, bs) ** 2))(dense)
    got_gv = np.asarray(dataclasses.replace(a, vals=gv).to_dense())
    mask = np.asarray(a.to_dense()) != 0
    np.testing.assert_allclose(got_gv[mask], np.asarray(gvd)[mask],
                               rtol=1e-4, atol=1e-4)
    got = jax.vmap(lambda b: execute_plan(plan, a.vals, b, XLA))(bs)
    want = jnp.einsum("mk,bkn->bmn", dense, bs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_rowgroup_rejects_global_l_pad():
    a = _csr(10)
    with pytest.raises(ValueError, match="per row group"):
        build_plan(a, method="rowgroup", l_pad=64)


def test_rowgroup_inline_under_trace_raises():
    a = _csr(11)
    b = _b(a)
    with pytest.raises(ValueError, match="host-side"):
        jax.jit(lambda aa, bb: spmm(aa, bb, PlanPolicy(method="rowgroup"),
                                    plan="inline"))(a, b)


def test_rowgroup_tunedb_exact_replay():
    """An exact TuneDB record naming rowgroup drives the auto ladder."""
    a = _csr(12, npr=(0, 6))
    from repro.matrices import compute_stats
    s = compute_stats(a)
    db = TuneDB(backend="test")
    db.record(pattern_fingerprint(a),
              TuneRecord(method="rowgroup", merge_us=30.0, rowsplit_us=20.0,
                         m=s.m, k=s.k, d=s.d, cv=s.cv, n=8,
                         timings={"merge": 30.0, "rowsplit": 20.0,
                                  "rowgroup": 10.0}))
    plan = PlanCache().get(a, PlanPolicy(tunedb=db))
    assert plan.meta.method == "rowgroup"


def test_tune_pattern_times_all_registered_methods():
    from repro.tune import tune_pattern
    a = _csr(13, m=16, k=16, npr=(0, 4))
    rec = tune_pattern(a, n=4, warmup=0, repeat=1)
    assert set(rec.timings) == set(registry.method_names())
    assert rec.method == min(rec.timings, key=rec.timings.get)


# ------------------------------------------------- SparseMatrix frontend ---


def test_sparse_matrix_matmul_matches_dense():
    a = _csr(14, m=40, k=32, npr=(0, 10))
    b = _b(a, n=16)
    A = SparseMatrix.from_csr(a)
    want = np.asarray(ref.spmm_dense_ref(a, b))
    np.testing.assert_allclose(np.asarray(A @ b), want, rtol=2e-5,
                               atol=2e-5)
    assert A.spmm_plan is None               # lazily planned via the cache
    planned = A.plan(PlanPolicy(method="rowsplit"))
    assert planned.method == "rowsplit"
    np.testing.assert_allclose(np.asarray(planned @ b), want, rtol=2e-5,
                               atol=2e-5)


def test_sparse_matrix_from_dense_and_with_vals():
    rng = np.random.default_rng(0)
    dense = rng.standard_normal((24, 16)) * (rng.random((24, 16)) < 0.3)
    A = SparseMatrix.from_dense(dense.astype(np.float32)).plan()
    b = jax.random.normal(jax.random.PRNGKey(15), (16, 8))
    np.testing.assert_allclose(np.asarray(A @ b),
                               dense.astype(np.float32) @ np.asarray(b),
                               rtol=2e-5, atol=2e-5)
    A2 = A.with_vals(2.0 * A.vals)
    assert A2.spmm_plan is A.spmm_plan       # pattern frozen: plan survives
    np.testing.assert_allclose(np.asarray(A2 @ b), 2 * np.asarray(A @ b),
                               rtol=1e-5, atol=1e-5)


def test_sparse_matrix_is_jit_safe_pytree():
    A = SparseMatrix.from_csr(_csr(16)).plan()
    b = _b(A.data)

    @jax.jit
    def f(mtx, bb):
        return mtx @ bb

    from repro import engine
    misses0 = engine.cache_stats().misses
    y1 = f(A, b)
    y2 = f(A.with_vals(2.0 * A.vals), b)
    assert engine.cache_stats().misses == misses0, "jit replanned"
    np.testing.assert_allclose(np.asarray(y2), 2 * np.asarray(y1),
                               rtol=1e-5, atol=1e-5)
    leaves = jax.tree.leaves(A)
    assert all(isinstance(x, jax.Array) for x in leaves)


def test_sparse_matrix_unplanned_under_jit_raises():
    A = SparseMatrix.from_csr(_csr(17))
    b = _b(A.data)
    with pytest.raises(ValueError, match="outside jit"):
        jax.jit(lambda m, bb: m @ bb)(A, b)


def test_sparse_matrix_plan_shape_mismatch_raises():
    plan = build_plan(_csr(18, m=16, k=16, npr=(0, 4)))
    with pytest.raises(ValueError, match="built for pattern"):
        SparseMatrix(_csr(19, m=8, k=8, npr=(0, 2)), plan)


def test_sparse_matrix_grad_flows_to_vals():
    A = SparseMatrix.from_csr(_csr(20, m=16, k=12, npr=(1, 4))).plan()
    b = _b(A.data, n=4)

    def loss(vals):
        return jnp.sum((A.with_vals(vals).matmul(b, XLA)) ** 2)

    g = jax.grad(loss)(A.vals)
    dense = jnp.asarray(A.to_dense())
    gd = jax.grad(lambda d: jnp.sum((d @ b) ** 2))(dense)
    # compare through the pattern: scatter sparse grads densely
    got = np.asarray(A.with_vals(g).to_dense())
    rows = np.asarray(A.data.col_ind)  # noqa: F841 (pattern sanity below)
    mask = np.asarray(A.to_dense()) != 0
    np.testing.assert_allclose(got[mask], np.asarray(gd)[mask],
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------- unified inline/planned resolve ---


def test_inline_and_planned_paths_resolve_identically():
    """The pre-v1 bug: the inline path resolved method='auto' through the
    module-global heuristic, bypassing the TuneDB ladder the planned path
    used — the same matrix could run different kernels depending on the
    calling convention.  Both now funnel through PlanPolicy.resolve."""
    from repro import engine
    from repro.core import Heuristic

    a = _csr(21, m=64, k=512, npr=30)        # d=30: analytic → rowsplit
    b = _b(a)
    from repro.matrices import compute_stats
    s = compute_stats(a)
    db = TuneDB(backend="test")
    db.record(pattern_fingerprint(a),
              TuneRecord(method="merge", merge_us=10.0, rowsplit_us=20.0,
                         m=s.m, k=s.k, d=s.d, cv=s.cv, n=8))
    assert Heuristic().choose(a) == "rowsplit"

    calls = []
    spec = registry.get_method("merge")
    counted = dataclasses.replace(
        spec, inline=lambda *args, **kw: calls.append("merge")
        or spec.inline(*args, **kw))
    registry.register_method(counted, override=True)
    try:
        engine.set_tunedb(db)
        # planned path: TuneDB exact hit → merge
        assert get_plan(a).meta.method == "merge"
        # inline path must resolve through the same ladder → merge too
        spmm(a, b, exec=XLA, plan="inline")
        assert calls == ["merge"]
    finally:
        engine.set_tunedb(None)
        registry.register_method(spec, override=True)


def test_inline_explicit_l_pad_still_validated():
    a = random_csr(jax.random.PRNGKey(22), 8, 32, nnz_per_row=16)
    b = _b(a)
    with pytest.raises(ValueError, match="silently drop"):
        spmm(a, b, PlanPolicy(method="rowsplit", l_pad=8), plan="inline")


def test_inline_honors_policy_tl():
    """The inline path must receive the resolved tl, not recompute its
    own default — for rowgroup, tl shapes the group pads themselves."""
    a = _csr(23, m=24, k=20, npr=(0, 6))
    b = _b(a)
    want = np.asarray(ref.spmm_dense_ref(a, b))
    for method in ("rowsplit", "rowgroup"):
        got = spmm(a, b, PlanPolicy(method=method, tl=8), XLA,
                   plan="inline")
        np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5,
                                   atol=2e-5, err_msg=method)
    plan = build_plan(a, method="rowgroup", tl=8)
    assert plan.meta.tl == 8
    assert all(l % 8 == 0 for _, l in plan.meta.extra)


def test_unregistered_tunedb_method_degrades_not_crashes():
    """A stale DB naming a method this process doesn't have must degrade
    to the heuristic rung (with a warning), not crash every plan."""
    a = _csr(24)
    from repro.matrices import compute_stats
    s = compute_stats(a)
    db = TuneDB(backend="test")
    db.record(pattern_fingerprint(a),
              TuneRecord(method="plugin_method", merge_us=1.0,
                         rowsplit_us=2.0, m=s.m, k=s.k, d=s.d, cv=s.cv,
                         n=8))
    with pytest.warns(UserWarning, match="unregistered method"):
        plan = build_plan(a, tunedb=db)
    assert plan.meta.method in registry.method_names()
    # ...and the class rung still gets consulted: a twin pattern in the
    # same (m, k, d, cv) class with a valid record drives the choice,
    # even though the exact record is broken.
    twin = _csr(124)            # different seed, same family/shape
    s2 = compute_stats(twin)
    db.record(pattern_fingerprint(twin),
              TuneRecord(method="rowsplit", merge_us=9.0, rowsplit_us=1.0,
                         m=s2.m, k=s2.k, d=s2.d, cv=s2.cv, n=8))
    cls_method = db.lookup_class_for(a)
    if cls_method is not None:       # twin landed in a's binned class
        with pytest.warns(UserWarning, match="unregistered method"):
            plan2 = build_plan(a, tunedb=db)
        assert plan2.meta.method == cls_method


def test_ensure_spmm_plans_preserves_pinned_sparse_matrix_method():
    from repro.runtime import steps as R
    A = SparseMatrix.from_csr(_csr(25)).plan(PlanPolicy(method="rowgroup"))
    tree = {"w": A, "dense": jnp.ones(3)}
    out = R.ensure_spmm_plans(tree)
    assert out["w"].method == "rowgroup"
    # an explicit policy still overrides
    out2 = R.ensure_spmm_plans(tree, policy=PlanPolicy(method="merge"))
    assert out2["w"].method == "merge"
    # un-planned matrices get planned
    out3 = R.ensure_spmm_plans({"w": SparseMatrix.from_csr(_csr(25))})
    assert out3["w"].spmm_plan is not None


def test_sparse_linear_rejects_policy_heuristic_mix():
    from repro.core import Heuristic
    from repro.models.sparse import SparseLinear
    w = jnp.asarray(np.random.default_rng(0).standard_normal((16, 24)),
                    jnp.float32)
    with pytest.raises(ValueError, match="not both"):
        SparseLinear.from_dense(w, 0.3, heuristic=Heuristic(),
                                policy=PlanPolicy())
    sl = SparseLinear.from_dense(w, 0.3)
    with pytest.raises(ValueError, match="not both"):
        sl.with_plan(heuristic=Heuristic(), policy=PlanPolicy())


def test_plan_cache_legacy_kwargs_warn(fresh_warnings):
    a = _csr(26)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        PlanCache().get(a, method="merge")
    assert any("PlanCache.get(method=...)" in str(x.message) for x in w)


def test_auto_with_l_pad_survives_rowgroup_exact_record():
    """An 'auto' request carrying a global l_pad must not crash when the
    TuneDB exact record replays a method that rejects l_pad — it falls
    back to the analytic choice (the caller never chose rowgroup)."""
    a = _csr(27, npr=(0, 6))
    from repro.matrices import compute_stats
    s = compute_stats(a)
    lmax = int(np.diff(np.asarray(a.row_ptr)).max())
    db = TuneDB(backend="test")
    db.record(pattern_fingerprint(a),
              TuneRecord(method="rowgroup", merge_us=2.0, rowsplit_us=3.0,
                         m=s.m, k=s.k, d=s.d, cv=s.cv, n=8))
    # without the user l_pad the record replays fine
    assert build_plan(a, tunedb=db).meta.method == "rowgroup"
    plan = build_plan(a, tunedb=db, l_pad=lmax + 2)
    assert plan.meta.method in ("merge", "rowsplit")
    # explicit rowgroup + l_pad still raises: the user asked for it
    with pytest.raises(ValueError, match="per row group"):
        build_plan(a, method="rowgroup", l_pad=lmax + 2)


def test_plan_override_tl_conflict_raises():
    a = _csr(28)
    b = _b(a)
    plan = build_plan(a, method="rowsplit")
    with pytest.raises(ValueError, match="conflict"):
        spmm(a, b, PlanPolicy(tl=plan.meta.tl + 8), plan=plan)
    got = spmm(a, b, PlanPolicy(tl=plan.meta.tl), XLA, plan=plan)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.spmm_dense_ref(a, b)),
                               rtol=2e-5, atol=2e-5)


def test_replan_after_pattern_surgery_rederives_l_pad():
    """Replaying plan statics must not break pattern surgery: when the
    new pattern outgrows the old l_pad, re-derive instead of raising."""
    from repro.models.sparse import SparseLinear
    from repro.runtime import steps as R

    short = random_csr(jax.random.PRNGKey(40), 8, 64, nnz_per_row=4,
                       pad_to=128)
    long_ = random_csr(jax.random.PRNGKey(41), 8, 64, nnz_per_row=16,
                       pad_to=128)
    sl = SparseLinear(short, None).with_plan(
        policy=PlanPolicy(method="rowsplit"))
    assert sl.plan.meta.l_pad == 4
    surgered = dataclasses.replace(sl, weight=long_, plan=sl.plan)
    refixed = surgered.with_plan()
    assert refixed.plan.meta.method == "rowsplit"
    assert refixed.plan.meta.l_pad == 16
    # same through ensure_spmm_plans on a bare SparseMatrix leaf
    A = SparseMatrix(short).plan(PlanPolicy(method="rowsplit"))
    out = R.ensure_spmm_plans({"w": dataclasses.replace(A, data=long_)})
    assert out["w"].spmm_plan.meta.l_pad == 16


def test_replan_preserves_tuned_statics():
    """Re-attaching plans (checkpoint restore path) must replay the full
    tuned statics — method AND t/tl/l_pad — not just the method."""
    from repro.models.sparse import SparseLinear
    from repro.runtime import steps as R

    a = _csr(29, npr=(0, 6))
    lmax = int(np.diff(np.asarray(a.row_ptr)).max())
    tuned = PlanPolicy(method="rowsplit", l_pad=lmax + 8)
    A = SparseMatrix.from_csr(a).plan(tuned)
    assert A.spmm_plan.meta.l_pad == lmax + 8
    out = R.ensure_spmm_plans({"w": A})
    assert out["w"].spmm_plan.meta.l_pad == lmax + 8

    w = jnp.asarray(np.random.default_rng(1).standard_normal((16, 24)),
                    jnp.float32)
    sl = SparseLinear.from_dense(w, 0.3, policy=PlanPolicy(
        method="rowsplit", l_pad=8))
    stripped = dataclasses.replace(sl, plan=None)
    refixed = R.ensure_spmm_plans({"w": dataclasses.replace(
        sl, plan=sl.plan)})["w"]
    assert refixed.plan.meta == sl.plan.meta
    assert stripped.with_plan().plan is not None
