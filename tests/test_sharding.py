"""Sharding rules: divisibility fallbacks, param/cache specs, constraint
no-op behaviour, elastic validation."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_smoke_config
from repro.distributed import elastic, sharding as sh
from repro.models import model as M


def _mesh(shape=(1, 1), axes=("data", "model")):
    # 1 CPU device → 1×1 mesh; rules are still exercised (everything falls
    # back to replication via the divisibility check)
    from repro.launch.mesh import make_mesh
    return make_mesh(shape, axes)


def test_fit_drops_nondivisible_axes():
    mesh = _mesh()
    assert sh._fit(mesh, 10, "data") == "data"  # size 1 divides anything
    # emulate divisibility logic directly with a fake bigger axis size
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    assert sh._fit(FakeMesh, 49155, ("data",)) is None   # granite vocab
    assert sh._fit(FakeMesh, 49152, ("data",)) == "data"


def test_fit_partial_axis_drop():
    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}
    # 128 % (2*16) == 0 → keeps both; 24 % 32 != 0, 24 % 2 == 0 → pod only
    assert sh._fit(FakeMesh, 128, ("pod", "data")) == ("pod", "data")
    assert sh._fit(FakeMesh, 24, ("pod", "data")) == "pod"


def test_param_pspec_rules():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    cfg = get_smoke_config("llama3.2-1b")
    params = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    specs = {jax.tree_util.keystr(path): sh.param_pspec(path, leaf, FakeMesh)
             for path, leaf in flat}
    # embed (256, 64): vocab 256 % 16 == 0 → data; d 64 % 16 == 0 → model
    assert specs["['embed']"] == P("data", "model")
    # norm scales replicated
    assert specs["['final_norm']['scale']"] == P(None)
    # stacked attention weights: leading layer dim unsharded
    wq = [v for k, v in specs.items() if "wq" in k][0]
    assert wq == P(None, "data", "model")


def test_moe_expert_weights_not_expert_sharded():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    cfg = get_smoke_config("olmoe-1b-7b")
    params = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    for path, leaf in flat:
        ks = jax.tree_util.keystr(path)
        if "moe" in ks and "w1" in ks:
            spec = sh.param_pspec(path, leaf, FakeMesh)
            # (layers, E, d, ff): expert dim replicated, d→data, ff→model
            assert spec[0] is None and spec[1] is None


def test_cache_pspec_seq_sharding():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    class Leaf:
        def __init__(self, shape):
            self.shape = shape

    path = (jax.tree_util.DictKey("k"),)
    # batch divisible → batch over dp, seq over model
    spec = sh.cache_pspec(path, Leaf((16, 128, 32768, 8, 128)), FakeMesh)
    assert spec == P(None, "data", "model", None, None)
    # batch=1 (long_500k) → seq over dp+model
    spec = sh.cache_pspec(path, Leaf((56, 1, 524288, 8, 128)), FakeMesh)
    assert spec == P(None, None, ("data", "model"), None, None)


def test_constrain_is_noop_without_mesh():
    x = jnp.ones((4, 4))
    y = sh.constrain(x, "dp", None)
    assert y is x


def test_constrain_applies_under_mesh():
    mesh = _mesh()
    with sh.use_mesh(mesh):
        assert sh.active_mesh() is mesh
        y = jax.jit(lambda x: sh.constrain(x, "dp", None))(jnp.ones((4, 4)))
        np.testing.assert_array_equal(np.asarray(y), np.ones((4, 4)))
    assert sh.active_mesh() is None


def test_elastic_validate():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    class Smaller:
        axis_names = ("data", "model")
        shape = {"data": 12, "model": 16}

    probs = elastic.validate_elastic_resize(FakeMesh, Smaller, 256)
    assert any("not divisible" in p for p in probs)
    probs = elastic.validate_elastic_resize(FakeMesh, Smaller, 252)
    assert probs == []


def test_elastic_reshard_roundtrip():
    mesh = _mesh()
    params = {"w": jnp.arange(16.0).reshape(4, 4)}
    out = elastic.reshard_params(params, mesh)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(params["w"]))
